"""End-to-end driver: federated training of a language model over the
Modified UDP transport.

Each FL client is a (simulated) pod training an LM on its own data shard;
between rounds, model deltas are packetized, int8-compressed with error
feedback, and shipped through lossy WAN links with the paper's MUDP
reliability. The server runs weighted FedAvg, checkpoints every round, and a
straggler deadline keeps slow clients from stalling the fleet.

Default is a CPU-friendly ~1M-param xLSTM so the example finishes in
minutes; ``--scale 100m`` instantiates a ~100M-param model for a real run
(same code path).

  PYTHONPATH=src python examples/fl_train_lm.py --rounds 6 --clients 3
"""

import argparse
import dataclasses
import os
import sys
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager, FLJournal
from repro.configs import get_config, smoke_variant
from repro.core import (BernoulliLoss, FederatedSystem, FLClient, FLConfig,
                        Link, Simulator, TransportConfig, WAN_LINK)
from repro.data import federated_partitions
from repro.models import model as M
from repro.optim import AdamW, constant

SERVER = "10.0.0.1"


def model_config(scale: str):
    base = smoke_variant(get_config("xlstm-350m"))
    if scale == "tiny":
        return base
    if scale == "100m":
        return dataclasses.replace(
            base, num_layers=16, d_model=640, num_heads=4, head_dim=160,
            vocab_size=50304, slstm_every=8)
    raise ValueError(scale)


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--rounds", type=int, default=6)
    ap.add_argument("--clients", type=int, default=3)
    ap.add_argument("--local-steps", type=int, default=8)
    ap.add_argument("--scale", choices=["tiny", "100m"], default="tiny")
    ap.add_argument("--loss-rate", type=float, default=0.05)
    ap.add_argument("--codec", default="int8",
                    choices=["raw", "hex", "int8", "topk"])
    ap.add_argument("--non-iid", type=float, default=0.3)
    ap.add_argument("--straggler", action="store_true",
                    help="make the last client 10x slower + round deadline")
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()

    cfg = model_config(args.scale)
    n_params = None
    opt = AdamW(schedule=constant(2e-3), weight_decay=0.0)
    loss_fn = M.loss_fn(cfg, remat_policy="none")

    @jax.jit
    def local_step(state, batch):
        step = M.make_train_step(cfg, opt)
        return step(state, batch)

    pipes = federated_partitions(cfg.vocab_size, 64, 8, args.clients,
                                 seed=0, non_iid=args.non_iid)

    def make_train_fn(idx):
        def train(params, round_idx, client):
            state = (jnp.zeros((), jnp.int32), params, opt.init(params))
            from repro.optim import TrainState
            state = TrainState(*state)
            losses = []
            for s in range(args.local_steps):
                batch = pipes[idx].batch(round_idx * args.local_steps + s)
                state, metrics = local_step(state, batch)
                losses.append(float(metrics["loss"]))
            return state.params, {"first_loss": losses[0],
                                  "last_loss": losses[-1]}
        return train

    # WAN topology with IID Bernoulli loss on every uplink.
    sim = Simulator()
    clients = []
    for i in range(args.clients):
        addr = f"10.0.1.{10 + i}"
        up = Link(WAN_LINK["data_rate_bps"], WAN_LINK["delay_ns"],
                  BernoulliLoss(p=args.loss_rate, seed=i))
        down = Link(WAN_LINK["data_rate_bps"], WAN_LINK["delay_ns"])
        sim.connect(addr, SERVER, up, down)
        tt = 2_000_000_000 * (10 if (args.straggler
                                     and i == args.clients - 1) else 1)
        clients.append(FLClient(addr, make_train_fn(i), train_time_ns=tt,
                                weight=1.0))

    global_params = M.init(cfg, jax.random.PRNGKey(0))
    n_params = sum(int(np.prod(np.asarray(l).shape))
                   for l in jax.tree_util.tree_leaves(global_params))
    print(f"model: {cfg.name}-derived, {n_params/1e6:.1f}M params, "
          f"{args.clients} clients, codec={args.codec}, "
          f"loss_rate={args.loss_rate}")

    fl_cfg = FLConfig(
        aggregation="fedavg",
        send_deltas=True,
        error_feedback=(args.codec in ("int8", "topk")),
        transport=TransportConfig(kind="mudp", codec=args.codec, mtu=9000,
                                  timeout_ns=3_000_000_000, max_retries=3),
        round_deadline_ns=(90_000_000_000 if args.straggler else None),
    )
    system = FederatedSystem(sim, SERVER, clients, global_params, fl_cfg)

    ckpt_dir = args.ckpt_dir or tempfile.mkdtemp(prefix="fl_ckpt_")
    mgr = CheckpointManager(ckpt_dir, keep=2)
    journal = FLJournal(os.path.join(ckpt_dir, "journal.jsonl"))

    def on_round_end(result, params):
        path = mgr.save(result.round_idx, params,
                        {"round": result.round_idx})
        journal.round_finalized(result.round_idx, path, result.arrived,
                                result.failed)

    system.on_round_end = on_round_end

    eval_pipe = federated_partitions(cfg.vocab_size, 64, 16, 1, seed=77)[0]
    eval_batch = eval_pipe.batch(0)

    def eval_nll(params):
        return float(loss_fn(params, {k: jnp.asarray(v)
                                      for k, v in eval_batch.items()}))

    print(f"round -: eval NLL {eval_nll(system.global_params):.4f} "
          f"(ln V = {np.log(cfg.vocab_size):.2f})")
    for r in range(args.rounds):
        journal.round_started(r, [c.addr for c in clients])
        res = system.run_round()
        nll = eval_nll(system.global_params)
        print(f"round {r}: t={res.duration_ns/1e9:7.2f}s  "
              f"arrived={len(res.arrived)}/{args.clients} "
              f"retx={res.retransmissions:3d} "
              f"wire={res.bytes_sent/1e6:7.1f}MB  eval NLL {nll:.4f}",
              flush=True)

    print(f"\ncheckpoints + journal in {ckpt_dir}")
    print(f"resume round would be: {journal.resume_round()}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
