"""Fleet-scale scenario demo: 48 heterogeneous clients, MUDP vs the UDP
baseline, and sync (round barrier) vs async (FedBuff-style) scheduling.

The paper's topology is 2 clients on identical links; this example is the
"larger Federated learning system" its future work asks for: a seeded
cohort draw (fiber / lte / congested-edge), full participation, a
4-simulated-second deadline (sync: straggler cutoff; async: per-session
watchdog), and weighted FedAvg over whatever arrived.  With ``--mode
both`` (the default) it also prints the simulated time-to-target-loss for
each scheduling policy — the same 48 clients either way, so scheduling
really is the only variable: the round barrier waits out its slowest
client (or the deadline) every round, the async server aggregates
whenever K updates are buffered while clients re-enter at their own
cadence.

``--topology`` swaps the wiring (``repro.core.topology``): ``star`` is
the paper's single server, ``hier`` inserts ``--cells`` edge aggregators
that run local FedAvg and forward one merged update upstream, ``gossip``
drops the server entirely and lets peers exchange updates at degree
``--neighbors``.  Each run prints per-hop byte counters next to the
time-to-target-loss, so the hierarchy's root-link savings are visible in
the same breath as its convergence.

``--model`` swaps what the clients train (``repro.core.client_compute``):
``consensus`` is the analytic objective above, ``mlp`` trains the MNIST
MLP on non-IID dirichlet shards (offline: a seeded synthetic digit set)
and prints test accuracy per round.  ``--train-backend vmap`` batches
every round's local training into one ``jax.vmap`` call — identical
rounds, a fraction of the wall time.

``--control adaptive`` turns on the transport control plane
(``repro.core.control``): the server watches each client's telemetry
EWMAs and renegotiates its wire pipeline and FEC geometry between
transactions — fiber clients relax to light compression with no parity,
congested-edge clients escalate to heavy sparsification and dense parity.
Each run then prints per-cohort renegotiation counts next to the
time-to-target-loss.

  PYTHONPATH=src python examples/fleet_sim.py
  PYTHONPATH=src python examples/fleet_sim.py --mode async
  PYTHONPATH=src python examples/fleet_sim.py --topology hier --cells 6
  PYTHONPATH=src python examples/fleet_sim.py --model mlp --train-backend vmap
  PYTHONPATH=src python examples/fleet_sim.py --control adaptive
"""

from __future__ import annotations

import argparse

from repro.core import (FLConfig, FleetConfig, TransportConfig,
                        build_fleet_training, cohort_counts)

N_CLIENTS = 48
ROUNDS = {"sync": 3, "async": 12}      # ~comparable simulated horizons
TARGET_FRAC = 0.1                      # time-to-target = loss <= 10% of L0
NS = 1_000_000_000


def run(transport: str, mode: str, topology: str = "star", cells: int = 4,
        neighbors: int = 4, model: str = "consensus",
        train_backend: str = "python", control: str = "static") -> None:
    # The adaptive controller renegotiates pipeline specs in-band, which
    # needs a self-describing uplink (the PR 5 WireHeader names the
    # pipeline each payload was encoded with).  Gossip has no server core
    # to run a controller, so control degrades to static there.
    adaptive = control == "adaptive" and topology != "gossip"
    up_spec, down_spec = "delta|ef|topk(0.15)|int8(1024)", "int8(1024)"
    hops = None
    wire = {}
    if adaptive:
        if topology == "hier":
            # Hier takes per-hop specs; each tier's ServerCore then runs
            # its own controller over its own clients' telemetry.
            hops = (f"client->edge: {up_spec}; edge->client: {down_spec}; "
                    f"edge->root: {up_spec}; root->edge: {down_spec}")
        else:
            wire = {"uplink": up_spec, "downlink": down_spec}
    fleet = FleetConfig(n_clients=N_CLIENTS, seed=7, mode=mode, buffer_k=8,
                        round_deadline_ns=4 * NS, topology=topology,
                        cells=cells, neighbors=neighbors,
                        model=model, train_backend=train_backend,
                        hops=hops,
                        control="adaptive" if adaptive else "static")
    cfg = FLConfig(aggregation="fedavg",
                   transport=TransportConfig(kind=transport,
                                             timeout_ns=2 * NS,
                                             udp_deadline_ns=3 * NS,
                                             **wire))
    build = build_fleet_training(fleet, cfg)
    sim, system, profiles = build.sim, build.system, build.profiles
    objective = build.model
    loss0 = objective.loss(system.global_params)
    target = TARGET_FRAC * loss0
    crossed_ns = [None]

    shape = {"star": "star", "hier": f"hier x{fleet.cells} cells",
             "gossip": f"gossip k={fleet.neighbors}"}[topology]
    print(f"\n=== {transport} / {mode} / {shape} / {model}"
          f"[{train_backend}]: {N_CLIENTS} clients, "
          f"cohorts {cohort_counts(profiles)} ===")

    def on_round(res, params):
        loss = objective.loss(params)
        if crossed_ns[0] is None and loss <= target:
            crossed_ns[0] = sim.now_ns
        cut = sorted(set(res.roster) - set(res.arrived) - set(res.failed))
        acc = (f" | acc {objective.accuracy(params):.3f}"
               if hasattr(objective, "accuracy") else "")
        print(f"round {res.round_idx}: sampled {len(res.roster):2d} | "
              f"arrived {len(res.arrived):2d} | in-flight/cut {len(cut):2d} "
              f"| late-folded {res.late_folded} | "
              f"retx {res.retransmissions:3d} | "
              f"{res.bytes_sent / 1e6:.2f} MB on wire | "
              f"loss {loss:.4f}{acc}")

    system.on_round_end = on_round
    system.run_rounds(ROUNDS[mode])
    hops = " | ".join(f"{hop} {b / 1e6:.2f} MB"
                      for hop, b in sorted(sim.hop_bytes.items()))
    if build.trainer is not None:
        sizes = build.trainer.batch_sizes
        print(f"    [{train_backend}] {sum(sizes)} client-trainings in "
              f"{len(sizes)} batched calls (sizes {sizes})")
    if crossed_ns[0] is not None:
        print(f"--> {mode} time-to-target-loss ({TARGET_FRAC:.0%} of L0): "
              f"{crossed_ns[0] / 1e9:.2f} simulated seconds  [{hops}]")
    else:
        print(f"--> {mode}: target loss not reached in {ROUNDS[mode]} "
              f"rounds  [{hops}]")
    if control != "static":
        # Every ServerCore runs its own controller: one under star, one
        # per cell plus the root under hier.  Gossip has no server core,
        # so the control knob is a documented no-op there.
        cores = ([system.core] if hasattr(system, "core")
                 else [system.root.core] + [e.core for e in system.edges]
                 if hasattr(system, "edges") else [])
        by_addr: dict = {}
        for c in cores:
            for addr, n in c.renegotiations.items():
                by_addr[addr] = by_addr.get(addr, 0) + n
        cohort_of = {p.addr: p.cohort for p in profiles}
        by_cohort: dict = {}
        for addr, n in by_addr.items():
            key = cohort_of.get(addr, "edge")
            by_cohort[key] = by_cohort.get(key, 0) + n
        print(f"    [{control}] renegotiations by cohort: "
              f"{dict(sorted(by_cohort.items()))} "
              f"({sum(by_addr.values())} total)")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--mode", default="both",
                    choices=["sync", "async", "both"],
                    help="scheduling policy to demo (default: both, "
                         "printing time-to-target-loss for each)")
    ap.add_argument("--topology", default="star",
                    choices=["star", "hier", "gossip"],
                    help="fleet wiring: the paper's star, hierarchical "
                         "edge aggregation, or serverless gossip")
    ap.add_argument("--cells", type=int, default=4,
                    help="hier only: number of edge aggregators")
    ap.add_argument("--neighbors", type=int, default=4,
                    help="gossip only: target peer degree")
    ap.add_argument("--model", default="consensus",
                    choices=["consensus", "mlp"],
                    help="what the clients train: the analytic consensus "
                         "objective or the MNIST MLP on non-IID shards")
    ap.add_argument("--train-backend", default="python",
                    choices=["python", "vmap", "shard"],
                    help="how local training executes: per-client loop, "
                         "one vmapped batch per round, or vmap sharded "
                         "over the device mesh")
    ap.add_argument("--control", default="static",
                    choices=["static", "adaptive"],
                    help="transport control plane: static never "
                         "renegotiates; adaptive walks each client along "
                         "a loss-driven compression/FEC ladder and prints "
                         "per-cohort renegotiation counts")
    args = ap.parse_args()
    modes = ["sync", "async"] if args.mode == "both" else [args.mode]
    if args.topology == "gossip":
        modes = ["sync"]   # gossip has no server to schedule async rounds
    transports = (("mudp+fec",) if args.control == "adaptive"
                  else ("mudp", "udp"))
    for transport in transports:
        for mode in modes:
            run(transport, mode, topology=args.topology, cells=args.cells,
                neighbors=args.neighbors, model=args.model,
                train_backend=args.train_backend, control=args.control)
    print("\nSame seed, same cohorts — transport, scheduling, and wiring "
          "are the only variables. MUDP recovers every update where UDP's "
          "zero-filled gaps keep the loss high; the async server stops "
          "paying the round barrier for stragglers, so it reaches the "
          "target loss in a fraction of the simulated time. With "
          "--topology hier the per-hop counters show the root link "
          "carrying cells-many merged updates instead of the whole fleet; "
          "with --topology gossip there is no server link at all.")


if __name__ == "__main__":
    main()
