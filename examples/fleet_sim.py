"""Fleet-scale scenario demo: 48 heterogeneous clients, partial
participation, straggler cutoffs — MUDP vs the UDP baseline.

The paper's topology is 2 clients on identical links; this example is the
"larger Federated learning system" its future work asks for: a seeded
cohort draw (fiber / lte / congested-edge), 50% of clients sampled per
round, a 4-simulated-second server deadline that cuts congested-edge
stragglers, and weighted FedAvg over whatever arrived.

  PYTHONPATH=src python examples/fleet_sim.py
"""

from __future__ import annotations

from repro.core import (ConsensusObjective, FLConfig, FleetConfig,
                        TransportConfig, build_fleet, cohort_counts)

N_CLIENTS = 48
ROUNDS = 3
NS = 1_000_000_000


def run(transport: str) -> None:
    fleet = FleetConfig(n_clients=N_CLIENTS, seed=7,
                        participation_fraction=0.5,
                        round_deadline_ns=4 * NS)
    objective = ConsensusObjective(N_CLIENTS, 1024, seed=7)
    cfg = FLConfig(aggregation="fedavg",
                   transport=TransportConfig(kind=transport,
                                             timeout_ns=2 * NS,
                                             udp_deadline_ns=3 * NS))
    sim, system, profiles = build_fleet(fleet, objective.init_params(),
                                        objective.train_fn, cfg)
    print(f"\n=== {transport}: {N_CLIENTS} clients, cohorts "
          f"{cohort_counts(profiles)} ===")
    for _ in range(ROUNDS):
        res = system.run_round()
        cut = sorted(set(res.roster) - set(res.arrived) - set(res.failed))
        print(f"round {res.round_idx}: sampled {len(res.roster):2d} | "
              f"arrived {len(res.arrived):2d} | cut-at-deadline {len(cut):2d} "
              f"| late-folded {res.late_folded} | "
              f"retx {res.retransmissions:3d} | "
              f"{res.bytes_sent / 1e6:.2f} MB on wire | "
              f"loss {objective.loss(system.global_params):.4f}")


def main() -> None:
    for transport in ("mudp", "udp"):
        run(transport)
    print("\nSame seed, same cohorts, same per-round samples — the "
          "transport is the only variable. MUDP recovers every sampled "
          "update; UDP's zero-filled gaps keep the global loss high.")


if __name__ == "__main__":
    main()
