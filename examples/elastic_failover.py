"""Fault tolerance and elasticity at the FL layer, end to end:

 1. a client's uplink dies mid-training -> MUDP exhausts Y=3 retries, the
    round completes without it (straggler cutoff semantics);
 2. the health tracker benches the dead client and re-admits it after the
    cool-down — it rejoins and contributes again;
 3. a brand-new client joins elastically between rounds;
 4. the server "crashes" after round 2; a fresh process-equivalent restores
    from the atomic checkpoint + journal and resumes at the right round.

  PYTHONPATH=src python examples/elastic_failover.py
"""

import os
import sys
import tempfile

import numpy as np

from repro.checkpoint import CheckpointManager, FLJournal
from repro.core import (DropList, FederatedSystem, FLClient, FLConfig, Link,
                        NoLoss, Simulator, TransportConfig)

SERVER = "10.9.0.1"


def const_train(v):
    def fn(params, r, client):
        return {k: np.full_like(p, v) for k, p in params.items()}, {}
    return fn


def main() -> int:
    sim = Simulator()
    params = {"w": np.zeros((5_000,), np.float32)}
    dead_after_round0 = {(s, a) for s in range(1, 100) for a in range(0, 50)}

    clients = []
    for i, loss in ((0, NoLoss()), (1, NoLoss())):
        addr = f"10.9.0.{10 + i}"
        sim.connect(addr, SERVER, Link(1e8, 1_000_000, loss),
                    Link(1e8, 1_000_000))
        clients.append(FLClient(addr, const_train(float(i + 1)),
                                train_time_ns=1_000_000))

    cfg = FLConfig(aggregation="fedavg", broadcast_model=False,
                   unhealthy_after_failures=1, readmit_after_rounds=1,
                   transport=TransportConfig(timeout_ns=500_000_000))
    system = FederatedSystem(sim, SERVER, clients, params, cfg)
    for c in clients:
        c.params = params

    ckpt_dir = tempfile.mkdtemp(prefix="failover_")
    mgr = CheckpointManager(ckpt_dir, keep=3)
    journal = FLJournal(os.path.join(ckpt_dir, "journal.jsonl"))
    system.on_round_end = lambda res, p: journal.round_finalized(
        res.round_idx, mgr.save(res.round_idx, p), res.arrived, res.failed)

    print("round 0: both clients healthy")
    journal.round_started(0, [c.addr for c in clients])
    r0 = system.run_round()
    print(f"  arrived={r0.arrived} failed={r0.failed}")
    assert len(r0.arrived) == 2

    print("round 1: client .11's uplink goes dead (MUDP exhausts retries)")
    sim._links[("10.9.0.11", SERVER)].loss = DropList(dead_after_round0)
    journal.round_started(1, [c.addr for c in clients])
    r1 = system.run_round()
    print(f"  arrived={r1.arrived} failed={r1.failed}")
    assert r1.failed == ["10.9.0.11"]

    print("round 2: dead client is benched; a NEW client joins elastically")
    sim.connect("10.9.0.99", SERVER, Link(1e8, 1_000_000),
                Link(1e8, 1_000_000))
    system.add_client(FLClient("10.9.0.99", const_train(9.0),
                               train_time_ns=1_000_000))
    journal.round_started(2, [c.addr for c in system.pool.active(2)])
    r2 = system.run_round()
    print(f"  arrived={r2.arrived} benched={r2.skipped_unhealthy}")
    assert "10.9.0.11" in r2.skipped_unhealthy
    assert "10.9.0.99" in r2.arrived

    print("server crash! restoring from checkpoint + journal …")
    j2 = FLJournal(os.path.join(ckpt_dir, "journal.jsonl"))
    restored, meta = mgr.restore(params)
    resume = j2.resume_round()
    print(f"  restored checkpoint of round {meta['step']}, resume at round "
          f"{resume}")
    assert resume == 3
    np.testing.assert_allclose(restored["w"], system.global_params["w"])

    print("round 3 (post-restart): link healed -> .11 re-admitted")
    sim._links[("10.9.0.11", SERVER)].loss = NoLoss()
    # the crashed server process is gone: detach every old transport handler
    # before the restarted process installs its own
    sim.node(SERVER)._handlers.clear()
    for c in system.pool.clients.values():
        sim.node(c.addr)._handlers.clear()
    system2 = FederatedSystem(sim, SERVER, list(system.pool.clients.values()),
                              restored, cfg)
    for c in system2.pool.clients.values():
        c.params = restored
    r3 = system2.run_round(resume)
    print(f"  arrived={r3.arrived}")
    assert "10.9.0.11" in r3.arrived
    print("\nOK: failure detected, benched, elastic join, crash-restart, "
          "re-admission — all green.")
    return 0


if __name__ == "__main__":
    sys.exit(main())
