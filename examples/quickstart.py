"""Quickstart: the paper's experiment, end to end.

Reproduces §V of "A Modified UDP for Federated Learning Packet
Transmissions": a 3-node star (two clients, one server) over 5 Mbps /
2000 ms links, clients train a small MLP on (synthetic) MNIST, weights are
hex-encoded into packets with (X, Np, A) headers, and the Modified UDP
recovers the deliberately dropped packets — test cases 1, 2 and 3.

  PYTHONPATH=src python examples/quickstart.py
"""

import sys

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (DropList, FederatedSystem, FLClient, FLConfig, Link,
                        NoLoss, Simulator, TransportConfig)
from repro.data import SyntheticMnist

CLIENT1, CLIENT2, SERVER = "10.1.2.4", "10.1.2.6", "10.1.2.5"
PAPER_RATE, PAPER_DELAY = 5_000_000.0, 2_000_000_000  # 5 Mbps, 2000 ms


# ---------------------------------------------------------------------------
# The paper's client model: a small MLP on MNIST (Keras-equivalent, in JAX).
# ---------------------------------------------------------------------------
def init_mlp(rng, sizes=(784, 32, 10)):
    params = {}
    for i, (a, b) in enumerate(zip(sizes[:-1], sizes[1:])):
        rng, k = jax.random.split(rng)
        params[f"w{i}"] = (jax.random.normal(k, (a, b)) / np.sqrt(a)).astype(
            jnp.float32)
        params[f"b{i}"] = jnp.zeros((b,), jnp.float32)
    return params


def mlp_loss(params, x, y):
    h = x
    n = len(params) // 2
    for i in range(n):
        h = h @ params[f"w{i}"] + params[f"b{i}"]
        if i < n - 1:
            h = jax.nn.relu(h)
    logp = jax.nn.log_softmax(h)
    return -jnp.mean(jnp.take_along_axis(logp, y[:, None], axis=1))


@jax.jit
def sgd_epoch(params, x, y, lr=0.1):
    loss, g = jax.value_and_grad(mlp_loss)(params, x, y)
    return jax.tree_util.tree_map(lambda p, gi: p - lr * gi, params, g), loss


def make_train_fn(dataset, client_id):
    def train(params, round_idx, client):
        x, y = dataset.sample(256, client=client_id, step=round_idx)
        x, y = jnp.asarray(x), jnp.asarray(y)
        for _ in range(3):  # local epochs
            params, loss = sgd_epoch(params, x, y)
        return params, {"local_loss": float(loss)}
    return train


def accuracy(params, dataset):
    x, y = dataset.sample(1024, client=99, step=0)
    h = jnp.asarray(x)
    n = len(params) // 2
    for i in range(n):
        h = h @ jnp.asarray(params[f"w{i}"]) + jnp.asarray(params[f"b{i}"])
        if i < n - 1:
            h = jax.nn.relu(h)
    return float((jnp.argmax(h, 1) == jnp.asarray(y)).mean())


def main():
    print("=== Modified UDP for FL: paper quickstart ===")
    dataset = SyntheticMnist(seed=0)

    # Star topology, paper link parameters. Client 1's uplink deliberately
    # drops packet 2 on its first transmission (test case 1); to exercise
    # test case 2, add (3,0),(4,0)... to the drop list.
    sim = Simulator(trace=True)
    drop_tc1 = DropList({(2, 0)})
    sim.connect(CLIENT1, SERVER, Link(PAPER_RATE, PAPER_DELAY, drop_tc1),
                Link(PAPER_RATE, PAPER_DELAY))
    sim.connect(CLIENT2, SERVER, Link(PAPER_RATE, PAPER_DELAY, NoLoss()),
                Link(PAPER_RATE, PAPER_DELAY))

    global_params = init_mlp(jax.random.PRNGKey(0))
    clients = [
        FLClient(CLIENT1, make_train_fn(dataset, 1),
                 train_time_ns=1_000_000_000),
        FLClient(CLIENT2, make_train_fn(dataset, 2),
                 train_time_ns=1_000_000_000),
    ]
    cfg = FLConfig(
        aggregation="pairwise",                      # paper Eq. (1)
        transport=TransportConfig(kind="mudp", codec="hex",  # Algorithm I
                                  timeout_ns=6_000_000_000, max_retries=3),
        broadcast_model=False,                       # round 0: clients seeded
    )
    system = FederatedSystem(sim, SERVER, clients, global_params, cfg)
    for c in clients:
        c.params = global_params

    acc0 = accuracy(global_params, dataset)
    print(f"global model accuracy before round: {acc0:.3f}")
    res = system.run_round()

    print(f"\nround 0 complete at t={res.duration_ns/1e9:.2f}s (sim time)")
    print(f"  arrived: {res.arrived}   failed: {res.failed}")
    print(f"  packets sent={res.packets_sent} dropped={res.packets_dropped}"
          f" retransmissions={res.retransmissions}")
    acc1 = accuracy(system.global_params, dataset)
    print(f"global model accuracy after round:  {acc1:.3f}")

    print("\n--- transport trace (paper Figs 5/7 equivalent) ---")
    shown = 0
    for line in sim.trace_lines:
        if any(s in line for s in ("missing", "NACK", "DROP", "(0, 0,")):
            print(" ", line)
            shown += 1
        if shown > 14:
            break

    assert sorted(res.arrived) == [CLIENT1, CLIENT2], "recovery failed!"
    assert acc1 > acc0, "global model did not improve"
    print("\nOK: lost packet recovered, both clients aggregated, "
          "global model improved.")


if __name__ == "__main__":
    sys.exit(main())
