"""Transport ablation: every registered transport across loss rates — the
comparison the paper's future-work section calls for.

For each (transport, loss rate): one FL round of a small model over the
paper's 3-node topology. Reports round completion time, delivered fraction,
wire bytes, and global-model corruption (L2 error vs the lossless result).
The transport list comes from ``available_transports()``, so registering a
new protocol adds a row here with no edits.

  PYTHONPATH=src python examples/transport_ablation.py
"""

import sys

import numpy as np

from repro.core import (BernoulliLoss, FederatedSystem, FLClient, FLConfig,
                        Link, Simulator, TransportConfig,
                        available_transports)
from repro.core.packetizer import flatten_to_vector

SERVER = "10.1.2.5"


def const_train(value):
    def fn(params, round_idx, client):
        return {k: np.full_like(v, value) for k, v in params.items()}, {}
    return fn


def run(transport: str, p_loss: float, seed: int = 0):
    sim = Simulator()
    params = {"w": np.zeros((40_000,), np.float32)}
    clients = []
    for i in range(2):
        addr = f"10.1.2.{10 + i}"
        up = Link(1e8, 5_000_000, BernoulliLoss(p=p_loss, seed=seed + i))
        sim.connect(addr, SERVER, up, Link(1e8, 5_000_000))
        clients.append(FLClient(addr, const_train(float(i + 1)),
                                train_time_ns=1_000_000))
    cfg = FLConfig(
        aggregation="fedavg",
        transport=TransportConfig(kind=transport, timeout_ns=2_000_000_000,
                                  udp_deadline_ns=3_000_000_000),
        broadcast_model=False,
    )
    system = FederatedSystem(sim, SERVER, clients, params, cfg)
    for c in clients:
        c.params = params
    res = system.run_round()
    return system, res


def main() -> int:
    clean, _ = run("mudp", 0.0)
    target = flatten_to_vector(clean.global_params)

    print(f"{'transport':>9s} {'loss':>5s} {'t_round(s)':>10s} "
          f"{'arrived':>7s} {'retx':>5s} {'wireMB':>7s} {'L2err':>9s}")
    for p in (0.0, 0.05, 0.2):
        for tr in available_transports():
            system, res = run(tr, p)
            vec = flatten_to_vector(system.global_params)
            err = float(np.linalg.norm(vec - target))
            print(f"{tr:>9s} {p:5.2f} {res.duration_ns/1e9:10.3f} "
                  f"{len(res.arrived)}/2{'':>3s} {res.retransmissions:5d} "
                  f"{res.bytes_sent/1e6:7.2f} {err:9.4f}")
    print("\nUDP corrupts the global model as loss rises (zero-filled gaps);"
          "\nTCP recovers but pays handshake+windowing latency; MUDP "
          "recovers at\nnear-UDP latency, and mudp+fec trades ~1/B bandwidth "
          "for fewer\nretransmissions still.")
    return 0


if __name__ == "__main__":
    sys.exit(main())
