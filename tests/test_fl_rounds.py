"""End-to-end FL orchestration tests (paper Fig. 4 + at-scale features)."""

import numpy as np
import pytest

from repro.core.channel import BernoulliLoss, DropList, Link, NoLoss
from repro.core.rounds import (FederatedSystem, FLClient, FLConfig,
                               TransportConfig)
from repro.core.simulator import Simulator

SERVER = "10.1.2.5"


def make_params(seed=0, n=300):
    rng = np.random.default_rng(seed)
    return {"w": rng.standard_normal((n,)).astype(np.float32),
            "b": np.zeros((7,), dtype=np.float32)}


def const_train_fn(value):
    """Train step that outputs constant parameters (analytically checkable)."""
    def fn(params, round_idx, client):
        return ({k: np.full_like(v, value) for k, v in params.items()},
                {"loss": 0.0})
    return fn


def add_train_fn(delta):
    def fn(params, round_idx, client):
        return ({k: v + delta for k, v in params.items()}, {"loss": 0.0})
    return fn


def build(n_clients=2, loss_models=None, cfg=None, train_fns=None,
          train_times=None, server_link=None):
    sim = Simulator()
    clients = []
    for i in range(n_clients):
        addr = f"10.1.2.{10 + i}"
        lm = (loss_models or {}).get(addr, NoLoss())
        up = Link(1e8, 1_000_000, lm)
        down = Link(1e8, 1_000_000, NoLoss())
        sim.connect(addr, SERVER, up, down)
        fn = (train_fns or {}).get(addr, add_train_fn(1.0))
        tt = (train_times or {}).get(addr, 1_000_000)
        clients.append(FLClient(addr, fn, train_time_ns=tt))
    system = FederatedSystem(sim, SERVER, clients, make_params(),
                             cfg or FLConfig())
    return sim, system, clients


class TestFedAvg:
    def test_uniform_average_of_constant_clients(self):
        cfg = FLConfig(aggregation="fedavg")
        sim, system, clients = build(
            n_clients=3,
            train_fns={"10.1.2.10": const_train_fn(1.0),
                       "10.1.2.11": const_train_fn(2.0),
                       "10.1.2.12": const_train_fn(3.0)})
        res = system.run_round()
        assert sorted(res.arrived) == ["10.1.2.10", "10.1.2.11", "10.1.2.12"]
        np.testing.assert_allclose(system.global_params["w"], 2.0, atol=1e-6)

    def test_weighted_average(self):
        cfg = FLConfig(aggregation="fedavg")
        sim, system, clients = build(
            n_clients=2,
            train_fns={"10.1.2.10": const_train_fn(0.0),
                       "10.1.2.11": const_train_fn(4.0)},
            cfg=cfg)
        clients[0].weight = 3.0
        clients[1].weight = 1.0
        system.run_round()
        np.testing.assert_allclose(system.global_params["w"], 1.0, atol=1e-6)


class TestPairwiseEq1:
    """Paper Eq. (1): sequential (client+server)/2 per arrival."""

    def test_matches_hand_fold(self):
        cfg = FLConfig(aggregation="pairwise")
        g0 = make_params()
        sim, system, _ = build(
            n_clients=2,
            train_fns={"10.1.2.10": const_train_fn(2.0),
                       "10.1.2.11": const_train_fn(6.0)},
            cfg=cfg)
        system.run_round()
        # fold in arrival order (same train time, same link -> .10 then .11)
        expect = (g0["w"] + 2.0) / 2.0
        expect = (expect + 6.0) / 2.0
        np.testing.assert_allclose(system.global_params["w"], expect,
                                   atol=1e-5)


class TestRecoveryInsideFL:
    def test_packet_loss_does_not_corrupt_global_model(self):
        """MUDP recovers, so lossy links give the SAME global model as
        lossless ones — the paper's central claim."""
        drops = {f"10.1.2.{10 + i}": BernoulliLoss(p=0.2, seed=i)
                 for i in range(3)}
        cfg = FLConfig(aggregation="fedavg")
        _, lossy, _ = build(3, loss_models=drops, cfg=cfg)
        _, clean, _ = build(3, cfg=cfg)
        lossy.run_round()
        clean.run_round()
        np.testing.assert_allclose(lossy.global_params["w"],
                                   clean.global_params["w"], atol=1e-6)

    def test_udp_with_loss_corrupts_the_update(self):
        cfg = FLConfig(aggregation="fedavg",
                       transport=TransportConfig(kind="udp", mtu=428,
                                                 udp_deadline_ns=10**9))
        drops = {"10.1.2.10": DropList({(2, 0)})}
        _, lossy, _ = build(1, loss_models=drops, cfg=cfg,
                            train_fns={"10.1.2.10": const_train_fn(5.0)})
        lossy.run_round()
        w = lossy.global_params["w"]
        assert (w == 0.0).any(), "zero-filled gap expected"
        assert not np.allclose(w, 5.0)


class TestStragglerCutoff:
    def test_deadline_excludes_slow_client(self):
        cfg = FLConfig(aggregation="fedavg", round_deadline_ns=2_000_000_000)
        sim, system, clients = build(
            n_clients=2,
            train_times={"10.1.2.10": 1_000_000,
                         "10.1.2.11": 10_000_000_000},  # 10 s straggler
            train_fns={"10.1.2.10": const_train_fn(1.0),
                       "10.1.2.11": const_train_fn(100.0)},
            cfg=cfg)
        res = system.run_round()
        assert res.arrived == ["10.1.2.10"]
        np.testing.assert_allclose(system.global_params["w"], 1.0, atol=1e-6)

    def test_late_update_folds_into_next_round_discounted(self):
        cfg = FLConfig(aggregation="fedavg", round_deadline_ns=2_000_000_000,
                       staleness_discount=0.5)
        sim, system, clients = build(
            n_clients=2,
            train_times={"10.1.2.10": 1_000_000,
                         "10.1.2.11": 5_000_000_000},
            train_fns={"10.1.2.10": const_train_fn(1.0),
                       "10.1.2.11": const_train_fn(9.0)},
            cfg=cfg)
        r0 = system.run_round()
        assert r0.arrived == ["10.1.2.10"]
        r1 = system.run_round()
        assert r1.late_folded == 1
        # round 1 contributions: fresh .10 (1.0, w=1), fresh .11 (9.0, w=1)
        # if it finished in time, plus the stale round-0 .11 (9.0, w=0.5).
        w = system.global_params["w"]
        assert np.all(w > 1.0) and np.all(w < 9.0)


class TestTransportFailureHealth:
    def test_dead_client_is_benched_and_readmitted(self):
        dead = {(s, a) for s in range(1, 2000) for a in range(0, 50)}
        cfg = FLConfig(aggregation="fedavg",
                       unhealthy_after_failures=1, readmit_after_rounds=2,
                       transport=TransportConfig(timeout_ns=500_000_000))
        sim, system, clients = build(
            n_clients=2,
            loss_models={"10.1.2.11": DropList(dead)},
            train_fns={"10.1.2.10": const_train_fn(1.0),
                       "10.1.2.11": const_train_fn(9.0)},
            cfg=cfg)
        r0 = system.run_round()
        assert "10.1.2.11" in r0.failed
        r1 = system.run_round()
        assert "10.1.2.11" in r1.skipped_unhealthy
        # readmitted after cool-down
        r3_roster = system.pool.active(r0.round_idx + 4)
        assert any(c.addr == "10.1.2.11" for c in r3_roster)


class TestElasticPool:
    def test_join_between_rounds(self):
        cfg = FLConfig(aggregation="fedavg")
        sim, system, clients = build(
            1, cfg=cfg, train_fns={"10.1.2.10": const_train_fn(2.0)})
        system.run_round()
        addr = "10.1.2.99"
        sim.connect(addr, SERVER, Link(1e8, 1_000_000), Link(1e8, 1_000_000))
        newc = FLClient(addr, const_train_fn(4.0), train_time_ns=1_000_000)
        system.add_client(newc)
        res = system.run_round()
        assert addr in res.arrived
        np.testing.assert_allclose(system.global_params["w"], 3.0, atol=1e-6)

    def test_leave_between_rounds(self):
        cfg = FLConfig(aggregation="fedavg")
        sim, system, clients = build(2, cfg=cfg)
        system.run_round()
        system.remove_client("10.1.2.11")
        res = system.run_round()
        assert res.arrived == ["10.1.2.10"]


class TestDeltaAndCompression:
    def test_delta_mode_equals_weight_mode_for_lossless(self):
        cfgw = FLConfig(aggregation="fedavg", send_deltas=False)
        cfgd = FLConfig(aggregation="fedavg", send_deltas=True)
        _, sys_w, _ = build(2, cfg=cfgw)
        _, sys_d, _ = build(2, cfg=cfgd)
        sys_w.run_round()
        sys_d.run_round()
        np.testing.assert_allclose(sys_w.global_params["w"],
                                   sys_d.global_params["w"], atol=1e-5)

    def test_int8_compressed_round_close_to_lossless(self):
        cfg8 = FLConfig(aggregation="fedavg",
                        transport=TransportConfig(codec="int8"))
        cfgr = FLConfig(aggregation="fedavg")
        _, s8, _ = build(2, cfg=cfg8)
        _, sr, _ = build(2, cfg=cfgr)
        s8.run_round()
        sr.run_round()
        err = np.abs(s8.global_params["w"] - sr.global_params["w"]).max()
        assert err < 0.05  # blockwise int8 on O(1) weights

    def test_hex_codec_paper_faithful_roundtrip(self):
        cfg = FLConfig(aggregation="fedavg",
                       transport=TransportConfig(codec="hex"))
        _, s, _ = build(2, cfg=cfg)
        _, ref, _ = build(2, cfg=FLConfig(aggregation="fedavg"))
        s.run_round()
        ref.run_round()
        np.testing.assert_allclose(s.global_params["w"],
                                   ref.global_params["w"], atol=1e-7)

    def test_hex_doubles_wire_bytes(self):
        cfg_hex = FLConfig(transport=TransportConfig(codec="hex"))
        cfg_raw = FLConfig(transport=TransportConfig(codec="raw"))
        _, sh, _ = build(1, cfg=cfg_hex)
        _, sr, _ = build(1, cfg=cfg_raw)
        rh = sh.run_round()
        rr = sr.run_round()
        assert rh.bytes_sent > 1.8 * rr.bytes_sent


class TestTcpTransport:
    def test_tcp_round_completes_but_slower_than_mudp(self):
        cfg_tcp = FLConfig(transport=TransportConfig(kind="tcp"))
        cfg_mudp = FLConfig(transport=TransportConfig(kind="mudp"))
        _, st_, _ = build(2, cfg=cfg_tcp)
        _, sm, _ = build(2, cfg=cfg_mudp)
        rt = st_.run_round()
        rm = sm.run_round()
        assert sorted(rt.arrived) == sorted(rm.arrived)
        np.testing.assert_allclose(st_.global_params["w"],
                                   sm.global_params["w"], atol=1e-6)
        assert rt.duration_ns > rm.duration_ns  # handshake + windowing
