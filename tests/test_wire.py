"""Wire-plane tests: composable pipelines, self-describing headers,
per-direction negotiation, and the codec regression/adversarial suite.

The acceptance bar for the redesign:

* a payload encoded under ANY registered pipeline spec decodes correctly
  from its WireHeader alone — no out-of-band config (negotiation);
* legacy single-codec pipelines are byte-identical to the historical
  ``repro.core.compression`` wire formats (the orchestrator-equivalence
  digests pin the end-to-end version of this);
* malformed/truncated payloads raise :class:`WireDecodeError` with a
  reason, and the server degrades them explicitly (zeros + counter);
* a full async fleet round runs with independently configured uplink and
  downlink pipelines, error-feedback state held in pipeline state.
"""

from __future__ import annotations

import warnings

import numpy as np
import pytest

from repro.core import wire
from repro.core.compression import (CODECS, Int8Codec, TopKCodec, make_codec)
from repro.core.rounds import (FederatedSystem, FLClient, FLConfig,
                               TransportConfig)
from repro.core.simulator import Simulator
from repro.core.wire import (DeltaStage, ErrorFeedbackStage, Pipeline,
                             WireDecodeError, WireError, WireHeader,
                             available_stages, decode_payload,
                             legacy_pipeline, parse_pipeline, parse_stage,
                             register_stage)

RNG = np.random.default_rng(7)


def vec(n: int = 4096) -> np.ndarray:
    return RNG.standard_normal(n).astype(np.float32)


# Specs chosen so every registered built-in stage appears at least once,
# alone where legal and composed where interesting.
NEGOTIATION_SPECS = [
    "raw",
    "hex",
    "int8(256)",
    "int8(1024)",
    "topk(0.05)",
    "delta|raw",
    "delta|ef|int8(128)",
    "topk(0.1)|int8(64)",
    "delta|ef|topk(0.01)|int8(1024)",
    "int8(128)|hex",
    "int8(256)|crc",
    "delta|ef|topk(0.05)|int8(512)|crc",
]


def test_negotiation_specs_cover_every_registered_stage():
    covered = set()
    for spec in NEGOTIATION_SPECS:
        for s in parse_pipeline(spec).stages:
            covered.add(s.name)
    assert covered == set(available_stages())


# --------------------------------------------------------------------------
# Parsing, registry, caps
# --------------------------------------------------------------------------
class TestSpecParsing:
    @pytest.mark.parametrize("spec", NEGOTIATION_SPECS)
    def test_canonical_spec_round_trips(self, spec):
        p = parse_pipeline(spec)
        assert parse_pipeline(p.spec).spec == p.spec

    def test_whitespace_tolerant(self):
        assert (parse_pipeline(" delta | ef | int8( 128 ) ").spec
                == "delta|ef|int8(128)")

    @pytest.mark.parametrize("bad", ["", "|", "zstd9", "topk(", "topk)x(",
                                     "topk(a)", "int8(0)", "topk(0)",
                                     "topk(1.5)", "delta|ef"])
    def test_bad_specs_raise(self, bad):
        with pytest.raises(WireError):
            parse_pipeline(bad)

    def test_duplicate_registration_refused(self):
        with pytest.raises(WireError, match="already registered"):
            register_stage("raw", wire.RawStage)

    def test_caps_derivation(self):
        p = parse_pipeline("delta|ef|topk(0.01)|int8(1024)")
        assert not p.caps.lossless          # topk+int8 are lossy
        assert p.caps.stateful              # delta + ef carry state
        assert p.caps.delta_domain
        assert p.caps.est_ratio == pytest.approx(
            2 * 0.01 * (0.25 + 1 / 1024), rel=1e-6)
        q = parse_pipeline("hex")
        assert q.caps.lossless and not q.caps.stateful
        assert q.caps.est_ratio == 2.0

    def test_ef_cannot_terminate(self):
        with pytest.raises(WireError, match="terminal"):
            Pipeline([DeltaStage(), ErrorFeedbackStage()])

    @pytest.mark.parametrize("spec", ["ef|delta|raw", "ef|delta|int8(64)"])
    def test_ef_cannot_wrap_delta(self, spec):
        # residual would become comp - (comp - ref) = ref: the whole
        # reference model re-injected every message.
        with pytest.raises(WireError, match="wrap delta"):
            parse_pipeline(spec)
        with pytest.raises(ValueError, match="wrap delta"):
            TransportConfig(uplink=spec)

    @pytest.mark.parametrize("spec", ["topk(0.01)|ef|int8(64)",
                                      "int8(64)|ef|raw"])
    def test_ef_cannot_follow_a_remapping_stage(self, spec):
        # after topk/int8 the coordinates are per-message: last round's
        # residual would be added onto this round's different positions.
        with pytest.raises(WireError, match="remapping"):
            parse_pipeline(spec)

    def test_third_party_delta_stage_declares_the_capability(self):
        class MyDelta(DeltaStage):
            name = "mydelta"
        p = Pipeline([MyDelta(), wire.RawStage()])
        assert p.caps.delta_domain
        st = p.new_state()
        p.set_reference(st, vec(8))       # attribute-driven, not isinstance
        assert "ref" in st.slots[0]


# --------------------------------------------------------------------------
# The header
# --------------------------------------------------------------------------
class TestWireHeader:
    def test_pack_unpack(self):
        h = WireHeader("delta|int8(64)", [b"", b"abc"], 1)
        packed = h.pack()
        h2, off = WireHeader.unpack(packed + b"BODY")
        assert (h2.spec, h2.stage_params, h2.dtype_code) == \
            ("delta|int8(64)", [b"", b"abc"], 1)
        assert off == len(packed)

    @pytest.mark.parametrize("mutate", [
        lambda d: b"",                               # empty
        lambda d: d[:4],                             # truncated header
        lambda d: b"XX" + d[2:],                     # bad magic
        lambda d: d[:2] + b"\x63" + d[3:],           # unknown version
        lambda d: d[:2] + b"\x00" + d[3:],           # version 0
        lambda d: d[:-3],                            # truncated params
    ])
    def test_malformed_headers_raise_decode_error(self, mutate):
        data = WireHeader("int8(64)", [b"p"], 0).pack()
        with pytest.raises(WireDecodeError):
            WireHeader.unpack(mutate(data))

    def test_truncated_body_raises(self):
        p = parse_pipeline("int8(64)")
        data = p.encode(vec(300))
        with pytest.raises(WireDecodeError):
            decode_payload(data[:-17])

    @pytest.mark.parametrize("n", [2 ** 40, (1 << 28) + 1, 0xFFFFFFFF])
    def test_topk_giant_n_rejected_before_allocating(self, n):
        """A forged topk header must never size an allocation from its
        wire-supplied n (17 GiB at the u32 limit, 4 TiB at 2**40)."""
        import struct
        params = struct.pack("!Q", n)                # n huge, no indices
        h = WireHeader("topk(0.5)", [params], 0).pack()
        with pytest.raises(WireDecodeError, match="MAX_DECODE_PARAMS"):
            decode_payload(h)
        legacy = struct.pack("!Q", n) + struct.pack("!I", 0)
        with pytest.raises(ValueError, match="MAX_DECODE_PARAMS"):
            TopKCodec().decode(legacy)

    def test_negotiation_memo_is_size_capped(self):
        from repro.core.wire import _NEGOTIATED, _NEGOTIATED_CAP
        for block in range(1, _NEGOTIATED_CAP + 50):
            spec = f"int8({block})"
            q = parse_pipeline(spec)
            decode_payload(q.encode(vec(16)))
        assert len(_NEGOTIATED) <= _NEGOTIATED_CAP

    def test_unregistered_stage_in_header_raises(self):
        h = WireHeader("lzma", [b""], 0).pack() + vec(4).tobytes()
        with pytest.raises(WireDecodeError, match="unknown stage"):
            decode_payload(h)

    @pytest.mark.parametrize("spec", ["int8(inf)", "int8(nan)", "raw(1)",
                                      "topk(0.1,0.2)"])
    def test_hostile_stage_args_in_header_degrade_not_crash(self, spec):
        """A wire-controlled spec whose stage constructor blows up
        (OverflowError, TypeError, ...) must still surface as
        WireDecodeError — the server's explicit-degradation contract."""
        h = WireHeader(spec, [b""], 0).pack() + vec(4).tobytes()
        with pytest.raises(WireDecodeError):
            decode_payload(h)


# --------------------------------------------------------------------------
# Wire negotiation: decode from the header alone
# --------------------------------------------------------------------------
class TestNegotiation:
    @pytest.mark.parametrize("spec", NEGOTIATION_SPECS)
    @pytest.mark.parametrize("n", [0, 1, 255, 4096])
    def test_decodes_from_header_alone(self, spec, n):
        p = parse_pipeline(spec)
        v = vec(n)
        data = p.encode(v, p.new_state())
        out, negotiated = decode_payload(data)     # zero out-of-band config
        assert negotiated.spec == p.spec
        assert out.dtype == np.float32 and out.size == v.size
        if p.caps.lossless:
            np.testing.assert_array_equal(out, v)
        else:
            # Lossy pipelines must agree with their own out-of-band decode.
            np.testing.assert_array_equal(out, p.decode(data, p.new_state()))

    @pytest.mark.parametrize("spec", ["raw", "delta|raw", "int8(64)"])
    def test_decoded_vector_is_writable(self, spec):
        # The legacy codec contract returns writable arrays; a headered
        # raw decode must not hand back a read-only wire-buffer view.
        p = parse_pipeline(spec)
        out, _ = decode_payload(p.encode(vec(32), p.new_state()))
        assert out.flags.writeable
        out += 1.0   # must not raise

    def test_receiver_config_is_ignored(self):
        """The sender's header wins even when the receiver was configured
        with a different pipeline — that is the negotiation."""
        sender = parse_pipeline("int8(128)")
        data = sender.encode(vec(500))
        out, negotiated = decode_payload(data)
        assert negotiated.spec == "int8(128)"
        receiver = parse_pipeline("hex")
        with pytest.raises(WireDecodeError, match="names pipeline"):
            receiver.decode(data)   # strict decode refuses a foreign header

    def test_topk_int8_composition_quantizes_only_values(self):
        v = vec(2000)
        p = parse_pipeline("topk(0.05)|int8(50)")
        out, _ = decode_payload(p.encode(v))
        k = int(2000 * 0.05)
        assert np.count_nonzero(out) <= k
        kept = np.argsort(-np.abs(v))[:k]
        np.testing.assert_allclose(out[kept], v[kept], rtol=0.02, atol=1e-4)


# --------------------------------------------------------------------------
# Legacy bit-identity
# --------------------------------------------------------------------------
class TestLegacyMode:
    @pytest.mark.parametrize("name", sorted(CODECS))
    @pytest.mark.parametrize("n", [0, 1, 3, 1000, 1025])
    def test_headerless_bytes_identical_to_codec(self, name, n):
        codec = make_codec(name)
        p = legacy_pipeline(name)
        v = vec(n)
        assert p.encode(v, p.new_state()) == codec.encode(v)

    def test_legacy_ef_matches_historical_contract(self):
        """legacy [ef][int8]: residual == compensated - codec.decode(bytes),
        compounding across calls exactly like the old ErrorFeedback."""
        codec = Int8Codec()
        p = legacy_pipeline("int8", error_feedback=True)
        st = p.new_state()
        residual = None
        for _ in range(4):
            v = vec(3000)
            comp = v if residual is None else v + residual
            expect = codec.encode(comp)
            assert p.encode(v, st) == expect
            residual = comp - codec.decode(expect)
            np.testing.assert_array_equal(st.slots[0]["residual"], residual)

    def test_legacy_ef_skipped_for_lossless_codec(self):
        p = legacy_pipeline("raw", error_feedback=True)
        assert p.spec == "raw"      # no ef stage: nothing to feed back

    def test_legacy_conflicting_codec_args_refused(self):
        # "int8(512)" already names a block; a contradicting codec_kwargs
        # must raise, not silently win or lose.
        assert legacy_pipeline("int8(512)").stages[-1].block == 512
        with pytest.raises(WireError, match="ambiguous"):
            legacy_pipeline("int8(512)", {"block": 1024})

    def test_mid_pipeline_params_refuse_legacy(self):
        p = Pipeline([wire.TopKStage(0.1), wire.Int8Stage(64)],
                     self_describing=False)
        with pytest.raises(WireError, match="legacy"):
            p.encode(vec(100))


# --------------------------------------------------------------------------
# Stage state: delta + error feedback
# --------------------------------------------------------------------------
class TestStages:
    def test_delta_uses_primed_reference(self):
        p = parse_pipeline("delta|raw")
        st = p.new_state()
        ref, v = vec(64), vec(64)
        p.set_reference(st, ref)
        out, _ = decode_payload(p.encode(v, st))
        np.testing.assert_array_equal(out, v - ref)   # decode stays in delta domain

    def test_delta_unprimed_is_delta_against_zero(self):
        p = parse_pipeline("delta|raw")
        v = vec(32)
        out, _ = decode_payload(p.encode(v, p.new_state()))
        np.testing.assert_array_equal(out, v)

    def test_delta_reference_size_mismatch_raises(self):
        p = parse_pipeline("delta|raw")
        st = p.new_state()
        p.set_reference(st, vec(8))
        with pytest.raises(WireError, match="reference"):
            p.encode(vec(16), st)

    def test_error_feedback_reduces_accumulated_error(self):
        """EF's whole point: over repeated sends of the same signal, the
        accumulated decoded sum tracks the true sum better than without
        (the residual rotates through the coordinates top-k keeps
        dropping)."""
        rounds = 40
        v = vec(4000) * 0.1
        with_ef = parse_pipeline("ef|topk(0.05)")
        without = parse_pipeline("topk(0.05)")
        st = with_ef.new_state()
        got_ef = np.zeros_like(v)
        got_plain = np.zeros_like(v)
        for _ in range(rounds):
            got_ef += decode_payload(with_ef.encode(v, st))[0]
            got_plain += decode_payload(without.encode(v))[0]
        err_ef = np.linalg.norm(got_ef - rounds * v)
        err_plain = np.linalg.norm(got_plain - rounds * v)
        assert err_ef < 0.25 * err_plain

    def test_state_slot_count_is_checked(self):
        p = parse_pipeline("delta|raw")
        with pytest.raises(WireError, match="slots"):
            p.encode(vec(8), parse_pipeline("raw").new_state())


# --------------------------------------------------------------------------
# Satellite: TopK empty/small-vector regression, across every codec
# --------------------------------------------------------------------------
class TestSmallVectorRegression:
    @pytest.mark.parametrize("name", sorted(CODECS))
    @pytest.mark.parametrize("n", [0, 1, 2])
    def test_encode_decode_empty_and_tiny(self, name, n):
        """TopKCodec used to pack k=1 for an empty vector while writing
        zero entries, so decode read past the buffer; every codec must
        round-trip n in {0, 1, 2}."""
        codec = make_codec(name)
        v = np.linspace(-1, 1, n, dtype=np.float32)
        out = codec.decode(codec.encode(v))
        assert out.size == n
        if codec.lossless:
            np.testing.assert_array_equal(out, v)

    def test_topk_header_k_clamped_to_entries(self):
        data = TopKCodec(k_fraction=0.01).encode(np.zeros(0, np.float32))
        import struct
        n = struct.unpack_from("!Q", data, 0)[0]
        k = struct.unpack_from("!I", data, 8)[0]
        assert (n, k) == (0, 0)
        assert len(data) == 12              # header only, no phantom entry

    @pytest.mark.parametrize("n", [1, 5, 49])
    def test_topk_size_smaller_than_k(self, n):
        codec = TopKCodec(k_fraction=1.0)   # requests k = n
        v = vec(n)
        np.testing.assert_array_equal(codec.decode(codec.encode(v)), v)

    @pytest.mark.parametrize("spec", ["topk(0.5)", "topk(0.5)|int8(8)"])
    def test_topk_stage_empty_vector(self, spec):
        p = parse_pipeline(spec)
        out, _ = decode_payload(p.encode(np.zeros(0, np.float32)))
        assert out.size == 0


# --------------------------------------------------------------------------
# Satellite: adversarial codec round-trips
# --------------------------------------------------------------------------
ADVERSARIAL = {
    "nan_inf": np.array([np.nan, np.inf, -np.inf, 0.0, 1.0, -1.0],
                        dtype=np.float32),
    "denormal": np.array([1e-42, -1e-42, np.float32(1.4e-45), 0.0],
                         dtype=np.float32),
    "huge_tiny": np.array([3.4e38, -3.4e38, 1e-38, -1e-38],
                          dtype=np.float32),
    "off_block": RNG.standard_normal(1023).astype(np.float32),
    "block_plus_one": RNG.standard_normal(1025).astype(np.float32),
}


class TestAdversarialVectors:
    @pytest.mark.parametrize("name", sorted(CODECS))
    @pytest.mark.parametrize("case", sorted(ADVERSARIAL))
    def test_round_trip_shape_and_bits(self, name, case):
        codec = make_codec(name)
        v = ADVERSARIAL[case]
        with warnings.catch_warnings():
            warnings.simplefilter("ignore", RuntimeWarning)
            out = codec.decode(codec.encode(v))
        assert out.dtype == np.float32 and out.size == v.size
        if codec.lossless:
            # Bit-exact, including NaN payloads and denormals.
            assert out.tobytes() == v.tobytes()

    @pytest.mark.parametrize("case", ["off_block", "block_plus_one"])
    def test_int8_non_multiple_block_lengths(self, case):
        codec = Int8Codec(block=256)
        v = ADVERSARIAL[case]
        out = codec.decode(codec.encode(v))
        assert out.size == v.size
        np.testing.assert_allclose(out, v, atol=np.abs(v).max() / 100)

    @pytest.mark.parametrize("case", sorted(ADVERSARIAL))
    def test_hex_raw_cross_consistency(self, case):
        """hex is exactly hexlify(raw): decoding one through the other's
        lens must agree bit-for-bit."""
        import binascii
        v = ADVERSARIAL[case]
        raw, hexc = make_codec("raw"), make_codec("hex")
        assert binascii.hexlify(raw.encode(v)) == hexc.encode(v)
        assert raw.decode(binascii.unhexlify(hexc.encode(v))).tobytes() \
            == v.tobytes()
        assert hexc.decode(binascii.hexlify(raw.encode(v))).tobytes() \
            == v.tobytes()


# --------------------------------------------------------------------------
# Orchestrator integration
# --------------------------------------------------------------------------
SERVER = "10.9.0.1"


def _star(n_clients, cfg, train_value=2.0, n_params=300):
    from repro.core.channel import Link, NoLoss
    sim = Simulator()
    clients = []
    for i in range(n_clients):
        addr = f"10.9.0.{10 + i}"
        sim.connect(addr, SERVER,
                    Link(1e8, 1_000_000, NoLoss()),
                    Link(1e8, 1_000_000, NoLoss()))

        def fn(params, round_idx, client, _v=train_value * (i + 1)):
            return ({k: np.full_like(v, _v) for k, v in params.items()}, {})
        clients.append(FLClient(addr, fn, train_time_ns=1_000_000 * (i + 1)))
    params = {"w": np.linspace(-1, 1, n_params, dtype=np.float32)}
    return sim, FederatedSystem(sim, SERVER, clients, params, cfg)


class TestServerWirePlane:
    def test_config_rejects_legacy_flags_with_uplink_spec(self):
        with pytest.raises(ValueError, match="legacy spellings"):
            FLConfig(send_deltas=True,
                     transport=TransportConfig(uplink="delta|raw"))

    def test_config_rejects_delta_downlink(self):
        with pytest.raises(ValueError, match="downlink"):
            TransportConfig(downlink="delta|int8(64)")

    def test_config_rejects_unknown_stage_early(self):
        with pytest.raises(ValueError, match="uplink"):
            TransportConfig(uplink="gzip|raw")

    @pytest.mark.parametrize("spec", ["int8(64)|raw", "hex|int8(64)",
                                      "raw|topk(0.1)|hex|int8(64)"])
    def test_config_rejects_incoherent_stage_order_early(self, spec):
        """Parseable but dtype-incoherent specs must fail at config time,
        not by silently zero-degrading every payload at runtime."""
        with pytest.raises(ValueError, match="round-trip"):
            TransportConfig(uplink=spec)

    def test_malformed_uplink_degrades_explicitly(self):
        # Self-describing uplink: garbage has no valid header -> explicit
        # WireDecodeError -> zero vector + counter (never a bare except).
        _, system = _star(1, FLConfig(
            transport=TransportConfig(uplink="raw")))
        core = system.core
        before = core.decode_errors
        out = core.decode_vec(b"\x13\x37 garbage that is not a payload")
        assert out.size == core.n_params and not out.any()
        assert core.decode_errors == before + 1

    def test_delta_domain_mismatch_degrades_not_misaggregates(self):
        """A sender whose header negotiates a different delta-ness than
        the server's configured uplink must be refused (zero-fill), never
        aggregated under the wrong semantics."""
        _, system = _star(1, FLConfig(
            transport=TransportConfig(uplink="int8(128)")))
        core = system.core
        rogue = parse_pipeline("delta|int8(128)")
        data = rogue.encode(vec(core.n_params), rogue.new_state())
        out = core.decode_vec(data)
        assert not out.any() and core.decode_errors == 1
        # matching delta-ness still decodes
        ok = parse_pipeline("int8(128)")
        assert core.decode_vec(ok.encode(vec(core.n_params))).any()
        assert core.decode_errors == 1

    def test_packetizer_rejects_codec_and_pipeline_together(self):
        from repro.core.compression import Int8Codec
        from repro.core.packetizer import Packetizer
        with pytest.raises(WireError, match="not both"):
            Packetizer(codec=Int8Codec(), pipeline=parse_pipeline("raw"))

    def test_wire_bytes_measurement_does_not_advance_ef_state(self):
        from repro.core.packetizer import Packetizer
        p = parse_pipeline("ef|int8(64)")
        pz = Packetizer(pipeline=p)
        st = p.new_state()
        tree = {"w": vec(500)}
        pz.wire_bytes(tree, st)                  # measurement only
        assert "residual" not in st.slots[0]     # live state untouched
        real = p.encode(vec(500), st)            # first REAL send
        fresh = p.encode(vec(500), p.new_state())
        assert len(real) == len(fresh)

    def test_removed_client_wire_state_is_forgotten(self):
        """A client re-added at a recycled address must not inherit the
        dead client's EF residual / delta reference."""
        cfg = FLConfig(error_feedback=True,
                       transport=TransportConfig(codec="int8"))
        _, system = _star(2, cfg)
        system.run_round()
        addr = "10.9.0.10"
        assert addr in system.core._up_enc_state     # residual accrued
        system.remove_client(addr)
        assert addr not in system.core._up_enc_state

    def test_malformed_legacy_payload_degrades_explicitly(self):
        _, system = _star(1, FLConfig(
            transport=TransportConfig(codec="int8")))
        core = system.core
        out = core.decode_vec(b"\x00\x01")   # truncated int8 header
        assert out.size == core.n_params and not out.any()
        assert core.decode_errors == 1

    def test_n_params_cache_invalidated_on_assignment(self):
        _, system = _star(1, FLConfig())
        core = system.core
        assert core.n_params == 300
        core.global_params = {"w": np.zeros(5, np.float32)}
        assert core.n_params == 5

    def test_wire_state_none_for_stateless_pipeline(self):
        _, system = _star(1, FLConfig())
        assert system.core.wire_state("10.9.0.10",
                                      direction="uplink") is None

    def test_sync_round_self_describing_both_directions(self):
        cfg = FLConfig(transport=TransportConfig(
            uplink="delta|ef|int8(128)", downlink="hex"))
        _, system = _star(3, cfg)
        res = system.run_round()
        assert len(res.arrived) == 3
        core = system.core
        assert core.uplink_pipeline.caps.delta_domain
        # Wire really is self-describing: the broadcast + update payloads
        # carry headers, so bytes grow vs the raw legacy wire.
        _, legacy = _star(3, FLConfig())
        legacy_res = legacy.run_round()
        assert res.bytes_sent != legacy_res.bytes_sent

    def test_async_fleet_round_with_per_direction_pipelines(self):
        """Acceptance: a full async (FedBuff) fleet round with independent
        uplink/downlink pipelines; EF state lives in per-client pipeline
        state on the core, not in ServerCore fields or FLClient."""
        from repro.core import ConsensusObjective, FleetConfig, build_fleet
        obj = ConsensusObjective(16, n_params=256, seed=3)
        fleet = FleetConfig(
            n_clients=16, seed=3, mode="async", buffer_k=4,
            uplink="delta|ef|topk(0.2)|int8(128)", downlink="int8(128)")
        cfg = FLConfig(transport=TransportConfig(kind="mudp"), mode="async")
        _, system, _ = build_fleet(fleet, obj.init_params(), obj.train_fn,
                                   cfg)
        loss0 = obj.loss(system.global_params)
        results = system.run_rounds(3)
        assert len(results) == 3
        assert obj.loss(system.global_params) < loss0
        core = system.core
        assert core.uplink_pipeline.spec == "delta|ef|topk(0.2)|int8(128)"
        assert core.downlink_pipeline.spec == "int8(128)"
        # error-feedback residual + delta reference are pipeline state
        states = core._up_enc_state
        assert states, "stateful uplink must have per-client states"
        assert any("residual" in s for st in states.values()
                   for s in st.slots)
        assert any("ref" in s for st in states.values() for s in st.slots)
        assert not hasattr(next(iter(core.pool.clients.values())),
                           "error_feedback")

    def test_legacy_round_matches_headered_round_numerically(self):
        """Same lossless transform, different wire: a raw legacy system
        and a self-describing raw system converge to identical floats
        (only the wire framing differs)."""
        _, legacy = _star(3, FLConfig())
        _, headered = _star(3, FLConfig(transport=TransportConfig(
            uplink="raw", downlink="raw")))
        r1 = legacy.run_round()
        r2 = headered.run_round()
        assert r1.arrived == r2.arrived
        np.testing.assert_array_equal(legacy.global_params["w"],
                                      headered.global_params["w"])
