"""Batched wire-plane tests: batch==loop parity, per-item degradation,
and the server's broadcast-encode cache.

The acceptance bar for the batch plane:

* ``Pipeline.encode_batch`` is **byte-identical** to the per-item encode
  loop and ``decode_batch`` / ``decode_payload_batch`` are bit-identical
  to per-item decode, for every registered stage, across batch sizes —
  including the per-client EF/delta state evolution across messages;
* one malformed payload in a batch zero-fills *that* client's row and
  bumps ``decode_errors`` exactly once — it never poisons the batch;
* the broadcast-encode cache serves bytes identical to per-client
  encoding, is refused for stateful downlinks, and is invalidated on
  every model update (a stale model is never served);
* a fleet round under ``batch_wire=True`` (the default) is bit-identical
  to ``batch_wire=False`` — the orchestrator-equivalence digests pin the
  end-to-end version of this.
"""

from __future__ import annotations

import numpy as np
import pytest

from repro.core.rounds import (FederatedSystem, FLClient, FLConfig,
                               TransportConfig)
from repro.core.simulator import Simulator
from repro.core.wire import (WireDecodeError, WireHeader, available_stages,
                             batch_backend, decode_payload_batch,
                             parse_pipeline)

RNG = np.random.default_rng(11)
SERVER = "10.8.0.1"


def vecs(n_items: int, n: int) -> list[np.ndarray]:
    return [RNG.standard_normal(n).astype(np.float32)
            for _ in range(n_items)]


# Specs chosen so every registered built-in stage appears at least once,
# alone where legal and composed where interesting (EF wrapping lossy
# tails, delta+EF together, hex terminal after lossy stages).
BATCH_SPECS = [
    "raw",
    "hex",
    "int8(256)",
    "int8(1024)",
    "topk(0.05)",
    "delta|raw",
    "delta|ef|int8(128)",
    "topk(0.1)|int8(64)",
    "delta|ef|topk(0.03)|int8(1024)",
    "int8(128)|hex",
    "ef|int8(64)",
    "delta|ef|topk(0.1)|hex",
    "int8(256)|crc",
    "delta|ef|topk(0.1)|int8(512)|crc",
]


def test_batch_specs_cover_every_registered_stage():
    covered = set()
    for spec in BATCH_SPECS:
        for tok in spec.split("|"):
            covered.add(tok.partition("(")[0])
    assert covered == set(available_stages())


def _assert_states_equal(states_a, states_b, spec):
    for sa, sb in zip(states_a, states_b):
        for slot_a, slot_b in zip(sa.slots, sb.slots):
            assert set(slot_a) == set(slot_b), spec
            for key in slot_a:
                np.testing.assert_array_equal(
                    np.asarray(slot_a[key]), np.asarray(slot_b[key]),
                    err_msg=f"{spec}: slot {key!r} diverged")


def _run_parity(spec: str, n_items: int, n_params: int,
                n_messages: int = 2, seed: int = 0):
    """Drive the same message sequence through the per-item loop and the
    batch walk; assert bytes, decoded matrices, and per-client pipeline
    state all match exactly."""
    rng = np.random.default_rng(seed)
    pipeline = parse_pipeline(spec)
    states_loop = [pipeline.new_state() for _ in range(n_items)]
    states_batch = [pipeline.new_state() for _ in range(n_items)]
    if pipeline.caps.delta_domain:
        model = rng.standard_normal(n_params).astype(np.float32)
        for st in states_loop + states_batch:
            pipeline.set_reference(st, model)
    for _ in range(n_messages):
        batch_in = [rng.standard_normal(n_params).astype(np.float32)
                    for _ in range(n_items)]
        loop_bytes = [pipeline.encode(v, s)
                      for v, s in zip(batch_in, states_loop)]
        batch_bytes = pipeline.encode_batch(batch_in, states_batch)
        assert batch_bytes == loop_bytes, f"{spec}: encode bytes diverged"
        _assert_states_equal(states_loop, states_batch, spec)

        loop_dec = [pipeline.decode(d) for d in loop_bytes]
        batch_dec = pipeline.decode_batch(batch_bytes)
        assert batch_dec.dtype == np.float32
        assert batch_dec.shape == (n_items, loop_dec[0].size)
        np.testing.assert_array_equal(
            batch_dec, np.stack(loop_dec),
            err_msg=f"{spec}: decode diverged from per-item loop")

        for (mat_vec, _, err), ref in zip(
                decode_payload_batch(batch_bytes), loop_dec):
            assert err is None, f"{spec}: {err}"
            np.testing.assert_array_equal(mat_vec, ref)


class TestBatchLoopParity:
    """The tentpole contract: batch paths are byte/bit-identical twins."""

    def test_numpy_backend_is_default(self):
        assert batch_backend() == "numpy"

    @pytest.mark.parametrize("n_items", [1, 7, 64])
    @pytest.mark.parametrize("spec", BATCH_SPECS)
    def test_batch_matches_loop(self, spec, n_items):
        _run_parity(spec, n_items, n_params=777, seed=hash(spec) % 2**32)

    @pytest.mark.parametrize("n_params", [0, 1, 5, 1023, 1025])
    def test_awkward_vector_lengths(self, n_params):
        for spec in ("int8(1024)", "topk(0.05)", "hex",
                     "delta|ef|topk(0.1)|int8(256)"):
            _run_parity(spec, 3, n_params, seed=n_params + 1)

    def test_ragged_batch_falls_back_to_loop(self):
        pipeline = parse_pipeline("int8(64)")
        ragged = [vecs(1, 100)[0], vecs(1, 200)[0]]
        out = pipeline.encode_batch(ragged)
        assert out == [pipeline.encode(v) for v in ragged]

    def test_empty_batch(self):
        pipeline = parse_pipeline("raw")
        assert pipeline.encode_batch([]) == []
        assert pipeline.decode_batch([]).shape == (0, 0)

    def test_legacy_pipeline_not_batchable_but_still_works(self):
        from repro.core.wire import legacy_pipeline
        pipeline = legacy_pipeline("int8")
        assert not pipeline.batchable
        batch = vecs(3, 500)
        out = pipeline.encode_batch(batch)
        assert out == [pipeline.encode(v) for v in batch]
        np.testing.assert_array_equal(
            pipeline.decode_batch(out),
            np.stack([pipeline.decode(d) for d in out]))

    def test_decode_batch_rejects_foreign_spec(self):
        ours = parse_pipeline("raw")
        theirs = parse_pipeline("hex")
        data = theirs.encode(vecs(1, 32)[0])
        with pytest.raises(WireDecodeError, match="names pipeline"):
            ours.decode_batch([data, data])


# --------------------------------------------------------------------------
# Property test: random well-formed specs, random shapes (hypothesis-gated
# per-test — the rest of this module must run without it)
# --------------------------------------------------------------------------
try:
    from hypothesis import given, settings, strategies as st
    _HAVE_HYPOTHESIS = True
except ImportError:                                   # pragma: no cover
    _HAVE_HYPOTHESIS = False

if _HAVE_HYPOTHESIS:
    @st.composite
    def _wire_specs(draw):
        """Random *coherent* specs: delta first, ef next, optional topk,
        then a terminal — the same ordering TransportConfig accepts."""
        prefix = draw(st.sampled_from(["", "delta|", "ef|", "delta|ef|"]))
        mid = draw(st.sampled_from(["", "topk(0.25)|", "topk(0.02)|"]))
        terminal = draw(st.sampled_from(
            ["raw", "hex", "int8(64)", "int8(1024)"]))
        return prefix + mid + terminal

    @given(spec=_wire_specs(),
           n_items=st.integers(min_value=1, max_value=9),
           n_params=st.integers(min_value=0, max_value=600),
           seed=st.integers(min_value=0, max_value=2**31))
    @settings(max_examples=40, deadline=None)
    def test_batch_parity_property(spec, n_items, n_params, seed):
        _run_parity(spec, n_items, n_params, seed=seed)
else:
    @pytest.mark.skip(reason="hypothesis not installed")
    def test_batch_parity_property():
        pytest.importorskip("hypothesis")


# --------------------------------------------------------------------------
# Server integration: per-item degradation + broadcast cache
# --------------------------------------------------------------------------
def _star(n_clients: int, cfg: FLConfig, n_params: int = 300):
    from repro.core.channel import Link, NoLoss
    sim = Simulator()
    clients = []
    for i in range(n_clients):
        addr = f"10.8.0.{10 + i}"
        sim.connect(addr, SERVER,
                    Link(1e8, 1_000_000, NoLoss()),
                    Link(1e8, 1_000_000, NoLoss()))

        def fn(params, round_idx, client, _v=0.25 * (i + 1)):
            return ({k: np.full_like(v, _v) for k, v in params.items()}, {})
        clients.append(FLClient(addr, fn, train_time_ns=1_000_000 * (i + 1),
                                cadence_ns=5_000_000))
    params = {"w": np.linspace(-1, 1, n_params, dtype=np.float32)}
    return sim, FederatedSystem(sim, SERVER, clients, params, cfg)


class TestBatchDecodeDegradation:
    def _payloads(self, core, n_items):
        pipeline = core.uplink_pipeline
        updates = vecs(n_items, core.n_params)
        return updates, [pipeline.encode(v) for v in updates]

    def test_corrupt_payload_degrades_only_its_row(self):
        """Regression (satellite 1): a corrupt payload inside a batch
        zero-fills its own row, bumps decode_errors once, and leaves
        every other row bit-identical to per-item decode."""
        _, system = _star(1, FLConfig(
            transport=TransportConfig(uplink="topk(0.1)|int8(64)")))
        core = system.core
        updates, datas = self._payloads(core, 5)
        # Inject a bad WireHeader: same spec, same body (so the payload
        # lands in the batch group), but garbage stage params — the
        # vectorized walk must reject it and the fallback isolate it.
        victim = datas[2]
        _, off = WireHeader.unpack(victim)
        bad = WireHeader(core.uplink_pipeline.spec,
                         [b"\x13\x37", b"\x00"], dtype_code=0).pack()
        datas[2] = bad + victim[off:]
        before = core.decode_errors
        mat = core.decode_vec_batch(datas)
        assert core.decode_errors == before + 1
        assert not mat[2].any()
        for i in (0, 1, 3, 4):
            np.testing.assert_array_equal(mat[i], core.decode_vec(datas[i]))

    def test_unparseable_garbage_degrades_only_its_row(self):
        _, system = _star(1, FLConfig(
            transport=TransportConfig(uplink="int8(128)")))
        core = system.core
        _, datas = self._payloads(core, 4)
        datas[1] = b"\x00\x01 not a wire payload at all"
        before = core.decode_errors
        mat = core.decode_vec_batch(datas)
        assert core.decode_errors == before + 1
        assert not mat[1].any()
        assert all(mat[i].any() for i in (0, 2, 3))

    def test_delta_domain_mismatch_degrades_per_row(self):
        """A rogue delta-domain header inside a batch is refused (policy,
        not parse) without touching its neighbours."""
        _, system = _star(1, FLConfig(
            transport=TransportConfig(uplink="int8(128)")))
        core = system.core
        _, datas = self._payloads(core, 3)
        rogue = parse_pipeline("delta|int8(128)")
        datas[0] = rogue.encode(vecs(1, core.n_params)[0],
                                rogue.new_state())
        before = core.decode_errors
        mat = core.decode_vec_batch(datas)
        assert core.decode_errors == before + 1
        assert not mat[0].any() and mat[1].any() and mat[2].any()


class TestBroadcastCache:
    def test_cache_hit_counting_and_reuse(self):
        _, system = _star(2, FLConfig(
            transport=TransportConfig(downlink="int8(1024)")))
        core = system.core
        first = core.broadcast_payload()
        assert first is not None and core.bcast_cache_hits == 0
        second = core.broadcast_payload()
        assert second is first                 # same object: no re-encode
        assert core.bcast_cache_hits == 1

    def test_cache_bytes_identical_to_per_client_encode(self):
        _, system = _star(2, FLConfig(
            transport=TransportConfig(downlink="topk(0.5)|hex")))
        core = system.core
        assert core.broadcast_payload() == core.packetizer.encode_bytes(
            core.global_params)

    def test_stale_cache_never_served_after_model_update(self):
        """Satellite 2: every global_params assignment drops the cache."""
        _, system = _star(1, FLConfig(
            transport=TransportConfig(downlink="int8(1024)")))
        core = system.core
        stale = core.broadcast_payload()
        new_params = {"w": np.linspace(3, 4, core.n_params,
                                       dtype=np.float32)}
        core.global_params = new_params
        fresh = core.broadcast_payload()
        assert fresh != stale
        assert fresh == core.packetizer.encode_bytes(new_params)

    def test_cache_refused_for_stateful_downlink(self):
        _, system = _star(1, FLConfig(
            transport=TransportConfig(downlink="ef|int8(64)")))
        core = system.core
        assert core.downlink_pipeline.caps.stateful
        assert core.broadcast_payload() is None

    def test_cache_refused_when_batch_wire_off(self):
        _, system = _star(1, FLConfig(
            batch_wire=False,
            transport=TransportConfig(downlink="int8(1024)")))
        assert system.core.broadcast_payload() is None

    def test_aggregation_invalidates_cache(self):
        cfg = FLConfig(transport=TransportConfig(
            uplink="topk(0.2)|int8(256)", downlink="int8(1024)"))
        _, system = _star(3, cfg)
        core = system.core
        stale = core.broadcast_payload()
        system.run_round()
        assert core.broadcast_payload() != stale


class TestBatchWireEquivalence:
    """End-to-end: batch_wire=True (default) is bit-identical to the
    eager per-delivery path, for sync and async, wire and legacy."""

    @pytest.mark.parametrize("mode,transport", [
        ("sync", TransportConfig(uplink="delta|ef|topk(0.1)|int8(256)",
                                 downlink="int8(1024)")),
        ("sync", TransportConfig(codec="int8")),
        ("async", TransportConfig(uplink="topk(0.2)|int8(128)",
                                  downlink="hex")),
    ])
    def test_rounds_bit_identical(self, mode, transport):
        import dataclasses
        kw = dict(mode=mode, transport=transport)
        if mode == "async":
            kw.update(buffer_k=2, max_staleness=4)
        base = FLConfig(**kw)
        results = {}
        for batch in (True, False):
            _, system = _star(4, dataclasses.replace(base,
                                                     batch_wire=batch))
            system.run_rounds(3)   # async mode: 3 buffered aggregations
            results[batch] = system.core.global_params
        for key in results[True]:
            np.testing.assert_array_equal(results[True][key],
                                          results[False][key])

    def test_pending_updates_resolve_in_one_batch(self):
        """Under batch_wire the scheduler holds opaque pending tokens;
        decode_errors stays correct because resolution happens before the
        zero-weight filter in apply_aggregation."""
        cfg = FLConfig(transport=TransportConfig(uplink="int8(256)"))
        _, system = _star(3, cfg)
        system.run_round()
        assert system.core.decode_errors == 0
        assert system.core.bcast_cache_hits >= 1   # 3 clients, 1 encode
