"""Adaptive transport control plane: telemetry, policies, renegotiation.

Three contracts are gated here:

1. **Pure add-on** — ``control="static"`` (set explicitly, not just
   defaulted) leaves every pinned orchestrator-equivalence digest
   byte-identical, and the always-on telemetry plane cannot move them.
2. **Engine-independence** — telemetry snapshots are bit-identical under
   the ``per_packet`` and ``batched`` engines (the flow engine's
   distributional version lives in tests/test_flow_engine.py).
3. **Renegotiation mechanics** — the crc wire stage, encoder
   state-migration rules, decision dedup, and the adaptive ladder.
"""

from __future__ import annotations

import os
import sys

import numpy as np
import pytest

from repro.core import (FLConfig, FleetConfig, TransportConfig,
                        build_fleet_training)
from repro.core.control import (AdaptivePolicy, ControlDecision,
                                DEFAULT_TIERS, StaticPolicy,
                                available_policies, make_policy,
                                register_policy)
from repro.core.telemetry import ClientHealth, Telemetry
from repro.core.wire import (CrcStage, WireDecodeError, WireError,
                             chunksum32, migrate_state, parse_pipeline)

sys.path.insert(0, os.path.dirname(__file__))
from test_orchestrator_equivalence import (EXPECTED,            # noqa: E402
                                           PACKET_ENGINES, run_digest)

NS = 1_000_000_000

UP_SPEC = "delta|ef|topk(0.15)|int8(1024)"


def _build(engine: str, control: str = "static", *, n_clients: int = 10,
           seed: int = 3, rounds: int = 3, mode: str = "sync",
           transport: str = "mudp+fec"):
    fl = FLConfig(transport=TransportConfig(
        kind=transport, uplink=UP_SPEC, downlink="int8(1024)",
        timeout_ns=2 * NS, udp_deadline_ns=3 * NS))
    fleet = FleetConfig(n_clients=n_clients, seed=seed, engine=engine,
                        mode=mode, model="consensus",
                        model_args={"n_params": 256}, control=control)
    build = build_fleet_training(fleet, fl)
    build.system.run_rounds(rounds)
    return build


# --------------------------------------------------------------------------
# 1. Pure add-on: explicit control="static" keeps every pinned digest
# --------------------------------------------------------------------------
@pytest.mark.parametrize("scenario,kind", sorted(EXPECTED), ids=str)
def test_static_control_keeps_pinned_digests(scenario, kind):
    for engine in PACKET_ENGINES:
        assert run_digest(scenario, kind, engine,
                          control="static") == EXPECTED[(scenario, kind)], (
            f"{scenario}/{kind}/{engine}: control='static' moved a pinned "
            f"digest — the control plane is not a pure add-on")


def test_unknown_policy_rejected_at_config_time():
    with pytest.raises(ValueError, match="unknown control policy"):
        FLConfig(control="nope")
    with pytest.raises(ValueError, match="unknown control policy"):
        FleetConfig(n_clients=2, control="nope")


def test_policy_registry_idiom():
    assert available_policies() == ["adaptive", "static"]
    with pytest.raises(ValueError, match="already registered"):
        register_policy("static", StaticPolicy)
    register_policy("static", StaticPolicy, overwrite=True)
    assert isinstance(make_policy("static"), StaticPolicy)
    with pytest.raises(ValueError, match="unknown control policy"):
        make_policy("definitely-not-registered")


# --------------------------------------------------------------------------
# 2. Telemetry: engine-independent, deterministic, always on
# --------------------------------------------------------------------------
def test_telemetry_bit_identical_per_packet_vs_batched():
    snaps = {}
    for engine in PACKET_ENGINES:
        b = _build(engine)
        snaps[engine] = b.system.core.telemetry.snapshot_all()
    assert snaps["per_packet"] == snaps["batched"]
    assert snaps["batched"], "telemetry plane observed nothing"
    for health in snaps["batched"].values():
        assert health.txns > 0
        assert health.rtt_ns > 0
        assert health.goodput_bps > 0


def test_round_result_exports_health_and_counters():
    b = _build("batched")
    last = b.system.history[-1]
    assert set(last.client_health) == {p.addr for p in b.profiles}
    assert all(isinstance(h, ClientHealth)
               for h in last.client_health.values())
    assert last.decode_errors == 0
    # Stateless downlink + unrenegotiated clients: the broadcast encode is
    # computed once and served from cache for the rest of the roster.
    assert last.bcast_cache_hits > 0


def test_telemetry_ewma_math():
    t = Telemetry(alpha=0.5)
    t.observe_txn("a", now_ns=10, duration_ns=100, data_sent=10,
                  retransmissions=2, payload_bytes=1000)
    h = t.snapshot("a")
    # First observation initializes the EWMA directly.
    assert h.loss_rate == pytest.approx(0.2)
    assert h.rtt_ns == pytest.approx(100.0)
    t.observe_txn("a", now_ns=20, duration_ns=200, data_sent=10,
                  retransmissions=0, payload_bytes=1000)
    h = t.snapshot("a")
    assert h.loss_rate == pytest.approx(0.5 * 0.0 + 0.5 * 0.2)
    assert h.rtt_ns == pytest.approx(0.5 * 200 + 0.5 * 100)
    assert h.txns == 2 and h.failures == 0
    t.observe_decode_error("a", now_ns=30)
    assert t.snapshot("a").decode_errors == 1
    assert t.snapshot("missing") is None
    t.forget("a")
    assert t.snapshot("a") is None


def test_failed_txn_counts_as_failure_with_zero_goodput():
    t = Telemetry()
    t.observe_txn("a", now_ns=5, duration_ns=100, data_sent=4,
                  retransmissions=4, payload_bytes=400, completed=False)
    h = t.snapshot("a")
    assert h.failures == 1 and h.txns == 1
    assert h.goodput_bps == 0.0
    assert h.loss_rate == pytest.approx(1.0)


# --------------------------------------------------------------------------
# 3a. The crc wire stage (repro.kernels.checksum on the wire)
# --------------------------------------------------------------------------
def test_crc_stage_roundtrip_and_corruption():
    p = parse_pipeline("int8(1024)|crc")
    arr = np.linspace(-1.0, 1.0, 257, dtype=np.float32)
    payload = p.encode(arr, p.new_state())
    out = p.decode(payload, p.new_state())
    assert np.allclose(out, arr, atol=1e-2)
    for flip in (len(payload) - 1, len(payload) // 2):
        bad = bytearray(payload)
        bad[flip] ^= 0x40
        with pytest.raises(WireDecodeError, match="crc mismatch"):
            p.decode(bytes(bad), p.new_state())


def test_crc_must_be_terminal():
    with pytest.raises(WireError, match="terminal"):
        parse_pipeline("crc|int8(1024)")
    parse_pipeline("crc")   # a lone crc is trivially terminal


def test_crc_batch_matches_scalar():
    stage = CrcStage()
    rng = np.random.default_rng(0)
    mat = rng.standard_normal((5, 33)).astype(np.float32)
    _, params = stage.encode_batch(mat, [{} for _ in range(5)])
    for row, param in zip(mat, params):
        _, scalar = stage.encode(row, {})
        assert scalar == param
    dec = stage.decode_batch(mat, list(params), [{} for _ in range(5)])
    assert np.array_equal(dec, mat)
    with pytest.raises(WireDecodeError, match="crc mismatch"):
        corrupt = mat.copy()
        corrupt[3, 0] += 1.0
        stage.decode_batch(corrupt, list(params), [{} for _ in range(5)])


def test_chunksum32_matches_reference_kernel():
    jax = pytest.importorskip("jax")          # noqa: F841  (ref.py needs it)
    from repro.kernels.checksum.ref import chunksum32_np
    rng = np.random.default_rng(7)
    for n in (0, 1, 8190, 8191, 8192, 20000):
        data = rng.integers(0, 256, size=n, dtype=np.uint8).tobytes()
        assert chunksum32(data) == int(chunksum32_np(
            np.frombuffer(data, dtype=np.uint8)))


# --------------------------------------------------------------------------
# 3b. Encoder state migration across a pipeline swap
# --------------------------------------------------------------------------
def test_migrate_state_carries_ef_residual_and_delta_ref():
    old = parse_pipeline("delta|ef|topk(0.2)|int8(1024)")
    new = parse_pipeline("delta|ef|topk(0.05)|int8(1024)")
    state = old.new_state()
    ref = np.ones(16, dtype=np.float32)
    old.set_reference(state, ref)
    old.encode(np.linspace(0, 1, 16, dtype=np.float32), state)
    old_residual = next(s["residual"] for s in state.slots if "residual" in s)
    assert old_residual is not None

    migrated = migrate_state(old, state, new)
    assert migrated is not None
    carried_ref = next(s["ref"] for s in migrated.slots if "ref" in s)
    carried_res = next(s["residual"] for s in migrated.slots
                      if "residual" in s)
    np.testing.assert_array_equal(carried_ref, ref)
    np.testing.assert_array_equal(carried_res, old_residual)


def test_migrate_state_to_stateless_pipeline_is_none():
    old = parse_pipeline("delta|int8(1024)")
    new = parse_pipeline("int8(1024)")
    state = old.new_state()
    old.set_reference(state, np.zeros(8, dtype=np.float32))
    assert migrate_state(old, state, new) is None


def test_migrate_state_without_old_state_is_fresh():
    new = parse_pipeline("delta|int8(1024)")
    migrated = migrate_state(parse_pipeline("int8(1024)"), None, new)
    assert migrated is not None and len(migrated.slots) == 2


# --------------------------------------------------------------------------
# 3c. The adaptive ladder and server-side renegotiation
# --------------------------------------------------------------------------
def _health(addr="c", loss=0.0, txns=5):
    return ClientHealth(addr=addr, txns=txns, loss_rate=loss)


def test_adaptive_policy_walks_the_ladder_with_hysteresis():
    pol = AdaptivePolicy(hi=0.03, lo=0.008, start_tier=1)
    cfg = TransportConfig(kind="mudp+fec", uplink=UP_SPEC)
    assert pol.renegotiate("c", None, cfg) is None          # no telemetry yet
    pol.renegotiate("c", _health(loss=0.10), cfg)
    assert pol.tier_of("c") == 2                            # escalate
    pol.renegotiate("c", _health(loss=0.02), cfg)
    assert pol.tier_of("c") == 2                            # hysteresis hold
    pol.renegotiate("c", _health(loss=0.001), cfg)
    assert pol.tier_of("c") == 1                            # relax
    pol.renegotiate("c", _health(loss=0.0), cfg)
    assert pol.tier_of("c") == 0                            # floor next
    pol.renegotiate("c", _health(loss=0.0), cfg)
    assert pol.tier_of("c") == 0
    d = pol.renegotiate("c", _health(loss=0.0), cfg)
    assert d.uplink == DEFAULT_TIERS[0]["uplink"]
    assert d.fec_parity == 0


def test_adaptive_policy_validates_args():
    with pytest.raises(ValueError, match="at least one tier"):
        AdaptivePolicy(tiers=())
    with pytest.raises(ValueError, match="unknown transport fields"):
        AdaptivePolicy(tiers=({"uplink": "raw", "mtu": 100},))
    with pytest.raises(ValueError, match="lo <= hi"):
        AdaptivePolicy(hi=0.01, lo=0.05)
    with pytest.raises(ValueError, match="start_tier"):
        AdaptivePolicy(start_tier=9)


def test_apply_decision_dedupes_and_counts():
    b = _build("batched", rounds=1)
    core = b.system.core
    addr = b.profiles[0].addr
    decision = ControlDecision(uplink=DEFAULT_TIERS[0]["uplink"],
                               fec_block=16, fec_parity=0)
    assert core._apply_decision(addr, decision) is True
    assert core.renegotiations[addr] == 1
    cfg = core.transport_cfg_for(addr)
    assert cfg.uplink == DEFAULT_TIERS[0]["uplink"]
    assert cfg.fec_parity == 0
    # Identical decision again: nothing changes, nothing is counted.
    assert core._apply_decision(addr, decision) is False
    assert core.renegotiations[addr] == 1
    # Other clients keep the base config.
    other = b.profiles[1].addr
    assert core.transport_cfg_for(other).uplink == UP_SPEC


def test_renegotiated_uplink_cannot_flip_aggregation_domain():
    b = _build("batched", rounds=1)
    core = b.system.core
    addr = b.profiles[0].addr
    with pytest.raises(ValueError, match="domain"):
        core._apply_decision(addr,
                             ControlDecision(uplink="topk(0.1)|int8(1024)"))


def test_adaptive_requires_self_describing_uplink():
    # FleetConfig.control is forwarded onto the FLConfig by the topology,
    # so a legacy-codec uplink must be rejected at ServerCore construction.
    fl = FLConfig(transport=TransportConfig(kind="mudp", codec="int8"))
    fleet = FleetConfig(n_clients=2, seed=0, model="consensus",
                        model_args={"n_params": 64}, control="adaptive")
    with pytest.raises(ValueError, match="self-describing"):
        build_fleet_training(fleet, fl)


def test_adaptive_fleet_renegotiates_and_converges():
    b = _build("batched", control="adaptive", n_clients=16, rounds=4)
    core = b.system.core
    assert sum(core.renegotiations.values()) > 0
    # The sum of per-cohort counts in the benchmark equals the core's.
    last = b.system.history[-1]
    assert set(last.client_health) == {p.addr for p in b.profiles}
    assert b.model.loss(b.system.global_params) < 0.5


def test_adaptive_identical_across_packet_engines():
    results = {}
    for engine in PACKET_ENGINES:
        b = _build(engine, control="adaptive", n_clients=8, rounds=3)
        results[engine] = (
            dict(b.system.core.renegotiations),
            b.system.core.telemetry.snapshot_all(),
            {k: v.tolist() for k, v in b.system.global_params.items()},
        )
    assert results["per_packet"] == results["batched"]


# --------------------------------------------------------------------------
# 3d. FEC parity 0 (the trailer-less tier) and config validation
# --------------------------------------------------------------------------
def test_transport_config_validates_fec_geometry():
    with pytest.raises(ValueError, match="fec_block"):
        TransportConfig(kind="mudp+fec", fec_block=0)
    with pytest.raises(ValueError, match="fec_parity"):
        TransportConfig(kind="mudp+fec", fec_parity=-1)
    TransportConfig(kind="mudp+fec", fec_parity=0)   # valid: no trailer


@pytest.mark.parametrize("engine", [*PACKET_ENGINES, "flow"])
def test_fec_parity_zero_runs_every_engine(engine):
    fl = FLConfig(transport=TransportConfig(
        kind="mudp+fec", fec_parity=0, timeout_ns=2 * NS,
        udp_deadline_ns=3 * NS))
    fleet = FleetConfig(n_clients=6, seed=1, engine=engine,
                        model="consensus", model_args={"n_params": 128})
    b = build_fleet_training(fleet, fl)
    res = b.system.run_rounds(2)
    assert len(res) == 2
    assert all(r.parity_packets == 0 for r in res)


def test_fec_parity_zero_matches_plain_mudp_on_packet_engines():
    """With no trailer, mudp+fec must behave exactly like mudp."""
    out = {}
    for kind, parity in (("mudp", 1), ("mudp+fec", 0)):
        fl = FLConfig(transport=TransportConfig(
            kind=kind, fec_parity=parity, timeout_ns=2 * NS,
            udp_deadline_ns=3 * NS))
        fleet = FleetConfig(n_clients=6, seed=2, engine="batched",
                            model="consensus", model_args={"n_params": 128})
        b = build_fleet_training(fleet, fl)
        b.system.run_rounds(2)
        out[kind] = ({k: v.tolist()
                      for k, v in b.system.global_params.items()},
                     b.sim.now_ns)
    assert out["mudp"] == out["mudp+fec"]
