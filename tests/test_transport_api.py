"""The pluggable Transport API: registry, capability flags, the unified
Delivery contract, and the mudp+fec loss-repair guarantees.

Deliberately hypothesis-free (unlike test_transport_properties.py) so it runs
in minimal environments; the FEC "property" tests enumerate drop patterns
exhaustively instead of sampling them.
"""

import dataclasses

import numpy as np
import pytest

from repro.core import (BernoulliLoss, Delivery, DropList, FederatedSystem,
                        FLClient, FLConfig, Link, NoLoss, Simulator, Transport,
                        TransportCaps, TransportConfig, available_transports,
                        make_transport, register_transport)
from repro.core.fec import (FecMudpReceiver, FecMudpSender,
                            expected_parity_count, parity_groups)
from repro.core.packetizer import packetize
from repro.core.transport import _REGISTRY

C, S = "10.0.0.1", "10.0.0.2"
SERVER = "10.1.2.5"


def link_pair(sim, loss=None, rate=1e7, delay=50_000_000):
    sim.connect(C, S, Link(rate, delay, loss or NoLoss()), Link(rate, delay))


def run_transfer(kind, data, loss=None, *, cfg=None, mtu=156):
    """One transaction C -> S through the public Transport API."""
    cfg = cfg or TransportConfig(kind=kind, timeout_ns=2_000_000_000,
                                 udp_deadline_ns=3_000_000_000, fec_block=4)
    transport = make_transport(kind)
    sim = Simulator()
    link_pair(sim, loss)
    pkts = packetize(data, C, txn=5, mtu=mtu)
    seen, outcome = [], {}
    rx = transport.create_receiver(sim, sim.node(S), cfg, seen.append)
    tx = transport.create_sender(sim, sim.node(C), sim.node(S), pkts, cfg,
                                 on_complete=lambda s: outcome.update(ok=True),
                                 on_fail=lambda s: outcome.update(ok=False))
    tx.start()
    sim.run()
    return seen, outcome, tx, rx, len(pkts)


# --------------------------------------------------------------------------
# Registry
# --------------------------------------------------------------------------
class TestRegistry:
    def test_builtins_are_registered(self):
        names = available_transports()
        for name in ("mudp", "udp", "tcp", "mudp+fec"):
            assert name in names

    def test_register_make_roundtrip(self):
        class NullTransport(Transport):
            name = "null-test"
            caps = TransportCaps(reliable=False, supports_fail_cb=False)

            def create_sender(self, sim, src, dst, packets, cfg, *,
                              on_complete=None, on_fail=None):
                raise NotImplementedError

            def create_receiver(self, sim, node, cfg, on_deliver):
                raise NotImplementedError

        try:
            register_transport("null-test", NullTransport)
            assert "null-test" in available_transports()
            made = make_transport("null-test")
            assert isinstance(made, NullTransport)
            assert made.caps.reliable is False
            # registered names are immediately valid config kinds
            TransportConfig(kind="null-test")
        finally:
            _REGISTRY.pop("null-test", None)

    def test_duplicate_name_raises(self):
        with pytest.raises(ValueError, match="already registered"):
            register_transport("mudp", lambda: None)

    def test_unknown_name_raises_with_listing(self):
        with pytest.raises(ValueError, match="mudp"):
            make_transport("quic")

    def test_unknown_kind_fails_at_config_construction(self):
        with pytest.raises(ValueError, match="registered transports"):
            TransportConfig(kind="carrier-pigeon")

    def test_unknown_kind_fails_at_flconfig_replace(self):
        cfg = FLConfig()
        bad = dataclasses.replace(cfg.transport)
        bad.kind = "carrier-pigeon"   # post-construction typo
        with pytest.raises(ValueError, match="registered transports"):
            dataclasses.replace(cfg, transport=bad)


# --------------------------------------------------------------------------
# The unified Delivery contract, over every registered transport
# --------------------------------------------------------------------------
class TestDeliveryContract:
    @pytest.mark.parametrize("kind", available_transports())
    def test_lossless_link_same_bytes_out(self, kind):
        data = bytes(range(256)) * 13  # ~3.3KB -> many packets at mtu=156
        seen, outcome, tx, rx, total = run_transfer(kind, data)
        assert outcome.get("ok") is True
        assert len(seen) == 1, "on_deliver must fire exactly once"
        d = seen[0]
        assert isinstance(d, Delivery)
        assert d.sender_addr == C
        assert d.txn == 5
        assert d.total == total
        assert d.complete is True
        assert sorted(d.packets) == list(range(1, total + 1))
        assert d.reassemble() == data

    @pytest.mark.parametrize("kind", available_transports())
    def test_caps_reliable_transports_survive_loss(self, kind):
        caps = make_transport(kind).caps
        data = bytes(range(256)) * 13
        seen, outcome, *_ = run_transfer(
            kind, data, loss=BernoulliLoss(p=0.15, seed=7))
        assert len(seen) == 1
        d = seen[0]
        if caps.reliable:
            assert d.complete and d.reassemble() == data
        else:
            assert caps.partial_delivery
            # whatever arrived is delivered; gaps zero-fill
            assert len(d.reassemble()) > 0

    def test_partial_delivery_flag_reflects_gaps(self):
        data = bytes(range(256)) * 13
        seen, _, _, _, total = run_transfer("udp", data,
                                            loss=DropList({(2, 0)}))
        d = seen[0]
        assert d.complete is False
        assert d.total == total
        assert 2 not in d.packets
        blob = d.reassemble()
        assert len(blob) == len(data)
        chunk = len(packetize(data, C, mtu=156)[0].payload)
        assert blob[chunk:2 * chunk] == b"\x00" * chunk


# --------------------------------------------------------------------------
# mudp+fec: forward repair of isolated losses
# --------------------------------------------------------------------------
class TestFecRepair:
    N_PACKETS = 9          # at mtu=156 with the data below
    DATA = bytes(range(256)) * 5  # 1280B -> 9 packets of <=128B data + hdr

    def _run(self, drops, fec_block=4, fec_parity=1):
        cfg = TransportConfig(kind="mudp+fec", timeout_ns=2_000_000_000,
                              fec_block=fec_block, fec_parity=fec_parity)
        seen, outcome, tx, rx, total = run_transfer(
            "mudp+fec", self.DATA, loss=DropList(drops), cfg=cfg)
        return seen, outcome, tx, rx, total

    def test_single_loss_per_block_repairs_with_zero_nacks(self):
        # Property, enumerated exhaustively: ANY single dropped data packet
        # per block is repaired forward => no NACK is ever sent.
        _, _, _, _, total = self._run(set())
        for seq in range(1, total + 1):
            seen, outcome, tx, rx, _ = self._run({(seq, 0)})
            assert outcome.get("ok") is True, f"seq {seq}"
            assert seen[0].complete and seen[0].reassemble() == self.DATA
            assert rx.stats_nacks_sent == 0, \
                f"seq {seq}: FEC should repair without NACKs"
            assert rx.stats_repairs == 1
            assert tx.stats.retransmissions == 0

    def test_one_loss_in_every_block_still_zero_nacks(self):
        drops = {(1, 0), (6, 0), (9, 0)}  # blocks are 1-4, 5-8, 9
        seen, outcome, tx, rx, _ = self._run(drops)
        assert outcome.get("ok") is True
        assert seen[0].reassemble() == self.DATA
        assert rx.stats_nacks_sent == 0
        assert rx.stats_repairs == 3

    def test_double_loss_in_one_group_falls_back_to_nack(self):
        seen, outcome, tx, rx, _ = self._run({(2, 0), (3, 0)})
        assert outcome.get("ok") is True
        assert seen[0].reassemble() == self.DATA
        assert rx.stats_nacks_sent > 0          # FEC could not cover this
        assert tx.stats.retransmissions > 0

    def test_interleaved_parity_covers_two_losses_per_block(self):
        # k=2 parity per block: seqs 2 and 3 land in different XOR groups.
        seen, outcome, tx, rx, _ = self._run({(2, 0), (3, 0)}, fec_parity=2)
        assert outcome.get("ok") is True
        assert seen[0].reassemble() == self.DATA
        assert rx.stats_nacks_sent == 0
        assert rx.stats_repairs == 2

    def test_lost_parity_is_harmless(self):
        # Drop a parity packet (attempt 0 of parity idx 1) AND a data packet
        # of another block: data still recovers (via NACK for its own block
        # if needed), and the transfer completes.
        class DropParity:
            def __init__(self):
                self.dropped = False

            def drops(self, pkt):
                from repro.core.packets import PacketKind
                if (pkt.kind == PacketKind.PARITY and pkt.seq == 1
                        and not self.dropped):
                    self.dropped = True
                    return True
                return False

        cfg = TransportConfig(kind="mudp+fec", timeout_ns=1_000_000_000,
                              fec_block=4)
        seen, outcome, tx, rx, total = run_transfer(
            "mudp+fec", self.DATA, loss=DropParity(), cfg=cfg)
        assert outcome.get("ok") is True
        assert seen[0].reassemble() == self.DATA

    def test_parity_overhead_is_bounded(self):
        _, _, tx, _, total = self._run(set())
        assert tx.stats.parity_sent == expected_parity_count(total, 4, 1)
        assert tx.stats.data_sent == total

    def test_parity_groups_partition_the_block(self):
        for total in (1, 3, 8, 17):
            for block in (1, 4, 8):
                for k in (1, 2, 3):
                    groups = parity_groups(total, block, k)
                    covered = sorted(s for g in groups for s in g)
                    assert covered == list(range(1, total + 1))


# --------------------------------------------------------------------------
# FL integration through the registry
# --------------------------------------------------------------------------
def _const_train(value):
    def fn(params, round_idx, client):
        return ({k: np.full_like(v, value) for k, v in params.items()}, {})
    return fn


def _build_system(kind, loss_models=None, mtu=1500, **cfg_kw):
    sim = Simulator()
    clients = []
    for i, value in enumerate((1.0, 3.0)):
        addr = f"10.1.2.{10 + i}"
        lm = (loss_models or {}).get(addr, NoLoss())
        sim.connect(addr, SERVER, Link(1e8, 1_000_000, lm),
                    Link(1e8, 1_000_000))
        clients.append(FLClient(addr, _const_train(value),
                                train_time_ns=1_000_000))
    params = {"w": np.zeros((300,), np.float32)}
    cfg = FLConfig(aggregation="fedavg",
                   transport=TransportConfig(kind=kind, mtu=mtu,
                                             timeout_ns=1_000_000_000,
                                             udp_deadline_ns=2_000_000_000,
                                             **cfg_kw))
    return FederatedSystem(sim, SERVER, clients, params, cfg), sim


class TestFlThroughRegistry:
    @pytest.mark.parametrize("kind", available_transports())
    def test_lossless_round_agrees_across_transports(self, kind):
        system, _ = _build_system(kind)
        res = system.run_round()
        assert len(res.arrived) == 2
        np.testing.assert_allclose(system.global_params["w"], 2.0, atol=1e-6)

    def test_fec_round_survives_loss_with_fewer_retx_than_mudp(self):
        losses = lambda: {"10.1.2.10": BernoulliLoss(p=0.1, seed=3),
                          "10.1.2.11": BernoulliLoss(p=0.1, seed=4)}
        fec, _ = _build_system("mudp+fec", loss_models=losses(), mtu=200)
        plain, _ = _build_system("mudp", loss_models=losses(), mtu=200)
        rf = fec.run_round()
        rp = plain.run_round()
        assert sorted(rf.arrived) == sorted(rp.arrived)
        np.testing.assert_allclose(fec.global_params["w"],
                                   plain.global_params["w"], atol=1e-6)
        assert rf.retransmissions < rp.retransmissions

    @pytest.mark.parametrize("kind", ["mudp", "mudp+fec", "tcp"])
    def test_broadcast_ack_crosstalk_does_not_lose_a_client(self, kind):
        # Server broadcast runs one sender per client under the SAME txn on
        # the server node: client B's ACK must not complete (or steer) client
        # A's transaction while A is still recovering a dropped packet.
        sim = Simulator()
        clients = []
        for i, value in enumerate((1.0, 3.0)):
            addr = f"10.1.2.{10 + i}"
            # Client A: lossy AND slower downlink, so B's ACK reaches the
            # server before A's NACK — the exact interleaving where a
            # txn-only match lets B's ACK falsely complete A's sender.
            down_loss = DropList({(2, 0)}) if i == 0 else NoLoss()
            delay = 5_000_000 if i == 0 else 1_000_000
            sim.connect(addr, SERVER, Link(1e8, delay),
                        Link(1e8, delay, down_loss))
            clients.append(FLClient(addr, _const_train(value),
                                    train_time_ns=1_000_000))
        params = {"w": np.zeros((300,), np.float32)}
        cfg = FLConfig(aggregation="fedavg",
                       transport=TransportConfig(kind=kind, mtu=428,
                                                 timeout_ns=1_000_000_000))
        system = FederatedSystem(sim, SERVER, clients, params, cfg)
        res = system.run_round()
        assert sorted(res.arrived) == ["10.1.2.10", "10.1.2.11"]
        np.testing.assert_allclose(system.global_params["w"], 2.0, atol=1e-6)

    def test_partial_downlink_is_not_treated_as_full_model(self):
        # Drop one downlink broadcast packet: the udp client must train on a
        # zero-filled model (Delivery.complete=False path), not crash or
        # silently use stale params.
        sim = Simulator()
        addr = "10.1.2.10"
        sim.connect(addr, SERVER, Link(1e8, 1_000_000, NoLoss()),
                    Link(1e8, 1_000_000, DropList({(2, 0)})))
        received = {}

        def spy_train(params, round_idx, client):
            received["params"] = params
            return params, {}

        params = {"w": np.ones((300,), np.float32)}
        cfg = FLConfig(aggregation="fedavg",
                       transport=TransportConfig(kind="udp", mtu=428,
                                                 udp_deadline_ns=10 ** 9))
        client = FLClient(addr, spy_train, train_time_ns=1_000_000)
        system = FederatedSystem(sim, SERVER, [client], params, cfg)
        res = system.run_round()
        assert "params" in received, "client must still train"
        w = received["params"]["w"]
        assert (w == 0.0).any(), "gap must surface as zeros"
        assert (w == 1.0).any(), "delivered chunks must survive"
        assert res.arrived == [addr]
