"""Fleet-scale simulation tests: seeded determinism, partial participation,
and straggler-aware aggregation (hand-computed weighted FedAvg)."""

import dataclasses

import numpy as np
import pytest

from repro.core import (COHORT_PRESETS, ConsensusObjective, FLClient,
                        FLConfig, FleetConfig, Link, TransportConfig,
                        available_transports, build_fleet, cohort_counts,
                        links_for, profiles_digest, sample_profiles)
from repro.core.channel import NoLoss
from repro.core.packets import make_data_packet
from repro.core.rounds import FederatedSystem
from repro.core.simulator import Simulator

NS = 1_000_000_000
SERVER = "10.1.2.5"


# --------------------------------------------------------------------------
# Cohort / profile determinism
# --------------------------------------------------------------------------
class TestProfileDeterminism:
    def test_same_seed_bit_identical_profiles(self):
        cfg = FleetConfig(n_clients=64, seed=123)
        a, b = sample_profiles(cfg), sample_profiles(cfg)
        assert a == b                      # frozen dataclasses: exact equality
        assert profiles_digest(a) == profiles_digest(b)

    def test_different_seed_differs(self):
        a = sample_profiles(FleetConfig(n_clients=64, seed=1))
        b = sample_profiles(FleetConfig(n_clients=64, seed=2))
        assert a != b

    def test_cohort_mix_respected(self):
        cfg = FleetConfig(n_clients=400, seed=0)
        counts = cohort_counts(sample_profiles(cfg))
        assert set(counts) <= set(COHORT_PRESETS)
        # 30/50/20 mix within loose tolerance at n=400
        assert 60 <= counts["fiber"] <= 180
        assert 120 <= counts["lte"] <= 280
        assert 30 <= counts["congested-edge"] <= 150

    def test_profiles_within_cohort_bands(self):
        for p in sample_profiles(FleetConfig(n_clients=100, seed=5)):
            spec = COHORT_PRESETS[p.cohort]
            assert spec.up_rate_bps[0] <= p.up_rate_bps <= spec.up_rate_bps[1]
            assert spec.delay_ns[0] <= p.delay_ns <= spec.delay_ns[1]
            assert spec.loss_p[0] <= p.loss_p <= spec.loss_p[1]
            assert p.down_rate_bps == pytest.approx(
                p.up_rate_bps * spec.down_up_ratio)

    def test_unknown_cohort_rejected(self):
        cfg = FleetConfig(n_clients=4, cohort_mix=(("dialup", 1.0),))
        with pytest.raises(ValueError, match="dialup"):
            sample_profiles(cfg)

    def test_link_draws_deterministic(self):
        p = sample_profiles(FleetConfig(n_clients=8, seed=9))[3]
        up1, down1 = links_for(p)
        up2, down2 = links_for(p)
        for l1, l2 in ((up1, up2), (down1, down2)):
            assert (l1.data_rate_bps, l1.delay_ns, l1.jitter_ns,
                    l1.jitter_seed) == \
                   (l2.data_rate_bps, l2.delay_ns, l2.jitter_ns,
                    l2.jitter_seed)
            assert l1.loss == l2.loss


class TestLinkJitter:
    def test_jitter_deterministic_and_bounded(self):
        link = Link(1e8, 10_000_000, NoLoss(), jitter_ns=5_000_000,
                    jitter_seed=42)
        pkt = make_data_packet(1, 4, "10.0.0.2", b"x", txn=7)
        d1 = link.propagation_ns(pkt)
        assert d1 == link.propagation_ns(pkt)
        assert 10_000_000 <= d1 < 15_000_000

    def test_jitter_varies_per_packet(self):
        link = Link(1e8, 10_000_000, NoLoss(), jitter_ns=5_000_000)
        delays = {link.propagation_ns(
            make_data_packet(s, 64, "10.0.0.2", b"x", txn=1))
            for s in range(1, 65)}
        assert len(delays) > 1

    def test_zero_jitter_is_fixed_delay(self):
        link = Link(1e8, 10_000_000, NoLoss())
        pkt = make_data_packet(1, 1, "10.0.0.2", b"x")
        assert link.propagation_ns(pkt) == 10_000_000
        assert link.propagation_ns(None) == 10_000_000


# --------------------------------------------------------------------------
# Partial participation sampling (fleet wiring from the shared
# ``simple_star`` fixture in conftest.py)
# --------------------------------------------------------------------------
class TestPartialParticipation:
    def test_fraction_honored_and_deterministic(self, simple_star):
        cfg = FLConfig(participation_fraction=0.5, participation_seed=3)
        _, sys_a, _ = simple_star(8, cfg)
        _, sys_b, _ = simple_star(8, cfg)
        ra, rb = sys_a.run_round(), sys_b.run_round()
        assert len(ra.roster) == 4
        assert ra.roster == rb.roster
        assert ra.arrived == rb.arrived

    def test_rosters_rotate_across_rounds(self, simple_star):
        cfg = FLConfig(participation_fraction=0.5, participation_seed=0)
        _, system, _ = simple_star(12, cfg)
        rosters = {tuple(system.run_round().roster) for _ in range(6)}
        assert len(rosters) > 1

    def test_min_participants_floor(self, simple_star):
        cfg = FLConfig(participation_fraction=0.01, min_participants=2)
        _, system, _ = simple_star(6, cfg)
        assert len(system.run_round().roster) == 2

    def test_full_participation_unchanged(self, simple_star):
        cfg = FLConfig()   # participation_fraction=1.0 default
        _, system, _ = simple_star(5, cfg)
        assert len(system.run_round().roster) == 5


# --------------------------------------------------------------------------
# Straggler deadline -> hand-computed weighted FedAvg over arrivals
# --------------------------------------------------------------------------
class TestStragglerAggregation:
    def test_partial_aggregation_matches_hand_computed_fedavg(self):
        """Deadline cuts the straggler; the global model must equal the
        weighted FedAvg of exactly the arrived updates."""
        sim = Simulator()
        spec = [("10.1.2.10", 2.0, 3.0, 1_000_000),     # value, weight, fast
                ("10.1.2.11", 10.0, 1.0, 2_000_000),
                ("10.1.2.12", 99.0, 5.0, 50 * NS)]      # straggler
        clients = []
        for addr, value, weight, tt in spec:
            sim.connect(addr, SERVER, Link(1e8, 1_000_000, NoLoss()),
                        Link(1e8, 1_000_000, NoLoss()))

            def fn(params, round_idx, client, v=value):
                return ({k: np.full_like(p, v) for k, p in params.items()},
                        {})
            c = FLClient(addr, fn, train_time_ns=tt)
            c.weight = weight
            clients.append(c)
        cfg = FLConfig(aggregation="fedavg", round_deadline_ns=2 * NS)
        system = FederatedSystem(sim, SERVER, clients,
                                 {"w": np.zeros((40,), np.float32)}, cfg)
        res = system.run_round()
        assert res.arrived == ["10.1.2.10", "10.1.2.11"]
        assert "10.1.2.12" in res.roster
        expected = (3.0 * 2.0 + 1.0 * 10.0) / (3.0 + 1.0)   # = 4.0
        np.testing.assert_allclose(system.global_params["w"], expected,
                                   atol=1e-6)

    def test_fleet_round_outcome_bit_identical_across_builds(self):
        """Same FleetConfig seed => same cohorts, same samples, same link
        draws, bit-identical round outcomes and global model."""
        def one():
            fleet = FleetConfig(n_clients=24, seed=11,
                                participation_fraction=0.5,
                                round_deadline_ns=6 * NS)
            obj = ConsensusObjective(24, 256, seed=11)
            cfg = FLConfig(aggregation="fedavg",
                           transport=TransportConfig(
                               kind="mudp", timeout_ns=2 * NS))
            _, system, profiles = build_fleet(fleet, obj.init_params(),
                                              obj.train_fn, cfg)
            results = [system.run_round() for _ in range(2)]
            return profiles, results, system.global_params["w"]

        pa, ra, wa = one()
        pb, rb, wb = one()
        assert pa == pb
        for x, y in zip(ra, rb):
            assert dataclasses.asdict(x) == dataclasses.asdict(y)
        assert np.array_equal(wa, wb)        # bit-identical, not allclose


# --------------------------------------------------------------------------
# Every registered transport drives a fleet round
# --------------------------------------------------------------------------
class TestFleetAcrossTransports:
    @pytest.mark.parametrize("kind", available_transports())
    def test_fleet_round_completes(self, kind):
        fleet = FleetConfig(n_clients=12, seed=3, participation_fraction=0.75,
                            round_deadline_ns=15 * NS)
        obj = ConsensusObjective(12, 128, seed=3)
        cfg = FLConfig(aggregation="fedavg",
                       transport=TransportConfig(kind=kind, timeout_ns=2 * NS,
                                                 udp_deadline_ns=3 * NS))
        _, system, _ = build_fleet(fleet, obj.init_params(), obj.train_fn,
                                   cfg)
        res = system.run_round()
        assert len(res.roster) == 9
        assert res.bytes_sent > 0
        assert len(res.arrived) >= 1
        assert obj.loss(system.global_params) < obj.loss(obj.init_params())
