"""Substrate tests: optimizers, data pipeline, checkpointing, journal."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # property tests need hypothesis
from hypothesis import given, settings, strategies as st

from repro.checkpoint import CheckpointManager, FLJournal, load_pytree, \
    save_pytree
from repro.data import SyntheticMnist, TokenPipeline, federated_partitions
from repro.optim import AdamW, Sgd, TrainState, constant, cosine_schedule


class TestOptimizers:
    def _quadratic(self, opt, steps=200):
        target = jnp.asarray([1.5, -2.0, 0.5])
        params = {"w": jnp.zeros(3)}

        def loss(p):
            return jnp.sum((p["w"] - target) ** 2)

        state = opt.init(params)
        step = jnp.zeros((), jnp.int32)
        for _ in range(steps):
            g = jax.grad(loss)(params)
            params, state, _ = opt.update(g, state, params, step)
            step += 1
        return float(loss(params))

    def test_adamw_converges(self):
        assert self._quadratic(AdamW(schedule=constant(0.05),
                                     weight_decay=0.0)) < 1e-3

    def test_sgd_momentum_converges(self):
        assert self._quadratic(Sgd(schedule=constant(0.05),
                                   momentum=0.9)) < 1e-3

    def test_grad_clip_bounds_update(self):
        opt = AdamW(schedule=constant(1.0), grad_clip=1e-3, weight_decay=0.0)
        params = {"w": jnp.zeros(4)}
        state = opt.init(params)
        g = {"w": jnp.full((4,), 1e6)}
        _, _, m = opt.update(g, state, params, jnp.zeros((), jnp.int32))
        assert float(m["grad_norm"]) > 1e3  # reported pre-clip

    def test_cosine_schedule_shape(self):
        sch = cosine_schedule(1.0, warmup_steps=10, total_steps=100)
        assert float(sch(jnp.asarray(0))) == 0.0
        assert float(sch(jnp.asarray(10))) == pytest.approx(1.0, rel=1e-3)
        assert float(sch(jnp.asarray(100))) == pytest.approx(0.1, rel=1e-2)

    def test_adamw_moments_fp32_for_bf16_params(self):
        opt = AdamW(schedule=constant(1e-3))
        params = {"w": jnp.zeros(4, jnp.bfloat16)}
        state = opt.init(params)
        assert state["m"]["w"].dtype == jnp.float32


class TestPipeline:
    def test_deterministic_and_resumable(self):
        p1 = TokenPipeline(100, 16, 4, seed=3)
        p2 = TokenPipeline(100, 16, 4, seed=3)
        b1, b2 = p1.batch(5), p2.batch(5)
        np.testing.assert_array_equal(b1["tokens"], b2["tokens"])

    def test_labels_are_shifted_tokens(self):
        b = TokenPipeline(100, 16, 4, seed=0).batch(0)
        np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])

    def test_learnable_structure(self):
        """A bigram table fit on one batch beats uniform on the next."""
        p = TokenPipeline(50, 256, 8, seed=1)
        b0, b1 = p.batch(0), p.batch(1)
        counts = np.ones((50, 50))
        for row_t, row_l in zip(b0["tokens"].reshape(-1),
                                b0["labels"].reshape(-1)):
            counts[row_t, row_l] += 1
        probs = counts / counts.sum(1, keepdims=True)
        nll = -np.mean(np.log(probs[b1["tokens"].reshape(-1),
                                    b1["labels"].reshape(-1)]))
        assert nll < np.log(50) * 0.95

    def test_worker_slices_partition(self):
        p = TokenPipeline(100, 8, 8, seed=0)
        b = p.batch(0)
        slices = [p.worker_slice(b, w, 4) for w in range(4)]
        recon = np.concatenate([s["tokens"] for s in slices])
        np.testing.assert_array_equal(recon, b["tokens"])

    def test_federated_partitions_iid_share_distribution(self):
        ps = federated_partitions(100, 8, 4, 3, seed=0, non_iid=0.0)
        np.testing.assert_allclose(ps[0]._table_logits, ps[1]._table_logits)

    def test_federated_partitions_non_iid_differ(self):
        ps = federated_partitions(100, 8, 4, 2, seed=0, non_iid=0.8)
        assert np.abs(ps[0]._table_logits - ps[1]._table_logits).max() > 0.1

    def test_mnist_templates_separable(self):
        ds = SyntheticMnist(seed=0)
        x, y = ds.sample(256, client=0, step=0)
        assert x.shape == (256, 784)
        # nearest-template classification is near perfect
        t = ds.templates.reshape(10, -1)
        pred = np.argmin(
            ((x[:, None] - t[None]) ** 2).sum(-1), axis=1)
        assert (pred == y).mean() > 0.9


class TestCheckpoint:
    def _tree(self, seed=0):
        rng = np.random.default_rng(seed)
        return {"a": rng.standard_normal((4, 5)).astype(np.float32),
                "nested": {"b": rng.integers(0, 10, (3,)).astype(np.int32),
                           "c": jnp.asarray(rng.standard_normal((2, 2)),
                                            jnp.bfloat16)}}

    def test_roundtrip(self, tmp_path):
        tree = self._tree()
        p = str(tmp_path / "x.ckpt")
        save_pytree(p, tree, {"round": 7})
        out, meta = load_pytree(p, tree)
        assert meta["round"] == 7
        np.testing.assert_array_equal(out["a"], tree["a"])
        np.testing.assert_array_equal(out["nested"]["b"], tree["nested"]["b"])
        np.testing.assert_array_equal(
            np.asarray(out["nested"]["c"], np.float32),
            np.asarray(tree["nested"]["c"], np.float32))

    def test_atomicity_tmp_never_left(self, tmp_path):
        p = str(tmp_path / "x.ckpt")
        save_pytree(p, self._tree(), {})
        assert not os.path.exists(p + ".tmp")

    def test_shape_mismatch_rejected(self, tmp_path):
        p = str(tmp_path / "x.ckpt")
        save_pytree(p, self._tree(), {})
        bad = self._tree()
        bad["a"] = np.zeros((9, 9), np.float32)
        with pytest.raises(ValueError):
            load_pytree(p, bad)

    def test_manager_retention_and_latest(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), keep=2)
        for s in (1, 2, 3, 4):
            mgr.save(s, self._tree(s))
        assert mgr.steps() == [3, 4]
        out, meta = mgr.restore(self._tree())
        assert meta["step"] == 4

    def test_manager_restore_specific_step(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), keep=5)
        for s in (1, 2):
            mgr.save(s, self._tree(s))
        out, meta = mgr.restore(self._tree(), step=1)
        np.testing.assert_array_equal(out["a"], self._tree(1)["a"])


class TestJournal:
    def test_resume_round_after_crash(self, tmp_path):
        p = str(tmp_path / "journal.jsonl")
        j = FLJournal(p)
        j.round_started(0, ["c1", "c2"])
        j.update_ingested(0, "c1")
        j.update_ingested(0, "c2")
        j.round_finalized(0, "ckpt_0", ["c1", "c2"], [])
        j.round_started(1, ["c1", "c2"])
        j.update_ingested(1, "c1")
        # crash here; new process reads the journal
        j2 = FLJournal(p)
        assert j2.last_finalized_round() == 0
        assert j2.resume_round() == 1
        assert j2.pending_clients() == ["c2"]
        assert j2.last_checkpoint() == "ckpt_0"

    def test_fresh_journal(self, tmp_path):
        j = FLJournal(str(tmp_path / "j.jsonl"))
        assert j.resume_round() == 0
        assert j.pending_clients() == []
