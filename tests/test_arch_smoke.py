"""Per-architecture smoke tests: reduced same-family config, one forward +
one train step on CPU, asserting output shapes and no NaNs. The FULL configs
are exercised only via the dry-run (ShapeDtypeStruct, no allocation).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, smoke_variant
from repro.models import model as M
from repro.models.transformer import padded_vocab
from repro.optim import AdamW, constant

B, S = 2, 32


def make_batch(cfg, rng):
    tokens = jax.random.randint(rng, (B, S), 0, cfg.vocab_size)
    batch = {"tokens": tokens, "labels": tokens}
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(
            rng, (B, cfg.encoder_seq, cfg.d_model), jnp.float32)
    if cfg.mrope:
        batch["positions"] = jnp.broadcast_to(
            jnp.arange(S, dtype=jnp.int32)[None, None], (3, B, S))
        batch["vision_embeds"] = 0.02 * jax.random.normal(
            rng, (B, cfg.vision_tokens, cfg.d_model), jnp.float32)
    return batch


@pytest.fixture(scope="module", params=ARCH_IDS)
def arch(request):
    return request.param


class TestSmoke:
    def test_forward_loss_finite(self, arch):
        cfg = smoke_variant(get_config(arch))
        rng = jax.random.PRNGKey(0)
        params = M.init(cfg, rng)
        batch = make_batch(cfg, rng)
        loss = M.loss_fn(cfg)(params, batch)
        assert loss.shape == ()
        assert bool(jnp.isfinite(loss)), f"{arch}: non-finite loss"
        # an untrained model should be near ln(V) perplexity
        assert 0.5 * np.log(cfg.vocab_size) < float(loss) < \
            2.5 * np.log(padded_vocab(cfg))

    def test_train_step_updates_and_finite(self, arch):
        cfg = smoke_variant(get_config(arch))
        rng = jax.random.PRNGKey(1)
        opt = AdamW(schedule=constant(1e-3), weight_decay=0.0)
        state = M.init_train_state(cfg, opt, rng)
        step = jax.jit(M.make_train_step(cfg, opt))
        batch = make_batch(cfg, rng)
        new_state, metrics = step(state, batch)
        assert int(new_state.step) == 1
        assert bool(jnp.isfinite(metrics["loss"]))
        assert bool(jnp.isfinite(metrics["grad_norm"]))
        assert float(metrics["grad_norm"]) > 0.0
        # params actually moved
        moved = jax.tree_util.tree_map(
            lambda a, b: float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                               - b.astype(jnp.float32)))),
            state.params, new_state.params)
        assert max(jax.tree_util.tree_leaves(moved)) > 0.0

    def test_loss_decreases_over_steps(self, arch):
        cfg = smoke_variant(get_config(arch))
        rng = jax.random.PRNGKey(2)
        opt = AdamW(schedule=constant(3e-3), weight_decay=0.0)
        state = M.init_train_state(cfg, opt, rng)
        step = jax.jit(M.make_train_step(cfg, opt))
        batch = make_batch(cfg, rng)   # fixed batch -> must memorize
        losses = []
        for _ in range(8):
            state, metrics = step(state, batch)
            losses.append(float(metrics["loss"]))
        assert losses[-1] < losses[0], f"{arch}: {losses}"

    def test_decode_step(self, arch):
        cfg = smoke_variant(get_config(arch))
        if not cfg.has_decoder:
            pytest.skip("encoder-only arch has no decode step")
        rng = jax.random.PRNGKey(3)
        params = M.init(cfg, rng)
        cache = M.init_cache(cfg, B, S)
        decode = jax.jit(M.make_decode_step(cfg))
        tokens = jax.random.randint(rng, (B, 1), 0, cfg.vocab_size)
        kwargs = {}
        if cfg.family == "encdec":
            # warm the cross-KV via prefill on a short prompt
            prefill = M.make_prefill_step(cfg, attn_impl="einsum")
            frames = jax.random.normal(
                rng, (B, cfg.encoder_seq, cfg.d_model), jnp.float32)
            lg, cache_p = prefill(params, {"tokens": tokens,
                                           "frames": frames})
            pad = S - cache_p["k"].shape[2]
            cache = dict(
                cache_p,
                k=jnp.pad(cache_p["k"], ((0, 0), (0, 0), (0, pad),
                                         (0, 0), (0, 0))),
                v=jnp.pad(cache_p["v"], ((0, 0), (0, 0), (0, pad),
                                         (0, 0), (0, 0))))
        logits, new_cache = decode(params, cache, tokens)
        assert logits.shape == (B, padded_vocab(cfg))
        assert bool(jnp.isfinite(logits).all()), f"{arch}: NaN logits"
        assert int(new_cache["pos"]) == int(cache["pos"]) + 1

    def test_decode_matches_forward(self, arch):
        """Greedy decode logits at position t must match the full forward at
        position t (cache correctness), for cache-based families."""
        cfg = smoke_variant(get_config(arch))
        if cfg.family not in ("dense", "moe", "hybrid", "ssm"):
            pytest.skip("covered via family-specific tests")
        rng = jax.random.PRNGKey(4)
        params = M.init(cfg, rng)
        tokens = jax.random.randint(rng, (B, 8), 0, cfg.vocab_size)
        # full forward logits
        if cfg.family == "ssm":
            from repro.models import xlstm as X
            hidden = X.xlstm_hidden(cfg, params, tokens, "none")
            full = jnp.einsum("bsd,vd->bsv", hidden, params["embed"])
        elif cfg.family == "hybrid":
            from repro.models import hymba as HY
            hidden = HY.hymba_hidden(cfg, params, tokens, "none")
            full = jnp.einsum("bsd,vd->bsv", hidden, params["embed"])
        else:
            from repro.models import transformer as T
            hidden = T.decoder_hidden(cfg, params, tokens,
                                      remat_policy="none")
            full = T.decoder_logits(cfg, params, hidden)
        # token-by-token decode
        cache = M.init_cache(cfg, B, 8)
        decode = jax.jit(M.make_decode_step(cfg))
        outs = []
        for t in range(8):
            lg, cache = decode(params, cache, tokens[:, t:t + 1])
            outs.append(lg)
        stepwise = jnp.stack(outs, axis=1)
        np.testing.assert_allclose(np.asarray(stepwise),
                                   np.asarray(full), rtol=2e-2, atol=2e-2)


class TestConfigs:
    def test_all_archs_registered(self):
        from repro.configs import list_configs
        assert set(ARCH_IDS) <= set(list_configs())

    @pytest.mark.parametrize("name,expect", [
        ("granite-34b", dict(num_layers=88, d_model=6144, num_heads=48,
                             num_kv_heads=1, d_ff=24576, vocab_size=49152)),
        ("starcoder2-7b", dict(num_layers=32, d_model=4608, num_heads=36,
                               num_kv_heads=4, d_ff=18432,
                               vocab_size=49152)),
        ("yi-9b", dict(num_layers=48, d_model=4096, num_heads=32,
                       num_kv_heads=4, d_ff=11008, vocab_size=64000)),
        ("gemma3-12b", dict(num_layers=48, d_model=3840, num_heads=16,
                            num_kv_heads=8, d_ff=15360, vocab_size=262144)),
        ("whisper-tiny", dict(num_layers=4, d_model=384, num_heads=6,
                              num_kv_heads=6, d_ff=1536, vocab_size=51865)),
        ("qwen3-moe-235b-a22b", dict(num_layers=94, d_model=4096,
                                     num_heads=64, num_kv_heads=4,
                                     d_ff=1536, vocab_size=151936,
                                     num_experts=128,
                                     num_experts_per_tok=8)),
        ("olmoe-1b-7b", dict(num_layers=16, d_model=2048, num_heads=16,
                             num_kv_heads=16, d_ff=1024, vocab_size=50304,
                             num_experts=64, num_experts_per_tok=8)),
        ("qwen2-vl-72b", dict(num_layers=80, d_model=8192, num_heads=64,
                              num_kv_heads=8, d_ff=29568,
                              vocab_size=152064)),
        ("xlstm-350m", dict(num_layers=24, d_model=1024, num_heads=4,
                            d_ff=0, vocab_size=50304)),
        ("hymba-1.5b", dict(num_layers=32, d_model=1600, num_heads=25,
                            num_kv_heads=5, d_ff=5504, vocab_size=32001,
                            ssm_state=16)),
    ])
    def test_exact_assigned_config(self, name, expect):
        cfg = get_config(name)
        for k, v in expect.items():
            assert getattr(cfg, k) == v, (name, k, getattr(cfg, k), v)

    def test_param_counts_in_expected_band(self):
        """Analytic N close to the published sizes (sanity on configs)."""
        bands = {"granite-34b": (30e9, 40e9), "starcoder2-7b": (6e9, 9e9),
                 "yi-9b": (7.5e9, 10e9), "gemma3-12b": (9e9, 14e9),
                 "whisper-tiny": (25e6, 60e6),
                 "qwen3-moe-235b-a22b": (200e9, 260e9),
                 "olmoe-1b-7b": (5.5e9, 8e9),
                 "qwen2-vl-72b": (60e9, 80e9),
                 "xlstm-350m": (250e6, 500e6),
                 "hymba-1.5b": (1.1e9, 2.0e9)}
        for name, (lo, hi) in bands.items():
            n = get_config(name).param_count()
            assert lo <= n <= hi, f"{name}: {n/1e9:.2f}B not in band"

    @pytest.mark.parametrize("name", ARCH_IDS)
    def test_analytic_count_matches_allocation(self, name):
        """param_count() must track what init() actually allocates (it feeds
        MODEL_FLOPS in the roofline) — checked exactly on the smoke config,
        up to vocab padding and small biases/norms."""
        cfg = smoke_variant(get_config(name))
        params = M.init(cfg, jax.random.PRNGKey(0))
        actual = sum(int(np.prod(l.shape))
                     for l in jax.tree_util.tree_leaves(params))
        analytic = cfg.param_count()
        assert abs(actual - analytic) / actual < 0.15, \
            f"{name}: analytic {analytic} vs actual {actual}"
