"""Topology engine: registry, star bit-identity, hier and gossip semantics.

The load-bearing test is ``test_star_bit_identical_to_historical_wiring``:
it hand-rolls the exact pre-topology ``build_fleet`` body and asserts the
topology-engine path produces byte-identical global params and an
identical simulator stats digest — the same guarantee the 24 pinned
orchestrator-equivalence digests give at the scheduler level.
"""

import dataclasses

import numpy as np
import pytest

from repro.core.fleet import (ConsensusObjective, FleetConfig, build_fleet,
                              links_for, sample_profiles)
from repro.core.rounds import (FederatedSystem, FLClient, FLConfig,
                               TransportConfig)
from repro.core.simulator import Simulator
from repro.core.topology import (GossipTopology, HierSystem, StarTopology,
                                 Topology, available_topologies,
                                 edge_client_addr, edge_server_addr,
                                 make_topology, neighbor_graph,
                                 register_topology, topology_hops)
from repro.core.wire import WireError, parse_hop_specs


# Fleet construction and the params hash come from the shared
# ``consensus_fleet`` / ``params_digest`` fixtures in conftest.py.

# --------------------------------------------------------------------------
# Registry
# --------------------------------------------------------------------------
def test_registry_lists_builtins():
    assert available_topologies() == ["gossip", "hier", "star"]
    assert isinstance(make_topology("star"), StarTopology)


def test_registry_unknown_name():
    with pytest.raises(ValueError, match="unknown topology"):
        make_topology("mesh")


def test_registry_refuses_silent_shadowing():
    with pytest.raises(ValueError, match="already registered"):
        register_topology("star", StarTopology)


def test_topology_hops():
    assert topology_hops("star") == ("client->server", "server->client")
    assert "edge->root" in topology_hops("hier")
    assert topology_hops("gossip") == ("peer->peer",)


# --------------------------------------------------------------------------
# Per-hop wire spec parsing
# --------------------------------------------------------------------------
def test_parse_hop_specs():
    out = parse_hop_specs(
        "client->edge: topk(0.01)|int8(1024); edge->root: delta",
        known_hops=topology_hops("hier"))
    assert out == {"client->edge": "topk(0.01)|int8(1024)",
                   "edge->root": "delta"}


@pytest.mark.parametrize("spec", [
    "",                                     # empty
    "client->edge",                         # no pipeline
    "client->edge: raw; client->edge: hex",  # duplicate hop
    "client->edge: not_a_stage",            # bad pipeline
    "nope->where: raw",                     # unknown hop
])
def test_parse_hop_specs_rejects(spec):
    with pytest.raises(WireError):
        parse_hop_specs(spec, known_hops=topology_hops("hier"))


# --------------------------------------------------------------------------
# star: bit-identical to the historical wiring
# --------------------------------------------------------------------------
def test_star_bit_identical_to_historical_wiring(params_digest):
    n, rounds = 12, 3
    obj = ConsensusObjective(n, 48, seed=3)
    fleet = FleetConfig(n_clients=n, seed=7)
    base_cfg = FLConfig(transport=TransportConfig(kind="mudp"))

    # The exact pre-topology-engine build_fleet body.
    profiles = sample_profiles(fleet)
    fl_cfg = dataclasses.replace(
        base_cfg,
        participation_fraction=fleet.participation_fraction,
        min_participants=fleet.min_participants,
        participation_seed=fleet.seed,
        round_deadline_ns=fleet.round_deadline_ns,
        mode=fleet.mode,
        buffer_k=fleet.buffer_k)
    sim_old = Simulator(engine=fleet.engine)
    clients = []
    for i, p in enumerate(profiles):
        up, down = links_for(p)
        sim_old.connect(p.addr, fleet.server_addr, up, down)
        clients.append(FLClient(p.addr, obj.train_fn(i, p),
                                train_time_ns=p.train_time_ns,
                                weight=p.weight, cadence_ns=p.cadence_ns))
    old = FederatedSystem(sim_old, fleet.server_addr, clients,
                          obj.init_params(), fl_cfg)
    old_results = old.run_rounds(rounds)

    sim_new, new, _ = build_fleet(fleet, obj.init_params(),
                                  lambda i, p: obj.train_fn(i, p), base_cfg)
    new_results = new.run_rounds(rounds)

    assert params_digest(new.global_params) == \
        params_digest(old.global_params)
    assert sim_new.stats_digest() == sim_old.stats_digest()
    for a, b in zip(old_results, new_results):
        assert (a.arrived, a.failed, a.bytes_sent, a.duration_ns) == \
            (b.arrived, b.failed, b.bytes_sent, b.duration_ns)


def test_star_hop_counters_cover_all_traffic(consensus_fleet):
    _, sim, _, _ = consensus_fleet("star")
    assert set(sim.hop_bytes) == {"client->server", "server->client"}
    assert sum(sim.hop_bytes.values()) == sim.stats["bytes_sent"]
    assert sum(sim.hop_packets.values()) == sim.stats["packets_sent"]


# --------------------------------------------------------------------------
# hier: edge aggregation
# --------------------------------------------------------------------------
def test_hier_matches_star_final_model(consensus_fleet):
    obj_s, _, star, _ = consensus_fleet("star", n=16)
    obj_h, _, hier, _ = consensus_fleet("hier", n=16, cells=4)
    np.testing.assert_allclose(hier.global_params["w"],
                               star.global_params["w"],
                               rtol=1e-5, atol=1e-6)
    assert abs(obj_h.loss(hier.global_params)
               - obj_s.loss(star.global_params)) < 1e-6


def test_hier_root_link_smaller_than_star(consensus_fleet):
    _, sim_s, _, _ = consensus_fleet("star", n=16)
    _, sim_h, _, _ = consensus_fleet("hier", n=16, cells=4)
    assert sim_h.hop_bytes["edge->root"] < sim_s.hop_bytes["client->server"]
    assert set(sim_h.hop_bytes) == {"client->edge", "edge->client",
                                    "edge->root", "root->edge"}
    assert sum(sim_h.hop_bytes.values()) == sim_h.stats["bytes_sent"]


def test_hier_cell_assignment_round_robin(consensus_fleet):
    fleet = FleetConfig(n_clients=10, topology="hier", cells=3)
    assert [fleet.cell_of(i) for i in range(6)] == [0, 1, 2, 0, 1, 2]
    _, _, hier, _ = consensus_fleet("hier", n=10, cells=3, rounds=1)
    assert isinstance(hier, HierSystem)
    sizes = sorted(len(e.core.pool.clients) for e in hier.edges)
    assert sizes == [3, 3, 4]
    # Every client is in exactly one cell, and edge_for finds it.
    for e in hier.edges:
        for addr in e.core.pool.clients:
            assert hier.edge_for(addr) is e


def test_hier_addresses_are_dual_plane(consensus_fleet):
    _, sim, hier, _ = consensus_fleet("hier", n=8, cells=2, rounds=1)
    for m, e in enumerate(hier.edges):
        assert e.addr == edge_client_addr(m)
        assert e.server_addr == edge_server_addr(m)
        assert e.addr != e.server_addr


def test_hier_per_cell_histories_advance(consensus_fleet):
    _, _, hier, results = consensus_fleet("hier", n=16, cells=4, rounds=3)
    assert len(results) == 3
    for e in hier.edges:
        assert len(e.core.history) == 3


def test_hier_async_root(consensus_fleet):
    _, sim, hier, results = consensus_fleet(
        "hier", n=16, cells=4, rounds=3, mode="async", buffer_k=4,
        round_deadline_ns=120_000_000_000)
    assert len(results) == 3
    assert sim.hop_bytes["edge->root"] > 0


def test_hier_cell_scheduler_refuses_direct_drive(consensus_fleet):
    _, _, hier, _ = consensus_fleet("hier", n=8, cells=2, rounds=1)
    with pytest.raises(RuntimeError, match="parent tier"):
        hier.edges[0].scheduler.run_round()


def test_hier_per_hop_pipeline_specs(consensus_fleet):
    _, sim, hier, _ = consensus_fleet(
        "hier", n=16, cells=4,
        hops="client->edge: int8(48); edge->root: raw")
    plain = consensus_fleet("hier", n=16, cells=4)[1]
    # int8 quantization (block sized to the model) shrinks the cell uplink
    # vs the raw float default.
    assert sim.hop_bytes["client->edge"] < plain.hop_bytes["client->edge"]


# --------------------------------------------------------------------------
# gossip: serverless
# --------------------------------------------------------------------------
def test_neighbor_graph_connected_and_seeded():
    adj = neighbor_graph(20, 4, seed=1)
    assert adj == neighbor_graph(20, 4, seed=1)
    assert all(len(a) >= 4 for a in adj)
    assert all(i not in adj[i] for i in range(20))
    # Symmetry.
    for i in range(20):
        for j in adj[i]:
            assert i in adj[j]
    # Ring edges guarantee connectivity.
    seen, stack = {0}, [0]
    while stack:
        for j in adj[stack.pop()]:
            if j not in seen:
                seen.add(j)
                stack.append(j)
    assert len(seen) == 20


def test_gossip_has_zero_server_nodes(consensus_fleet):
    fleet_server = FleetConfig(n_clients=12, topology="gossip",
                               neighbors=3).server_addr
    _, sim, system, results = consensus_fleet("gossip", n=12, neighbors=3)
    assert fleet_server not in sim._nodes
    assert set(sim.hop_bytes) == {"peer->peer"}
    assert sim.hop_bytes["peer->peer"] == sim.stats["bytes_sent"]
    assert results[-1].metrics["neighbors_mean"] > 0


def test_gossip_converges_and_is_deterministic(consensus_fleet,
                                               params_digest):
    obj1, _, s1, _ = consensus_fleet("gossip", n=12, neighbors=3, rounds=4)
    obj2, _, s2, _ = consensus_fleet("gossip", n=12, neighbors=3, rounds=4)
    assert params_digest(s1.global_params) == params_digest(s2.global_params)
    initial = obj1.loss({"w": np.zeros(48, np.float32)})
    assert obj1.loss(s1.global_params) < 0.5 * initial


def test_gossip_rejects_delta_pipelines():
    obj = ConsensusObjective(8, 16, seed=0)
    fleet = FleetConfig(n_clients=8, topology="gossip", neighbors=2,
                        hops="peer->peer: delta|int8(1024)")
    with pytest.raises(ValueError, match="delta|weight-domain"):
        build_fleet(fleet, obj.init_params(),
                    lambda i, p: obj.train_fn(i, p),
                    FLConfig(transport=TransportConfig(kind="mudp")))


# --------------------------------------------------------------------------
# FleetConfig validation (fail at construction, not deep in build)
# --------------------------------------------------------------------------
@pytest.mark.parametrize("kw,match", [
    (dict(topology="mesh"), "unknown topology"),
    (dict(topology="hier", cells=0), "cells"),
    (dict(topology="hier", cells=17), "cannot exceed"),
    (dict(topology="hier", edge_cohort="dialup"), "edge_cohort"),
    (dict(topology="hier", cell_transport="pigeon"), "transport"),
    (dict(topology="gossip", neighbors=0), "degree"),
    (dict(topology="gossip", neighbors=16), "must be <"),
    (dict(hops="client->server: bogus_stage"), "invalid hops"),
    (dict(hops="peer->peer: raw"), "invalid hops"),   # not a star hop
    (dict(hops="client->server: raw", uplink="raw"), "two spellings"),
    (dict(n_clients=0), "n_clients"),
])
def test_fleetconfig_validation(kw, match):
    base = dict(n_clients=16)
    base.update(kw)
    with pytest.raises(ValueError, match=match):
        FleetConfig(**base)


def test_custom_topology_plugs_in():
    class NullTopology(Topology):
        name = "null"
        hops = ()

        def build(self, fleet, profiles, global_params, train_fn_factory,
                  fl_cfg):
            return Simulator(), None

    register_topology("null", NullTopology, overwrite=True)
    try:
        fleet = FleetConfig(n_clients=2, topology="null")
        sim, system, profiles = build_fleet(fleet, {"w": np.zeros(4)},
                                            lambda i, p: None)
        assert system is None and len(profiles) == 2
    finally:
        import repro.core.topology as topo_mod
        del topo_mod._REGISTRY["null"]
