"""Launch-layer tests: loop-aware HLO cost analysis + dry-run plumbing."""

import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import pytest

from repro.launch.hlo_cost import analyze_hlo_text
from repro.launch.lowering import xla_cost_dict


class TestHloCost:
    def test_matmul_flops_match_xla(self):
        a = jax.ShapeDtypeStruct((512, 512), jnp.bfloat16)
        c = jax.jit(lambda a, b: a @ b).lower(a, a).compile()
        mine = analyze_hlo_text(c.as_text())
        assert mine.flops == pytest.approx(2 * 512 ** 3, rel=1e-6)
        # XLA's own count agrees on a loop-free graph
        assert mine.flops == pytest.approx(xla_cost_dict(c)["flops"],
                                           rel=0.01)

    def test_scan_flops_are_trip_count_multiplied(self):
        """THE reason this module exists: cost_analysis() counts a while
        body once; the analyzer multiplies by known_trip_count."""
        a = jax.ShapeDtypeStruct((512, 512), jnp.bfloat16)
        f = jax.jit(lambda a, b: jax.lax.scan(
            lambda x, _: (x @ b, None), a, None, length=7)[0])
        c = f.lower(a, a).compile()
        assert analyze_hlo_text(c.as_text()).flops == 7 * 2 * 512 ** 3
        assert xla_cost_dict(c)["flops"] < 2 * 2 * 512 ** 3  # undercounts

    def test_nested_scan_multiplies(self):
        a = jax.ShapeDtypeStruct((128, 128), jnp.float32)

        def inner(x, b):
            return jax.lax.scan(lambda y, _: (y @ b, None), x, None,
                                length=3)[0]

        f = jax.jit(lambda a, b: jax.lax.scan(
            lambda x, _: (inner(x, b), None), a, None, length=5)[0])
        c = f.lower(a, a).compile()
        assert analyze_hlo_text(c.as_text()).flops == \
            15 * 2 * 128 ** 3

    def test_collectives_counted_with_multipliers(self):
        code = textwrap.dedent("""
            import os
            os.environ['XLA_FLAGS']='--xla_force_host_platform_device_count=4'
            import inspect
            import jax, jax.numpy as jnp
            from jax.sharding import PartitionSpec as P
            from repro.launch.hlo_cost import analyze_hlo_text
            # jax.shard_map landed after 0.4.x; the replication-check kwarg
            # was renamed check_rep -> check_vma along the way.
            shard_map = getattr(jax, 'shard_map', None)
            if shard_map is None:
                from jax.experimental.shard_map import shard_map
            params = inspect.signature(shard_map).parameters
            kw = ({'check_vma': False} if 'check_vma' in params
                  else {'check_rep': False})
            mesh = jax.make_mesh((4,), ('x',))
            def f(a):
                return shard_map(lambda v: jax.lax.psum(v, 'x'),
                                 mesh=mesh, in_specs=P('x'),
                                 out_specs=P(), **kw)(a)
            a = jax.ShapeDtypeStruct((4, 256), jnp.float32)
            c = jax.jit(f).lower(a).compile()
            cost = analyze_hlo_text(c.as_text())
            ar = [k for k in cost.collective_counts if 'all-reduce' in k]
            assert ar, cost.collective_counts
            assert cost.collective_bytes > 0
            print('OK', cost.collective_bytes)
        """)
        r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                           text=True, env={"PYTHONPATH": "src",
                                           "PATH": "/usr/bin:/bin"})
        assert "OK" in r.stdout, r.stderr[-1500:]


class TestDryrunPlumbing:
    def test_smoke_cell_lowers_on_debug_mesh(self):
        """The full dry-run plumbing (rules, shardings, train step, HLO
        analysis) on a (2,2) mesh with a reduced config, in a subprocess so
        the main process keeps 1 device."""
        code = textwrap.dedent("""
            import os
            os.environ['XLA_FLAGS']='--xla_force_host_platform_device_count=4'
            import dataclasses, jax
            from repro.configs import get_config, smoke_variant, SHAPES
            from repro.configs.base import ShapeConfig, TrainConfig
            from repro.distributed import sharding as sh
            from repro.launch.lowering import _build_lowerable
            from repro.launch import hlo_cost

            cfg = dataclasses.replace(smoke_variant(get_config('yi-9b')),
                                      dtype='bfloat16')
            shape = ShapeConfig('t', 64, 8, 'train')
            mesh = jax.make_mesh((2, 2), ('data', 'model'))
            rules = sh.rules_for(cfg, shape, mesh)
            with sh.use_mesh(mesh, rules):
                fn, args = _build_lowerable(
                    cfg, shape, mesh, rules, attn_impl='einsum',
                    train_cfg=TrainConfig(grad_accum=2))
                compiled = fn.lower(*args).compile()
            mem = compiled.memory_analysis()
            cost = hlo_cost.analyze_hlo_text(compiled.as_text())
            assert mem.temp_size_in_bytes > 0
            assert cost.flops > 0
            assert cost.collective_bytes > 0   # grad reduce must exist
            print('OK')
        """)
        # Hang guard only, not a speed assertion: the yi-9b smoke compile
        # takes minutes on a share-throttled CPU, and 300s proved flaky.
        r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                           text=True, timeout=1200,
                           env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin"})
        assert "OK" in r.stdout, (r.stdout[-500:], r.stderr[-1500:])

    def test_rules_divisibility_fallbacks(self):
        """hymba's 25 heads / whisper's 6 heads cannot shard 16 ways -> the
        rules builder must drop those mappings, never crash."""
        code = textwrap.dedent("""
            import os
            os.environ['XLA_FLAGS']='--xla_force_host_platform_device_count=4'
            import jax
            from repro.configs import get_config, SHAPES
            from repro.distributed import sharding as sh
            mesh = jax.make_mesh((2, 2), ('data', 'model'))
            for arch, heads_dropped in (('hymba-1.5b', False),
                                        ('whisper-tiny', True)):
                cfg = get_config(arch)
                r = sh.rules_for(cfg, SHAPES['train_4k'], mesh)
                if cfg.num_heads % 2 != 0:
                    assert r['heads'] is None
                assert r['batch'] is not None
            # on the production 16-way axis both drop heads
            mesh16 = None
            print('OK')
        """)
        r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                           text=True, env={"PYTHONPATH": "src",
                                           "PATH": "/usr/bin:/bin"})
        assert "OK" in r.stdout, r.stderr[-1500:]

    def test_skip_policy(self):
        from repro.launch.lowering import cell_is_skipped
        assert cell_is_skipped("granite-34b", "long_500k") is not None
        assert cell_is_skipped("xlstm-350m", "long_500k") is None
        assert cell_is_skipped("gemma3-12b", "long_500k") is None
        assert cell_is_skipped("granite-34b", "train_4k") is None

    def test_model_flops_conventions(self):
        from repro.configs import SHAPES, get_config
        from repro.launch.lowering import model_flops
        cfg = get_config("yi-9b")
        n = cfg.active_param_count()
        assert model_flops(cfg, SHAPES["train_4k"]) == \
            pytest.approx(6 * n * 256 * 4096)
        assert model_flops(cfg, SHAPES["decode_32k"]) == \
            pytest.approx(2 * n * 128)
        moe = get_config("qwen3-moe-235b-a22b")
        assert moe.active_param_count() < 0.15 * moe.param_count()
