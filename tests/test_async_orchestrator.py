"""Async (FedBuff-style) scheduler semantics: buffered aggregation,
staleness discounting (with the underflow clamp), per-client cadence,
overlapping sessions on every transport, and deterministic replay."""

import dataclasses

import numpy as np
import pytest

from repro.core import (AsyncScheduler, ConsensusObjective, FLClient,
                        FLConfig, FederatedSystem, FleetConfig, Simulator,
                        TransportConfig, available_transports, build_fleet,
                        make_transport)
from repro.core.channel import DropList, Link, NoLoss

SERVER = "10.1.2.5"
NS = 1_000_000_000
MS = 1_000_000


def build(mode="async", n=4, cfg_kwargs=None, train_times=None,
          cadences=None, train_values=None, weights=None, loss_models=None,
          n_params=50):
    sim = Simulator()
    clients = []
    for i in range(n):
        addr = f"10.1.2.{10 + i}"
        lm = (loss_models or {}).get(addr, NoLoss())
        sim.connect(addr, SERVER, Link(1e8, 1 * MS, lm),
                    Link(1e8, 1 * MS, NoLoss()))

        def fn(params, round_idx, client, v=(train_values or {}).get(
                f"10.1.2.{10 + i}", float(i + 1))):
            return ({k: np.full_like(p, v) for k, p in params.items()}, {})
        c = FLClient(addr, fn,
                     train_time_ns=(train_times or {}).get(addr,
                                                           (i + 1) * 100 * MS),
                     cadence_ns=(cadences or {}).get(addr, 50 * MS))
        if weights and addr in weights:
            c.weight = weights[addr]
        clients.append(c)
    cfg = FLConfig(mode=mode, aggregation="fedavg",
                   transport=TransportConfig(kind="mudp", timeout_ns=NS),
                   **(cfg_kwargs or {}))
    params = {"w": np.zeros((n_params,), np.float32)}
    return sim, FederatedSystem(sim, SERVER, clients, params, cfg), clients


class TestBufferedAggregation:
    def test_aggregates_at_buffer_k(self):
        _, system, _ = build(cfg_kwargs={"buffer_k": 2})
        results = system.run_rounds(3)
        assert len(results) == 3
        for r in results:
            assert len(r.arrived) == 2           # exactly K per flush
            assert r.metrics["buffer_size"] == 2

    def test_rounds_overlap_fast_client_reenters(self):
        """The fastest client contributes to multiple aggregations while the
        slowest is still working — the barrier is gone."""
        _, system, _ = build(
            n=3, cfg_kwargs={"buffer_k": 2},
            train_times={"10.1.2.10": 50 * MS, "10.1.2.11": 60 * MS,
                         "10.1.2.12": 5 * NS})
        results = system.run_rounds(3)
        seen = [a for r in results for a in r.arrived]
        assert seen.count("10.1.2.10") >= 2      # re-entered mid-run
        assert all("10.1.2.12" in r.roster for r in results)  # still in flight

    def test_model_version_increments_per_aggregation(self):
        _, system, _ = build(cfg_kwargs={"buffer_k": 2})
        results = system.run_rounds(4)
        assert [r.metrics["model_version"] for r in results] == [1, 2, 3, 4]

    def test_partial_flush_on_drain(self):
        """Fewer clients than buffer_k with no re-entry possible: the drain
        flush folds what arrived instead of losing it."""
        _, system, _ = build(n=2, cfg_kwargs={"buffer_k": 50})
        results = system.run_rounds(1)
        assert len(results) == 1
        assert len(results[0].arrived) >= 2      # both clients (+ re-entries)

    def test_explicit_round_idx_rejected(self):
        _, system, _ = build()
        with pytest.raises(ValueError, match="sync-only"):
            system.run_round(round_idx=7)


class TestStaleness:
    def test_staleness_discount_hand_computed(self):
        """K=1: each arrival aggregates alone.  The second client's update
        was computed against version 0 but lands at version>0, so its
        weight is discount**staleness — pin the resulting model exactly."""
        _, system, _ = build(
            n=2, cfg_kwargs={"buffer_k": 1, "staleness_discount": 0.5},
            train_times={"10.1.2.10": 10 * MS, "10.1.2.11": 300 * MS},
            cadences={"10.1.2.10": 10 * NS, "10.1.2.11": 10 * NS},
            train_values={"10.1.2.10": 2.0, "10.1.2.11": 8.0})
        results = system.run_rounds(2)
        # Flush 1: client .10 alone (staleness 0) -> w = 2.0.  Flush 2:
        # client .11 alone; weight 0.5**1 but normalized over a single
        # contribution -> w = 8.0 regardless.  The *staleness accounting*
        # is what must be right:
        assert results[0].metrics["staleness_max"] == 0
        assert results[1].metrics["staleness_max"] == 1
        assert results[1].late_folded == 1
        np.testing.assert_allclose(system.global_params["w"], 8.0)

    def test_stale_update_downweighted_in_mixed_buffer(self):
        """A fresh and a stale update in one buffer: the stale one
        contributes discount/(1+discount) of the average."""
        _, system, _ = build(
            n=3, cfg_kwargs={"buffer_k": 2, "staleness_discount": 0.5},
            train_times={"10.1.2.10": 10 * MS, "10.1.2.11": 20 * MS,
                         "10.1.2.12": 500 * MS},
            cadences={"10.1.2.10": 1000 * MS, "10.1.2.11": 1200 * MS},
            train_values={"10.1.2.10": 1.0, "10.1.2.11": 1.0,
                          "10.1.2.12": 10.0})
        results = system.run_rounds(2)
        # Flush 1 (~22ms): .10 + .11, fresh, w=1 each -> w = 1.0.
        # .12 arrives (~0.5s) stale by 1 and waits in the buffer until
        # .10's re-entry (~1.03s) completes the pair:
        # w = (0.5*10 + 1*1) / 1.5 = 4.0
        assert results[1].metrics["staleness_max"] == 1
        np.testing.assert_allclose(system.global_params["w"], 4.0,
                                   atol=1e-6)

    def test_discount_underflow_clamped_not_dropped(self):
        """The bugfix: discount**age underflowing must clamp to
        staleness_floor and be reported, never silently zero the update."""
        _, system, _ = build(
            n=2, cfg_kwargs={"buffer_k": 1, "staleness_discount": 1e-200,
                             "staleness_floor": 1e-6},
            train_times={"10.1.2.10": 10 * MS, "10.1.2.11": 900 * MS},
            cadences={"10.1.2.10": 50 * MS},
            train_values={"10.1.2.10": 1.0, "10.1.2.11": 7.0})
        results = system.run_rounds(20)
        clamped = [r for r in results if r.staleness_clamped > 0]
        assert clamped, "straggler's discount**age must hit the floor"
        # The clamped contribution still aggregated (it flushed alone, so
        # normalization makes its value land despite the tiny weight).
        lone = [r for r in clamped if len(r.arrived) == 1
                and r.arrived == ["10.1.2.11"]]
        assert lone, "clamped update must still be aggregated"

    def test_max_staleness_drops_and_reports(self):
        _, system, _ = build(
            n=2, cfg_kwargs={"buffer_k": 1, "max_staleness": 0},
            train_times={"10.1.2.10": 10 * MS, "10.1.2.11": 900 * MS},
            cadences={"10.1.2.10": 50 * MS})
        results = system.run_rounds(20)
        dropped = sum(r.metrics["stale_dropped"] for r in results)
        assert dropped >= 1


class TestCadence:
    def test_cadence_throttles_reentry(self):
        """Same fleet, one client with a huge cadence: it contributes far
        fewer updates than its twin."""
        def run(cadence):
            _, system, _ = build(
                n=2, cfg_kwargs={"buffer_k": 1},
                train_times={"10.1.2.10": 10 * MS, "10.1.2.11": 10 * MS},
                cadences={"10.1.2.10": 1 * MS, "10.1.2.11": cadence})
            results = system.run_rounds(10)
            seen = [a for r in results for a in r.arrived]
            return seen.count("10.1.2.11")
        assert run(2 * NS) < run(1 * MS)


class TestTransportsAndDeterminism:
    @pytest.mark.parametrize("kind", available_transports())
    def test_async_runs_on_every_transport(self, kind):
        assert make_transport(kind).caps.concurrent_txns
        sim = Simulator()
        clients = []
        for i in range(4):
            addr = f"10.1.2.{10 + i}"
            sim.connect(addr, SERVER, Link(1e8, 1 * MS, NoLoss()),
                        Link(1e8, 1 * MS, NoLoss()))

            def fn(params, round_idx, client, v=float(i + 1)):
                return ({k: np.full_like(p, v) for k, p in params.items()},
                        {})
            clients.append(FLClient(addr, fn, train_time_ns=(i + 1) * 50 * MS,
                                    cadence_ns=20 * MS))
        cfg = FLConfig(mode="async", buffer_k=2,
                       transport=TransportConfig(kind=kind, timeout_ns=NS,
                                                 udp_deadline_ns=NS))
        system = FederatedSystem(sim, SERVER, clients,
                                 {"w": np.zeros((50,), np.float32)}, cfg)
        results = system.run_rounds(3)
        assert len(results) == 3
        assert all(len(r.arrived) >= 1 for r in results)

    def test_async_replay_bit_identical(self):
        def one():
            fleet = FleetConfig(n_clients=12, seed=5, mode="async",
                                buffer_k=3, round_deadline_ns=10 * NS)
            obj = ConsensusObjective(12, 128, seed=5)
            cfg = FLConfig(transport=TransportConfig(kind="mudp",
                                                     timeout_ns=2 * NS))
            _, system, _ = build_fleet(fleet, obj.init_params(),
                                       obj.train_fn, cfg)
            results = system.run_rounds(4)
            return results, system.global_params["w"]
        ra, wa = one()
        rb, wb = one()
        for x, y in zip(ra, rb):
            assert dataclasses.asdict(x) == dataclasses.asdict(y)
        assert np.array_equal(wa, wb)

    def test_async_engines_bit_identical(self):
        def one(engine):
            fleet = FleetConfig(n_clients=12, seed=5, mode="async",
                                buffer_k=3, engine=engine,
                                round_deadline_ns=10 * NS)
            obj = ConsensusObjective(12, 128, seed=5)
            _, system, _ = build_fleet(fleet, obj.init_params(), obj.train_fn)
            results = system.run_rounds(4)
            return ([dataclasses.asdict(r) for r in results],
                    system.global_params["w"])
        ra, wa = one("per_packet")
        rb, wb = one("batched")
        assert ra == rb
        assert np.array_equal(wa, wb)


class TestFailureHandling:
    def test_dead_client_benched_and_others_progress(self):
        """MUDP retry exhaustion on the dead uplink lands ~4 simulated
        seconds in (timeout * (1 + max_retries)); enough aggregations must
        be requested that a flush happens after it to report the failure."""
        dead = {(s, a) for s in range(1, 4000) for a in range(0, 80)}
        _, system, _ = build(
            n=3, cfg_kwargs={"buffer_k": 2, "unhealthy_after_failures": 1},
            loss_models={"10.1.2.12": DropList(dead)},
            train_times={"10.1.2.10": 20 * MS, "10.1.2.11": 30 * MS,
                         "10.1.2.12": 20 * MS})
        results = system.run_rounds(80)
        assert len(results) == 80
        failed = {a for r in results for a in r.failed}
        assert "10.1.2.12" in failed
        arrived = {a for r in results for a in r.arrived}
        assert "10.1.2.12" not in arrived

    def test_session_watchdog_recovers_stuck_udp_leg(self):
        """UDP with a fully dead uplink raises no failure callback; the
        per-session watchdog (round_deadline_ns) must re-enter the client
        instead of hanging the run."""
        dead = {(s, a) for s in range(1, 8000) for a in range(0, 200)}
        sim = Simulator()
        clients = []
        for i, (tt, lm) in enumerate(
                [(20 * MS, NoLoss()), (20 * MS, DropList(dead))]):
            addr = f"10.1.2.{10 + i}"
            sim.connect(addr, SERVER, Link(1e8, 1 * MS, lm),
                        Link(1e8, 1 * MS, NoLoss()))

            def fn(params, round_idx, client, v=float(i + 1)):
                return ({k: np.full_like(p, v) for k, p in params.items()},
                        {})
            clients.append(FLClient(addr, fn, train_time_ns=tt,
                                    cadence_ns=300 * MS))
        cfg = FLConfig(mode="async", buffer_k=2, round_deadline_ns=NS,
                       transport=TransportConfig(kind="udp",
                                                 udp_deadline_ns=20 * NS))
        system = FederatedSystem(sim, SERVER, clients,
                                 {"w": np.zeros((2000,), np.float32)}, cfg)
        results = system.run_rounds(6)
        assert len(results) == 6
        assert sum(r.metrics["session_timeouts"] for r in results) >= 1

    def test_all_dead_fleet_terminates(self):
        """Liveness: when every client's uplink is dead on a transport with
        no failure callback, repeated watchdog timeouts must bench the
        clients (timeout counts as a health failure) so the calendar
        drains and run_rounds returns instead of cycling forever."""
        dead = {(s, a) for s in range(1, 8000) for a in range(0, 200)}
        sim = Simulator()
        clients = []
        for i in range(2):
            addr = f"10.1.2.{10 + i}"
            sim.connect(addr, SERVER, Link(1e8, 1 * MS, DropList(dead)),
                        Link(1e8, 1 * MS, NoLoss()))

            def fn(params, round_idx, client):
                return (params, {})
            clients.append(FLClient(addr, fn, train_time_ns=10 * MS,
                                    cadence_ns=10 * MS))
        cfg = FLConfig(mode="async", buffer_k=2, round_deadline_ns=NS,
                       unhealthy_after_failures=2,
                       transport=TransportConfig(kind="udp",
                                                 udp_deadline_ns=30 * NS))
        system = FederatedSystem(sim, SERVER, clients,
                                 {"w": np.zeros((2000,), np.float32)}, cfg)
        results = system.run_rounds(4)      # must return, not hang
        assert len(results) <= 1            # at most the drain flush
        assert system.pool.benched(system.scheduler._agg_idx)


class TestSyncUnaffected:
    def test_sync_explicit_mode_matches_default(self):
        _, a, _ = build(mode="sync")
        _, b, _ = build(mode="sync")
        b.cfg = dataclasses.replace(b.cfg)      # mode survives replace()
        ra = [dataclasses.asdict(r) for r in a.run_rounds(2)]
        rb = [dataclasses.asdict(r) for r in b.run_rounds(2)]
        assert ra == rb

    def test_sync_scheduler_ignores_cadence(self):
        _, sys_a, _ = build(mode="sync", cadences={"10.1.2.10": 10 * NS})
        _, sys_b, _ = build(mode="sync", cadences={"10.1.2.10": 0})
        ra = sys_a.run_round()
        rb = sys_b.run_round()
        assert dataclasses.asdict(ra) == dataclasses.asdict(rb)

    def test_bad_mode_rejected(self):
        with pytest.raises(ValueError, match="mode"):
            FLConfig(mode="chaotic")

    def test_async_requires_concurrent_txns(self):
        import repro.core.server as server_mod
        import repro.core.scheduling as sched_mod

        class FakeTransport:
            name = "fake"
            caps = dataclasses.replace(
                make_transport("mudp").caps, concurrent_txns=False)

        sim = Simulator()
        sim.connect("10.1.2.10", SERVER, Link(1e8, 1 * MS, NoLoss()),
                    Link(1e8, 1 * MS, NoLoss()))
        core = object.__new__(server_mod.ServerCore)
        core.cfg = FLConfig(mode="async")
        core.transport = FakeTransport()
        with pytest.raises(ValueError, match="concurrent_txns"):
            AsyncScheduler(core)
