"""Per-kernel validation: pallas_call (interpret=True on CPU) vs pure-jnp
oracle, swept over shapes and dtypes (hypothesis + parametrize)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # property tests need hypothesis
from hypothesis import given, settings, strategies as st

from repro.kernels.checksum.checksum import checksum_pallas
from repro.kernels.checksum.ops import checksum_bytes, checksum_bytes_ref
from repro.kernels.checksum.ref import chunksum32_jnp, chunksum32_np
from repro.kernels.fedavg.fedavg import fedavg_pallas
from repro.kernels.fedavg.ops import fedavg_trees, pairwise_average_flat
from repro.kernels.fedavg.ref import fedavg_flat as fedavg_ref
from repro.kernels.flash_attention.flash_attention import \
    flash_attention_pallas
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.mlstm.mlstm import mlstm_pallas
from repro.kernels.mlstm.ref import mlstm_ref
from repro.kernels.quantize.ops import dequantize_vector, quantize_vector
from repro.kernels.quantize.quantize import (QBLOCK, dequantize_pallas,
                                             quantize_pallas)
from repro.kernels.quantize.ref import (dequantize_blockwise,
                                        quantize_blockwise)


class TestFedavgKernel:
    @pytest.mark.parametrize("K,N", [(2, 100), (3, 16_384), (5, 70_000),
                                     (16, 1_000), (1, 16_384)])
    def test_matches_ref(self, K, N):
        rng = np.random.default_rng(K * 1000 + N)
        stack = jnp.asarray(rng.standard_normal((K, N)), jnp.float32)
        w = jnp.asarray(rng.uniform(0.1, 1.0, K), jnp.float32)
        out = fedavg_pallas(stack, w, interpret=True)
        np.testing.assert_allclose(np.asarray(out),
                                   np.asarray(fedavg_ref(stack, w)),
                                   rtol=1e-6, atol=1e-6)

    @settings(max_examples=20, deadline=None)
    @given(K=st.integers(1, 8), N=st.integers(1, 5000),
           seed=st.integers(0, 99))
    def test_property_sweep(self, K, N, seed):
        rng = np.random.default_rng(seed)
        stack = jnp.asarray(rng.standard_normal((K, N)), jnp.float32)
        w = jnp.asarray(rng.uniform(0.0, 1.0, K) + 1e-3, jnp.float32)
        out = fedavg_pallas(stack, w, interpret=True, block_n=1024)
        np.testing.assert_allclose(np.asarray(out),
                                   np.asarray(fedavg_ref(stack, w)),
                                   rtol=1e-5, atol=1e-5)

    def test_pairwise_matches_paper_eq1(self):
        rng = np.random.default_rng(0)
        s = rng.standard_normal(1000).astype(np.float32)
        c = rng.standard_normal(1000).astype(np.float32)
        out = pairwise_average_flat(s, c)
        np.testing.assert_allclose(np.asarray(out), (s + c) / 2.0,
                                   rtol=1e-6, atol=1e-6)

    def test_tree_api_matches_core_aggregation(self):
        from repro.core.aggregation import fedavg as core_fedavg
        rng = np.random.default_rng(1)
        trees = [{"a": rng.standard_normal((10, 3)).astype(np.float32),
                  "b": rng.standard_normal(7).astype(np.float32)}
                 for _ in range(3)]
        out = fedavg_trees(trees, [1.0, 2.0, 3.0])
        ref = core_fedavg(trees, [1.0, 2.0, 3.0])
        np.testing.assert_allclose(out["a"], ref["a"], rtol=1e-5, atol=1e-6)


class TestQuantizeKernel:
    @pytest.mark.parametrize("nb", [1, 7, 8, 33])
    def test_matches_ref(self, nb):
        rng = np.random.default_rng(nb)
        x = jnp.asarray(rng.standard_normal((nb, QBLOCK)) * 10, jnp.float32)
        q, s = quantize_pallas(x, interpret=True)
        qr, sr = quantize_blockwise(x)
        # int8 codes may differ by 1 on exact-tie rounding of float noise
        diff = np.abs(np.asarray(q, np.int32) - np.asarray(qr, np.int32))
        assert diff.max() <= 1 and (diff > 0).mean() < 1e-3
        np.testing.assert_allclose(np.asarray(s), np.asarray(sr), rtol=1e-6)

    @pytest.mark.parametrize("nb", [1, 9])
    def test_dequant_roundtrip(self, nb):
        rng = np.random.default_rng(nb + 50)
        x = jnp.asarray(rng.standard_normal((nb, QBLOCK)), jnp.float32)
        q, s = quantize_pallas(x, interpret=True)
        out = dequantize_pallas(q, s, interpret=True)
        ref = dequantize_blockwise(*quantize_blockwise(x))
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=1e-6, atol=1e-7)

    @settings(max_examples=15, deadline=None)
    @given(n=st.integers(1, 9000), scale=st.floats(1e-3, 1e3),
           seed=st.integers(0, 99))
    def test_vector_api_error_bound(self, n, scale, seed):
        rng = np.random.default_rng(seed)
        vec = (rng.standard_normal(n) * scale).astype(np.float32)
        q, s, n_out = quantize_vector(vec)
        back = np.asarray(dequantize_vector(q, s, n_out))
        # matches the wire codec's numerics
        from repro.core.compression import dequantize_int8, quantize_int8
        qr, sr = quantize_int8(vec, QBLOCK)
        ref = dequantize_int8(qr, sr, n, QBLOCK)
        np.testing.assert_allclose(back, ref, rtol=1e-6, atol=1e-6)

    def test_matches_transport_codec_exactly(self):
        rng = np.random.default_rng(7)
        vec = rng.standard_normal(5000).astype(np.float32)
        from repro.core.compression import quantize_int8
        q_kernel, s_kernel, _ = quantize_vector(vec)
        q_codec, s_codec = quantize_int8(vec, QBLOCK)
        np.testing.assert_array_equal(np.asarray(q_kernel).reshape(-1),
                                      q_codec)
        np.testing.assert_allclose(np.asarray(s_kernel), s_codec, rtol=1e-7)


class TestChecksumKernel:
    @pytest.mark.parametrize("n", [1, 100, 8192, 8193, 100_000])
    def test_matches_ref(self, n):
        rng = np.random.default_rng(n)
        data = rng.integers(0, 256, n, dtype=np.uint8)
        out = int(np.uint32(np.asarray(
            checksum_pallas(jnp.asarray(data.astype(np.int32)),
                            interpret=True))))
        assert out == chunksum32_np(data)

    @settings(max_examples=25, deadline=None)
    @given(data=st.binary(min_size=1, max_size=4096))
    def test_bytes_api_property(self, data):
        assert checksum_bytes(data) == checksum_bytes_ref(data)

    def test_detects_single_byte_corruption(self):
        rng = np.random.default_rng(3)
        data = bytearray(rng.integers(0, 256, 2048, dtype=np.uint8))
        ref = checksum_bytes(bytes(data))
        data[777] = (data[777] + 1) % 256
        assert checksum_bytes(bytes(data)) != ref

    def test_detects_swap(self):
        """Positional weighting catches transpositions plain sums miss."""
        data = bytearray(b"\x01\x02" + b"\x00" * 100)
        ref = checksum_bytes(bytes(data))
        data[0], data[1] = data[1], data[0]
        assert checksum_bytes(bytes(data)) != ref

    def test_jnp_ref_matches_np_ref(self):
        rng = np.random.default_rng(9)
        data = rng.integers(0, 256, 5000, dtype=np.uint8)
        a = int(np.uint32(np.asarray(
            chunksum32_jnp(jnp.asarray(data.astype(np.int32))))))
        assert a == chunksum32_np(data)


class TestFlashAttentionKernel:
    @pytest.mark.parametrize("B,H,S,hd,causal,window", [
        (1, 2, 256, 64, True, 0),
        (2, 1, 128, 128, True, 0),
        (1, 2, 256, 64, True, 64),     # sliding window
        (1, 1, 256, 64, False, 0),     # bidirectional (whisper encoder)
        (2, 3, 384, 32, True, 128),
    ])
    def test_matches_ref(self, B, H, S, hd, causal, window):
        rng = np.random.default_rng(S + hd)
        q = jnp.asarray(rng.standard_normal((B, H, S, hd)), jnp.float32)
        k = jnp.asarray(rng.standard_normal((B, H, S, hd)), jnp.float32)
        v = jnp.asarray(rng.standard_normal((B, H, S, hd)), jnp.float32)
        out = flash_attention_pallas(q, k, v, causal=causal, window=window,
                                     interpret=True)
        ref = attention_ref(q, k, v, causal=causal, window=window)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=2e-5, atol=2e-5)

    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_dtypes(self, dtype):
        rng = np.random.default_rng(0)
        mk = lambda: jnp.asarray(rng.standard_normal((1, 2, 128, 64)), dtype)
        q, k, v = mk(), mk(), mk()
        out = flash_attention_pallas(q, k, v, interpret=True)
        ref = attention_ref(q, k, v)
        tol = 3e-2 if dtype == jnp.bfloat16 else 3e-5
        np.testing.assert_allclose(
            np.asarray(out, np.float32), np.asarray(ref, np.float32),
            rtol=tol, atol=tol)

    def test_gqa_wrapper_matches_model_attention(self):
        from repro.models import layers as L
        rng = np.random.default_rng(1)
        B, S, H, KV, hd = 2, 128, 8, 2, 64
        q = jnp.asarray(rng.standard_normal((B, S, H, hd)), jnp.float32)
        k = jnp.asarray(rng.standard_normal((B, S, KV, hd)), jnp.float32)
        v = jnp.asarray(rng.standard_normal((B, S, KV, hd)), jnp.float32)
        pos = jnp.arange(S, dtype=jnp.int32)
        out = flash_attention(q, k, v, causal=True, interpret=True)
        ref = L.gqa_attention(q, k, v, q_pos=pos, kv_pos=pos, causal=True)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=2e-5, atol=2e-5)

    @settings(max_examples=8, deadline=None)
    @given(nq=st.integers(1, 4), hd=st.sampled_from([32, 64]),
           seed=st.integers(0, 20))
    def test_block_tiling_sweep(self, nq, hd, seed):
        S = 128 * nq
        rng = np.random.default_rng(seed)
        q = jnp.asarray(rng.standard_normal((1, 1, S, hd)), jnp.float32)
        k = jnp.asarray(rng.standard_normal((1, 1, S, hd)), jnp.float32)
        v = jnp.asarray(rng.standard_normal((1, 1, S, hd)), jnp.float32)
        out = flash_attention_pallas(q, k, v, bq=128, bk=128, interpret=True)
        ref = attention_ref(q, k, v)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=2e-5, atol=2e-5)


class TestMlstmKernel:
    @pytest.mark.parametrize("B,S,nh,dh", [
        (1, 128, 2, 64), (2, 256, 1, 32), (1, 384, 4, 64),
    ])
    def test_matches_parallel_ref(self, B, S, nh, dh):
        rng = np.random.default_rng(S)
        q = jnp.asarray(rng.standard_normal((B, S, nh, dh)), jnp.float32)
        k = jnp.asarray(rng.standard_normal((B, S, nh, dh)), jnp.float32)
        v = jnp.asarray(rng.standard_normal((B, S, nh, dh)), jnp.float32)
        ig = jnp.asarray(rng.standard_normal((B, S, nh)), jnp.float32)
        fg = jnp.asarray(rng.standard_normal((B, S, nh)) + 2.0, jnp.float32)
        out = mlstm_pallas(q, k, v, ig, fg, interpret=True)
        ref = mlstm_ref(q, k, v, ig, fg)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=3e-4, atol=3e-4)

    def test_matches_recurrent_stepping(self):
        """Kernel == step-by-step recurrence (the decode path)."""
        from repro.models.xlstm import mlstm_step
        rng = np.random.default_rng(5)
        B, S, nh, dh = 1, 128, 2, 32
        q = jnp.asarray(rng.standard_normal((B, S, nh, dh)), jnp.float32)
        k = jnp.asarray(rng.standard_normal((B, S, nh, dh)), jnp.float32)
        v = jnp.asarray(rng.standard_normal((B, S, nh, dh)), jnp.float32)
        ig = jnp.asarray(rng.standard_normal((B, S, nh)), jnp.float32)
        fg = jnp.asarray(rng.standard_normal((B, S, nh)) + 1.0, jnp.float32)
        C = jnp.zeros((B, nh, dh, dh))
        n = jnp.zeros((B, nh, dh))
        m = jnp.full((B, nh), -jnp.inf)
        hs = []
        state = (C, n, m)
        for t in range(S):
            state, h = mlstm_step(state, q[:, t], k[:, t], v[:, t],
                                  ig[:, t], fg[:, t])
            hs.append(h)
        ref = jnp.stack(hs, axis=1)
        out = mlstm_pallas(q, k, v, ig, fg, interpret=True)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=2e-3, atol=2e-3)

    @settings(max_examples=6, deadline=None)
    @given(seed=st.integers(0, 50), nh=st.integers(1, 3))
    def test_property_sweep(self, seed, nh):
        rng = np.random.default_rng(seed)
        B, S, dh = 1, 256, 32
        q = jnp.asarray(rng.standard_normal((B, S, nh, dh)), jnp.float32)
        k = jnp.asarray(rng.standard_normal((B, S, nh, dh)), jnp.float32)
        v = jnp.asarray(rng.standard_normal((B, S, nh, dh)), jnp.float32)
        ig = jnp.asarray(rng.standard_normal((B, S, nh)), jnp.float32)
        fg = jnp.asarray(rng.standard_normal((B, S, nh)), jnp.float32)
        out = mlstm_pallas(q, k, v, ig, fg, interpret=True)
        ref = mlstm_ref(q, k, v, ig, fg)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=5e-4, atol=5e-4)
