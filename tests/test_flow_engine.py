"""The flow engine: analytic per-burst fast path.

Two layers of guarantees:

* **Smokes (tier-1, unmarked)** — the flow engine drives every registered
  transport end to end, is deterministic per seed, is *additive* (the
  packet engines never see a flow adapter; their pinned digests are
  untouched), and runs every topology and both scheduler modes.
* **Distributional gates (``-m stats``)** — multi-seed sweeps asserting
  the flow engine's metric distributions match the batched engine within
  the documented tolerances of ``tests/statcheck.py``, at the single-link
  level (per transport x loss regime) and the fleet level (per transport
  x topology).  The tolerance numbers here are the contract; the
  methodology behind them is docs/PERFORMANCE.md.
"""

import os
import sys

import pytest

from repro.core import available_transports, make_transport
from repro.core.flow import FlowTransport, available_flow_models, maybe_flow
from repro.core.simulator import ENGINES, Simulator

sys.path.insert(0, os.path.dirname(__file__))
from statcheck import (Tolerance, compare_sweeps,          # noqa: E402
                       fleet_metrics, sweep, transfer_metrics)

SEEDS_LINK = range(100, 125)      # 25 seeds per single-link scenario
SEEDS_FLEET = range(300, 320)     # 20 seeds per fleet scenario
TRANSPORTS = ("mudp", "udp", "tcp", "mudp+fec")


# --------------------------------------------------------------------------
# Tier-1 smokes
# --------------------------------------------------------------------------
def test_flow_is_a_registered_engine():
    assert "flow" in ENGINES
    assert Simulator(engine="flow").engine == "flow"
    with pytest.raises(ValueError, match="unknown engine"):
        Simulator(engine="warp")


def test_every_transport_has_a_flow_model():
    assert set(available_flow_models()) >= set(available_transports())


@pytest.mark.parametrize("kind", TRANSPORTS)
def test_flow_transfer_completes(kind):
    m = transfer_metrics("flow", kind, seed=42, loss_p=0.1, payload=24_000)
    assert m["completed"] == 1.0
    assert m["delivered"] == 1.0
    assert m["bytes_sent"] > 24_000
    # Plain udp is fire-and-forget (duration_ns stays 0 on both packet
    # engines too); simulated time always advances.
    assert m["sim_end_ns"] > 0


@pytest.mark.parametrize("kind", TRANSPORTS)
def test_flow_transfer_deterministic_per_seed(kind):
    a = transfer_metrics("flow", kind, seed=7, loss_p=0.1)
    b = transfer_metrics("flow", kind, seed=7, loss_p=0.1)
    c = transfer_metrics("flow", kind, seed=8, loss_p=0.1)
    assert a == b
    assert a != c


def test_flow_is_additive_to_packet_engines():
    """maybe_flow must hand the base transport back untouched on the
    packet engines — flow is a third tier, not a change to the first
    two."""
    base = make_transport("mudp")
    for engine in ("per_packet", "batched"):
        assert maybe_flow(Simulator(engine=engine), base) is base
    wrapped = maybe_flow(Simulator(engine="flow"), base)
    assert isinstance(wrapped, FlowTransport)
    assert wrapped is not base


@pytest.mark.parametrize("topology", ["star", "hier", "gossip"])
def test_flow_fleet_round_completes(topology):
    m = fleet_metrics("flow", "mudp", seed=5, n_clients=12, rounds=2,
                      topology=topology, n_params=128)
    assert m["round_time_ns"] > 0
    assert m["bytes_on_wire"] > 0
    assert m["final_loss"] >= 0.0


def test_flow_fleet_async_mode():
    m = fleet_metrics("flow", "mudp", seed=5, n_clients=12, rounds=2,
                      mode="async", n_params=128)
    assert m["round_time_ns"] > 0 and m["bytes_on_wire"] > 0


def test_flow_fleet_deterministic_per_seed():
    a = fleet_metrics("flow", "mudp", seed=9, n_clients=12, rounds=2,
                      n_params=128)
    b = fleet_metrics("flow", "mudp", seed=9, n_clients=12, rounds=2,
                      n_params=128)
    assert a == b


# --------------------------------------------------------------------------
# Distributional gates (stats lane)
# --------------------------------------------------------------------------
# Single-link tolerances.  Variance bands are skipped (None) at low loss:
# the reference duration variance there is dominated by rare timer waits
# (see statcheck module docstring).  At 10% loss recovery dominates and a
# loose one-sided band holds.
def _link_tols(loss_p: float) -> dict:
    rare = loss_p < 0.05
    return {
        "duration_ns": Tolerance(mean_rtol=0.15,
                                 var_hi=None if rare else 4.0,
                                 var_lo=None if rare else 16.0),
        "bytes_sent": Tolerance(mean_rtol=0.05,
                                var_hi=None if rare else 8.0,
                                var_lo=None),
        "retransmissions": Tolerance(mean_rtol=0.25, mean_atol=1.0,
                                     var_hi=None if rare else 8.0,
                                     var_lo=None),
        "completed": Tolerance(mean_rtol=0.0, mean_atol=0.05),
        "delivered": Tolerance(mean_rtol=0.0, mean_atol=0.05),
    }


@pytest.mark.stats
@pytest.mark.parametrize("kind", TRANSPORTS)
@pytest.mark.parametrize("loss_p,bursty", [(0.02, False), (0.1, False),
                                           (0.1, True)])
def test_link_distributional_equivalence(kind, loss_p, bursty):
    ref = sweep(lambda s: transfer_metrics("batched", kind, s,
                                           loss_p=loss_p, bursty=bursty),
                SEEDS_LINK)
    flow = sweep(lambda s: transfer_metrics("flow", kind, s,
                                            loss_p=loss_p, bursty=bursty),
                 SEEDS_LINK)
    fails = compare_sweeps(ref, flow, _link_tols(loss_p))
    assert not fails, "\n".join(fails)


# Fleet tolerances: jitter collapse and deadline quantization make
# round-time variance one-sided; bytes/retx variance at fleet scale is
# rare-event dominated (incomplete broadcasts), so those gate on means.
FLEET_TOLS = {
    "round_time_ns": Tolerance(mean_rtol=0.20, var_lo=None),
    "bytes_on_wire": Tolerance(mean_rtol=0.05, var_hi=None, var_lo=None),
    "retransmissions": Tolerance(mean_rtol=0.30, mean_atol=2.0,
                                 var_hi=None, var_lo=None),
    "rounds_to_target": Tolerance(mean_rtol=0.25, mean_atol=1.0,
                                  var_hi=None, var_lo=None),
    "final_loss": Tolerance(mean_rtol=0.25, mean_atol=0.05,
                            var_hi=None, var_lo=None),
}


@pytest.mark.stats
@pytest.mark.parametrize("transport", TRANSPORTS)
@pytest.mark.parametrize("topology", ["star", "hier"])
def test_fleet_distributional_equivalence(transport, topology):
    ref = sweep(lambda s: fleet_metrics("batched", transport, s,
                                        topology=topology), SEEDS_FLEET)
    flow = sweep(lambda s: fleet_metrics("flow", transport, s,
                                         topology=topology), SEEDS_FLEET)
    fails = compare_sweeps(ref, flow, FLEET_TOLS)
    assert not fails, "\n".join(fails)


# --------------------------------------------------------------------------
# Telemetry under the flow engine (repro.core.telemetry)
# --------------------------------------------------------------------------
def _telemetry_metrics(engine: str, seed: int) -> dict:
    """Fleet-averaged ClientHealth EWMAs after a short lte-cohort run: the
    flow engine feeds the same telemetry plane through the same TxnStats
    shape, so the per-client estimators must agree distributionally."""
    from repro.core import (ConsensusObjective, FLConfig, FleetConfig,
                            TransportConfig, build_fleet)
    NS = 1_000_000_000
    n_clients = 16
    fleet = FleetConfig(n_clients=n_clients, seed=seed, engine=engine,
                        cohort_mix=(("lte", 1.0),),
                        round_deadline_ns=60 * NS)
    objective = ConsensusObjective(n_clients, 512, seed=seed)
    cfg = FLConfig(transport=TransportConfig(kind="mudp", timeout_ns=2 * NS,
                                             udp_deadline_ns=3 * NS))
    _, system, _ = build_fleet(fleet, objective.init_params(),
                               objective.train_fn, cfg)
    system.run_rounds(3)
    health = system.core.telemetry.snapshot_all().values()
    n = max(1, len(health))
    return {
        "txns": sum(h.txns for h in health) / n,
        "rtt_ns": sum(h.rtt_ns for h in health) / n,
        "loss_rate": sum(h.loss_rate for h in health) / n,
        "goodput_bps": sum(h.goodput_bps for h in health) / n,
    }


# rtt/goodput variance is straggler-dominated (one slow draw owns the
# fleet mean), so those gate on means; the loss-rate EWMA at lte loss
# levels is a rare-event average and needs an absolute floor.
TELEMETRY_TOLS = {
    "txns": Tolerance(mean_rtol=0.0),
    "rtt_ns": Tolerance(mean_rtol=0.20, var_hi=None, var_lo=None),
    "loss_rate": Tolerance(mean_rtol=0.5, mean_atol=0.01,
                           var_hi=None, var_lo=None),
    "goodput_bps": Tolerance(mean_rtol=0.25, var_hi=None, var_lo=None),
}


@pytest.mark.stats
def test_flow_telemetry_distributional_equivalence():
    ref = sweep(lambda s: _telemetry_metrics("batched", s), SEEDS_FLEET)
    flow = sweep(lambda s: _telemetry_metrics("flow", s), SEEDS_FLEET)
    fails = compare_sweeps(ref, flow, TELEMETRY_TOLS)
    assert not fails, "\n".join(fails)
