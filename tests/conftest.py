"""Shared fleet fixtures.

Three builders cover the fleet setups the suite used to copy-paste:

* ``simple_star`` — a hand-wired :class:`FederatedSystem` over NoLoss
  links with constant-update clients (participation / aggregation tests
  that need exact arithmetic, no transport noise);
* ``consensus_fleet`` — the seeded :func:`build_fleet` path over a
  :class:`ConsensusObjective` (topology / transport semantics);
* ``training_fleet`` — the :func:`build_fleet_training` path with a
  model + train backend (client-compute parity tests; callers gate on
  jax themselves via ``pytest.importorskip``).

All three are factory fixtures: they return a builder so one test can
construct several fleets with different knobs.
"""

import hashlib

import numpy as np
import pytest

from repro.core import (ConsensusObjective, FLClient, FLConfig, FleetConfig,
                        Link, TransportConfig, build_fleet,
                        build_fleet_training)
from repro.core.channel import NoLoss
from repro.core.rounds import FederatedSystem
from repro.core.simulator import Simulator

NS = 1_000_000_000
SIMPLE_SERVER = "10.1.2.5"


@pytest.fixture
def simple_star():
    """Factory: ``build(n_clients, cfg, ...)`` -> (sim, system, clients).

    Every client trains to a constant ``train_value`` over a lossless
    100 Mb/s link, so aggregation results can be hand-computed exactly.
    """
    def build(n_clients, cfg, train_value=1.0, train_times=None,
              weights=None, server=SIMPLE_SERVER, n_params=50):
        sim = Simulator()
        clients = []
        for i in range(n_clients):
            addr = f"10.1.2.{10 + i}"
            sim.connect(addr, server, Link(1e8, 1_000_000, NoLoss()),
                        Link(1e8, 1_000_000, NoLoss()))

            def fn(params, round_idx, client, v=train_value):
                return ({k: np.full_like(p, v) for k, p in params.items()},
                        {})
            tt = (train_times or {}).get(addr, 1_000_000)
            c = FLClient(addr, fn, train_time_ns=tt)
            if weights and addr in weights:
                c.weight = weights[addr]
            clients.append(c)
        params = {"w": np.zeros((n_params,), np.float32)}
        return sim, FederatedSystem(sim, server, clients, params,
                                    cfg), clients
    return build


@pytest.fixture
def consensus_fleet():
    """Factory: ``build(topology, ...)`` -> (obj, sim, system, results).

    The seeded cohort path: :func:`build_fleet` over a
    :class:`ConsensusObjective`, then ``rounds`` rounds (``rounds=0``
    skips running so the caller can drive the system itself).
    """
    def build(topology="star", *, n=16, rounds=3, seed=7, obj_params=48,
              obj_seed=3, transport="mudp", fl_cfg=None, **fleet_kw):
        obj = ConsensusObjective(n, obj_params, seed=obj_seed)
        fleet = FleetConfig(n_clients=n, seed=seed, topology=topology,
                            **fleet_kw)
        cfg = fl_cfg or FLConfig(transport=TransportConfig(kind=transport))
        sim, system, _ = build_fleet(
            fleet, obj.init_params(), lambda i, p: obj.train_fn(i, p), cfg)
        results = system.run_rounds(rounds) if rounds else []
        return obj, sim, system, results
    return build


@pytest.fixture
def training_fleet():
    """Factory: ``run(backend, ...)`` -> (FleetBuild, results), the
    :func:`build_fleet_training` path with a model and train backend."""
    def run(backend, *, seed=0, transport="mudp", mode="sync",
            topology="star", model="consensus", rounds=2, n_clients=10,
            model_args=None, **fleet_kw):
        if model_args is None:
            model_args = ({"n_params": 96} if model == "consensus"
                          else {"n_train": 512, "n_test": 128,
                                "shard_size": 32, "hidden": 16})
        fleet = FleetConfig(n_clients=n_clients, seed=seed,
                            topology=topology, mode=mode, model=model,
                            train_backend=backend, model_args=model_args,
                            **fleet_kw)
        fl = FLConfig(aggregation="fedavg", mode=mode,
                      transport=TransportConfig(kind=transport,
                                                timeout_ns=2 * NS,
                                                udp_deadline_ns=3 * NS))
        build = build_fleet_training(fleet, fl)
        results = build.system.run_rounds(rounds)
        return build, results
    return run


@pytest.fixture
def params_digest():
    """Stable content hash of a ``{"w": ...}`` parameter dict."""
    def digest(params) -> str:
        return hashlib.sha256(
            np.asarray(params["w"], np.float32).tobytes()).hexdigest()
    return digest
