"""Bit-faithful reproduction of the paper's §V test cases.

Topology mirrors the paper: client 10.1.2.4, second client 10.1.2.6, server
10.1.2.5; links at 5 Mbps with a 2000 ms delay (the paper's NS3 config).
Four data packets per transaction, exactly as in the paper's Figs 5-7.
"""

import numpy as np
import pytest

from repro.core.channel import DropList, Link, NoLoss
from repro.core.mudp import MudpReceiver, MudpSender
from repro.core.packetizer import packetize, reassemble
from repro.core.simulator import Simulator

CLIENT = "10.1.2.4"
SERVER = "10.1.2.5"
CLIENT2 = "10.1.2.6"

PAPER_RATE = 5_000_000.0
PAPER_DELAY = 2_000_000_000  # 2000 ms


def make_sim(drop_pairs=(), *, trace=True):
    sim = Simulator(trace=trace)
    up = Link(PAPER_RATE, PAPER_DELAY, DropList(drop_pairs))
    down = Link(PAPER_RATE, PAPER_DELAY, NoLoss())
    sim.connect(CLIENT, SERVER, up, down)
    return sim


def four_packets(addr=CLIENT, payload_bytes=1200):
    data = bytes(range(256)) * (payload_bytes * 4 // 256)
    pkts = packetize(data, addr, txn=0, mtu=payload_bytes + 28)
    assert len(pkts) == 4, "paper scenario uses exactly 4 packets"
    return data, pkts


def run_scenario(drop_pairs, timeout_ns=6_000_000_000):
    sim = make_sim(drop_pairs)
    data, pkts = four_packets()
    delivered = {}

    rx = MudpReceiver(sim, sim.node(SERVER), nack_timeout_ns=timeout_ns,
                      on_deliver=lambda a, t, p: delivered.update(p))
    outcome = {}
    tx = MudpSender(sim, sim.node(CLIENT), sim.node(SERVER), pkts,
                    timeout_ns=timeout_ns,
                    on_complete=lambda s: outcome.update(ok=True),
                    on_fail=lambda s: outcome.update(ok=False))
    tx.start()
    sim.run()
    return sim, tx, rx, delivered, outcome, data


class TestCase1:
    """Paper test case 1: packet (2, 4, 10.1.2.4) deliberately skipped."""

    def test_recovers_missing_interior_packet(self):
        sim, tx, rx, delivered, outcome, data = run_scenario({(2, 0)})
        assert outcome["ok"] is True
        assert sorted(delivered) == [1, 2, 3, 4]
        assert reassemble(delivered) == data

    def test_exactly_one_nack_and_one_retransmission(self):
        sim, tx, rx, delivered, outcome, _ = run_scenario({(2, 0)})
        assert rx.stats_nacks_sent == 1
        assert tx.stats.retransmissions == 1
        # The timer path (resend-last) is never taken: the last packet arrived.
        assert tx.stats.last_packet_retries == 0

    def test_server_header_in_trace(self):
        sim, *_ = run_scenario({(2, 0)})
        text = "\n".join(sim.trace_lines)
        assert "(2, 4, 10.1.2.4)" in text          # the missing packet
        assert f"(0, 0, {SERVER})" in text          # the success ACK


class TestCase2:
    """Paper test case 2: packets (2,4), (3,4) and (4,4) all skipped — the
    lost tail means the server cannot start recovery; the client's timer
    expires and it resends the LAST packet to trigger gap reporting."""

    def test_recovers_after_timer_driven_last_packet_resend(self):
        sim, tx, rx, delivered, outcome, data = run_scenario(
            {(2, 0), (3, 0), (4, 0)})
        assert outcome["ok"] is True
        assert sorted(delivered) == [1, 2, 3, 4]
        assert reassemble(delivered) == data

    def test_timer_path_taken(self):
        sim, tx, rx, delivered, outcome, _ = run_scenario(
            {(2, 0), (3, 0), (4, 0)})
        assert tx.stats.last_packet_retries == 1       # one timer expiry
        assert rx.stats_nacks_sent == 2                 # NACKs for 2 and 3
        # retransmissions: last packet (timer) + packets 2 and 3 (NACKed)
        assert tx.stats.retransmissions == 3

    def test_within_three_retries(self):
        sim, tx, *_ = run_scenario({(2, 0), (3, 0), (4, 0)})
        assert tx.stats.last_packet_retries <= 3        # the paper's Y


class TestCase3:
    """Paper test case 3: client two, lossless — server ACKs immediately and
    the timer stops 'to avoid transaction delays for other clients'."""

    def test_clean_transaction(self):
        sim, tx, rx, delivered, outcome, data = run_scenario(set())
        assert outcome["ok"] is True
        assert tx.stats.retransmissions == 0
        assert tx.stats.last_packet_retries == 0
        assert rx.stats_nacks_sent == 0
        assert reassemble(delivered) == data

    def test_two_concurrent_clients_do_not_interfere(self):
        """Client 1 loses a packet; client 2 is clean (paper Figs 5+7)."""
        sim = Simulator(trace=True)
        sim.connect(CLIENT, SERVER, Link(PAPER_RATE, PAPER_DELAY,
                                         DropList({(2, 0)})),
                    Link(PAPER_RATE, PAPER_DELAY))
        sim.connect(CLIENT2, SERVER, Link(PAPER_RATE, PAPER_DELAY),
                    Link(PAPER_RATE, PAPER_DELAY))
        data1, pkts1 = four_packets(CLIENT)
        data2, pkts2 = four_packets(CLIENT2)
        got = {}
        MudpReceiver(sim, sim.node(SERVER),
                     on_deliver=lambda a, t, p: got.__setitem__(a, p))
        done = {}
        MudpSender(sim, sim.node(CLIENT), sim.node(SERVER), pkts1,
                   on_complete=lambda s: done.__setitem__(CLIENT, True)
                   ).start()
        MudpSender(sim, sim.node(CLIENT2), sim.node(SERVER), pkts2,
                   on_complete=lambda s: done.__setitem__(CLIENT2, True)
                   ).start()
        sim.run()
        assert done == {CLIENT: True, CLIENT2: True}
        assert reassemble(got[CLIENT]) == data1
        assert reassemble(got[CLIENT2]) == data2


class TestFailurePath:
    """Beyond the figures: Y=3 retries then the transaction fails (paper
    §IV.A: 'with Y amount of maximum retries')."""

    def test_gives_up_after_three_retries_when_link_is_dead(self):
        # Drop every attempt of every data packet.
        dead = {(s, a) for s in range(1, 5) for a in range(0, 16)}
        sim, tx, rx, delivered, outcome, _ = run_scenario(dead)
        assert outcome["ok"] is False
        assert tx.stats.last_packet_retries == 3
        assert delivered == {}

    def test_ack_loss_is_survivable(self):
        """If the (0,0,A) ACK itself is lost, the sender's timer fires, the
        last packet is resent, and the receiver re-ACKs a completed txn."""
        sim = Simulator(trace=True)

        class DropFirstAck:
            dropped = False
            def drops(self, pkt):
                from repro.core.packets import PacketKind
                if pkt.kind == PacketKind.ACK_OK and not self.dropped:
                    self.dropped = True
                    return True
                return False

        sim.connect(CLIENT, SERVER, Link(PAPER_RATE, PAPER_DELAY, NoLoss()),
                    Link(PAPER_RATE, PAPER_DELAY, DropFirstAck()))
        data, pkts = four_packets()
        delivered = {}
        outcome = {}
        MudpReceiver(sim, sim.node(SERVER),
                     on_deliver=lambda a, t, p: delivered.update(p))
        MudpSender(sim, sim.node(CLIENT), sim.node(SERVER), pkts,
                   on_complete=lambda s: outcome.update(ok=True),
                   on_fail=lambda s: outcome.update(ok=False)).start()
        sim.run()
        assert outcome["ok"] is True
        assert reassemble(delivered) == data


class TestTiming:
    """Sanity on the simulated clock: the paper's Fig. 6 shows a multi-second
    transaction (+17.5 s) driven by the 2000 ms link delay — our recovery
    path should land in the same order of magnitude."""

    def test_lossless_duration_is_dominated_by_link_delay(self):
        sim, tx, *_ = run_scenario(set())
        # one-way data + one-way ACK = at least 2 * 2000 ms
        assert tx.stats.duration_ns >= 2 * PAPER_DELAY
        assert tx.stats.duration_ns < 10 * PAPER_DELAY

    def test_case2_duration_matches_paper_scale(self):
        sim, tx, *_ = run_scenario({(2, 0), (3, 0), (4, 0)})
        # timer (6 s) + resend/NACK round trips (4+ s) => 10-25 s window,
        # consistent with the ~17.5 s the paper logs for this scenario.
        assert 10_000_000_000 <= tx.stats.duration_ns <= 25_000_000_000
