"""Backend parity for vectorized client compute.

The contract under test (``repro.core.client_compute``):

* the ``python`` train backend is the historical per-client path — with no
  trainer attached the orchestrator byte-replays every pinned digest;
* the ``vmap``/``shard`` backends produce the *same rounds* — identical
  rosters, arrivals and event ordering, parameters equal to within an
  explicit ULP bound — across seeds x transports x sync/async x topology;
* the MNIST data layer is deterministic offline (the CI bugfix), and the
  dirichlet sharder is seeded and actually non-IID.
"""

import dataclasses
import os
import sys

import numpy as np
import pytest

jax = pytest.importorskip("jax")

from repro.core import FleetConfig                         # noqa: E402
from repro.core.client_compute import (BatchTrainer,       # noqa: E402
                                       ConsensusModel, available_models,
                                       available_train_backends, make_model,
                                       make_train_backend, register_model,
                                       register_train_backend)
from repro.core.fleet import ConsensusObjective            # noqa: E402
from repro.core.packetizer import flatten_to_vector        # noqa: E402
from repro.data.mnist import (SyntheticMnist,              # noqa: E402
                              dirichlet_shards, load_mnist)

sys.path.insert(0, os.path.dirname(__file__))
from test_orchestrator_equivalence import EXPECTED, run_digest  # noqa: E402

# The explicit parity bound the issue asks for: python-vs-vmap must agree
# to <= 4 float32 ULPs elementwise (jax-vs-jax on the same arithmetic; in
# practice the difference is exactly zero on CPU, but reduction order is
# not contractually fixed under vmap batching).
ULP_BOUND = 4


def assert_ulp_close(a: np.ndarray, b: np.ndarray, bound: int = ULP_BOUND):
    a = np.asarray(a, np.float32)
    b = np.asarray(b, np.float32)
    tol = bound * np.spacing(np.maximum(np.abs(a), np.abs(b)))
    diff = np.abs(a - b)
    assert np.all(diff <= tol), (
        f"parity beyond {bound} ULP: max diff {diff.max()} "
        f"at tol {tol.flat[np.argmax(diff - tol)]}")


# --------------------------------------------------------------------------
# Registries
# --------------------------------------------------------------------------
class TestRegistries:
    def test_builtins_present(self):
        assert "consensus" in available_models()
        assert "mlp" in available_models()
        assert set(available_train_backends()) >= {"python", "vmap", "shard"}

    def test_unknown_names_raise(self):
        with pytest.raises(ValueError, match="unknown model"):
            make_model("resnet900", 4)
        with pytest.raises(ValueError, match="unknown train backend"):
            make_train_backend("cuda")

    def test_shadowing_refused(self):
        with pytest.raises(ValueError, match="already registered"):
            register_model("consensus", ConsensusModel)
        with pytest.raises(ValueError, match="already registered"):
            register_train_backend(
                "python", lambda: make_train_backend("python"))

    def test_fleet_config_validates(self):
        with pytest.raises(ValueError, match="unknown model"):
            FleetConfig(n_clients=4, model="resnet900")
        with pytest.raises(ValueError, match="unknown train backend"):
            FleetConfig(n_clients=4, train_backend="cuda")
        with pytest.raises(ValueError, match="model_args"):
            FleetConfig(n_clients=4, model_args={"hidden": 8})


# --------------------------------------------------------------------------
# ConsensusModel == ConsensusObjective, bit for bit
# --------------------------------------------------------------------------
class TestConsensusModel:
    def test_bit_identical_to_objective(self):
        model = make_model("consensus", 6, seed=3, n_params=128)
        obj = ConsensusObjective(6, 128, seed=3)
        np.testing.assert_array_equal(model.init_params()["w"],
                                      obj.init_params()["w"])
        params = {"w": np.linspace(-1, 1, 128, dtype=np.float32)}
        for i in (0, 5):
            got, gm = model.train_fn(i)(params, 0, None)
            want, wm = obj.train_fn(i)(params, 0, None)
            np.testing.assert_array_equal(got["w"], want["w"])
            assert gm == wm
        assert model.loss(params) == obj.loss(params)


# --------------------------------------------------------------------------
# Compute-level backend parity
# --------------------------------------------------------------------------
@pytest.mark.parametrize("model_name", ["consensus", "mlp"])
def test_backend_parity_compute_level(model_name):
    kwargs = ({"n_params": 96} if model_name == "consensus"
              else {"n_train": 512, "n_test": 128, "shard_size": 32,
                    "hidden": 16})
    model = make_model(model_name, 8, seed=0, **kwargs)
    vec0 = flatten_to_vector(model.init_params())
    rng = np.random.default_rng(7)
    stack = (vec0[None] + 0.01 * rng.standard_normal(
        (8, vec0.size))).astype(np.float32)
    ci = np.arange(8, dtype=np.int32)
    ri = np.asarray([0, 0, 1, 1, 2, 2, 3, 3], np.int32)
    out_py, met_py = make_train_backend("python").train(model, stack, ci, ri)
    out_vm, met_vm = make_train_backend("vmap").train(model, stack, ci, ri)
    out_sh, met_sh = make_train_backend("shard").train(model, stack, ci, ri)
    assert_ulp_close(out_py, out_vm)
    # shard falls back to vmap on one device: exactly equal there, and
    # still ULP-bounded vs python on any mesh.
    assert_ulp_close(out_py, out_sh)
    assert len(met_py) == len(met_vm) == 8
    for a, b in zip(met_py, met_vm):
        assert set(a) == set(b)
        for key in a:
            assert_ulp_close(np.float32(a[key]), np.float32(b[key]),
                             bound=64)  # scalar summaries, looser


def test_vmap_padding_is_invisible(n=5):
    # 5 rows pad to 8 under the pow2 rule; padded outputs must not leak.
    model = make_model("consensus", n, seed=1, n_params=64)
    stack = np.tile(flatten_to_vector(model.init_params()), (n, 1))
    ci = np.arange(n, dtype=np.int32)
    ri = np.zeros(n, np.int32)
    out, met = make_train_backend("vmap").train(model, stack, ci, ri)
    assert out.shape == (n, 64) and len(met) == n
    out_py, _ = make_train_backend("python").train(model, stack, ci, ri)
    assert_ulp_close(out_py, out)


# --------------------------------------------------------------------------
# Fleet-level parity: identical rounds across the scenario matrix.
# Fleet construction comes from the shared ``training_fleet`` fixture in
# conftest.py.
# --------------------------------------------------------------------------
@pytest.mark.parametrize("seed", [0, 1])
@pytest.mark.parametrize("transport", ["mudp", "udp"])
@pytest.mark.parametrize("mode", ["sync", "async"])
def test_fleet_parity_matrix(training_fleet, seed, transport, mode):
    bp, rp = training_fleet("python", seed=seed, transport=transport,
                            mode=mode)
    bv, rv = training_fleet("vmap", seed=seed, transport=transport,
                            mode=mode)
    # The event layer must be untouched by batching: same rosters, same
    # arrivals, same simulated durations, round for round.
    assert [r.roster for r in rp] == [r.roster for r in rv]
    assert [r.arrived for r in rp] == [r.arrived for r in rv]
    assert [r.duration_ns for r in rp] == [r.duration_ns for r in rv]
    assert_ulp_close(flatten_to_vector(bp.system.global_params),
                     flatten_to_vector(bv.system.global_params))
    # vmap actually batched: fewer backend calls than client-trainings.
    assert bv.trainer is not None
    assert sum(bv.trainer.batch_sizes) >= len(bv.trainer.batch_sizes)


@pytest.mark.parametrize("topology,kw", [("hier", {"cells": 3}),
                                         ("gossip", {})])
def test_fleet_parity_topologies(training_fleet, topology, kw):
    bp, rp = training_fleet("python", topology=topology, **kw)
    bv, rv = training_fleet("vmap", topology=topology, **kw)
    assert [r.arrived for r in rp] == [r.arrived for r in rv]
    assert_ulp_close(flatten_to_vector(bp.system.global_params),
                     flatten_to_vector(bv.system.global_params))


def test_fleet_parity_mlp_over_mudp(training_fleet):
    bp, rp = training_fleet("python", model="mlp", rounds=2, n_clients=8)
    bv, rv = training_fleet("vmap", model="mlp", rounds=2, n_clients=8)
    assert [r.arrived for r in rp] == [r.arrived for r in rv]
    assert_ulp_close(flatten_to_vector(bp.system.global_params),
                     flatten_to_vector(bv.system.global_params))
    # And the model actually learns on its synthetic shards.
    m = bv.model
    assert m.accuracy(bv.system.global_params) > m.accuracy(m.init_params())


def test_python_backend_attaches_no_trainer(training_fleet):
    build, _ = training_fleet("python")
    assert build.trainer is None


# --------------------------------------------------------------------------
# The default path byte-replays every pinned digest
# --------------------------------------------------------------------------
def test_all_default_path_digests_unchanged():
    for (scenario, kind), want in sorted(EXPECTED.items()):
        assert run_digest(scenario, kind, "batched") == want, (
            f"default-path digest moved for {scenario}/{kind}")


# --------------------------------------------------------------------------
# BatchTrainer mechanics
# --------------------------------------------------------------------------
class TestBatchTrainer:
    def _trainer(self, n=4):
        model = make_model("consensus", n, seed=0, n_params=32)
        index = {f"10.1.0.{i + 1}": i for i in range(n)}
        return model, BatchTrainer(model, make_train_backend("vmap"), index)

    def test_lazy_flush_batches_pending(self):
        model, tr = self._trainer()
        p = model.init_params()
        for i in range(3):
            tr.submit(("s", i), f"10.1.0.{i + 1}", p, 0)
        received, trained, metrics = tr.collect(("s", 1))
        assert tr.batch_sizes == [3]          # one call for all pending
        np.testing.assert_array_equal(received["w"], p["w"])
        want, _ = model.train_fn(1)(p, 0, None)
        assert_ulp_close(trained["w"], want["w"])
        # The other two were computed in the same flush.
        tr.collect(("s", 0))
        tr.collect(("s", 2))
        assert tr.batch_sizes == [3]

    def test_duplicate_and_unknown_keys(self):
        model, tr = self._trainer()
        p = model.init_params()
        tr.submit("a", "10.1.0.1", p, 0)
        tr.flush()
        with pytest.raises(RuntimeError, match="duplicate"):
            tr.submit("a", "10.1.0.1", p, 0)
        with pytest.raises(KeyError, match="never submitted"):
            tr.collect("ghost")
        with pytest.raises(KeyError, match="client index"):
            tr.submit("b", "172.16.0.9", p, 0)

    def test_flush_empty_is_noop(self):
        _, tr = self._trainer()
        tr.flush()
        assert tr.batch_sizes == []


# --------------------------------------------------------------------------
# MNIST offline determinism (the CI bugfix) + dirichlet sharding
# --------------------------------------------------------------------------
class TestMnistOffline:
    def test_offline_fallback_is_deterministic(self):
        a = load_mnist(256, 64, seed=5, download=False)
        b = load_mnist(256, 64, seed=5, download=False)
        assert a.source == b.source == "synthetic"
        np.testing.assert_array_equal(a.x_train, b.x_train)
        np.testing.assert_array_equal(a.y_train, b.y_train)
        np.testing.assert_array_equal(a.x_test, b.x_test)
        np.testing.assert_array_equal(a.y_test, b.y_test)
        assert a.x_train.dtype == np.float32 and a.x_train.shape == (256, 784)
        assert a.n_train == 256

    def test_unreachable_download_falls_back(self, monkeypatch):
        import repro.data.mnist as mnist_mod
        monkeypatch.setattr(
            mnist_mod, "_MNIST_MIRRORS",
            ("http://127.0.0.1:9/nowhere/",))   # port 9: discard, refuses
        data = mnist_mod.load_mnist(128, 32, seed=1, timeout=0.2)
        assert data.source == "synthetic"
        ref = mnist_mod.load_mnist(128, 32, seed=1, download=False)
        np.testing.assert_array_equal(data.x_train, ref.x_train)

    def test_seed_changes_data(self):
        a = load_mnist(128, 32, seed=0, download=False)
        b = load_mnist(128, 32, seed=1, download=False)
        assert not np.array_equal(a.x_train, b.x_train)

    def test_splits_are_distinct(self):
        d = load_mnist(128, 128, seed=0, download=False)
        assert not np.array_equal(d.x_train, d.x_test)

    def test_synthetic_is_learnable_structure(self):
        syn = SyntheticMnist(seed=0)
        x, y = syn.sample(64, client=0, step=0)
        x2, y2 = syn.sample(64, client=0, step=0)
        np.testing.assert_array_equal(x, x2)
        np.testing.assert_array_equal(y, y2)


class TestDirichletShards:
    def test_deterministic_and_shaped(self):
        labels = np.repeat(np.arange(10), 50)
        a = dirichlet_shards(labels, 8, alpha=0.5, seed=3, shard_size=40)
        b = dirichlet_shards(labels, 8, alpha=0.5, seed=3, shard_size=40)
        np.testing.assert_array_equal(a, b)
        assert a.shape == (8, 40) and a.dtype == np.int32
        assert a.min() >= 0 and a.max() < len(labels)

    def test_low_alpha_concentrates_classes(self):
        labels = np.repeat(np.arange(10), 100)
        shards = dirichlet_shards(labels, 16, alpha=0.05, seed=0,
                                  shard_size=100)
        # Each client's label histogram should be dominated by few classes.
        top2 = []
        for row in shards:
            hist = np.bincount(labels[row], minlength=10)
            top2.append(np.sort(hist)[-2:].sum() / hist.sum())
        assert np.mean(top2) > 0.8

    def test_validation(self):
        labels = np.arange(10)
        with pytest.raises(ValueError, match="n_clients"):
            dirichlet_shards(labels, 0)
        with pytest.raises(ValueError, match="alpha"):
            dirichlet_shards(labels, 2, alpha=0.0)
