"""Distributional-equivalence harness for simulator engines.

The per-packet and batched engines are pinned bit-for-bit by
``test_engine_equivalence.py``.  The flow engine (``repro.core.flow``)
deliberately is not bit-exact — its contract is *statistical*: over a sweep
of seeds, its per-metric distributions (round time, bytes on wire,
retransmissions, rounds-to-target-loss, ...) must agree with the batched
engine within documented tolerances.  This module is the reusable machinery
for that claim:

* :func:`sweep` — run a ``seed -> {metric: value}`` scenario over N seeds
  and collect per-metric samples;
* :func:`summarize` — mean/variance/confidence interval of one sample set;
* :func:`compare` — the equivalence gate: mean agreement (relative band
  plus a z-score on the standard error, so tight distributions are held
  tight and noisy ones are judged by their own spread), a variance-ratio
  band, and a KS-style max-CDF-distance bound;
* :func:`ks_statistic` — two-sample Kolmogorov-Smirnov distance (exact
  O(n log n) over the pooled sample, no scipy dependency).

What "equivalent" means per metric (the documented tolerances live with
each test via :class:`Tolerance`; the methodology is docs/PERFORMANCE.md):

* ``mean``: ``|mean_a - mean_b| <= rtol * max(|a|,|b|) + atol`` OR within
  ``z_max`` pooled standard errors — the OR matters because a near-zero
  metric (e.g. retx on a clean link) makes any relative band meaningless,
  and a wide-variance metric (round time under bursty loss) can miss a
  tight relative band while being statistically indistinguishable.
* ``variance``: ``var_a <= var_hi * var_b + atol^2`` and vice versa with
  ``var_lo``.  The flow engine replaces per-packet jitter by its mean, so
  a one-sided lower band (flow allowed less variance, never more) is the
  physically honest default.  Either band may be ``None`` to skip it:
  at very low loss rates the duration variance is dominated by a rare
  timer-wait event (a few percent per seed), and a sample-variance ratio
  over tens of seeds measures the luck of rare-event counts, not a
  difference between the engines — gate those metrics on the mean only.
* ``ks``: max CDF distance <= ``ks_max``.  Used where distribution *shape*
  matters (rounds-to-target is small-integer-valued; a mean test alone
  could hide a bimodal mismatch).

Every check returns a list of human-readable failure strings instead of
asserting, so a test can aggregate all metric failures into one report.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Callable, Optional, Sequence

NS = 1_000_000_000


# --------------------------------------------------------------------------
# Summaries
# --------------------------------------------------------------------------
@dataclasses.dataclass
class Summary:
    n: int
    mean: float
    var: float            # unbiased (n-1) sample variance
    lo: float             # 95% CI on the mean
    hi: float

    @property
    def sd(self) -> float:
        return math.sqrt(self.var)

    @property
    def sem(self) -> float:
        return math.sqrt(self.var / self.n) if self.n else 0.0


def summarize(values: Sequence[float]) -> Summary:
    vals = [float(v) for v in values]
    n = len(vals)
    if n == 0:
        return Summary(0, 0.0, 0.0, 0.0, 0.0)
    mean = sum(vals) / n
    var = (sum((v - mean) ** 2 for v in vals) / (n - 1)) if n > 1 else 0.0
    sem = math.sqrt(var / n) if n else 0.0
    return Summary(n, mean, var, mean - 1.96 * sem, mean + 1.96 * sem)


def ks_statistic(a: Sequence[float], b: Sequence[float]) -> float:
    """Two-sample KS distance: max |F_a(x) - F_b(x)| over the pooled
    sample."""
    xa, xb = sorted(float(v) for v in a), sorted(float(v) for v in b)
    na, nb = len(xa), len(xb)
    if not na or not nb:
        return 1.0
    i = j = 0
    d = 0.0
    while i < na and j < nb:
        x = min(xa[i], xb[j])
        while i < na and xa[i] <= x:
            i += 1
        while j < nb and xb[j] <= x:
            j += 1
        d = max(d, abs(i / na - j / nb))
    return max(d, abs(1.0 - j / nb) if i >= na else abs(i / na - 1.0))


# --------------------------------------------------------------------------
# The equivalence gate
# --------------------------------------------------------------------------
@dataclasses.dataclass
class Tolerance:
    """Per-metric equivalence bands (see module docstring for semantics)."""

    mean_rtol: float = 0.10      # relative band on the means
    mean_atol: float = 0.0       # absolute floor (units of the metric)
    z_max: float = 4.0           # pooled-SEM z-score alternative
    var_hi: float | None = 4.0   # var_a <= var_hi * var_b (+atol^2)
    var_lo: float | None = 16.0  # var_b <= var_lo * var_a (+atol^2) — loose:
    # the flow engine collapses jitter to its mean, so *less* variance than
    # the packet engines is expected and mostly unbounded below.
    ks_max: Optional[float] = None   # optional shape gate


def compare(name: str, a: Sequence[float], b: Sequence[float],
            tol: Tolerance) -> list[str]:
    """Gate sample sets ``a`` (reference engine) vs ``b`` (flow engine).

    Returns human-readable failure strings; empty means equivalent."""
    sa, sb = summarize(a), summarize(b)
    fails: list[str] = []
    diff = abs(sa.mean - sb.mean)
    scale = max(abs(sa.mean), abs(sb.mean))
    pooled_sem = math.sqrt(sa.sem ** 2 + sb.sem ** 2)
    mean_ok = diff <= tol.mean_rtol * scale + tol.mean_atol
    z_ok = pooled_sem > 0 and diff <= tol.z_max * pooled_sem
    if not (mean_ok or z_ok):
        fails.append(
            f"{name}: mean mismatch ref={sa.mean:.6g} flow={sb.mean:.6g} "
            f"(diff {diff:.4g} > rtol {tol.mean_rtol}*{scale:.4g}"
            f"+atol {tol.mean_atol:.4g}; z={diff / pooled_sem:.2f}"
            if pooled_sem > 0 else
            f"{name}: mean mismatch ref={sa.mean:.6g} flow={sb.mean:.6g}")
    a2 = tol.mean_atol ** 2
    if tol.var_hi is not None and sb.var > tol.var_hi * sa.var + a2:
        fails.append(f"{name}: flow variance {sb.var:.4g} exceeds "
                     f"{tol.var_hi}x reference {sa.var:.4g}")
    if tol.var_lo is not None and sa.var > tol.var_lo * sb.var + a2:
        fails.append(f"{name}: flow variance {sb.var:.4g} collapsed below "
                     f"reference/{tol.var_lo} ({sa.var:.4g})")
    if tol.ks_max is not None:
        d = ks_statistic(a, b)
        if d > tol.ks_max:
            fails.append(f"{name}: KS distance {d:.3f} > {tol.ks_max}")
    return fails


# --------------------------------------------------------------------------
# Seed sweeps
# --------------------------------------------------------------------------
def sweep(run: Callable[[int], dict], seeds: Sequence[int]
          ) -> dict[str, list[float]]:
    """Run ``run(seed) -> {metric: value}`` over the seeds; collect
    per-metric sample lists.  ``None`` values (e.g. rounds-to-target never
    reached) are recorded as ``math.inf`` so shape gates still see them."""
    out: dict[str, list[float]] = {}
    for seed in seeds:
        row = run(seed)
        for k, v in row.items():
            out.setdefault(k, []).append(
                math.inf if v is None else float(v))
    return out


def compare_sweeps(ref: dict[str, list[float]], flow: dict[str, list[float]],
                   tols: dict[str, Tolerance]) -> list[str]:
    """Apply per-metric tolerances to two sweep results; unknown metrics in
    either sweep are an error (a silently dropped metric is a silently
    skipped gate)."""
    fails: list[str] = []
    for name, tol in tols.items():
        if name not in ref or name not in flow:
            fails.append(f"{name}: metric missing from sweep "
                         f"(ref: {name in ref}, flow: {name in flow})")
            continue
        a = [v for v in ref[name] if not math.isinf(v)]
        b = [v for v in flow[name] if not math.isinf(v)]
        ninf_a = len(ref[name]) - len(a)
        ninf_b = len(flow[name]) - len(b)
        # Unreached targets must agree in *rate* before means are comparable.
        n = max(len(ref[name]), 1)
        if abs(ninf_a - ninf_b) > max(2, 0.25 * n):
            fails.append(f"{name}: unreached-target rate differs "
                         f"(ref {ninf_a}/{len(ref[name])}, "
                         f"flow {ninf_b}/{len(flow[name])})")
            continue
        if not a and not b:
            continue
        fails.extend(compare(name, a, b, tol))
    return fails


# --------------------------------------------------------------------------
# Scenario builders (shared by tests and benchmarks)
# --------------------------------------------------------------------------
def transfer_metrics(engine: str, kind: str, seed: int, *,
                     loss_p: float = 0.1, bursty: bool = False,
                     payload: int = 60_000, mtu: int = 1200,
                     rate_bps: float = 1e7, delay_ns: int = 5_000_000,
                     jitter_ns: int = 0,
                     timeout_ns: int = 2 * NS) -> dict:
    """One direct transfer over one seeded lossy link; the single-link
    microscope the per-transport distributional tests look through."""
    from repro.core import (GilbertElliott, BernoulliLoss, Link, Simulator,
                            TransportConfig, make_transport, packetize)
    from repro.core.flow import maybe_flow
    sim = Simulator(engine=engine)
    if bursty:
        loss = lambda s: GilbertElliott(  # noqa: E731
            p_good_loss=loss_p / 4, p_bad_loss=min(1.0, loss_p * 10),
            p_bad=0.075, seed=s)
    else:
        loss = lambda s: BernoulliLoss(p=loss_p, seed=s)  # noqa: E731
    mk = lambda s: Link(rate_bps, delay_ns, loss(s),  # noqa: E731
                        jitter_ns=jitter_ns, jitter_seed=s + 77)
    src, dst = "10.1.0.9", "10.0.0.1"
    sim.connect(src, dst, mk(seed), mk(seed + 1))
    tr = maybe_flow(sim, make_transport(kind))
    cfg = TransportConfig(kind=kind, mtu=mtu, timeout_ns=timeout_ns,
                          udp_deadline_ns=4 * NS)
    got = []
    tr.create_receiver(sim, sim.node(dst), cfg, got.append)
    data = bytes(range(256)) * (payload // 256)
    sender = tr.create_sender(sim, sim.node(src), sim.node(dst),
                              packetize(data, src, txn=1, mtu=mtu), cfg)
    sender.start()
    sim.run()
    st = sender.stats
    return {
        "duration_ns": st.duration_ns,
        "sim_end_ns": sim.now_ns,
        "bytes_sent": sim.stats["bytes_sent"],
        "packets_sent": sim.stats["packets_sent"],
        "packets_dropped": sim.stats["packets_dropped"],
        "retransmissions": st.retransmissions,
        "completed": 1.0 if st.completed else 0.0,
        "delivered": float(len(got)),
    }


def fleet_metrics(engine: str, transport: str, seed: int, *,
                  n_clients: int = 24, rounds: int = 3,
                  topology: str = "star", cells: int = 4,
                  participation: float = 0.5, n_params: int = 512,
                  mode: str = "sync",
                  deadline_ns: int = 60 * NS) -> dict:
    """One seeded fleet scenario (the fleet_scale benchmark's cell, sized
    for sweeps): returns the tentpole's four gated metrics."""
    from repro.core import (ConsensusObjective, FLConfig, FleetConfig,
                            TransportConfig, build_fleet)
    fleet = FleetConfig(n_clients=n_clients, seed=seed,
                        participation_fraction=participation,
                        round_deadline_ns=deadline_ns, engine=engine,
                        mode=mode, topology=topology,
                        cells=min(cells, n_clients))
    objective = ConsensusObjective(n_clients, n_params, seed=seed)
    fl_cfg = FLConfig(
        aggregation="fedavg",
        transport=TransportConfig(kind=transport, timeout_ns=2 * NS,
                                  udp_deadline_ns=3 * NS))
    sim, system, _ = build_fleet(fleet, objective.init_params(),
                                 objective.train_fn, fl_cfg)
    loss0 = objective.loss(system.global_params)
    losses: list[float] = []
    durations: list[int] = []
    retx: list[int] = []

    def _on_round(r, params):
        losses.append(objective.loss(params))
        durations.append(r.duration_ns)
        retx.append(r.retransmissions)

    system.on_round_end = _on_round
    system.run_rounds(rounds)
    return {
        "round_time_ns": (sum(durations) / len(durations)) if durations
        else 0.0,
        "bytes_on_wire": sim.stats["bytes_sent"],
        "retransmissions": sum(retx),
        "rounds_to_target": next(
            (i + 1 for i, l in enumerate(losses) if l <= 0.1 * loss0), None),
        "final_loss": losses[-1] if losses else loss0,
    }
