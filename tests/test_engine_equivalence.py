"""Engine equivalence: the batched flight engine must be bit-for-bit
identical to the per-packet reference — same stats, same final clock, same
delivered bytes, same FL round results — across seeds, transports, and
jittered/reordering/lossy links.  Plus unit coverage for the pieces the
equivalence rests on: the keyed counter-based RNG (scalar == vectorized),
the bulk-ingestion fallback, per-kind counters, and the arithmetic
``wire_bytes``.
"""

import dataclasses
import hashlib

import numpy as np
import pytest

from repro.core import (BernoulliLoss, ConsensusObjective, DropList, FLConfig,
                        FleetConfig, GilbertElliott, Link, LossModel, NoLoss,
                        Packetizer, Simulator, TransportConfig,
                        available_transports, build_fleet, keyed_uniform,
                        keyed_uniforms, make_transport, packet_key_arrays,
                        packetize)
from repro.core.channel import JITTER_STREAM, LOSS_STREAM
from repro.core.fleet import links_for, sample_profiles
from repro.core.packets import HEADER_BYTES, make_data_packet

NS = 1_000_000_000
SERVER = "10.0.0.1"


# --------------------------------------------------------------------------
# The keyed RNG: one function, two shapes
# --------------------------------------------------------------------------
class TestKeyedUniforms:
    def test_scalar_equals_vectorized(self):
        pkts = [make_data_packet(s, 64, "10.1.0.1", b"x" * s, txn=3)
                for s in range(1, 65)]
        pkts = [dataclasses.replace(p, attempt=s % 3)
                for s, p in enumerate(pkts)]
        txns, kinds, seqs, attempts = packet_key_arrays(pkts)
        for stream in (LOSS_STREAM, JITTER_STREAM, 0xABCD):
            for seed in (0, 1, -7, 2**63):
                vec = keyed_uniforms(stream, seed, txns, kinds, seqs,
                                     attempts)
                sca = [keyed_uniform(stream, seed, p) for p in pkts]
                assert vec.tolist() == sca

    def test_draws_in_unit_interval_and_vary(self):
        pkts = [make_data_packet(s, 999, "a", b"", txn=0)
                for s in range(1, 1000)]
        txns, kinds, seqs, attempts = packet_key_arrays(pkts)
        u = keyed_uniforms(LOSS_STREAM, 42, txns, kinds, seqs, attempts)
        assert float(u.min()) >= 0.0 and float(u.max()) < 1.0
        assert 0.3 < float(u.mean()) < 0.7
        assert len(set(u.tolist())) == len(pkts)

    def test_streams_decorrelated(self):
        p = make_data_packet(5, 9, "a", b"x", txn=2)
        assert keyed_uniform(LOSS_STREAM, 0, p) != \
            keyed_uniform(JITTER_STREAM, 0, p)

    def test_loss_models_scalar_vs_mask(self):
        pkts = packetize(bytes(range(256)) * 40, "10.1.0.2", txn=7, mtu=200)
        arrays = packet_key_arrays(pkts)
        for model in (BernoulliLoss(p=0.3, seed=5),
                      GilbertElliott(p_good_loss=0.05, p_bad_loss=0.6,
                                     p_bad=0.2, seed=9),
                      NoLoss(),
                      DropList({(2, 0), (5, 0)})):
            mask = model.drop_mask(pkts, *arrays)
            assert mask.tolist() == [model.drops(p) for p in pkts]

    def test_custom_loss_model_default_mask_falls_back(self):
        class OddSeqLoss(LossModel):
            def drops(self, pkt):
                return pkt.seq % 2 == 1

        pkts = packetize(b"z" * 4000, "10.1.0.3", txn=1, mtu=300)
        mask = OddSeqLoss().drop_mask(pkts, *packet_key_arrays(pkts))
        assert mask.tolist() == [p.seq % 2 == 1 for p in pkts]

    def test_jitter_scalar_vs_array(self):
        link = Link(1e8, 10_000_000, NoLoss(), jitter_ns=5_000_000,
                    jitter_seed=11)
        pkts = packetize(b"q" * 9000, "10.1.0.4", txn=4, mtu=256)
        arr = link.propagation_array(*packet_key_arrays(pkts))
        assert arr.tolist() == [link.propagation_ns(p) for p in pkts]


# --------------------------------------------------------------------------
# Direct transfers: one link, adversarial conditions
# --------------------------------------------------------------------------
def _transfer_digest(engine, kind, loss, *, jitter_ns=0, mtu=300,
                     payload=6000, timeout_ns=2 * NS):
    sim = Simulator(engine=engine)
    link = lambda seed: Link(1e7, 5_000_000, loss(),  # noqa: E731
                             jitter_ns=jitter_ns, jitter_seed=seed)
    sim.connect("10.1.0.9", SERVER, link(1), link(2))
    tr = make_transport(kind)
    cfg = TransportConfig(kind=kind, mtu=mtu, timeout_ns=timeout_ns,
                          udp_deadline_ns=4 * NS)
    got = []
    tr.create_receiver(sim, sim.node(SERVER), cfg, got.append)
    data = bytes(range(256)) * (payload // 256)
    sender = tr.create_sender(sim, sim.node("10.1.0.9"), sim.node(SERVER),
                              packetize(data, "10.1.0.9", txn=1, mtu=mtu),
                              cfg)
    sender.start()
    sim.run()
    blob = repr((sim.now_ns, sorted(sim.stats.items()),
                 [(d.sender_addr, d.txn, d.total, d.complete,
                   d.reassemble()) for d in got],
                 dataclasses.astuple(sender.stats)))
    return hashlib.sha256(blob.encode()).hexdigest()


@pytest.mark.parametrize("kind", available_transports())
class TestDirectTransferEquivalence:
    def test_clean_link(self, kind):
        assert _transfer_digest("per_packet", kind, NoLoss) == \
            _transfer_digest("batched", kind, NoLoss)

    def test_reordering_jitter(self, kind):
        # Jitter larger than the serialization gap reorders in flight.
        for seed in range(3):
            mk = lambda: BernoulliLoss(p=0.05, seed=seed)  # noqa: E731
            a = _transfer_digest("per_packet", kind, mk, jitter_ns=8_000_000)
            b = _transfer_digest("batched", kind, mk, jitter_ns=8_000_000)
            assert a == b

    def test_bursty_loss(self, kind):
        mk = lambda: GilbertElliott(p_good_loss=0.02, p_bad_loss=0.5,  # noqa: E731
                                    p_bad=0.15, seed=3)
        assert _transfer_digest("per_packet", kind, mk) == \
            _transfer_digest("batched", kind, mk)

    def test_exact_drop_pattern(self, kind):
        mk = lambda: DropList({(1, 0), (2, 0), (7, 0), (21, 1)})  # noqa: E731
        assert _transfer_digest("per_packet", kind, mk) == \
            _transfer_digest("batched", kind, mk)

    def test_timer_fires_mid_flight(self, kind):
        # Sender timeout far shorter than the burst's serialization time:
        # timer-driven resends and NACK rounds cross with the in-flight
        # data flight — the adversarial interleaving for deep ingestion.
        for timeout in (20_000_000, 60_000_000):
            mk = lambda: BernoulliLoss(p=0.15, seed=4)  # noqa: E731
            a = _transfer_digest("per_packet", kind, mk,
                                 jitter_ns=8_000_000, timeout_ns=timeout)
            b = _transfer_digest("batched", kind, mk,
                                 jitter_ns=8_000_000, timeout_ns=timeout)
            assert a == b


# --------------------------------------------------------------------------
# Fleet rounds: full FL stack, heterogeneous cohorts
# --------------------------------------------------------------------------
def _fleet_round_digest(engine, kind, seed, *, n_clients=8, rounds=2,
                        n_params=600):
    fleet = FleetConfig(n_clients=n_clients, seed=seed,
                        participation_fraction=0.75,
                        round_deadline_ns=90 * NS, engine=engine)
    objective = ConsensusObjective(n_clients, n_params, seed=seed)
    cfg = FLConfig(aggregation="fedavg",
                   transport=TransportConfig(kind=kind, timeout_ns=4 * NS,
                                             udp_deadline_ns=6 * NS))
    sim, system, _ = build_fleet(fleet, objective.init_params(),
                                 objective.train_fn, cfg)
    results = [system.run_round() for _ in range(rounds)]
    blob = repr((sim.now_ns, sorted(sim.stats.items()),
                 [dataclasses.asdict(r) for r in results],
                 system.global_params["w"].tobytes()))
    return hashlib.sha256(blob.encode()).hexdigest()


@pytest.mark.parametrize("kind", available_transports())
@pytest.mark.parametrize("seed", [0, 1, 7])
def test_fleet_round_bit_identical(kind, seed):
    assert _fleet_round_digest("per_packet", kind, seed) == \
        _fleet_round_digest("batched", kind, seed)


# --------------------------------------------------------------------------
# Engine plumbing
# --------------------------------------------------------------------------
class TestEnginePlumbing:
    def test_unknown_engine_rejected(self):
        with pytest.raises(ValueError, match="engine"):
            Simulator(engine="warp")

    def test_send_burst_fallback_is_per_packet_loop(self):
        # Under the per-packet engine, send_burst == N sends, exactly.
        def run(use_burst):
            sim = Simulator(engine="per_packet")
            sim.connect("a", "b", Link(1e8, 1_000_000))
            got = []
            sim.node("b").register(lambda p: got.append(p) or True)
            pkts = packetize(b"x" * 3000, "a", txn=1, mtu=300)
            if use_burst:
                sim.node("a").send_burst(pkts, sim.node("b"))
            else:
                for p in pkts:
                    sim.node("a").send(p, sim.node("b"))
            sim.run()
            return sim.stats_digest(), [p.seq for p in got]

        assert run(True) == run(False)

    def test_per_kind_counters_sum_to_totals(self):
        sim = Simulator(engine="batched")
        profiles = sample_profiles(FleetConfig(n_clients=4, seed=3))
        for p in profiles:
            up, down = links_for(p)
            sim.connect(p.addr, SERVER, up, down)
        tr = make_transport("mudp+fec")
        cfg = TransportConfig(kind="mudp+fec", timeout_ns=4 * NS)
        tr.create_receiver(sim, sim.node(SERVER), cfg, lambda d: None)
        for p in profiles:
            tr.create_sender(sim, sim.node(p.addr), sim.node(SERVER),
                             packetize(b"y" * 20_000, p.addr, txn=1,
                                       mtu=cfg.mtu), cfg).start()
        sim.run()
        s = sim.stats
        for total, prefix in (("packets_sent", "sent_"),
                              ("packets_dropped", "dropped_"),
                              ("packets_delivered", "delivered_")):
            by_kind = sum(v for k, v in s.items() if k.startswith(prefix))
            assert by_kind == s[total]
        assert s.get("sent_parity", 0) > 0    # FEC trailer was counted

    def test_events_processed_counts_match_engines(self):
        a = Simulator(engine="per_packet")
        b = Simulator(engine="batched")
        for sim in (a, b):
            sim.connect("a", "b", Link(1e8, 1_000_000,
                                       jitter_ns=500_000, jitter_seed=5))
            tr = make_transport("mudp")
            cfg = TransportConfig(kind="mudp")
            tr.create_receiver(sim, sim.node("b"), cfg, lambda d: None)
            tr.create_sender(sim, sim.node("a"), sim.node("b"),
                             packetize(b"k" * 8000, "a", txn=1, mtu=300),
                             cfg).start()
            sim.run()
        assert a.events_processed == b.events_processed
        assert a.stats_digest() == b.stats_digest()

    def test_paused_run_resumes_identically(self):
        def staged(engine):
            sim = Simulator(engine=engine)
            sim.connect("a", "b", Link(1e7, 2_000_000, jitter_ns=3_000_000,
                                       jitter_seed=2))
            tr = make_transport("udp")
            cfg = TransportConfig(kind="udp", udp_deadline_ns=4 * NS)
            got = []
            tr.create_receiver(sim, sim.node("b"), cfg, got.append)
            tr.create_sender(sim, sim.node("a"), sim.node("b"),
                             packetize(b"m" * 12_000, "a", txn=1, mtu=300),
                             cfg).start()
            mids = []
            # Pause mid-flight several times, then drain.
            for until in (2_500_000, 3_500_000, 5_000_000):
                sim.run(until_ns=until)
                mids.append((sim.now_ns, dict(sim.stats)))
            sim.run()
            return mids, sim.stats_digest(), [d.reassemble() for d in got]

        assert staged("per_packet") == staged("batched")


# --------------------------------------------------------------------------
# wire_bytes (arithmetic form == materialized packets)
# --------------------------------------------------------------------------
class TestWireBytes:
    @pytest.mark.parametrize("n_params", [0, 1, 37, 1000])
    @pytest.mark.parametrize("mtu", [60, 428, 1500])
    def test_matches_packet_sum(self, n_params, mtu):
        pz = Packetizer(mtu=mtu)
        tree = {"w": np.arange(n_params, dtype=np.float32)}
        data = pz.codec.encode(np.arange(n_params, dtype=np.float32))
        pkts = packetize(data, "0.0.0.0", 0, mtu)
        assert pz.wire_bytes(tree) == sum(p.size_bytes for p in pkts)

    def test_single_empty_packet_is_header_only(self):
        assert Packetizer().wire_bytes({"w": np.zeros(0, np.float32)}) == \
            HEADER_BYTES

    def test_mtu_too_small_raises(self):
        with pytest.raises(ValueError, match="mtu"):
            Packetizer(mtu=10).wire_bytes({"w": np.ones(4, np.float32)})
