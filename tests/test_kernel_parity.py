"""Parity pins between the numpy transport-layer math and the Pallas
kernels whose docstrings claim to mirror it.

* ``repro.core.aggregation.fedavg`` (numpy backend) vs
  ``repro.kernels.fedavg.ops.fedavg_trees`` — the "optional backend" the
  orchestrator can select via ``FLConfig.aggregation_backend``.  The two
  agree to ~1 ULP (the kernel reduces over clients in one fused pass, so
  exact bit-identity is NOT guaranteed — which is why numpy stays the
  digest-stable default).
* ``repro.core.compression.quantize_int8``/``dequantize_int8`` vs
  ``repro.kernels.quantize.ref`` — the "kernel's oracle" comment, now
  enforced: identical scales (bit-for-bit) and identical int8 codes on
  shared random vectors.
"""

import numpy as np
import pytest

jax = pytest.importorskip("jax")

from repro.core import aggregation as agg                    # noqa: E402
from repro.core.compression import (dequantize_int8,          # noqa: E402
                                    quantize_int8)
from repro.kernels.fedavg import ops as fedavg_ops            # noqa: E402
from repro.kernels.quantize import ref as quantize_ref        # noqa: E402


def _trees(rng, k, n):
    return [{"w": rng.standard_normal(n).astype(np.float32),
             "b": rng.standard_normal(7).astype(np.float32)}
            for _ in range(k)]


class TestFedavgBackendParity:
    @pytest.mark.parametrize("k,n", [(2, 300), (3, 1024), (8, 4096),
                                     (5, 16384 + 13)])
    def test_kernel_mirrors_numpy(self, k, n):
        rng = np.random.default_rng(k * 1000 + n)
        trees = _trees(rng, k, n)
        weights = (rng.random(k) * 2.0 + 0.1).tolist()
        a = agg.fedavg(trees, weights, backend="numpy")
        b = fedavg_ops.fedavg_trees(trees, weights)
        for key in a:
            np.testing.assert_allclose(a[key], np.asarray(b[key]),
                                       rtol=1e-6, atol=1e-6)

    def test_uniform_weights_default(self):
        rng = np.random.default_rng(0)
        trees = _trees(rng, 4, 512)
        a = agg.fedavg(trees, backend="numpy")
        b = agg.fedavg(trees, backend="kernel")
        for key in a:
            np.testing.assert_allclose(a[key], np.asarray(b[key]),
                                       rtol=1e-6, atol=1e-6)

    def test_backend_dispatch(self):
        rng = np.random.default_rng(1)
        trees = _trees(rng, 3, 256)
        # auto == kernel whenever jax imports (it does in this test).
        auto = agg.fedavg(trees, backend="auto")
        kern = agg.fedavg(trees, backend="kernel")
        for key in auto:
            np.testing.assert_array_equal(np.asarray(auto[key]),
                                          np.asarray(kern[key]))
        with pytest.raises(ValueError, match="backend"):
            agg.fedavg(trees, backend="gpu4000")

    def test_orchestrator_accepts_kernel_backend(self):
        from repro.core import FLConfig
        cfg = FLConfig(aggregation_backend="auto")
        assert cfg.aggregation_backend == "auto"
        with pytest.raises(ValueError, match="aggregation_backend"):
            FLConfig(aggregation_backend="nope")


class TestFedavgStackParity:
    """The batched path: ``fedavg_stack`` over a flat ``(K, P)`` stack.

    Two claims from its docstring, both load-bearing: the numpy stack path
    is **bit-identical** to the per-leaf tree fold (so the orchestrator's
    flat fast path cannot move a replay digest), and the kernel backend
    mirrors it to ~1 ULP (the same oracle contract as the tree path).
    """

    @pytest.mark.parametrize("k,n", [(2, 300), (3, 1024), (8, 4096),
                                     (5, 16384 + 13)])
    def test_stack_numpy_bitwise_equals_tree_numpy(self, k, n):
        from repro.core.packetizer import (flatten_to_vector,
                                           unflatten_from_vector)
        rng = np.random.default_rng(k * 31 + n)
        trees = _trees(rng, k, n)
        weights = (rng.random(k) * 2.0 + 0.1).tolist()
        tree_out = agg.fedavg(trees, weights, backend="numpy")
        stack = np.stack([flatten_to_vector(t) for t in trees])
        vec = agg.fedavg_stack(stack, weights, backend="numpy")
        rebuilt = unflatten_from_vector(vec, trees[0])
        for key in tree_out:
            np.testing.assert_array_equal(tree_out[key], rebuilt[key])

    @pytest.mark.parametrize("k,n", [(2, 256), (7, 4096), (16, 16384 + 5)])
    def test_kernel_mirrors_numpy_stack(self, k, n):
        rng = np.random.default_rng(k * 97 + n)
        stack = rng.standard_normal((k, n)).astype(np.float32)
        weights = (rng.random(k) + 0.05).tolist()
        a = agg.fedavg_stack(stack, weights, backend="numpy")
        b = agg.fedavg_stack(stack, weights, backend="kernel")
        np.testing.assert_allclose(a, b, rtol=1e-6, atol=1e-6)

    def test_auto_routes_to_kernel_and_validates(self):
        rng = np.random.default_rng(2)
        stack = rng.standard_normal((3, 512)).astype(np.float32)
        auto = agg.fedavg_stack(stack, backend="auto")
        kern = agg.fedavg_stack(stack, backend="kernel")
        np.testing.assert_array_equal(auto, kern)
        with pytest.raises(ValueError, match="backend"):
            agg.fedavg_stack(stack, backend="gpu4000")
        with pytest.raises(ValueError, match="stack"):
            agg.fedavg_stack(np.zeros((0, 8), np.float32))
        with pytest.raises(ValueError, match="stack"):
            agg.fedavg_stack(np.zeros(8, np.float32))

    def test_kernel_flat_direct(self):
        # fedavg_flat is the raw Pallas entry the stack path routes to;
        # K=1 must be the identity up to weight normalization.
        vec = np.linspace(-1, 1, 777, dtype=np.float32)
        out = np.asarray(fedavg_ops.fedavg_flat(vec[None], [3.0]))
        np.testing.assert_allclose(out, vec, rtol=1e-6, atol=1e-7)


class TestQuantizeOracleParity:
    """The compression docstring says quantize_int8 mirrors
    repro.kernels.quantize.ref — pinned here on shared random vectors."""

    @pytest.mark.parametrize("n,block", [(1024, 256), (4096, 1024),
                                         (1000, 256), (37, 16)])
    def test_quantize_matches_ref(self, n, block):
        rng = np.random.default_rng(n * 7 + block)
        vec = (rng.standard_normal(n) * 10).astype(np.float32)
        q_np, scales_np = quantize_int8(vec, block=block)

        nb = -(-n // block)
        padded = np.zeros(nb * block, dtype=np.float32)
        padded[:n] = vec
        q_ref, scales_ref = quantize_ref.quantize_blockwise(
            padded.reshape(nb, block))

        np.testing.assert_array_equal(q_np.reshape(nb, block),
                                      np.asarray(q_ref))
        np.testing.assert_array_equal(scales_np, np.asarray(scales_ref))

    def test_dequantize_matches_ref(self):
        rng = np.random.default_rng(5)
        n, block = 2048, 512
        vec = (rng.standard_normal(n) * 3).astype(np.float32)
        q, scales = quantize_int8(vec, block=block)
        out_np = dequantize_int8(q, scales, n, block=block)
        out_ref = np.asarray(quantize_ref.dequantize_blockwise(
            np.asarray(q).reshape(-1, block), np.asarray(scales))).reshape(-1)
        np.testing.assert_array_equal(out_np, out_ref[:n])

    def test_roundtrip_error_bounded_by_scale(self):
        rng = np.random.default_rng(6)
        vec = (rng.standard_normal(513) * 4).astype(np.float32)
        q, scales = quantize_int8(vec, block=128)
        out = dequantize_int8(q, scales, vec.size, block=128)
        err = np.abs(out - vec)
        per_block_bound = np.repeat(scales, 128)[:vec.size] * 0.5 + 1e-7
        assert np.all(err <= per_block_bound)


# --------------------------------------------------------------------------
# Wire batch-plane stage kernels (PR 9): topk gather/scatter + matrix
# quantize, the Pallas fast paths behind wire.set_batch_backend("pallas")
# --------------------------------------------------------------------------
from repro.core.compression import (dequantize_int8_batch,     # noqa: E402
                                    quantize_int8_batch)
from repro.kernels.quantize import ops as quantize_ops         # noqa: E402
from repro.kernels.quantize.quantize import QBLOCK             # noqa: E402
from repro.kernels.topk import ops as topk_ops                 # noqa: E402
from repro.kernels.topk import ref as topk_ref                 # noqa: E402


def _unique_idx(rng, n_items, p, k):
    return np.stack([np.sort(rng.choice(p, size=k, replace=False))
                     for _ in range(n_items)]).astype(np.int32)


class TestTopKKernelParity:
    """Gather/scatter are pure data movement: the Pallas kernels must be
    **exact** against both the numpy wire path and the jnp oracle — this
    is what lets the pallas batch backend keep the wire's bit-identity
    contract for ``topk`` stages."""

    @pytest.mark.parametrize("n_items,p,k", [(1, 64, 4), (7, 1000, 50),
                                             (16, 4096, 41)])
    def test_gather_exact(self, n_items, p, k):
        rng = np.random.default_rng(n_items * 131 + p)
        batch = rng.standard_normal((n_items, p)).astype(np.float32)
        idx = _unique_idx(rng, n_items, p, k)
        out = np.asarray(topk_ops.topk_gather(batch, idx))
        np.testing.assert_array_equal(out,
                                      np.take_along_axis(batch, idx, axis=1))
        np.testing.assert_array_equal(
            out, np.asarray(topk_ref.gather_rows(
                jax.numpy.asarray(batch), jax.numpy.asarray(idx))))

    @pytest.mark.parametrize("n_items,p,k", [(1, 64, 4), (7, 1000, 50),
                                             (16, 4096, 41)])
    def test_scatter_exact(self, n_items, p, k):
        rng = np.random.default_rng(n_items * 17 + p)
        idx = _unique_idx(rng, n_items, p, k)
        vals = rng.standard_normal((n_items, k)).astype(np.float32)
        out = np.asarray(topk_ops.topk_scatter(idx, vals, p))
        dense = np.zeros((n_items, p), np.float32)
        dense[np.repeat(np.arange(n_items), k), idx.reshape(-1)] = \
            vals.reshape(-1)
        np.testing.assert_array_equal(out, dense)
        np.testing.assert_array_equal(
            out, np.asarray(topk_ref.scatter_rows(
                jax.numpy.asarray(idx), jax.numpy.asarray(vals), p)))

    def test_scatter_duplicate_indices_last_wins(self):
        """Malformed payloads can carry duplicate indices; the kernel's
        sequential row loop must resolve them exactly like numpy fancy
        assignment (last occurrence wins) so batch decode stays
        bit-identical even on garbage."""
        idx = np.array([[3, 3, 7], [0, 5, 0]], np.int32)
        vals = np.array([[1., 2., 3.], [4., 5., 6.]], np.float32)
        out = np.asarray(topk_ops.topk_scatter(idx, vals, 8))
        dense = np.zeros((2, 8), np.float32)
        dense[np.repeat(np.arange(2), 3), idx.reshape(-1)] = vals.reshape(-1)
        np.testing.assert_array_equal(out, dense)

    def test_gather_scatter_roundtrip(self):
        rng = np.random.default_rng(9)
        batch = rng.standard_normal((5, 300)).astype(np.float32)
        idx = _unique_idx(rng, 5, 300, 30)
        vals = np.asarray(topk_ops.topk_gather(batch, idx))
        dense = np.asarray(topk_ops.topk_scatter(idx, vals, 300))
        np.testing.assert_array_equal(
            np.take_along_axis(dense, idx, axis=1), vals)


class TestQuantizeMatrixKernelParity:
    """The batched (N, P) quantize behind the wire's pallas ``int8``
    path.  XLA rewrites the scale division into multiply-by-reciprocal,
    so the jit'd kernel is NOT bit-identical to numpy — the pinned
    contract is: scales within 1 ULP, codes within 1 step (a boundary
    value can round across when its scale moved 1 ULP), and dequantize
    on shared (q, scales) inputs **bitwise** identical."""

    @pytest.mark.parametrize("n_items,n", [(1, QBLOCK), (4, 3 * QBLOCK),
                                           (7, 2 * QBLOCK + 37), (3, 5)])
    def test_quantize_matrix_ulp_pinned(self, n_items, n):
        rng = np.random.default_rng(n_items * 101 + n)
        mat = (rng.standard_normal((n_items, n)) * 8).astype(np.float32)
        q_np, s_np = quantize_int8_batch(mat, block=QBLOCK)
        q_k, s_k = quantize_ops.quantize_matrix(mat)
        q_k, s_k = np.asarray(q_k), np.asarray(s_k)
        assert q_k.shape == q_np.shape and s_k.shape == s_np.shape
        np.testing.assert_array_max_ulp(s_k, s_np, maxulp=1)
        assert np.abs(q_k.astype(np.int16)
                      - q_np.astype(np.int16)).max() <= 1

    def test_dequantize_matrix_bitwise_on_shared_inputs(self):
        rng = np.random.default_rng(12)
        n_items, n = 5, 2 * QBLOCK + 11
        mat = (rng.standard_normal((n_items, n)) * 3).astype(np.float32)
        q, s = quantize_int8_batch(mat, block=QBLOCK)
        out_np = dequantize_int8_batch(q, s, n, block=QBLOCK)
        out_k = np.asarray(quantize_ops.dequantize_matrix(q, s, n))
        np.testing.assert_array_equal(out_np, out_k)

    def test_matrix_matches_vector_rows(self):
        """(N, P) kernel == N independent vector-kernel calls: batching
        must not change any row's result."""
        rng = np.random.default_rng(13)
        mat = (rng.standard_normal((3, QBLOCK + 9)) * 2).astype(np.float32)
        q_m, s_m = quantize_ops.quantize_matrix(mat)
        for i, row in enumerate(mat):
            q_v, s_v, _ = quantize_ops.quantize_vector(row)
            np.testing.assert_array_equal(np.asarray(q_m)[i],
                                          np.asarray(q_v).reshape(-1))
            np.testing.assert_array_equal(np.asarray(s_m)[i],
                                          np.asarray(s_v))


class TestPallasWireBackend:
    """Stage-level pins for wire.set_batch_backend("pallas")."""

    @pytest.fixture
    def pallas_backend(self):
        from repro.core import wire
        prev = wire.set_batch_backend("pallas")
        yield
        wire.set_batch_backend(prev)

    def test_auto_selects_pallas_when_kernels_import(self):
        from repro.core import wire
        prev = wire.set_batch_backend("auto")
        try:
            assert wire.batch_backend() == "pallas"
        finally:
            wire.set_batch_backend(prev)

    @pytest.mark.parametrize("spec", ["topk(0.05)", "topk(0.1)|hex"])
    def test_topk_stage_bytes_identical(self, spec, pallas_backend):
        """Gather/scatter are exact, so the pallas backend keeps full
        byte-identity for topk pipelines."""
        from repro.core import wire
        pipeline = wire.parse_pipeline(spec)
        rng = np.random.default_rng(21)
        batch = [rng.standard_normal(900).astype(np.float32)
                 for _ in range(6)]
        pallas_bytes = pipeline.encode_batch(batch)
        wire.set_batch_backend("numpy")
        numpy_bytes = pipeline.encode_batch(batch)
        assert pallas_bytes == numpy_bytes
        wire.set_batch_backend("pallas")
        np.testing.assert_array_equal(pipeline.decode_batch(numpy_bytes),
                                      np.stack([pipeline.decode(d)
                                                for d in numpy_bytes]))

    def test_int8_stage_within_one_code_step(self, pallas_backend):
        """int8 under pallas is ULP-pinned, not byte-pinned: decoded
        values may differ from the numpy path by at most one quantization
        step per element (the documented jit reciprocal drift)."""
        from repro.core import wire
        pipeline = wire.parse_pipeline("int8(1024)")
        rng = np.random.default_rng(22)
        batch = [(rng.standard_normal(3000) * 5).astype(np.float32)
                 for _ in range(4)]
        pallas_dec = pipeline.decode_batch(pipeline.encode_batch(batch))
        wire.set_batch_backend("numpy")
        numpy_dec = pipeline.decode_batch(pipeline.encode_batch(batch))
        wire.set_batch_backend("pallas")
        max_scale = max(np.abs(v).max() for v in batch) / 127.0
        np.testing.assert_allclose(pallas_dec, numpy_dec,
                                   atol=1.01 * max_scale, rtol=0)

    def test_default_backend_unaffected_by_kernel_availability(self):
        from repro.core import wire
        assert wire.batch_backend() == "numpy"
