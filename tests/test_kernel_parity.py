"""Parity pins between the numpy transport-layer math and the Pallas
kernels whose docstrings claim to mirror it.

* ``repro.core.aggregation.fedavg`` (numpy backend) vs
  ``repro.kernels.fedavg.ops.fedavg_trees`` — the "optional backend" the
  orchestrator can select via ``FLConfig.aggregation_backend``.  The two
  agree to ~1 ULP (the kernel reduces over clients in one fused pass, so
  exact bit-identity is NOT guaranteed — which is why numpy stays the
  digest-stable default).
* ``repro.core.compression.quantize_int8``/``dequantize_int8`` vs
  ``repro.kernels.quantize.ref`` — the "kernel's oracle" comment, now
  enforced: identical scales (bit-for-bit) and identical int8 codes on
  shared random vectors.
"""

import numpy as np
import pytest

jax = pytest.importorskip("jax")

from repro.core import aggregation as agg                    # noqa: E402
from repro.core.compression import (dequantize_int8,          # noqa: E402
                                    quantize_int8)
from repro.kernels.fedavg import ops as fedavg_ops            # noqa: E402
from repro.kernels.quantize import ref as quantize_ref        # noqa: E402


def _trees(rng, k, n):
    return [{"w": rng.standard_normal(n).astype(np.float32),
             "b": rng.standard_normal(7).astype(np.float32)}
            for _ in range(k)]


class TestFedavgBackendParity:
    @pytest.mark.parametrize("k,n", [(2, 300), (3, 1024), (8, 4096),
                                     (5, 16384 + 13)])
    def test_kernel_mirrors_numpy(self, k, n):
        rng = np.random.default_rng(k * 1000 + n)
        trees = _trees(rng, k, n)
        weights = (rng.random(k) * 2.0 + 0.1).tolist()
        a = agg.fedavg(trees, weights, backend="numpy")
        b = fedavg_ops.fedavg_trees(trees, weights)
        for key in a:
            np.testing.assert_allclose(a[key], np.asarray(b[key]),
                                       rtol=1e-6, atol=1e-6)

    def test_uniform_weights_default(self):
        rng = np.random.default_rng(0)
        trees = _trees(rng, 4, 512)
        a = agg.fedavg(trees, backend="numpy")
        b = agg.fedavg(trees, backend="kernel")
        for key in a:
            np.testing.assert_allclose(a[key], np.asarray(b[key]),
                                       rtol=1e-6, atol=1e-6)

    def test_backend_dispatch(self):
        rng = np.random.default_rng(1)
        trees = _trees(rng, 3, 256)
        # auto == kernel whenever jax imports (it does in this test).
        auto = agg.fedavg(trees, backend="auto")
        kern = agg.fedavg(trees, backend="kernel")
        for key in auto:
            np.testing.assert_array_equal(np.asarray(auto[key]),
                                          np.asarray(kern[key]))
        with pytest.raises(ValueError, match="backend"):
            agg.fedavg(trees, backend="gpu4000")

    def test_orchestrator_accepts_kernel_backend(self):
        from repro.core import FLConfig
        cfg = FLConfig(aggregation_backend="auto")
        assert cfg.aggregation_backend == "auto"
        with pytest.raises(ValueError, match="aggregation_backend"):
            FLConfig(aggregation_backend="nope")


class TestFedavgStackParity:
    """The batched path: ``fedavg_stack`` over a flat ``(K, P)`` stack.

    Two claims from its docstring, both load-bearing: the numpy stack path
    is **bit-identical** to the per-leaf tree fold (so the orchestrator's
    flat fast path cannot move a replay digest), and the kernel backend
    mirrors it to ~1 ULP (the same oracle contract as the tree path).
    """

    @pytest.mark.parametrize("k,n", [(2, 300), (3, 1024), (8, 4096),
                                     (5, 16384 + 13)])
    def test_stack_numpy_bitwise_equals_tree_numpy(self, k, n):
        from repro.core.packetizer import (flatten_to_vector,
                                           unflatten_from_vector)
        rng = np.random.default_rng(k * 31 + n)
        trees = _trees(rng, k, n)
        weights = (rng.random(k) * 2.0 + 0.1).tolist()
        tree_out = agg.fedavg(trees, weights, backend="numpy")
        stack = np.stack([flatten_to_vector(t) for t in trees])
        vec = agg.fedavg_stack(stack, weights, backend="numpy")
        rebuilt = unflatten_from_vector(vec, trees[0])
        for key in tree_out:
            np.testing.assert_array_equal(tree_out[key], rebuilt[key])

    @pytest.mark.parametrize("k,n", [(2, 256), (7, 4096), (16, 16384 + 5)])
    def test_kernel_mirrors_numpy_stack(self, k, n):
        rng = np.random.default_rng(k * 97 + n)
        stack = rng.standard_normal((k, n)).astype(np.float32)
        weights = (rng.random(k) + 0.05).tolist()
        a = agg.fedavg_stack(stack, weights, backend="numpy")
        b = agg.fedavg_stack(stack, weights, backend="kernel")
        np.testing.assert_allclose(a, b, rtol=1e-6, atol=1e-6)

    def test_auto_routes_to_kernel_and_validates(self):
        rng = np.random.default_rng(2)
        stack = rng.standard_normal((3, 512)).astype(np.float32)
        auto = agg.fedavg_stack(stack, backend="auto")
        kern = agg.fedavg_stack(stack, backend="kernel")
        np.testing.assert_array_equal(auto, kern)
        with pytest.raises(ValueError, match="backend"):
            agg.fedavg_stack(stack, backend="gpu4000")
        with pytest.raises(ValueError, match="stack"):
            agg.fedavg_stack(np.zeros((0, 8), np.float32))
        with pytest.raises(ValueError, match="stack"):
            agg.fedavg_stack(np.zeros(8, np.float32))

    def test_kernel_flat_direct(self):
        # fedavg_flat is the raw Pallas entry the stack path routes to;
        # K=1 must be the identity up to weight normalization.
        vec = np.linspace(-1, 1, 777, dtype=np.float32)
        out = np.asarray(fedavg_ops.fedavg_flat(vec[None], [3.0]))
        np.testing.assert_allclose(out, vec, rtol=1e-6, atol=1e-7)


class TestQuantizeOracleParity:
    """The compression docstring says quantize_int8 mirrors
    repro.kernels.quantize.ref — pinned here on shared random vectors."""

    @pytest.mark.parametrize("n,block", [(1024, 256), (4096, 1024),
                                         (1000, 256), (37, 16)])
    def test_quantize_matches_ref(self, n, block):
        rng = np.random.default_rng(n * 7 + block)
        vec = (rng.standard_normal(n) * 10).astype(np.float32)
        q_np, scales_np = quantize_int8(vec, block=block)

        nb = -(-n // block)
        padded = np.zeros(nb * block, dtype=np.float32)
        padded[:n] = vec
        q_ref, scales_ref = quantize_ref.quantize_blockwise(
            padded.reshape(nb, block))

        np.testing.assert_array_equal(q_np.reshape(nb, block),
                                      np.asarray(q_ref))
        np.testing.assert_array_equal(scales_np, np.asarray(scales_ref))

    def test_dequantize_matches_ref(self):
        rng = np.random.default_rng(5)
        n, block = 2048, 512
        vec = (rng.standard_normal(n) * 3).astype(np.float32)
        q, scales = quantize_int8(vec, block=block)
        out_np = dequantize_int8(q, scales, n, block=block)
        out_ref = np.asarray(quantize_ref.dequantize_blockwise(
            np.asarray(q).reshape(-1, block), np.asarray(scales))).reshape(-1)
        np.testing.assert_array_equal(out_np, out_ref[:n])

    def test_roundtrip_error_bounded_by_scale(self):
        rng = np.random.default_rng(6)
        vec = (rng.standard_normal(513) * 4).astype(np.float32)
        q, scales = quantize_int8(vec, block=128)
        out = dequantize_int8(q, scales, vec.size, block=128)
        err = np.abs(out - vec)
        per_block_bound = np.repeat(scales, 128)[:vec.size] * 0.5 + 1e-7
        assert np.all(err <= per_block_bound)
