"""Property-based invariants of the transport layer (hypothesis).

The MUDP contract: for ANY pattern of data-packet loss in which each sequence
number is droppable only finitely often, the receiver reconstructs the exact
byte stream and the sender terminates; if the link is effectively dead, the
sender fails after exactly Y=3 last-packet retries and never delivers a
corrupted payload.
"""

import dataclasses

import numpy as np
import pytest

pytest.importorskip("hypothesis")  # property tests need hypothesis
from hypothesis import given, settings, strategies as st

from repro.core.channel import BernoulliLoss, DropList, Link
from repro.core.compression import (HexCodec, Int8Codec, RawCodec, TopKCodec,
                                    dequantize_int8, quantize_int8)
from repro.core.mudp import MudpReceiver, MudpSender
from repro.core.packetizer import (flatten_to_vector, packetize, reassemble,
                                   unflatten_from_vector)
from repro.core.packets import Packet, checksum32
from repro.core.simulator import Simulator

C, S = "10.0.0.1", "10.0.0.2"


def _run(data: bytes, loss_model, mtu=156, timeout_ns=5_000_000_000):
    sim = Simulator()
    sim.connect(C, S, Link(1e7, 50_000_000, loss_model), Link(1e7, 50_000_000))
    pkts = packetize(data, C, txn=7, mtu=mtu)
    got, outcome = {}, {}
    MudpReceiver(sim, sim.node(S), nack_timeout_ns=timeout_ns,
                 on_deliver=lambda a, t, p: got.update(p))
    MudpSender(sim, sim.node(C), sim.node(S), pkts, timeout_ns=timeout_ns,
               on_complete=lambda s: outcome.update(ok=True),
               on_fail=lambda s: outcome.update(ok=False)).start()
    sim.run()
    return got, outcome, pkts


@settings(max_examples=60, deadline=None)
@given(
    data=st.binary(min_size=1, max_size=4096),
    drops=st.sets(st.tuples(st.integers(1, 40), st.integers(0, 2)),
                  max_size=30),
)
def test_any_finite_drop_pattern_delivers_exact_bytes(data, drops):
    got, outcome, pkts = _run(data, DropList(drops))
    # Droppable at most 3 attempts per seq (0..2) < sender+receiver retry
    # budget, so delivery is guaranteed.
    assert outcome["ok"] is True
    assert reassemble(got) == data


@settings(max_examples=25, deadline=None)
@given(data=st.binary(min_size=1, max_size=2048),
       p=st.floats(0.0, 0.4), seed=st.integers(0, 2**31))
def test_bernoulli_loss_delivery_or_clean_failure(data, p, seed):
    got, outcome, _ = _run(data, BernoulliLoss(p=p, seed=seed))
    if outcome["ok"]:
        assert reassemble(got) == data
    else:
        # Failure is only legal after exhausting the retry budget; the
        # receiver must never have delivered (no partial delivery).
        assert got == {} or reassemble(got) == data


@settings(max_examples=40, deadline=None)
@given(data=st.binary(min_size=0, max_size=8192),
       mtu=st.integers(60, 2000))
def test_packetize_reassemble_roundtrip(data, mtu):
    pkts = packetize(data, C, txn=1, mtu=mtu)
    assert pkts[0].total == len(pkts)
    assert all(p.seq == i + 1 for i, p in enumerate(pkts))
    assert reassemble({p.seq: p for p in pkts}) == data


@settings(max_examples=40, deadline=None)
@given(vec=st.lists(st.floats(-1e6, 1e6, allow_nan=False, width=32),
                    min_size=0, max_size=600))
def test_lossless_codecs_roundtrip(vec):
    v = np.asarray(vec, dtype=np.float32)
    for codec in (RawCodec(), HexCodec()):
        out = codec.decode(codec.encode(v))
        np.testing.assert_array_equal(out, v)


@settings(max_examples=40, deadline=None)
@given(vec=st.lists(st.floats(-100, 100, allow_nan=False, width=32),
                    min_size=1, max_size=3000),
       block=st.sampled_from([64, 256, 1024]))
def test_int8_quantization_error_bound(vec, block):
    v = np.asarray(vec, dtype=np.float32)
    q, scales = quantize_int8(v, block)
    out = dequantize_int8(q, scales, v.size, block)
    # absmax blockwise quantization: |err| <= scale/2 = absmax/254 per block
    nb = -(-v.size // block)
    padded = np.zeros(nb * block, np.float32)
    padded[:v.size] = v
    absmax = np.abs(padded.reshape(nb, block)).max(axis=1)
    bound = np.repeat(np.maximum(absmax, 1e-12) / 127.0, block)[:v.size]
    assert np.all(np.abs(out - v) <= 0.5 * bound + 1e-6)


@settings(max_examples=20, deadline=None)
@given(vec=st.lists(st.floats(-100, 100, allow_nan=False, width=32),
                    min_size=1, max_size=1000))
def test_int8_codec_wire_roundtrip(vec):
    v = np.asarray(vec, dtype=np.float32)
    codec = Int8Codec(block=128)
    out = codec.decode(codec.encode(v))
    assert out.shape == v.shape


@settings(max_examples=20, deadline=None)
@given(n=st.integers(1, 500), frac=st.floats(0.01, 1.0),
       seed=st.integers(0, 1000))
def test_topk_codec_keeps_largest(n, frac, seed):
    rng = np.random.default_rng(seed)
    v = rng.standard_normal(n).astype(np.float32)
    codec = TopKCodec(k_fraction=frac)
    out = codec.decode(codec.encode(v))
    k = max(1, int(n * frac))
    kept = np.flatnonzero(out)
    assert len(kept) <= k
    # every kept value is exact
    np.testing.assert_array_equal(out[kept], v[kept])
    # smallest kept magnitude >= largest dropped magnitude
    dropped = np.setdiff1d(np.arange(n), kept)
    if kept.size and dropped.size:
        assert np.abs(v[kept]).min() >= np.abs(v[dropped]).max() - 1e-6


@settings(max_examples=20, deadline=None)
@given(shapes=st.lists(
    st.tuples(st.integers(1, 5), st.integers(1, 5)), min_size=1, max_size=6),
    seed=st.integers(0, 100))
def test_pytree_vector_roundtrip(shapes, seed):
    rng = np.random.default_rng(seed)
    tree = {f"w{i}": rng.standard_normal(s).astype(np.float32)
            for i, s in enumerate(shapes)}
    vec = flatten_to_vector(tree)
    back = unflatten_from_vector(vec, tree)
    for k in tree:
        np.testing.assert_array_equal(back[k], tree[k])


def test_corrupted_payload_is_rejected():
    data = b"x" * 500
    pkts = packetize(data, C, txn=0, mtu=156)
    bad = dataclasses.replace(pkts[1], payload=b"y" * len(pkts[1].payload))
    assert not bad.verify()
    # The receiver treats a checksum failure as loss -> NACK path recovers.
    sim = Simulator()

    class CorruptSecondOnce:
        done = False
        def drops(self, pkt):
            return False

    sim.connect(C, S, Link(1e7, 1_000_000), Link(1e7, 1_000_000))
    got = {}
    MudpReceiver(sim, sim.node(S), nack_timeout_ns=1_000_000_000,
                 on_deliver=lambda a, t, p: got.update(p))
    outcome = {}
    sender = MudpSender(sim, sim.node(C), sim.node(S), pkts,
                        timeout_ns=1_000_000_000,
                        on_complete=lambda s: outcome.update(ok=True))
    # Corrupt the stored copy for the first transmission only: emulate by
    # sending the bad packet manually before starting (receiver drops it).
    sim.node(C).send(bad, sim.node(S))
    sender.start()
    sim.run()
    assert outcome["ok"] is True
    assert reassemble(got) == data


def test_packet_wire_codec_roundtrip():
    p = Packet.from_bytes(
        packetize(b"hello world", "10.1.2.4", txn=3, mtu=100)[0].to_bytes())
    assert p.payload == b"hello world"
    assert p.addr == "10.1.2.4"
    assert p.txn == 3
    assert p.verify()
