"""Checkpoint layer: container round-trips, retention, journal replay.

The crash-restart story is snapshot (CheckpointManager) + journal
(FLJournal): the journal says which round to resume and which clients were
mid-flight, the checkpoint holds the model those facts refer to.  The
integration test at the bottom drives a real FederatedSystem through a
simulated crash and verifies the restarted run resumes from the journaled
round with bit-identical params.
"""

import os

import numpy as np
import pytest

from repro.checkpoint import (CheckpointManager, FLJournal, load_pytree,
                              save_pytree)
from repro.checkpoint.checkpointer import _CODEC_ZLIB, _compress, _decompress


def tree_equal(a, b) -> bool:
    if isinstance(a, dict):
        return (set(a) == set(b)
                and all(tree_equal(a[k], b[k]) for k in a))
    return (np.asarray(a).dtype == np.asarray(b).dtype
            and np.array_equal(np.asarray(a), np.asarray(b)))


@pytest.fixture
def tree():
    return {
        "layer0": {"w": np.arange(12, dtype=np.float32).reshape(3, 4),
                   "b": np.full((4,), -1.5, dtype=np.float32)},
        "head": np.arange(7, dtype=np.int32),
    }


# --------------------------------------------------------------------------
# Container round-trips
# --------------------------------------------------------------------------
def test_roundtrip_without_template(tmp_path, tree):
    p = str(tmp_path / "a.ckpt")
    save_pytree(p, tree, {"round": 5, "note": "x"})
    out, meta = load_pytree(p)
    assert meta == {"round": 5, "note": "x"}
    assert tree_equal(out, tree)


def test_roundtrip_with_template_preserves_structure(tmp_path, tree):
    p = str(tmp_path / "a.ckpt")
    save_pytree(p, tree)
    out, meta = load_pytree(p, template=tree)
    assert meta == {}
    assert tree_equal(out, tree)


def test_template_shape_mismatch_raises(tmp_path, tree):
    p = str(tmp_path / "a.ckpt")
    save_pytree(p, tree)
    bad = {**tree, "head": np.zeros(9, np.int32)}
    with pytest.raises(ValueError, match="shape"):
        load_pytree(p, template=bad)


def test_template_missing_leaf_raises(tmp_path, tree):
    p = str(tmp_path / "a.ckpt")
    save_pytree(p, tree)
    bigger = {**tree, "extra": np.zeros(2, np.float32)}
    with pytest.raises(KeyError, match="extra"):
        load_pytree(p, template=bigger)


def test_not_a_checkpoint_raises(tmp_path):
    p = str(tmp_path / "junk.ckpt")
    with open(p, "wb") as f:
        f.write(b"definitely not a checkpoint")
    with pytest.raises(ValueError, match="magic|truncated"):
        load_pytree(p)


def test_atomic_write_leaves_no_tmp(tmp_path, tree):
    p = str(tmp_path / "a.ckpt")
    save_pytree(p, tree)
    assert not os.path.exists(p + ".tmp")


def test_zlib_codec_always_roundtrips():
    raw = np.arange(1000, dtype=np.float32).tobytes()
    assert _decompress(_CODEC_ZLIB, _compress(_CODEC_ZLIB, raw)) == raw


# --------------------------------------------------------------------------
# Manager: step indexing + retention
# --------------------------------------------------------------------------
def test_manager_retention_and_latest(tmp_path, tree):
    m = CheckpointManager(str(tmp_path / "ckpts"), keep=2)
    for step in (1, 2, 3, 4):
        m.save(step, tree, {"x": step})
    assert m.steps() == [3, 4]
    assert m.latest_step() == 4
    out, meta = m.restore(tree)
    assert meta["step"] == 4 and meta["x"] == 4
    assert tree_equal(out, tree)
    out3, meta3 = m.restore(tree, step=3)
    assert meta3["step"] == 3


def test_manager_empty_dir_raises(tmp_path, tree):
    m = CheckpointManager(str(tmp_path / "empty"))
    with pytest.raises(FileNotFoundError):
        m.restore(tree)


# --------------------------------------------------------------------------
# Journal: replay bookkeeping
# --------------------------------------------------------------------------
def test_journal_resume_and_pending(tmp_path):
    j = FLJournal(str(tmp_path / "j.log"))
    assert j.resume_round() == 0 and j.pending_clients() == []
    j.round_started(0, ["a", "b", "c"])
    j.update_ingested(0, "a")
    j.round_finalized(0, "ckpt_0", arrived=["a"], failed=["b", "c"])
    j.round_started(1, ["a", "b"])
    j.update_ingested(1, "b")
    # Crash here: round 1 never finalized.
    j2 = FLJournal(str(tmp_path / "j.log"))   # reload from disk
    assert j2.last_finalized_round() == 0
    assert j2.last_checkpoint() == "ckpt_0"
    assert j2.resume_round() == 1
    assert j2.pending_clients() == ["a"]      # b already ingested


# --------------------------------------------------------------------------
# Integration: snapshot/restore + journal replay over a real system
# --------------------------------------------------------------------------
def _digest(params) -> bytes:
    return np.asarray(params["w"], np.float32).tobytes()


def test_crash_restart_resumes_bitwise(tmp_path):
    from repro.core.fleet import (ConsensusObjective, FleetConfig,
                                  build_fleet)
    from repro.core.rounds import FLConfig, TransportConfig

    def fresh():
        obj = ConsensusObjective(8, 32, seed=11)
        fleet = FleetConfig(n_clients=8, seed=5)
        return obj, build_fleet(
            fleet, obj.init_params(), lambda i, p: obj.train_fn(i, p),
            FLConfig(transport=TransportConfig(kind="mudp")))

    mgr = CheckpointManager(str(tmp_path / "ckpts"), keep=3)
    journal = FLJournal(str(tmp_path / "journal.log"))

    # First life: run 3 rounds, snapshot + journal each finalize.
    obj, (sim, system, profiles) = fresh()
    for r in range(3):
        journal.round_started(r, sorted(p.addr for p in profiles))
        result = system.run_round(r)
        path = mgr.save(r, system.global_params, {"loss":
                                                  obj.loss(system.global_params)})
        journal.round_finalized(r, path, arrived=result.arrived,
                                failed=result.failed)
    want = _digest(system.global_params)

    # Crash + restart: a brand-new process recovers its position from the
    # journal and its model from the checkpoint, then replays the rest.
    journal2 = FLJournal(str(tmp_path / "journal.log"))
    assert journal2.resume_round() == 3
    restored, meta = mgr.restore({"w": np.zeros(32, np.float32)})
    assert meta["step"] == 2
    assert _digest(restored) == want

    # The restored model continues exactly like the uninterrupted run: an
    # identical fresh system fast-forwarded to the same round from the
    # checkpoint produces the same round-3 result (determinism end to end).
    obj_a, (sim_a, sys_a, _) = fresh()
    sys_a.run_rounds(3)
    r_a = sys_a.run_round(3)
    obj_b, (sim_b, sys_b, _) = fresh()
    sys_b.run_rounds(3)
    sys_b.global_params = restored            # checkpoint swap-in
    r_b = sys_b.run_round(3)
    assert _digest(sys_a.global_params) == _digest(sys_b.global_params)
    assert r_a.arrived == r_b.arrived
