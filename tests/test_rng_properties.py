"""Property tests for the counter-based keyed RNG (splitmix64).

Every stochastic decision in all three engines funnels through
``repro.core.channel``'s keyed draws, so three properties carry the whole
determinism story:

* **scalar == vector** — ``keyed_uniform`` (python-int chain) and
  ``keyed_uniforms`` (uint64 wrap-around chain) produce bit-identical
  values for the same key, for *any* key material, not just the pinned
  engine-equivalence scenarios; ``flow_uniform`` is the same chain on raw
  integers.
* **uniformity** — per-key draws fill [0, 1) evenly for any (stream,
  seed) the caller picks.
* **lane independence** — draws are decorrelated across counter lanes and
  distinct keys never alias in practice.

Hypothesis drives the key material; the module skips cleanly where
hypothesis isn't installed (it is pinned in requirements-ci.txt).
"""

import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")

from hypothesis import given, settings, strategies as st   # noqa: E402

from repro.core.channel import (FLOW_STREAM, JITTER_STREAM,  # noqa: E402
                                LOSS_STREAM, flow_uniform, keyed_uniform,
                                keyed_uniforms)
from repro.core.packets import Packet, PacketKind           # noqa: E402

U64 = st.integers(min_value=0, max_value=(1 << 64) - 1)
KINDS = st.sampled_from(list(PacketKind))
STREAMS = st.sampled_from([LOSS_STREAM, JITTER_STREAM, FLOW_STREAM])


def _vec(stream, seed, keys):
    cols = [np.asarray(c, np.uint64) for c in zip(*keys)]
    return keyed_uniforms(stream, seed, *cols)


# --------------------------------------------------------------------------
# scalar == vector, bit for bit, on arbitrary key material
# --------------------------------------------------------------------------
@given(stream=STREAMS, seed=U64,
       keys=st.lists(st.tuples(U64, KINDS, U64, U64), min_size=1,
                     max_size=32))
def test_scalar_matches_vector(stream, seed, keys):
    keys = [(t, int(k), s, a) for t, k, s, a in keys]
    vec = _vec(stream, seed, keys)
    for i, (txn, kind, seq, attempt) in enumerate(keys):
        pkt = Packet(PacketKind(kind), seq, seq + 1, "10.0.0.1", txn,
                     b"", 0, attempt=attempt)
        assert keyed_uniform(stream, seed, pkt) == vec[i]
        # flow_uniform is the identical chain on raw ints.
        assert flow_uniform(stream, seed, txn, kind, seq,
                            attempt) == vec[i]


@given(stream=STREAMS, seed=U64, txn=U64, kind=KINDS, seq=U64, attempt=U64)
def test_draw_is_in_unit_interval(stream, seed, txn, kind, seq, attempt):
    u = flow_uniform(stream, seed, txn, int(kind), seq, attempt)
    assert 0.0 <= u < 1.0


# --------------------------------------------------------------------------
# uniformity over the counter lane, for any (stream, seed)
# --------------------------------------------------------------------------
@settings(max_examples=25, deadline=None)
@given(stream=STREAMS, seed=U64, txn=U64)
def test_counter_stream_is_uniform(stream, seed, txn):
    n = 2048
    keys = [(txn, 0, c, 0) for c in range(n)]
    u = _vec(stream, seed, keys)
    # ~8 sigma bands: hypothesis tries many seeds, so the per-example
    # false-positive rate has to be negligible.
    assert abs(u.mean() - 0.5) < 0.05
    hist, _ = np.histogram(u, bins=8, range=(0.0, 1.0))
    assert hist.min() > 150          # expected 256 per octile
    assert len(np.unique(u)) == n    # 53-bit draws: no aliasing


# --------------------------------------------------------------------------
# lane independence
# --------------------------------------------------------------------------
@settings(max_examples=25, deadline=None)
@given(seed=U64, txn=U64)
def test_adjacent_counter_lanes_uncorrelated(seed, txn):
    n = 1024
    a = _vec(FLOW_STREAM, seed, [(txn, 0, i, 0) for i in range(n)])
    b = _vec(FLOW_STREAM, seed, [(txn, 0, i + 1, 0) for i in range(n)])
    c = _vec(FLOW_STREAM, seed, [(txn, 1, i, 0) for i in range(n)])
    # Shifting the counter or touching another lane yields a stream that
    # is (a) nowhere equal and (b) statistically uncorrelated (~5 sigma).
    assert not np.any(a == b) and not np.any(a == c)
    assert abs(np.corrcoef(a, b)[0, 1]) < 0.16
    assert abs(np.corrcoef(a, c)[0, 1]) < 0.16


@given(seed=U64, key=st.tuples(U64, KINDS, U64, U64))
def test_any_single_lane_change_changes_the_draw(seed, key):
    txn, kind, seq, attempt = (key[0], int(key[1]), key[2], key[3])
    base = flow_uniform(FLOW_STREAM, seed, txn, kind, seq, attempt)
    for variant in ((txn + 1, kind, seq, attempt),
                    (txn, kind + 1, seq, attempt),
                    (txn, kind, seq + 1, attempt),
                    (txn, kind, seq, attempt + 1)):
        assert flow_uniform(FLOW_STREAM, seed, *variant) != base
    assert flow_uniform(LOSS_STREAM, seed, txn, kind, seq, attempt) != base
