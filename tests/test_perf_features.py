"""Tests for the §Perf optimizations: they must be semantically equivalent
to the baselines they replace (or have documented, bounded deviations)."""

import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, smoke_variant
from repro.models import transformer as T
from repro.models.xlstm import mlstm_chunked, mlstm_parallel


class TestCapacityGroupedMoe:
    def _setup(self):
        cfg = smoke_variant(get_config("qwen3-moe-235b-a22b"))
        rng = jax.random.PRNGKey(0)
        params = T.init_decoder(cfg, rng)
        tokens = jax.random.randint(rng, (2, 16), 0, cfg.vocab_size)
        return cfg, params, {"tokens": tokens, "labels": tokens}

    def test_loss_matches_scan_baseline_without_drops(self, monkeypatch):
        monkeypatch.setattr(T, "MOE_CAPACITY_FACTOR", 1000.0)
        cfg, params, batch = self._setup()
        l_scan = float(T.decoder_loss(cfg, params, batch, moe_impl="scan",
                                      remat_policy="none"))
        l_grp = float(T.decoder_loss(cfg, params, batch, moe_impl="ragged",
                                     remat_policy="none"))
        np.testing.assert_allclose(l_scan, l_grp, rtol=1e-5)

    def test_grads_match_scan_baseline(self, monkeypatch):
        monkeypatch.setattr(T, "MOE_CAPACITY_FACTOR", 1000.0)
        cfg, params, batch = self._setup()
        g1 = jax.grad(lambda p: T.decoder_loss(
            cfg, p, batch, moe_impl="scan", remat_policy="none"))(params)
        g2 = jax.grad(lambda p: T.decoder_loss(
            cfg, p, batch, moe_impl="ragged", remat_policy="none"))(params)
        for k in ("we_gate", "we_up", "we_down", "router", "wq"):
            np.testing.assert_allclose(
                np.asarray(g1["layers"][k]), np.asarray(g2["layers"][k]),
                rtol=1e-4, atol=1e-6)

    def test_capacity_drops_are_bounded(self, monkeypatch):
        """At cf=2 with a random router, dropped mass is small: outputs stay
        close to the dropless result."""
        cfg, params, batch = self._setup()
        monkeypatch.setattr(T, "MOE_CAPACITY_FACTOR", 1000.0)
        full = float(T.decoder_loss(cfg, params, batch, moe_impl="ragged",
                                    remat_policy="none"))
        monkeypatch.setattr(T, "MOE_CAPACITY_FACTOR", 2.0)
        capped = float(T.decoder_loss(cfg, params, batch, moe_impl="ragged",
                                      remat_policy="none"))
        assert abs(full - capped) < 0.05


class TestChunkedMlstm:
    @pytest.mark.parametrize("S,chunk", [(2048, 512), (4096, 1024)])
    def test_matches_parallel(self, S, chunk):
        rng = np.random.default_rng(S)
        B, nh, dh = 2, 2, 32
        mk = lambda *s: jnp.asarray(rng.standard_normal(s), jnp.float32)
        q, k, v = mk(B, S, nh, dh), mk(B, S, nh, dh), mk(B, S, nh, dh)
        ig, fg = mk(B, S, nh), mk(B, S, nh) + 1.0
        a = mlstm_parallel(q, k, v, ig, fg)
        b = mlstm_chunked(q, k, v, ig, fg, chunk=chunk)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=5e-4, atol=5e-4)

    def test_short_sequences_fall_back(self):
        rng = np.random.default_rng(0)
        mk = lambda *s: jnp.asarray(rng.standard_normal(s), jnp.float32)
        q = mk(1, 64, 2, 16)
        out = mlstm_chunked(q, q, q, mk(1, 64, 2), mk(1, 64, 2))
        assert out.shape == (1, 64, 2, 16)


class TestXlstmPrefillStateHandoff:
    def test_prefill_state_continues_decode_consistently(self):
        """prefill(prompt) then decode(next) == stepping decode through
        prompt+next (the closed-form final-state extraction is exact)."""
        from repro.models import xlstm as X
        cfg = smoke_variant(get_config("xlstm-350m"))
        rng = jax.random.PRNGKey(1)
        params = X.init_xlstm(cfg, rng)
        B, P = 2, 8
        tokens = jax.random.randint(rng, (B, P + 1), 0, cfg.vocab_size)
        lg_p, state = X.xlstm_prefill(cfg, params, tokens[:, :P])
        lg1, _ = X.xlstm_decode(cfg, params, state, tokens[:, P:P + 1])
        # reference: step everything through decode
        st = X.init_xlstm_state(cfg, B)
        for t in range(P + 1):
            lg2, st = X.xlstm_decode(cfg, params, st, tokens[:, t:t + 1])
        np.testing.assert_allclose(np.asarray(lg1), np.asarray(lg2),
                                   rtol=2e-3, atol=2e-3)
        # and the prefill's last-token logits match the P-th decode step
        np.testing.assert_allclose(np.asarray(lg_p), np.asarray(lg2 * 0
                                   + lg_p), rtol=1e-5)


class TestFlMeshAggregation:
    def test_exact_pod_aggregation_small_mesh(self):
        """Paper Eq. 1 over the pod axis on a (2,2,2) debug mesh in a
        subprocess with 8 fake devices: every pod ends with the mean."""
        code = textwrap.dedent("""
            import os
            os.environ['XLA_FLAGS'] = '--xla_force_host_platform_device_count=8'
            import jax, jax.numpy as jnp, numpy as np
            from repro.distributed import fl_mesh as F
            from repro.distributed import sharding as sh
            mesh = jax.make_mesh((2,2,2), ('pod','data','model'))
            rules = dict(sh.TRAIN_RULES); rules['fl_pod']='pod'
            with sh.use_mesh(mesh, rules):
                x = {'w': jnp.stack([jnp.full((4,8), 1.0),
                                     jnp.full((4,8), 3.0)])}
                specs = F.stacked_specs({'w': ('w_data', None)})
                sh_tree = sh.tree_shardings(specs)
                agg = F.make_fl_aggregate(mesh, mode='exact')
                out = jax.jit(agg, in_shardings=(sh_tree,),
                              out_shardings=sh_tree)(x)
                np.testing.assert_allclose(np.asarray(out['w']), 2.0)
            print('OK')
        """)
        r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                           text=True, env={"PYTHONPATH": "src",
                                           "PATH": "/usr/bin:/bin"})
        assert "OK" in r.stdout, r.stderr[-2000:]


class TestOneHotPaths:
    def test_embed_one_hot_equals_gather(self):
        """The mesh-mode one-hot embedding must equal the gather path."""
        from repro.models import layers as L
        from repro.distributed import sharding as sh
        rng = np.random.default_rng(0)
        embed = jnp.asarray(rng.standard_normal((64, 16)), jnp.float32)
        tokens = jnp.asarray(rng.integers(0, 64, (2, 8)), jnp.int32)
        ref = L.embed_tokens(embed, tokens)

        import unittest.mock as um
        with um.patch.object(sh, "active_mesh", return_value=object()), \
             um.patch.object(L, "constraint", side_effect=lambda x, *a: x):
            out = L.embed_tokens(embed, tokens)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=1e-6)

    def test_gold_logit_one_hot_equals_take(self):
        from repro.models.layers import _gold_logit
        from repro.distributed import sharding as sh
        rng = np.random.default_rng(1)
        logits = jnp.asarray(rng.standard_normal((2, 8, 32)), jnp.float32)
        labels = jnp.asarray(rng.integers(0, 32, (2, 8)), jnp.int32)
        ref = _gold_logit(logits, labels)

        class FakeMesh:
            axis_names = ()
        sh._STATE.mesh = FakeMesh()
        try:
            out = _gold_logit(logits, labels)
        finally:
            sh._STATE.mesh = None
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=1e-6)
