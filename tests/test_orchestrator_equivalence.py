"""Bit-identity pin for the event-driven orchestrator refactor.

``FederatedSystem(mode="sync")`` must be **bit-compatible** with the
pre-refactor round-barrier loop: same seeds, same transports, same
engines -> byte-identical round results, wire stats, and global model.
The SHA-256 digests below were captured from the pre-refactor
``FederatedSystem.run_round`` (commit cf53848) over a scenario matrix
chosen to exercise every code path the refactor moved:

* ``basic``      — 3 heterogeneous train fns, clean links, 2 rounds;
* ``lossy``      — Bernoulli loss + per-packet jitter (retransmissions,
                   NACK volleys, FEC repair, zero-filled UDP gaps);
* ``deadline``   — a 5 s straggler against a 2 s round deadline (cutoff,
                   late-update staleness buffer, discounted fold);
* ``partial``    — 6 clients at participation_fraction=0.5 (seeded
                   Fisher-Yates roster draws);
* ``codec``      — pairwise Eq.-1 aggregation over the paper's hex codec
                   plus delta shipping with error-feedback int8;
* ``failure``    — a dead uplink (DropList everything) driving retry
                   exhaustion, health benching, and re-admission.

Every scenario runs for every registered transport under both simulator
engines and must reproduce the pinned digest exactly.  All inputs are
deterministic by construction (linspace params, arithmetic train fns,
keyed splitmix64 link draws, Random.random()-only participation draws),
so these digests are stable across platforms and Python versions.

Regenerate (only legitimate after an *intentional* behavior change):

  PYTHONPATH=src python tests/test_orchestrator_equivalence.py --print
"""

from __future__ import annotations

import dataclasses
import hashlib

import numpy as np
import pytest

from repro.core.channel import (BernoulliLoss, DropList, GilbertElliott, Link,
                                NoLoss)
from repro.core.rounds import FederatedSystem, FLClient, FLConfig
from repro.core.simulator import PACKET_ENGINES, Simulator
from repro.core.transport import TransportConfig, available_transports

SERVER = "10.1.2.5"
NS = 1_000_000_000


# --------------------------------------------------------------------------
# Deterministic building blocks (no sequential RNG anywhere)
# --------------------------------------------------------------------------
def make_params(n: int = 301):
    return {"w": np.linspace(-1.0, 1.0, n, dtype=np.float32),
            "b": np.zeros((7,), dtype=np.float32)}


def add_train_fn(delta):
    def fn(params, round_idx, client):
        return ({k: v + np.float32(delta) for k, v in params.items()},
                {"loss": 0.0})
    return fn


def const_train_fn(value):
    def fn(params, round_idx, client):
        return ({k: np.full_like(v, value) for k, v in params.items()}, {})
    return fn


def _connect(sim, addr, *, loss=None, jitter_ns=0, seed=0):
    up = Link(1e8, 1_000_000, loss or NoLoss(),
              jitter_ns=jitter_ns, jitter_seed=seed)
    down = Link(1e8, 1_000_000, NoLoss(),
                jitter_ns=jitter_ns, jitter_seed=seed + 1)
    sim.connect(addr, SERVER, up, down)


# --------------------------------------------------------------------------
# Scenario matrix
# --------------------------------------------------------------------------
def _basic(sim, kind):
    clients = []
    for i in range(3):
        addr = f"10.1.2.{10 + i}"
        _connect(sim, addr)
        fn = const_train_fn(2.0) if i == 2 else add_train_fn(0.1 * (i + 1))
        clients.append(FLClient(addr, fn, train_time_ns=1_000_000 * (i + 1)))
    cfg = FLConfig(aggregation="fedavg",
                   transport=TransportConfig(kind=kind, timeout_ns=NS))
    return clients, cfg, 2


def _lossy(sim, kind):
    clients = []
    for i in range(3):
        addr = f"10.1.2.{10 + i}"
        loss = (GilbertElliott(p_good_loss=0.02, p_bad_loss=0.5,
                               p_bad=0.1, seed=40 + i) if i == 2
                else BernoulliLoss(p=0.15, seed=30 + i))
        _connect(sim, addr, loss=loss, jitter_ns=500_000, seed=7 * i)
        clients.append(FLClient(addr, add_train_fn(0.5),
                                train_time_ns=2_000_000))
    cfg = FLConfig(aggregation="fedavg",
                   transport=TransportConfig(kind=kind, timeout_ns=NS,
                                             udp_deadline_ns=2 * NS))
    return clients, cfg, 2


def _deadline(sim, kind):
    clients = []
    for i, tt in enumerate((1_000_000, 5 * NS)):
        addr = f"10.1.2.{10 + i}"
        _connect(sim, addr)
        clients.append(FLClient(addr, const_train_fn(float(i + 1)),
                                train_time_ns=tt))
    cfg = FLConfig(aggregation="fedavg", round_deadline_ns=2 * NS,
                   staleness_discount=0.5,
                   transport=TransportConfig(kind=kind, timeout_ns=NS))
    return clients, cfg, 2


def _partial(sim, kind):
    clients = []
    for i in range(6):
        addr = f"10.1.2.{10 + i}"
        _connect(sim, addr)
        clients.append(FLClient(addr, add_train_fn(0.25),
                                train_time_ns=1_000_000 + 250_000 * i))
        clients[-1].weight = 1.0 + 0.5 * i
    cfg = FLConfig(aggregation="fedavg", participation_fraction=0.5,
                   participation_seed=13,
                   transport=TransportConfig(kind=kind, timeout_ns=NS))
    return clients, cfg, 2


def _codec(sim, kind):
    clients = []
    for i in range(2):
        addr = f"10.1.2.{10 + i}"
        _connect(sim, addr)
        clients.append(FLClient(addr, add_train_fn(0.3 * (i + 1)),
                                train_time_ns=1_000_000))
    cfg = FLConfig(aggregation="pairwise", send_deltas=True,
                   error_feedback=True,
                   transport=TransportConfig(kind=kind, codec="int8",
                                             timeout_ns=NS,
                                             udp_deadline_ns=2 * NS))
    return clients, cfg, 2


def _failure(sim, kind):
    dead = {(s, a) for s in range(1, 4000) for a in range(0, 60)}
    clients = []
    for i in range(2):
        addr = f"10.1.2.{10 + i}"
        _connect(sim, addr, loss=DropList(dead) if i == 1 else None)
        clients.append(FLClient(addr, const_train_fn(float(i + 1)),
                                train_time_ns=1_000_000))
    cfg = FLConfig(aggregation="fedavg", unhealthy_after_failures=1,
                   readmit_after_rounds=2,
                   transport=TransportConfig(kind=kind,
                                             timeout_ns=500_000_000,
                                             udp_deadline_ns=NS))
    return clients, cfg, 3


SCENARIOS = {
    "basic": _basic,
    "lossy": _lossy,
    "deadline": _deadline,
    "partial": _partial,
    "codec": _codec,
    "failure": _failure,
}

# RoundResult fields pinned by the digest — exactly the pre-refactor field
# set, so fields *added* by the refactor (staleness accounting etc.) extend
# the record without invalidating the pin.
_PINNED_FIELDS = ("round_idx", "duration_ns", "arrived", "failed",
                  "skipped_unhealthy", "late_folded", "bytes_sent",
                  "packets_sent", "packets_dropped", "retransmissions",
                  "metrics", "roster", "data_packets", "nack_packets",
                  "parity_packets")


def run_digest(scenario: str, kind: str, engine: str, **cfg_extra) -> str:
    sim = Simulator(engine=engine)
    clients, cfg, rounds = SCENARIOS[scenario](sim, kind)
    if cfg_extra:
        cfg = dataclasses.replace(cfg, **cfg_extra)
    system = FederatedSystem(sim, SERVER, clients, make_params(), cfg)
    h = hashlib.sha256()
    for _ in range(rounds):
        res = system.run_round()
        row = {f: getattr(res, f) for f in _PINNED_FIELDS}
        h.update(repr(sorted(row.items())).encode())
    for key in sorted(system.global_params):
        leaf = np.ascontiguousarray(system.global_params[key], dtype="<f4")
        h.update(key.encode())
        h.update(leaf.tobytes())
    h.update(sim.stats_digest().encode())
    return h.hexdigest()


# --------------------------------------------------------------------------
# Pinned digests: {(scenario, transport): sha256}.  Captured from the
# pre-refactor FederatedSystem; identical under both engines by the PR-3
# engine-equivalence guarantee.
# --------------------------------------------------------------------------
EXPECTED: dict[tuple[str, str], str] = {
    ("basic", "mudp"):
        "89fb6e2b9edf5fe600538b45bd46e97f7ba4d8a495c41639de4857ec508a0645",
    ("basic", "mudp+fec"):
        "8fe3d920b5e83b2eaa777965b696d98042a649e2458c291ffc662bb7afdf04e0",
    ("basic", "tcp"):
        "85f45a84dcbd9994148adc32d544eaadd4d6d82f88bc78e7350278a98117ad4b",
    ("basic", "udp"):
        "d5f0e0c624bbfab4d717c8bf5741607ad65547e11298a460deb9fdac80399624",
    ("lossy", "mudp"):
        "ce626ed312762297ea3ae48d965a2b1ac9bc3191fd6d41c3a91a78954cbcf59a",
    ("lossy", "mudp+fec"):
        "081d2185673fa48eb2960c49f72f25bff3fb92e89ce6b167ff2cece330816c48",
    ("lossy", "tcp"):
        "54a38adf5b567a7bc4c2ed00aa315fba72a04a76ef699fec580a5116a396b0d1",
    ("lossy", "udp"):
        "fdd7d3197493395cf8afe45ea67f636224a55eca99f4d18cbe67a51d4274786e",
    ("deadline", "mudp"):
        "74508036fd32fd10ebe66dbd10f3ca47a8ff6315e8f76e89f6f5c387f1f96fec",
    ("deadline", "mudp+fec"):
        "df5f0193035e8ccdfb86b544f4ad167f29c5cc2a9897b3d8ed3797920a9e21d7",
    ("deadline", "tcp"):
        "3b8fddb1777cc101ce1cc9a503d72f08d6d1e9045e0c835d78e1978d4d95464e",
    ("deadline", "udp"):
        "3ff41d0b24d722881c2cc2b55bb84fcb3644b6bb402a6867f1cbdfb9e822d310",
    ("partial", "mudp"):
        "d3e2cc4914b80afa0d27fc5922baa0408c3692fb5b15c4f30f9634b5f01df53e",
    ("partial", "mudp+fec"):
        "a0e28541021f3cb6d7a3162919decb880a35d90e8e2ae7bcac36fc230c628304",
    ("partial", "tcp"):
        "297a8a4669e84b7161c5c65eb9bcc99084597cb8a96021179f3ca5e6a01d81eb",
    ("partial", "udp"):
        "73d2ccefc6a5a2eb9167902e32bc29bb04c69964603ada62b4d04bf17236c9c8",
    ("codec", "mudp"):
        "1216a1a977f05185bc59437bbaa76fd0824516fae11d4031e7a0a24708fb8068",
    ("codec", "mudp+fec"):
        "04143c9931b554b1444ab79673f6b8ff86a96f0f44574f2ec8bea654a39669d2",
    ("codec", "tcp"):
        "615a63883ab4c11ce7ce761ac5fd76794fcc7c6392304b2b6448de31a7a5e21d",
    ("codec", "udp"):
        "a29e894de49e248aa9329a877745d3cbd342e33de335fac17156bfc9cc8052a1",
    ("failure", "mudp"):
        "362bf8ac844d8f80da997b17d0f308e5e9942891b6d212ab45fd6f059e43cfb5",
    ("failure", "mudp+fec"):
        "86793be1501a6f601cc9be8812aaa166f62cbb529e75aa42c65fcf168fe2678e",
    ("failure", "tcp"):
        "7e2520d085e7a61507e2de9fbf475fd293de6a393a3cbf42e1cabe447f91a9f5",
    ("failure", "udp"):
        "f3a82d5bcca04a3a2cad7e069d0c150c2cae4d6fd219e391d51393ab923eb615",
}


def _matrix():
    # Packet engines only: the flow engine is statistically, not bit,
    # equivalent — its contract is gated by tests/test_flow_engine.py.
    for (scenario, kind), digest in sorted(EXPECTED.items()):
        for engine in PACKET_ENGINES:
            yield scenario, kind, engine, digest


@pytest.mark.parametrize("scenario,kind,engine,digest",
                         list(_matrix()),
                         ids=lambda v: str(v)[:16])
def test_sync_mode_bit_identical_to_pre_refactor(scenario, kind, engine,
                                                 digest):
    assert run_digest(scenario, kind, engine) == digest


def test_every_registered_transport_is_pinned():
    pinned = {k for _, k in EXPECTED}
    assert pinned == set(available_transports())


def main() -> None:
    print("EXPECTED: dict[tuple[str, str], str] = {")
    for scenario in SCENARIOS:
        for kind in available_transports():
            d = run_digest(scenario, kind, "per_packet")
            d2 = run_digest(scenario, kind, "batched")
            assert d == d2, (scenario, kind, "engine divergence!")
            print(f'    ("{scenario}", "{kind}"):\n        "{d}",')
    print("}")


if __name__ == "__main__":
    import sys
    if "--print" in sys.argv:
        main()
