# One function per paper table. Print ``name,us_per_call,derived`` CSV.
"""Benchmark harness: one module per paper table/figure (+ roofline).

  PYTHONPATH=src python -m benchmarks.run              # all
  PYTHONPATH=src python -m benchmarks.run --only codecs
"""

from __future__ import annotations

import argparse
import sys
import time

from benchmarks import (adaptive_bench, aggregation, async_vs_sync, codecs,
                        fl_convergence, fleet_scale, kernels_bench, roofline,
                        simcore, topology_bench, transport_comparison,
                        transport_scenarios, vmap_train, wire_bench)

SUITES = {
    "simcore": simcore,
    "transport_scenarios": transport_scenarios,
    "transport_comparison": transport_comparison,
    "fleet_scale": fleet_scale,
    "topology": topology_bench,
    "async_vs_sync": async_vs_sync,
    "adaptive": adaptive_bench,
    "fl_convergence": fl_convergence,
    "codecs": codecs,
    "wire": wire_bench,
    "aggregation": aggregation,
    "kernels": kernels_bench,
    "roofline": roofline,
    "vmap_train": vmap_train,
}


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--only", default=None, choices=list(SUITES))
    args = ap.parse_args()
    suites = {args.only: SUITES[args.only]} if args.only else SUITES
    print("name,us_per_call,derived")
    for name, mod in suites.items():
        t0 = time.perf_counter()
        try:
            for row, us, derived in mod.bench():
                print(f"{row},{us:.1f},{derived}", flush=True)
        except Exception as e:  # noqa: BLE001 - a suite failure is a row
            print(f"{name}/SUITE_ERROR,0.0,{type(e).__name__}:{e}")
        print(f"{name}/suite_wall,"
              f"{(time.perf_counter()-t0)*1e6:.0f},complete", flush=True)


if __name__ == "__main__":
    main()
