"""Benchmark 2 — TCP vs UDP vs Modified UDP (the paper's future-work
comparison): one FL round of a 40k-param model on the paper topology, swept
over loss rates. Derived: simulated round time, delivered clients, global
model L2 corruption vs lossless."""

from __future__ import annotations

import time

import numpy as np

from repro.core import (BernoulliLoss, FederatedSystem, FLClient, FLConfig,
                        Link, Simulator, TransportConfig)
from repro.core.packetizer import flatten_to_vector

SERVER = "10.1.2.5"


def _const_train(value):
    def fn(params, round_idx, client):
        return {k: np.full_like(v, value) for k, v in params.items()}, {}
    return fn


def run(transport: str, p_loss: float, seed: int = 0):
    sim = Simulator()
    params = {"w": np.zeros((40_000,), np.float32)}
    clients = []
    for i in range(2):
        addr = f"10.1.2.{10 + i}"
        sim.connect(addr, SERVER,
                    Link(1e8, 5_000_000, BernoulliLoss(p=p_loss,
                                                       seed=seed + i)),
                    Link(1e8, 5_000_000))
        clients.append(FLClient(addr, _const_train(float(i + 1)),
                                train_time_ns=1_000_000))
    cfg = FLConfig(aggregation="fedavg", broadcast_model=False,
                   transport=TransportConfig(kind=transport,
                                             timeout_ns=2_000_000_000,
                                             udp_deadline_ns=3_000_000_000))
    system = FederatedSystem(sim, SERVER, clients, params, cfg)
    for c in clients:
        c.params = params
    res = system.run_round()
    return system, res


def bench():
    clean, _ = run("mudp", 0.0)
    target = flatten_to_vector(clean.global_params)
    rows = []
    for p in (0.0, 0.05, 0.2):
        for tr in ("tcp", "udp", "mudp"):
            t0 = time.perf_counter()
            system, res = run(tr, p)
            wall_us = (time.perf_counter() - t0) * 1e6
            err = float(np.linalg.norm(
                flatten_to_vector(system.global_params) - target))
            rows.append((f"transport_comparison/{tr}_p{p:g}", wall_us,
                         f"sim_s={res.duration_ns/1e9:.3f}"
                         f";arrived={len(res.arrived)}"
                         f";retx={res.retransmissions}"
                         f";l2err={err:.3f}"))
    return rows


def main():
    for name, us, derived in bench():
        print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
