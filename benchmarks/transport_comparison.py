"""Benchmark 2 — every registered transport (the paper's future-work
comparison): FL rounds of a 40k-param model on the paper topology, swept
over loss rates. Derived: simulated round time, delivered clients,
retransmissions, global model L2 corruption vs lossless.

Iterates ``available_transports()``, so a transport registered through
``repro.core.transport.register_transport`` is benchmarked with no edits
here — that is how ``mudp+fec`` (fewer retransmissions than plain ``mudp``
at p=0.1) shows up in the sweep.

  PYTHONPATH=src python benchmarks/transport_comparison.py [--rounds N]
"""

from __future__ import annotations

import argparse
import time

import numpy as np

from repro.core import (BernoulliLoss, FederatedSystem, FLClient, FLConfig,
                        Link, Simulator, TransportConfig,
                        available_transports)
from repro.core.packetizer import flatten_to_vector

SERVER = "10.1.2.5"
LOSS_RATES = (0.0, 0.05, 0.1, 0.2)


def _const_train(value):
    def fn(params, round_idx, client):
        return {k: np.full_like(v, value) for k, v in params.items()}, {}
    return fn


def run(transport: str, p_loss: float, seed: int = 0, rounds: int = 1):
    sim = Simulator()
    params = {"w": np.zeros((40_000,), np.float32)}
    clients = []
    for i in range(2):
        addr = f"10.1.2.{10 + i}"
        sim.connect(addr, SERVER,
                    Link(1e8, 5_000_000, BernoulliLoss(p=p_loss,
                                                       seed=seed + i)),
                    Link(1e8, 5_000_000))
        clients.append(FLClient(addr, _const_train(float(i + 1)),
                                train_time_ns=1_000_000))
    cfg = FLConfig(aggregation="fedavg", broadcast_model=False,
                   transport=TransportConfig(kind=transport,
                                             timeout_ns=2_000_000_000,
                                             udp_deadline_ns=3_000_000_000))
    system = FederatedSystem(sim, SERVER, clients, params, cfg)
    for c in clients:
        c.params = params
    results = [system.run_round() for _ in range(rounds)]
    return system, results


def bench(rounds: int = 1):
    clean, _ = run("mudp", 0.0, rounds=rounds)
    target = flatten_to_vector(clean.global_params)
    rows = []
    for p in LOSS_RATES:
        for tr in available_transports():
            t0 = time.perf_counter()
            system, results = run(tr, p, rounds=rounds)
            wall_us = (time.perf_counter() - t0) * 1e6
            err = float(np.linalg.norm(
                flatten_to_vector(system.global_params) - target))
            # Aggregate over all rounds so every column shares provenance
            # with wall_us and the final-model l2err.
            sim_s = sum(r.duration_ns for r in results) / 1e9
            retx = sum(r.retransmissions for r in results)
            arrivals = sum(len(r.arrived) for r in results)
            rows.append((f"transport_comparison/{tr}_p{p:g}", wall_us,
                         f"sim_s={sim_s:.3f}"
                         f";arrivals={arrivals}/{2 * len(results)}"
                         f";retx={retx}"
                         f";l2err={err:.3f}"))
    return rows


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--rounds", type=int, default=1,
                    help="FL rounds per (transport, loss) configuration")
    args = ap.parse_args()
    if args.rounds < 1:
        ap.error("--rounds must be >= 1")
    for name, us, derived in bench(rounds=args.rounds):
        print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
