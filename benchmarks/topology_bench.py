"""Benchmark — topology engine: star vs hierarchical vs gossip federation.

One seeded fleet (identical cohorts and link draws — the *wiring* is the
only variable) runs the synthetic consensus objective under:

* ``star``  — the paper's single server (baseline),
* ``hier``  — edge aggregation at each cell count in ``--cells``,
* ``gossip`` — serverless peer exchange at degree ``--neighbors``.

Reported per cell: final loss, per-hop byte counters
(``Simulator.hop_bytes``), and round rows.  The claims under test:

1. the root link shrinks ~linearly in aggregator count — per-aggregator
   root-link bytes are ~constant while the star's server link carries the
   full O(clients) stream;
2. hier converges to the same final loss as star (weighted FedAvg
   decomposes exactly across tiers);
3. gossip reaches the target loss with **zero** server nodes in the
   simulation.

``--check`` turns those three into hard gates (non-zero exit) — CI runs
that and uploads ``BENCH_topology.json``.

  PYTHONPATH=src python benchmarks/topology_bench.py
  PYTHONPATH=src python benchmarks/topology_bench.py --check \\
      --clients 64 --cells 2,4,8
"""

from __future__ import annotations

import argparse
import json
import sys
import time

from repro.core import (ConsensusObjective, FLConfig, FleetConfig,
                        TransportConfig, build_fleet, profiles_digest)

NS_PER_SEC = 1_000_000_000


def run_topology(topology: str, *, n_clients: int, rounds: int, seed: int,
                 n_params: int, transport: str, cells: int = 4,
                 neighbors: int = 4, engine: str = "batched") -> dict:
    """One topology cell: every field derives from the simulation."""
    fleet = FleetConfig(n_clients=n_clients, seed=seed, engine=engine,
                        topology=topology, cells=cells, neighbors=neighbors)
    objective = ConsensusObjective(n_clients, n_params, seed=seed)
    fl_cfg = FLConfig(transport=TransportConfig(
        kind=transport, timeout_ns=2 * NS_PER_SEC,
        udp_deadline_ns=3 * NS_PER_SEC))
    sim, system, profiles = build_fleet(fleet, objective.init_params(),
                                        objective.train_fn, fl_cfg)
    loss0 = objective.loss(system.global_params)
    rows, losses = [], []

    def _on_round(r, params):
        loss = objective.loss(params)
        losses.append(loss)
        rows.append({"round": r.round_idx, "duration_ns": r.duration_ns,
                     "arrived": len(r.arrived), "roster": len(r.roster),
                     "bytes_sent": r.bytes_sent,
                     "retransmissions": r.retransmissions, "loss": loss})

    system.on_round_end = _on_round
    system.run_rounds(rounds)
    server_node_count = sum(
        1 for addr in sim._nodes
        if addr == fleet.server_addr
        or addr.startswith("10.2."))   # edge server planes
    return {
        "topology": topology,
        "cells": cells if topology == "hier" else None,
        "neighbors": neighbors if topology == "gossip" else None,
        "profiles_digest": profiles_digest(profiles),
        "rounds": rows,
        "hop_bytes": dict(sorted(sim.hop_bytes.items())),
        "hop_packets": dict(sorted(sim.hop_packets.items())),
        "server_nodes": server_node_count,
        "sim_time_ns": sum(r["duration_ns"] for r in rows),
        "initial_loss": loss0,
        "final_loss": losses[-1] if losses else loss0,
        "rounds_to_target_loss": next(
            (i + 1 for i, l in enumerate(losses) if l <= 0.1 * loss0), None),
    }


def run_suite(args) -> tuple[dict, dict, list[str]]:
    """(deterministic results, wall section, gate failures)."""
    results: dict = {}
    wall: dict = {}
    common = dict(n_clients=args.clients, rounds=args.rounds,
                  seed=args.seed, n_params=args.params,
                  transport=args.transport, engine=args.engine,
                  neighbors=args.neighbors)

    def _run(key, topology, **kw):
        t0 = time.perf_counter()
        cell = run_topology(topology, **{**common, **kw})
        wall[key] = {"wall_s": time.perf_counter() - t0}
        results[key] = cell
        root = (cell["hop_bytes"].get("edge->root")
                or cell["hop_bytes"].get("client->server")
                or cell["hop_bytes"].get("peer->peer"))
        print(f"topology/{key},{wall[key]['wall_s'] * 1e6:.1f},"
              f"loss={cell['final_loss']:.4f}"
              f";root_bytes={root}"
              f";server_nodes={cell['server_nodes']}", flush=True)
        return cell

    star = _run("star", "star")
    hier_cells = {}
    for c in args.cells:
        hier_cells[c] = _run(f"hier_cells{c}", "hier", cells=c)
    gossip = _run(f"gossip_k{args.neighbors}", "gossip")

    # -- gates ---------------------------------------------------------------
    failures: list[str] = []
    loss0 = star["initial_loss"]

    # Gate 1: per-aggregator root traffic ~constant => root link scales
    # with cells, not clients (the star server link is the O(clients)
    # reference point).
    per_agg = {c: hier_cells[c]["hop_bytes"]["edge->root"] / c
               for c in args.cells}
    if max(per_agg.values()) > 1.6 * min(per_agg.values()):
        failures.append(f"root-link bytes not ~linear in aggregator count: "
                        f"per-aggregator bytes {per_agg}")
    star_server_link = star["hop_bytes"]["client->server"]
    for c in args.cells:
        expect = star_server_link * c / args.clients
        got = hier_cells[c]["hop_bytes"]["edge->root"]
        if not 0.4 * expect <= got <= 2.5 * expect:
            failures.append(
                f"hier cells={c}: root link {got}B not ~{expect:.0f}B "
                f"(= star server link x cells/clients)")

    # Gate 2: equal final loss (hierarchical FedAvg decomposes exactly).
    for c in args.cells:
        gap = abs(hier_cells[c]["final_loss"] - star["final_loss"])
        if gap > 0.02 * loss0:
            failures.append(f"hier cells={c}: final loss "
                            f"{hier_cells[c]['final_loss']:.6f} != star "
                            f"{star['final_loss']:.6f} (gap {gap:.2e})")

    # Gate 3: gossip reaches the target loss with zero server nodes.
    if gossip["server_nodes"] != 0:
        failures.append(f"gossip wired {gossip['server_nodes']} server "
                        f"nodes; expected 0")
    if gossip["rounds_to_target_loss"] is None:
        failures.append(f"gossip never reached 10% of initial loss "
                        f"(final {gossip['final_loss']:.4f} vs initial "
                        f"{gossip['initial_loss']:.4f})")
    return results, wall, failures


def bench(rounds: int = 2):
    """benchmarks.run harness entry: one small cell per topology."""
    rows = []
    for topology, kw in (("star", {}), ("hier", {"cells": 4}),
                         ("gossip", {"neighbors": 3})):
        t0 = time.perf_counter()
        cell = run_topology(topology, n_clients=16, rounds=rounds, seed=0,
                            n_params=1024, transport="mudp", **kw)
        wall_us = (time.perf_counter() - t0) * 1e6
        root = (cell["hop_bytes"].get("edge->root")
                or cell["hop_bytes"].get("client->server")
                or cell["hop_bytes"].get("peer->peer"))
        rows.append((f"topology/{topology}_c16", wall_us,
                     f"loss={cell['final_loss']:.4f}"
                     f";root_bytes={root}"
                     f";server_nodes={cell['server_nodes']}"))
    return rows


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--clients", type=int, default=64)
    ap.add_argument("--rounds", type=int, default=3)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--params", type=int, default=1024,
                    help="model size in float32 parameters")
    ap.add_argument("--transport", default="mudp")
    ap.add_argument("--cells", default="2,4,8",
                    help="comma-separated hier aggregator counts")
    ap.add_argument("--neighbors", type=int, default=4,
                    help="gossip peer degree")
    ap.add_argument("--engine", default="batched",
                    choices=["batched", "per_packet"])
    ap.add_argument("--out", default="BENCH_topology.json")
    ap.add_argument("--check", action="store_true",
                    help="fail (exit 1) unless the scaling/equal-loss/"
                         "serverless gates hold")
    args = ap.parse_args()
    args.cells = [int(c) for c in str(args.cells).split(",") if c]
    if any(c < 1 for c in args.cells) or args.rounds < 1:
        ap.error("--cells and --rounds must be >= 1")

    results, wall, failures = run_suite(args)
    report = {
        "meta": {"clients": args.clients, "rounds": args.rounds,
                 "seed": args.seed, "params": args.params,
                 "transport": args.transport, "cells": args.cells,
                 "neighbors": args.neighbors, "engine": args.engine},
        "results": results,
        "gate_failures": failures,
        "wall": wall,
    }
    with open(args.out, "w") as f:
        json.dump(report, f, indent=1, sort_keys=True)
    print(f"wrote {args.out}", flush=True)

    if failures:
        for msg in failures:
            print(f"GATE FAILED: {msg}", file=sys.stderr)
        return 1 if args.check else 0
    print("gates: root-link ~linear in cells, hier==star loss, "
          "gossip serverless", flush=True)
    return 0


if __name__ == "__main__":
    sys.exit(main())
