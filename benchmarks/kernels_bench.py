"""Benchmark 6 — Pallas kernels vs jnp oracles.

This container executes kernels in interpret mode (Python emulation of the
TPU grid), so wall times here validate CORRECTNESS-path overhead only — the
TPU is the performance target; roofline expectations are derived in
EXPERIMENTS.md. Derived: max abs deviation vs the oracle.
"""

from __future__ import annotations

import time

import jax.numpy as jnp
import numpy as np

from repro.kernels.checksum.checksum import checksum_pallas
from repro.kernels.checksum.ref import chunksum32_jnp
from repro.kernels.fedavg.fedavg import fedavg_pallas
from repro.kernels.fedavg.ref import fedavg_flat
from repro.kernels.flash_attention.flash_attention import \
    flash_attention_pallas
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.mlstm.mlstm import mlstm_pallas
from repro.kernels.mlstm.ref import mlstm_ref
from repro.kernels.quantize.quantize import quantize_pallas
from repro.kernels.quantize.ref import quantize_blockwise


def _time(fn, reps=2):
    out = fn()
    jnp.asarray(out[0] if isinstance(out, (tuple, list)) else out
                ).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn()
        jnp.asarray(out[0] if isinstance(out, (tuple, list)) else out
                    ).block_until_ready()
    return (time.perf_counter() - t0) * 1e6 / reps, out


def bench():
    rng = np.random.default_rng(0)
    rows = []

    stack = jnp.asarray(rng.standard_normal((4, 262_144)), jnp.float32)
    w = jnp.asarray([0.1, 0.2, 0.3, 0.4], jnp.float32)
    us_k, out_k = _time(lambda: fedavg_pallas(stack, w, interpret=True))
    us_r, out_r = _time(lambda: fedavg_flat(stack, w))
    dev = float(jnp.abs(out_k - out_r).max())
    rows.append(("kernels/fedavg_pallas", us_k, f"max_dev={dev:.2e}"))
    rows.append(("kernels/fedavg_ref", us_r, "oracle"))

    x = jnp.asarray(rng.standard_normal((64, 1024)), jnp.float32)
    us_k, (q_k, s_k) = _time(lambda: quantize_pallas(x, interpret=True))
    us_r, (q_r, s_r) = _time(lambda: quantize_blockwise(x))
    dev = float(jnp.abs(s_k - s_r).max())
    rows.append(("kernels/quantize_pallas", us_k, f"scale_dev={dev:.2e}"))
    rows.append(("kernels/quantize_ref", us_r, "oracle"))

    data = jnp.asarray(rng.integers(0, 256, 262_144).astype(np.int32))
    us_k, c_k = _time(lambda: checksum_pallas(data, interpret=True))
    us_r, c_r = _time(lambda: chunksum32_jnp(data))
    rows.append(("kernels/checksum_pallas", us_k,
                 f"match={int(c_k) == int(c_r)}"))
    rows.append(("kernels/checksum_ref", us_r, "oracle"))

    B, H, S, hd = 1, 2, 512, 64
    q = jnp.asarray(rng.standard_normal((B, H, S, hd)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, H, S, hd)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, H, S, hd)), jnp.float32)
    us_k, o_k = _time(lambda: flash_attention_pallas(q, k, v,
                                                     interpret=True), 1)
    us_r, o_r = _time(lambda: attention_ref(q, k, v), 1)
    dev = float(jnp.abs(o_k - o_r).max())
    rows.append(("kernels/flash_attention_pallas", us_k,
                 f"max_dev={dev:.2e};S={S}"))
    rows.append(("kernels/flash_attention_ref", us_r, "oracle"))

    nh, dh = 2, 64
    qm = jnp.asarray(rng.standard_normal((1, 256, nh, dh)), jnp.float32)
    km = jnp.asarray(rng.standard_normal((1, 256, nh, dh)), jnp.float32)
    vm = jnp.asarray(rng.standard_normal((1, 256, nh, dh)), jnp.float32)
    ig = jnp.asarray(rng.standard_normal((1, 256, nh)), jnp.float32)
    fg = jnp.asarray(rng.standard_normal((1, 256, nh)) + 1, jnp.float32)
    us_k, m_k = _time(lambda: mlstm_pallas(qm, km, vm, ig, fg,
                                           interpret=True), 1)
    us_r, m_r = _time(lambda: mlstm_ref(qm, km, vm, ig, fg), 1)
    dev = float(jnp.abs(m_k - m_r).max())
    rows.append(("kernels/mlstm_pallas", us_k, f"max_dev={dev:.2e}"))
    rows.append(("kernels/mlstm_ref", us_r, "oracle"))
    return rows


def main():
    for name, us, derived in bench():
        print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
