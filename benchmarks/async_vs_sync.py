"""Benchmark — async (FedBuff-style) vs sync scheduling: simulated
time-to-target-loss on the congested-edge cohort.

Same seeded fleet, same links, same transport, same
:class:`ConsensusObjective`; the only variable is ``FleetConfig.mode``.
Sync pays the round barrier (every round waits for its slowest sampled
client or the deadline); async aggregates whenever ``buffer_k`` updates
are buffered while clients re-enter at their own cadence, so stragglers
stop gating progress.  The metric is the simulated wall-clock at which the
global loss first reaches ``target_frac * L0`` — fully deterministic, so
``--check`` can gate CI on the acceptance criterion:

    async time-to-target <= 0.8 x sync time-to-target

  PYTHONPATH=src python benchmarks/async_vs_sync.py
  PYTHONPATH=src python benchmarks/async_vs_sync.py --check
"""

from __future__ import annotations

import argparse
import json
import sys
import time

from repro.core import (ConsensusObjective, FLConfig, FleetConfig,
                        TransportConfig, build_fleet)

NS = 1_000_000_000


def time_to_target(mode: str, *, n_clients: int, seed: int,
                   target_frac: float, n_params: int, max_rounds: int,
                   transport: str, buffer_k: int, deadline_ns: int,
                   engine: str = "batched") -> dict:
    """Run one mode until the loss target is crossed (or max_rounds)."""
    fleet = FleetConfig(n_clients=n_clients, seed=seed, mode=mode,
                        buffer_k=buffer_k, engine=engine,
                        cohort_mix=(("congested-edge", 1.0),),
                        round_deadline_ns=deadline_ns)
    objective = ConsensusObjective(n_clients, n_params, seed=seed)
    cfg = FLConfig(aggregation="fedavg",
                   transport=TransportConfig(kind=transport,
                                             timeout_ns=2 * NS,
                                             udp_deadline_ns=3 * NS))
    sim, system, _ = build_fleet(fleet, objective.init_params(),
                                 objective.train_fn, cfg)
    loss0 = objective.loss(system.global_params)
    target = target_frac * loss0
    trace: list[dict] = []

    def on_round(res, params):
        trace.append({"round": res.round_idx, "sim_ns": sim.now_ns,
                      "loss": objective.loss(params),
                      "arrived": len(res.arrived)})
    system.on_round_end = on_round
    t0 = time.perf_counter()
    system.run_rounds(max_rounds)
    wall_s = time.perf_counter() - t0

    crossed = next((row for row in trace if row["loss"] <= target), None)
    return {
        "mode": mode,
        "initial_loss": loss0,
        "target_loss": target,
        "rounds_run": len(trace),
        "rounds_to_target": crossed["round"] + 1 if crossed else None,
        "sim_ns_to_target": crossed["sim_ns"] if crossed else None,
        "final_loss": trace[-1]["loss"] if trace else loss0,
        "trace": trace,
        "wall_s": wall_s,
    }


def compare(args) -> dict:
    kw = dict(n_clients=args.clients, seed=args.seed,
              target_frac=args.target_frac, n_params=args.params,
              transport=args.transport, buffer_k=args.buffer_k,
              deadline_ns=int(args.deadline_s * NS), engine=args.engine)
    # Sync rounds are ~an order of magnitude longer than async buffer
    # windows, so it needs far fewer iterations for the same sim-time.
    sync = time_to_target("sync", max_rounds=args.max_rounds, **kw)
    async_ = time_to_target("async", max_rounds=8 * args.max_rounds, **kw)
    ratio = None
    if sync["sim_ns_to_target"] and async_["sim_ns_to_target"]:
        ratio = async_["sim_ns_to_target"] / sync["sim_ns_to_target"]
    return {"meta": vars(args), "sync": sync, "async": async_,
            "time_ratio_async_over_sync": ratio}


def bench(rounds: int = 1):
    """benchmarks.run harness entry: one small comparison cell."""
    rows = []
    ns = argparse.Namespace(clients=16, seed=0, target_frac=0.1, params=1024,
                            max_rounds=8, transport="mudp", buffer_k=4,
                            deadline_s=8.0, engine="batched", check=False,
                            out=None)
    report = compare(ns)
    for mode in ("sync", "async"):
        cell = report[mode]
        rows.append((f"async_vs_sync/{mode}_c16",
                     cell["wall_s"] * 1e6,
                     f"sim_s_to_target={(cell['sim_ns_to_target'] or 0) / 1e9:.2f}"
                     f";rounds={cell['rounds_to_target']}"
                     f";final_loss={cell['final_loss']:.4f}"))
    ratio = report["time_ratio_async_over_sync"]
    rows.append(("async_vs_sync/ratio", 0.0,
                 f"async/sync={ratio:.3f}" if ratio else "no_crossing"))
    return rows


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--clients", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--target-frac", type=float, default=0.05,
                    help="target loss as a fraction of the initial loss")
    ap.add_argument("--params", type=int, default=2048)
    ap.add_argument("--max-rounds", type=int, default=20,
                    help="sync round budget (async gets 8x)")
    ap.add_argument("--transport", default="mudp")
    ap.add_argument("--buffer-k", type=int, default=8)
    ap.add_argument("--deadline-s", type=float, default=8.0,
                    help="sync round deadline / async session watchdog")
    ap.add_argument("--engine", default="batched",
                    choices=["batched", "per_packet"])
    ap.add_argument("--out", default=None,
                    help="optional JSON report path")
    ap.add_argument("--check", action="store_true",
                    help="exit non-zero unless async time-to-target is "
                         "<= 0.8x sync (both must cross)")
    args = ap.parse_args()

    report = compare(args)
    for mode in ("sync", "async"):
        cell = report[mode]
        sim_s = (cell["sim_ns_to_target"] or 0) / 1e9
        print(f"{mode:>5}: L0={cell['initial_loss']:.3f} -> target "
              f"{cell['target_loss']:.4f} in "
              f"{cell['rounds_to_target']} rounds, sim t={sim_s:.2f}s "
              f"(wall {cell['wall_s']:.2f}s)", flush=True)
    ratio = report["time_ratio_async_over_sync"]
    if ratio is not None:
        print(f"async reaches target in {ratio:.2f}x the sync sim-time")
    else:
        print("WARNING: a mode never crossed the target", file=sys.stderr)

    if args.out:
        with open(args.out, "w") as f:
            json.dump(report, f, indent=1, sort_keys=True)
        print(f"wrote {args.out}")

    if args.check:
        if ratio is None:
            print("CHECK FAILED: no crossing", file=sys.stderr)
            return 1
        if ratio > 0.8:
            print(f"CHECK FAILED: async/sync = {ratio:.3f} > 0.8",
                  file=sys.stderr)
            return 1
        print("check passed: async/sync <= 0.8")
    return 0


if __name__ == "__main__":
    sys.exit(main())
