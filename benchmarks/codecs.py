"""Benchmark 4 — packet payload codecs (paper Algorithm I hex vs production
codecs): encode+decode wall time and wire size for a 1M-param vector.
Derived: wire bytes per parameter and max abs reconstruction error."""

from __future__ import annotations

import time

import numpy as np

from repro.core.compression import make_codec


def bench():
    rng = np.random.default_rng(0)
    vec = rng.standard_normal(1_000_000).astype(np.float32)
    rows = []
    for name in ("hex", "raw", "int8", "topk"):
        codec = make_codec(name)
        t0 = time.perf_counter()
        reps = 3
        for _ in range(reps):
            data = codec.encode(vec)
            out = codec.decode(data)
        us = (time.perf_counter() - t0) * 1e6 / reps
        err = float(np.abs(
            out[:vec.size] - vec).max()) if name != "topk" else float("nan")
        rows.append((f"codecs/{name}", us,
                     f"bytes_per_param={len(data)/vec.size:.2f}"
                     f";max_err={err:.2e}"))
    return rows


def main():
    for name, us, derived in bench():
        print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
