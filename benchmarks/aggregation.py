"""Benchmark 5 — aggregation strategies (paper Eq. 1 vs weighted FedAvg).

Quantifies the order-dependence of the paper's sequential pairwise average
(later arrivals dominate: the k-th last client carries weight 2^-k) and
times the fused fedavg kernel against its jnp oracle.
"""

from __future__ import annotations

import itertools
import time

import numpy as np

from repro.core.aggregation import fedavg, pairwise_average
from repro.kernels.fedavg.fedavg import fedavg_pallas
from repro.kernels.fedavg.ref import fedavg_flat as ref_flat

import jax.numpy as jnp


def bench():
    rows = []
    rng = np.random.default_rng(0)
    clients = [{"w": rng.standard_normal(1000).astype(np.float32)}
               for _ in range(4)]
    g0 = {"w": np.zeros(1000, np.float32)}

    # order dependence of Eq. (1)
    outs = []
    for perm in itertools.permutations(range(4)):
        g = g0
        for i in perm:
            g = pairwise_average(g, clients[i])
        outs.append(g["w"])
    spread = float(max(np.linalg.norm(a - b)
                       for a in outs for b in outs))
    fa = fedavg(clients)["w"]
    worst_vs_fedavg = float(max(np.linalg.norm(o - fa) for o in outs))
    rows.append(("aggregation/pairwise_order_dependence", 0.0,
                 f"perm_spread_l2={spread:.3f}"
                 f";max_dev_from_fedavg={worst_vs_fedavg:.3f}"))

    # kernel vs oracle timing (N = 4M params, K = 8 clients)
    K, N = 8, 4_000_000
    stack = jnp.asarray(rng.standard_normal((K, N)), jnp.float32)
    w = jnp.asarray(rng.uniform(0.5, 1.5, K), jnp.float32)
    for name, fn in (("kernel_interpret",
                      lambda: fedavg_pallas(stack, w, interpret=True)),
                     ("jnp_ref", lambda: ref_flat(stack, w))):
        fn().block_until_ready()
        t0 = time.perf_counter()
        reps = 3
        for _ in range(reps):
            fn().block_until_ready()
        us = (time.perf_counter() - t0) * 1e6 / reps
        rows.append((f"aggregation/fedavg_{name}", us,
                     f"K={K};N={N}"))
    return rows


def main():
    for name, us, derived in bench():
        print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
