"""Benchmark 7 — roofline table from the multi-pod dry-run.

Reads the dry-run JSON reports (results/dryrun*.json, later files override
earlier per cell), derives the three roofline terms per (arch x shape x
mesh), the dominant bottleneck, MODEL_FLOPS/HLO_FLOPs usefulness, and the
MFU bound = useful-FLOPs-at-bottleneck-speed / peak. Writes
results/roofline.md for EXPERIMENTS.md §Roofline.

Regenerate inputs with:
  PYTHONPATH=src python -m repro.launch.dryrun --all --multi-pod both \
      --out results/dryrun.json
"""

from __future__ import annotations

import glob
import json
import os

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9

HINTS = {
    ("compute", "moe"): "cut dense-all-experts waste: sorted/ragged dispatch "
                        "computes only top-k experts",
    ("collective", "moe"): "per-expert-scan weight collectives dominate; "
                           "EP all-to-all dispatch or expert-replicated "
                           "weights remove the per-step gathers",
    ("memory", "train"): "activation traffic: raise arithmetic intensity "
                         "(fused attention kernel, larger microbatch)",
    ("memory", "decode"): "KV-cache reads dominate; int8 KV cache or "
                          "grouped-query kernel halves bytes",
    ("memory", "prefill"): "attention score materialization; flash/chunked "
                           "attention keeps tiles in VMEM",
    ("collective", "train"): "grad all-reduce / SP all-gathers; overlap with "
                             "backward compute, int8-compress cross-pod "
                             "reduce",
    ("collective", "decode"): "sharded-KV softmax combine; shard_map "
                              "flash-decode with single LSE all-reduce",
}


def load_cells() -> dict:
    cells = {}
    for path in sorted(glob.glob("results/dryrun*.json")):
        try:
            with open(path) as f:
                for rec in json.load(f):
                    cells[(rec["arch"], rec["shape"], rec["mesh"])] = rec
        except (json.JSONDecodeError, KeyError):
            continue
    return cells


def derive(rec: dict) -> dict:
    terms = {"compute": rec["compute_s"], "memory": rec["memory_s"],
             "collective": rec["collective_s"]}
    bottleneck = max(terms.values()) or 1e-30
    n = rec["num_devices"] or 1
    useful_per_dev = rec["model_flops_global"] / n
    mfu_bound = useful_per_dev / PEAK_FLOPS / bottleneck
    mode = ("train" if rec["shape"].startswith("train") else
            "prefill" if rec["shape"].startswith("prefill") else "decode")
    fam = ("moe" if "moe" in rec["arch"] or "olmoe" in rec["arch"] else mode)
    hint = HINTS.get((rec["dominant"], "moe")) if fam == "moe" else None
    hint = hint or HINTS.get((rec["dominant"], mode), "")
    return {"bottleneck_s": bottleneck, "mfu_bound": mfu_bound,
            "hint": hint, **terms}


def render_markdown(cells: dict) -> str:
    lines = [
        "| arch | shape | mesh | GiB/dev | compute_s | memory_s | "
        "collective_s | dominant | MODEL_FLOPs | useful/HLO | MFU bound | "
        "next lever |",
        "|---|---|---|---|---|---|---|---|---|---|---|---|",
    ]
    for key in sorted(cells):
        r = cells[key]
        if r["status"] == "skipped":
            lines.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                         f"— | — | — | — | skipped | — | — | — | "
                         f"{r['error'][:60]} |")
            continue
        if r["status"] != "ok":
            lines.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                         f"ERROR | | | | | | | | {r['error'][:60]} |")
            continue
        d = derive(r)
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
            f"{r['bytes_per_device']/2**30:.1f} | "
            f"{d['compute']:.3e} | {d['memory']:.3e} | "
            f"{d['collective']:.3e} | {r['dominant']} | "
            f"{r['model_flops_global']:.2e} | {r['useful_ratio']:.2f} | "
            f"{d['mfu_bound']*100:.1f}% | {d['hint'][:70]} |")
    return "\n".join(lines)


def bench():
    cells = load_cells()
    rows = []
    if not cells:
        return [("roofline/missing_inputs", 0.0,
                 "run repro.launch.dryrun first")]
    ok = [c for c in cells.values() if c["status"] == "ok"]
    md = render_markdown(cells)
    os.makedirs("results", exist_ok=True)
    with open("results/roofline.md", "w") as f:
        f.write(md + "\n")
    for key in sorted(cells):
        r = cells[key]
        if r["status"] != "ok":
            continue
        d = derive(r)
        rows.append((f"roofline/{r['arch']}/{r['shape']}/{r['mesh']}",
                     0.0,
                     f"dominant={r['dominant']}"
                     f";mfu_bound={d['mfu_bound']*100:.1f}%"
                     f";useful_ratio={r['useful_ratio']:.2f}"
                     f";mem_gib={r['bytes_per_device']/2**30:.1f}"))
    rows.append(("roofline/summary", 0.0,
                 f"cells_ok={len(ok)}"
                 f";table=results/roofline.md"))
    return rows


def main():
    for name, us, derived in bench():
        print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
