"""Benchmark — simulator core: the batched flight engine vs per-packet.

Two workloads, both on the standard 64-client heterogeneous fleet topology
(cohort link draws, jitter, loss — identical across engines):

* ``fleet_burst`` — the simulator hot path: every client ships one model
  update (default 1 MiB) to the server through the chosen transport.
  Packets are built outside the timed region, so the numbers measure the
  event engine (FIFO serialization, jitter/loss draws, delivery, protocol
  state machines), not the packetizer.
* ``fl_round`` — one end-to-end FL round (broadcast, local training,
  uplink, aggregation) of the synthetic consensus objective: the honest
  Amdahl view, where packetization and FL math dilute the engine speedup.

Every cell runs under BOTH engines and fails loudly unless their replay
digests (stats + final clock + payload bytes) are bit-identical — the
benchmark doubles as an equivalence check.  Results land in ``--out``
(default ``BENCH_simcore.json``): events/sec, wall seconds, and the
batched/per-packet speedup per (workload, transport).

  PYTHONPATH=src python benchmarks/simcore.py
  PYTHONPATH=src python benchmarks/simcore.py --clients 64 --payload-kib 1024 \\
      --transports mudp,udp --min-speedup 5
"""

from __future__ import annotations

import argparse
import hashlib
import json
import sys
import time

from repro.core import (ConsensusObjective, FLConfig, FleetConfig, Simulator,
                        TransportConfig, available_transports, build_fleet,
                        make_transport, packetize, sample_profiles)
from repro.core.fleet import links_for

NS = 1_000_000_000
SERVER = "10.0.0.1"


# --------------------------------------------------------------------------
# Workloads
# --------------------------------------------------------------------------
def fleet_burst(engine: str, transport: str, *, n_clients: int,
                payload: int, seed: int) -> dict:
    """Every client uplinks one ``payload``-byte update through
    ``transport`` over its drawn fleet link; returns engine metrics."""
    profiles = sample_profiles(FleetConfig(n_clients=n_clients, seed=seed))
    sim = Simulator(engine=engine)
    for p in profiles:
        up, down = links_for(p)
        sim.connect(p.addr, SERVER, up, down)
    tr = make_transport(transport)
    cfg = TransportConfig(kind=transport, timeout_ns=16 * NS,
                          udp_deadline_ns=24 * NS)
    deliveries: list = []
    tr.create_receiver(sim, sim.node(SERVER), cfg, deliveries.append)
    data = bytes(range(256)) * (payload // 256)
    bursts = [packetize(data, p.addr, txn=1, mtu=cfg.mtu) for p in profiles]
    senders = [tr.create_sender(sim, sim.node(p.addr), sim.node(SERVER),
                                pkts, cfg)
               for p, pkts in zip(profiles, bursts)]
    t0 = time.perf_counter()
    for s in senders:
        s.start()
    sim.run()
    wall_s = time.perf_counter() - t0
    payload_hash = hashlib.sha256()
    for blob in sorted((d.sender_addr.encode() + d.reassemble())
                       for d in deliveries):
        payload_hash.update(blob)
    return {
        "wall_s": wall_s,
        "events": sim.events_processed,
        "events_per_sec": sim.events_processed / wall_s if wall_s else None,
        "packets_sent": sim.stats["packets_sent"],
        "packets_delivered": sim.stats["packets_delivered"],
        "deliveries": len(deliveries),
        "digest": sim.stats_digest() + payload_hash.hexdigest()[:16],
    }


def fl_round(engine: str, transport: str, *, n_clients: int,
             n_params: int, seed: int) -> dict:
    """One full FL round on the fleet scenario engine."""
    fleet = FleetConfig(n_clients=n_clients, seed=seed,
                        participation_fraction=1.0,
                        round_deadline_ns=120 * NS, engine=engine)
    objective = ConsensusObjective(n_clients, n_params, seed=seed)
    cfg = FLConfig(aggregation="fedavg",
                   transport=TransportConfig(kind=transport,
                                             timeout_ns=8 * NS,
                                             udp_deadline_ns=12 * NS))
    sim, system, _ = build_fleet(fleet, objective.init_params(),
                                 objective.train_fn, cfg)
    t0 = time.perf_counter()
    r = system.run_round()
    wall_s = time.perf_counter() - t0
    return {
        "wall_s": wall_s,
        "events": sim.events_processed,
        "events_per_sec": sim.events_processed / wall_s if wall_s else None,
        "packets_sent": r.packets_sent,
        "data_packets": r.data_packets,
        "nack_packets": r.nack_packets,
        "parity_packets": r.parity_packets,
        "digest": (sim.stats_digest()
                   + system.global_params["w"].tobytes().hex()[:32]),
    }


WORKLOADS = {"fleet_burst": fleet_burst, "fl_round": fl_round}


# --------------------------------------------------------------------------
# Driver
# --------------------------------------------------------------------------
def run_cell(workload: str, transport: str, *, n_clients: int, payload: int,
             n_params: int, seed: int, repeats: int) -> dict:
    """One (workload, transport) cell under both engines; best-of-N wall
    (robust to load spikes), plus the digest-equality verdict."""
    cell: dict = {}
    for engine in ("per_packet", "batched"):
        best = None
        for _ in range(repeats):
            if workload == "fleet_burst":
                m = fleet_burst(engine, transport, n_clients=n_clients,
                                payload=payload, seed=seed)
            else:
                m = fl_round(engine, transport, n_clients=n_clients,
                             n_params=n_params, seed=seed)
            if best is None or m["wall_s"] < best["wall_s"]:
                best = m
        cell[engine] = best
    pp, ba = cell["per_packet"], cell["batched"]
    cell["digests_match"] = pp["digest"] == ba["digest"]
    cell["speedup_events_per_sec"] = (
        ba["events_per_sec"] / pp["events_per_sec"]
        if pp["events_per_sec"] else None)
    return cell


def bench(rounds: int = 1):
    """benchmarks.run harness entry: a small burst, both engines."""
    rows = []
    for tr in ("mudp", "udp"):
        cell = run_cell("fleet_burst", tr, n_clients=16, payload=128 * 1024,
                        n_params=1024, seed=0, repeats=1)
        rows.append((
            f"simcore/{tr}_burst_c16",
            cell["batched"]["wall_s"] * 1e6,
            f"speedup={cell['speedup_events_per_sec']:.2f}x"
            f";eps={cell['batched']['events_per_sec']:.0f}"
            f";identical={cell['digests_match']}"))
    return rows


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--clients", type=int, default=64)
    ap.add_argument("--payload-kib", type=int, default=1024,
                    help="fleet_burst: update size per client (KiB)")
    ap.add_argument("--params", type=int, default=32768,
                    help="fl_round: model size in float32 parameters")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--repeats", type=int, default=3,
                    help="timing repeats per cell (best wall kept)")
    ap.add_argument("--transports", default="mudp,udp,mudp+fec,tcp",
                    help="comma-separated subset of registered transports")
    ap.add_argument("--workloads", default="fleet_burst,fl_round")
    ap.add_argument("--min-speedup", type=float, default=0.0,
                    help="fail unless the best fleet_burst speedup reaches "
                         "this factor (CI acceptance gate)")
    ap.add_argument("--out", default="BENCH_simcore.json")
    args = ap.parse_args()

    transports = [t for t in args.transports.split(",") if t]
    for t in transports:
        if t not in available_transports():
            ap.error(f"unknown transport {t!r}; registered: "
                     f"{available_transports()}")
    workloads = [w for w in args.workloads.split(",") if w]
    for w in workloads:
        if w not in WORKLOADS:
            ap.error(f"unknown workload {w!r}; one of {sorted(WORKLOADS)}")

    report: dict = {
        "meta": {
            "clients": args.clients,
            "payload_kib": args.payload_kib,
            "params": args.params,
            "seed": args.seed,
            "repeats": args.repeats,
            "transports": transports,
            "workloads": workloads,
        },
        "cells": {},
    }
    mismatches = []
    for w in workloads:
        report["cells"][w] = {}
        for tr in transports:
            cell = run_cell(w, tr, n_clients=args.clients,
                            payload=args.payload_kib * 1024,
                            n_params=args.params, seed=args.seed,
                            repeats=args.repeats)
            report["cells"][w][tr] = cell
            if not cell["digests_match"]:
                mismatches.append(f"{w}/{tr}")
            print(f"simcore/{w}/{tr}: "
                  f"per_packet={cell['per_packet']['wall_s']:.3f}s "
                  f"batched={cell['batched']['wall_s']:.3f}s "
                  f"speedup={cell['speedup_events_per_sec']:.2f}x "
                  f"eps={cell['batched']['events_per_sec']:,.0f} "
                  f"identical={cell['digests_match']}", flush=True)

    best_burst = max(
        (c["speedup_events_per_sec"] or 0.0
         for c in report["cells"].get("fleet_burst", {}).values()),
        default=0.0)
    report["best_fleet_burst_speedup"] = best_burst

    with open(args.out, "w") as f:
        json.dump(report, f, indent=1, sort_keys=True)
    print(f"wrote {args.out}", flush=True)

    if mismatches:
        print(f"ENGINE DIVERGENCE: {mismatches}", file=sys.stderr)
        return 2
    if args.min_speedup and best_burst < args.min_speedup:
        print(f"SPEEDUP GATE FAILED: best fleet_burst speedup "
              f"{best_burst:.2f}x < {args.min_speedup}x", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
