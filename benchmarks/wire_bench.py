"""Benchmark — wire-plane pipelines: encode/decode throughput and
compression ratio per pipeline spec (``repro.core.wire``).

For each spec: encode + self-describing decode of an N-param float32
vector, reporting wall time per direction, MB/s against the *input* size,
wire bytes per parameter, and max |reconstruction error| against the
input (delta-domain specs run against a zero reference, so their decoded
output stays elementwise comparable).

Determinism check (``--check``): every spec is encoded twice through two
independently constructed pipelines (fresh state each); the wire bytes
must hash identically, and a header-only ``decode_payload`` must
reproduce the out-of-band decode bit-for-bit.  CI runs this and uploads
``BENCH_wire.json``.

  PYTHONPATH=src python benchmarks/wire_bench.py --check --out BENCH_wire.json
  PYTHONPATH=src python -m benchmarks.run --only wire
"""

from __future__ import annotations

import argparse
import hashlib
import json
import sys
import time

import numpy as np

from repro.core.wire import decode_payload, parse_pipeline

#: The spec matrix: the four legacy codecs as single-stage pipelines plus
#: the compositions the FL layer actually ships.
SPECS = (
    "raw",
    "hex",
    "int8(1024)",
    "topk(0.01)",
    "delta|ef|int8(1024)",
    "delta|ef|topk(0.01)|int8(1024)",
    "topk(0.01)|int8(256)",
)


def _fresh_state(pipeline, vec):
    state = pipeline.new_state()
    if pipeline.caps.delta_domain:
        pipeline.set_reference(state, np.zeros_like(vec))
    return state


def _bench_spec(spec: str, vec: np.ndarray, repeats: int) -> dict:
    pipeline = parse_pipeline(spec)

    # Reported bytes/ratio/error come from a dedicated ONE-SHOT encode on
    # fresh state, so BENCH_wire.json is identical whatever --repeats is
    # (an ef residual would otherwise make repeat N's payload differ).
    data = pipeline.encode(vec, _fresh_state(pipeline, vec))
    out, _ = decode_payload(data)

    t0 = time.perf_counter()
    for _ in range(repeats):
        pipeline.encode(vec, _fresh_state(pipeline, vec))
    enc_s = (time.perf_counter() - t0) / repeats

    t0 = time.perf_counter()
    for _ in range(repeats):
        decode_payload(data)
    dec_s = (time.perf_counter() - t0) / repeats

    in_bytes = vec.size * 4
    # Comparable for every spec: the zero delta reference makes the
    # delta-domain output the input itself, so this column is exactly the
    # quantization/sparsification error the benchmark exists to report.
    err = float(np.abs(out - vec).max())
    return {
        "spec": pipeline.spec,
        "caps": {
            "lossless": pipeline.caps.lossless,
            "stateful": pipeline.caps.stateful,
            "delta_domain": pipeline.caps.delta_domain,
            "est_ratio": pipeline.caps.est_ratio,
        },
        "n_params": int(vec.size),
        "wire_bytes": len(data),
        "bytes_per_param": len(data) / vec.size,
        "measured_ratio": len(data) / in_bytes,
        "encode_us": enc_s * 1e6,
        "decode_us": dec_s * 1e6,
        "encode_mb_s": in_bytes / enc_s / 1e6,
        "decode_mb_s": in_bytes / dec_s / 1e6,
        "max_abs_err": err,
    }


def _determinism_check(vec: np.ndarray) -> list[str]:
    """Two independent pipeline constructions must produce identical wire
    bytes, and the header-only decode must match the out-of-band decode
    bit-for-bit.  Returns a list of failures (empty = deterministic)."""
    failures = []
    for spec in SPECS:
        digests = []
        for _ in range(2):
            p = parse_pipeline(spec)
            st = p.new_state()
            if p.caps.delta_domain:
                p.set_reference(st, np.zeros_like(vec))
            data = p.encode(vec, st)
            negotiated, _ = decode_payload(data)
            oob = p.decode(data, p.new_state())
            if negotiated.tobytes() != oob.tobytes():
                failures.append(f"{spec}: header-only decode != out-of-band")
            digests.append(hashlib.sha256(data).hexdigest())
        if digests[0] != digests[1]:
            failures.append(f"{spec}: wire bytes differ across constructions")
    return failures


def run(n_params: int, repeats: int) -> dict:
    rng = np.random.default_rng(0)
    vec = rng.standard_normal(n_params).astype(np.float32)
    return {
        "n_params": n_params,
        "repeats": repeats,
        "pipelines": [_bench_spec(s, vec, repeats) for s in SPECS],
        "determinism_failures": _determinism_check(vec),
    }


def bench():
    """benchmarks.run contract: yield (row, us_per_call, derived)."""
    report = run(n_params=1_000_000, repeats=3)
    rows = []
    for p in report["pipelines"]:
        rows.append((
            f"wire/{p['spec']}",
            p["encode_us"] + p["decode_us"],
            f"bytes_per_param={p['bytes_per_param']:.3f}"
            f";enc_mb_s={p['encode_mb_s']:.0f}"
            f";dec_mb_s={p['decode_mb_s']:.0f}",
        ))
    status = ("ok" if not report["determinism_failures"]
              else ";".join(report["determinism_failures"]))
    rows.append(("wire/determinism", 0.0, status))
    return rows


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--params", type=int, default=1_000_000)
    ap.add_argument("--repeats", type=int, default=3)
    ap.add_argument("--out", default=None, help="write BENCH_wire.json here")
    ap.add_argument("--check", action="store_true",
                    help="exit non-zero if the determinism check fails")
    args = ap.parse_args()

    report = run(args.params, args.repeats)
    for p in report["pipelines"]:
        print(f"{p['spec']:34s} {p['bytes_per_param']:7.3f} B/param  "
              f"enc {p['encode_mb_s']:8.0f} MB/s  "
              f"dec {p['decode_mb_s']:8.0f} MB/s  "
              f"max_err {p['max_abs_err']:.2e}")
    if args.out:
        with open(args.out, "w") as f:
            json.dump(report, f, indent=2)
        print(f"wrote {args.out}")
    if report["determinism_failures"]:
        for fail in report["determinism_failures"]:
            print(f"DETERMINISM FAILURE: {fail}", file=sys.stderr)
        if args.check:
            sys.exit(1)
    elif args.check:
        print("determinism check: ok")


if __name__ == "__main__":
    main()
