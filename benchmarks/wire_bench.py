"""Benchmark — wire-plane pipelines: encode/decode throughput and
compression ratio per pipeline spec (``repro.core.wire``).

For each spec: encode + self-describing decode of an N-param float32
vector, reporting wall time per direction, MB/s against the *input* size,
wire bytes per parameter, and max |reconstruction error| against the
input (delta-domain specs run against a zero reference, so their decoded
output stays elementwise comparable).

Determinism check (``--check``): every spec is encoded twice through two
independently constructed pipelines (fresh state each); the wire bytes
must hash identically, and a header-only ``decode_payload`` must
reproduce the out-of-band decode bit-for-bit.  CI runs this and uploads
``BENCH_wire.json``.

Batch sweep: the same specs over a clients axis (N in {1,16,64,256}) at a
smaller vector size where per-call overhead dominates, comparing the
vectorized ``encode_batch``/``decode_payload_batch`` plane against the
per-client loop.  ``--check`` additionally gates (a) batch bytes being
identical to the loop's and (b) the numpy batch path clearing a >=4x
combined encode+decode speedup at 256 clients.

  PYTHONPATH=src python benchmarks/wire_bench.py --check --out BENCH_wire.json
  PYTHONPATH=src python -m benchmarks.run --only wire
"""

from __future__ import annotations

import argparse
import hashlib
import json
import sys
import time

import numpy as np

from repro.core.wire import (decode_payload, decode_payload_batch,
                             parse_pipeline)

#: The spec matrix: the four legacy codecs as single-stage pipelines plus
#: the compositions the FL layer actually ships.
SPECS = (
    "raw",
    "hex",
    "int8(1024)",
    "topk(0.01)",
    "delta|ef|int8(1024)",
    "delta|ef|topk(0.01)|int8(1024)",
    "topk(0.01)|int8(256)",
)


def _fresh_state(pipeline, vec):
    state = pipeline.new_state()
    if pipeline.caps.delta_domain:
        pipeline.set_reference(state, np.zeros_like(vec))
    return state


def _bench_spec(spec: str, vec: np.ndarray, repeats: int) -> dict:
    pipeline = parse_pipeline(spec)

    # Reported bytes/ratio/error come from a dedicated ONE-SHOT encode on
    # fresh state, so BENCH_wire.json is identical whatever --repeats is
    # (an ef residual would otherwise make repeat N's payload differ).
    data = pipeline.encode(vec, _fresh_state(pipeline, vec))
    out, _ = decode_payload(data)

    t0 = time.perf_counter()
    for _ in range(repeats):
        pipeline.encode(vec, _fresh_state(pipeline, vec))
    enc_s = (time.perf_counter() - t0) / repeats

    t0 = time.perf_counter()
    for _ in range(repeats):
        decode_payload(data)
    dec_s = (time.perf_counter() - t0) / repeats

    in_bytes = vec.size * 4
    # Comparable for every spec: the zero delta reference makes the
    # delta-domain output the input itself, so this column is exactly the
    # quantization/sparsification error the benchmark exists to report.
    err = float(np.abs(out - vec).max())
    return {
        "spec": pipeline.spec,
        "caps": {
            "lossless": pipeline.caps.lossless,
            "stateful": pipeline.caps.stateful,
            "delta_domain": pipeline.caps.delta_domain,
            "est_ratio": pipeline.caps.est_ratio,
        },
        "n_params": int(vec.size),
        "wire_bytes": len(data),
        "bytes_per_param": len(data) / vec.size,
        "measured_ratio": len(data) / in_bytes,
        "encode_us": enc_s * 1e6,
        "decode_us": dec_s * 1e6,
        "encode_mb_s": in_bytes / enc_s / 1e6,
        "decode_mb_s": in_bytes / dec_s / 1e6,
        "max_abs_err": err,
    }


def _determinism_check(vec: np.ndarray) -> list[str]:
    """Two independent pipeline constructions must produce identical wire
    bytes, and the header-only decode must match the out-of-band decode
    bit-for-bit.  Returns a list of failures (empty = deterministic)."""
    failures = []
    for spec in SPECS:
        digests = []
        for _ in range(2):
            p = parse_pipeline(spec)
            st = p.new_state()
            if p.caps.delta_domain:
                p.set_reference(st, np.zeros_like(vec))
            data = p.encode(vec, st)
            negotiated, _ = decode_payload(data)
            oob = p.decode(data, p.new_state())
            if negotiated.tobytes() != oob.tobytes():
                failures.append(f"{spec}: header-only decode != out-of-band")
            digests.append(hashlib.sha256(data).hexdigest())
        if digests[0] != digests[1]:
            failures.append(f"{spec}: wire bytes differ across constructions")
    return failures


#: Batch-plane sweep: clients axis at a vector size small enough that
#: per-payload Python overhead (header packing, stage dispatch) dominates
#: — exactly the regime the vectorized plane exists for.
BATCH_SPECS = (
    "int8(1024)",
    "topk(0.01)|int8(256)",
    "delta|ef|topk(0.01)|int8(1024)",
)
BATCH_CLIENTS = (1, 16, 64, 256)
BATCH_PARAMS = 256
#: The CI gate: combined encode+decode speedup the numpy batch path must
#: clear at this many clients (ISSUE 9 acceptance).
BATCH_GATE_CLIENTS = 256
BATCH_GATE_SPEEDUP = 4.0


def _bench_batch_point(pipeline, vecs, repeats: int) -> dict:
    """One (spec, n_clients) point: loop vs batch.  Stateful/delta
    pipelines get fresh per-rep states so both paths do identical work;
    stateless ones run with no caller state, the shape the server's hot
    path actually uses.  Each side reports the best of three timed blocks
    (min damps scheduler/allocator noise in shared CI containers)."""
    n_clients = len(vecs)
    needs_state = pipeline.caps.stateful or pipeline.caps.delta_domain

    def loop_once():
        if not needs_state:
            datas = [pipeline.encode(v) for v in vecs]
        else:
            states = [_fresh_state(pipeline, vecs[0])
                      for _ in range(n_clients)]
            datas = [pipeline.encode(v, s) for v, s in zip(vecs, states)]
        for d in datas:
            decode_payload(d)
        return datas

    def batch_once():
        if not needs_state:
            datas = pipeline.encode_batch(vecs)
        else:
            states = [_fresh_state(pipeline, vecs[0])
                      for _ in range(n_clients)]
            datas = pipeline.encode_batch(vecs, states)
        decode_payload_batch(datas)
        return datas

    def best_of(fn, trials=3):
        best = float("inf")
        for _ in range(trials):
            t0 = time.perf_counter()
            for _ in range(repeats):
                fn()
            best = min(best, (time.perf_counter() - t0) / repeats)
        return best

    loop_bytes, batch_bytes = loop_once(), batch_once()   # warm + parity
    loop_s = best_of(loop_once)
    batch_s = best_of(batch_once)

    in_mb = n_clients * vecs[0].size * 4 / 1e6
    return {
        "n_clients": n_clients,
        "loop_us": loop_s * 1e6,
        "batch_us": batch_s * 1e6,
        "loop_mb_s": in_mb / loop_s,
        "batch_mb_s": in_mb / batch_s,
        "speedup": loop_s / batch_s,
        "bytes_identical": batch_bytes == loop_bytes,
    }


def run_batch_sweep(repeats: int) -> list[dict]:
    rng = np.random.default_rng(0)
    out = []
    for spec in BATCH_SPECS:
        pipeline = parse_pipeline(spec)
        points = []
        for n_clients in BATCH_CLIENTS:
            vecs = [rng.standard_normal(BATCH_PARAMS).astype(np.float32)
                    for _ in range(n_clients)]
            points.append(_bench_batch_point(pipeline, vecs, repeats))
        out.append({"spec": pipeline.spec, "n_params": BATCH_PARAMS,
                    "points": points})
    return out


def _batch_gate_failures(sweep: list[dict]) -> list[str]:
    """CI gate: parity everywhere; >=BATCH_GATE_SPEEDUP at the gate point
    for at least one swept spec (the gate pins the *plane*, not every
    composition — a raw-dominated spec has less overhead to amortize)."""
    failures = []
    best_at_gate = 0.0
    for entry in sweep:
        for pt in entry["points"]:
            if not pt["bytes_identical"]:
                failures.append(f"{entry['spec']} @N={pt['n_clients']}: "
                                f"batch bytes != loop bytes")
            if pt["n_clients"] == BATCH_GATE_CLIENTS:
                best_at_gate = max(best_at_gate, pt["speedup"])
    if best_at_gate < BATCH_GATE_SPEEDUP:
        failures.append(
            f"batch speedup at {BATCH_GATE_CLIENTS} clients is "
            f"{best_at_gate:.2f}x (< {BATCH_GATE_SPEEDUP:.1f}x gate)")
    return failures


def run(n_params: int, repeats: int) -> dict:
    rng = np.random.default_rng(0)
    vec = rng.standard_normal(n_params).astype(np.float32)
    batch_sweep = run_batch_sweep(repeats)
    return {
        "n_params": n_params,
        "repeats": repeats,
        "pipelines": [_bench_spec(s, vec, repeats) for s in SPECS],
        "batch_sweep": batch_sweep,
        "batch_gate": {
            "n_clients": BATCH_GATE_CLIENTS,
            "required_speedup": BATCH_GATE_SPEEDUP,
            "failures": _batch_gate_failures(batch_sweep),
        },
        "determinism_failures": _determinism_check(vec),
    }


def bench():
    """benchmarks.run contract: yield (row, us_per_call, derived)."""
    report = run(n_params=1_000_000, repeats=3)
    rows = []
    for p in report["pipelines"]:
        rows.append((
            f"wire/{p['spec']}",
            p["encode_us"] + p["decode_us"],
            f"bytes_per_param={p['bytes_per_param']:.3f}"
            f";enc_mb_s={p['encode_mb_s']:.0f}"
            f";dec_mb_s={p['decode_mb_s']:.0f}",
        ))
    for entry in report["batch_sweep"]:
        gate_pt = entry["points"][-1]
        rows.append((
            f"wire_batch/{entry['spec']}@{gate_pt['n_clients']}",
            gate_pt["batch_us"],
            f"speedup={gate_pt['speedup']:.2f}x"
            f";batch_mb_s={gate_pt['batch_mb_s']:.0f}"
            f";bytes_identical={gate_pt['bytes_identical']}",
        ))
    failures = (report["determinism_failures"]
                + report["batch_gate"]["failures"])
    status = "ok" if not failures else ";".join(failures)
    rows.append(("wire/determinism", 0.0, status))
    return rows


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--params", type=int, default=1_000_000)
    ap.add_argument("--repeats", type=int, default=3)
    ap.add_argument("--out", default=None, help="write BENCH_wire.json here")
    ap.add_argument("--check", action="store_true",
                    help="exit non-zero if the determinism check fails")
    args = ap.parse_args()

    report = run(args.params, args.repeats)
    for p in report["pipelines"]:
        print(f"{p['spec']:34s} {p['bytes_per_param']:7.3f} B/param  "
              f"enc {p['encode_mb_s']:8.0f} MB/s  "
              f"dec {p['decode_mb_s']:8.0f} MB/s  "
              f"max_err {p['max_abs_err']:.2e}")
    print(f"\nbatch plane (P={BATCH_PARAMS}, encode+decode, "
          f"batch vs loop):")
    for entry in report["batch_sweep"]:
        cells = "  ".join(
            f"N={pt['n_clients']:<3d} {pt['speedup']:5.2f}x"
            for pt in entry["points"])
        print(f"{entry['spec']:34s} {cells}")
    if args.out:
        with open(args.out, "w") as f:
            json.dump(report, f, indent=2)
        print(f"wrote {args.out}")
    failures = (report["determinism_failures"]
                + report["batch_gate"]["failures"])
    if failures:
        for fail in failures:
            print(f"WIRE GATE FAILURE: {fail}", file=sys.stderr)
        if args.check:
            sys.exit(1)
    elif args.check:
        print(f"determinism check: ok; batch gate: ok "
              f"(>= {BATCH_GATE_SPEEDUP:.0f}x at "
              f"{BATCH_GATE_CLIENTS} clients)")


if __name__ == "__main__":
    main()
