"""Benchmark 1 — paper §V test cases (Figs 5-7), quantified, for every
registered transport.

For each (transport, scenario): simulated transaction duration, data packets
sent, retransmissions, and delivery completeness through the unified
``Delivery`` contract. The paper reports ~17.5 s for the triple-loss case on
its 5 Mbps / 2000 ms link with MUDP; the same scenario lands in that band
here. Reliable transports must deliver the exact bytes; the UDP baseline
reports whatever fraction survived.

Iterates ``available_transports()`` so newly registered protocols (e.g.
``mudp+fec``) are measured on the paper's scenarios for free.
"""

from __future__ import annotations

import time

from repro.core import TransportConfig, available_transports, make_transport
from repro.core.channel import DropList, Link
from repro.core.packetizer import packetize
from repro.core.simulator import Simulator

CLIENT, SERVER = "10.1.2.4", "10.1.2.5"
RATE, DELAY = 5_000_000.0, 2_000_000_000

CASES = {
    "tc1_drop_pkt2": {(2, 0)},
    "tc2_drop_tail": {(2, 0), (3, 0), (4, 0)},
    "tc3_lossless": set(),
}


def run_case(transport_name: str, drops):
    cfg = TransportConfig(kind=transport_name, timeout_ns=6_000_000_000,
                          udp_deadline_ns=30_000_000_000, fec_block=4)
    transport = make_transport(transport_name)
    sim = Simulator()
    sim.connect(CLIENT, SERVER, Link(RATE, DELAY, DropList(drops)),
                Link(RATE, DELAY))
    data = bytes(range(256)) * 18  # ~4.6KB -> 4 packets at MTU 1228
    pkts = packetize(data, CLIENT, mtu=1228)
    assert len(pkts) == 4
    got, outcome = {}, {}
    rx = transport.create_receiver(sim, sim.node(SERVER), cfg,
                                   lambda d: got.update(d=d))
    tx = transport.create_sender(sim, sim.node(CLIENT), sim.node(SERVER),
                                 pkts, cfg,
                                 on_complete=lambda s: outcome.update(ok=True),
                                 on_fail=lambda s: outcome.update(ok=False))
    tx.start()
    sim.run()
    d = got.get("d")
    if transport.caps.reliable:
        assert outcome.get("ok") and d is not None and d.complete
        assert d.reassemble() == data
    return tx, rx, d


def bench():
    rows = []
    for name in available_transports():
        for case, drops in CASES.items():
            t0 = time.perf_counter()
            tx, rx, d = run_case(name, drops)
            wall_us = (time.perf_counter() - t0) * 1e6
            delivered = 0 if d is None else len(d.packets)
            total = 4 if d is None else d.total
            rows.append((f"transport_scenarios/{name}_{case}", wall_us,
                         f"sim_s={tx.stats.duration_ns/1e9:.2f}"
                         f";sent={tx.stats.data_sent}"
                         f";retx={tx.stats.retransmissions}"
                         f";parity={tx.stats.parity_sent}"
                         f";nacks={getattr(rx, 'stats_nacks_sent', 0)}"
                         f";delivered={delivered}/{total}"))
    return rows


def main():
    for name, us, derived in bench():
        print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
