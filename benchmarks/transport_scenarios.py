"""Benchmark 1 — paper §V test cases (Figs 5-7), quantified.

For each scenario: simulated transaction duration, data packets sent,
retransmissions, NACKs, and timer-path retries. The paper reports ~17.5 s
for the triple-loss case on its 5 Mbps / 2000 ms link; the same scenario
lands in that band here.
"""

from __future__ import annotations

import time

from repro.core.channel import DropList, Link, NoLoss
from repro.core.mudp import MudpReceiver, MudpSender
from repro.core.packetizer import packetize, reassemble
from repro.core.simulator import Simulator

CLIENT, SERVER = "10.1.2.4", "10.1.2.5"
RATE, DELAY = 5_000_000.0, 2_000_000_000


def run_case(drops):
    sim = Simulator()
    sim.connect(CLIENT, SERVER, Link(RATE, DELAY, DropList(drops)),
                Link(RATE, DELAY))
    data = bytes(range(256)) * 18  # ~4.6KB -> 4 packets at MTU 1228
    pkts = packetize(data, CLIENT, mtu=1228)
    assert len(pkts) == 4
    got, ok = {}, {}
    rx = MudpReceiver(sim, sim.node(SERVER),
                      on_deliver=lambda a, t, p: got.update(p))
    tx = MudpSender(sim, sim.node(CLIENT), sim.node(SERVER), pkts,
                    timeout_ns=6_000_000_000,
                    on_complete=lambda s: ok.update(v=True))
    tx.start()
    sim.run()
    assert ok.get("v") and reassemble(got) == data
    return tx, rx


def bench():
    rows = []
    cases = {
        "tc1_drop_pkt2": {(2, 0)},
        "tc2_drop_tail": {(2, 0), (3, 0), (4, 0)},
        "tc3_lossless": set(),
    }
    for name, drops in cases.items():
        t0 = time.perf_counter()
        tx, rx = run_case(drops)
        wall_us = (time.perf_counter() - t0) * 1e6
        rows.append((f"transport_scenarios/{name}", wall_us,
                     f"sim_s={tx.stats.duration_ns/1e9:.2f}"
                     f";retx={tx.stats.retransmissions}"
                     f";nacks={rx.stats_nacks_sent}"
                     f";timer_retries={tx.stats.last_packet_retries}"))
    return rows


def main():
    for name, us, derived in bench():
        print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
