"""Benchmark — adaptive transport control vs every static wire/FEC config:
simulated time-to-target-loss on the mixed fiber/lte/congested-edge cohort.

Same seeded fleet, same links, same :class:`ConsensusObjective`; the only
variable is ``FleetConfig.control``.  The static arms pin each tier of the
adaptive policy's ladder (``repro.core.control.DEFAULT_TIERS``) fleet-wide
— light compression + no FEC, the medium middle, heavy compression + dense
parity — while the adaptive arm starts every client on the middle rung and
lets the loss-rate EWMA walk it: fiber clients relax to the light tier
(more signal per round, zero parity overhead), congested-edge clients
escalate to the heavy tier (updates that actually survive and arrive
before the deadline).  No single static configuration fits a mixed cohort,
which is exactly the claim ``--check`` gates CI on:

    adaptive time-to-target < every static arm's time-to-target

``--check`` also re-runs the full orchestrator-equivalence digest matrix
with ``control="static"`` explicitly set, proving the control plane is a
pure add-on: all pinned digests must stay byte-identical.

  PYTHONPATH=src python benchmarks/adaptive_bench.py
  PYTHONPATH=src python benchmarks/adaptive_bench.py --check
"""

from __future__ import annotations

import argparse
import json
import sys
import time

from repro.core import (FLConfig, FleetConfig, TransportConfig,
                        build_fleet_training)
from repro.core.control import DEFAULT_TIERS

NS = 1_000_000_000

#: The static arms: each ladder tier, pinned fleet-wide.
STATIC_ARMS = {f"static/tier{i}": t for i, t in enumerate(DEFAULT_TIERS)}


def _fl_cfg(*, uplink: str, fec_block: int, fec_parity: int,
            deadline_ns: int) -> FLConfig:
    return FLConfig(
        aggregation="fedavg",
        transport=TransportConfig(
            kind="mudp+fec", uplink=uplink, downlink="int8(1024)",
            fec_block=fec_block, fec_parity=fec_parity,
            timeout_ns=2 * NS, udp_deadline_ns=3 * NS))


def time_to_target(arm: str, *, n_clients: int, seed: int, target_frac: float,
                   n_params: int, max_rounds: int, deadline_ns: int,
                   engine: str) -> dict:
    """Run one arm until the loss target is crossed (or max_rounds)."""
    if arm == "adaptive":
        control, tier = "adaptive", DEFAULT_TIERS[1]   # the starting rung
    else:
        control, tier = "static", STATIC_ARMS[arm]
    fleet = FleetConfig(n_clients=n_clients, seed=seed, engine=engine,
                        model="consensus",
                        model_args={"n_params": n_params},
                        round_deadline_ns=deadline_ns, control=control)
    build = build_fleet_training(
        fleet, _fl_cfg(uplink=tier["uplink"], fec_block=tier["fec_block"],
                       fec_parity=tier["fec_parity"],
                       deadline_ns=deadline_ns))
    sim, system, model = build.sim, build.system, build.model
    loss0 = model.loss(system.global_params)
    target = target_frac * loss0
    trace: list[dict] = []

    def on_round(res, params):
        trace.append({"round": res.round_idx, "sim_ns": sim.now_ns,
                      "loss": model.loss(params),
                      "arrived": len(res.arrived),
                      "decode_errors": res.decode_errors})
    system.on_round_end = on_round
    t0 = time.perf_counter()
    system.run_rounds(max_rounds)
    wall_s = time.perf_counter() - t0

    crossed = next((row for row in trace if row["loss"] <= target), None)
    core = system.core
    reneg_by_cohort: dict[str, int] = {}
    for p in build.profiles:
        reneg_by_cohort[p.cohort] = (reneg_by_cohort.get(p.cohort, 0)
                                     + core.renegotiations.get(p.addr, 0))
    return {
        "arm": arm,
        "initial_loss": loss0,
        "target_loss": target,
        "rounds_run": len(trace),
        "rounds_to_target": crossed["round"] + 1 if crossed else None,
        "sim_ns_to_target": crossed["sim_ns"] if crossed else None,
        "final_loss": trace[-1]["loss"] if trace else loss0,
        "renegotiations": sum(core.renegotiations.values()),
        "renegotiations_by_cohort": dict(sorted(reneg_by_cohort.items())),
        "decode_errors": sum(r["decode_errors"] for r in trace),
        "trace": trace,
        "wall_s": wall_s,
    }


def compare(args) -> dict:
    kw = dict(n_clients=args.clients, seed=args.seed,
              target_frac=args.target_frac, n_params=args.params,
              max_rounds=args.max_rounds,
              deadline_ns=int(args.deadline_s * NS), engine=args.engine)
    arms = {name: time_to_target(name, **kw)
            for name in (*STATIC_ARMS, "adaptive")}
    adaptive_ns = arms["adaptive"]["sim_ns_to_target"]
    beats_all = adaptive_ns is not None and all(
        cell["sim_ns_to_target"] is None
        or adaptive_ns < cell["sim_ns_to_target"]
        for name, cell in arms.items() if name != "adaptive")
    return {"meta": vars(args), "arms": arms,
            "adaptive_beats_every_static": beats_all}


def digests_frozen_under_static() -> list[str]:
    """Re-run the pinned orchestrator-equivalence matrix with
    ``control="static"`` explicitly set; return the mismatches.  Empty
    means the control plane provably does not perturb the default path."""
    import pathlib
    tests_dir = str(pathlib.Path(__file__).resolve().parent.parent / "tests")
    if tests_dir not in sys.path:
        sys.path.insert(0, tests_dir)
    from test_orchestrator_equivalence import (EXPECTED, PACKET_ENGINES,
                                               run_digest)
    mismatches = []
    for (scenario, kind), want in sorted(EXPECTED.items()):
        for engine in PACKET_ENGINES:
            got = run_digest(scenario, kind, engine, control="static")
            if got != want:
                mismatches.append(f"{scenario}/{kind}/{engine}: "
                                  f"{got} != pinned {want}")
    return mismatches


def bench(rounds: int = 1):
    """benchmarks.run harness entry: one small comparison cell."""
    ns = argparse.Namespace(clients=24, seed=0, target_frac=0.02,
                            params=1024, max_rounds=12, deadline_s=20.0,
                            engine="batched", check=False, out=None)
    report = compare(ns)
    rows = []
    for name, cell in report["arms"].items():
        rows.append((f"adaptive/{name.replace('/', '_')}_c24",
                     cell["wall_s"] * 1e6,
                     f"sim_s_to_target="
                     f"{(cell['sim_ns_to_target'] or 0) / 1e9:.2f}"
                     f";rounds={cell['rounds_to_target']}"
                     f";reneg={cell['renegotiations']}"
                     f";final_loss={cell['final_loss']:.4f}"))
    rows.append(("adaptive/beats_every_static", 0.0,
                 str(report["adaptive_beats_every_static"])))
    return rows


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--clients", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--target-frac", type=float, default=0.02,
                    help="target loss as a fraction of the initial loss")
    ap.add_argument("--params", type=int, default=2048)
    ap.add_argument("--max-rounds", type=int, default=16)
    ap.add_argument("--deadline-s", type=float, default=20.0,
                    help="sync round deadline (straggler cutoff)")
    ap.add_argument("--engine", default="batched",
                    choices=["batched", "per_packet"])
    ap.add_argument("--out", default=None, help="optional JSON report path")
    ap.add_argument("--check", action="store_true",
                    help="exit non-zero unless adaptive reaches the target "
                         "in strictly less simulated time than every "
                         "static arm AND control='static' leaves every "
                         "pinned orchestrator digest byte-identical")
    args = ap.parse_args()

    report = compare(args)
    for name, cell in report["arms"].items():
        sim_s = (cell["sim_ns_to_target"] or 0) / 1e9
        crossed = cell["rounds_to_target"] is not None
        print(f"{name:>13}: L0={cell['initial_loss']:.3f} -> target "
              f"{cell['target_loss']:.4f} "
              + (f"in {cell['rounds_to_target']} rounds, "
                 f"sim t={sim_s:.2f}s" if crossed else
                 f"NOT REACHED (final {cell['final_loss']:.4f})")
              + f", reneg={cell['renegotiations']} "
              f"{cell['renegotiations_by_cohort']}", flush=True)
    print("adaptive beats every static arm:"
          f" {report['adaptive_beats_every_static']}")

    if args.out:
        with open(args.out, "w") as f:
            json.dump(report, f, indent=1, sort_keys=True)
        print(f"wrote {args.out}")

    if args.check:
        ok = True
        if not report["adaptive_beats_every_static"]:
            print("CHECK FAILED: a static arm matched or beat adaptive "
                  "time-to-target", file=sys.stderr)
            ok = False
        mismatches = digests_frozen_under_static()
        if mismatches:
            print("CHECK FAILED: control='static' perturbed pinned "
                  "digests:", file=sys.stderr)
            for m in mismatches:
                print(f"  {m}", file=sys.stderr)
            ok = False
        else:
            print("digest check passed: control='static' leaves all "
                  f"pinned digests byte-identical")
        if not ok:
            return 1
        print("check passed: adaptive < every static arm")
    return 0


if __name__ == "__main__":
    sys.exit(main())
