"""Benchmark 3 — fleet-scale FL: heterogeneous cohorts, partial
participation, straggler-aware rounds, for every registered transport.

For each fleet size and each transport in ``available_transports()`` the
same seeded :class:`FleetConfig` (identical cohorts, link draws, and
per-round client samples — the transport is the only variable) runs
``--rounds`` FL rounds of the synthetic consensus objective and reports:
simulated round time, rounds/sec (simulated and wall), bytes on wire
(total and per hop), retransmissions, arrivals vs roster (stragglers cut
at the deadline), and rounds-to-target-loss.  ``--topology hier|gossip``
swaps the wiring (edge aggregation / serverless peer exchange —
``repro.core.topology``); a ``scaling`` section summarizes
clients-vs-wall-time across the ``--clients`` sweep.  Results land in ``--out`` (default
``BENCH_fleet.json``); everything outside the top-level ``"wall"`` key is
bit-for-bit reproducible for a fixed seed (``--replay-check`` proves it by
running the whole matrix twice).

``--engine flow`` swaps in the analytic flow engine
(``repro.core.flow``): same seeded scenario, per-burst closed forms
instead of per-packet events — the only engine that takes 10k/100k-client
fleets through CI in minutes.  ``--flow-gate`` additionally runs one mudp
cell on both the batched and flow engines and fails unless flow clears
``--flow-gate-min`` x the packet events per wall second.

The process exits non-zero if any requested transport is missing from the
results — CI uses this so no transport is ever silently skipped.

  PYTHONPATH=src python benchmarks/fleet_scale.py --clients 100 --rounds 2
  PYTHONPATH=src python benchmarks/fleet_scale.py --clients 64 --rounds 1 \\
      --replay-check
  PYTHONPATH=src python benchmarks/fleet_scale.py --clients 10000,100000 \\
      --engine flow --topology hier --cells 32 --transports mudp \\
      --flow-gate
"""

from __future__ import annotations

import argparse
import json
import sys
import time

from repro.core import (ConsensusObjective, FLConfig, FleetConfig,
                        TransportConfig, available_transports, build_fleet,
                        cohort_counts, profiles_digest)

NS_PER_SEC = 1_000_000_000


def run_fleet(transport: str, *, n_clients: int, rounds: int, seed: int,
              participation: float, deadline_ns: int, n_params: int,
              engine: str = "batched", mode: str = "sync",
              buffer_k: int = 8, topology: str = "star", cells: int = 4,
              neighbors: int = 4) -> dict:
    """One (transport, fleet size) cell. Returns a JSON-ready dict whose
    every field derives from the simulation — no wall-clock anywhere.
    ``mode="async"`` runs FedBuff-style scheduling: each row is one
    buffered aggregation instead of one barrier round.  ``topology``
    picks the wiring (repro.core.topology): ``star``, ``hier`` (with
    ``cells`` edge aggregators), or ``gossip`` (degree ``neighbors``)."""
    fleet = FleetConfig(n_clients=n_clients, seed=seed,
                        participation_fraction=participation,
                        round_deadline_ns=deadline_ns, engine=engine,
                        mode=mode, buffer_k=buffer_k, topology=topology,
                        cells=min(cells, n_clients),
                        neighbors=min(neighbors, n_clients - 1))
    objective = ConsensusObjective(n_clients, n_params, seed=seed)
    fl_cfg = FLConfig(
        aggregation="fedavg",
        transport=TransportConfig(kind=transport,
                                  timeout_ns=2 * NS_PER_SEC,
                                  udp_deadline_ns=3 * NS_PER_SEC))
    sim, system, profiles = build_fleet(fleet, objective.init_params(),
                                        objective.train_fn, fl_cfg)
    loss0 = objective.loss(system.global_params)
    round_rows, losses = [], []

    # Loss must be sampled per aggregation event: under async scheduling
    # rounds complete *inside* one run_rounds() call, so a post-hoc loop
    # would only ever see the final model.
    def _on_round(r, params):
        loss = objective.loss(params)
        losses.append(loss)
        round_rows.append({
            "round": r.round_idx,
            "duration_ns": r.duration_ns,
            "roster": len(r.roster),
            "arrived": len(r.arrived),
            "failed": len(r.failed),
            "late_folded": r.late_folded,
            "bytes_sent": r.bytes_sent,
            "packets_sent": r.packets_sent,
            "packets_dropped": r.packets_dropped,
            "retransmissions": r.retransmissions,
            "data_packets": r.data_packets,
            "nack_packets": r.nack_packets,
            "parity_packets": r.parity_packets,
            "decode_errors": r.decode_errors,
            "bcast_cache_hits": r.bcast_cache_hits,
            "staleness_clamped": r.staleness_clamped,
            "metrics": r.metrics,
            "loss": loss,
        })

    system.on_round_end = _on_round
    system.run_rounds(rounds)
    sim_ns = sum(r["duration_ns"] for r in round_rows)
    # len(round_rows), not the requested count: an async run may drain
    # early with fewer aggregations than asked for.
    return {
        "cohorts": cohort_counts(profiles),
        "hop_bytes": dict(sorted(sim.hop_bytes.items())),
        "profiles_digest": profiles_digest(profiles),
        "rounds": round_rows,
        "sim_time_ns": sim_ns,
        "rounds_per_sim_sec": (len(round_rows) * NS_PER_SEC / sim_ns)
        if sim_ns else None,
        "bytes_on_wire": sum(r["bytes_sent"] for r in round_rows),
        "retransmissions": sum(r["retransmissions"] for r in round_rows),
        "initial_loss": loss0,
        "final_loss": losses[-1] if losses else loss0,
        "rounds_to_target_loss": next(
            (i + 1 for i, l in enumerate(losses) if l <= 0.1 * loss0), None),
    }


def run_matrix(args, transports: list[str]) -> tuple[dict, dict, dict]:
    """(deterministic results, wall-clock section, errors)."""
    fleets: dict = {}
    wall: dict = {}
    errors: dict = {}
    for n_clients in args.clients:
        fleets[str(n_clients)] = {"transports": {}}
        wall[str(n_clients)] = {}
        for tr in transports:
            t0 = time.perf_counter()
            try:
                cell = run_fleet(
                    tr, n_clients=n_clients, rounds=args.rounds,
                    seed=args.seed, participation=args.participation,
                    deadline_ns=int(args.deadline_s * NS_PER_SEC),
                    n_params=args.params, engine=args.engine,
                    mode=args.mode, buffer_k=args.buffer_k,
                    topology=args.topology, cells=args.cells,
                    neighbors=args.neighbors)
            except Exception as e:  # noqa: BLE001 - a cell failure is a row
                errors[f"{n_clients}/{tr}"] = f"{type(e).__name__}: {e}"
                continue
            wall_s = time.perf_counter() - t0
            fleets[str(n_clients)]["transports"][tr] = cell
            wall[str(n_clients)][tr] = {
                "wall_s": wall_s,
                "rounds_per_wall_sec": args.rounds / wall_s if wall_s else None,
            }
            print(f"fleet_scale/{tr}_c{n_clients},{wall_s * 1e6:.1f},"
                  f"sim_s={cell['sim_time_ns'] / 1e9:.2f}"
                  f";bytes={cell['bytes_on_wire']}"
                  f";retx={cell['retransmissions']}"
                  f";decode_err={sum(r['decode_errors'] for r in cell['rounds'])}"
                  f";bcast_hits={sum(r['bcast_cache_hits'] for r in cell['rounds'])}"
                  f";arrived={sum(r['arrived'] for r in cell['rounds'])}"
                  f"/{sum(r['roster'] for r in cell['rounds'])}"
                  f";loss={cell['final_loss']:.4f}"
                  f";rtt_loss={cell['rounds_to_target_loss']}", flush=True)
    return fleets, wall, errors


def flow_gate(n_clients: int, min_ratio: float, *, rounds: int = 2,
              seed: int = 0) -> dict:
    """Run the same seeded mudp fleet on the batched and flow engines and
    compare simulated packet events per wall second.  The flow engine's
    whole point is per-burst closed forms instead of per-packet events, so
    its event throughput must dominate; *what* it computes is gated
    separately by the distributional tests (tests/test_flow_engine.py)."""
    out: dict = {}
    for engine in ("batched", "flow"):
        t0 = time.perf_counter()
        cell = run_fleet("mudp", n_clients=n_clients, rounds=rounds,
                         seed=seed, participation=0.6,
                         deadline_ns=10 * NS_PER_SEC, n_params=2048,
                         engine=engine)
        wall_s = time.perf_counter() - t0
        events = sum(r["packets_sent"] for r in cell["rounds"])
        out[engine] = {"wall_s": wall_s, "packet_events": events,
                       "events_per_sec": events / wall_s if wall_s else 0.0}
    ratio = (out["flow"]["events_per_sec"]
             / out["batched"]["events_per_sec"]
             if out["batched"]["events_per_sec"] else float("inf"))
    out.update(ratio=ratio, min_ratio=min_ratio, ok=ratio >= min_ratio)
    print(f"flow-gate: clients={n_clients} flow/batched events-per-sec "
          f"ratio {ratio:.1f}x (floor {min_ratio:.1f}x)", flush=True)
    return out


def bench(rounds: int = 1):
    """benchmarks.run harness entry: a small fleet across all transports."""
    rows = []
    for tr in available_transports():
        t0 = time.perf_counter()
        cell = run_fleet(tr, n_clients=16, rounds=rounds, seed=0,
                         participation=0.75, deadline_ns=20 * NS_PER_SEC,
                         n_params=1024)
        wall_us = (time.perf_counter() - t0) * 1e6
        rows.append((f"fleet_scale/{tr}_c16", wall_us,
                     f"sim_s={cell['sim_time_ns'] / 1e9:.2f}"
                     f";bytes={cell['bytes_on_wire']}"
                     f";retx={cell['retransmissions']}"
                     f";loss={cell['final_loss']:.4f}"))
    return rows


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--clients", default="100",
                    help="comma-separated fleet sizes (default 100)")
    ap.add_argument("--rounds", type=int, default=2)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--participation", type=float, default=0.6,
                    help="per-round client sampling fraction")
    ap.add_argument("--deadline-s", type=float, default=10.0,
                    help="server round deadline in simulated seconds "
                         "(straggler cutoff)")
    ap.add_argument("--params", type=int, default=2048,
                    help="model size in float32 parameters")
    ap.add_argument("--transports", default=None,
                    help="comma-separated subset (default: every "
                         "registered transport)")
    ap.add_argument("--engine", default="batched",
                    choices=["batched", "per_packet", "flow"],
                    help="simulator engine: batched/per_packet are "
                         "bit-identical; flow is the analytic fast path "
                         "(statistically equivalent — tests/statcheck.py "
                         "gates it), the only engine that reaches "
                         "100k-client fleets in CI minutes")
    ap.add_argument("--flow-gate", action="store_true",
                    help="run one mudp cell on batched AND flow at "
                         "--flow-gate-clients and fail unless flow "
                         "processes >= --flow-gate-min x the simulated "
                         "packet events per wall second")
    ap.add_argument("--flow-gate-clients", type=int, default=1024,
                    help="fleet size for the --flow-gate comparison "
                         "(large enough for the flow advantage to "
                         "dominate, small enough for batched to finish "
                         "in seconds)")
    ap.add_argument("--flow-gate-min", type=float, default=2.0,
                    help="minimum flow/batched events-per-sec ratio "
                         "(conservative: locally the ratio is >> 10x, "
                         "shared CI runners are noisy)")
    ap.add_argument("--mode", default="sync", choices=["sync", "async"],
                    help="scheduling policy: sync round barrier or "
                         "FedBuff-style async (each row is one buffered "
                         "aggregation; --deadline-s becomes the "
                         "per-session watchdog)")
    ap.add_argument("--buffer-k", type=int, default=8,
                    help="async only: updates buffered per aggregation")
    ap.add_argument("--topology", default="star",
                    choices=["star", "hier", "gossip"],
                    help="fleet wiring (repro.core.topology): the paper's "
                         "star, hierarchical edge aggregation, or "
                         "serverless gossip")
    ap.add_argument("--cells", type=int, default=4,
                    help="hier only: number of edge aggregators "
                         "(clamped to the fleet size)")
    ap.add_argument("--neighbors", type=int, default=4,
                    help="gossip only: target peer degree "
                         "(clamped to n_clients - 1)")
    ap.add_argument("--out", default="BENCH_fleet.json")
    ap.add_argument("--replay-check", action="store_true",
                    help="run the matrix twice and fail unless the "
                         "deterministic results are bit-identical")
    args = ap.parse_args()
    args.clients = [int(c) for c in str(args.clients).split(",") if c]
    if args.rounds < 1 or not args.clients:
        ap.error("--rounds and --clients must be >= 1")

    requested = (args.transports.split(",") if args.transports
                 else available_transports())
    for tr in requested:
        if tr not in available_transports():
            ap.error(f"unknown transport {tr!r}; registered: "
                     f"{available_transports()}")

    fleets, wall, errors = run_matrix(args, requested)

    # Clients-vs-wall-time scaling: one row per fleet size, total wall
    # across transports, so doubling --clients answers "how does the
    # simulator cost grow?" at a glance.
    scaling = []
    for n in args.clients:
        cells = wall.get(str(n), {})
        total = sum(c["wall_s"] for c in cells.values())
        scaling.append({
            "clients": n,
            "wall_s_total": total,
            "wall_s_per_client": total / n if n else None,
            "rounds_per_wall_sec": (len(cells) * args.rounds / total
                                    if total else None),
        })
        print(f"scaling: clients={n} wall_s={total:.2f} "
              f"wall_s_per_client={total / n:.4f}", flush=True)

    gate = (flow_gate(args.flow_gate_clients, args.flow_gate_min,
                      rounds=args.rounds, seed=args.seed)
            if args.flow_gate else None)

    report = {
        "meta": {
            "clients": args.clients,
            "rounds": args.rounds,
            "seed": args.seed,
            "participation": args.participation,
            "deadline_s": args.deadline_s,
            "params": args.params,
            "transports": requested,
            "engine": args.engine,
            "mode": args.mode,
            "buffer_k": args.buffer_k,
            "topology": args.topology,
            "cells": args.cells,
            "neighbors": args.neighbors,
        },
        "fleets": fleets,
        "errors": errors,
        "wall": wall,
        "scaling": scaling,
        "flow_gate": gate,
    }

    if args.replay_check:
        fleets2, _, errors2 = run_matrix(args, requested)
        if (fleets2, errors2) != (fleets, errors):
            print("REPLAY CHECK FAILED: results differ between two runs "
                  "with the same seed", file=sys.stderr)
            return 2
        print("replay-check: bit-identical across two runs", flush=True)

    with open(args.out, "w") as f:
        json.dump(report, f, indent=1, sort_keys=True)
    print(f"wrote {args.out}", flush=True)

    # No transport may be silently skipped: every requested transport must
    # have produced a result cell for every fleet size.
    missing = [f"{n}/{tr}" for n in fleets for tr in requested
               if tr not in fleets[n]["transports"]]
    if missing or errors:
        for key in missing:
            print(f"MISSING RESULT: {key}", file=sys.stderr)
        for key, err in errors.items():
            print(f"TRANSPORT ERROR: {key}: {err}", file=sys.stderr)
        return 1
    if gate is not None and not gate["ok"]:
        print(f"FLOW GATE FAILED: flow/batched events-per-sec ratio "
              f"{gate['ratio']:.2f} < {gate['min_ratio']}", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
