"""Benchmark — vectorized client compute: vmap vs the python loop.

Two halves, both written to ``BENCH_vmap.json`` (the jax-train CI lane
runs ``--check`` and uploads the artifact):

* **Compute matrix** — one full local-training batch (the MNIST MLP at
  smoke scale) at 16 / 64 / 256 clients per round, through the ``python``
  per-client loop and the one-call ``vmap`` backend.  The smoke-scale
  model makes per-client dispatch the dominant cost — exactly the regime
  a scale simulator lives in (PeerFL's argument for batching client
  compute).  Gate: ``vmap`` >= ``--min-speedup`` (default 5x) over the
  python loop at 256 clients.
* **Learning curve** — a 16-client non-IID MNIST fleet (dirichlet
  alpha=0.5 shards) trained over ``mudp`` with every link dropping 10% of
  packets, vmap backend.  Gate: test accuracy reaches ``--target-acc``
  (default 0.95) within ``--max-rounds`` (default 20) — the paper's
  protocol claim made on a real learning workload: MUDP's NACK repair
  keeps convergence intact at loss rates that stall plain UDP.

  PYTHONPATH=src python benchmarks/vmap_train.py --check --out BENCH_vmap.json
  PYTHONPATH=src python -m benchmarks.run --only vmap_train
"""

from __future__ import annotations

import argparse
import json
import sys
import time

import numpy as np

from repro.core import (CohortSpec, FleetConfig, FLConfig, TransportConfig,
                        build_fleet_training)
from repro.core.client_compute import make_model, make_train_backend
from repro.core.packetizer import flatten_to_vector

NS = 1_000_000_000

#: Smoke-scale MLP for the compute matrix: small enough that per-client
#: dispatch overhead dominates (the regime batching exists to fix), big
#: enough (~12.7k params) to exercise the real stack/gather/scan path.
MATRIX_MODEL_ARGS = {"hidden": 16, "batch_size": 16, "local_steps": 1,
                     "shard_size": 128}

#: Full-size training config for the learning-curve gate.
CURVE_MODEL_ARGS = {"hidden": 32, "batch_size": 32, "local_steps": 4,
                    "shard_size": 256, "alpha": 0.5}

#: Every client on a 10%-loss link: the paper's lossy regime, uniform so
#: the curve measures the transport, not cohort luck.
LOSSY10 = CohortSpec(
    name="lossy10",
    up_rate_bps=(20e6, 20e6),
    down_up_ratio=2.0,
    delay_ns=(5_000_000, 20_000_000),
    jitter_frac=0.3,
    loss_p=(0.10, 0.10),
    bursty=False,
    train_time_ns=(200_000_000, 800_000_000),
)


def _time_call(fn, budget_s: float = 1.0) -> tuple[float, int]:
    fn()                                   # warm (jit compile, caches)
    t0 = time.perf_counter()
    reps = 0
    while time.perf_counter() - t0 < budget_s:
        fn()
        reps += 1
    return (time.perf_counter() - t0) / reps, reps


def compute_matrix(client_counts, *, seed: int = 0,
                   budget_s: float = 1.0) -> list[dict]:
    """ms per full-batch local-training call, python loop vs vmap."""
    n_max = max(client_counts)
    model = make_model("mlp", n_max, seed=seed, **MATRIX_MODEL_ARGS)
    vec0 = flatten_to_vector(model.init_params())
    rows = []
    for k in client_counts:
        stack = np.tile(vec0, (k, 1))
        ci = np.arange(k, dtype=np.int32)
        ri = np.zeros(k, np.int32)
        timings = {}
        for name in ("python", "vmap"):
            backend = make_train_backend(name)
            s, reps = _time_call(
                lambda: backend.train(model, stack, ci, ri), budget_s)
            timings[name] = s
            rows.append({"clients": k, "backend": name,
                         "ms_per_call": s * 1e3,
                         "us_per_client": s * 1e6 / k,
                         "reps": reps})
        for row in rows[-2:]:
            row["speedup_vs_python"] = (timings["python"]
                                        / timings[row["backend"]])
    return rows


def learning_curve(*, seed: int = 0, n_clients: int = 16,
                   max_rounds: int = 20, transport: str = "mudp") -> dict:
    """Non-IID MNIST over a uniformly 10%-lossy fleet, vmap backend."""
    fleet = FleetConfig(
        n_clients=n_clients, seed=seed,
        cohorts={"lossy10": LOSSY10}, cohort_mix=(("lossy10", 1.0),),
        model="mlp", train_backend="vmap", model_args=dict(CURVE_MODEL_ARGS))
    fl_cfg = FLConfig(
        aggregation="fedavg",
        transport=TransportConfig(kind=transport, timeout_ns=2 * NS,
                                  udp_deadline_ns=3 * NS))
    build = build_fleet_training(fleet, fl_cfg)
    model, system = build.model, build.system
    curve = []
    t0 = time.perf_counter()
    for r in range(max_rounds):
        res = system.run_round()
        curve.append({"round": r + 1,
                      "accuracy": model.accuracy(system.global_params),
                      "loss": model.loss(system.global_params),
                      "arrived": len(res.arrived),
                      "bytes_sent": res.bytes_sent,
                      "retransmissions": res.retransmissions})
    return {
        "transport": transport,
        "n_clients": n_clients,
        "loss_p": 0.10,
        "alpha": CURVE_MODEL_ARGS["alpha"],
        "data_source": model.data.source,
        "init_accuracy": model.accuracy(model.init_params()),
        "final_accuracy": curve[-1]["accuracy"],
        "curve": curve,
        "batch_sizes": build.trainer.batch_sizes,
        "wall_s": time.perf_counter() - t0,
    }


def rounds_to_accuracy(curve: list[dict], target: float):
    for row in curve:
        if row["accuracy"] >= target:
            return row["round"]
    return None


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--clients", type=int, nargs="+", default=[16, 64, 256])
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--budget-s", type=float, default=1.0,
                    help="timing budget per matrix cell")
    ap.add_argument("--max-rounds", type=int, default=20)
    ap.add_argument("--target-acc", type=float, default=0.95)
    ap.add_argument("--min-speedup", type=float, default=5.0)
    ap.add_argument("--skip-curve", action="store_true")
    ap.add_argument("--check", action="store_true",
                    help="fail unless both gates pass")
    ap.add_argument("--out", default=None)
    args = ap.parse_args(argv)

    matrix = compute_matrix(args.clients, seed=args.seed,
                            budget_s=args.budget_s)
    for row in matrix:
        print(f"clients={row['clients']:>4} {row['backend']:<7} "
              f"{row['ms_per_call']:8.2f} ms/call  "
              f"{row['us_per_client']:7.1f} us/client  "
              f"speedup={row['speedup_vs_python']:.2f}x")

    k_gate = max(args.clients)
    speedup = next(r["speedup_vs_python"] for r in matrix
                   if r["clients"] == k_gate and r["backend"] == "vmap")
    speedup_ok = speedup >= args.min_speedup
    print(f"speedup gate @ {k_gate} clients: {speedup:.2f}x "
          f"(>= {args.min_speedup}x) -> {'PASS' if speedup_ok else 'FAIL'}")

    report = {
        "model_args": MATRIX_MODEL_ARGS,
        "matrix": matrix,
        "gates": {"min_speedup": args.min_speedup,
                  "speedup_clients": k_gate,
                  "speedup": speedup,
                  "speedup_pass": speedup_ok},
    }

    curve_ok = True
    if not args.skip_curve:
        curve = learning_curve(seed=args.seed, max_rounds=args.max_rounds)
        hit = rounds_to_accuracy(curve["curve"], args.target_acc)
        curve_ok = hit is not None
        print(f"learning curve ({curve['transport']}, 10% loss, non-IID "
              f"alpha={curve['alpha']}, {curve['data_source']} data): "
              f"final acc {curve['final_accuracy']:.4f}; target "
              f"{args.target_acc} reached "
              f"{'at round ' + str(hit) if hit else 'NEVER'} "
              f"-> {'PASS' if curve_ok else 'FAIL'}")
        report["learning_curve"] = curve
        report["gates"].update({"target_acc": args.target_acc,
                                "rounds_to_target": hit,
                                "curve_pass": curve_ok})

    if args.out:
        with open(args.out, "w") as f:
            json.dump(report, f, indent=1)
        print(f"wrote {args.out}")

    if args.check and not (speedup_ok and curve_ok):
        print("GATE FAILURE", file=sys.stderr)
        return 1
    return 0


def bench():
    """benchmarks.run suite hook: the small end of the matrix."""
    for row in compute_matrix([16, 64], budget_s=0.3):
        yield (f"vmap_train/{row['backend']}_{row['clients']}c",
               row["ms_per_call"] * 1e3,
               f"speedup={row['speedup_vs_python']:.2f}x")


if __name__ == "__main__":
    sys.exit(main())
