"""Benchmark 3 — global-model quality across FL rounds under packet loss
("maximizing the potential of the Global model in each round", paper §I).

A small MLP on synthetic MNIST, 2 clients, 6 rounds, 10% uplink loss:
MUDP matches the lossless baseline; plain UDP degrades the global model.
Derived: final eval accuracy per transport.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (BernoulliLoss, FederatedSystem, FLClient, FLConfig,
                        Link, NoLoss, Simulator, TransportConfig)
from repro.data import SyntheticMnist

SERVER = "10.1.2.5"


def init_mlp(rng, sizes=(784, 32, 10)):
    params = {}
    for i, (a, b) in enumerate(zip(sizes[:-1], sizes[1:])):
        rng, k = jax.random.split(rng)
        params[f"w{i}"] = (jax.random.normal(k, (a, b))
                           / np.sqrt(a)).astype(jnp.float32)
        params[f"b{i}"] = jnp.zeros((b,), jnp.float32)
    return params


def _forward(params, x):
    h = x
    n = len(params) // 2
    for i in range(n):
        h = h @ jnp.asarray(params[f"w{i}"]) + jnp.asarray(params[f"b{i}"])
        if i < n - 1:
            h = jax.nn.relu(h)
    return h


def mlp_loss(params, x, y):
    logp = jax.nn.log_softmax(_forward(params, x))
    return -jnp.mean(jnp.take_along_axis(logp, y[:, None], axis=1))


@jax.jit
def _sgd(params, x, y, lr=0.1):
    g = jax.grad(mlp_loss)(params, x, y)
    return jax.tree_util.tree_map(lambda p, gi: p - lr * gi, params, g)


def make_train_fn(dataset, cid):
    def train(params, round_idx, client):
        x, y = dataset.sample(256, client=cid, step=round_idx)
        x, y = jnp.asarray(x), jnp.asarray(y)
        for _ in range(3):
            params = _sgd(params, x, y)
        return params, {}
    return train


def accuracy(params, dataset):
    x, y = dataset.sample(1024, client=99, step=1)
    pred = jnp.argmax(_forward(params, jnp.asarray(x)), 1)
    return float((pred == jnp.asarray(y)).mean())


def run(transport: str, p_loss: float, rounds: int = 6):
    ds = SyntheticMnist(seed=0)
    sim = Simulator()
    clients = []
    for i in range(2):
        addr = f"10.1.2.{10 + i}"
        lm = BernoulliLoss(p=p_loss, seed=i) if p_loss else NoLoss()
        sim.connect(addr, SERVER, Link(1e8, 2_000_000, lm),
                    Link(1e8, 2_000_000))
        clients.append(FLClient(addr, make_train_fn(ds, i + 1),
                                train_time_ns=500_000_000))
    cfg = FLConfig(aggregation="fedavg",
                   transport=TransportConfig(kind=transport,
                                             timeout_ns=1_000_000_000,
                                             udp_deadline_ns=2_000_000_000))
    system = FederatedSystem(sim, SERVER, clients,
                             init_mlp(jax.random.PRNGKey(0)), cfg)
    system.run_rounds(rounds)
    return accuracy(system.global_params, ds), system


def bench():
    rows = []
    for name, tr, p in (("lossless_mudp", "mudp", 0.0),
                        ("lossy10_mudp", "mudp", 0.1),
                        ("lossy10_mudp+fec", "mudp+fec", 0.1),
                        ("lossy10_udp", "udp", 0.1)):
        t0 = time.perf_counter()
        acc, system = run(tr, p)
        wall_us = (time.perf_counter() - t0) * 1e6
        rows.append((f"fl_convergence/{name}", wall_us,
                     f"acc6rounds={acc:.3f}"))
    return rows


def main():
    for name, us, derived in bench():
        print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
