from repro.checkpoint.checkpointer import (CheckpointManager, load_pytree,
                                           save_pytree)
from repro.checkpoint.journal import FLJournal

__all__ = ["CheckpointManager", "load_pytree", "save_pytree", "FLJournal"]
