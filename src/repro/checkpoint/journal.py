"""FL round journal: crash-consistent record of per-round transport state.

The server appends an entry per state transition (round started, client
update ingested, round finalized). On restart, the journal tells the server
which round to resume, which client updates were already aggregated, and
which transactions were in flight (those clients simply retransmit —
MUDP's receiver dedups by (addr, txn), so replays are idempotent).
"""

from __future__ import annotations

import json
import os
from typing import Any, Optional


class FLJournal:
    def __init__(self, path: str):
        self.path = path
        self._entries: list[dict] = []
        if os.path.exists(path):
            with open(path) as f:
                self._entries = [json.loads(l) for l in f if l.strip()]

    def append(self, kind: str, **fields) -> None:
        entry = {"kind": kind, **fields}
        self._entries.append(entry)
        with open(self.path, "a") as f:
            f.write(json.dumps(entry) + "\n")
            f.flush()
            os.fsync(f.fileno())

    # -- writers ------------------------------------------------------------
    def round_started(self, round_idx: int, roster: list[str]) -> None:
        self.append("round_started", round=round_idx, roster=roster)

    def update_ingested(self, round_idx: int, client: str) -> None:
        self.append("update_ingested", round=round_idx, client=client)

    def round_finalized(self, round_idx: int, ckpt: str,
                        arrived: list[str], failed: list[str]) -> None:
        self.append("round_finalized", round=round_idx, ckpt=ckpt,
                    arrived=arrived, failed=failed)

    # -- recovery ----------------------------------------------------------
    def last_finalized_round(self) -> Optional[int]:
        for e in reversed(self._entries):
            if e["kind"] == "round_finalized":
                return e["round"]
        return None

    def last_checkpoint(self) -> Optional[str]:
        for e in reversed(self._entries):
            if e["kind"] == "round_finalized":
                return e["ckpt"]
        return None

    def resume_round(self) -> int:
        last = self.last_finalized_round()
        return 0 if last is None else last + 1

    def pending_clients(self) -> list[str]:
        """Clients whose round-in-progress update never finalized."""
        started: Optional[dict] = None
        for e in self._entries:
            if e["kind"] == "round_started":
                started = e
            elif e["kind"] == "round_finalized":
                started = None
        if started is None:
            return []
        done = {e["client"] for e in self._entries
                if e["kind"] == "update_ingested"
                and e["round"] == started["round"]}
        return [c for c in started["roster"] if c not in done]
