"""Checkpointing: self-contained leaf container, atomic writes, retention.

Pytree leaves are serialized path-keyed (shape/dtype-tagged raw bytes,
compressed per leaf), so restore can reshard onto any topology — the
template controls placement, the file stores only bytes.  Writes are
atomic (tmp + fsync + rename) so a crash mid-save never corrupts the
latest checkpoint — that plus the FL journal gives the crash-restart
story at scale.

The container needs nothing beyond the standard library::

    magic "FLCK" | version u8 | codec u8 | manifest_len u32 LE
    manifest JSON: {"metadata": ..., "leaves": [{name, shape, dtype,
                                                 offset, size}, ...]}
    body: concatenated compressed leaf blobs

``codec`` names the compressor per *file*: zlib (always available) or
zstd (used for writes when the ``zstandard`` package is importable —
better ratio and speed, but never required to exist).  A reader that
lacks zstd fails with an explicit error naming the gap instead of a bare
ImportError at module load: environments without optional packages can
still import, write, and read their own checkpoints.
"""

from __future__ import annotations

import json
import os
import struct
import zlib
from typing import Any, Optional

import jax
import numpy as np

try:
    import zstandard as _zstd
except ImportError:          # optional: zlib is the floor, not a stub
    _zstd = None

_MAGIC = b"FLCK"
_VERSION = 2
_CODEC_ZLIB = 0
_CODEC_ZSTD = 1
_HEADER = struct.Struct("<4sBBI")     # magic, version, codec, manifest_len


def _compress(codec: int, raw: bytes) -> bytes:
    if codec == _CODEC_ZSTD:
        return _zstd.ZstdCompressor(level=3).compress(raw)
    return zlib.compress(raw, 6)


def _decompress(codec: int, blob: bytes) -> bytes:
    if codec == _CODEC_ZSTD:
        if _zstd is None:
            raise RuntimeError(
                "this checkpoint was written with zstd compression but the "
                "'zstandard' package is not importable here; install it or "
                "re-save the checkpoint from a zlib-only environment")
        return _zstd.ZstdDecompressor().decompress(blob)
    if codec == _CODEC_ZLIB:
        return zlib.decompress(blob)
    raise ValueError(f"unknown checkpoint codec id {codec}")


def _path_str(path) -> str:
    out = []
    for p in path:
        if hasattr(p, "key"):
            out.append(str(p.key))
        elif hasattr(p, "idx"):
            out.append(str(p.idx))
        elif hasattr(p, "name"):
            out.append(str(p.name))
        else:
            out.append(str(p))
    return "/".join(out)


def save_pytree(path: str, tree: Any, metadata: Optional[dict] = None
                ) -> None:
    codec = _CODEC_ZSTD if _zstd is not None else _CODEC_ZLIB
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    leaves = []
    blobs = []
    offset = 0
    for kpath, leaf in flat:
        arr = np.asarray(leaf)
        blob = _compress(codec, arr.tobytes())
        leaves.append({
            "name": _path_str(kpath),
            "shape": list(arr.shape),
            "dtype": str(arr.dtype),
            "offset": offset,
            "size": len(blob),
        })
        blobs.append(blob)
        offset += len(blob)
    manifest = json.dumps({"metadata": metadata or {},
                           "leaves": leaves}).encode("utf-8")
    tmp = path + ".tmp"
    with open(tmp, "wb") as f:
        f.write(_HEADER.pack(_MAGIC, _VERSION, codec, len(manifest)))
        f.write(manifest)
        for blob in blobs:
            f.write(blob)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, path)   # atomic


def load_pytree(path: str, template: Optional[Any] = None
                ) -> tuple[Any, dict]:
    with open(path, "rb") as f:
        head = f.read(_HEADER.size)
        if len(head) < _HEADER.size:
            raise ValueError(f"{path}: truncated checkpoint header")
        magic, version, codec, manifest_len = _HEADER.unpack(head)
        if magic != _MAGIC:
            raise ValueError(f"{path}: not a checkpoint file "
                             f"(magic {magic!r})")
        if version != _VERSION:
            raise ValueError(f"{path}: unsupported checkpoint version "
                             f"{version} (expected {_VERSION})")
        manifest = json.loads(f.read(manifest_len).decode("utf-8"))
        body = f.read()
    leaves = {rec["name"]: rec for rec in manifest["leaves"]}

    def read(name):
        rec = leaves[name]
        buf = _decompress(codec,
                          body[rec["offset"]:rec["offset"] + rec["size"]])
        dt = rec["dtype"]
        if dt == "bfloat16":
            import ml_dtypes  # part of jax deps
            arr = np.frombuffer(buf, dtype=ml_dtypes.bfloat16)
        else:
            arr = np.frombuffer(buf, dtype=np.dtype(dt))
        return arr.reshape(rec["shape"]).copy()

    if template is None:
        # Rebuild a nested dict from the path keys.
        out: dict = {}
        for name in leaves:
            parts = name.split("/")
            cur = out
            for p in parts[:-1]:
                cur = cur.setdefault(p, {})
            cur[parts[-1]] = read(name)
        return out, manifest["metadata"]

    flat = jax.tree_util.tree_flatten_with_path(template)
    vals = []
    for kpath, leaf in flat[0]:
        name = _path_str(kpath)
        if name not in leaves:
            raise KeyError(f"checkpoint missing leaf {name}")
        arr = read(name)
        want = tuple(np.asarray(leaf).shape)
        if tuple(arr.shape) != want:
            raise ValueError(f"{name}: shape {arr.shape} != template {want}")
        vals.append(arr)
    return jax.tree_util.tree_unflatten(flat[1], vals), manifest["metadata"]


class CheckpointManager:
    """step-indexed directory of checkpoints with retention."""

    SUFFIX = ".ckpt"

    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)

    def _file(self, step: int) -> str:
        return os.path.join(self.dir, f"ckpt_{step:010d}{self.SUFFIX}")

    def save(self, step: int, tree: Any, metadata: Optional[dict] = None
             ) -> str:
        meta = dict(metadata or {}, step=step)
        path = self._file(step)
        save_pytree(path, tree, meta)
        self._gc()
        return path

    def steps(self) -> list[int]:
        out = []
        for f in os.listdir(self.dir):
            if f.startswith("ckpt_") and f.endswith(self.SUFFIX):
                out.append(int(f[5:15]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        s = self.steps()
        return s[-1] if s else None

    def restore(self, template: Any, step: Optional[int] = None
                ) -> tuple[Any, dict]:
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        return load_pytree(self._file(step), template)

    def _gc(self) -> None:
        steps = self.steps()
        for s in steps[:-self.keep]:
            os.remove(self._file(s))
