"""Checkpointing: msgpack + zstd leaf codec, atomic writes, retention.

Pytree leaves are serialized path-keyed (shape/dtype-tagged raw bytes,
zstd-compressed), so restore can reshard onto any topology — the template
controls placement, the file stores only bytes. Writes are atomic
(tmp + rename) so a crash mid-save never corrupts the latest checkpoint —
that plus the FL journal gives the crash-restart story at scale.
"""

from __future__ import annotations

import dataclasses
import json
import os
import shutil
from typing import Any, Optional

import jax
import msgpack
import numpy as np
import zstandard

_CCTX = zstandard.ZstdCompressor(level=3)
_DCTX = zstandard.ZstdDecompressor()


def _path_str(path) -> str:
    out = []
    for p in path:
        if hasattr(p, "key"):
            out.append(str(p.key))
        elif hasattr(p, "idx"):
            out.append(str(p.idx))
        elif hasattr(p, "name"):
            out.append(str(p.name))
        else:
            out.append(str(p))
    return "/".join(out)


def save_pytree(path: str, tree: Any, metadata: Optional[dict] = None
                ) -> None:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    record = {}
    for kpath, leaf in flat:
        arr = np.asarray(leaf)
        record[_path_str(kpath)] = {
            "shape": list(arr.shape),
            "dtype": str(arr.dtype),
            "data": _CCTX.compress(arr.tobytes()),
        }
    blob = msgpack.packb({"leaves": record, "metadata": metadata or {}},
                         use_bin_type=True)
    tmp = path + ".tmp"
    with open(tmp, "wb") as f:
        f.write(blob)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, path)   # atomic


def load_pytree(path: str, template: Optional[Any] = None
                ) -> tuple[Any, dict]:
    with open(path, "rb") as f:
        obj = msgpack.unpackb(f.read(), raw=False)
    leaves = obj["leaves"]

    def read(name):
        rec = leaves[name]
        buf = _DCTX.decompress(rec["data"])
        dt = rec["dtype"]
        if dt == "bfloat16":
            import ml_dtypes  # part of jax deps
            arr = np.frombuffer(buf, dtype=ml_dtypes.bfloat16)
        else:
            arr = np.frombuffer(buf, dtype=np.dtype(dt))
        return arr.reshape(rec["shape"]).copy()

    if template is None:
        # Rebuild a nested dict from the path keys.
        out: dict = {}
        for name in leaves:
            parts = name.split("/")
            cur = out
            for p in parts[:-1]:
                cur = cur.setdefault(p, {})
            cur[parts[-1]] = read(name)
        return out, obj["metadata"]

    flat = jax.tree_util.tree_flatten_with_path(template)
    vals = []
    for kpath, leaf in flat[0]:
        name = _path_str(kpath)
        if name not in leaves:
            raise KeyError(f"checkpoint missing leaf {name}")
        arr = read(name)
        want = tuple(np.asarray(leaf).shape)
        if tuple(arr.shape) != want:
            raise ValueError(f"{name}: shape {arr.shape} != template {want}")
        vals.append(arr)
    return jax.tree_util.tree_unflatten(flat[1], vals), obj["metadata"]


class CheckpointManager:
    """step-indexed directory of checkpoints with retention."""

    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)

    def _file(self, step: int) -> str:
        return os.path.join(self.dir, f"ckpt_{step:010d}.msgpack.zst")

    def save(self, step: int, tree: Any, metadata: Optional[dict] = None
             ) -> str:
        meta = dict(metadata or {}, step=step)
        path = self._file(step)
        save_pytree(path, tree, meta)
        self._gc()
        return path

    def steps(self) -> list[int]:
        out = []
        for f in os.listdir(self.dir):
            if f.startswith("ckpt_") and f.endswith(".msgpack.zst"):
                out.append(int(f[5:15]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        s = self.steps()
        return s[-1] if s else None

    def restore(self, template: Any, step: Optional[int] = None
                ) -> tuple[Any, dict]:
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        return load_pytree(self._file(step), template)

    def _gc(self) -> None:
        steps = self.steps()
        for s in steps[:-self.keep]:
            os.remove(self._file(s))
