"""Whisper-style encoder-decoder backbone.

The audio conv frontend is a STUB per the assignment: ``input_specs()``
supplies precomputed frame embeddings (B, 1500, d). Positions are sinusoidal
(parameter-free) on both sides so any decode horizon is mechanically
supported. Norms are RMSNorm for uniformity with the rest of the zoo
(assumption recorded in DESIGN.md).
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.distributed.sharding import constraint
from repro.models import layers as L
from repro.models.transformer import padded_vocab


def sinusoidal(seq: int, d: int, dtype) -> jax.Array:
    pos = np.arange(seq)[:, None]
    dim = np.arange(d // 2)[None]
    ang = pos / np.power(10000.0, 2 * dim / d)
    out = np.concatenate([np.sin(ang), np.cos(ang)], axis=-1)
    return jnp.asarray(out, dtype)


def _attn_params(ks, Lr, d, H, KV, hd, dt, prefix=""):
    return {
        prefix + "wq": L.dense_init(next(ks), (Lr, d, H, hd), dt, d),
        prefix + "wk": L.dense_init(next(ks), (Lr, d, KV, hd), dt, d),
        prefix + "wv": L.dense_init(next(ks), (Lr, d, KV, hd), dt, d),
        prefix + "wo": L.dense_init(next(ks), (Lr, H, hd, d), dt, H * hd),
    }


def init_encdec(cfg: ModelConfig, rng: jax.Array) -> dict:
    dt = jnp.dtype(cfg.dtype)
    d, hd = cfg.d_model, cfg.resolved_head_dim
    H, KV, F = cfg.num_heads, cfg.num_kv_heads, cfg.d_ff
    Le, Ld = cfg.encoder_layers, cfg.num_layers
    V = padded_vocab(cfg)
    ks = iter(jax.random.split(rng, 40))

    enc_layer = {
        "attn_norm": jnp.ones((Le, d), dt),
        "mlp_norm": jnp.ones((Le, d), dt),
        "w_up": L.dense_init(next(ks), (Le, d, F), dt, d),
        "w_down": L.dense_init(next(ks), (Le, F, d), dt, F),
        **_attn_params(ks, Le, d, H, KV, hd, dt),
    }
    dec_layer = {
        "attn_norm": jnp.ones((Ld, d), dt),
        "cross_norm": jnp.ones((Ld, d), dt),
        "mlp_norm": jnp.ones((Ld, d), dt),
        "w_up": L.dense_init(next(ks), (Ld, d, F), dt, d),
        "w_down": L.dense_init(next(ks), (Ld, F, d), dt, F),
        **_attn_params(ks, Ld, d, H, KV, hd, dt),
        **_attn_params(ks, Ld, d, H, KV, hd, dt, prefix="c"),
    }
    return {
        "embed": L.dense_init(next(ks), (V, d), dt, d),
        "unembed": L.dense_init(next(ks), (d, V), dt, d),
        "enc_layers": enc_layer,
        "dec_layers": dec_layer,
        "enc_norm": jnp.ones((d,), dt),
        "dec_norm": jnp.ones((d,), dt),
    }


def encdec_param_specs(cfg: ModelConfig) -> dict:
    att = {
        "wq": ("layers", "w_data", "heads", "head_dim"),
        "wk": ("layers", "w_data", "kv_heads", "head_dim"),
        "wv": ("layers", "w_data", "kv_heads", "head_dim"),
        "wo": ("layers", "heads", "head_dim", "w_data"),
    }
    mlp = {"w_up": ("layers", "w_data", "d_ff"),
           "w_down": ("layers", "d_ff", "w_data")}
    return {
        "embed": ("vocab", "embed_d"),
        "unembed": ("embed_d", "vocab"),
        "enc_layers": {"attn_norm": ("layers", None),
                       "mlp_norm": ("layers", None), **att, **mlp},
        "dec_layers": {"attn_norm": ("layers", None),
                       "cross_norm": ("layers", None),
                       "mlp_norm": ("layers", None), **att, **mlp,
                       **{"c" + k: v for k, v in att.items()}},
        "enc_norm": (None,),
        "dec_norm": (None,),
    }


# --------------------------------------------------------------------------
# Encoder
# --------------------------------------------------------------------------
def encode(cfg: ModelConfig, params: dict, frames: jax.Array,
           remat_policy: str = "dots") -> jax.Array:
    """frames: precomputed conv-frontend embeddings (B, Te, d)."""
    B, Te, d = frames.shape
    x = frames + sinusoidal(Te, d, frames.dtype)[None]
    x = constraint(x, "batch", "act_seq", None)
    pos = jnp.arange(Te, dtype=jnp.int32)
    KV, G = cfg.num_kv_heads, cfg.q_groups

    def body(h, p):
        a_in = L.rmsnorm(h, p["attn_norm"])
        q, k, v = L.qkv_proj(a_in, p["wq"], p["wk"], p["wv"], KV, G)
        o = L.gqa_attention(q, k, v, q_pos=pos, kv_pos=pos, causal=False)
        h = h + L.out_proj(o, p["wo"])
        m_in = L.rmsnorm(h, p["mlp_norm"])
        h = h + L.mlp(m_in, p, "gelu")
        return h, None

    if remat_policy != "none":
        body = jax.checkpoint(body)
    x, _ = jax.lax.scan(body, x, params["enc_layers"])
    return L.rmsnorm(x, params["enc_norm"])


# --------------------------------------------------------------------------
# Decoder (train forward)
# --------------------------------------------------------------------------
def decode_train(cfg: ModelConfig, params: dict, tokens: jax.Array,
                 enc_out: jax.Array, remat_policy: str = "dots",
                 attn_impl: str = "einsum") -> jax.Array:
    B, S = tokens.shape
    d = cfg.d_model
    x = L.embed_tokens(params["embed"], tokens)
    x = x + sinusoidal(S, d, x.dtype)[None]
    pos = jnp.arange(S, dtype=jnp.int32)
    epos = jnp.arange(enc_out.shape[1], dtype=jnp.int32)
    KV, G = cfg.num_kv_heads, cfg.q_groups

    def body(h, p):
        a_in = L.rmsnorm(h, p["attn_norm"])
        q, k, v = L.qkv_proj(a_in, p["wq"], p["wk"], p["wv"], KV, G)
        o = L.attention(q, k, v, q_pos=pos, kv_pos=pos, causal=True,
                        impl=attn_impl)
        h = h + L.out_proj(o, p["wo"])
        c_in = L.rmsnorm(h, p["cross_norm"])
        cq = jnp.einsum("bsd,dnh->bsnh", c_in, p["cwq"])
        # cross K/V come from the encoder stream
        ck = jnp.einsum("btd,dkh->btkh", enc_out, p["cwk"])
        cv = jnp.einsum("btd,dkh->btkh", enc_out, p["cwv"])
        co = L.gqa_attention(cq, ck, cv, q_pos=pos, kv_pos=epos, causal=False)
        h = h + L.out_proj(co, p["cwo"])
        m_in = L.rmsnorm(h, p["mlp_norm"])
        h = h + L.mlp(m_in, p, "gelu")
        return h, None

    if remat_policy != "none":
        body = jax.checkpoint(body)
    x, _ = jax.lax.scan(body, x, params["dec_layers"])
    return L.rmsnorm(x, params["dec_norm"])


def encdec_loss(cfg: ModelConfig, params: dict, batch: dict, *,
                remat_policy: str = "dots", attn_impl: str = "einsum",
                loss_chunk: int = 0) -> jax.Array:
    enc_out = encode(cfg, params, batch["frames"], remat_policy)
    hidden = decode_train(cfg, params, batch["tokens"], enc_out,
                          remat_policy, attn_impl)
    logits = jnp.einsum("bsd,dv->bsv", hidden, params["unembed"],
                        preferred_element_type=jnp.float32)
    return L.cross_entropy(logits, batch["labels"])


# --------------------------------------------------------------------------
# Serving: prefill + decode with self-KV cache and fixed cross-KV
# --------------------------------------------------------------------------
def init_encdec_cache(cfg: ModelConfig, batch: int, max_len: int) -> dict:
    dt = jnp.dtype(cfg.dtype)
    KV, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    Ld, Te = cfg.num_layers, cfg.encoder_seq
    return {
        "k": jnp.zeros((Ld, batch, max_len, KV, hd), dt),
        "v": jnp.zeros((Ld, batch, max_len, KV, hd), dt),
        "ck": jnp.zeros((Ld, batch, Te, KV, hd), dt),
        "cv": jnp.zeros((Ld, batch, Te, KV, hd), dt),
        "pos": jnp.zeros((), jnp.int32),
    }


def encdec_cache_specs(cfg: ModelConfig) -> dict:
    kv = ("layers", "batch", "kv_seq", "kv_heads", "head_dim")
    ckv = ("layers", "batch", None, "kv_heads", "head_dim")
    return {"k": kv, "v": kv, "ck": ckv, "cv": ckv, "pos": ()}


def encdec_prefill(cfg: ModelConfig, params: dict, tokens: jax.Array,
                   frames: jax.Array, attn_impl: str = "chunked"):
    """Encode audio, precompute per-layer cross-KV, prefill decoder self-KV."""
    enc_out = encode(cfg, params, frames, remat_policy="none")
    B, S = tokens.shape
    d = cfg.d_model
    KV, G = cfg.num_kv_heads, cfg.q_groups
    x = L.embed_tokens(params["embed"], tokens)
    x = x + sinusoidal(S, d, x.dtype)[None]
    pos = jnp.arange(S, dtype=jnp.int32)
    epos = jnp.arange(enc_out.shape[1], dtype=jnp.int32)

    def body(h, p):
        a_in = L.rmsnorm(h, p["attn_norm"])
        q, k, v = L.qkv_proj(a_in, p["wq"], p["wk"], p["wv"], KV, G)
        o = L.attention(q, k, v, q_pos=pos, kv_pos=pos, causal=True,
                        impl=attn_impl)
        h = h + L.out_proj(o, p["wo"])
        c_in = L.rmsnorm(h, p["cross_norm"])
        cq = jnp.einsum("bsd,dnh->bsnh", c_in, p["cwq"])
        ck = jnp.einsum("btd,dkh->btkh", enc_out, p["cwk"])
        cv = jnp.einsum("btd,dkh->btkh", enc_out, p["cwv"])
        co = L.gqa_attention(cq, ck, cv, q_pos=pos, kv_pos=epos, causal=False)
        h = h + L.out_proj(co, p["cwo"])
        m_in = L.rmsnorm(h, p["mlp_norm"])
        h = h + L.mlp(m_in, p, "gelu")
        return h, (k, v, ck, cv)

    x, (k, v, ck, cv) = jax.lax.scan(body, x, params["dec_layers"])
    x = L.rmsnorm(x, params["dec_norm"])
    logits = jnp.einsum("bd,dv->bv", x[:, -1], params["unembed"],
                        preferred_element_type=jnp.float32)
    cache = {"k": k, "v": v, "ck": ck, "cv": cv,
             "pos": jnp.asarray(S, jnp.int32)}
    return logits, cache


def encdec_decode(cfg: ModelConfig, params: dict, cache: dict,
                  tokens: jax.Array):
    B, S1 = tokens.shape
    d = cfg.d_model
    T = cache["k"].shape[2]
    pos = cache["pos"]
    KV, G = cfg.num_kv_heads, cfg.q_groups
    x = L.embed_tokens(params["embed"], tokens)
    # sinusoidal at the current position
    dim = jnp.arange(d // 2, dtype=jnp.float32)
    ang = pos.astype(jnp.float32) / jnp.power(10000.0, 2 * dim / d)
    x = x + jnp.concatenate([jnp.sin(ang), jnp.cos(ang)])[None, None].astype(x.dtype)
    q_pos = jnp.full((S1,), pos, jnp.int32)
    kv_pos = jnp.arange(T, dtype=jnp.int32)
    kv_valid = jnp.broadcast_to((kv_pos <= pos)[None], (B, T))
    epos = jnp.arange(cache["ck"].shape[2], dtype=jnp.int32)

    def body(h, xs):
        p, k_l, v_l, ck_l, cv_l = xs
        a_in = L.rmsnorm(h, p["attn_norm"])
        q, k_new, v_new = L.qkv_proj(a_in, p["wq"], p["wk"], p["wv"], KV, G)
        k_l = jax.lax.dynamic_update_slice(k_l, k_new.astype(k_l.dtype),
                                           (0, pos, 0, 0))
        v_l = jax.lax.dynamic_update_slice(v_l, v_new.astype(v_l.dtype),
                                           (0, pos, 0, 0))
        o = L.gqa_attention(q, k_l, v_l, q_pos=q_pos, kv_pos=kv_pos,
                            causal=True, kv_valid=kv_valid)
        h = h + L.out_proj(o, p["wo"])
        c_in = L.rmsnorm(h, p["cross_norm"])
        cq = jnp.einsum("bsd,dnh->bsnh", c_in, p["cwq"])
        co = L.gqa_attention(cq, ck_l, cv_l, q_pos=q_pos, kv_pos=epos,
                             causal=False)
        h = h + L.out_proj(co, p["cwo"])
        m_in = L.rmsnorm(h, p["mlp_norm"])
        h = h + L.mlp(m_in, p, "gelu")
        return h, (k_l, v_l)

    x, (k, v) = jax.lax.scan(
        body, x, (params["dec_layers"], cache["k"], cache["v"],
                  cache["ck"], cache["cv"]))
    x = L.rmsnorm(x, params["dec_norm"])
    logits = jnp.einsum("bsd,dv->bsv", x, params["unembed"],
                        preferred_element_type=jnp.float32)
    new_cache = dict(cache, k=k, v=v, pos=pos + 1)
    return logits[:, 0], new_cache
