"""Unified model API: every architecture family behind one dispatch.

  init(cfg, rng)            -> params pytree
  param_specs(cfg)          -> logical-axis tree (mirrors params)
  loss_fn(cfg, ...)         -> callable(params, batch) -> scalar
  make_train_step(cfg, opt) -> callable(state, batch) -> (state, metrics)
  make_prefill_step(cfg)    -> callable(params, batch) -> (logits, cache)
  make_decode_step(cfg)     -> callable(params, cache, tokens) -> (logits, cache)
  init_cache / cache_specs  -> per-family serve-state constructors
  input_specs(cfg, shape)   -> ShapeDtypeStruct stand-ins for every input
  batch_specs(cfg, shape)   -> logical-axis tree for the batch

``input_specs`` is the dry-run contract: weak-type-correct, shardable, no
device allocation.
"""

from __future__ import annotations

import functools
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig, TrainConfig
from repro.models import encdec as E
from repro.models import hymba as HY
from repro.models import transformer as T
from repro.models import xlstm as X
from repro.optim import Optimizer, TrainState

TRANSFORMER_FAMILIES = ("dense", "moe", "vlm")


# --------------------------------------------------------------------------
# init / specs
# --------------------------------------------------------------------------
def init(cfg: ModelConfig, rng: jax.Array):
    if cfg.family in TRANSFORMER_FAMILIES:
        return T.init_decoder(cfg, rng)
    if cfg.family == "encdec":
        return E.init_encdec(cfg, rng)
    if cfg.family == "ssm":
        return X.init_xlstm(cfg, rng)
    if cfg.family == "hybrid":
        return HY.init_hymba(cfg, rng)
    raise ValueError(cfg.family)


def param_specs(cfg: ModelConfig):
    if cfg.family in TRANSFORMER_FAMILIES:
        return T.decoder_param_specs(cfg)
    if cfg.family == "encdec":
        return E.encdec_param_specs(cfg)
    if cfg.family == "ssm":
        return X.xlstm_param_specs(cfg)
    if cfg.family == "hybrid":
        return HY.hymba_param_specs(cfg)
    raise ValueError(cfg.family)


def abstract_params(cfg: ModelConfig):
    """Parameter ShapeDtypeStructs without allocating (dry-run path)."""
    return jax.eval_shape(lambda: init(cfg, jax.random.PRNGKey(0)))


# --------------------------------------------------------------------------
# loss / train step
# --------------------------------------------------------------------------
def loss_fn(cfg: ModelConfig, *, attn_impl: str = "einsum",
            remat_policy: str = "dots", loss_chunk: int = 0,
            moe_impl: str = "scan") -> Callable[[Any, dict], jax.Array]:
    if cfg.family in TRANSFORMER_FAMILIES:
        return functools.partial(T.decoder_loss, cfg, attn_impl=attn_impl,
                                 remat_policy=remat_policy,
                                 loss_chunk=loss_chunk, moe_impl=moe_impl)
    if cfg.family == "encdec":
        return functools.partial(E.encdec_loss, cfg, attn_impl=attn_impl,
                                 remat_policy=remat_policy)
    if cfg.family == "ssm":
        return functools.partial(X.xlstm_loss, cfg,
                                 remat_policy=remat_policy)
    if cfg.family == "hybrid":
        return functools.partial(HY.hymba_loss, cfg, attn_impl=attn_impl,
                                 remat_policy=remat_policy)
    raise ValueError(cfg.family)


def make_train_step(cfg: ModelConfig, opt: Optimizer,
                    train_cfg: Optional[TrainConfig] = None,
                    attn_impl: str = "einsum"):
    tc = train_cfg or TrainConfig()
    lf = loss_fn(cfg, attn_impl=attn_impl, remat_policy=tc.remat_policy,
                 loss_chunk=tc.loss_chunk, moe_impl=tc.moe_impl)

    def _grads(params, batch):
        if tc.grad_accum <= 1:
            return jax.value_and_grad(lf)(params, batch)
        # Gradient accumulation: scan over microbatches (the standard
        # memory/throughput trade at scale — activation footprint / n).
        n = tc.grad_accum
        from repro.distributed.sharding import constraint

        def _split(x):
            assert x.shape[0] % n == 0, (x.shape, n)
            y = x.reshape(n, x.shape[0] // n, *x.shape[1:])
            return constraint(y, None, "batch",
                              *([None] * (y.ndim - 2)))

        # positions for M-RoPE are (3, B, S): microbatch on axis 1.
        micro = {}
        for k, v in batch.items():
            if k == "positions":
                y = v.reshape(v.shape[0], n, v.shape[1] // n, *v.shape[2:])
                micro[k] = jnp.moveaxis(y, 1, 0)
            else:
                micro[k] = _split(v)

        adt = jnp.dtype(tc.accum_dtype)
        zeros = jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, adt), params)

        def body(carry, mb):
            gsum, lsum = carry
            loss, g = jax.value_and_grad(lf)(params, mb)
            gsum = jax.tree_util.tree_map(
                lambda a, b: a + b.astype(adt), gsum, g)
            return (gsum, lsum + loss), None

        (gsum, lsum), _ = jax.lax.scan(body, (zeros, jnp.float32(0)), micro)
        grads = jax.tree_util.tree_map(lambda g: g / n, gsum)
        return lsum / n, grads

    def train_step(state: TrainState, batch: dict):
        loss, grads = _grads(state.params, batch)
        new_params, new_opt, om = opt.update(grads, state.opt_state,
                                             state.params, state.step)
        metrics = {"loss": loss, **om}
        return TrainState(state.step + 1, new_params, new_opt), metrics

    return train_step


def init_train_state(cfg: ModelConfig, opt: Optimizer,
                     rng: jax.Array) -> TrainState:
    params = init(cfg, rng)
    return TrainState(jnp.zeros((), jnp.int32), params, opt.init(params))


def abstract_train_state(cfg: ModelConfig, opt: Optimizer) -> TrainState:
    return jax.eval_shape(
        lambda: init_train_state(cfg, opt, jax.random.PRNGKey(0)))


def train_state_specs(cfg: ModelConfig, opt: Optimizer):
    ps = param_specs(cfg)
    return TrainState(step=(), params=ps, opt_state=opt.state_specs(ps))


# --------------------------------------------------------------------------
# serving
# --------------------------------------------------------------------------
def init_cache(cfg: ModelConfig, batch: int, max_len: int):
    if cfg.family in TRANSFORMER_FAMILIES:
        return T.init_cache(cfg, batch, max_len)
    if cfg.family == "encdec":
        return E.init_encdec_cache(cfg, batch, max_len)
    if cfg.family == "ssm":
        return X.init_xlstm_state(cfg, batch)
    if cfg.family == "hybrid":
        return HY.init_hymba_cache(cfg, batch, max_len)
    raise ValueError(cfg.family)


def cache_specs(cfg: ModelConfig):
    if cfg.family in TRANSFORMER_FAMILIES:
        return T.cache_specs(cfg)
    if cfg.family == "encdec":
        return E.encdec_cache_specs(cfg)
    if cfg.family == "ssm":
        return X.xlstm_state_specs(cfg)
    if cfg.family == "hybrid":
        return HY.hymba_cache_specs(cfg)
    raise ValueError(cfg.family)


def make_prefill_step(cfg: ModelConfig, attn_impl: str = "chunked"):
    if cfg.family in TRANSFORMER_FAMILIES:
        def prefill(params, batch):
            return T.decoder_prefill(
                cfg, params, batch["tokens"],
                positions=batch.get("positions"),
                vision_embeds=batch.get("vision_embeds"),
                attn_impl=attn_impl)
        return prefill
    if cfg.family == "encdec":
        def prefill(params, batch):
            return E.encdec_prefill(cfg, params, batch["tokens"],
                                    batch["frames"], attn_impl=attn_impl)
        return prefill
    if cfg.family == "ssm":
        def prefill(params, batch):
            return X.xlstm_prefill(cfg, params, batch["tokens"])
        return prefill
    if cfg.family == "hybrid":
        def prefill(params, batch):
            return HY.hymba_prefill(cfg, params, batch["tokens"],
                                    attn_impl=attn_impl)
        return prefill
    raise ValueError(cfg.family)


def make_decode_step(cfg: ModelConfig):
    if cfg.family in TRANSFORMER_FAMILIES:
        def decode(params, cache, tokens, positions=None):
            return T.decoder_decode(cfg, params, cache, tokens,
                                    positions=positions)
        return decode
    if cfg.family == "encdec":
        def decode(params, cache, tokens, positions=None):
            return E.encdec_decode(cfg, params, cache, tokens)
        return decode
    if cfg.family == "ssm":
        def decode(params, cache, tokens, positions=None):
            return X.xlstm_decode(cfg, params, cache, tokens)
        return decode
    if cfg.family == "hybrid":
        def decode(params, cache, tokens, positions=None):
            return HY.hymba_decode(cfg, params, cache, tokens)
        return decode
    raise ValueError(cfg.family)


# --------------------------------------------------------------------------
# input specs (dry-run contract)
# --------------------------------------------------------------------------
def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of this cell."""
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    dt = jnp.dtype(cfg.dtype)
    sds = jax.ShapeDtypeStruct

    if shape.mode == "train":
        batch = {"tokens": sds((B, S), i32), "labels": sds((B, S), i32)}
        if cfg.family == "encdec":
            batch["frames"] = sds((B, cfg.encoder_seq, cfg.d_model), dt)
        if cfg.mrope:
            batch["positions"] = sds((3, B, S), i32)
            batch["vision_embeds"] = sds((B, cfg.vision_tokens, cfg.d_model),
                                         dt)
        return {"batch": batch}

    if shape.mode == "prefill":
        batch = {"tokens": sds((B, S), i32)}
        if cfg.family == "encdec":
            batch["frames"] = sds((B, cfg.encoder_seq, cfg.d_model), dt)
        if cfg.mrope:
            batch["positions"] = sds((3, B, S), i32)
            batch["vision_embeds"] = sds((B, cfg.vision_tokens, cfg.d_model),
                                         dt)
        return {"batch": batch}

    if shape.mode == "decode":
        cache = jax.eval_shape(lambda: init_cache(cfg, B, shape.kv_len))
        out = {"tokens": sds((B, 1), i32), "cache": cache}
        if cfg.mrope:
            out["positions"] = sds((3, B, 1), i32)
        return out

    raise ValueError(shape.mode)


def batch_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    """Logical-axis tree matching ``input_specs`` (for in_shardings)."""
    tok = ("batch", "act_seq")
    if shape.mode in ("train", "prefill"):
        batch = {"tokens": tok}
        if shape.mode == "train":
            batch["labels"] = tok
        if cfg.family == "encdec":
            batch["frames"] = ("batch", None, None)
        if cfg.mrope:
            batch["positions"] = (None, "batch", "act_seq")
            batch["vision_embeds"] = ("batch", None, None)
        return {"batch": batch}
    out = {"tokens": ("batch", None), "cache": cache_specs(cfg)}
    if cfg.mrope:
        out["positions"] = (None, "batch", None)
    return out
