"""Decoder-only transformer family: dense (granite/starcoder2/yi/gemma3),
MoE (qwen3-moe/olmoe), and VLM (qwen2-vl text backbone + patch-embed prefix).

Structure: stacked per-layer parameters + ``jax.lax.scan`` over layers (one
layer body in the HLO regardless of depth — compact compiles at 512 fake
devices and production-idiomatic). Heterogeneous attention patterns (gemma3's
5 local : 1 global, hymba-style explicit full layers) are a per-layer scalar
flag consumed inside the scan body as a traced window select — no parameter
or compute duplication.

MoE baseline is a scan over experts with top-k combine weights (clean GSPMD
sharding; computes every expert — the deliberate waste shows up in the
roofline usefulness ratio and is the target of the §Perf MoE hillclimb).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.distributed.sharding import constraint
from repro.models import layers as L

def padded_vocab(cfg: ModelConfig) -> int:
    return cfg.padded_vocab


def _dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


def is_global_flags(cfg: ModelConfig) -> np.ndarray:
    """Per-layer bool: True = full/global attention, False = windowed."""
    flags = np.zeros((cfg.num_layers,), dtype=bool)
    if cfg.sliding_window == 0:
        flags[:] = True
    else:
        if cfg.global_every:
            flags[cfg.global_every - 1::cfg.global_every] = True
        for i in cfg.full_attn_layers:
            flags[i] = True
    return flags


# --------------------------------------------------------------------------
# Init
# --------------------------------------------------------------------------
def init_decoder(cfg: ModelConfig, rng: jax.Array) -> dict:
    dt = _dtype(cfg)
    d, hd = cfg.d_model, cfg.resolved_head_dim
    H, KV, F, Lr = cfg.num_heads, cfg.num_kv_heads, cfg.d_ff, cfg.num_layers
    V = padded_vocab(cfg)
    ks = iter(jax.random.split(rng, 16))

    layer: dict[str, jax.Array] = {
        "attn_norm": jnp.ones((Lr, d), dt),
        "mlp_norm": jnp.ones((Lr, d), dt),
        "wq": L.dense_init(next(ks), (Lr, d, H, hd), dt, d),
        "wk": L.dense_init(next(ks), (Lr, d, KV, hd), dt, d),
        "wv": L.dense_init(next(ks), (Lr, d, KV, hd), dt, d),
        "wo": L.dense_init(next(ks), (Lr, H, hd, d), dt, H * hd),
    }
    if cfg.num_experts:
        E = cfg.num_experts
        layer["router"] = L.dense_init(next(ks), (Lr, d, E), dt, d)
        layer["we_gate"] = L.dense_init(next(ks), (Lr, E, d, F), dt, d)
        layer["we_up"] = L.dense_init(next(ks), (Lr, E, d, F), dt, d)
        layer["we_down"] = L.dense_init(next(ks), (Lr, E, F, d), dt, F)
    else:
        if cfg.mlp_type == "swiglu":
            layer["w_gate"] = L.dense_init(next(ks), (Lr, d, F), dt, d)
        layer["w_up"] = L.dense_init(next(ks), (Lr, d, F), dt, d)
        layer["w_down"] = L.dense_init(next(ks), (Lr, F, d), dt, F)

    params = {
        "embed": L.dense_init(next(ks), (V, d), dt, d),
        "final_norm": jnp.ones((d,), dt),
        "layers": layer,
    }
    if not cfg.tie_embeddings:
        params["unembed"] = L.dense_init(next(ks), (d, V), dt, d)
    return params


def decoder_param_specs(cfg: ModelConfig) -> dict:
    """Logical-axis tree mirroring ``init_decoder`` output."""
    layer = {
        "attn_norm": ("layers", None),
        "mlp_norm": ("layers", None),
        "wq": ("layers", "w_data", "heads", "head_dim"),
        "wk": ("layers", "w_data", "kv_heads", "head_dim"),
        "wv": ("layers", "w_data", "kv_heads", "head_dim"),
        "wo": ("layers", "heads", "head_dim", "w_data"),
    }
    if cfg.num_experts:
        layer.update({
            "router": ("layers", "w_data", None),
            "we_gate": ("layers", None, "w_data", "d_ff"),
            "we_up": ("layers", None, "w_data", "d_ff"),
            "we_down": ("layers", None, "d_ff", "w_data"),
        })
    else:
        if cfg.mlp_type == "swiglu":
            layer["w_gate"] = ("layers", "w_data", "d_ff")
        layer["w_up"] = ("layers", "w_data", "d_ff")
        layer["w_down"] = ("layers", "d_ff", "w_data")
    specs = {
        "embed": ("vocab", "embed_d"),
        "final_norm": (None,),
        "layers": layer,
    }
    if not cfg.tie_embeddings:
        specs["unembed"] = ("embed_d", "vocab")
    return specs


# --------------------------------------------------------------------------
# Blocks
# --------------------------------------------------------------------------
def _moe_block(x: jax.Array, p: dict, cfg: ModelConfig) -> jax.Array:
    """Top-k MoE, baseline: scan over ALL experts with combine weights.
    FLOPs = E/k x the active compute — see module docstring."""
    E, K = cfg.num_experts, cfg.num_experts_per_tok
    router_logits = jnp.einsum("bsd,de->bse", x, p["router"],
                               preferred_element_type=jnp.float32)
    probs = jax.nn.softmax(router_logits, axis=-1)
    top_w, top_i = jax.lax.top_k(probs, K)                       # (B,S,K)
    top_w = top_w / jnp.sum(top_w, axis=-1, keepdims=True)
    combine = jnp.sum(
        jax.nn.one_hot(top_i, E, dtype=x.dtype) * top_w[..., None].astype(x.dtype),
        axis=-2)                                                  # (B,S,E)

    def expert_body(acc, xs):
        wg, wu, wd, w_tok = xs            # (d,F) (d,F) (F,d) (B,S)
        h = jax.nn.silu(jnp.einsum("bsd,df->bsf", x, wg)) \
            * jnp.einsum("bsd,df->bsf", x, wu)
        h = h * w_tok[..., None]
        return acc + jnp.einsum("bsf,fd->bsd", h, wd), None

    acc0 = jnp.zeros_like(x)
    combine_e = jnp.moveaxis(combine, -1, 0)                      # (E,B,S)
    out, _ = jax.lax.scan(
        expert_body, acc0,
        (p["we_gate"], p["we_up"], p["we_down"], combine_e))
    return out


MOE_CAPACITY_FACTOR = 2.0   # expert capacity = cf * TK/E (grouped MoE path)


@jax.custom_vjp
def grouped_matmul(lhs: jax.Array, rhs: jax.Array,
                   group_sizes: jax.Array) -> jax.Array:
    """(T,K) x (G,K,N) -> (T,N), rows grouped by ``group_sizes``.

    jax's built-in VJP for ragged_dot falls back to dense per-group masks
    ((T,T) and (G,T,K) f32 monsters — observed 4 GiB buffers in the qwen3
    cell). Both transposes are themselves ragged products, so this custom
    VJP keeps the backward ragged:
      dlhs = ragged_dot(dout, rhs^T)            (ragged non-contracting)
      drhs = ragged_dot_general(lhs, dout)      (ragged CONTRACTING -> per
                                                 group lhs_g^T @ dout_g)
    """
    return jax.lax.ragged_dot(lhs, rhs, group_sizes)


def _gmm_fwd(lhs, rhs, group_sizes):
    return jax.lax.ragged_dot(lhs, rhs, group_sizes), (lhs, rhs, group_sizes)


def _gmm_bwd(res, dout):
    lhs, rhs, gs = res
    dlhs = jax.lax.ragged_dot(dout, jnp.swapaxes(rhs, 1, 2), gs)
    dn = jax.lax.RaggedDotDimensionNumbers(
        dot_dimension_numbers=(((0,), (0,)), ((), ())),
        lhs_ragged_dimensions=[0], rhs_group_dimensions=[])
    drhs = jax.lax.ragged_dot_general(lhs, dout.astype(lhs.dtype), gs, dn)
    dgs = np.zeros(gs.shape, dtype=jax.dtypes.float0)
    return dlhs.astype(lhs.dtype), drhs.astype(rhs.dtype), dgs


grouped_matmul.defvjp(_gmm_fwd, _gmm_bwd)


def _moe_block_ragged(x: jax.Array, p: dict, cfg: ModelConfig) -> jax.Array:
    """Dropless top-k MoE via sort + ragged_dot (the §Perf rewrite).

    Token-parallel: every device keeps its own tokens, contracts against its
    (d/dp, F/tp) weight shards, and the partial sums meet in two small psums
    + one d-axis all-gather — no per-expert weight/activation collectives
    and FLOPs are top-k-only (vs. the scan baseline's all-expert compute).
    Off-mesh it runs the same math single-device (used by the equivalence
    tests)."""
    from repro.distributed.sharding import active_mesh, constraint
    E, K = cfg.num_experts, cfg.num_experts_per_tok
    B, S, d = x.shape
    mesh = active_mesh()

    def local_moe(x_l, router_l, wg_l, wu_l, wd_l):
        data_ax = mesh is not None and "data" in mesh.axis_names
        Bl, Sl, _ = x_l.shape
        T = Bl * Sl
        xf = x_l.reshape(T, d)
        if data_ax:
            dp = jax.lax.axis_size("data")
            d_loc = d // dp
            di = jax.lax.axis_index("data")
            x_slice = jax.lax.dynamic_slice_in_dim(xf, di * d_loc, d_loc, 1)
        else:
            x_slice = xf
        logits = jnp.einsum("td,de->te", x_slice, router_l,
                            preferred_element_type=jnp.float32)
        if data_ax:
            logits = jax.lax.psum(logits, "data")
        probs = jax.nn.softmax(logits, axis=-1)
        top_w, top_i = jax.lax.top_k(probs, K)                  # (T, K)
        top_w = top_w / jnp.sum(top_w, axis=-1, keepdims=True)

        flat_e = top_i.reshape(-1)                               # (T*K,)
        TK = T * K
        order = jnp.argsort(flat_e)
        tok_of_row = order // K
        x_sorted = jnp.take(x_slice, tok_of_row, axis=0)         # (TK, d_l)
        group_sizes = jnp.zeros((E,), jnp.int32).at[flat_e].add(1)

        # Capacity-grouped dispatch: contiguous (sorted) expert segments are
        # gathered into a dense (E, CAP, d) tensor so the expert FFN is a
        # single batched matmul (clean VJP + partitioning on every backend;
        # ragged_dot lowers to dense one-hot expansions off-TPU). Rows past
        # an expert's capacity are dropped (GShard semantics, cf = 2).
        cap = min(TK, int(-(-TK // E) * MOE_CAPACITY_FACTOR))
        starts = jnp.cumsum(group_sizes) - group_sizes
        slot = jnp.arange(cap, dtype=jnp.int32)
        valid = slot[None, :] < group_sizes[:, None]             # (E, CAP)
        rows = jnp.where(valid, starts[:, None] + slot[None, :], TK)
        x_pad = jnp.concatenate(
            [x_sorted, jnp.zeros((1, x_sorted.shape[1]), x_sorted.dtype)])
        x_grp = jnp.take(x_pad, rows, axis=0)                    # (E,CAP,d_l)

        g = jnp.einsum("ecd,edf->ecf", x_grp, wg_l,
                       preferred_element_type=jnp.float32)
        u = jnp.einsum("ecd,edf->ecf", x_grp, wu_l,
                       preferred_element_type=jnp.float32)
        if data_ax:
            g = jax.lax.psum(g, "data")
            u = jax.lax.psum(u, "data")
        h = (jax.nn.silu(g) * u).astype(x_l.dtype)               # (E,CAP,F_l)
        o = jnp.einsum("ecf,efd->ecd", h, wd_l,
                       preferred_element_type=jnp.float32)
        if mesh is not None and "model" in mesh.axis_names:
            o = jax.lax.psum(o, "model")                         # (E,CAP,d_l)
        # scatter rows back to sorted order (dropped rows contribute zero)
        o_sorted = jnp.zeros((TK + 1, o.shape[-1]), o.dtype).at[
            rows.reshape(-1)].add(o.reshape(-1, o.shape[-1])
                                  * valid.reshape(-1, 1))
        o_unsorted = jnp.take(
            o_sorted[:TK], jnp.argsort(order), axis=0)
        o_tok = jnp.einsum("tkd,tk->td",
                           o_unsorted.reshape(T, K, -1),
                           top_w.astype(o.dtype))
        if data_ax:
            o_tok = jax.lax.all_gather(o_tok, "data", axis=1, tiled=True)
        return o_tok.reshape(Bl, Sl, d).astype(x_l.dtype)

    if mesh is None:
        return local_moe(x, p["router"], p["we_gate"], p["we_up"],
                         p["we_down"])

    from jax.sharding import PartitionSpec as P
    x = constraint(x, "batch", None, None)   # exit SP once per block
    batch_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    fspec = "model" if "model" in mesh.axis_names else None
    dspec = "data" if "data" in mesh.axis_names else None
    out = jax.shard_map(
        local_moe, mesh=mesh,
        in_specs=(P(batch_axes or None, None, None),
                  P(dspec, None),
                  P(None, dspec, fspec),
                  P(None, dspec, fspec),
                  P(None, fspec, dspec)),
        out_specs=P(batch_axes or None, None, None),
        check_vma=False,
    )(x, p["router"], p["we_gate"], p["we_up"], p["we_down"])
    return out


def _attn_block(x, p, cfg, cos, sin, q_pos, kv_pos, window, *,
                k_ext=None, v_ext=None, kv_valid=None, impl="einsum"):
    """Self-attention with optional external KV (decode cache)."""
    KV, G = cfg.num_kv_heads, cfg.q_groups
    q, k, v = L.qkv_proj(x, p["wq"], p["wk"], p["wv"], KV, G)
    q = L.apply_rope(q, cos, sin)
    k = L.apply_rope(k, cos, sin)
    if k_ext is not None:
        k_all, v_all = k_ext, v_ext
    else:
        k_all, v_all = k, v
    o = L.attention(q, k_all, v_all, q_pos=q_pos, kv_pos=kv_pos, causal=True,
                    window=window, kv_valid=kv_valid, impl=impl)
    return L.out_proj(o, p["wo"]), k, v


def _ffn(x, p, cfg, moe_impl: str = "scan"):
    if cfg.num_experts:
        if moe_impl == "ragged":
            return _moe_block_ragged(x, p, cfg)
        return _moe_block(x, p, cfg)
    return L.mlp(x, p, cfg.mlp_type)


# --------------------------------------------------------------------------
# Forward (training / prefill hidden states)
# --------------------------------------------------------------------------
def decoder_hidden(cfg: ModelConfig, params: dict, tokens: jax.Array, *,
                   positions: Optional[jax.Array] = None,
                   vision_embeds: Optional[jax.Array] = None,
                   attn_impl: str = "einsum",
                   remat_policy: str = "dots",
                   moe_impl: str = "scan",
                   collect_kv: bool = False):
    """tokens (B,S) -> hidden (B,S,D); optionally per-layer (k, v) stacks."""
    B, S = tokens.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None],
                                     (B, S))
    sections = cfg.mrope_sections if cfg.mrope else None
    cos, sin = L.rope_cos_sin(positions, cfg.resolved_head_dim,
                              cfg.rope_theta, sections)
    x = L.embed_tokens(params["embed"], tokens)
    if vision_embeds is not None:
        x = jax.lax.dynamic_update_slice(
            x, vision_embeds.astype(x.dtype), (0, 0, 0))
    x = constraint(x, "batch", "act_seq", None)
    q_pos = (positions[0] if positions.ndim == 2 else positions[0, 0])

    flags = jnp.asarray(is_global_flags(cfg))
    win = cfg.sliding_window

    def body(h, xs):
        p, flag = xs
        window = jnp.where(flag, jnp.int32(0), jnp.int32(win))
        # Megatron-SP block boundary: all-gather the sequence BEFORE the
        # projections (so heads/d_ff TP applies inside), reduce-scatter the
        # projection outputs back to sequence shards. Both constraints are
        # no-ops when act_seq is unmapped.
        attn_in = constraint(L.rmsnorm(h, p["attn_norm"]),
                             "batch", None, None)
        attn_out, k, v = _attn_block(attn_in, p, cfg, cos, sin, q_pos, q_pos,
                                     window, impl=attn_impl)
        h = h + constraint(attn_out, "batch", "act_seq", None)
        mlp_in = constraint(L.rmsnorm(h, p["mlp_norm"]),
                            "batch", None, None)
        h = h + constraint(_ffn(mlp_in, p, cfg, moe_impl),
                           "batch", "act_seq", None)
        return h, ((k, v) if collect_kv else None)

    if remat_policy == "full":
        body = jax.checkpoint(body)
    elif remat_policy == "dots":
        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)

    x, kv = jax.lax.scan(body, x, (params["layers"], flags))
    x = L.rmsnorm(x, params["final_norm"])
    return (x, kv) if collect_kv else x


def decoder_logits(cfg, params, hidden):
    V = padded_vocab(cfg)
    logits = L.logits_from_hidden(hidden, params, cfg.tie_embeddings)
    return logits  # (B,S,Vpad) f32


def decoder_loss(cfg: ModelConfig, params: dict, batch: dict, *,
                 attn_impl: str = "einsum", remat_policy: str = "dots",
                 loss_chunk: int = 0, moe_impl: str = "scan") -> jax.Array:
    hidden = decoder_hidden(
        cfg, params, batch["tokens"], positions=batch.get("positions"),
        vision_embeds=batch.get("vision_embeds"), attn_impl=attn_impl,
        remat_policy=remat_policy, moe_impl=moe_impl)
    labels = batch["labels"]
    if loss_chunk and hidden.shape[1] % loss_chunk == 0:
        # Stream the (B,chunk,V) logits: never materialize (B,S,V).
        n = hidden.shape[1] // loss_chunk
        hc = hidden.reshape(hidden.shape[0], n, loss_chunk, -1)
        lc = labels.reshape(labels.shape[0], n, loss_chunk)

        def chunk_loss(carry, xs):
            h, lab = xs
            logits = L.logits_from_hidden(h, params, cfg.tie_embeddings)
            logits = logits.astype(jnp.float32)
            lse = jax.nn.logsumexp(logits, axis=-1)
            gold = L._gold_logit(logits, lab)
            mask = (lab >= 0).astype(jnp.float32)
            return (carry[0] + jnp.sum((lse - gold) * mask),
                    carry[1] + jnp.sum(mask)), None

        (tot, cnt), _ = jax.lax.scan(
            chunk_loss, (jnp.float32(0), jnp.float32(0)),
            (jnp.moveaxis(hc, 1, 0), jnp.moveaxis(lc, 1, 0)))
        return tot / jnp.maximum(cnt, 1.0)
    logits = decoder_logits(cfg, params, hidden)
    return L.cross_entropy(logits, labels)


# --------------------------------------------------------------------------
# KV cache: prefill + decode
# --------------------------------------------------------------------------
def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> dict:
    dt = _dtype(cfg)
    KV, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    shape = (cfg.num_layers, batch, max_len, KV, hd)
    return {"k": jnp.zeros(shape, dt), "v": jnp.zeros(shape, dt),
            "pos": jnp.zeros((), jnp.int32)}


def cache_specs(cfg: ModelConfig) -> dict:
    return {"k": ("layers", "batch", "kv_seq", "kv_heads", "head_dim"),
            "v": ("layers", "batch", "kv_seq", "kv_heads", "head_dim"),
            "pos": ()}


def decoder_prefill(cfg: ModelConfig, params: dict, tokens: jax.Array, *,
                    positions=None, vision_embeds=None,
                    attn_impl: str = "chunked"):
    """Full-sequence forward that also returns the populated KV cache and the
    last-position logits (the realistic serve entry point)."""
    hidden, kv = decoder_hidden(
        cfg, params, tokens, positions=positions,
        vision_embeds=vision_embeds, attn_impl=attn_impl,
        remat_policy="none", collect_kv=True)
    k, v = kv                                   # (L, B, S, KV, hd)
    cache = {"k": k, "v": v,
             "pos": jnp.asarray(tokens.shape[1], jnp.int32)}
    last = hidden[:, -1]
    logits = L.logits_from_hidden(last[:, None], params, cfg.tie_embeddings)
    return logits[:, 0], cache


def decoder_decode(cfg: ModelConfig, params: dict, cache: dict,
                   tokens: jax.Array, *, positions=None):
    """One decode step. tokens (B,1); cache KV (L,B,T,KV,hd); returns
    (logits (B,Vpad), new cache)."""
    B, S1 = tokens.shape
    T = cache["k"].shape[2]
    pos = cache["pos"]
    if positions is None:
        positions = jnp.full((B, S1), pos, jnp.int32)
    sections = cfg.mrope_sections if cfg.mrope else None
    cos, sin = L.rope_cos_sin(positions, cfg.resolved_head_dim,
                              cfg.rope_theta, sections)
    x = L.embed_tokens(params["embed"], tokens)
    q_pos = jnp.full((S1,), pos, jnp.int32)
    kv_pos = jnp.arange(T, dtype=jnp.int32)
    kv_valid = jnp.broadcast_to((kv_pos <= pos)[None], (B, T))
    flags = jnp.asarray(is_global_flags(cfg))
    win = cfg.sliding_window

    def body(h, xs):
        p, flag, k_l, v_l = xs
        window = jnp.where(flag, jnp.int32(0), jnp.int32(win))
        attn_in = L.rmsnorm(h, p["attn_norm"])
        KV, G = cfg.num_kv_heads, cfg.q_groups
        q, k_new, v_new = L.qkv_proj(attn_in, p["wq"], p["wk"], p["wv"],
                                     KV, G)
        q = L.apply_rope(q, cos, sin)
        k_new = L.apply_rope(k_new, cos, sin)
        k_l = jax.lax.dynamic_update_slice(k_l, k_new.astype(k_l.dtype),
                                           (0, pos, 0, 0))
        v_l = jax.lax.dynamic_update_slice(v_l, v_new.astype(v_l.dtype),
                                           (0, pos, 0, 0))
        o = L.attention(q, k_l, v_l, q_pos=q_pos, kv_pos=kv_pos, causal=True,
                        window=window, kv_valid=kv_valid)
        h = h + L.out_proj(o, p["wo"])
        h = h + _ffn(L.rmsnorm(h, p["mlp_norm"]), p, cfg)
        return h, (k_l, v_l)

    x, (k_new, v_new) = jax.lax.scan(
        body, x, (params["layers"], flags, cache["k"], cache["v"]))
    x = L.rmsnorm(x, params["final_norm"])
    logits = L.logits_from_hidden(x, params, cfg.tie_embeddings)
    new_cache = {"k": k_new, "v": v_new, "pos": pos + 1}
    return logits[:, 0], new_cache
