"""Hymba hybrid backbone: each block runs attention heads and a Mamba
(selective-SSM) head IN PARALLEL on the same input, fuses the two normalized
streams by averaging, then a SwiGLU MLP. Sliding-window attention everywhere
except the configured full-attention layers ({first, middle, last}).

Stubs recorded in DESIGN.md: meta-token prefix omitted; the SSM inner width
equals d_model (parallel-head formulation).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.distributed.sharding import constraint
from repro.models import layers as L
from repro.models import mamba as M
from repro.models.transformer import is_global_flags, padded_vocab


def init_hymba(cfg: ModelConfig, rng: jax.Array) -> dict:
    dt = jnp.dtype(cfg.dtype)
    d, hd = cfg.d_model, cfg.resolved_head_dim
    H, KV, F, Lr = cfg.num_heads, cfg.num_kv_heads, cfg.d_ff, cfg.num_layers
    V = padded_vocab(cfg)
    ks = iter(jax.random.split(rng, 32))
    layer = {
        "attn_norm": jnp.ones((Lr, d), dt),
        "mlp_norm": jnp.ones((Lr, d), dt),
        "fuse_norm_attn": jnp.ones((Lr, d), dt),
        "fuse_norm_ssm": jnp.ones((Lr, d), dt),
        "wq": L.dense_init(next(ks), (Lr, d, H, hd), dt, d),
        "wk": L.dense_init(next(ks), (Lr, d, KV, hd), dt, d),
        "wv": L.dense_init(next(ks), (Lr, d, KV, hd), dt, d),
        "wo": L.dense_init(next(ks), (Lr, H, hd, d), dt, H * hd),
        "w_in": L.dense_init(next(ks), (Lr, d, d), dt, d),
        "w_gate_ssm": L.dense_init(next(ks), (Lr, d, d), dt, d),
        "w_out_ssm": L.dense_init(next(ks), (Lr, d, d), dt, d),
        "w_gate": L.dense_init(next(ks), (Lr, d, F), dt, d),
        "w_up": L.dense_init(next(ks), (Lr, d, F), dt, d),
        "w_down": L.dense_init(next(ks), (Lr, F, d), dt, F),
        "ssm": M.init_ssm(ks, (Lr,), d, cfg.ssm_state, cfg.ssm_conv, dt, d),
    }
    return {
        "embed": L.dense_init(next(ks), (V, d), dt, d),
        "final_norm": jnp.ones((d,), dt),
        "layers": layer,
    }


def hymba_param_specs(cfg: ModelConfig) -> dict:
    layer = {
        "attn_norm": ("layers", None), "mlp_norm": ("layers", None),
        "fuse_norm_attn": ("layers", None), "fuse_norm_ssm": ("layers", None),
        "wq": ("layers", "w_data", "heads", "head_dim"),
        "wk": ("layers", "w_data", "kv_heads", "head_dim"),
        "wv": ("layers", "w_data", "kv_heads", "head_dim"),
        "wo": ("layers", "heads", "head_dim", "w_data"),
        "w_in": ("layers", "w_data", "d_inner"),
        "w_gate_ssm": ("layers", "w_data", "d_inner"),
        "w_out_ssm": ("layers", "d_inner", "w_data"),
        "w_gate": ("layers", "w_data", "d_ff"),
        "w_up": ("layers", "w_data", "d_ff"),
        "w_down": ("layers", "d_ff", "w_data"),
        "ssm": M.ssm_param_specs(),
    }
    return {"embed": ("vocab", "embed_d"), "final_norm": (None,),
            "layers": layer}


def _block(x, p, cfg, cos, sin, q_pos, kv_pos, window, *,
           kv_valid=None, attn_impl="einsum",
           k_cache=None, v_cache=None, pos=None,
           ssm_state=None, conv_state=None):
    """One hybrid block. Cache args trigger the decode path."""
    KV, G = cfg.num_kv_heads, cfg.q_groups
    h = L.rmsnorm(x, p["attn_norm"])
    # -- attention path --
    q, k, v = L.qkv_proj(h, p["wq"], p["wk"], p["wv"], KV, G)
    q = L.apply_rope(q, cos, sin)
    k = L.apply_rope(k, cos, sin)
    new_kv = (None, None)
    if k_cache is not None:
        k_cache = jax.lax.dynamic_update_slice(
            k_cache, k.astype(k_cache.dtype), (0, pos, 0, 0))
        v_cache = jax.lax.dynamic_update_slice(
            v_cache, v.astype(v_cache.dtype), (0, pos, 0, 0))
        k, v = k_cache, v_cache
        new_kv = (k_cache, v_cache)
    o = L.attention(q, k, v, q_pos=q_pos, kv_pos=kv_pos, causal=True,
                    window=window, kv_valid=kv_valid, impl=attn_impl)
    attn_out = L.out_proj(o, p["wo"])
    # -- SSM path (parallel, same input) --
    xin = jnp.einsum("bsd,de->bse", h, p["w_in"])
    z = jnp.einsum("bsd,de->bse", h, p["w_gate_ssm"])
    y, new_ssm, new_conv = M.selective_scan(
        xin, p["ssm"], state=ssm_state, conv_state=conv_state)
    ssm_out = jnp.einsum("bse,ed->bsd", y * jax.nn.silu(z), p["w_out_ssm"])
    # -- fuse: mean of normalized streams --
    fused = 0.5 * (L.rmsnorm(attn_out, p["fuse_norm_attn"])
                   + L.rmsnorm(ssm_out, p["fuse_norm_ssm"]))
    x = x + fused
    x = x + L.mlp(L.rmsnorm(x, p["mlp_norm"]), p, cfg.mlp_type)
    return x, new_kv, new_ssm, new_conv


def hymba_hidden(cfg: ModelConfig, params: dict, tokens: jax.Array,
                 remat_policy: str = "dots", attn_impl: str = "einsum",
                 collect_kv: bool = False):
    B, S = tokens.shape
    pos = jnp.arange(S, dtype=jnp.int32)
    cos, sin = L.rope_cos_sin(jnp.broadcast_to(pos[None], (B, S)),
                              cfg.resolved_head_dim, cfg.rope_theta)
    x = L.embed_tokens(params["embed"], tokens)
    x = constraint(x, "batch", "act_seq", None)
    flags = jnp.asarray(is_global_flags(cfg))
    win = cfg.sliding_window

    def body(h, xs):
        p, flag = xs
        window = jnp.where(flag, jnp.int32(0), jnp.int32(win))
        out, kv, _, _ = _block(h, p, cfg, cos, sin, pos, pos, window,
                               attn_impl=attn_impl)
        return out, None

    if remat_policy == "full":
        body = jax.checkpoint(body)
    elif remat_policy == "dots":
        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    x, _ = jax.lax.scan(body, x, (params["layers"], flags))
    return L.rmsnorm(x, params["final_norm"])


def hymba_loss(cfg, params, batch, *, remat_policy="dots",
               attn_impl="einsum", **_):
    hidden = hymba_hidden(cfg, params, batch["tokens"], remat_policy,
                          attn_impl)
    logits = jnp.einsum("bsd,dv->bsv", hidden, params["unembed"],
                        preferred_element_type=jnp.float32) \
        if "unembed" in params else \
        jnp.einsum("bsd,vd->bsv", hidden, params["embed"],
                   preferred_element_type=jnp.float32)
    return L.cross_entropy(logits, batch["labels"])


# --------------------------------------------------------------------------
# Serving
# --------------------------------------------------------------------------
def init_hymba_cache(cfg: ModelConfig, batch: int, max_len: int) -> dict:
    dt = jnp.dtype(cfg.dtype)
    KV, hd, Lr = cfg.num_kv_heads, cfg.resolved_head_dim, cfg.num_layers
    return {
        "k": jnp.zeros((Lr, batch, max_len, KV, hd), dt),
        "v": jnp.zeros((Lr, batch, max_len, KV, hd), dt),
        "ssm": jnp.zeros((Lr, batch, cfg.d_model, cfg.ssm_state),
                         jnp.float32),
        "conv": jnp.zeros((Lr, batch, cfg.ssm_conv - 1, cfg.d_model), dt),
        "pos": jnp.zeros((), jnp.int32),
    }


def hymba_cache_specs(cfg: ModelConfig) -> dict:
    return {"k": ("layers", "batch", "kv_seq", "kv_heads", "head_dim"),
            "v": ("layers", "batch", "kv_seq", "kv_heads", "head_dim"),
            "ssm": ("layers", "batch", "d_inner", None),
            "conv": ("layers", "batch", None, "d_inner"),
            "pos": ()}


def hymba_prefill(cfg: ModelConfig, params: dict, tokens: jax.Array,
                  attn_impl: str = "chunked"):
    """Parallel prompt processing returning last-token logits + serve cache
    (KV per layer, SSM state, conv tail)."""
    B, S = tokens.shape
    pos = jnp.arange(S, dtype=jnp.int32)
    cos, sin = L.rope_cos_sin(jnp.broadcast_to(pos[None], (B, S)),
                              cfg.resolved_head_dim, cfg.rope_theta)
    x = L.embed_tokens(params["embed"], tokens)
    flags = jnp.asarray(is_global_flags(cfg))
    win = cfg.sliding_window
    KV, G = cfg.num_kv_heads, cfg.q_groups

    def body(h, xs):
        p, flag = xs
        window = jnp.where(flag, jnp.int32(0), jnp.int32(win))
        hn = L.rmsnorm(h, p["attn_norm"])
        q, k, v = L.qkv_proj(hn, p["wq"], p["wk"], p["wv"], KV, G)
        q = L.apply_rope(q, cos, sin)
        k = L.apply_rope(k, cos, sin)
        o = L.attention(q, k, v, q_pos=pos, kv_pos=pos, causal=True,
                        window=window, impl=attn_impl)
        attn_out = L.out_proj(o, p["wo"])
        xin = jnp.einsum("bsd,de->bse", hn, p["w_in"])
        z = jnp.einsum("bsd,de->bse", hn, p["w_gate_ssm"])
        y, ssm_state, _ = M.selective_scan(xin, p["ssm"])
        conv_tail = xin[:, -(cfg.ssm_conv - 1):, :]
        ssm_out = jnp.einsum("bse,ed->bsd", y * jax.nn.silu(z),
                             p["w_out_ssm"])
        fused = 0.5 * (L.rmsnorm(attn_out, p["fuse_norm_attn"])
                       + L.rmsnorm(ssm_out, p["fuse_norm_ssm"]))
        h = h + fused
        h = h + L.mlp(L.rmsnorm(h, p["mlp_norm"]), p, cfg.mlp_type)
        return h, (k, v, ssm_state, conv_tail)

    x, (k, v, ssm, conv) = jax.lax.scan(body, x, (params["layers"], flags))
    x = L.rmsnorm(x, params["final_norm"])
    logits = jnp.einsum("bd,vd->bv", x[:, -1], params["embed"],
                        preferred_element_type=jnp.float32)
    cache = {"k": k, "v": v, "ssm": ssm, "conv": conv.astype(jnp.dtype(cfg.dtype)),
             "pos": jnp.asarray(S, jnp.int32)}
    return logits, cache


def hymba_decode(cfg: ModelConfig, params: dict, cache: dict,
                 tokens: jax.Array):
    B, S1 = tokens.shape
    T = cache["k"].shape[2]
    pos = cache["pos"]
    positions = jnp.full((B, S1), pos, jnp.int32)
    cos, sin = L.rope_cos_sin(positions, cfg.resolved_head_dim,
                              cfg.rope_theta)
    x = L.embed_tokens(params["embed"], tokens)
    q_pos = jnp.full((S1,), pos, jnp.int32)
    kv_pos = jnp.arange(T, dtype=jnp.int32)
    kv_valid = jnp.broadcast_to((kv_pos <= pos)[None], (B, T))
    flags = jnp.asarray(is_global_flags(cfg))
    win = cfg.sliding_window

    def body(h, xs):
        p, flag, k_l, v_l, ssm_l, conv_l = xs
        window = jnp.where(flag, jnp.int32(0), jnp.int32(win))
        out, (k2, v2), ssm2, conv2 = _block(
            h, p, cfg, cos, sin, q_pos, kv_pos, window, kv_valid=kv_valid,
            k_cache=k_l, v_cache=v_l, pos=pos,
            ssm_state=ssm_l, conv_state=conv_l)
        return out, (k2, v2, ssm2, conv2)

    x, (k, v, ssm, conv) = jax.lax.scan(
        body, x, (params["layers"], flags, cache["k"], cache["v"],
                  cache["ssm"], cache["conv"]))
    x = L.rmsnorm(x, params["final_norm"])
    logits = jnp.einsum("bsd,vd->bsv", x, params["embed"],
                        preferred_element_type=jnp.float32)
    new_cache = {"k": k, "v": v, "ssm": ssm, "conv": conv, "pos": pos + 1}
    return logits[:, 0], new_cache
