from repro.models.model import (abstract_params, abstract_train_state,
                                batch_specs, cache_specs, init, init_cache,
                                init_train_state, input_specs, loss_fn,
                                make_decode_step, make_prefill_step,
                                make_train_step, param_specs,
                                train_state_specs)

__all__ = ["abstract_params", "abstract_train_state", "batch_specs",
           "cache_specs", "init", "init_cache", "init_train_state",
           "input_specs", "loss_fn", "make_decode_step", "make_prefill_step",
           "make_train_step", "param_specs", "train_state_specs"]
