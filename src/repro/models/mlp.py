"""The paper's MNIST MLP as a vmappable :class:`ClientModel`.

A two-layer softmax classifier (784 -> hidden -> 10) trained with K local
SGD steps per round on each client's non-IID dirichlet shard
(:func:`repro.data.mnist.dirichlet_shards`).  The whole local round —
minibatch sampling included — is one pure JAX function of
``(flat_params, client_idx, round_idx)``, so the fleet's ``vmap``/``shard``
train backends batch every client of a round into a single compiled call.

The legacy per-client path (:meth:`MnistMLPModel.train_fn`) runs the *same*
jitted function unbatched, so python-vs-vmap parity is jax-vs-jax and
ULP-bounded (pinned in ``tests/test_client_compute.py``); the data and
minibatch schedule are keyed only by ``(seed, client_idx, round_idx)``,
never by call order.
"""

from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.client_compute import ClientModel
from repro.core.packetizer import flatten_to_vector, unflatten_from_vector
from repro.data.mnist import dirichlet_shards, load_mnist


class MnistMLPModel(ClientModel):
    """784 -> hidden -> 10 MLP over per-client dirichlet shards.

    ``download=False`` by default: benchmarks and CI must be hermetic, so
    the seeded synthetic MNIST fallback is the default diet; pass
    ``download=True`` (or ``data_dir=``) to train on the real digits.
    """

    name = "mlp"

    def __init__(self, n_clients: int, *, seed: int = 0, hidden: int = 32,
                 local_steps: int = 4, batch_size: int = 32,
                 lr: float = 0.1, alpha: float = 0.5,
                 n_train: int = 8192, n_test: int = 1024,
                 shard_size: int = 256, download: bool = False,
                 data_dir: str | None = None):
        super().__init__(n_clients, seed=seed)
        self.hidden = int(hidden)
        self.local_steps = int(local_steps)
        self.batch_size = int(batch_size)
        self.lr = float(lr)
        self.data = load_mnist(n_train, n_test, seed=seed,
                               data_dir=data_dir, download=download)
        self.shards = dirichlet_shards(
            self.data.y_train, n_clients, alpha=alpha, seed=seed,
            shard_size=shard_size)
        # Device-resident constants closed over by the jitted step.
        self._x = jnp.asarray(self.data.x_train)
        self._y = jnp.asarray(self.data.y_train)
        self._shards = jnp.asarray(self.shards)
        # Flat-vector layout: tree_leaves order of the params template.
        template = self.init_params()
        leaves, self._treedef = jax.tree_util.tree_flatten(template)
        self._shapes = [leaf.shape for leaf in leaves]
        sizes = [int(np.prod(s)) if s else 1 for s in self._shapes]
        self._offsets = np.cumsum([0] + sizes).tolist()
        self.n_params = self._offsets[-1]
        self._single: Callable | None = None

    # -- ClientModel ------------------------------------------------------
    def init_params(self) -> Any:
        rng = np.random.default_rng(self.seed)
        h = self.hidden
        scale1 = np.sqrt(2.0 / 784.0)
        scale2 = np.sqrt(2.0 / h)
        return {
            "w1": (rng.standard_normal((784, h)) * scale1).astype(np.float32),
            "b1": np.zeros(h, np.float32),
            "w2": (rng.standard_normal((h, 10)) * scale2).astype(np.float32),
            "b2": np.zeros(10, np.float32),
        }

    def loss(self, params: Any) -> float:
        """Mean softmax cross-entropy on the held-out test split."""
        logits = self._forward_np(params, self.data.x_test)
        logits = logits - logits.max(axis=1, keepdims=True)
        logz = np.log(np.exp(logits).sum(axis=1))
        return float(np.mean(
            logz - logits[np.arange(len(logits)), self.data.y_test]))

    def accuracy(self, params: Any) -> float:
        logits = self._forward_np(params, self.data.x_test)
        return float(np.mean(logits.argmax(axis=1) == self.data.y_test))

    def eval_metrics(self, params: Any) -> dict:
        return {"loss": self.loss(params), "accuracy": self.accuracy(params),
                "data_source": self.data.source}

    def train_fn(self, i: int, profile: Any = None) -> Callable:
        if self._single is None:
            self._single = jax.jit(self.jax_train)
        single = self._single
        template = {k: np.asarray(v) for k, v in self.init_params().items()}
        idx = int(i)

        def _train(params: Any, round_idx: int, client: Any
                   ) -> tuple[Any, dict]:
            vec = jnp.asarray(flatten_to_vector(params))
            new, aux = single(vec, jnp.int32(idx), jnp.int32(round_idx))
            tree = unflatten_from_vector(np.asarray(new, np.float32),
                                         template)
            return tree, {k: float(v) for k, v in aux.items()}

        return _train

    def jax_train(self, vec, client_idx, round_idx):
        params = self._unflatten_jax(vec.astype(jnp.float32))
        shard = self._shards[client_idx]              # (shard_size,) indices
        key = jax.random.fold_in(
            jax.random.fold_in(jax.random.PRNGKey(self.seed), client_idx),
            round_idx)

        def step(carry, k):
            p, _ = carry
            bkey = jax.random.fold_in(key, k)
            pick = jax.random.randint(
                bkey, (self.batch_size,), 0, shard.shape[0])
            rows = shard[pick]
            x, y = self._x[rows], self._y[rows]
            loss, grads = jax.value_and_grad(self._ce)(p, x, y)
            p = jax.tree_util.tree_map(
                lambda w, g: w - jnp.float32(self.lr) * g, p, grads)
            return (p, loss), None

        (params, last_loss), _ = jax.lax.scan(
            step, (params, jnp.float32(0.0)),
            jnp.arange(self.local_steps, dtype=jnp.int32))
        return self._flatten_jax(params), {"train_loss": last_loss}

    # -- internals --------------------------------------------------------
    def _ce(self, params, x, y):
        logits = jnp.dot(jnp.tanh(jnp.dot(x, params["w1"]) + params["b1"]),
                         params["w2"]) + params["b2"]
        logp = jax.nn.log_softmax(logits)
        return -jnp.mean(jnp.take_along_axis(logp, y[:, None], axis=1))

    def _forward_np(self, params: Any, x: np.ndarray) -> np.ndarray:
        h = np.tanh(x @ np.asarray(params["w1"]) + np.asarray(params["b1"]))
        return h @ np.asarray(params["w2"]) + np.asarray(params["b2"])

    def _unflatten_jax(self, vec):
        leaves = [vec[a:b].reshape(shape) for a, b, shape in
                  zip(self._offsets, self._offsets[1:], self._shapes)]
        return jax.tree_util.tree_unflatten(self._treedef, leaves)

    def _flatten_jax(self, params):
        return jnp.concatenate(
            [leaf.reshape(-1) for leaf in jax.tree_util.tree_leaves(params)])
