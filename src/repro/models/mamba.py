"""Selective SSM (Mamba-style) head used by the Hymba hybrid blocks.

Training/prefill uses ``jax.lax.associative_scan`` over the linear recurrence
h_t = a_t * h_{t-1} + b_t (log-depth parallel); decode is the O(1) recurrent
step. Depthwise short conv is a causal 1D conv (kernel ``ssm_conv``).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers as L


def init_ssm(ks, shape_prefix, d_inner: int, n_state: int, conv: int, dt,
             d_in: int):
    sp = shape_prefix
    return {
        "conv_w": L.dense_init(next(ks), sp + (conv, d_inner), dt, conv),
        "w_dt": L.dense_init(next(ks), sp + (d_inner, d_inner), dt, d_inner),
        "b_dt": jnp.full(sp + (d_inner,), -4.6, dt),   # softplus^-1(~0.01)
        "w_B": L.dense_init(next(ks), sp + (d_inner, n_state), dt, d_inner),
        "w_C": L.dense_init(next(ks), sp + (d_inner, n_state), dt, d_inner),
        "A_log": jnp.zeros(sp + (d_inner, n_state), dt),
        "D": jnp.ones(sp + (d_inner,), dt),
    }


def ssm_param_specs():
    return {
        "conv_w": ("layers", None, "d_inner"),
        "w_dt": ("layers", "w_data", "d_inner"),
        "b_dt": ("layers", "d_inner"),
        "w_B": ("layers", "w_data", None),
        "w_C": ("layers", "w_data", None),
        "A_log": ("layers", "d_inner", None),
        "D": ("layers", "d_inner"),
    }


def causal_conv(x: jax.Array, w: jax.Array,
                state: jax.Array | None = None):
    """Depthwise causal conv. x (B,S,D), w (K,D). With ``state`` (B,K-1,D)
    performs the streaming update (decode) and returns (y, new_state)."""
    K = w.shape[0]
    if state is not None:
        window = jnp.concatenate([state, x], axis=1)      # (B,K,D) for S=1
        y = jnp.einsum("bkd,kd->bd", window[:, -K:], w)[:, None]
        return y, window[:, 1:]
    pad = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    y = sum(pad[:, i:i + x.shape[1]] * w[i] for i in range(K))
    return y, None


def selective_scan(x: jax.Array, p: dict, *, state=None, conv_state=None):
    """x: (B,S,Di) pre-activation stream. Returns (y (B,S,Di), new_state,
    new_conv_state). ``state``: (B,Di,N) triggers single-step decode."""
    xc, new_conv = causal_conv(x, p["conv_w"], conv_state)
    xc = jax.nn.silu(xc)
    dt = jax.nn.softplus(
        jnp.einsum("bsd,de->bse", xc, p["w_dt"]) + p["b_dt"])
    Bm = jnp.einsum("bsd,dn->bsn", xc, p["w_B"])
    Cm = jnp.einsum("bsd,dn->bsn", xc, p["w_C"])
    A = -jnp.exp(p["A_log"].astype(jnp.float32))           # (Di,N)
    a = jnp.exp(dt[..., None].astype(jnp.float32) * A)     # (B,S,Di,N)
    b = (dt[..., None] * Bm[:, :, None, :] * xc[..., None]).astype(jnp.float32)

    if state is None:
        def op(e1, e2):
            a1, b1 = e1
            a2, b2 = e2
            return a2 * a1, a2 * b1 + b2
        _, h = jax.lax.associative_scan(op, (a, b), axis=1)
        new_state = h[:, -1]
    else:
        h = a[:, 0] * state + b[:, 0]                      # (B,Di,N)
        new_state = h
        h = h[:, None]
    y = jnp.einsum("bsdn,bsn->bsd", h.astype(Cm.dtype), Cm)
    y = y + p["D"] * xc
    return y.astype(x.dtype), new_state, new_conv
