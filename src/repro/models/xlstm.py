"""xLSTM backbone: mLSTM (matrix-memory, parallelizable) and sLSTM
(scalar-memory, sequential) blocks interleaved 7:1 (xLSTM[7:1]).

Training/prefill uses the stabilized parallel (quadratic) mLSTM form — the
chunkwise-linear Pallas kernel (`repro.kernels.mlstm`) is the TPU hot path
for long context. Decode uses the O(1)/token recurrent forms; there is no KV
cache, only per-layer state — which is why this arch runs long_500k.

Layout: layers are scanned in GROUPS of ``slstm_every`` (7 mLSTM + 1 sLSTM),
preserving the interleave with stacked params: mLSTM params lead with
(G, 7, ...), sLSTM with (G, ...).

Simplifications recorded in DESIGN.md: the short causal conv preceding q/k in
the reference mLSTM block is omitted; norms are RMSNorm.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.distributed.sharding import constraint
from repro.models import layers as L
from repro.models.transformer import padded_vocab

PROJ_FACTOR = 2  # mLSTM up-projection factor


def _dims(cfg: ModelConfig):
    d = cfg.d_model
    di = PROJ_FACTOR * d
    nh = cfg.num_heads
    dh = di // nh
    return d, di, nh, dh


# --------------------------------------------------------------------------
# Init
# --------------------------------------------------------------------------
def _init_mlstm_layer(ks, shape_prefix, cfg, dt):
    d, di, nh, dh = _dims(cfg)
    sp = shape_prefix
    return {
        "norm": jnp.ones(sp + (d,), dt),
        "w_up": L.dense_init(next(ks), sp + (d, di), dt, d),
        "w_z": L.dense_init(next(ks), sp + (d, di), dt, d),
        "w_q": L.dense_init(next(ks), sp + (di, nh, dh), dt, di),
        "w_k": L.dense_init(next(ks), sp + (di, nh, dh), dt, di),
        "w_v": L.dense_init(next(ks), sp + (di, nh, dh), dt, di),
        "w_if": L.dense_init(next(ks), sp + (di, 2, nh), dt, di),
        "b_if": jnp.zeros(sp + (2, nh), dt),
        "w_down": L.dense_init(next(ks), sp + (di, d), dt, di),
    }


def _init_slstm_layer(ks, shape_prefix, cfg, dt):
    d, di, nh, dh = _dims(cfg)
    dh_s = d // nh      # sLSTM operates at model width
    sp = shape_prefix
    return {
        "norm": jnp.ones(sp + (d,), dt),
        "w_gates": L.dense_init(next(ks), sp + (d, 4, nh, dh_s), dt, d),
        "r_gates": L.dense_init(next(ks), sp + (4, nh, dh_s, dh_s), dt, dh_s),
        "b_gates": jnp.zeros(sp + (4, nh, dh_s), dt),
        "w_down": L.dense_init(next(ks), sp + (d, d), dt, d),
    }


def init_xlstm(cfg: ModelConfig, rng: jax.Array) -> dict:
    dt = jnp.dtype(cfg.dtype)
    V = padded_vocab(cfg)
    per = cfg.slstm_every
    G = cfg.num_layers // per
    M = per - 1
    ks = iter(jax.random.split(rng, 64))
    return {
        "embed": L.dense_init(next(ks), (V, cfg.d_model), dt, cfg.d_model),
        "final_norm": jnp.ones((cfg.d_model,), dt),
        "mlstm": _init_mlstm_layer(ks, (G, M), cfg, dt),
        "slstm": _init_slstm_layer(ks, (G,), cfg, dt),
    }


def xlstm_param_specs(cfg: ModelConfig) -> dict:
    m = {
        "norm": ("layers", "layers2", None),
        "w_up": ("layers", "layers2", "w_data", "heads"),
        "w_z": ("layers", "layers2", "w_data", "heads"),
        "w_q": ("layers", "layers2", "w_data", None, "head_dim"),
        "w_k": ("layers", "layers2", "w_data", None, "head_dim"),
        "w_v": ("layers", "layers2", "w_data", None, "head_dim"),
        "w_if": ("layers", "layers2", "w_data", None, None),
        "b_if": ("layers", "layers2", None, None),
        "w_down": ("layers", "layers2", "heads", "w_data"),
    }
    s = {
        "norm": ("layers", None),
        "w_gates": ("layers", "w_data", None, None, None),
        "r_gates": ("layers", None, None, None, None),
        "b_gates": ("layers", None, None, None),
        "w_down": ("layers", "w_data", None),
    }
    return {"embed": ("vocab", "embed_d"), "final_norm": (None,),
            "mlstm": m, "slstm": s}


# --------------------------------------------------------------------------
# mLSTM: stabilized parallel (train) + recurrent (decode)
# --------------------------------------------------------------------------
def mlstm_parallel(q, k, v, i_gate, f_gate):
    """q/k/v: (B,S,nh,dh); i/f raw gate logits: (B,S,nh) -> h (B,S,nh,dh).

    D[t,s] = cumlogsig(f)[t] - cumlogsig(f)[s] + i[s]  (s <= t), stabilized
    per row; h = (exp(D - m) * (q k^T / sqrt(dh))) v / max(|row sum|, e^-m).
    Mirrors ``repro.kernels.mlstm.ref``.
    """
    B, S, nh, dh = q.shape
    logf = jax.nn.log_sigmoid(f_gate.astype(jnp.float32))       # (B,S,nh)
    F = jnp.cumsum(logf, axis=1)
    ii = i_gate.astype(jnp.float32)
    D = (F[:, :, None, :] - F[:, None, :, :]
         + ii[:, None, :, :])                                   # (B,t,s,nh)
    t_idx = jnp.arange(S)
    causal = t_idx[:, None] >= t_idx[None, :]
    D = jnp.where(causal[None, :, :, None], D, -jnp.inf)
    m = jnp.max(D, axis=2, keepdims=True)                       # (B,t,1,nh)
    Dexp = jnp.exp(D - m)                                        # (B,t,s,nh)
    scores = jnp.einsum("bthd,bshd->btsh", q, k,
                        preferred_element_type=jnp.float32)
    scores = scores * (dh ** -0.5) * Dexp
    norm = jnp.maximum(jnp.abs(scores.sum(axis=2)),
                       jnp.exp(-m[:, :, 0, :]))                  # (B,t,nh)
    h = jnp.einsum("btsh,bshd->bthd", scores, v,
                   preferred_element_type=jnp.float32)
    return (h / norm[..., None]).astype(v.dtype)


def mlstm_chunked(q, k, v, i_gate, f_gate, *, chunk: int = 1024):
    """Blockwise mLSTM: identical math to ``mlstm_parallel`` but never
    materializes the (S, S) gating matrix — O(S * chunk) live memory, the
    XLA twin of the Pallas kernel (repro.kernels.mlstm). This is what makes
    xlstm prefill_32k fit (71.8 GiB -> ~2 GiB per device, §Perf log).

    Outer map over query chunks; inner scan over KV chunks with running
    (m, n, acc) in the xLSTM stabilized form.
    """
    B, S, nh, dh = q.shape
    if S % chunk != 0 or S <= chunk:
        return mlstm_parallel(q, k, v, i_gate, f_gate)
    nc = S // chunk
    logf = jax.nn.log_sigmoid(f_gate.astype(jnp.float32))
    F = jnp.cumsum(logf, axis=1)                            # (B,S,nh)
    ii = i_gate.astype(jnp.float32)
    scale = dh ** -0.5

    kc = k.reshape(B, nc, chunk, nh, dh)
    vc = v.reshape(B, nc, chunk, nh, dh)
    Fc = F.reshape(B, nc, chunk, nh)
    ic = ii.reshape(B, nc, chunk, nh)
    qc = q.reshape(B, nc, chunk, nh, dh)
    pos = jnp.arange(S, dtype=jnp.int32).reshape(nc, chunk)

    def one_q_chunk(args):
        qi, Fq, qpos, idx = args              # (B,chunk,nh,dh) ...

        def kv_body(carry, xs):
            m, n, acc = carry
            kj, vj, Fk, ik, kpos = xs
            d = (Fq[:, :, None, :] - Fk[:, None, :, :]
                 + ik[:, None, :, :])                      # (B,cq,ck,nh)
            causal = qpos[:, None] >= kpos[None, :]
            d = jnp.where(causal[None, :, :, None], d, -jnp.inf)
            m_new = jnp.maximum(m, jnp.max(d, axis=2))     # (B,cq,nh)
            m_safe = jnp.maximum(m_new, -1e30)             # rows w/o keys yet
            gate = jnp.exp(d - m_safe[:, :, None, :])
            s = jnp.einsum("bthd,bshd->btsh", qi, kj,
                           preferred_element_type=jnp.float32) * scale * gate
            corr = jnp.exp(jnp.maximum(m, -1e30) - m_safe)
            corr = jnp.where(jnp.isfinite(m), corr, 0.0)
            n2 = corr * n + jnp.sum(s, axis=2)
            acc2 = corr[..., None] * acc + jnp.einsum(
                "btsh,bshd->bthd", s, vj.astype(jnp.float32))
            return (m_new, n2, acc2), None

        m0 = jnp.full((B, chunk, nh), -jnp.inf, jnp.float32)
        n0 = jnp.zeros((B, chunk, nh), jnp.float32)
        a0 = jnp.zeros((B, chunk, nh, dh), jnp.float32)
        (m, n, acc), _ = jax.lax.scan(
            kv_body, (m0, n0, a0),
            (jnp.moveaxis(kc, 1, 0), jnp.moveaxis(vc, 1, 0),
             jnp.moveaxis(Fc, 1, 0), jnp.moveaxis(ic, 1, 0), pos))
        denom = jnp.maximum(jnp.abs(n), jnp.exp(-m))
        return (acc / denom[..., None]).astype(v.dtype)

    out = jax.lax.map(one_q_chunk,
                      (jnp.moveaxis(qc, 1, 0), jnp.moveaxis(Fc, 1, 0),
                       pos, jnp.arange(nc)))
    return jnp.moveaxis(out, 0, 1).reshape(B, S, nh, dh)


def mlstm_step(state, q, k, v, i_gate, f_gate):
    """Recurrent mLSTM. state: C (B,nh,dh,dh), n (B,nh,dh), m (B,nh).
    q/k/v: (B,nh,dh); gates (B,nh). Returns (new_state, h (B,nh,dh))."""
    C, n, m = state
    dh = q.shape[-1]
    logf = jax.nn.log_sigmoid(f_gate.astype(jnp.float32))
    ii = i_gate.astype(jnp.float32)
    m_new = jnp.maximum(logf + m, ii)
    f_s = jnp.exp(logf + m - m_new)[..., None]                 # (B,nh,1)
    i_s = jnp.exp(ii - m_new)[..., None]
    kf, vf, qf = (a.astype(jnp.float32) for a in (k, v, q))
    C = f_s[..., None] * C + i_s[..., None] * kf[..., :, None] * vf[..., None, :]
    n = f_s * n + i_s * kf
    num = jnp.einsum("bhd,bhde->bhe", qf * (dh ** -0.5), C)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", qf * (dh ** -0.5), n)),
                      jnp.exp(-m_new))
    h = num / den[..., None]
    return (C, n, m_new), h.astype(v.dtype)


def mlstm_block(x, p, cfg, *, state=None):
    """Pre-norm residual mLSTM block. ``state`` triggers the recurrent path
    (decode, S==1); returns (out, new_state)."""
    d, di, nh, dh = _dims(cfg)
    h = L.rmsnorm(x, p["norm"])
    up = jnp.einsum("bsd,de->bse", h, p["w_up"])
    z = jnp.einsum("bsd,de->bse", h, p["w_z"])
    q = jnp.einsum("bse,ehd->bshd", up, p["w_q"])
    k = jnp.einsum("bse,ehd->bshd", up, p["w_k"])
    v = jnp.einsum("bse,ehd->bshd", up, p["w_v"])
    gates = jnp.einsum("bse,egh->bsgh", up, p["w_if"]) + p["b_if"]
    i_g, f_g = gates[:, :, 0], gates[:, :, 1]                   # (B,S,nh)
    if state is None:
        hh = mlstm_chunked(q, k, v, i_g, f_g)
        new_state = None
    else:
        (C, n, m) = state
        new_state, h1 = mlstm_step((C, n, m), q[:, 0], k[:, 0], v[:, 0],
                                   i_g[:, 0], f_g[:, 0])
        hh = h1[:, None]
    out = hh.reshape(hh.shape[0], hh.shape[1], di) * jax.nn.silu(z)
    return x + jnp.einsum("bse,ed->bsd", out, p["w_down"]), new_state


# --------------------------------------------------------------------------
# sLSTM: sequential scan (train) + single step (decode)
# --------------------------------------------------------------------------
def _slstm_cell(carry, gz):
    """carry: (c, n, m, h_prev) each (B,nh,dh); gz: pre-activations
    (B,4,nh,dh) BEFORE adding recurrence."""
    c, n, m, h_prev, r = carry
    rec = jnp.einsum("bhd,ghde->bghe", h_prev, r)
    zi, zf, zz, zo = [gz[:, j] + rec[:, j] for j in range(4)]
    log_i = zi.astype(jnp.float32)
    log_f = jax.nn.log_sigmoid(zf.astype(jnp.float32))
    m_new = jnp.maximum(log_f + m, log_i)
    i_s = jnp.exp(log_i - m_new)
    f_s = jnp.exp(log_f + m - m_new)
    zt = jnp.tanh(zz.astype(jnp.float32))
    ot = jax.nn.sigmoid(zo.astype(jnp.float32))
    c_new = f_s * c + i_s * zt
    n_new = f_s * n + i_s
    h = ot * c_new / jnp.maximum(n_new, 1e-6)
    return (c_new, n_new, m_new, h.astype(gz.dtype), r), h.astype(gz.dtype)


def slstm_block(x, p, cfg, *, state=None):
    """Sequential sLSTM over time. state (decode): (c, n, m, h_prev)."""
    B, S, d = x.shape
    nh = cfg.num_heads
    dh = d // nh
    h_in = L.rmsnorm(x, p["norm"])
    gz = jnp.einsum("bsd,dghe->bsghe", h_in, p["w_gates"]) + p["b_gates"]
    if state is None:
        z0 = jnp.zeros((B, nh, dh), jnp.float32)
        carry0 = (z0, z0, jnp.full((B, nh, dh), -jnp.inf, jnp.float32),
                  z0.astype(x.dtype), p["r_gates"])
        carry, hs = jax.lax.scan(_slstm_cell, carry0,
                                 jnp.moveaxis(gz, 1, 0))
        hs = jnp.moveaxis(hs, 0, 1)                              # (B,S,nh,dh)
        new_state = None
    else:
        carry0 = (*state, p["r_gates"])
        carry, h1 = _slstm_cell(carry0, gz[:, 0])
        new_state = carry[:4]
        hs = h1[:, None]
    out = hs.reshape(B, -1, d)
    return x + jnp.einsum("bsd,de->bse", out, p["w_down"]), new_state


# --------------------------------------------------------------------------
# Full model
# --------------------------------------------------------------------------
def xlstm_hidden(cfg: ModelConfig, params: dict, tokens: jax.Array,
                 remat_policy: str = "dots") -> jax.Array:
    x = L.embed_tokens(params["embed"], tokens)
    x = constraint(x, "batch", "act_seq", None)

    def group_body(h, gp):
        mp, sp = gp

        def m_body(hh, lp):
            out, _ = mlstm_block(hh, lp, cfg)
            return out, None

        h, _ = jax.lax.scan(m_body, h, mp)
        h, _ = slstm_block(h, sp, cfg)
        return h, None

    if remat_policy != "none":
        group_body = jax.checkpoint(group_body)
    x, _ = jax.lax.scan(group_body, x, (params["mlstm"], params["slstm"]))
    return L.rmsnorm(x, params["final_norm"])


def xlstm_loss(cfg, params, batch, *, remat_policy="dots", **_):
    hidden = xlstm_hidden(cfg, params, batch["tokens"], remat_policy)
    logits = jnp.einsum("bsd,vd->bsv", hidden, params["embed"],
                        preferred_element_type=jnp.float32)
    return L.cross_entropy(logits, batch["labels"])


def init_xlstm_state(cfg: ModelConfig, batch: int) -> dict:
    """Recurrent decode state (no KV cache — O(1) in context length)."""
    d, di, nh, dh = _dims(cfg)
    dh_s = d // nh
    per = cfg.slstm_every
    G = cfg.num_layers // per
    M = per - 1
    f32 = jnp.float32
    return {
        "m_C": jnp.zeros((G, M, batch, nh, dh, dh), f32),
        "m_n": jnp.zeros((G, M, batch, nh, dh), f32),
        "m_m": jnp.zeros((G, M, batch, nh), f32),
        "s_c": jnp.zeros((G, batch, nh, dh_s), f32),
        "s_n": jnp.zeros((G, batch, nh, dh_s), f32),
        "s_m": jnp.full((G, batch, nh, dh_s), -jnp.inf, f32),
        "s_h": jnp.zeros((G, batch, nh, dh_s), jnp.dtype(cfg.dtype)),
        "pos": jnp.zeros((), jnp.int32),
    }


def xlstm_state_specs(cfg: ModelConfig) -> dict:
    return {"m_C": ("layers", "layers2", "batch", None, None, None),
            "m_n": ("layers", "layers2", "batch", None, None),
            "m_m": ("layers", "layers2", "batch", None),
            "s_c": ("layers", "batch", None, None),
            "s_n": ("layers", "batch", None, None),
            "s_m": ("layers", "batch", None, None),
            "s_h": ("layers", "batch", None, None),
            "pos": ()}


def mlstm_final_state(q, k, v, i_gate, f_gate):
    """Final recurrent state (C, n, m) equivalent to stepping through the
    sequence — closed form from the parallel quantities (prefill->decode
    handoff)."""
    B, S, nh, dh = k.shape
    logf = jax.nn.log_sigmoid(f_gate.astype(jnp.float32))
    F = jnp.cumsum(logf, axis=1)                       # (B,S,nh)
    ii = i_gate.astype(jnp.float32)
    # weight of step s in the final state: F_S - F_s + i_s
    w = F[:, -1:, :] - F + ii                          # (B,S,nh)
    m = jnp.max(w, axis=1)                             # (B,nh)
    wexp = jnp.exp(w - m[:, None, :])
    kf, vf = k.astype(jnp.float32), v.astype(jnp.float32)
    C = jnp.einsum("bsh,bshd,bshe->bhde", wexp, kf, vf)
    n = jnp.einsum("bsh,bshd->bhd", wexp, kf)
    return C, n, m


def xlstm_prefill(cfg: ModelConfig, params: dict, tokens: jax.Array):
    """Process the prompt in parallel, returning last-token logits plus the
    recurrent state ready for decode."""
    B, S = tokens.shape
    x = L.embed_tokens(params["embed"], tokens)
    x = constraint(x, "batch", "act_seq", None)

    def group_body(h, gp):
        mp, sp = gp

        def m_body(hh, lp):
            d, di, nh, dh = _dims(cfg)
            hn = L.rmsnorm(hh, lp["norm"])
            up = jnp.einsum("bsd,de->bse", hn, lp["w_up"])
            z = jnp.einsum("bsd,de->bse", hn, lp["w_z"])
            q = jnp.einsum("bse,ehd->bshd", up, lp["w_q"])
            k = jnp.einsum("bse,ehd->bshd", up, lp["w_k"])
            v = jnp.einsum("bse,ehd->bshd", up, lp["w_v"])
            gates = jnp.einsum("bse,egh->bsgh", up, lp["w_if"]) + lp["b_if"]
            i_g, f_g = gates[:, :, 0], gates[:, :, 1]
            hh_out = mlstm_chunked(q, k, v, i_g, f_g)
            C, n, m = mlstm_final_state(q, k, v, i_g, f_g)
            out = hh_out.reshape(hh_out.shape[0], hh_out.shape[1], di) \
                * jax.nn.silu(z)
            return hh + jnp.einsum("bse,ed->bsd", out, lp["w_down"]), (C, n, m)

        h, (mC, mn, mm) = jax.lax.scan(m_body, h, mp)
        # sLSTM: run the sequential scan, keep final carry
        B_, S_, d_ = h.shape
        nh = cfg.num_heads
        dh_s = d_ // nh
        h_in = L.rmsnorm(h, sp["norm"])
        gz = jnp.einsum("bsd,dghe->bsghe", h_in, sp["w_gates"]) + sp["b_gates"]
        z0 = jnp.zeros((B_, nh, dh_s), jnp.float32)
        carry0 = (z0, z0, jnp.full((B_, nh, dh_s), -jnp.inf, jnp.float32),
                  z0.astype(h.dtype), sp["r_gates"])
        carry, hs = jax.lax.scan(_slstm_cell, carry0, jnp.moveaxis(gz, 1, 0))
        hs = jnp.moveaxis(hs, 0, 1)
        h = h + jnp.einsum("bsd,de->bse", hs.reshape(B_, S_, d_),
                           sp["w_down"])
        return h, (mC, mn, mm, carry[0], carry[1], carry[2], carry[3])

    x, states = jax.lax.scan(group_body, x,
                             (params["mlstm"], params["slstm"]))
    x = L.rmsnorm(x, params["final_norm"])
    logits = jnp.einsum("bd,vd->bv", x[:, -1], params["embed"],
                        preferred_element_type=jnp.float32)
    state = {"m_C": states[0], "m_n": states[1], "m_m": states[2],
             "s_c": states[3], "s_n": states[4], "s_m": states[5],
             "s_h": states[6], "pos": jnp.asarray(tokens.shape[1], jnp.int32)}
    return logits, state


def xlstm_decode(cfg: ModelConfig, params: dict, state: dict,
                 tokens: jax.Array):
    """One decode step: tokens (B,1) -> (logits (B,V), new state)."""
    x = L.embed_tokens(params["embed"], tokens)

    def group_body(h, xs):
        mp, sp, mC, mn, mm, sc, sn, sm, sh = xs

        def m_body(hh, lxs):
            lp, C, n, m = lxs
            out, (C2, n2, m2) = mlstm_block(hh, lp, cfg, state=(C, n, m))
            return out, (C2, n2, m2)

        h, (mC2, mn2, mm2) = jax.lax.scan(m_body, h, (mp, mC, mn, mm))
        h, (sc2, sn2, sm2, sh2) = slstm_block(h, sp, cfg,
                                              state=(sc, sn, sm, sh))
        return h, (mC2, mn2, mm2, sc2, sn2, sm2, sh2)

    x, news = jax.lax.scan(
        group_body, x,
        (params["mlstm"], params["slstm"], state["m_C"], state["m_n"],
         state["m_m"], state["s_c"], state["s_n"], state["s_m"],
         state["s_h"]))
    x = L.rmsnorm(x, params["final_norm"])
    logits = jnp.einsum("bsd,vd->bsv", x, params["embed"],
                        preferred_element_type=jnp.float32)
    new_state = {"m_C": news[0], "m_n": news[1], "m_m": news[2],
                 "s_c": news[3], "s_n": news[4], "s_m": news[5],
                 "s_h": news[6], "pos": state["pos"] + 1}
    return logits[:, 0], new_state
