"""Shared model building blocks: norms, RoPE/M-RoPE, GQA attention (einsum +
query-chunked memory-efficient variants), MLPs, embeddings.

Conventions:
 * activations  (B, S, D);  queries (B, S, KV, G, hd);  keys/values
   (B, T, KV, hd) — GQA is a grouped einsum, repeated KV is never
   materialized;
 * masks are built on the fly from position vectors (never a materialized
   (S, S) array at long context);
 * softmax/normalization in float32, matmuls in the model dtype with float32
   accumulation via ``preferred_element_type``.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.sharding import constraint


# --------------------------------------------------------------------------
# Norms
# --------------------------------------------------------------------------
def rmsnorm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)
    return out.astype(x.dtype)


# --------------------------------------------------------------------------
# RoPE / M-RoPE
# --------------------------------------------------------------------------
def rope_cos_sin(positions: jax.Array, head_dim: int, theta: float,
                 sections: Optional[tuple] = None
                 ) -> tuple[jax.Array, jax.Array]:
    """positions: (B, S) int32, or (C, B, S) for M-RoPE with C position
    channels (temporal/height/width). Returns cos/sin of shape (B, S, hd/2).

    M-RoPE (Qwen2-VL): frequency slot i draws its position from channel
    section_id(i), with ``sections`` giving the per-channel slot counts.
    """
    half = head_dim // 2
    freq = theta ** (-jnp.arange(half, dtype=jnp.float32) * 2.0 / head_dim)
    pos = positions if positions.ndim == 3 else positions[None]
    if sections is None:
        sec_ids = np.zeros((half,), dtype=np.int32)
    else:
        assert sum(sections) == half, (sections, half)
        sec_ids = np.repeat(np.arange(len(sections)), sections).astype(np.int32)
    pos_sel = pos[sec_ids]                      # (half, B, S)
    angles = jnp.einsum("hbs,h->bsh", pos_sel.astype(jnp.float32), freq)
    return jnp.cos(angles), jnp.sin(angles)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x: (B, S, ..., hd); cos/sin: (B, S, hd/2) broadcast over head dims."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    shape = cos.shape[:2] + (1,) * (x.ndim - 3) + cos.shape[2:]
    c, s = cos.reshape(shape), sin.reshape(shape)
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    return jnp.concatenate([xf1 * c - xf2 * s, xf2 * c + xf1 * s],
                           axis=-1).astype(x.dtype)


# --------------------------------------------------------------------------
# Attention
# --------------------------------------------------------------------------
NEG_INF = -1e30


def _band_bias(q_pos: jax.Array, kv_pos: jax.Array, causal: bool,
               window) -> jax.Array:
    """Additive bias (..., Sq, Tk) computed from positions; ``window`` may be
    a traced scalar (0 = unwindowed) so local/global layers share one scan
    body."""
    q = q_pos[..., :, None].astype(jnp.int32)
    k = kv_pos[..., None, :].astype(jnp.int32)
    ok = jnp.ones(q.shape[:-1] + (k.shape[-1],), dtype=bool)
    if causal:
        ok = ok & (k <= q)
    w = jnp.asarray(window, jnp.int32)
    ok = ok & ((w <= 0) | (q - k < w))
    return jnp.where(ok, 0.0, NEG_INF).astype(jnp.float32)


def repeat_kv(k: jax.Array, num_heads: int) -> jax.Array:
    """(B,T,KV,hd) -> (B,T,H,hd). Flat-head layout keeps every tensor sharded
    on the H axis — GSPMD propagates it cleanly, whereas a (KV,G) grouped
    reshape of an H-sharded tensor forces involuntary rematerialization
    (observed; see DESIGN.md §6). XLA fuses the broadcast into the dot."""
    B, T, KV, hd = k.shape
    if KV == num_heads:
        return k
    G = num_heads // KV
    return jnp.broadcast_to(k[:, :, :, None, :], (B, T, KV, G, hd)) \
        .reshape(B, T, num_heads, hd)


def gqa_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  q_pos: jax.Array, kv_pos: jax.Array,
                  causal: bool = True, window=0,
                  kv_valid: Optional[jax.Array] = None) -> jax.Array:
    """Einsum attention. q: (B,S,H,hd), k/v: (B,T,KV,hd) -> (B,S,H,hd).

    ``kv_valid``: optional (B, T) bool marking populated cache slots
    (decode). Softmax in f32.
    """
    H = q.shape[2]
    k = repeat_kv(k, H)
    v = repeat_kv(v, H)
    scale = q.shape[-1] ** -0.5
    scores = jnp.einsum("bshd,bthd->bhst", q, k,
                        preferred_element_type=jnp.float32) * scale
    bias = _band_bias(q_pos, kv_pos, causal, window)      # (S, T) or (B,S,T)
    while bias.ndim < scores.ndim:
        bias = bias[..., None, :, :] if bias.ndim >= 3 else bias[None]
    scores = scores + bias
    if kv_valid is not None:
        scores = jnp.where(kv_valid[:, None, None, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores.astype(jnp.float32), axis=-1)
    out = jnp.einsum("bhst,bthd->bshd", probs.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return out.astype(v.dtype)


def chunked_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                      q_pos: jax.Array, kv_pos: jax.Array,
                      causal: bool = True, window=0,
                      chunk: int = 512) -> jax.Array:
    """Memory-efficient attention: map over query chunks so peak live memory
    is O(S * chunk) instead of O(S^2). The XLA analogue of flash attention —
    the Pallas kernel (`repro.kernels.flash_attention`) is the TPU hot path;
    this is the portable default for 32k+ prefill."""
    B, S, H, hd = q.shape
    assert S % chunk == 0, (S, chunk)
    nq = S // chunk
    qc = q.reshape(B, nq, chunk, H, hd).transpose(1, 0, 2, 3, 4)
    pc = q_pos.reshape(nq, chunk)

    def one_chunk(args):
        qi, pi = args
        return gqa_attention(qi, k, v, q_pos=pi, kv_pos=kv_pos,
                             causal=causal, window=window)

    out = jax.lax.map(one_chunk, (qc, pc))        # (nq, B, chunk, H, hd)
    return out.transpose(1, 0, 2, 3, 4).reshape(B, S, H, hd)


def attention(q, k, v, *, q_pos, kv_pos, causal=True, window=0,
              kv_valid=None, impl: str = "einsum", chunk: int = 512):
    if impl == "chunked" and q.shape[1] > chunk and kv_valid is None:
        return chunked_attention(q, k, v, q_pos=q_pos, kv_pos=kv_pos,
                                 causal=causal, window=window, chunk=chunk)
    return gqa_attention(q, k, v, q_pos=q_pos, kv_pos=kv_pos, causal=causal,
                         window=window, kv_valid=kv_valid)


# --------------------------------------------------------------------------
# Projections / MLP
# --------------------------------------------------------------------------
def qkv_proj(x, wq, wk, wv, num_kv: int, groups: int):
    """x: (B,S,D) -> q (B,S,H,hd), k/v (B,S,KV,hd). Flat-head layout (no
    grouped reshape of sharded weights — see repeat_kv)."""
    q = jnp.einsum("bsd,dnh->bsnh", x, wq)
    k = jnp.einsum("bsd,dkh->bskh", x, wk)
    v = jnp.einsum("bsd,dkh->bskh", x, wv)
    return q, k, v


def out_proj(o, wo):
    """o: (B,S,H,hd), wo: (H, hd, D) -> (B,S,D)."""
    return jnp.einsum("bsnh,nhd->bsd", o, wo)


def mlp(x, params: dict, mlp_type: str):
    if mlp_type == "swiglu":
        gate = jnp.einsum("bsd,df->bsf", x, params["w_gate"])
        up = jnp.einsum("bsd,df->bsf", x, params["w_up"])
        h = jax.nn.silu(gate) * up
    else:
        up = jnp.einsum("bsd,df->bsf", x, params["w_up"])
        h = jax.nn.gelu(up)
    h = constraint(h, "batch", None, "d_ff")
    return jnp.einsum("bsf,fd->bsd", h, params["w_down"])


# --------------------------------------------------------------------------
# Embedding / logits / loss
# --------------------------------------------------------------------------
def embed_tokens(embed: jax.Array, tokens: jax.Array) -> jax.Array:
    """Under a mesh: one-hot matmul — a dot partitions cleanly when the table
    is (vocab x embed_d)-sharded (a row gather would all-gather the table).
    The one-hot carries explicit vocab sharding so the embed GRADIENT
    (oh^T @ dx) comes out vocab-sharded instead of replicated.
    Off-mesh (CPU tests): plain gather."""
    from repro.distributed.sharding import active_mesh
    if active_mesh() is not None:
        oh = jax.nn.one_hot(tokens, embed.shape[0], dtype=embed.dtype)
        oh = constraint(oh, "batch", None, "vocab")
        return jnp.einsum("...sv,vd->...sd", oh, embed)
    return jnp.take(embed, tokens, axis=0)


def logits_from_hidden(x, params, tie: bool):
    # Exit any sequence-parallel region before the LM head and pin the
    # vocab-parallel sharding of the logits: without this the unembed
    # GRADIENT materializes replicated (d x V in f32) on every device.
    x = constraint(x, "batch", None, None)
    if tie:
        out = jnp.einsum("bsd,vd->bsv", x, params["embed"],
                         preferred_element_type=jnp.float32)
    else:
        out = jnp.einsum("bsd,dv->bsv", x, params["unembed"],
                         preferred_element_type=jnp.float32)
    return constraint(out, "batch", None, "vocab")


def _gold_logit(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """logits[b,s,labels[b,s]] — as a one-hot contraction under a mesh (a
    gather along a vocab-sharded axis forces SPMD to replicate the logits
    and wrecks the unembed-gradient sharding; a dot partitions cleanly)."""
    from repro.distributed.sharding import active_mesh
    lab = jnp.maximum(labels, 0)
    if active_mesh() is not None:
        oh = jax.nn.one_hot(lab, logits.shape[-1], dtype=logits.dtype)
        return jnp.einsum("...v,...v->...", logits, oh)
    return jnp.take_along_axis(logits, lab[..., None], axis=-1)[..., 0]


def cross_entropy(logits: jax.Array, labels: jax.Array,
                  chunk: int = 0) -> jax.Array:
    """Mean token NLL; labels < 0 are masked. ``chunk`` > 0 computes the
    loss over sequence chunks (never materializing full (B,S,V) f32 logits
    at once when the caller fuses it — see model.loss_fn chunked path)."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    nll = lse - _gold_logit(logits, labels)
    mask = (labels >= 0).astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)


# --------------------------------------------------------------------------
# Init helpers
# --------------------------------------------------------------------------
def dense_init(rng, shape, dtype, fan_in: Optional[int] = None):
    fan = fan_in if fan_in is not None else shape[0]
    std = fan ** -0.5
    return (jax.random.normal(rng, shape, jnp.float32) * std).astype(dtype)


def split_tree(rng, n: int):
    return list(jax.random.split(rng, n))
