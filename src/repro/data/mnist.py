"""MNIST for the paper's quickstart scenario, with an offline fallback.

The paper trains a small Keras model on MNIST per client.  :func:`load_mnist`
tries the real IDX files first (a local ``data_dir``, then the canonical
mirrors) and — because CI and this container run offline — **falls back to a
seeded synthetic digit set** with the same geometry: each class is a fixed
seeded template (blurred blob constellation) plus pixel noise
(:class:`SyntheticMnist`).  Linearly separable enough that the paper's tiny
MLP learns it in a few local epochs, deterministic per (seed, client).

:func:`dirichlet_shards` produces the non-IID client partition (one
Dirichlet(alpha) class mixture per client) the federated scenarios train
over.
"""

from __future__ import annotations

import dataclasses
import gzip
import os
import struct
import urllib.request

import numpy as np


@dataclasses.dataclass
class SyntheticMnist:
    num_classes: int = 10
    side: int = 28
    seed: int = 0
    noise: float = 0.25

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        S = self.side
        self.templates = np.zeros((self.num_classes, S, S), np.float32)
        yy, xx = np.mgrid[0:S, 0:S]
        for c in range(self.num_classes):
            for _ in range(3):   # 3 gaussian blobs per class
                cy, cx = rng.uniform(4, S - 4, size=2)
                sig = rng.uniform(2.0, 4.0)
                self.templates[c] += np.exp(
                    -((yy - cy) ** 2 + (xx - cx) ** 2) / (2 * sig ** 2))
        self.templates /= self.templates.max(axis=(1, 2), keepdims=True)

    def sample(self, n: int, *, client: int = 0, step: int = 0
               ) -> tuple[np.ndarray, np.ndarray]:
        rng = np.random.default_rng(
            self.seed * 1_000_003 + client * 7919 + step)
        labels = rng.integers(0, self.num_classes, size=n)
        x = self.templates[labels] + rng.normal(
            0, self.noise, size=(n, self.side, self.side)).astype(np.float32)
        return x.reshape(n, -1).astype(np.float32), labels.astype(np.int32)


# --------------------------------------------------------------------------
# Real MNIST (IDX files) with the synthetic fallback
# --------------------------------------------------------------------------
_MNIST_FILES = {
    "x_train": "train-images-idx3-ubyte.gz",
    "y_train": "train-labels-idx1-ubyte.gz",
    "x_test": "t10k-images-idx3-ubyte.gz",
    "y_test": "t10k-labels-idx1-ubyte.gz",
}
_MNIST_MIRRORS = (
    "https://storage.googleapis.com/cvdf-datasets/mnist/",
    "https://ossci-datasets.s3.amazonaws.com/mnist/",
)


def _read_idx(data: bytes) -> np.ndarray:
    """Parse one IDX payload (images: magic 2051; labels: magic 2049)."""
    if len(data) < 8:
        raise ValueError("truncated IDX payload")
    magic, n = struct.unpack(">II", data[:8])
    if magic == 2049:                              # labels: (n,) uint8
        return np.frombuffer(data, np.uint8, count=n, offset=8)
    if magic == 2051:                              # images: (n, rows, cols)
        rows, cols = struct.unpack(">II", data[8:16])
        arr = np.frombuffer(data, np.uint8, count=n * rows * cols, offset=16)
        return arr.reshape(n, rows * cols)
    raise ValueError(f"bad IDX magic {magic}")


def _fetch_idx(name: str, data_dir: str | None, download: bool,
               timeout: float) -> np.ndarray:
    """One IDX file from ``data_dir`` or the mirrors; raises on any miss."""
    if data_dir is not None:
        path = os.path.join(data_dir, name)
        if os.path.exists(path):
            with gzip.open(path, "rb") as f:
                return _read_idx(f.read())
    if not download:
        raise FileNotFoundError(name)
    last: Exception | None = None
    for mirror in _MNIST_MIRRORS:
        try:
            with urllib.request.urlopen(mirror + name,
                                        timeout=timeout) as resp:
                raw = resp.read()
            data = gzip.decompress(raw)
            if data_dir is not None:
                os.makedirs(data_dir, exist_ok=True)
                with open(os.path.join(data_dir, name), "wb") as f:
                    f.write(raw)
            return _read_idx(data)
        except Exception as e:  # noqa: BLE001 - any mirror failure -> next
            last = e
    raise ConnectionError(f"no MNIST mirror reachable for {name}: {last}")


@dataclasses.dataclass(frozen=True)
class MnistData:
    """A concrete train/test split, real or synthetic.

    ``x_*`` are float32 ``(n, 784)`` in [~0, ~1]; ``y_*`` are int32 labels.
    ``source`` records which path produced the data (``"real"`` |
    ``"synthetic"``) so benchmarks can report it honestly.
    """

    x_train: np.ndarray
    y_train: np.ndarray
    x_test: np.ndarray
    y_test: np.ndarray
    source: str

    @property
    def n_train(self) -> int:
        return int(self.x_train.shape[0])


def load_mnist(n_train: int = 8192, n_test: int = 1024, *, seed: int = 0,
               data_dir: str | None = None, download: bool = True,
               timeout: float = 5.0) -> MnistData:
    """Real MNIST when reachable, the seeded synthetic set otherwise.

    The fallback is **deterministic** per ``seed`` (pinned by
    ``tests/test_client_compute.py``): CI runs offline today, so every
    offline run of the same config sees bit-identical data.  Set
    ``download=False`` to force the offline path explicitly.
    """
    try:
        x_train = _fetch_idx(_MNIST_FILES["x_train"], data_dir, download,
                             timeout)
        y_train = _fetch_idx(_MNIST_FILES["y_train"], data_dir, download,
                             timeout)
        x_test = _fetch_idx(_MNIST_FILES["x_test"], data_dir, download,
                            timeout)
        y_test = _fetch_idx(_MNIST_FILES["y_test"], data_dir, download,
                            timeout)
        return MnistData(
            x_train=(x_train[:n_train].astype(np.float32) / 255.0),
            y_train=y_train[:n_train].astype(np.int32),
            x_test=(x_test[:n_test].astype(np.float32) / 255.0),
            y_test=y_test[:n_test].astype(np.int32),
            source="real")
    except Exception:  # noqa: BLE001 - unreachable/corrupt -> synthetic
        syn = SyntheticMnist(seed=seed)
        # Distinct (client, step) keys for the two splits so the test set
        # is never a subset of the training set.
        x_train, y_train = syn.sample(n_train, client=0, step=0)
        x_test, y_test = syn.sample(n_test, client=1_000_000, step=0)
        return MnistData(x_train=x_train, y_train=y_train,
                         x_test=x_test, y_test=y_test, source="synthetic")


def dirichlet_shards(labels: np.ndarray, n_clients: int, *,
                     alpha: float = 0.5, seed: int = 0,
                     shard_size: int | None = None) -> np.ndarray:
    """Non-IID client partition: one Dirichlet(alpha) class mixture each.

    Returns an int32 index matrix ``(n_clients, shard_size)`` into
    ``labels``'s axis 0 — a fixed-width layout so the vmapped trainer can
    gather every client's shard with one indexed load.  Small ``alpha``
    concentrates each client on few classes (the FedAvg-hostile regime);
    large ``alpha`` approaches IID.  Sampling is with replacement within a
    class, seeded, and consumes only ``default_rng(seed)`` draws in a fixed
    order — deterministic across platforms.
    """
    if n_clients < 1:
        raise ValueError("n_clients must be >= 1")
    if alpha <= 0:
        raise ValueError("dirichlet alpha must be > 0")
    labels = np.asarray(labels)
    n = labels.shape[0]
    if shard_size is None:
        shard_size = max(1, n // n_clients)
    classes = np.unique(labels)
    by_class = {int(c): np.flatnonzero(labels == c) for c in classes}
    rng = np.random.default_rng(seed)
    out = np.empty((n_clients, shard_size), np.int32)
    for i in range(n_clients):
        mix = rng.dirichlet(np.full(len(classes), alpha))
        drawn_classes = rng.choice(len(classes), size=shard_size, p=mix)
        for j, ci in enumerate(drawn_classes):
            pool = by_class[int(classes[ci])]
            out[i, j] = pool[int(rng.integers(len(pool)))]
    return out
