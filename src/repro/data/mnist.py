"""Synthetic MNIST stand-in for the paper's quickstart scenario.

The paper trains a small Keras model on MNIST per client. This container is
offline, so we synthesize a 10-class 28x28 problem with the same geometry:
each class is a fixed seeded template (blurred blob constellation) plus
pixel noise. Linearly separable enough that the paper's tiny MLP learns it in
a few local epochs, deterministic per (seed, client).
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class SyntheticMnist:
    num_classes: int = 10
    side: int = 28
    seed: int = 0
    noise: float = 0.25

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        S = self.side
        self.templates = np.zeros((self.num_classes, S, S), np.float32)
        yy, xx = np.mgrid[0:S, 0:S]
        for c in range(self.num_classes):
            for _ in range(3):   # 3 gaussian blobs per class
                cy, cx = rng.uniform(4, S - 4, size=2)
                sig = rng.uniform(2.0, 4.0)
                self.templates[c] += np.exp(
                    -((yy - cy) ** 2 + (xx - cx) ** 2) / (2 * sig ** 2))
        self.templates /= self.templates.max(axis=(1, 2), keepdims=True)

    def sample(self, n: int, *, client: int = 0, step: int = 0
               ) -> tuple[np.ndarray, np.ndarray]:
        rng = np.random.default_rng(
            self.seed * 1_000_003 + client * 7919 + step)
        labels = rng.integers(0, self.num_classes, size=n)
        x = self.templates[labels] + rng.normal(
            0, self.noise, size=(n, self.side, self.side)).astype(np.float32)
        return x.reshape(n, -1).astype(np.float32), labels.astype(np.int32)
