"""Deterministic synthetic data pipeline.

Offline container: no datasets on disk, so the pipeline synthesizes a
learnable token distribution (order-2 Markov chains with per-stream
transition tables) — losses genuinely decrease, smoke tests and the FL
convergence benchmarks have signal, and everything is reproducible from a
seed. The pipeline is shard-aware: ``worker_slice`` carves the global batch
for a data-parallel worker, and ``federated_partitions`` gives each FL client
a disjoint sub-distribution (non-IID knob included).
"""

from __future__ import annotations

import dataclasses
from typing import Iterator, Optional

import numpy as np


@dataclasses.dataclass
class TokenPipeline:
    """Infinite deterministic stream of (tokens, labels) batches."""

    vocab_size: int
    seq_len: int
    batch_size: int
    seed: int = 0
    markov_order_states: int = 64   # distinct hidden transition rows
    skew: float = 1.2               # zipf-ish skew of the transition tables

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        V = self.vocab_size
        # Row-stochastic transition table over a hashed state.
        raw = rng.pareto(self.skew, size=(self.markov_order_states, V)) + 1e-3
        self._table_logits = np.log(raw / raw.sum(axis=1, keepdims=True))
        self._step = 0

    def _state(self, prev: np.ndarray, prev2: np.ndarray) -> np.ndarray:
        # Order-1 dominant (bigram-learnable) so tiny models get signal fast.
        return prev % self.markov_order_states

    def batch(self, step: Optional[int] = None) -> dict:
        """Batch for a given step (stateless => resumable/replayable)."""
        if step is None:
            step = self._step
            self._step += 1
        rng = np.random.default_rng((self.seed + 1) * 1_000_003 + step)
        B, S, V = self.batch_size, self.seq_len, self.vocab_size
        toks = np.zeros((B, S + 1), dtype=np.int32)
        toks[:, 0] = rng.integers(0, V, size=B)
        toks[:, 1] = rng.integers(0, V, size=B)
        gumbel = rng.gumbel(size=(B, S + 1, 1)).astype(np.float32)
        for t in range(2, S + 1):
            state = self._state(toks[:, t - 1], toks[:, t - 2])
            logits = self._table_logits[state]          # (B, V)
            g = rng.gumbel(size=logits.shape).astype(np.float32)
            toks[:, t] = np.argmax(logits + g, axis=-1)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    def worker_slice(self, batch: dict, worker: int, num_workers: int) -> dict:
        per = self.batch_size // num_workers
        sl = slice(worker * per, (worker + 1) * per)
        return {k: v[sl] for k, v in batch.items()}

    def __iter__(self) -> Iterator[dict]:
        step = 0
        while True:
            yield self.batch(step)
            step += 1


def federated_partitions(vocab_size: int, seq_len: int, batch_size: int,
                         num_clients: int, *, seed: int = 0,
                         non_iid: float = 0.0) -> list[TokenPipeline]:
    """One pipeline per FL client. ``non_iid`` in [0,1] skews each client's
    transition tables away from the common distribution (0 = IID shards)."""
    out = []
    for c in range(num_clients):
        p = TokenPipeline(vocab_size, seq_len, batch_size,
                          seed=seed + 7919 * (c + 1))
        if non_iid > 0.0:
            common = TokenPipeline(vocab_size, seq_len, batch_size,
                                   seed=seed)._table_logits
            p._table_logits = ((1 - non_iid) * common
                               + non_iid * p._table_logits)
        else:
            p._table_logits = TokenPipeline(
                vocab_size, seq_len, batch_size, seed=seed)._table_logits
        out.append(p)
    return out


def synthetic_batch(vocab_size: int, seq_len: int, batch_size: int,
                    seed: int = 0) -> dict:
    return TokenPipeline(vocab_size, seq_len, batch_size, seed=seed).batch(0)
