from repro.data.pipeline import (TokenPipeline, federated_partitions,
                                 synthetic_batch)
from repro.data.mnist import SyntheticMnist

__all__ = ["TokenPipeline", "federated_partitions", "synthetic_batch",
           "SyntheticMnist"]
