"""Scheduling policies over the event-driven server core.

Two policies drive :class:`repro.core.server.ServerCore`:

* :class:`SyncScheduler` (``mode="sync"``) — the paper's round barrier:
  sample a roster, broadcast, wait for every sampled client (or the round
  deadline), aggregate, repeat.  **Bit-compatible** with the pre-refactor
  ``FederatedSystem.run_round`` loop — same roster draws, same transaction
  numbering (round-scoped ``2r``/``2r+1``), same event order, same floats —
  pinned by ``tests/test_orchestrator_equivalence.py``.

* :class:`AsyncScheduler` (``mode="async"``) — a FedBuff-style buffered
  asynchronous server: every client runs its own session loop
  (downlink -> train -> uplink -> cadence gap -> re-enter) and the server
  aggregates whenever ``buffer_k`` updates are buffered, weighting each by
  ``staleness_discount ** staleness`` (clamped at ``staleness_floor``),
  where staleness counts server aggregations since the update's downlink.
  Sessions from different virtual rounds overlap in flight, which is why
  transaction numbering is session-scoped (``ServerCore.new_txn_pair``) and
  the transport must declare ``caps.concurrent_txns``.  Semantics are
  documented in ``docs/ASYNC.md``.

Both emit one :class:`RoundResult` per aggregation into ``core.history``.
"""

from __future__ import annotations

import random
from typing import Optional

from repro.core.server import (ARRIVED, DOWNLINK, FAILED, TIMEOUT, TRAINING,
                               ClientSession, FLClient, FLConfig, RoundResult,
                               ServerCore)


# --------------------------------------------------------------------------
# Roster sampling (sync partial participation)
# --------------------------------------------------------------------------
def sample_participants(active: list[FLClient], round_idx: int,
                        cfg: FLConfig) -> list[FLClient]:
    f = cfg.participation_fraction
    if f >= 1.0 or len(active) <= 1:
        return list(active)
    k = max(cfg.min_participants, int(round(f * len(active))))
    k = min(k, len(active))
    # Partial Fisher-Yates over indices, driven only by Random.random()
    # (the one generator method with a cross-version stability guarantee),
    # keyed by integers so PYTHONHASHSEED cannot perturb the draw.
    rng = random.Random(hash((cfg.participation_seed, round_idx)))
    idx = list(range(len(active)))
    for j in range(k):
        pick = j + int(rng.random() * (len(idx) - j))
        idx[j], idx[pick] = idx[pick], idx[j]
    return [active[i] for i in sorted(idx[:k])]


# --------------------------------------------------------------------------
# Sync: the paper's round barrier
# --------------------------------------------------------------------------
class SyncScheduler:
    """Lockstep rounds.  One shared (txn_down, txn_up) = (2r, 2r+1) pair per
    round — receivers disambiguate by sender address — so the wire traffic
    is byte-identical to the pre-refactor loop."""

    mode = "sync"

    def __init__(self, core: ServerCore):
        self.core = core
        self.cfg = core.cfg
        core.bind(self)
        self._round_idx = -1
        self._round_open = False
        self._roster: dict[str, FLClient] = {}
        self._resolved: set[str] = set()
        # addr -> update token: a flat f32 vector, or an opaque pending
        # handle (core._PendingWire) when cfg.batch_wire defers wire decode
        # to the aggregation boundary.  Schedulers never inspect the value
        # — it flows straight into core.apply_aggregation, which resolves
        # pendings in one stacked batch decode.
        self._updates: dict = {}
        self._failed: list[str] = []
        self._deadline_timer = None
        self._late_folded = 0
        self._staleness_clamped = 0
        self._retx0 = 0
        self._decode0 = core.decode_errors
        self._bcast0 = core.bcast_cache_hits
        self._round_start_ns = 0
        self._stats0 = core.snapshot_stats()

    # -- round driver ---------------------------------------------------------
    def _begin_round(self, round_idx: Optional[int],
                     txn_pair: Optional[tuple[int, int]] = None,
                     clear_sessions: bool = True) -> None:
        """Open the barrier: sample a roster, arm the deadline, start every
        session.  ``txn_pair`` overrides the round-derived ``(2r, 2r+1)``
        numbering, and ``clear_sessions=False`` keeps earlier rounds'
        sessions registered (the hierarchical cell barrier runs many
        overlapping instances over one simulator, so its rounds draw
        session-scoped pairs from ``ServerCore.new_txn_pair`` and stragglers
        must still find their sessions)."""
        core = self.core
        self._round_idx = (self._round_idx + 1 if round_idx is None
                           else round_idx)
        r = self._round_idx
        if clear_sessions:
            core.clear_sessions()
        roster = sample_participants(core.pool.active(r), r, self.cfg)
        self._roster = {c.addr: c for c in roster}
        self._resolved = set()
        self._updates = {}
        self._failed = []
        self._round_open = True
        self._late_folded = 0
        self._staleness_clamped = 0
        self._retx0 = core.retx_total
        self._decode0 = core.decode_errors
        self._bcast0 = core.bcast_cache_hits
        self._round_start_ns = core.sim.now_ns
        self._stats0 = core.snapshot_stats()

        if core.controller is not None:
            # Control step: between transactions is exactly here — last
            # round's telemetry is final, this round's sessions are not yet
            # open, so a renegotiated spec governs the whole round.
            for client in roster:
                core.apply_control(client.addr)

        if self.cfg.round_deadline_ns is not None:
            self._deadline_timer = core.sim.schedule(
                self.cfg.round_deadline_ns, self._on_deadline)

        txn_down, txn_up = txn_pair if txn_pair is not None \
            else (2 * r, 2 * r + 1)
        for client in roster:
            session = core.open_session(client, r, txn_down, txn_up,
                                        model_version=r)
            if self.cfg.broadcast_model:
                core.begin_downlink(session)
            else:
                core.begin_local(session)

    def _build_result(self) -> RoundResult:
        core = self.core
        return RoundResult(
            round_idx=self._round_idx,
            duration_ns=core.sim.now_ns - self._round_start_ns,
            arrived=sorted(self._updates.keys()),
            failed=list(self._failed),
            skipped_unhealthy=core.pool.benched(self._round_idx),
            late_folded=self._late_folded,
            retransmissions=core.retx_total - self._retx0,
            roster=sorted(self._roster),
            staleness_clamped=self._staleness_clamped,
            decode_errors=core.decode_errors - self._decode0,
            bcast_cache_hits=core.bcast_cache_hits - self._bcast0,
            client_health=core.telemetry.snapshot_all(),
            **core.stats_delta(self._stats0),
        )

    def run_round(self, round_idx: Optional[int] = None) -> RoundResult:
        self._begin_round(round_idx)
        self.core.sim.run()
        if self._round_open:       # e.g. every client failed before deadline
            self._finalize()
        return self.core.emit_result(self._build_result())

    def run_rounds(self, n: int) -> list[RoundResult]:
        return [self.run_round() for _ in range(n)]

    # -- events from the core -------------------------------------------------
    def accept_downlink(self, session: ClientSession) -> bool:
        # A downlink of the current round is honored even after the barrier
        # closed (the training it triggers uplinks into the late buffer);
        # anything older is stale traffic from a finished round.
        return session.round_idx == self._round_idx

    def on_uplink(self, session: Optional[ClientSession], addr: str,
                  txn: int, vec) -> None:
        # `vec` is an opaque update token (flat vector, or a pending wire
        # handle under cfg.batch_wire) — stored, never inspected here.
        if session is None:
            return   # txn of a cleared round: cannot occur (rounds drain)
        if session.round_idx != self._round_idx or not self._round_open:
            # Straggler from a previous round: fold next round, discounted.
            self.core.late_buffer.append((session.round_idx, addr, vec))
            return
        session.state = ARRIVED
        self._updates[addr] = vec
        self.core.pool.record_success(addr)
        self._mark_resolved(addr)

    def on_session_failed(self, session: ClientSession) -> None:
        addr = session.addr
        if addr in self._roster and addr not in self._resolved:
            session.state = FAILED
            self._failed.append(addr)
            self.core.pool.record_failure(addr, self._round_idx)
            self._mark_resolved(addr)

    def on_client_added(self, client: FLClient) -> None:
        pass   # picked up by pool.active() at the next round

    # -- barrier --------------------------------------------------------------
    def _mark_resolved(self, addr: str) -> None:
        self._resolved.add(addr)
        if self._round_open and self._resolved >= set(self._roster):
            self._finalize()

    def _on_deadline(self) -> None:
        if self._round_open:
            sim = self.core.sim
            sim.log(f"t={sim.now_ns}ns SERVER round "
                    f"{self._round_idx} deadline -> straggler cutoff "
                    f"({len(self._updates)}/{len(self._roster)} arrived)")
            self._finalize()

    def _finalize(self) -> None:
        self._round_open = False
        if self._deadline_timer is not None:
            self._deadline_timer.cancel()
            self._deadline_timer = None
        contribs = []
        for addr, vec in self._updates.items():
            contribs.append((vec, self._roster[addr].weight))
        self._late_folded, self._staleness_clamped = \
            self.core.fold_late_buffer(self._round_idx, contribs)
        self.core.apply_aggregation(contribs)


# --------------------------------------------------------------------------
# Async: FedBuff-style buffered aggregation with overlapping sessions
# --------------------------------------------------------------------------
class AsyncScheduler:
    """No barrier: clients cycle at their own cadence, the server aggregates
    every ``buffer_k`` buffered updates with staleness-discounted weights.

    ``run_rounds(n)`` performs (up to) ``n`` aggregations: it enters every
    eligible client, lets the event loop run — aggregations fire *inside*
    the loop as the buffer fills — and stops re-entering clients once the
    target is reached, letting in-flight sessions drain.  A final partial
    flush folds whatever is still buffered if the calendar drained before
    the buffer refilled (e.g. every client went unhealthy).

    ``round_deadline_ns``, when set, is promoted from round level to
    *session* level: a watchdog re-enters a client whose downlink or uplink
    is permanently stuck (a best-effort transport that lost every packet of
    a leg never raises a failure callback).  The stuck session's update is
    not lost — if it arrives later it is buffered with its staleness.
    """

    mode = "async"

    def __init__(self, core: ServerCore):
        self.core = core
        self.cfg = core.cfg
        if not core.transport.caps.concurrent_txns:
            raise ValueError(
                f"transport {core.transport.name!r} does not support "
                f"concurrent transactions per address pair "
                f"(caps.concurrent_txns=False); async scheduling needs "
                f"overlapping sessions")
        core.bind(self)
        self._agg_idx = 0
        self._model_version = 0
        self._target = 0
        self._stopped = True
        self._buffer: list[tuple[ClientSession, object, int]] = []
        self._inflight: dict[str, ClientSession] = {}
        self._idle: set[str] = set()       # parked: benched or stopped
        self._client_round: dict[str, int] = {}
        self._watchdogs: dict[int, object] = {}   # id(session) -> Timer
        # Last timed-out session per client, kept registered so a late
        # arrival is still ingested — bounded at one per client (opening
        # the next one evicts the previous from the core registries).
        self._timed_out: dict[str, ClientSession] = {}
        self._failed_window: list[str] = []
        self._timeouts_window = 0
        self._stats0 = core.snapshot_stats()
        self._retx0 = core.retx_total
        self._decode0 = core.decode_errors
        self._bcast0 = core.bcast_cache_hits
        self._window_start_ns = core.sim.now_ns

    # -- drivers --------------------------------------------------------------
    def run_round(self, round_idx: Optional[int] = None) -> RoundResult:
        if round_idx is not None:
            raise ValueError("async mode numbers aggregations itself; "
                             "explicit round_idx is sync-only")
        results = self.run_rounds(1)
        if not results:
            raise RuntimeError(
                "async run drained without a single aggregation "
                "(no client could complete an upload)")
        return results[0]

    def run_rounds(self, n: int) -> list[RoundResult]:
        core = self.core
        hist0 = len(core.history)
        self._target = self._agg_idx + n
        self._stopped = False
        self._stats0 = core.snapshot_stats()
        self._retx0 = core.retx_total
        self._decode0 = core.decode_errors
        self._bcast0 = core.bcast_cache_hits
        self._window_start_ns = core.sim.now_ns
        for client in core.pool.active(self._agg_idx):
            if client.addr not in self._inflight:
                self._enter(client)
        core.sim.run()
        if self._agg_idx < self._target and self._buffer:
            self._flush()    # drained early: fold the partial buffer
        self._stopped = True
        return core.history[hist0:]

    # -- session entry / re-entry --------------------------------------------
    def _enter(self, client: FLClient) -> None:
        core = self.core
        addr = client.addr
        self._idle.discard(addr)
        # Control step: a session entry is this client's between-transactions
        # moment — its previous transactions' telemetry is final and nothing
        # of its next session is in flight yet.
        core.apply_control(addr)
        self._client_round[addr] = self._client_round.get(addr, -1) + 1
        txn_down, txn_up = core.new_txn_pair()
        session = core.open_session(client, self._client_round[addr],
                                    txn_down, txn_up,
                                    model_version=self._model_version)
        self._inflight[addr] = session
        if self.cfg.round_deadline_ns is not None:
            self._arm_watchdog(session)
        if self.cfg.broadcast_model:
            core.begin_downlink(session)
        else:
            core.begin_local(session)

    def _schedule_reentry(self, client: FLClient) -> None:
        if client.addr not in self.core.pool.clients:
            return
        self.core.sim.schedule(max(0, client.cadence_ns),
                               lambda: self._reenter(client))

    def _reenter(self, client: FLClient) -> None:
        addr = client.addr
        if addr not in self.core.pool.clients or addr in self._inflight:
            return
        if self._stopped or self._agg_idx >= self._target:
            self._idle.add(addr)
            return
        if not self.core.pool.is_active(addr, self._agg_idx):
            self._idle.add(addr)     # benched: re-enters after readmission
            return
        self._enter(client)

    # -- watchdog (async session deadline) ------------------------------------
    def _arm_watchdog(self, session: ClientSession) -> None:
        self._watchdogs[id(session)] = self.core.sim.schedule(
            self.cfg.round_deadline_ns, lambda: self._on_watchdog(session))

    def _cancel_watchdog(self, session: ClientSession) -> None:
        timer = self._watchdogs.pop(id(session), None)
        if timer is not None:
            timer.cancel()

    def _on_watchdog(self, session: ClientSession) -> None:
        self._watchdogs.pop(id(session), None)
        if session.state in (ARRIVED, FAILED, TIMEOUT):
            return
        if session.state == TRAINING:
            # The training timer always fires; the uplink will resolve,
            # fail, or be caught by the re-armed watchdog.
            self._arm_watchdog(session)
            return
        # Stuck DOWNLINK/UPLINK: a best-effort transport lost a whole leg
        # and will never call back.  Re-enter the client; keep the session
        # registered so a miraculous late arrival is still ingested (the
        # previous timed-out session, if any, is evicted — at most one
        # lingers per client, so the registries stay bounded).
        session.state = TIMEOUT
        addr = session.addr
        if self._inflight.get(addr) is session:
            del self._inflight[addr]
        prev = self._timed_out.get(addr)
        if prev is not None:
            self.core.drop_session(prev)
        self._timed_out[addr] = session
        self._timeouts_window += 1
        # A timeout counts against health like a transport failure:
        # without this, a permanently dead best-effort client would cycle
        # timeout -> cadence -> re-enter forever, keeping the calendar
        # alive and run_rounds() from ever draining.  A merely-slow client
        # benched this way re-enters after readmit_after_rounds
        # aggregations — bench-as-backoff.
        self.core.pool.record_failure(addr, self._agg_idx)
        self._schedule_reentry(session.client)

    # -- events from the core -------------------------------------------------
    def accept_downlink(self, session: ClientSession) -> bool:
        return session.state == DOWNLINK

    def on_uplink(self, session: Optional[ClientSession], addr: str,
                  txn: int, vec) -> None:
        # `vec` is an opaque update token (flat vector, or a pending wire
        # handle under cfg.batch_wire); it is buffered untouched and only
        # decoded when _flush() hands the batch to apply_aggregation.
        if session is None or session.state in (ARRIVED, FAILED):
            return
        was_timeout = session.state == TIMEOUT
        session.state = ARRIVED
        self._cancel_watchdog(session)
        self.core.drop_session(session)
        if self._inflight.get(addr) is session:
            del self._inflight[addr]
        if self._timed_out.get(addr) is session:
            del self._timed_out[addr]
        self.core.pool.record_success(addr)
        staleness = self._model_version - session.model_version
        self._buffer.append((session, vec, staleness))
        if (len(self._buffer) >= self.cfg.buffer_k
                and not self._stopped and self._agg_idx < self._target):
            self._flush()
        if not was_timeout:
            # A timed-out session's client already re-entered at timeout.
            self._schedule_reentry(session.client)

    def on_session_failed(self, session: ClientSession) -> None:
        if session.state in (ARRIVED, FAILED, TIMEOUT):
            return
        session.state = FAILED
        self._cancel_watchdog(session)
        self.core.drop_session(session)
        addr = session.addr
        if self._inflight.get(addr) is session:
            del self._inflight[addr]
        self._failed_window.append(addr)
        self.core.pool.record_failure(addr, self._agg_idx)
        self._schedule_reentry(session.client)

    def on_client_added(self, client: FLClient) -> None:
        # Joins mid-run enter immediately (if a run is live), else at the
        # next run_rounds() entry scan.
        if not self._stopped and client.addr not in self._inflight:
            self._enter(client)

    # -- aggregation ----------------------------------------------------------
    def _flush(self) -> None:
        core = self.core
        contribs, stales, arrived = [], [], []
        clamped = dropped = 0
        for session, vec, staleness in self._buffer:
            arrived.append(session.addr)
            if (self.cfg.max_staleness is not None
                    and staleness > self.cfg.max_staleness):
                dropped += 1
                continue
            factor, was_clamped = core.staleness_factor(staleness)
            clamped += was_clamped
            contribs.append((vec, factor * session.client.weight))
            stales.append(staleness)
        if contribs:
            core.apply_aggregation(contribs)
            self._model_version += 1

        now = core.sim.now_ns
        result = RoundResult(
            round_idx=self._agg_idx,
            duration_ns=now - self._window_start_ns,
            arrived=sorted(set(arrived)),
            failed=list(self._failed_window),
            skipped_unhealthy=core.pool.benched(self._agg_idx),
            late_folded=sum(1 for s in stales if s >= 1),
            retransmissions=core.retx_total - self._retx0,
            roster=sorted(set(arrived) | set(self._inflight)),
            staleness_clamped=clamped,
            decode_errors=core.decode_errors - self._decode0,
            bcast_cache_hits=core.bcast_cache_hits - self._bcast0,
            client_health=core.telemetry.snapshot_all(),
            metrics={
                "model_version": self._model_version,
                "buffer_size": len(self._buffer),
                "staleness_mean": (sum(stales) / len(stales)
                                   if stales else 0.0),
                "staleness_max": max(stales, default=0),
                "stale_dropped": dropped,
                "session_timeouts": self._timeouts_window,
            },
            **core.stats_delta(self._stats0),
        )
        core.emit_result(result)

        self._buffer = []
        self._failed_window = []
        self._timeouts_window = 0
        self._stats0 = core.snapshot_stats()
        self._retx0 = core.retx_total
        self._decode0 = core.decode_errors
        self._bcast0 = core.bcast_cache_hits
        self._window_start_ns = now
        self._agg_idx += 1
        if self._agg_idx >= self._target:
            self._stopped = True
            return
        # Opportunity scan: parked clients (benched at their cadence tick,
        # or stopped in a previous run) whose bench expired re-enter now.
        for addr in sorted(self._idle):
            if (addr not in self._inflight
                    and self.core.pool.is_active(addr, self._agg_idx)):
                client = self.core.pool.clients.get(addr)
                if client is not None:
                    self._enter(client)


SCHEDULERS = {"sync": SyncScheduler, "async": AsyncScheduler}


def make_scheduler(mode: str, core: ServerCore):
    try:
        cls = SCHEDULERS[mode]
    except KeyError:
        raise ValueError(f"unknown scheduling mode {mode!r}; "
                         f"one of {sorted(SCHEDULERS)}") from None
    return cls(core)
