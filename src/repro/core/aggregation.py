"""Model-parameter aggregation strategies.

The paper's Algorithm III / Eq. (1) is the sequential pairwise average
``new_i = (Client_i + Server_i) / 2`` applied per arriving client. That is
implemented faithfully (``pairwise_average``), alongside the principled
weighted FedAvg (McMahan et al., 2017) and a trimmed mean for robustness —
both of which the framework defaults to at scale.

All strategies operate on parameter pytrees. The flat-vector fast path (used
by the benchmark harness and backed by the Pallas ``fedavg`` kernel) lives in
``repro.kernels.fedavg.ops``.
"""

from __future__ import annotations

from typing import Any, Optional, Sequence

import jax
import numpy as np


def pairwise_average(server_tree: Any, client_tree: Any) -> Any:
    """Paper Eq. (1): AggregatedParameters = (Client + Server) / 2.

    Order-dependent when folded over multiple clients — exactly as the paper
    applies it (per-transaction, as each client's packets complete).
    """
    return jax.tree_util.tree_map(
        lambda s, c: (np.asarray(s, dtype=np.float32)
                      + np.asarray(c, dtype=np.float32)) / 2.0,
        server_tree, client_tree)


# Lazy probe for the Pallas fedavg kernel: None = not probed yet,
# False = unavailable (no jax / no pallas), else the ops module.
_KERNEL_OPS: Any = None

FEDAVG_BACKENDS = ("numpy", "kernel", "auto")


def _kernel_ops():
    global _KERNEL_OPS
    if _KERNEL_OPS is None:
        try:
            from repro.kernels.fedavg import ops as kernel_ops
            _KERNEL_OPS = kernel_ops
        except Exception:  # noqa: BLE001 - any import failure means "no kernel"
            _KERNEL_OPS = False
    return _KERNEL_OPS or None


def fedavg(trees: Sequence[Any], weights: Optional[Sequence[float]] = None,
           backend: str = "numpy") -> Any:
    """Weighted FedAvg. Weights default to uniform; normally |D_k|/|D|.

    ``backend`` selects the implementation:

    * ``"numpy"`` (default) — the per-leaf float32 accumulation below.
      Digest-stable: every replay test pins this path bit-for-bit.
    * ``"kernel"`` — the fused Pallas kernel
      (``repro.kernels.fedavg.ops.fedavg_trees``); raises if jax/pallas is
      not importable.
    * ``"auto"`` — the kernel when jax is importable, numpy otherwise.

    The two backends mirror each other to ~1 ULP
    (``tests/test_kernel_parity.py`` enforces the docstring claim) but are
    **not** bit-identical — the kernel reduces over clients in one fused
    pass while numpy accumulates sequentially — which is why the
    orchestrator defaults to numpy: replay digests must not depend on
    whether jax imports.
    """
    if not trees:
        raise ValueError("fedavg of zero clients")
    if backend not in FEDAVG_BACKENDS:
        raise ValueError(f"unknown fedavg backend {backend!r}; "
                         f"one of {FEDAVG_BACKENDS}")
    if backend != "numpy":
        ops = _kernel_ops()
        if ops is not None:
            ws = [1.0] * len(trees) if weights is None else list(weights)
            return ops.fedavg_trees(trees, ws)
        if backend == "kernel":
            raise RuntimeError("fedavg backend='kernel' requested but the "
                               "Pallas kernel is not importable (no jax?)")
    if weights is None:
        weights = [1.0] * len(trees)
    w = np.asarray(weights, dtype=np.float32)
    w = w / w.sum()

    def _avg(*leaves):
        acc = np.zeros_like(np.asarray(leaves[0], dtype=np.float32))
        for wi, leaf in zip(w, leaves):
            acc += wi * np.asarray(leaf, dtype=np.float32)
        return acc

    return jax.tree_util.tree_map(_avg, *trees)


def fedavg_stack(stack: np.ndarray,
                 weights: Optional[Sequence[float]] = None,
                 backend: str = "numpy") -> np.ndarray:
    """Weighted FedAvg over a flat update stack ``(K, P) -> (P,)``.

    The batched twin of :func:`fedavg`, used by the orchestrator now that
    contributions arrive as flat wire vectors: one accumulation over the
    stack and a single unflatten replaces K per-leaf tree folds.  The
    ``"numpy"`` path accumulates ``acc += w_i * row_i`` in the same order
    and dtype as the tree path — elementwise ops on a concatenation equal
    the ops on its slices, so it is **bit-identical** to per-leaf
    accumulation and digest-safe.  ``"kernel"``/``"auto"`` route to the
    fused Pallas kernel (``fedavg_flat``), ~1 ULP off and therefore never
    the default (``tests/test_kernel_parity.py`` pins both claims).
    """
    stack = np.asarray(stack, dtype=np.float32)
    if stack.ndim != 2 or stack.shape[0] == 0:
        raise ValueError(f"fedavg_stack needs a non-empty (K, P) stack, "
                         f"got shape {stack.shape}")
    if backend not in FEDAVG_BACKENDS:
        raise ValueError(f"unknown fedavg backend {backend!r}; "
                         f"one of {FEDAVG_BACKENDS}")
    if backend != "numpy":
        ops = _kernel_ops()
        if ops is not None:
            ws = ([1.0] * stack.shape[0] if weights is None
                  else [float(w) for w in weights])
            return np.asarray(ops.fedavg_flat(stack, ws), dtype=np.float32)
        if backend == "kernel":
            raise RuntimeError("fedavg backend='kernel' requested but the "
                               "Pallas kernel is not importable (no jax?)")
    if weights is None:
        weights = [1.0] * stack.shape[0]
    w = np.asarray(weights, dtype=np.float32)
    w = w / w.sum()
    acc = np.zeros(stack.shape[1], dtype=np.float32)
    for wi, row in zip(w, stack):
        acc += wi * row
    return acc


def trimmed_mean(trees: Sequence[Any], trim_fraction: float = 0.1) -> Any:
    """Coordinate-wise trimmed mean — robust to Byzantine/outlier clients."""
    k = int(len(trees) * trim_fraction)

    def _tm(*leaves):
        stack = np.stack([np.asarray(l, dtype=np.float32) for l in leaves])
        stack.sort(axis=0)
        sl = stack[k:len(trees) - k] if len(trees) - 2 * k > 0 else stack
        return sl.mean(axis=0)

    return jax.tree_util.tree_map(_tm, *trees)


def apply_delta(global_tree: Any, delta_tree: Any, server_lr: float = 1.0
                ) -> Any:
    """global + lr * delta (delta-transmission mode)."""
    return jax.tree_util.tree_map(
        lambda g, d: np.asarray(g, dtype=np.float32)
        + server_lr * np.asarray(d, dtype=np.float32),
        global_tree, delta_tree)


def tree_sub(a: Any, b: Any) -> Any:
    return jax.tree_util.tree_map(
        lambda x, y: np.asarray(x, dtype=np.float32)
        - np.asarray(y, dtype=np.float32), a, b)


AGGREGATORS = {
    "pairwise": "sequential pairwise average (paper Eq. 1)",
    "fedavg": "weighted federated averaging (McMahan et al.)",
    "trimmed_mean": "coordinate-wise trimmed mean (robust)",
}
