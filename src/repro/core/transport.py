"""Pluggable transport API: one delivery contract for every protocol.

The paper's deliverable is a *comparison between transports* ("a comparison
between the traditional UDP protocol and the Modified UDP protocol will be
simulated").  Comparing N protocols under one FL harness requires the
orchestrator to be protocol-agnostic, so this module defines the contract
every transport implements and a string-keyed registry (the ``make_codec``
idiom) the orchestrator dispatches through:

* :class:`Delivery` — the single receiver-side callback payload.  Reliable
  transports deliver ``complete=True`` with every packet present; best-effort
  transports deliver whatever arrived with ``complete=False`` and the FL layer
  decides what to do with the gaps (:meth:`Delivery.reassemble` zero-fills).
* :class:`TransportCaps` — static capability flags so callers can branch on
  *what a transport guarantees* instead of on its name.
* :class:`Transport` — the abstract factory: ``create_sender`` /
  ``create_receiver`` over the discrete-event simulator.
* :func:`register_transport` / :func:`make_transport` /
  :func:`available_transports` — the registry.  Third-party transports
  register themselves and every benchmark/test that iterates
  ``available_transports()`` picks them up for free.

Sender contract: the object returned by ``create_sender`` exposes
``start()`` and ``stats`` (a :class:`repro.core.mudp.TxnStats`); it calls
``on_complete(sender)`` on success and, if ``caps.supports_fail_cb``,
``on_fail(sender)`` after exhausting its retry budget.

Receiver contract: the object returned by ``create_receiver`` is persistent
(serves many senders/transactions) and invokes ``on_deliver(delivery)``
exactly once per transaction.
"""

from __future__ import annotations

import abc
import dataclasses
from typing import Callable, Optional

import numpy as np

from repro.core.mudp import MudpReceiver, MudpSender
from repro.core.packets import Packet
from repro.core.packetizer import DEFAULT_MTU, reassemble
from repro.core.simulator import Node, Simulator
from repro.core.tcp import TcpReceiver, TcpSender
from repro.core.udp import UdpReceiver, UdpSender, reassemble_partial
from repro.core.wire import WireError, parse_pipeline


# --------------------------------------------------------------------------
# The delivery contract
# --------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class Delivery:
    """What a receiver hands the application, for every transport.

    ``packets`` maps sequence number -> verified :class:`Packet`;
    ``total`` is the transaction's packet count ``Np`` (known even when some
    packets never arrived); ``complete`` is True iff all ``total`` packets are
    present — the unified form of the old reliable-full (3-arg) vs
    best-effort-partial (4-arg) callback shapes.
    """

    sender_addr: str
    txn: int
    packets: dict[int, Packet]
    total: int
    complete: bool

    def reassemble(self) -> bytes:
        """Byte stream for this delivery: exact when complete, zero-filled
        gaps otherwise (the UDP-baseline corruption the paper measures)."""
        if self.complete:
            return reassemble(self.packets)
        return reassemble_partial(self.packets, self.total)


@dataclasses.dataclass(frozen=True)
class TransportCaps:
    """Static guarantees a transport makes; callers branch on these, never on
    the transport's name."""

    reliable: bool = True            # delivers exactly the sent bytes or fails
    partial_delivery: bool = False   # may deliver with complete=False
    has_handshake: bool = False      # pays a connection setup round-trip
    supports_fail_cb: bool = True    # invokes on_fail after retry exhaustion
    # Multiple transactions may be in flight between one (src, dst) pair at
    # once: sender/receiver state is keyed by (addr, txn), never by address
    # alone.  Async (overlapping-round) scheduling requires this.  Opt-in
    # (default False) so a transport written before the flag existed is
    # refused by the async scheduler instead of silently corrupting
    # per-address state under overlapping sessions.
    concurrent_txns: bool = False


DeliverFn = Callable[[Delivery], None]


@dataclasses.dataclass
class TransportConfig:
    """Wire-level knobs shared by all transports (each reads what it needs).

    ``kind`` is validated against the registry at construction time, so a
    typo'd transport name fails at ``FLConfig(...)`` with the list of
    registered transports instead of deep inside receiver setup.  The same
    goes for the per-direction wire-pipeline specs.

    Wire plane: ``codec`` (+ ``codec_kwargs``) is the legacy single-stage
    form — headerless, byte-identical to the historical formats.
    ``uplink`` / ``downlink`` are composed pipeline specs
    (``repro.core.wire``, e.g. ``"delta|ef|topk(0.01)|int8(1024)"``); when
    set, that direction ships **self-describing** payloads (a versioned
    WireHeader the receiver decodes from, no out-of-band config) and
    ``codec`` is ignored for it.  Each direction is independent — an
    unset one falls back to the legacy codec.
    """

    kind: str = "mudp"                  # any name in available_transports()
    codec: str = "raw"                  # raw | hex | int8 | topk
    codec_kwargs: dict = dataclasses.field(default_factory=dict)
    uplink: Optional[str] = None        # pipeline spec, client -> server
    downlink: Optional[str] = None      # pipeline spec, server -> client
    mtu: int = DEFAULT_MTU
    timeout_ns: int = 6_000_000_000     # sender/NACK timer (paper's timer)
    max_retries: int = 3                # the paper's Y
    udp_deadline_ns: int = 30_000_000_000
    fec_block: int = 8                  # mudp+fec: data packets per FEC block
    fec_parity: int = 1                 # mudp+fec: parity per block (0 = no
                                        # trailer; degrades to plain mudp)

    def __post_init__(self) -> None:
        validate_transport_kind(self.kind)
        if self.fec_block < 1:
            raise ValueError(f"fec_block must be >= 1, got {self.fec_block}")
        if self.fec_parity < 0:
            raise ValueError(
                f"fec_parity must be >= 0, got {self.fec_parity}")
        for direction, spec in (("uplink", self.uplink),
                                ("downlink", self.downlink)):
            if spec is None:
                continue
            try:
                pipeline = parse_pipeline(spec)
            except WireError as e:
                raise ValueError(
                    f"bad {direction} pipeline spec {spec!r}: {e}") from e
            if direction == "downlink" and pipeline.caps.delta_domain:
                raise ValueError(
                    "downlink pipeline cannot contain 'delta': the client "
                    "needs the full model, not a server-side difference")
            # Dry-run probe: a spec can parse yet be incoherent between
            # stages (e.g. "int8(1024)|raw" — raw upcasts the int8 body,
            # so every decode fails; "hex|int8" feeds int8 non-floats).
            # Catching that here costs one 8-element round-trip instead of
            # a run that silently zero-degrades every payload.
            probe = np.linspace(-1.0, 1.0, 8, dtype=np.float32)
            state = pipeline.new_state()
            pipeline.set_reference(state, np.zeros(8, dtype=np.float32))
            try:
                pipeline.decode(pipeline.encode(probe, state),
                                pipeline.new_state())
            except WireError as e:
                raise ValueError(
                    f"{direction} pipeline {spec!r} cannot round-trip a "
                    f"payload (incoherent stage order?): {e}") from e


# --------------------------------------------------------------------------
# The transport interface
# --------------------------------------------------------------------------
class Transport(abc.ABC):
    """Factory for one protocol's sender/receiver state machines."""

    name: str = "abstract"
    caps: TransportCaps = TransportCaps()

    @abc.abstractmethod
    def create_sender(self, sim: Simulator, src: Node, dst: Node,
                      packets: list[Packet], cfg: TransportConfig, *,
                      on_complete: Optional[Callable] = None,
                      on_fail: Optional[Callable] = None):
        """One transaction: ship ``packets`` from ``src`` to ``dst``.
        Returns an un-started sender; the caller invokes ``.start()``."""

    @abc.abstractmethod
    def create_receiver(self, sim: Simulator, node: Node,
                        cfg: TransportConfig, on_deliver: DeliverFn):
        """Persistent receiver on ``node``; fires ``on_deliver(Delivery)``
        exactly once per completed transaction."""


# --------------------------------------------------------------------------
# Registry (the make_codec idiom, with explicit registration)
# --------------------------------------------------------------------------
# The three built-ins register at the bottom of this module; mudp+fec
# registers when repro.core.fec is imported, which the repro.core package
# __init__ does eagerly (it cannot be imported here: fec imports this module
# for the public API).
_REGISTRY: dict[str, Callable[[], Transport]] = {}


def register_transport(name: str, factory: Callable[[], Transport], *,
                       overwrite: bool = False) -> None:
    """Register ``factory`` (usually a Transport subclass) under ``name``.

    Re-registering an existing name raises unless ``overwrite=True`` — a
    silent shadowing of "mudp" would invalidate every benchmark comparison.
    """
    if not overwrite and name in _REGISTRY:
        raise ValueError(f"transport {name!r} is already registered "
                         f"(pass overwrite=True to replace it)")
    _REGISTRY[name] = factory


def make_transport(name: str) -> Transport:
    try:
        factory = _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown transport {name!r}; registered transports: "
            f"{available_transports()}") from None
    return factory()


def available_transports() -> list[str]:
    return sorted(_REGISTRY)


def validate_transport_kind(kind: str) -> None:
    """Raise ValueError (naming the registered transports) for unknown kinds."""
    if kind not in _REGISTRY:
        raise ValueError(
            f"unknown transport kind {kind!r}; registered transports: "
            f"{available_transports()}")


# --------------------------------------------------------------------------
# Built-in transports: thin adapters over the existing state machines
# --------------------------------------------------------------------------
def adapt_full_delivery(on_deliver: DeliverFn):
    """Adapt the reliable 3-arg callback (addr, txn, packets) -> Delivery."""
    def _cb(sender_addr: str, txn: int, packets: dict[int, Packet]) -> None:
        total = next(iter(packets.values())).total if packets else 0
        on_deliver(Delivery(sender_addr, txn, packets, total, complete=True))
    return _cb


def adapt_partial_delivery(on_deliver: DeliverFn):
    """Adapt the best-effort 4-arg callback (addr, txn, packets, total)."""
    def _cb(sender_addr: str, txn: int, packets: dict[int, Packet],
            total: int) -> None:
        complete = len(packets) == total
        on_deliver(Delivery(sender_addr, txn, packets, total, complete))
    return _cb


class MudpTransport(Transport):
    """The paper's Modified UDP: NACK-driven selective repeat (§IV.B)."""

    name = "mudp"
    caps = TransportCaps(reliable=True, partial_delivery=False,
                         has_handshake=False, supports_fail_cb=True,
                         concurrent_txns=True)

    def create_sender(self, sim, src, dst, packets, cfg, *,
                      on_complete=None, on_fail=None):
        return MudpSender(sim, src, dst, packets,
                          timeout_ns=cfg.timeout_ns,
                          max_retries=cfg.max_retries,
                          on_complete=on_complete, on_fail=on_fail)

    def create_receiver(self, sim, node, cfg, on_deliver):
        return MudpReceiver(sim, node, nack_timeout_ns=cfg.timeout_ns,
                            max_nack_retries=cfg.max_retries,
                            on_deliver=adapt_full_delivery(on_deliver))


class UdpTransport(Transport):
    """Plain UDP baseline: fire-and-forget, delivers whatever arrived."""

    name = "udp"
    caps = TransportCaps(reliable=False, partial_delivery=True,
                         has_handshake=False, supports_fail_cb=False,
                         concurrent_txns=True)

    def create_sender(self, sim, src, dst, packets, cfg, *,
                      on_complete=None, on_fail=None):
        # No retry budget to exhaust -> on_fail can never fire (see caps).
        return UdpSender(sim, src, dst, packets, on_complete=on_complete)

    def create_receiver(self, sim, node, cfg, on_deliver):
        return UdpReceiver(sim, node, deadline_ns=cfg.udp_deadline_ns,
                           on_deliver=adapt_partial_delivery(on_deliver))


class TcpTransport(Transport):
    """Reno-lite TCP baseline: handshake + cumulative ACKs + windowing."""

    name = "tcp"
    caps = TransportCaps(reliable=True, partial_delivery=False,
                         has_handshake=True, supports_fail_cb=True,
                         concurrent_txns=True)

    def create_sender(self, sim, src, dst, packets, cfg, *,
                      on_complete=None, on_fail=None):
        return TcpSender(sim, src, dst, packets, rto_ns=cfg.timeout_ns,
                         on_complete=on_complete, on_fail=on_fail)

    def create_receiver(self, sim, node, cfg, on_deliver):
        return TcpReceiver(sim, node,
                           on_deliver=adapt_full_delivery(on_deliver))


register_transport("mudp", MudpTransport)
register_transport("udp", UdpTransport)
register_transport("tcp", TcpTransport)
