"""Core paper contribution: Modified UDP transport + FL orchestration.

Transports are pluggable: every protocol implements the ``Transport``
interface (``repro.core.transport``) and registers under a string key, the
FL orchestrator (``repro.core.rounds``) dispatches purely through the
registry, and receivers hand the application one unified ``Delivery``
record whatever the protocol.  Built-ins: ``mudp`` (the paper's protocol),
``udp``/``tcp`` baselines, and ``mudp+fec`` (MUDP + XOR parity, the paper's
future-work optimization).  See ``docs/TRANSPORTS.md`` for the contract and
a write-your-own walkthrough.
"""

from repro.core.aggregation import (fedavg, fedavg_stack, pairwise_average,
                                    trimmed_mean)
from repro.core.client_compute import (BatchTrainer, ClientModel,
                                       ConsensusModel, TrainBackend,
                                       attach_trainer, available_models,
                                       available_train_backends, make_model,
                                       make_train_backend, register_model,
                                       register_train_backend)
from repro.core.channel import (BernoulliLoss, DropList, GilbertElliott, Link,
                                LossModel, NoLoss, keyed_uniform,
                                keyed_uniforms, packet_key_arrays,
                                DCN_LINK, PAPER_LINK, WAN_LINK)
from repro.core.compression import (Codec, HexCodec, Int8Codec, RawCodec,
                                    TopKCodec, make_codec)
from repro.core.control import (AdaptivePolicy, ControlDecision,
                                ControlPolicy, StaticPolicy,
                                available_policies, make_policy,
                                register_policy)
from repro.core.fec import (FecMudpReceiver, FecMudpSender, FecMudpTransport,
                            parity_groups)
from repro.core.fleet import (COHORT_PRESETS, ClientProfile, CohortSpec,
                              ConsensusObjective, FleetBuild, FleetConfig,
                              build_fleet, build_fleet_training,
                              cohort_counts, links_for, profiles_digest,
                              sample_profiles)
from repro.core.mudp import MudpReceiver, MudpSender, TxnStats
from repro.core.packetizer import (Packetizer, flatten_to_vector, packetize,
                                   reassemble, unflatten_from_vector)
from repro.core.packets import (Packet, PacketKind, make_ack_ok,
                                make_data_packet, make_nack)
from repro.core.rounds import (FederatedSystem, FLClient, FLConfig,
                               RoundResult)
from repro.core.scheduling import (SCHEDULERS, AsyncScheduler, SyncScheduler,
                                   make_scheduler)
from repro.core.server import ClientPool, ClientSession, ServerCore
from repro.core.simulator import Node, Simulator
from repro.core.tcp import TcpReceiver, TcpSender
from repro.core.telemetry import ClientHealth, Telemetry
from repro.core.topology import (CellScheduler, EdgeAggregator,
                                 GossipSystem, GossipTopology, HierSystem,
                                 HierTopology, StarTopology, Topology,
                                 available_topologies, make_topology,
                                 neighbor_graph, register_topology,
                                 topology_hops)
from repro.core.transport import (Delivery, Transport, TransportCaps,
                                  TransportConfig, available_transports,
                                  make_transport, register_transport,
                                  validate_transport_kind)
from repro.core.udp import UdpReceiver, UdpSender, reassemble_partial
from repro.core.wire import (CodecStage, CrcStage, DeltaStage,
                             ErrorFeedbackStage, HexStage, Int8Stage,
                             Pipeline, PipelineCaps, PipelineState, RawStage,
                             Stage, TopKStage, WireDecodeError, WireError,
                             WireHeader, available_stages, chunksum32,
                             decode_payload, legacy_pipeline, migrate_state,
                             parse_hop_specs, parse_pipeline, parse_stage,
                             register_stage, stage_for_codec)

__all__ = [
    "fedavg", "fedavg_stack", "pairwise_average", "trimmed_mean",
    "BatchTrainer", "ClientModel", "ConsensusModel", "TrainBackend",
    "attach_trainer", "available_models", "available_train_backends",
    "make_model", "make_train_backend", "register_model",
    "register_train_backend",
    "BernoulliLoss", "DropList", "GilbertElliott", "Link", "LossModel",
    "NoLoss", "keyed_uniform", "keyed_uniforms", "packet_key_arrays",
    "DCN_LINK", "PAPER_LINK", "WAN_LINK",
    "Codec", "HexCodec", "Int8Codec", "RawCodec", "TopKCodec", "make_codec",
    "AdaptivePolicy", "ControlDecision", "ControlPolicy", "StaticPolicy",
    "available_policies", "make_policy", "register_policy",
    "FecMudpReceiver", "FecMudpSender", "FecMudpTransport", "parity_groups",
    "COHORT_PRESETS", "ClientProfile", "CohortSpec", "ConsensusObjective",
    "FleetBuild", "FleetConfig", "build_fleet", "build_fleet_training",
    "cohort_counts", "links_for", "profiles_digest", "sample_profiles",
    "MudpReceiver", "MudpSender", "TxnStats",
    "Packetizer", "flatten_to_vector", "packetize", "reassemble",
    "unflatten_from_vector",
    "Packet", "PacketKind", "make_ack_ok", "make_data_packet", "make_nack",
    "FederatedSystem", "FLClient", "FLConfig", "RoundResult",
    "SCHEDULERS", "AsyncScheduler", "SyncScheduler", "make_scheduler",
    "ClientPool", "ClientSession", "ServerCore",
    "Node", "Simulator",
    "TcpReceiver", "TcpSender",
    "ClientHealth", "Telemetry",
    "CellScheduler", "EdgeAggregator", "GossipSystem", "GossipTopology",
    "HierSystem", "HierTopology", "StarTopology", "Topology",
    "available_topologies", "make_topology", "neighbor_graph",
    "register_topology", "topology_hops",
    "Delivery", "Transport", "TransportCaps", "TransportConfig",
    "available_transports", "make_transport", "register_transport",
    "validate_transport_kind",
    "UdpReceiver", "UdpSender", "reassemble_partial",
    "CodecStage", "CrcStage", "DeltaStage", "ErrorFeedbackStage", "HexStage",
    "Int8Stage", "Pipeline", "PipelineCaps", "PipelineState", "RawStage",
    "Stage", "TopKStage", "WireDecodeError", "WireError", "WireHeader",
    "available_stages", "chunksum32", "decode_payload", "legacy_pipeline",
    "migrate_state", "parse_hop_specs", "parse_pipeline", "parse_stage",
    "register_stage", "stage_for_codec",
]
