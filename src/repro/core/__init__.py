"""Core paper contribution: Modified UDP transport + FL orchestration."""

from repro.core.aggregation import fedavg, pairwise_average, trimmed_mean
from repro.core.channel import (BernoulliLoss, DropList, GilbertElliott, Link,
                                NoLoss, DCN_LINK, PAPER_LINK, WAN_LINK)
from repro.core.compression import (Codec, HexCodec, Int8Codec, RawCodec,
                                    TopKCodec, make_codec)
from repro.core.mudp import MudpReceiver, MudpSender, TxnStats
from repro.core.packetizer import (Packetizer, flatten_to_vector, packetize,
                                   reassemble, unflatten_from_vector)
from repro.core.packets import (Packet, PacketKind, make_ack_ok,
                                make_data_packet, make_nack)
from repro.core.rounds import (FederatedSystem, FLClient, FLConfig,
                               RoundResult, TransportConfig)
from repro.core.simulator import Node, Simulator
from repro.core.tcp import TcpReceiver, TcpSender
from repro.core.udp import UdpReceiver, UdpSender, reassemble_partial

__all__ = [
    "fedavg", "pairwise_average", "trimmed_mean",
    "BernoulliLoss", "DropList", "GilbertElliott", "Link", "NoLoss",
    "DCN_LINK", "PAPER_LINK", "WAN_LINK",
    "Codec", "HexCodec", "Int8Codec", "RawCodec", "TopKCodec", "make_codec",
    "MudpReceiver", "MudpSender", "TxnStats",
    "Packetizer", "flatten_to_vector", "packetize", "reassemble",
    "unflatten_from_vector",
    "Packet", "PacketKind", "make_ack_ok", "make_data_packet", "make_nack",
    "FederatedSystem", "FLClient", "FLConfig", "RoundResult",
    "TransportConfig",
    "Node", "Simulator",
    "TcpReceiver", "TcpSender",
    "UdpReceiver", "UdpSender", "reassemble_partial",
]
