"""Deterministic discrete-event network simulator — the NS3 stand-in.

Implements exactly what the paper uses NS3 for: a star topology of N client
nodes around one server node, point-to-point links with a data rate, a
propagation delay and a loss model, an event calendar in integer nanoseconds,
and cancellable timers (NS3 ``Simulator::Schedule``/``Cancel``).

Everything is single-threaded and seeded — a simulation replays bit-for-bit,
which the tests and benchmarks rely on.

Three engines drive the innermost loop (``Simulator(engine=...)``):

* ``"per_packet"`` (default) — the reference path: one calendar event plus
  one closure per transmitted packet, exactly the seed implementation.
* ``"batched"`` — the flight engine: a burst of packets sent over one link
  (``Node.send_burst``) is planned with vectorized numpy array ops — FIFO
  serialization starts, propagation, per-packet jitter and loss draws in
  one shot — and enters the calendar as a single *flight* instead of one
  event+closure per packet.  Runs of consecutive payload packets are then
  ingested through the receivers' bulk hooks (see :meth:`Node.register`)
  without touching the heap at all.
* ``"flow"`` — the analytic engine (``repro.core.flow``): each transport
  transaction is modeled in closed form — one Binomial loss draw per
  burst, FIFO-cumsum completion times with expected jitter, recovery as
  an expected-value recursion — and schedules a handful of events total.
  Not bit-exact, but statistically equivalent and deterministic per seed.

The first two engines are bit-for-bit identical: same keyed RNG draws (see
``repro.core.channel``), same tie-breaking (flights carry the tie numbers
per-packet scheduling would have assigned), same stats, same final clock.
``tests/test_engine_equivalence.py`` pins this down for every registered
transport; ``benchmarks/simcore.py`` measures the speedup.  The flow
engine's statistical-equivalence contract is pinned by the seed-sweep
harness in ``tests/statcheck.py`` + ``tests/test_flow_engine.py``.
"""

from __future__ import annotations

import dataclasses
import hashlib
import heapq
from bisect import bisect_left
from typing import Callable, Optional, Sequence

import numpy as np

from repro.core.channel import Link, packet_key_arrays
from repro.core.packets import Packet, PacketKind

ENGINES = ("per_packet", "batched", "flow")
# The packet-level engines are bit-for-bit interchangeable; "flow" is
# statistically equivalent only (gated by tests/test_flow_engine.py), so
# digest-pinned tests iterate PACKET_ENGINES, not ENGINES.
PACKET_ENGINES = ("per_packet", "batched")

# Bursts below this size go through the scalar path even under the batched
# engine: the fixed numpy planning cost only pays for itself on real bursts.
# Either path produces identical results, so this is purely a latency knob.
_MIN_BATCH = 4

# Flat per-kind stat keys, precomputed so the hot loops do one dict lookup.
_SENT_KEY = {k: f"sent_{k.name.lower()}" for k in PacketKind}
_DELIVERED_KEY = {k: f"delivered_{k.name.lower()}" for k in PacketKind}
_DROPPED_KEY = {k: f"dropped_{k.name.lower()}" for k in PacketKind}


def _budget_error() -> RuntimeError:
    return RuntimeError("simulator event budget exceeded (livelock in a "
                        "transport state machine?)")


class _Flight:
    """One planned burst over one link: packets already sequenced by
    (arrival, tie), delivered lazily by the run loop.

    ``bytes_csum`` is the prefix sum of packet sizes in delivery order (so
    a bulk-ingested run updates byte counters in O(1)); ``safe_until`` is
    the index of the first *statically effectful* packet (non-DATA, or the
    transaction's last packet) at or after ``idx``; ``key`` is the burst's
    ``(sender addr, txn)`` when homogeneous (None otherwise), which scopes
    how far *other* flights may be ingested past this one's effectful
    packets; ``seated_tie`` is the tie of the flight's one valid calendar
    seat (stale seats are skipped on pop); ``bulk_dead`` / ``refused_idx``
    record that the receiver's bulk hook permanently / currently declined
    the flight's due packet.
    """

    __slots__ = ("packets", "arrivals", "ties", "bytes_csum", "safe_until",
                 "key", "dst", "idx", "seated_tie", "bulk_dead",
                 "refused_idx")

    def __init__(self, packets: list, arrivals: list, ties: list,
                 bytes_csum: list, safe_until: int, key, dst: "Node"):
        self.packets = packets
        self.arrivals = arrivals
        self.ties = ties
        self.bytes_csum = bytes_csum
        self.safe_until = safe_until
        self.key = key
        self.dst = dst
        self.idx = 0
        self.seated_tie = ties[0]
        self.bulk_dead = False
        self.refused_idx = -1


@dataclasses.dataclass(order=True)
class _Event:
    time_ns: int
    tie: int
    fn: Callable[[], None] = dataclasses.field(compare=False)
    cancelled: bool = dataclasses.field(compare=False, default=False)


class Timer:
    """Handle for a scheduled event; ``cancel()`` is idempotent."""

    __slots__ = ("_event",)

    def __init__(self, event: _Event):
        self._event = event

    def cancel(self) -> None:
        self._event.cancelled = True

    @property
    def active(self) -> bool:
        return not self._event.cancelled


class Node:
    """A network endpoint with an IPv4-style address.

    Transports attach themselves via ``register`` to receive packets; the
    node dispatches on (txn) so multiple concurrent transactions coexist
    (N clients talking to one server).
    """

    def __init__(self, sim: "Simulator", addr: str):
        self.sim = sim
        self.addr = addr
        self._handlers: list[Callable[[Packet], bool]] = []
        self._bulk: dict[Callable, Callable] = {}
        # (txn, peer_addr) -> handlers: O(1) dispatch for transaction-bound
        # state machines (senders), tried after the broadcast handlers — a
        # server node with hundreds of concurrent senders must not scan
        # them all for every ACK/NACK.
        self._keyed: dict[tuple[int, str], list[Callable]] = {}
        # Immutable snapshot iterated by deliver(): rebuilding it on every
        # (un)register keeps the per-packet hot path allocation-free while
        # preserving copy-on-dispatch semantics under mid-dispatch mutation.
        self._dispatch: tuple[Callable[[Packet], bool], ...] = ()
        # Bulk hook of the FIRST registered handler (receivers register
        # before senders), used by the batched engine to ingest a run of
        # consecutive DATA packets in one call. None -> per-packet dispatch.
        self._bulk0: Optional[Callable] = None

    def _rebuild(self) -> None:
        self._dispatch = tuple(self._handlers)
        self._bulk0 = (self._bulk.get(self._handlers[0])
                       if self._handlers else None)

    def register(self, handler: Callable[[Packet], bool], *,
                 bulk: Optional[Callable] = None) -> None:
        """Handler returns True if it consumed the packet.

        ``bulk``, if given, is the handler's burst-ingestion fast path:
        ``bulk(pkts, i, j, arrivals) -> consumed`` may consume a prefix of
        ``pkts[i:j]`` (consecutive packets of one flight, arrival times in
        ``arrivals``) and must behave exactly like that many per-packet
        calls.  The contract that makes deep ingestion sound:

        * only DATA packets are consumed, and their processing is a pure
          per-transaction verify-and-store — no sends, no scheduling, no
          tie consumption, no reads of global state (``sim.stats`` etc.);
        * return ``0`` to decline the due packet this time (it is
          delivered per-packet, after which the hook is consulted again);
        * return ``-1`` to decline the flight *permanently* (e.g. the
          transaction's gap machinery is armed) — the remainder of the
          flight is delivered per-packet.

        Only the first registered handler's bulk hook is ever used.
        """
        self._handlers.append(handler)
        if bulk is not None:
            self._bulk[handler] = bulk
        self._rebuild()

    def unregister(self, handler: Callable[[Packet], bool]) -> None:
        if handler in self._handlers:
            self._handlers.remove(handler)
            self._bulk.pop(handler, None)
            self._rebuild()

    def register_keyed(self, key: tuple[int, str],
                       handler: Callable[[Packet], bool]) -> None:
        """Register a handler that only wants packets whose
        ``(txn, sender addr)`` equals ``key`` — dispatched by dict lookup
        instead of the broadcast scan."""
        self._keyed.setdefault(key, []).append(handler)

    def unregister_keyed(self, key: tuple[int, str],
                         handler: Callable[[Packet], bool]) -> None:
        hs = self._keyed.get(key)
        if hs and handler in hs:
            hs.remove(handler)
            if not hs:
                del self._keyed[key]

    def deliver(self, pkt: Packet) -> None:
        for h in self._dispatch:
            if h(pkt):
                return
        hs = self._keyed.get((pkt.txn, pkt.addr))
        if hs is not None:
            for h in tuple(hs):
                if h(pkt):
                    return
        if self.sim.trace:
            self.sim.log(f"{self.addr}: unhandled packet {pkt}")

    def send(self, pkt: Packet, dest: "Node") -> None:
        self.sim.transmit(self, dest, pkt)

    def send_burst(self, pkts: Sequence[Packet], dest: "Node") -> None:
        """Send ``pkts`` back-to-back to ``dest`` (one FIFO link occupancy
        per packet, exactly like consecutive :meth:`send` calls).  Under
        the batched engine this becomes one vectorized flight; otherwise it
        falls back to per-packet sends."""
        self.sim.transmit_burst(self, dest, pkts)


class Simulator:
    """Event calendar + topology. Times are integer nanoseconds."""

    def __init__(self, *, trace: bool = False, engine: str = "per_packet"):
        if engine not in ENGINES:
            raise ValueError(f"unknown engine {engine!r}; one of {ENGINES}")
        self.engine = engine
        self.now_ns: int = 0
        self._queue: list[_Event] = []
        # Batched engine: flights live in their own tuple heap (C-speed
        # comparisons) plus a registry for the deep-ingestion pass.
        self._flightq: list[tuple[int, int, _Flight]] = []
        self._active_flights: list[_Flight] = []
        self._tie_n = 0
        self._nodes: dict[str, Node] = {}
        self._links: dict[tuple[str, str], Link] = {}
        self.trace = trace
        self.trace_lines: list[str] = []
        self.events_processed: int = 0
        # Latest arrival bulk-ingested by a flight pass; folded into now_ns
        # when the calendar drains so both engines end at the same time.
        self._flight_horizon_ns: int = 0
        # Counters for benchmarks.  Per-kind counters (``sent_data``,
        # ``dropped_nack``, ``delivered_parity``, ...) appear lazily as
        # traffic of that kind occurs; the DATA triple is pre-seeded since
        # every consumer reads it.
        self.stats = {
            "packets_sent": 0, "packets_dropped": 0, "packets_delivered": 0,
            "bytes_sent": 0, "bytes_delivered": 0,
            "sent_data": 0, "dropped_data": 0, "delivered_data": 0,
        }
        # Per-hop accounting (repro.core.topology): directed (src, dst)
        # address pairs labeled via label_hop() accumulate sent bytes and
        # packets under their hop name.  Kept out of ``stats`` so the
        # replay digests of unlabeled simulations are untouched.
        self.hop_bytes: dict[str, int] = {}
        self.hop_packets: dict[str, int] = {}
        self._hop_of: dict[tuple[str, str], str] = {}

    # -- topology ----------------------------------------------------------
    def label_hop(self, src_addr: str, dst_addr: str, hop: str) -> None:
        """Tag the directed link ``src -> dst`` as belonging to ``hop``
        (e.g. ``"client->edge"``); all traffic sent over it accumulates in
        ``hop_bytes[hop]`` / ``hop_packets[hop]``.  Counted at send time,
        like ``stats["bytes_sent"]``, so dropped packets are included."""
        self._hop_of[(src_addr, dst_addr)] = hop
        self.hop_bytes.setdefault(hop, 0)
        self.hop_packets.setdefault(hop, 0)

    def node(self, addr: str) -> Node:
        if addr not in self._nodes:
            self._nodes[addr] = Node(self, addr)
        return self._nodes[addr]

    def connect(self, a: str, b: str, link_a_to_b: Link,
                link_b_to_a: Optional[Link] = None) -> None:
        """Install a bidirectional point-to-point link (one Link per
        direction so loss/rate can be asymmetric)."""
        self.node(a)
        self.node(b)
        self._links[(a, b)] = link_a_to_b
        self._links[(b, a)] = link_b_to_a if link_b_to_a is not None else \
            dataclasses.replace(link_a_to_b, _busy_until_ns=0)

    def star(self, server: str, clients: list[str], make_link) -> None:
        """The paper's topology: N clients around one server."""
        for c in clients:
            self.connect(c, server, make_link(), make_link())

    # -- scheduling ----------------------------------------------------------
    def schedule(self, delay_ns: int, fn: Callable[[], None]) -> Timer:
        tie = self._tie_n
        self._tie_n = tie + 1
        ev = _Event(self.now_ns + int(delay_ns), tie, fn)
        heapq.heappush(self._queue, ev)
        return Timer(ev)

    def transmit(self, src: Node, dst: Node, pkt: Packet) -> None:
        link = self._links.get((src.addr, dst.addr))
        if link is None:
            raise KeyError(f"no link {src.addr} -> {dst.addr}")
        stats = self.stats
        stats["packets_sent"] += 1
        stats["bytes_sent"] += pkt.size_bytes
        k = _SENT_KEY[pkt.kind]
        stats[k] = stats.get(k, 0) + 1
        if self._hop_of:
            hop = self._hop_of.get((src.addr, dst.addr))
            if hop is not None:
                self.hop_bytes[hop] += pkt.size_bytes
                self.hop_packets[hop] += 1
        # FIFO serialization: this packet starts when the link frees up.
        start = max(self.now_ns, link._busy_until_ns)
        ser = link.serialization_ns(pkt.size_bytes)
        link._busy_until_ns = start + ser
        arrival = start + ser + link.propagation_ns(pkt)
        if link.loss.drops(pkt):
            stats["packets_dropped"] += 1
            k = _DROPPED_KEY[pkt.kind]
            stats[k] = stats.get(k, 0) + 1
            if self.trace:
                self.log(f"t={self.now_ns}ns DROP  {src.addr}->{dst.addr} "
                         f"{pkt}")
            return
        if self.trace:
            self.log(f"t={self.now_ns}ns SEND  {src.addr}->{dst.addr} {pkt} "
                     f"arrives t={arrival}ns")

        def _deliver() -> None:
            stats["packets_delivered"] += 1
            stats["bytes_delivered"] += pkt.size_bytes
            k = _DELIVERED_KEY[pkt.kind]
            stats[k] = stats.get(k, 0) + 1
            dst.deliver(pkt)

        self.schedule(arrival - self.now_ns, _deliver)

    def transmit_burst(self, src: Node, dst: Node,
                       pkts: Sequence[Packet]) -> None:
        """Transmit a back-to-back burst over one link.

        Under ``engine="batched"`` the whole burst is planned as vectorized
        numpy ops — FIFO serialization starts, propagation + jitter, and
        loss draws in one shot — and scheduled as a single flight.  Under
        ``engine="per_packet"`` (or when tracing, so log lines stay exact)
        it falls back to per-packet :meth:`transmit` calls.  Both paths are
        bit-for-bit identical: the keyed draws are pure per-packet
        functions and the flight carries the tie numbers the per-packet
        path would have assigned.
        """
        if (self.engine != "batched" or len(pkts) < _MIN_BATCH or self.trace
                or dst._bulk0 is None):
            # No batched engine, tiny burst, exact trace lines wanted, or a
            # receiver with no bulk hook (e.g. windowed TCP, which ACKs
            # every packet — a flight would be pure overhead): per-packet.
            for p in pkts:
                self.transmit(src, dst, p)
            return
        link = self._links.get((src.addr, dst.addr))
        if link is None:
            raise KeyError(f"no link {src.addr} -> {dst.addr}")
        n = len(pkts)
        txns, kinds, seqs, attempts = packet_key_arrays(pkts)
        sizes = np.fromiter((p.size_bytes for p in pkts), np.int64, n)

        # Serialization through the scalar method, one call per *unique*
        # size (an MTU burst has at most two), so Link subclasses that
        # override serialization_ns stay exact.
        ser = np.empty(n, np.int64)
        for s in np.unique(sizes):
            ser[sizes == s] = link.serialization_ns(int(s))
        start0 = max(self.now_ns, link._busy_until_ns)
        ends = start0 + np.cumsum(ser)          # start_i + ser_i for each i
        link._busy_until_ns = int(ends[-1])
        arrivals = ends + link.propagation_array(txns, kinds, seqs, attempts)
        dropped = link.loss.drop_mask(pkts, txns, kinds, seqs, attempts)

        stats = self.stats
        stats["packets_sent"] += n
        stats["bytes_sent"] += int(sizes.sum())
        for kv, c in zip(*np.unique(kinds, return_counts=True)):
            k = _SENT_KEY[PacketKind(int(kv))]
            stats[k] = stats.get(k, 0) + int(c)
        if self._hop_of:
            hop = self._hop_of.get((src.addr, dst.addr))
            if hop is not None:
                self.hop_bytes[hop] += int(sizes.sum())
                self.hop_packets[hop] += n

        ndrop = int(dropped.sum())
        if ndrop:
            stats["packets_dropped"] += ndrop
            for kv, c in zip(*np.unique(kinds[dropped], return_counts=True)):
                k = _DROPPED_KEY[PacketKind(int(kv))]
                stats[k] = stats.get(k, 0) + int(c)
            if ndrop == n:
                return
            keep = ~dropped
            arrivals = arrivals[keep]
            sizes = sizes[keep]
            pkts = [p for p, kept in zip(pkts, keep.tolist()) if kept]

        # Survivors consume consecutive tie numbers in send order — exactly
        # what per-packet schedule() calls would have assigned.
        k = len(pkts)
        tie0 = self._tie_n
        self._tie_n = tie0 + k
        order = np.argsort(arrivals, kind="stable")
        olist = order.tolist()
        fpkts = [pkts[i] for i in olist]
        safe_until = k
        for idx, p in enumerate(fpkts):
            if p.kind != PacketKind.DATA or p.seq == p.total:
                safe_until = idx
                break
        p0 = fpkts[0]
        key = (p0.addr, p0.txn)
        if any(p.addr != p0.addr or p.txn != p0.txn for p in fpkts):
            key = None              # heterogeneous burst: bounds globally
        csum = [0]
        csum.extend(np.cumsum(sizes[order]).tolist())
        flight = _Flight(fpkts,
                         arrivals[order].tolist(),
                         [tie0 + i for i in olist],
                         csum, safe_until, key, dst)
        self._active_flights.append(flight)
        heapq.heappush(self._flightq,
                       (flight.arrivals[0], flight.ties[0], flight))

    # -- the deep-ingestion pass (batched engine) ----------------------------
    def _flight_pass(self, until_ns: Optional[int]) -> int:
        """Bulk-ingest every eligible pending flight packet below the next
        *effectful* point of the calendar; returns packets ingested.

        Bulk-eligible packet processing (see :meth:`Node.register`) is a
        pure per-transaction verify-and-store: it consumes no tie numbers,
        schedules nothing, sends nothing, and touches nothing shared across
        transactions beyond commutative counter additions.  Two such
        operations on different transactions therefore commute, so between
        two effectful points the engine may ingest flight-by-flight instead
        of in strict global arrival order and still reach a bit-identical
        state.  Effectful points — which bound the pass — are:

        * globally: the earliest pending non-flight event (timers, train
          completions, control-packet deliveries), whose handler may read
          any state and consume ties, plus the ``until_ns`` horizon of a
          paused run;
        * per transaction: the first *statically* unsafe packet (non-DATA /
          the transaction's last packet) of any flight carrying the same
          ``(sender, txn)`` key, whose processing delivers/ACKs/NACKs and
          reads the state this transaction's ingestion writes.

        Because ingestion never crosses those points, every timer handler
        still observes exactly the counters and receiver state it would
        have seen under per-packet execution, and every transaction's own
        packets are processed in exact arrival order.  Effectful packets of
        *other* transactions (their last packets, parity, declined bulk)
        do not bound a flight: their processing touches only their own
        transaction's state, and their sends/scheduling consume ties in
        true heap order, all of which commutes with this flight's ingested
        stores.  The one mid-stream approximation: such a handler sees
        ``sim.stats`` counters that already include ingested arrivals of
        other transactions (no shipped transport or FL callback reads them
        mid-run; final stats are exact either way).
        """
        act = self._active_flights
        queue = self._queue
        inf = 1 << 62
        gt, gtie = inf, inf
        if queue:
            h = queue[0]
            gt, gtie = h.time_ns, h.tie
        if until_ns is not None and until_ns < gt:
            gt, gtie = until_ns, inf
        # Per-key bounds: earliest statically unsafe packet per (addr, txn);
        # a heterogeneous (key=None) flight bounds everyone.
        key_bound: dict = {}
        compact = False
        for f in act:
            i = f.idx
            nf = len(f.packets)
            if i >= nf:
                compact = True
                continue
            su = f.safe_until
            if su >= nf:
                continue
            t2, k2 = f.arrivals[su], f.ties[su]
            if f.key is None:
                if t2 < gt or (t2 == gt and k2 < gtie):
                    gt, gtie = t2, k2
            else:
                cur = key_bound.get(f.key)
                if cur is None or t2 < cur[0] or (t2 == cur[0]
                                                  and k2 < cur[1]):
                    key_bound[f.key] = (t2, k2)

        total = 0
        stats = self.stats
        flightq = self._flightq
        horizon = self._flight_horizon_ns
        for f in act:
            i = f.idx
            nf = len(f.packets)
            if i >= nf or f.bulk_dead or f.refused_idx == i:
                continue
            bulk = f.dst._bulk0
            if bulk is None:
                continue
            bt, btie = gt, gtie
            kb = key_bound.get(f.key) if f.key is not None else None
            if kb is not None and (kb[0] < bt or (kb[0] == bt
                                                  and kb[1] < btie)):
                bt, btie = kb
            arr = f.arrivals
            ties = f.ties
            jmax = min(f.safe_until, nf)
            j = bisect_left(arr, bt, i, jmax)
            while j < jmax and arr[j] == bt and ties[j] < btie:
                j += 1
            if j <= i:
                continue
            self.now_ns = arr[i]
            c = bulk(f.packets, i, j, arr)
            if c <= 0:
                if c < 0:
                    f.bulk_dead = True
                else:
                    f.refused_idx = i
                continue
            csum = f.bytes_csum
            stats["packets_delivered"] += c
            stats["delivered_data"] += c
            stats["bytes_delivered"] += csum[i + c] - csum[i]
            total += c
            i += c
            f.idx = i
            if arr[i - 1] > horizon:
                horizon = arr[i - 1]
            if i < nf:
                if i < j:
                    # Dynamic stop before the bound: skip the wasted pass
                    # when this packet pops (the hook already declined it).
                    f.refused_idx = i
                tie2 = ties[i]
                f.seated_tie = tie2
                heapq.heappush(flightq, (arr[i], tie2, f))
            else:
                f.seated_tie = -1
                compact = True
        if compact:
            self._active_flights = [f for f in act
                                    if f.idx < len(f.packets)]
        self._flight_horizon_ns = horizon
        return total

    # -- main loop -----------------------------------------------------------
    def run(self, until_ns: Optional[int] = None, max_events: int = 10_000_000
            ) -> int:
        """Drain the calendar; returns the final simulation time."""
        n = 0
        queue = self._queue
        flightq = self._flightq
        stats = self.stats
        try:
            while queue or flightq:
                if flightq:
                    t, tie, fl = flightq[0]
                    if queue:
                        h = queue[0]
                        take_flight = (t < h.time_ns
                                       or (t == h.time_ns and tie < h.tie))
                    else:
                        take_flight = True
                else:
                    take_flight = False

                if not take_flight:
                    ev = heapq.heappop(queue)
                    if ev.cancelled:
                        continue
                    if until_ns is not None and ev.time_ns > until_ns:
                        # Put it back for a later resumed run().
                        heapq.heappush(queue, ev)
                        self.now_ns = until_ns
                        break
                    self.now_ns = ev.time_ns
                    ev.fn()
                    n += 1
                    if n >= max_events:
                        raise _budget_error()
                    continue

                entry = heapq.heappop(flightq)
                t, tie, fl = entry
                if tie != fl.seated_tie:
                    continue                    # stale seat (lazy deletion)
                if until_ns is not None and t > until_ns:
                    heapq.heappush(flightq, entry)
                    self.now_ns = until_ns
                    break
                self.now_ns = t
                i = fl.idx
                if (not fl.bulk_dead and fl.refused_idx != i
                        and i < fl.safe_until and fl.dst._bulk0 is not None):
                    n += self._flight_pass(until_ns)
                    if n >= max_events:
                        raise _budget_error()
                    if fl.idx != i:
                        # The pass ingested (and re-seated) this flight.
                        continue
                    self.now_ns = t
                # Deliver exactly one due packet through the per-packet
                # path (last packet, declined bulk, no bulk hook...).
                pkt = fl.packets[i]
                stats["packets_delivered"] += 1
                stats["bytes_delivered"] += pkt.size_bytes
                k = _DELIVERED_KEY[pkt.kind]
                stats[k] = stats.get(k, 0) + 1
                fl.dst.deliver(pkt)
                i += 1
                n += 1
                fl.idx = i
                nf = len(fl.packets)
                if fl.safe_until < i:
                    # The statically effectful packet has been processed;
                    # advance the bound to the next one so later passes are
                    # not pinned to a past arrival.
                    su, fpkts = i, fl.packets
                    while su < nf:
                        p = fpkts[su]
                        if p.kind != PacketKind.DATA or p.seq == p.total:
                            break
                        su += 1
                    fl.safe_until = su
                if n >= max_events:
                    raise _budget_error()
                if i < nf:
                    tie2 = fl.ties[i]
                    fl.seated_tie = tie2
                    heapq.heappush(flightq, (fl.arrivals[i], tie2, fl))
                else:
                    fl.seated_tie = -1
                    try:
                        self._active_flights.remove(fl)
                    except ValueError:
                        pass
            else:
                # Drained: the last processed thing may have been a
                # bulk-ingested arrival.
                if self._flight_horizon_ns > self.now_ns:
                    self.now_ns = self._flight_horizon_ns
            return self.now_ns
        finally:
            self.events_processed += n

    # -- replay digests ------------------------------------------------------
    def stats_digest(self) -> str:
        """Stable content hash of (final time, all counters) — the replay
        fingerprint the engine-equivalence tests and benchmarks compare."""
        blob = repr((self.now_ns, sorted(self.stats.items())))
        return hashlib.sha256(blob.encode()).hexdigest()

    def log(self, line: str) -> None:
        if self.trace:
            self.trace_lines.append(line)
