"""Deterministic discrete-event network simulator — the NS3 stand-in.

Implements exactly what the paper uses NS3 for: a star topology of N client
nodes around one server node, point-to-point links with a data rate, a
propagation delay and a loss model, an event calendar in integer nanoseconds,
and cancellable timers (NS3 ``Simulator::Schedule``/``Cancel``).

Everything is single-threaded and seeded — a simulation replays bit-for-bit,
which the tests and benchmarks rely on.
"""

from __future__ import annotations

import dataclasses
import heapq
import itertools
from typing import Callable, Optional

from repro.core.channel import Link
from repro.core.packets import Packet


@dataclasses.dataclass(order=True)
class _Event:
    time_ns: int
    tie: int
    fn: Callable[[], None] = dataclasses.field(compare=False)
    cancelled: bool = dataclasses.field(compare=False, default=False)


class Timer:
    """Handle for a scheduled event; ``cancel()`` is idempotent."""

    __slots__ = ("_event",)

    def __init__(self, event: _Event):
        self._event = event

    def cancel(self) -> None:
        self._event.cancelled = True

    @property
    def active(self) -> bool:
        return not self._event.cancelled


class Node:
    """A network endpoint with an IPv4-style address.

    Transports attach themselves via ``register`` to receive packets; the
    node dispatches on (txn) so multiple concurrent transactions coexist
    (N clients talking to one server).
    """

    def __init__(self, sim: "Simulator", addr: str):
        self.sim = sim
        self.addr = addr
        self._handlers: list[Callable[[Packet], bool]] = []

    def register(self, handler: Callable[[Packet], bool]) -> None:
        """Handler returns True if it consumed the packet."""
        self._handlers.append(handler)

    def unregister(self, handler: Callable[[Packet], bool]) -> None:
        if handler in self._handlers:
            self._handlers.remove(handler)

    def deliver(self, pkt: Packet) -> None:
        for h in list(self._handlers):
            if h(pkt):
                return
        self.sim.log(f"{self.addr}: unhandled packet {pkt}")

    def send(self, pkt: Packet, dest: "Node") -> None:
        self.sim.transmit(self, dest, pkt)


class Simulator:
    """Event calendar + topology. Times are integer nanoseconds."""

    def __init__(self, *, trace: bool = False):
        self.now_ns: int = 0
        self._queue: list[_Event] = []
        self._tie = itertools.count()
        self._nodes: dict[str, Node] = {}
        self._links: dict[tuple[str, str], Link] = {}
        self.trace = trace
        self.trace_lines: list[str] = []
        # Counters for benchmarks.
        self.stats = {
            "packets_sent": 0, "packets_dropped": 0, "packets_delivered": 0,
            "bytes_sent": 0, "bytes_delivered": 0,
        }

    # -- topology ----------------------------------------------------------
    def node(self, addr: str) -> Node:
        if addr not in self._nodes:
            self._nodes[addr] = Node(self, addr)
        return self._nodes[addr]

    def connect(self, a: str, b: str, link_a_to_b: Link,
                link_b_to_a: Optional[Link] = None) -> None:
        """Install a bidirectional point-to-point link (one Link per
        direction so loss/rate can be asymmetric)."""
        self.node(a)
        self.node(b)
        self._links[(a, b)] = link_a_to_b
        self._links[(b, a)] = link_b_to_a if link_b_to_a is not None else \
            dataclasses.replace(link_a_to_b, _busy_until_ns=0)

    def star(self, server: str, clients: list[str], make_link) -> None:
        """The paper's topology: N clients around one server."""
        for c in clients:
            self.connect(c, server, make_link(), make_link())

    # -- scheduling ----------------------------------------------------------
    def schedule(self, delay_ns: int, fn: Callable[[], None]) -> Timer:
        ev = _Event(self.now_ns + int(delay_ns), next(self._tie), fn)
        heapq.heappush(self._queue, ev)
        return Timer(ev)

    def transmit(self, src: Node, dst: Node, pkt: Packet) -> None:
        link = self._links.get((src.addr, dst.addr))
        if link is None:
            raise KeyError(f"no link {src.addr} -> {dst.addr}")
        self.stats["packets_sent"] += 1
        self.stats["bytes_sent"] += pkt.size_bytes
        # FIFO serialization: this packet starts when the link frees up.
        start = max(self.now_ns, link._busy_until_ns)
        ser = link.serialization_ns(pkt.size_bytes)
        link._busy_until_ns = start + ser
        arrival = start + ser + link.propagation_ns(pkt)
        if link.loss.drops(pkt):
            self.stats["packets_dropped"] += 1
            self.log(f"t={self.now_ns}ns DROP  {src.addr}->{dst.addr} {pkt}")
            return
        self.log(f"t={self.now_ns}ns SEND  {src.addr}->{dst.addr} {pkt} "
                 f"arrives t={arrival}ns")

        def _deliver() -> None:
            self.stats["packets_delivered"] += 1
            self.stats["bytes_delivered"] += pkt.size_bytes
            dst.deliver(pkt)

        self.schedule(arrival - self.now_ns, _deliver)

    # -- main loop -----------------------------------------------------------
    def run(self, until_ns: Optional[int] = None, max_events: int = 10_000_000
            ) -> int:
        """Drain the calendar; returns the final simulation time."""
        n = 0
        while self._queue:
            ev = heapq.heappop(self._queue)
            if ev.cancelled:
                continue
            if until_ns is not None and ev.time_ns > until_ns:
                # Put it back for a later resumed run().
                heapq.heappush(self._queue, ev)
                self.now_ns = until_ns
                break
            self.now_ns = ev.time_ns
            ev.fn()
            n += 1
            if n >= max_events:
                raise RuntimeError("simulator event budget exceeded "
                                   "(livelock in a transport state machine?)")
        return self.now_ns

    def log(self, line: str) -> None:
        if self.trace:
            self.trace_lines.append(line)
