"""Adaptive transport control plane: policies that renegotiate the wire.

The other half of the loop closed by :mod:`repro.core.telemetry`: a
registry-keyed :class:`ControlPolicy` (the transport/stage/topology/model
registry idiom) that the server consults **between transactions** — at
sync round starts and async session entries — and that may renegotiate
one client's uplink/downlink pipeline spec and FEC geometry from its
:class:`~repro.core.telemetry.ClientHealth`.

The renegotiation itself is carried entirely in-band: every
self-describing payload already names its pipeline in the PR 5
:class:`~repro.core.wire.WireHeader`, so a client that switches from
``topk(0.4)`` to ``topk(0.04)`` mid-run needs no out-of-band sync — the
receiver decodes whatever the header declares.  Encoder state survives
the swap under the :func:`repro.core.wire.migrate_state` rules (EF
residual and delta reference carry over; everything else resets).

Built-ins:

* ``static`` — the default: a no-op that never returns a decision.  The
  24 orchestrator-equivalence digests are pinned with this policy, which
  is the proof the control plane is a pure add-on.
* ``adaptive`` — a tiered escalation ladder driven by the loss-rate EWMA:
  clients observing heavy retransmission (congested edge) escalate top-k
  sparsity and FEC parity so their updates fit the round; clients on
  clean fiber relax to lighter compression and drop FEC overhead.

See ``docs/CONTROL.md`` for the telemetry fields, the renegotiation
sequence, and the state-migration rules.
"""

from __future__ import annotations

import abc
from dataclasses import dataclass
from typing import Callable, Optional

from repro.core.telemetry import ClientHealth

#: TransportConfig fields a decision may renegotiate.
DECISION_FIELDS = ("uplink", "downlink", "fec_block", "fec_parity")


@dataclass(frozen=True)
class ControlDecision:
    """One client's renegotiated transport parameters.

    ``None`` fields are left untouched; the server compares the rest
    against the client's current effective config and applies (and
    counts) only actual changes, so a policy may return its target
    configuration unconditionally.  ``reset_state=True`` drops the
    client's encoder state (EF residual, delta reference) instead of
    migrating it — the explicit-reset migration rule.
    """

    uplink: Optional[str] = None
    downlink: Optional[str] = None
    fec_block: Optional[int] = None
    fec_parity: Optional[int] = None
    reset_state: bool = False


class ControlPolicy(abc.ABC):
    """Decide, per client and per scheduling opportunity, whether to
    renegotiate.  Policies must be deterministic functions of the
    telemetry they are shown (no RNG, no wall clock): the simulation's
    replay guarantees extend through the control plane."""

    name: str = "abstract"

    @abc.abstractmethod
    def renegotiate(self, addr: str, health: Optional[ClientHealth],
                    cfg) -> Optional[ControlDecision]:
        """``health`` is the client's snapshot (None before any
        observation); ``cfg`` its current effective
        :class:`~repro.core.transport.TransportConfig`.  Return a
        :class:`ControlDecision` or None to leave the client alone."""


# --------------------------------------------------------------------------
# Registry (the transport-registry idiom)
# --------------------------------------------------------------------------
_POLICIES: dict[str, Callable[..., ControlPolicy]] = {}


def register_policy(name: str, factory: Callable[..., ControlPolicy], *,
                    overwrite: bool = False) -> None:
    """Register ``factory`` under ``name``; called with
    ``FLConfig.control_args``.  Re-registering raises unless
    ``overwrite=True`` (silently shadowing ``adaptive`` would invalidate
    every benchmark that names it)."""
    if not overwrite and name in _POLICIES:
        raise ValueError(f"control policy {name!r} is already registered "
                         f"(pass overwrite=True to replace it)")
    _POLICIES[name] = factory


def make_policy(name: str, **kwargs) -> ControlPolicy:
    try:
        factory = _POLICIES[name]
    except KeyError:
        raise ValueError(
            f"unknown control policy {name!r}; registered policies: "
            f"{available_policies()}") from None
    return factory(**kwargs)


def available_policies() -> list[str]:
    return sorted(_POLICIES)


# --------------------------------------------------------------------------
# static — the pinned no-op
# --------------------------------------------------------------------------
class StaticPolicy(ControlPolicy):
    """Never renegotiates anything.  ``FLConfig.control='static'`` (the
    default) does not even construct one — the server skips the control
    step entirely — but the class exists so ``make_policy('static')``
    works and third-party code can subclass the do-nothing baseline."""

    name = "static"

    def renegotiate(self, addr, health, cfg):
        return None


# --------------------------------------------------------------------------
# adaptive — loss-driven tier ladder
# --------------------------------------------------------------------------
#: The default ladder, light -> heavy.  All tiers share the uplink's
#: delta/ef prefix so (a) the aggregation domain never changes across a
#: swap (the server refuses domain flips) and (b) the EF residual carries
#: over and keeps compensating across tier switches.  ``fec_parity=0``
#: disables the FEC trailer outright (clean fiber pays zero overhead).
DEFAULT_TIERS = (
    {"uplink": "delta|ef|topk(0.4)|int8(1024)",
     "fec_block": 16, "fec_parity": 0},
    {"uplink": "delta|ef|topk(0.15)|int8(1024)",
     "fec_block": 8, "fec_parity": 1},
    {"uplink": "delta|ef|topk(0.04)|int8(1024)",
     "fec_block": 4, "fec_parity": 2},
)


class AdaptivePolicy(ControlPolicy):
    """Move each client along a compression/parity ladder by its observed
    loss-rate EWMA: ``>= hi`` steps one tier heavier, ``<= lo`` one tier
    lighter, in between holds (the hi/lo gap is the hysteresis band that
    keeps borderline clients from flapping).  Tiers, thresholds and the
    starting rung come from ``FLConfig.control_args``."""

    name = "adaptive"

    def __init__(self, *, tiers=None, hi: float = 0.03, lo: float = 0.008,
                 min_txns: int = 1, start_tier: int = 1):
        self.tiers = tuple(dict(t) for t in
                           (tiers if tiers is not None else DEFAULT_TIERS))
        if not self.tiers:
            raise ValueError("adaptive policy needs at least one tier")
        for t in self.tiers:
            unknown = set(t) - set(DECISION_FIELDS)
            if unknown:
                raise ValueError(f"tier {t} sets unknown transport fields "
                                 f"{sorted(unknown)}")
        if not 0.0 <= lo <= hi:
            raise ValueError(f"need 0 <= lo <= hi, got lo={lo} hi={hi}")
        if not 0 <= start_tier < len(self.tiers):
            raise ValueError(f"start_tier {start_tier} out of range for "
                             f"{len(self.tiers)} tiers")
        self.hi = float(hi)
        self.lo = float(lo)
        self.min_txns = int(min_txns)
        self.start_tier = int(start_tier)
        self._tier: dict[str, int] = {}

    def tier_of(self, addr: str) -> int:
        return self._tier.get(addr, self.start_tier)

    def renegotiate(self, addr, health, cfg):
        if health is None or health.txns < self.min_txns:
            return None
        cur = self.tier_of(addr)
        if health.loss_rate >= self.hi:
            new = min(cur + 1, len(self.tiers) - 1)
        elif health.loss_rate <= self.lo:
            new = max(cur - 1, 0)
        else:
            new = cur
        self._tier[addr] = new
        t = self.tiers[new]
        # Returned unconditionally: the server deduplicates against the
        # client's current config, so holding a tier costs nothing.
        return ControlDecision(
            uplink=t.get("uplink"), downlink=t.get("downlink"),
            fec_block=t.get("fec_block"), fec_parity=t.get("fec_parity"))


register_policy("static", StaticPolicy)
register_policy("adaptive", AdaptivePolicy)
