"""Event-driven FL server core: per-client sessions over one Simulator.

The paper's Fig. 4 round is a *lockstep loop*: broadcast, wait for every
client, aggregate, repeat.  This module dissolves that loop into its event
structure so scheduling becomes a policy choice (``repro.core.scheduling``)
instead of control flow:

* :class:`ClientSession` — one client's traversal of the
  broadcast -> train -> uplink -> ingest pipeline, with its own transaction
  numbers.  Sessions from different (virtual) rounds overlap freely in
  flight; every transport tolerates that because receivers key state by
  ``(sender addr, txn)`` (``TransportCaps.concurrent_txns``).
* :class:`ServerCore` — the mechanics shared by every scheduling policy:
  transport dispatch, packetizing, downlink/uplink senders, decode +
  zero-fill, the late-update staleness buffer, health tracking, and the
  aggregation math.  The core raises *events* (uplink ingested, session
  failed, downlink delivered) into whatever scheduler is bound to it; it
  never decides when a round starts or ends.

``repro.core.rounds.FederatedSystem`` is the stable facade over
(core, scheduler); ``mode="sync"`` reproduces the pre-refactor round loop
bit-for-bit (pinned by ``tests/test_orchestrator_equivalence.py``),
``mode="async"`` runs FedBuff-style overlapping rounds
(see ``docs/ASYNC.md``).

Configuration (:class:`FLConfig`), per-round accounting
(:class:`RoundResult`), the client object (:class:`FLClient`) and the
elastic health pool (:class:`ClientPool`) live here too — ``rounds``
re-exports them so existing imports keep working.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import numpy as np

from repro.core import aggregation as agg
from repro.core.control import available_policies, make_policy
from repro.core.packetizer import (Packetizer, flatten_to_vector, packetize,
                                   unflatten_from_vector)
from repro.core.flow import maybe_flow
from repro.core.simulator import Simulator
from repro.core.telemetry import Telemetry
from repro.core.transport import (Delivery, Transport, TransportConfig,
                                  make_transport, validate_transport_kind)
from repro.core.wire import (Pipeline, PipelineState, WireDecodeError,
                             decode_payload as wire_decode_payload,
                             decode_payload_batch as wire_decode_payload_batch,
                             legacy_pipeline, migrate_state, parse_pipeline)


def _scheduler_registry() -> dict:
    """The one source of truth for scheduling modes.

    Imported lazily: ``repro.core.scheduling`` defines the policies and
    imports this module for the core types, so a top-level import here
    would be circular.  By construction time of any ``FLConfig`` the
    import graph is settled and the registry is populated.
    """
    from repro.core.scheduling import SCHEDULERS
    return SCHEDULERS


# --------------------------------------------------------------------------
# Configuration (TransportConfig lives with the transport registry and is
# re-exported from repro.core.rounds for backward compatibility)
# --------------------------------------------------------------------------
@dataclasses.dataclass
class FLConfig:
    transport: TransportConfig = dataclasses.field(
        default_factory=TransportConfig)
    aggregation: str = "fedavg"          # pairwise (paper Eq.1) | fedavg | trimmed_mean
    # fedavg implementation: numpy (default, digest-stable) | kernel
    # (Pallas fedavg_trees; needs jax) | auto (kernel when importable).
    aggregation_backend: str = "numpy"
    send_deltas: bool = False            # ship (trained - received) instead of weights
    error_feedback: bool = False         # residual compensation for lossy codecs
    broadcast_model: bool = True         # server->client downlink each round
    round_deadline_ns: Optional[int] = None
    server_lr: float = 1.0               # for delta aggregation
    staleness_discount: float = 0.5      # late update weight *= discount^age
    # discount**age underflows for large ages; the factor is clamped to
    # this floor so a straggler's update is discounted, never silently
    # dropped.  Clamp events surface as RoundResult.staleness_clamped.
    staleness_floor: float = 1e-6
    unhealthy_after_failures: int = 2
    readmit_after_rounds: int = 2
    # Partial participation (fleet-scale): each round samples
    # round(participation_fraction * |active|) clients, at least
    # min_participants, via a seeded Fisher-Yates draw keyed by
    # (participation_seed, round_idx) — deterministic across Python versions
    # because it only consumes Random.random().  Sync mode only: async
    # participation emerges from per-client cadence + health instead.
    participation_fraction: float = 1.0
    min_participants: int = 1
    participation_seed: int = 0
    # Scheduling policy: "sync" is the paper's round barrier (bit-compatible
    # with the pre-refactor loop); "async" is the FedBuff-style buffered
    # asynchronous server (docs/ASYNC.md).
    mode: str = "sync"
    # Async only: aggregate whenever this many updates are buffered.
    buffer_k: int = 8
    # Async only: drop updates staler than this many aggregations (None =
    # keep everything, discounted).  Dropped counts surface in
    # RoundResult.metrics["stale_dropped"].
    max_staleness: Optional[int] = None
    # Batched wire-plane (repro.core.wire batch API): uplink payloads are
    # decoded in one vectorized pass per aggregation instead of one call
    # per delivery, and a stateless downlink broadcast is encoded once per
    # model version and the bytes reused across clients.  Both paths are
    # bit-identical to the per-client loop (pinned by the orchestrator-
    # equivalence digests, which run with this default), so False exists
    # only to time the difference and to simplify debugging.
    batch_wire: bool = True
    # Adaptive transport control plane (repro.core.control): the registered
    # policy consulted between transactions — sync round starts, async
    # session entries — to renegotiate each client's uplink/downlink
    # pipeline spec and FEC geometry from its telemetry.  "static" (the
    # default) skips the control step entirely and is pinned bit-identical
    # by the orchestrator-equivalence digests; "adaptive" is the built-in
    # loss-driven tier ladder.  control_args are the policy factory's
    # kwargs (e.g. {"hi": 0.05} for adaptive).
    control: str = "static"
    control_args: Optional[dict] = None

    def __post_init__(self) -> None:
        # Fail at construction time (with the registered names) rather than
        # deep inside receiver setup; also covers dataclasses.replace(...).
        validate_transport_kind(self.transport.kind)
        if self.mode not in _scheduler_registry():
            raise ValueError(f"unknown mode {self.mode!r}; one of "
                             f"{sorted(_scheduler_registry())}")
        if self.buffer_k < 1:
            raise ValueError("buffer_k must be >= 1")
        if self.aggregation_backend not in agg.FEDAVG_BACKENDS:
            raise ValueError(
                f"unknown aggregation_backend {self.aggregation_backend!r}; "
                f"one of {agg.FEDAVG_BACKENDS}")
        if (self.transport.uplink is not None
                and (self.send_deltas or self.error_feedback)):
            raise ValueError(
                "send_deltas/error_feedback are the legacy spellings of the "
                "'delta' and 'ef' pipeline stages; with transport.uplink "
                "set, put the stages in the spec instead "
                "(e.g. uplink='delta|ef|int8(1024)')")
        if self.control not in available_policies():
            raise ValueError(f"unknown control policy {self.control!r}; "
                             f"one of {available_policies()}")


@dataclasses.dataclass
class RoundResult:
    """One aggregation event.  Sync: one barrier round.  Async: one buffer
    flush (round_idx counts aggregations; roster is everyone who was in
    flight during the window)."""

    round_idx: int
    duration_ns: int
    arrived: list[str]
    failed: list[str]
    skipped_unhealthy: list[str]
    late_folded: int
    bytes_sent: int
    packets_sent: int
    packets_dropped: int
    retransmissions: int
    metrics: dict = dataclasses.field(default_factory=dict)
    roster: list[str] = dataclasses.field(default_factory=list)
    # Per-kind traffic split (from the simulator's per-PacketKind counters)
    # so benchmarks separate payload from protocol chatter.
    data_packets: int = 0
    nack_packets: int = 0
    parity_packets: int = 0
    # How many contributions had their staleness factor clamped to
    # FLConfig.staleness_floor (discount**age underflow guard).
    staleness_clamped: int = 0
    # Wire-plane counters for this window: payloads explicitly degraded to
    # zero-fill, and downlinks served from the broadcast-encode cache.
    decode_errors: int = 0
    bcast_cache_hits: int = 0
    # Per-client telemetry snapshots ({addr: repro.core.telemetry.
    # ClientHealth}, sorted by addr) as of this window's end.
    client_health: dict = dataclasses.field(default_factory=dict)


# --------------------------------------------------------------------------
# Client
# --------------------------------------------------------------------------
class FLClient:
    """One federated client.

    ``train_fn(params, round_idx, client) -> (new_params, metrics)`` runs real
    (JAX) local training; ``train_time_ns`` models how long that takes inside
    the simulation (heterogeneous values create stragglers); ``cadence_ns``
    is the async re-entry gap — how long the device stays unavailable after
    finishing an upload before it asks for fresh work (ignored by sync
    scheduling, where the round barrier sets the cadence).
    """

    def __init__(self, addr: str, train_fn: Callable, *,
                 train_time_ns: int = 1_000_000_000,
                 weight: float = 1.0,
                 cadence_ns: int = 0):
        self.addr = addr
        self.train_fn = train_fn
        self.train_time_ns = train_time_ns
        self.weight = weight
        self.cadence_ns = cadence_ns
        self.params: Any = None          # local copy of the global model
        # Wire state (delta references, error-feedback residuals) lives in
        # per-client PipelineStates owned by ServerCore, not here.
        self.metrics_history: list[dict] = []


class ClientPool:
    """Elastic membership with health tracking.  ``round_idx`` is the sync
    round counter or the async aggregation counter — benching and
    re-admission are measured in whichever unit the scheduler advances."""

    def __init__(self, clients: list[FLClient], *,
                 unhealthy_after: int = 2, readmit_after: int = 2):
        self.clients: dict[str, FLClient] = {c.addr: c for c in clients}
        self.failures: dict[str, int] = {c.addr: 0 for c in clients}
        self.benched_until: dict[str, int] = {}
        self.unhealthy_after = unhealthy_after
        self.readmit_after = readmit_after

    def add(self, client: FLClient) -> None:
        self.clients[client.addr] = client
        self.failures[client.addr] = 0

    def remove(self, addr: str) -> None:
        self.clients.pop(addr, None)
        self.failures.pop(addr, None)
        self.benched_until.pop(addr, None)

    def active(self, round_idx: int) -> list[FLClient]:
        out = []
        for addr, c in self.clients.items():
            if self.benched_until.get(addr, -1) > round_idx:
                continue
            out.append(c)
        return out

    def is_active(self, addr: str, round_idx: int) -> bool:
        return (addr in self.clients
                and self.benched_until.get(addr, -1) <= round_idx)

    def benched(self, round_idx: int) -> list[str]:
        return [a for a, r in self.benched_until.items() if r > round_idx]

    def record_failure(self, addr: str, round_idx: int) -> None:
        self.failures[addr] = self.failures.get(addr, 0) + 1
        if self.failures[addr] >= self.unhealthy_after:
            self.benched_until[addr] = round_idx + 1 + self.readmit_after
            self.failures[addr] = 0

    def record_success(self, addr: str) -> None:
        self.failures[addr] = 0


# --------------------------------------------------------------------------
# Sessions
# --------------------------------------------------------------------------
# Session lifecycle.  DOWNLINK -> TRAINING -> UPLINK -> ARRIVED is the happy
# path; FAILED (transport retry exhaustion) and TIMEOUT (async session
# watchdog) are terminal on the session but not on the client.
PENDING = "pending"
DOWNLINK = "downlink"
TRAINING = "training"
UPLINK = "uplink"
ARRIVED = "arrived"
FAILED = "failed"
TIMEOUT = "timeout"


@dataclasses.dataclass
class ClientSession:
    """One client's pass through broadcast -> train -> uplink -> ingest.

    ``round_idx`` is the *virtual* round this session belongs to (the loop
    index under sync scheduling; the client's own session count under
    async).  ``model_version`` is the server's aggregation counter at
    downlink time — async staleness is the version distance at ingest.
    Transaction numbering is session-scoped: the scheduler assigns
    ``txn_down``/``txn_up`` (sync reuses the round-derived pair so wire
    traffic is byte-identical to the pre-refactor loop; async draws a fresh
    pair per session so overlapping sessions never collide).
    """

    client: FLClient
    round_idx: int
    txn_down: int
    txn_up: int
    model_version: int = 0
    state: str = PENDING
    started_ns: int = 0

    @property
    def addr(self) -> str:
        return self.client.addr


class _PendingWire:
    """An uplink payload whose decode is deferred to aggregation time.

    With ``FLConfig.batch_wire`` the server hands schedulers one of these
    instead of a decoded vector; schedulers treat updates as opaque until
    :meth:`ServerCore.apply_aggregation`, which resolves every pending
    payload in one :func:`repro.core.wire.decode_payload_batch` call.
    Decode is pure computation (no simulator events), so deferring it
    cannot move any event time or order.
    """

    __slots__ = ("data", "vec", "addr")

    def __init__(self, data: bytes, addr: Optional[str] = None):
        self.data: Optional[bytes] = data
        self.vec: Optional[np.ndarray] = None
        # Sender address, kept so a deferred decode failure can still be
        # attributed to the right client's telemetry.
        self.addr = addr

    def __repr__(self) -> str:
        state = "decoded" if self.vec is not None else \
            f"{len(self.data)}B pending"
        return f"_PendingWire({state})"


# --------------------------------------------------------------------------
# The server core
# --------------------------------------------------------------------------
class ServerCore:
    """Transport + packetizing + ingest + aggregation mechanics, policy-free.

    A scheduler (``repro.core.scheduling``) is bound after construction and
    receives the events; the core never starts rounds, samples rosters, or
    decides when to aggregate.
    """

    def __init__(self, sim: Simulator, server_addr: str,
                 clients: list[FLClient], global_params: Any,
                 cfg: FLConfig):
        self.sim = sim
        self.cfg = cfg
        self.server_addr = server_addr
        self.server_node = sim.node(server_addr)
        self.pool = ClientPool(
            clients, unhealthy_after=cfg.unhealthy_after_failures,
            readmit_after=cfg.readmit_after_rounds)
        self.global_params = global_params

        # Wire plane: one pipeline per direction (repro.core.wire).  A
        # spec on the TransportConfig means self-describing payloads for
        # that direction; otherwise the legacy codec runs headerless,
        # byte-identical to the pre-pipeline wire format (pinned by the
        # orchestrator-equivalence digests).  delta/ef state lives in
        # per-client PipelineStates here, not in the orchestration logic.
        t = cfg.transport
        self.uplink_pipeline: Pipeline = (
            parse_pipeline(t.uplink) if t.uplink is not None
            else legacy_pipeline(t.codec, t.codec_kwargs,
                                 send_deltas=cfg.send_deltas,
                                 error_feedback=cfg.error_feedback))
        self.downlink_pipeline: Pipeline = (
            parse_pipeline(t.downlink) if t.downlink is not None
            else legacy_pipeline(t.codec, t.codec_kwargs))
        self.packetizer = Packetizer(pipeline=self.downlink_pipeline,
                                     mtu=t.mtu)
        # Per-(client, direction) wire state, created lazily and persistent
        # across rounds (an EF residual must survive the round barrier).
        self._up_enc_state: dict[str, PipelineState] = {}
        self._down_enc_state: dict[str, PipelineState] = {}
        # Payloads that failed to decode and were explicitly degraded to a
        # zero vector (WireDecodeError — never a bare except).
        self.decode_errors = 0
        # Broadcast-encode cache accounting: how many downlinks reused the
        # per-model-version encoded bytes instead of re-encoding.
        self.bcast_cache_hits = 0

        # Adaptive control plane.  The telemetry plane is always on (pure
        # bookkeeping: no RNG, no events, no sim.stats — it cannot move a
        # digest); the controller is None under the default "static"
        # policy, which skips the whole control step.  Renegotiated
        # clients get per-addr overrides here; everyone else falls through
        # to the base pipelines/packetizer/config, so the default path is
        # bit-identical with or without this machinery.
        self.telemetry = Telemetry()
        self.controller = (None if cfg.control == "static"
                           else make_policy(cfg.control,
                                            **(cfg.control_args or {})))
        self.renegotiations: dict[str, int] = {}
        self._uplink_over: dict[str, Pipeline] = {}
        self._down_over: dict[str, tuple[Pipeline, Packetizer]] = {}
        self._cfg_over: dict[str, TransportConfig] = {}
        if (self.controller is not None
                and not self.uplink_pipeline.self_describing):
            raise ValueError(
                "adaptive control renegotiates the uplink in-band via the "
                "self-describing WireHeader; set transport.uplink to a "
                "pipeline spec (legacy codec mode cannot renegotiate)")

        self.history: list[RoundResult] = []
        self.on_round_end: Optional[Callable[[RoundResult, Any], None]] = None

        # Transport dispatch goes through the registry: the core has no
        # per-protocol branches, so new transports plug in unchanged.  Under
        # the flow engine the transport is swapped for its analytic model
        # (same name/caps surface — see repro.core.flow).
        self.transport: Transport = maybe_flow(
            sim, make_transport(cfg.transport.kind))

        # Persistent receivers.
        self._server_rx = self.transport.create_receiver(
            sim, self.server_node, cfg.transport, self._on_server_delivery)
        self._client_rx: dict[str, object] = {}
        for c in clients:
            self.install_client_rx(c)

        self.scheduler = None            # bound by FederatedSystem
        # Topology hook (repro.core.topology): when set, a delivered
        # downlink triggers this callable instead of schedule_training —
        # the hierarchical topology uses it to run a whole edge-cell round
        # as one "training" step of the parent tier.  The override owes the
        # core an eventual send_update()/uplink_update() on the session (or
        # a session failure), exactly like the default path.
        self.train_override: Optional[Callable[[ClientSession], None]] = None
        # Optional repro.core.client_compute.BatchTrainer: when attached,
        # schedule_training submits each session's delivered model
        # immediately and collects the (batched) result when its timer
        # fires.  None = the historical per-client train_fn path, pinned
        # by the replay digests.
        self.batch_trainer: Optional[Any] = None
        # Session registries: uplink keyed by (client addr, txn_up) — the
        # server-side delivery identity — and downlink by (client addr,
        # txn_down) — the client-receiver identity.  Sync scheduling reuses
        # one (txn_down, txn_up) pair across a whole round, so values may be
        # shared; the addr component keeps lookups unambiguous.
        self._sessions_up: dict[tuple[str, int], ClientSession] = {}
        self._sessions_down: dict[tuple[str, int], ClientSession] = {}
        self._txn_counter = 0
        # Stragglers from closed sync rounds: (virtual round, addr, vec).
        # With batch_wire the third element may be a still-encoded
        # _PendingWire, resolved at the aggregation it folds into.
        self.late_buffer: list[tuple[int, str, Any]] = []
        # Monotonic retransmission counter (sender stats folded in on
        # completion or failure); schedulers snapshot + delta per window.
        self.retx_total = 0

    def bind(self, scheduler) -> None:
        self.scheduler = scheduler

    # -- global model + cached size -------------------------------------------
    @property
    def global_params(self) -> Any:
        return self._global_params

    @global_params.setter
    def global_params(self, value: Any) -> None:
        # Invalidate the cached flat size: recomputed at most once per
        # assignment (i.e. per aggregation) instead of once per uplink
        # delivery — a full pytree flatten used to sit on the hot path.
        # The broadcast-encode cache rides the same invalidation: any model
        # update (aggregation, external assignment) drops the cached bytes,
        # so a stale broadcast can never be served.
        self._global_params = value
        self._n_params: Optional[int] = None
        self._bcast_cache: Optional[bytes] = None

    @property
    def n_params(self) -> int:
        if self._n_params is None:
            self._n_params = int(flatten_to_vector(self._global_params).size)
        return self._n_params

    # -- per-client effective wire plane --------------------------------------
    # Renegotiated clients (repro.core.control) override the base pipeline
    # per address; everyone else falls through to the base objects, so the
    # static path allocates nothing and behaves bit-identically.
    def uplink_pipeline_for(self, addr: str) -> Pipeline:
        return self._uplink_over.get(addr, self.uplink_pipeline)

    def downlink_pipeline_for(self, addr: str) -> Pipeline:
        over = self._down_over.get(addr)
        return over[0] if over is not None else self.downlink_pipeline

    def packetizer_for(self, addr: str) -> Packetizer:
        over = self._down_over.get(addr)
        return over[1] if over is not None else self.packetizer

    def transport_cfg_for(self, addr: str) -> TransportConfig:
        return self._cfg_over.get(addr, self.cfg.transport)

    # -- per-client wire state -------------------------------------------------
    def wire_state(self, addr: str, *, direction: str) -> \
            Optional[PipelineState]:
        """The persistent PipelineState for one client's encode side of
        ``direction`` ("uplink": the client's encoder; "downlink": the
        server's per-client broadcast encoder).  None when that pipeline is
        stateless (nothing to persist).  Decode is stateless for every
        built-in stage."""
        pipeline, table = {
            "uplink": (self.uplink_pipeline_for(addr), self._up_enc_state),
            "downlink": (self.downlink_pipeline_for(addr),
                         self._down_enc_state),
        }[direction]
        if not pipeline.caps.stateful:
            return None
        state = table.get(addr)
        if state is None:
            state = table[addr] = pipeline.new_state()
        return state

    # -- adaptive control ------------------------------------------------------
    def apply_control(self, addr: str) -> bool:
        """Consult the bound control policy for one client (schedulers call
        this between transactions: sync at round start, async at session
        entry).  Returns True when something actually changed."""
        if self.controller is None:
            return False
        decision = self.controller.renegotiate(
            addr, self.telemetry.snapshot(addr),
            self.transport_cfg_for(addr))
        if decision is None:
            return False
        return self._apply_decision(addr, decision)

    def _apply_decision(self, addr: str, decision) -> bool:
        """Install one :class:`repro.core.control.ControlDecision`.

        No-op decisions (every field already at its target) are filtered
        here, so policies may return their target config unconditionally
        and only real changes count as renegotiations.  The new config
        revalidates through ``dataclasses.replace`` (spec parse + dry-run
        probe), pipeline swaps migrate encoder state under the
        :func:`repro.core.wire.migrate_state` rules (or reset it when the
        decision says so), and the aggregation domain is frozen: a policy
        that flips delta-ness would silently corrupt aggregation, so it is
        refused loudly.
        """
        cur = self.transport_cfg_for(addr)
        changes = {f: v for f in ("uplink", "downlink",
                                  "fec_block", "fec_parity")
                   if (v := getattr(decision, f)) is not None
                   and v != getattr(cur, f)}
        if not changes:
            return False
        new_cfg = dataclasses.replace(cur, **changes)
        if "uplink" in changes:
            new_pipe = parse_pipeline(new_cfg.uplink)
            if (new_pipe.caps.delta_domain
                    != self.uplink_pipeline.caps.delta_domain):
                raise ValueError(
                    f"control policy renegotiated {addr} to "
                    f"{new_cfg.uplink!r}, which flips the aggregation "
                    f"domain (delta vs weight) — policies must keep every "
                    f"tier in the configured domain")
            self._swap_state(addr, self._up_enc_state,
                             self.uplink_pipeline_for(addr), new_pipe,
                             reset=decision.reset_state)
            self._uplink_over[addr] = new_pipe
        if "downlink" in changes:
            if not self.downlink_pipeline.self_describing:
                raise ValueError(
                    "control policy renegotiated the downlink, but the "
                    "base downlink is a legacy (headerless) codec — the "
                    "client decodes those out-of-band and cannot follow "
                    "an in-band swap")
            new_down = parse_pipeline(new_cfg.downlink)
            self._swap_state(addr, self._down_enc_state,
                             self.downlink_pipeline_for(addr), new_down,
                             reset=decision.reset_state)
            self._down_over[addr] = (
                new_down, Packetizer(pipeline=new_down, mtu=new_cfg.mtu))
        self._cfg_over[addr] = new_cfg
        self.renegotiations[addr] = self.renegotiations.get(addr, 0) + 1
        return True

    def _swap_state(self, addr: str, table: dict, old_pipe: Pipeline,
                    new_pipe: Pipeline, *, reset: bool) -> None:
        """Re-key one client's encoder state for a renegotiated pipeline:
        migrate (EF residual / delta reference carry over) or reset."""
        if reset:
            state = new_pipe.new_state() if new_pipe.caps.stateful else None
        else:
            state = migrate_state(old_pipe, table.get(addr), new_pipe)
        if state is None:
            table.pop(addr, None)
        else:
            table[addr] = state

    # -- receiver plumbing ---------------------------------------------------
    def install_client_rx(self, client: FLClient) -> None:
        self._client_rx[client.addr] = self.transport.create_receiver(
            self.sim, self.sim.node(client.addr), self.cfg.transport,
            self._make_client_deliver(client))

    def remove_client(self, addr: str) -> None:
        """Elastic removal: drop pool membership AND the client's wire
        state — a later client at a recycled address must start with a
        clean delta reference / EF residual, not the dead client's."""
        self.pool.remove(addr)
        self._up_enc_state.pop(addr, None)
        self._down_enc_state.pop(addr, None)
        # Control-plane identity is per-address too: telemetry history,
        # renegotiated overrides and counters all die with the client.
        self.telemetry.forget(addr)
        self._uplink_over.pop(addr, None)
        self._down_over.pop(addr, None)
        self._cfg_over.pop(addr, None)
        self.renegotiations.pop(addr, None)

    # -- session management --------------------------------------------------
    def new_txn_pair(self) -> tuple[int, int]:
        """A fresh session-scoped (txn_down, txn_up) pair.  Starts above any
        round-scoped numbering so a mode switch can never collide."""
        sid = self._txn_counter
        self._txn_counter += 1
        return 2 * sid, 2 * sid + 1

    def reserve_txns(self, txn: int) -> None:
        """Keep session-scoped numbering above ``txn`` (sync rounds use
        round-derived pairs; async continues past them)."""
        self._txn_counter = max(self._txn_counter, txn // 2 + 1)

    def open_session(self, client: FLClient, round_idx: int,
                     txn_down: int, txn_up: int,
                     model_version: int = 0) -> ClientSession:
        s = ClientSession(client, round_idx, txn_down, txn_up,
                          model_version=model_version,
                          started_ns=self.sim.now_ns)
        self._sessions_down[(client.addr, txn_down)] = s
        self._sessions_up[(client.addr, txn_up)] = s
        self.reserve_txns(max(txn_down, txn_up))
        return s

    def clear_sessions(self) -> None:
        """Drop every session registration (sync: called at round start so
        stale traffic from a finished round can no longer match)."""
        self._sessions_up.clear()
        self._sessions_down.clear()

    def drop_session(self, session: ClientSession) -> None:
        self._sessions_down.pop((session.addr, session.txn_down), None)
        self._sessions_up.pop((session.addr, session.txn_up), None)

    def uplink_session(self, addr: str, txn: int) -> Optional[ClientSession]:
        return self._sessions_up.get((addr, txn))

    # -- downlink: server -> client -------------------------------------------
    def broadcast_payload(self) -> Optional[bytes]:
        """The current model's encoded broadcast bytes, cached per model
        version — or None when per-client encoding is required.

        A stateless downlink pipeline encodes the same model to the same
        bytes for every client (deterministic, pinned by wire_bench's
        determinism gate), so the N-client broadcast encodes **once** and
        reuses the bytes.  The cache is refused outright when the downlink
        pipeline is stateful (``PipelineCaps.stateful`` — e.g. ``ef|int8``
        compensates each client separately, so sharing bytes would corrupt
        per-client residuals) and invalidated on every ``global_params``
        assignment, so a stale model can never be served.
        """
        if not self.cfg.batch_wire or self.downlink_pipeline.caps.stateful:
            return None
        if self._bcast_cache is None:
            self._bcast_cache = self.packetizer.encode_bytes(
                self.global_params)
        else:
            self.bcast_cache_hits += 1
        return self._bcast_cache

    def begin_downlink(self, session: ClientSession) -> None:
        """Broadcast the current global model to the session's client
        through the downlink pipeline (per-client state: a stateful
        downlink, e.g. ``ef|int8``, compensates each client separately —
        such pipelines bypass the broadcast cache)."""
        session.state = DOWNLINK
        packetizer = self.packetizer_for(session.addr)
        # A renegotiated downlink encodes per client (its bytes differ
        # from the broadcast), so it bypasses the cache without charging a
        # spurious hit.
        data = (self.broadcast_payload()
                if session.addr not in self._down_over else None)
        if data is not None:
            packets = packetize(data, self.server_addr, session.txn_down,
                                packetizer.mtu)
        else:
            packets = packetizer.to_packets(
                self.global_params, self.server_addr, session.txn_down,
                state=self.wire_state(session.addr, direction="downlink"))
        self._make_sender(self.server_node,
                          self.sim.node(session.addr), packets,
                          session).start()

    def begin_local(self, session: ClientSession) -> None:
        """Skip the downlink (broadcast_model=False): hand the client the
        global model by reference and schedule training."""
        session.client.params = self.global_params
        self.begin_training_for(session)

    def _make_client_deliver(self, client: FLClient):
        def _cb(d: Delivery) -> None:
            session = self._sessions_down.get((client.addr, d.txn))
            if session is None or not self.scheduler.accept_downlink(session):
                return
            if d.complete:
                client.params = self.packetizer_for(
                    client.addr).from_packets(d.packets, self.global_params)
            else:
                # Best-effort downlink: the client trains on the zero-filled
                # model (Delivery.complete makes the gap explicit instead of
                # silently treating a partial broadcast as the full model).
                vec = self.decode_vec(d.reassemble(), direction="downlink",
                                      addr=client.addr)
                client.params = unflatten_from_vector(vec, self.global_params)
            self.begin_training_for(session)
        return _cb

    # -- local training ------------------------------------------------------
    def begin_training_for(self, session: ClientSession) -> None:
        """A delivered (or locally handed) model starts the session's
        training step: the default timer-driven ``train_fn`` call, or the
        topology's ``train_override`` (e.g. a nested edge-cell round)."""
        if self.train_override is not None:
            self.train_override(session)
        else:
            self.schedule_training(session)

    def schedule_training(self, session: ClientSession) -> None:
        session.state = TRAINING
        client = session.client

        if self.batch_trainer is not None:
            # The training input is fully known *now* (the model was just
            # delivered); only the result is deferred by the timer.  Submit
            # immediately so the trainer can run every pending session as
            # one vmapped batch, and collect at the timer — the result is
            # deterministic and per-client independent, so batching cannot
            # perturb any event time or order.
            trainer = self.batch_trainer
            key = id(session)
            trainer.submit(key, client.addr, client.params,
                           session.round_idx)

            def _batched_done() -> None:
                received, new_params, metrics = trainer.collect(key)
                client.metrics_history.append(metrics)
                client.params = new_params
                self.uplink_update(session, received, new_params)
            self.sim.schedule(client.train_time_ns, _batched_done)
            return

        def _train_done() -> None:
            received = client.params
            new_params, metrics = client.train_fn(
                received, session.round_idx, client)
            client.metrics_history.append(metrics)
            client.params = new_params
            self.uplink_update(session, received, new_params)
        self.sim.schedule(client.train_time_ns, _train_done)

    def uplink_update(self, session: ClientSession, received: Any,
                      new_params: Any) -> None:
        """Finish a training step: prime the uplink delta reference with
        the model the client trained *from* and ship the result.  Shared by
        the default timer path and topology train overrides."""
        pipeline = self.uplink_pipeline_for(session.addr)
        if pipeline.caps.delta_domain:
            # Prime the delta stage's reference: the model this client
            # just trained from.  The subtraction itself happens inside
            # the pipeline, not here.
            pipeline.set_reference(
                self.wire_state(session.addr, direction="uplink"),
                flatten_to_vector(received))
        self.send_update(session, new_params)

    # -- uplink: client -> server -------------------------------------------
    def send_update(self, session: ClientSession, payload_tree: Any) -> None:
        """Ship ``payload_tree`` through the uplink pipeline.  Delta
        shipping and error-feedback are pipeline stages; their state
        (reference model, residual) lives in this client's persistent
        PipelineState, not here."""
        session.state = UPLINK
        client = session.client
        vec = flatten_to_vector(payload_tree)
        data = self.uplink_pipeline_for(client.addr).encode(
            vec, self.wire_state(client.addr, direction="uplink"))
        packets = packetize(data, client.addr, session.txn_up,
                            self.packetizer.mtu)
        node = self.sim.node(client.addr)
        self._make_sender(node, self.server_node, packets, session).start()

    def _make_sender(self, src, dst, packets, session: ClientSession):
        addr = session.addr
        payload_bytes = sum(len(p.payload) for p in packets)
        n_packets = len(packets)

        def _observe(sender, completed: bool) -> None:
            # Telemetry feed: pure bookkeeping off the sender's TxnStats
            # (every engine — per_packet, batched, flow — fills the same
            # shape; getattr keeps third-party senders safe).  No events,
            # no RNG, no sim.stats: recording cannot move a digest.
            self._note_retx(sender)
            stats = getattr(sender, "stats", None)
            now = self.sim.now_ns
            start = getattr(stats, "start_ns", 0) if stats else 0
            end = getattr(stats, "end_ns", 0) if stats else 0
            duration = max(0, (end or now) - start) if start else 0
            self.telemetry.observe_txn(
                addr, now_ns=now, duration_ns=duration,
                data_sent=(getattr(stats, "data_sent", 0) or n_packets)
                if stats else n_packets,
                retransmissions=getattr(stats, "retransmissions", 0)
                if stats else 0,
                payload_bytes=payload_bytes, completed=completed)

        def _done(sender) -> None:
            _observe(sender, True)

        def _fail(sender) -> None:
            _observe(sender, False)
            self.scheduler.on_session_failed(session)
        return self.transport.create_sender(
            self.sim, src, dst, packets, self.transport_cfg_for(addr),
            on_complete=_done, on_fail=_fail)

    def _note_retx(self, sender) -> None:
        self.retx_total += getattr(sender.stats, "retransmissions", 0)

    # -- server-side delivery --------------------------------------------------
    def _on_server_delivery(self, d: Delivery) -> None:
        if not d.complete and not self.transport.caps.partial_delivery:
            return  # a reliable transport never hands over a partial payload
        if self.cfg.batch_wire:
            # Defer the decode: schedulers store updates opaquely until
            # aggregation, where every pending payload of the window
            # decodes in one vectorized batch (decode is pure computation,
            # so deferring it cannot move an event).  One caveat, by
            # design: a payload the scheduler *drops* before aggregating
            # (async max_staleness) is never decoded, so a malformed one
            # no longer bumps decode_errors — it contributes nothing
            # either way.
            vec: Any = _PendingWire(d.reassemble(), d.sender_addr)
        else:
            vec = self.decode_vec(d.reassemble(), addr=d.sender_addr)
        session = self.uplink_session(d.sender_addr, d.txn)
        self.scheduler.on_uplink(session, d.sender_addr, d.txn, vec)

    def decode_vec(self, data: bytes, *, direction: str = "uplink",
                   addr: Optional[str] = None) -> np.ndarray:
        """Decode a (possibly zero-filled) byte stream to a model-sized
        vector through the named direction's pipeline.

        Self-describing payloads decode from their own WireHeader (the
        receiver trusts the wire, not out-of-band config).  A payload that
        cannot be decoded raises :class:`WireDecodeError` inside the wire
        layer and is degraded **explicitly** here: zero vector +
        ``decode_errors`` counter — the same capability-driven zero-fill a
        partial best-effort delivery gets.  Any other exception is a bug
        and propagates."""
        pipeline = (self.uplink_pipeline if direction == "uplink"
                    else self.downlink_pipeline)
        n_expected = self.n_params
        try:
            if pipeline.self_describing:
                vec, negotiated = wire_decode_payload(data)
                if (negotiated.caps.delta_domain
                        != pipeline.caps.delta_domain):
                    # Aggregation semantics are server policy: a header
                    # whose delta-ness disagrees with the configured
                    # pipeline would be silently mis-aggregated (a delta
                    # read as full weights or vice versa), so it is
                    # refused like any other malformed payload.
                    raise WireDecodeError(
                        f"negotiated pipeline {negotiated.spec!r} is "
                        f"{'delta' if negotiated.caps.delta_domain else 'weight'}"
                        f"-domain but this server aggregates in the "
                        f"{'delta' if pipeline.caps.delta_domain else 'weight'}"
                        f" domain")
            else:
                vec = pipeline.decode(data)
        except WireDecodeError:
            self.decode_errors += 1
            if addr is not None:
                self.telemetry.observe_decode_error(addr,
                                                    now_ns=self.sim.now_ns)
            vec = np.zeros(n_expected, dtype=np.float32)
        if vec.size < n_expected:
            vec = np.concatenate(
                [vec, np.zeros(n_expected - vec.size, dtype=np.float32)])
        return vec[:n_expected]

    def decode_vec_batch(self, datas: list[bytes],
                         addrs: Optional[list] = None) -> np.ndarray:
        """Batched :meth:`decode_vec` over uplink payloads: one ``(N,
        n_params)`` float32 matrix, row i bit-identical to
        ``decode_vec(datas[i])`` — including the per-item degradation
        contract: a malformed payload zero-fills *its* row and bumps
        ``decode_errors``; it never poisons the rest of the batch
        (``decode_payload_batch`` isolates it via per-item fallback).
        ``addrs`` (parallel to ``datas``, entries may be None) attributes
        degradations to the right client's telemetry."""
        n_expected = self.n_params
        pipeline = self.uplink_pipeline

        def _degrade(i: int) -> None:
            self.decode_errors += 1
            if addrs is not None and addrs[i] is not None:
                self.telemetry.observe_decode_error(
                    addrs[i], now_ns=self.sim.now_ns)

        out = np.zeros((len(datas), n_expected), dtype=np.float32)
        if pipeline.self_describing:
            for i, (vec, negotiated, err) in enumerate(
                    wire_decode_payload_batch(datas)):
                if err is None and (negotiated.caps.delta_domain
                                    != pipeline.caps.delta_domain):
                    # Same policy refusal as decode_vec: a header whose
                    # delta-ness disagrees with the server's aggregation
                    # domain is degraded, not mis-aggregated.
                    vec = None
                if vec is None:
                    _degrade(i)
                    continue
                m = min(vec.size, n_expected)
                out[i, :m] = vec[:m]
            return out
        for i, data in enumerate(datas):
            try:
                vec = pipeline.decode(data)
            except WireDecodeError:
                _degrade(i)
                continue
            m = min(vec.size, n_expected)
            out[i, :m] = vec[:m]
        return out

    def _resolve_contribs(self, contribs: list) -> list:
        """Materialize any deferred (_PendingWire) updates in ``contribs``
        through one batched decode; pass decoded vectors through
        untouched.  The stacked matrix rows stream straight into the
        aggregation stack below, so a 256-client round does one vectorized
        wire pass instead of 256 pipeline walks."""
        pending = [v for v, _ in contribs
                   if isinstance(v, _PendingWire) and v.vec is None]
        if pending:
            mat = self.decode_vec_batch([p.data for p in pending],
                                        [p.addr for p in pending])
            for p, row in zip(pending, mat):
                p.vec = row
                p.data = None     # the bytes are dead weight once decoded
        return [(v.vec if isinstance(v, _PendingWire) else v, w)
                for v, w in contribs]

    # -- staleness -----------------------------------------------------------
    def staleness_factor(self, age: int) -> tuple[float, bool]:
        """``discount**age`` clamped to ``staleness_floor``: a stale update
        is discounted, never silently zeroed out.  Returns (factor,
        clamped?)."""
        factor = self.cfg.staleness_discount ** age
        if factor < self.cfg.staleness_floor:
            return self.cfg.staleness_floor, True
        return factor, False

    def fold_late_buffer(self, current_round: int,
                         contribs: list) -> tuple[int, int]:
        """Append the late-update buffer to ``contribs`` with
        staleness-discounted weights; returns (folded, clamped) counts."""
        folded = clamped = 0
        for upd_round, addr, vec in self.late_buffer:
            age = max(1, current_round - upd_round)
            w, was_clamped = self.staleness_factor(age)
            client = self.pool.clients.get(addr)
            contribs.append((vec, w * (client.weight if client else 1.0)))
            folded += 1
            clamped += was_clamped
        self.late_buffer = []
        return folded, clamped

    # -- aggregation -----------------------------------------------------------
    def apply_aggregation(self, contribs: list) -> None:
        """Fold ``[(flat vector, weight), ...]`` into the global model —
        the exact pre-refactor math, shared by every scheduling policy.
        Whether contributions are deltas is a *wire* property now: the
        uplink pipeline's ``delta_domain`` capability (the legacy
        ``send_deltas`` flag derives it)."""
        if not contribs:
            return
        # Batched wire-plane: updates arrive still-encoded (_PendingWire)
        # under batch_wire; decode them all in one vectorized pass BEFORE
        # the zero-weight filter so decode_errors accounting matches the
        # per-delivery mode for every payload that reached aggregation.
        contribs = self._resolve_contribs(contribs)
        # An empty-handed hierarchical edge forwards its unchanged model
        # with weight 0 (so the parent barrier still resolves); such
        # contributions carry no information and an all-zero-weight fold
        # would divide by zero, so they are dropped up front.
        contribs = [(v, w) for v, w in contribs if w > 0.0]
        if not contribs:
            return
        template = self.global_params
        if self.uplink_pipeline.caps.delta_domain:
            vecs = [v for v, _ in contribs]
            ws = np.asarray([w for _, w in contribs], dtype=np.float32)
            mean_delta = sum(w * v for v, w in zip(vecs, ws)) / ws.sum()
            delta_tree = unflatten_from_vector(
                mean_delta.astype(np.float32), template)
            self.global_params = agg.apply_delta(
                template, delta_tree, self.cfg.server_lr)
            return

        if self.cfg.aggregation == "pairwise":
            # Paper Eq. 1: fold per arrival order.
            g = self.global_params
            for v, _ in contribs:
                g = agg.pairwise_average(g, unflatten_from_vector(v, template))
            self.global_params = g
        elif self.cfg.aggregation == "fedavg":
            # Contributions are already flat wire vectors: aggregate the
            # stack directly and unflatten once.  Bit-identical to the old
            # per-leaf tree fold (fedavg_stack's numpy path accumulates in
            # the same order/dtype), so the replay digests are unchanged.
            stack = np.stack([v for v, _ in contribs])
            vec = agg.fedavg_stack(stack, [w for _, w in contribs],
                                   backend=self.cfg.aggregation_backend)
            self.global_params = unflatten_from_vector(
                vec.astype(np.float32, copy=False), template)
        elif self.cfg.aggregation == "trimmed_mean":
            self.global_params = agg.trimmed_mean(
                [unflatten_from_vector(v, template) for v, _ in contribs])
        else:
            raise ValueError(f"unknown aggregation {self.cfg.aggregation}")

    # -- result plumbing -------------------------------------------------------
    def snapshot_stats(self) -> dict:
        return dict(self.sim.stats)

    def stats_delta(self, stats0: dict) -> dict:
        s1 = self.sim.stats
        return {
            "bytes_sent": s1["bytes_sent"] - stats0["bytes_sent"],
            "packets_sent": s1["packets_sent"] - stats0["packets_sent"],
            "packets_dropped": (s1["packets_dropped"]
                                - stats0["packets_dropped"]),
            "data_packets": s1.get("sent_data", 0) - stats0.get("sent_data", 0),
            "nack_packets": s1.get("sent_nack", 0) - stats0.get("sent_nack", 0),
            "parity_packets": (s1.get("sent_parity", 0)
                               - stats0.get("sent_parity", 0)),
        }

    def emit_result(self, result: RoundResult) -> RoundResult:
        self.history.append(result)
        if self.on_round_end is not None:
            self.on_round_end(result, self.global_params)
        return result
