"""Forward-error-corrected Modified UDP (``mudp+fec``).

One concrete "Optimization of the Modified UDP" from the paper's future-work
section: the sender appends ``k`` XOR-parity packets per block of ``B`` data
packets, so the receiver repairs isolated losses *forward* — without the
NACK round-trip and retransmission MUDP would otherwise pay.  On a lossy WAN
link this trades a fixed ~1/B bandwidth overhead for fewer retransmissions
and lower tail latency.

Scheme: the ``B`` data packets of a block are split round-robin into ``k``
interleaved groups and each group gets one XOR parity packet, so up to ``k``
isolated losses per block (in distinct groups) are repairable.  Parity
packets are self-describing — the payload carries ``(data_total, B, k)`` plus
the true payload length of every covered packet — so the receiver can rebuild
a missing packet (and its checksum) exactly.

Loss patterns FEC cannot repair (two losses in one group, or a lost parity
packet) fall back to the inherited MUDP NACK machinery: the receiver defers
gap reporting for one timer period while parity is still outstanding, then
NACKs whatever is still missing.

This module is deliberately built ONLY on the public transport API
(:mod:`repro.core.transport`) plus the exported MUDP state machines — it is
the worked proof that a new protocol plugs into the FL harness, benchmarks,
and examples without touching the orchestrator.
"""

from __future__ import annotations

import struct
from typing import Optional

from repro.core.mudp import MudpReceiver, MudpSender, _RxState
from repro.core.packets import (HEADER_BYTES, Packet, PacketKind, checksum32,
                                make_data_packet)
from repro.core.transport import (Transport, TransportCaps, adapt_full_delivery,
                                  register_transport)

_PARITY_HEAD = struct.Struct("!IHH")   # data_total, fec_block, fec_parity
_U32 = struct.Struct("!I")


# --------------------------------------------------------------------------
# Block / group geometry (pure functions; sender and receiver must agree,
# which the self-describing parity header guarantees)
# --------------------------------------------------------------------------
def parity_groups(data_total: int, block: int, k: int) -> list[list[int]]:
    """Sequence numbers covered by each parity packet, in send order."""
    groups: list[list[int]] = []
    for b0 in range(0, data_total, block):
        seqs = list(range(b0 + 1, min(b0 + block, data_total) + 1))
        kk = min(k, len(seqs))
        groups.extend(seqs[g::kk] for g in range(kk))
    return groups


def expected_parity_count(data_total: int, block: int, k: int) -> int:
    return len(parity_groups(data_total, block, k))


def make_parity_packet(parity_seq: int, n_parity: int, group: list[int],
                       data_packets: dict[int, Packet], addr: str, txn: int,
                       data_total: int, block: int, k: int) -> Packet:
    """XOR the group's payloads (zero-padded to the longest) into one packet."""
    lens = [len(data_packets[s].payload) for s in group]
    width = max(lens)
    acc = 0
    for s in group:
        acc ^= int.from_bytes(data_packets[s].payload.ljust(width, b"\x00"),
                              "big")
    payload = (_PARITY_HEAD.pack(data_total, block, k)
               + b"".join(_U32.pack(n) for n in lens)
               + acc.to_bytes(width, "big"))
    return Packet(PacketKind.PARITY, parity_seq, n_parity, addr, txn,
                  payload, checksum32(payload))


def parse_parity_packet(pkt: Packet) -> tuple[list[int], list[int], int, int]:
    """Return (covered seqs, their true lengths, xor value, xor width)."""
    data_total, block, k = _PARITY_HEAD.unpack_from(pkt.payload, 0)
    covered = parity_groups(data_total, block, k)[pkt.seq - 1]
    off = _PARITY_HEAD.size
    lens = [_U32.unpack_from(pkt.payload, off + 4 * i)[0]
            for i in range(len(covered))]
    off += 4 * len(covered)
    width = len(pkt.payload) - off
    return covered, lens, int.from_bytes(pkt.payload[off:], "big"), width


# --------------------------------------------------------------------------
# Sender: MUDP + a parity trailer after the data burst
# --------------------------------------------------------------------------
class FecMudpSender(MudpSender):
    """MUDP sender that follows the data burst with XOR parity packets.

    The NACK/timer recovery path is inherited unchanged — FEC only reduces
    how often it is exercised.
    """

    def __init__(self, sim, node, dest, packets, *,
                 fec_block: int = 8, fec_parity: int = 1, **kwargs):
        super().__init__(sim, node, dest, packets, **kwargs)
        self.fec_block = max(1, fec_block)
        # parity 0 is a valid runtime setting (the adaptive control plane
        # drops the FEC trailer entirely for clean links): the sender
        # degenerates to plain MUDP for this transaction.
        self.fec_parity = max(0, fec_parity)

    def start(self) -> None:
        super().start()   # data burst + timer; no sim time elapses in between
        if self.fec_parity == 0:
            return        # no trailer: plain MUDP recovery only
        groups = parity_groups(self.total, self.fec_block, self.fec_parity)
        trailer = [
            make_parity_packet(i + 1, len(groups), group, self.packets,
                               self.node.addr, self.txn, self.total,
                               self.fec_block, self.fec_parity)
            for i, group in enumerate(groups)
        ]
        self.stats.parity_sent += len(trailer)
        # The parity trailer queues behind the data flight on the same FIFO
        # link — a second burst, vectorized under the batched engine.
        self.node.send_burst(trailer, self.dest)


# --------------------------------------------------------------------------
# Receiver: repair from parity before falling back to NACKs
# --------------------------------------------------------------------------
class FecMudpReceiver(MudpReceiver):
    """MUDP receiver that reconstructs isolated losses from XOR parity.

    Gap reporting is deferred while parity packets are still expected (they
    trail the data burst on the FIFO link): a transaction whose every gap is
    repairable completes with ZERO NACKs.  If parity itself is lost, one
    grace timer period later the inherited NACK machinery takes over.
    """

    def __init__(self, sim, node, *, fec_block: int = 8, fec_parity: int = 1,
                 **kwargs):
        super().__init__(sim, node, **kwargs)
        self.fec_block = max(1, fec_block)
        # parity 0: never expect a trailer, never defer gap reports.
        self.fec_parity = max(0, fec_parity)
        self.stats_repairs = 0
        # key -> {parity_seq: (covered, lens, xor, width)}
        self._parity: dict[tuple[str, int],
                           dict[int, tuple[list[int], list[int], int, int]]] = {}
        # key -> n_parity as declared by the sender (any parity pkt's total);
        # until one arrives we estimate from our own config.
        self._n_parity: dict[tuple[str, int], int] = {}
        self._graced: set[tuple[str, int]] = set()

    # -- packet dispatch --------------------------------------------------
    def _ingest_run(self, pkts: list, i: int, j: int, arrivals: list) -> int:
        """Bulk ingestion is only safe while no parity has arrived for the
        transaction: once it has, every DATA arrival must run the repair
        hook in :meth:`_on_packet`, so the rest of the flight permanently
        falls back to the per-packet path."""
        p0 = pkts[i]
        if (p0.kind == PacketKind.DATA
                and self._parity.get((p0.addr, p0.txn))):
            return -1
        return super()._ingest_run(pkts, i, j, arrivals)

    def _on_packet(self, pkt: Packet) -> bool:
        if pkt.kind == PacketKind.PARITY:
            self._on_parity(pkt)
            return True
        consumed = super()._on_packet(pkt)
        if consumed and pkt.kind == PacketKind.DATA:
            key = (pkt.addr, pkt.txn)
            st = self._rx.get(key)
            if st is not None and key in self._parity:
                self._repair(key, st)
        return consumed

    def _on_parity(self, pkt: Packet) -> None:
        key = (pkt.addr, pkt.txn)
        if key in self._completed or not pkt.verify():
            return
        self._parity.setdefault(key, {})[pkt.seq] = parse_parity_packet(pkt)
        self._n_parity[key] = pkt.total
        st = self._rx.get(key)
        if st is None:
            return
        self._repair(key, st)
        st = self._rx.get(key)
        if st is None:                      # repair completed the delivery
            return
        if st.saw_last and not self._parity_outstanding(key, st):
            # Every parity packet arrived and gaps remain: FEC cannot help
            # any further, fall back to NACKs immediately.
            if not self._try_deliver(key, st):
                MudpReceiver._report_gaps(self, key, st)

    # -- forward repair ----------------------------------------------------
    def _repair(self, key: tuple[str, int], st: _RxState) -> None:
        for covered, lens, xor, width in self._parity.get(key, {}).values():
            missing = [s for s in covered if s not in st.received]
            if len(missing) != 1:
                continue
            seq = missing[0]
            acc = xor
            for s in covered:
                if s != seq:
                    acc ^= int.from_bytes(
                        st.received[s].payload.ljust(width, b"\x00"), "big")
            payload = acc.to_bytes(width, "big")[:lens[covered.index(seq)]]
            self.stats_repairs += 1
            if self.sim.trace:
                self.sim.log(f"t={self.sim.now_ns}ns {self.node.addr}: FEC "
                             f"repaired missing packet ({seq}, {st.total}, "
                             f"{st.sender_addr}) from parity")
            # Inject through the inherited machinery so delivery/ACK logic
            # stays identical to a real arrival.
            MudpReceiver._on_packet(self, make_data_packet(
                seq, st.total, st.sender_addr, payload, key[1]))
            if key not in self._rx:         # delivery completed
                return

    def _parity_outstanding(self, key: tuple[str, int], st: _RxState) -> bool:
        # Sender truth once any parity packet arrived (its `total` field);
        # before that, estimate from local config (a mismatched sender can
        # cost at most one grace period, never a livelock).
        expected = self._n_parity.get(
            key, expected_parity_count(st.total, self.fec_block,
                                       self.fec_parity))
        return len(self._parity.get(key, {})) < expected

    # -- deferred gap reporting -------------------------------------------
    def _report_gaps(self, key: tuple[str, int], st: _RxState) -> None:
        if self._parity_outstanding(key, st) and key not in self._graced:
            # Parity packets trail the data burst: give them one timer
            # period to repair the gaps before spending NACKs.
            self._graced.add(key)
            if st.nack_timer is not None:
                st.nack_timer.cancel()
            st.nack_timer = self.sim.schedule(
                self.nack_timeout_ns, lambda: self._after_grace(key))
            return
        super()._report_gaps(key, st)

    def _after_grace(self, key: tuple[str, int]) -> None:
        st = self._rx.get(key)
        if st is not None and st.saw_last and not self._try_deliver(key, st):
            MudpReceiver._report_gaps(self, key, st)

    def _try_deliver(self, key: tuple[str, int], st: _RxState) -> bool:
        done = super()._try_deliver(key, st)
        if done:
            self._parity.pop(key, None)
            self._n_parity.pop(key, None)
            self._graced.discard(key)
        return done


# --------------------------------------------------------------------------
# Registration through the public API
# --------------------------------------------------------------------------
class FecMudpTransport(Transport):
    """MUDP + per-block XOR parity (fewer retransmissions on lossy links)."""

    name = "mudp+fec"
    caps = TransportCaps(reliable=True, partial_delivery=False,
                         has_handshake=False, supports_fail_cb=True,
                         concurrent_txns=True)

    def create_sender(self, sim, src, dst, packets, cfg, *,
                      on_complete=None, on_fail=None):
        return FecMudpSender(sim, src, dst, packets,
                             fec_block=cfg.fec_block,
                             fec_parity=cfg.fec_parity,
                             timeout_ns=cfg.timeout_ns,
                             max_retries=cfg.max_retries,
                             on_complete=on_complete, on_fail=on_fail)

    def create_receiver(self, sim, node, cfg, on_deliver):
        return FecMudpReceiver(sim, node,
                               fec_block=cfg.fec_block,
                               fec_parity=cfg.fec_parity,
                               nack_timeout_ns=cfg.timeout_ns,
                               max_nack_retries=cfg.max_retries,
                               on_deliver=adapt_full_delivery(on_deliver))


register_transport("mudp+fec", FecMudpTransport)


# --------------------------------------------------------------------------
# Flow-engine model (Simulator(engine="flow")) — see repro.core.flow
# --------------------------------------------------------------------------
def _fec_flow_model(ctx):
    """Analytic MUDP+FEC transaction: per-group loss draws, exact repairs.

    Per-packet losses are independent, so each parity group's loss count is
    an independent Binomial — drawn per group, which keeps the *joint*
    repair distribution exact: a group with exactly one loss is repaired
    iff its parity packet arrived; two or more losses (or a lost parity)
    fall through to the shared NACK-volley recursion.  Timing follows the
    packet receiver: repairs land at the parity trailer's arrival, a
    volley fires immediately when all parity arrived with gaps, and one
    grace timer period is paid when parity is still outstanding.
    """
    from repro.core.flow import (FlowOutcome, PH_LAST, PH_LOSS, PH_REORD,
                                 reorder_prob, spurious_reorder_nacks)
    from repro.core.mudp import (_mudp_flow_model, flow_ack_outcome,
                                 flow_recover, spurious_volley)
    cfg = ctx.cfg
    if cfg.fec_parity <= 0:
        # No trailer on the wire: the transaction is distributionally plain
        # MUDP (parity_groups is empty, so the packet engines send nothing
        # extra either).  Delegate so the flow engine stays equivalent.
        return _mudp_flow_model(ctx)
    n = ctx.total
    p = ctx.p
    st = ctx.stats
    st.data_sent += n
    _, last_arr = ctx.fwd.occupy(ctx.sim.now_ns, ctx.sizes)
    groups = parity_groups(n, cfg.fec_block, cfg.fec_parity)
    g = len(groups)
    # Per-group draws: data loss count, parity packet loss.
    k0 = kp = m_total = 0
    last_group = next(i for i, grp in enumerate(groups) if n in grp)
    l_last = unrep_last = 0
    for i, grp in enumerate(groups):
        li = ctx.binom(len(grp), p, PH_LOSS, 100 + i)
        pi_lost = ctx.uniform(PH_LOSS, 300 + i) < p
        k0 += li
        kp += 1 if pi_lost else 0
        unrep = li if (li >= 2 or (li == 1 and pi_lost)) else 0
        m_total += unrep
        if i == last_group:
            l_last, unrep_last = li, unrep
    last_lost = l_last > 0 and (
        ctx.uniform(PH_LAST, 0) < l_last / len(groups[last_group]))
    last_unrepaired = last_lost and unrep_last > 0 and (
        ctx.uniform(PH_LAST, 1) < unrep_last / l_last)
    dropped_bytes = ((k0 - 1) * ctx.chunk + ctx.sizes[-1] if last_lost
                     else k0 * ctx.chunk)
    ctx.count(ctx.fwd, PacketKind.DATA, n, ctx.data_bytes, k0, dropped_bytes)
    # Parity trailer: same FIFO link, queued behind the data flight.  Sizes
    # come from the group geometry alone — no packets are built.
    payload_of = {n: ctx.sizes[-1] - HEADER_BYTES}
    chunk_payload = ctx.chunk - HEADER_BYTES
    parity_sizes = [
        HEADER_BYTES + _PARITY_HEAD.size + 4 * len(grp)
        + max(payload_of.get(s, chunk_payload) for s in grp)
        for grp in groups
    ]
    st.parity_sent += g
    _, parity_arr = ctx.fwd.occupy(ctx.sim.now_ns, parity_sizes)
    ctx.count(ctx.fwd, PacketKind.PARITY, g, sum(parity_sizes),
              kp, kp * (sum(parity_sizes) // g))
    premature = False
    if not last_lost and kp == 0:
        # Jitter can land the whole parity trailer *before* the last data
        # packet.  The receiver's gap check runs ahead of its repair hook,
        # so at the last-data arrival it sees "all parity in, gaps remain"
        # and NACKs the whole gap set immediately — in-flight interiors
        # and packets parity rebuilds a moment later included.  Gate on
        # the trailer-first probability (bounded by the last parity
        # packet's send gap, the binding ordering constraint).
        trailer_gap = sum(ctx.fwd.link.serialization_ns(s)
                          for s in parity_sizes)
        gate = reorder_prob(ctx.fwd.link.jitter_ns, trailer_gap)
        premature = gate > 0.0 and ctx.uniform(PH_REORD, 0) < gate
        if premature:
            # Duplicate wire traffic: reordered in-flight survivors plus
            # the repairable losses (repair restores them right after the
            # NACKs left).  Unrepairable gaps ride the same volley, but
            # theirs is real recovery — flow_recover models it from
            # last_arr with no grace wait.
            m_s = spurious_reorder_nacks(ctx, trailer_gap_ns=trailer_gap,
                                         phase_base=64)
            m_s += k0 - m_total
            if m_total == 0:
                # Repair completes delivery at the same arrival, so the
                # ACK_OK departs right behind the NACKs and the jittered
                # reverse path decides which reaches the sender first —
                # a NACK that loses the race is never acted on.
                from repro.core.flow import CONTROL_BYTES as CB
                act_p = 1.0 - reorder_prob(
                    ctx.rev.link.jitter_ns, ctx.rev.link.serialization_ns(CB))
            else:
                act_p = 1.0
            spurious_volley(ctx, m_s, last_arr, act_p=act_p)
    m = m_total - (1 if last_unrepaired else 0)
    if last_unrepaired:
        # Receiver stays silent until a keepalive duplicate of the last
        # packet arrives; flow_recover models that sender-timer phase.
        completed, t_done = flow_recover(
            ctx, m=m, last_seen=False, t_last=last_arr,
            timeout_ns=cfg.timeout_ns, max_retries=cfg.max_retries,
            retain_p=p)
    else:
        # Last packet seen: directly, or rebuilt when its parity landed.
        base = last_arr if not last_lost else parity_arr
        if premature:
            # The trailer beat the last data packet: gap check, volley and
            # repairs all happened at the last-data arrival.
            t0 = last_arr
        elif m_total == 0:
            # Delivery at the last arrival, or at the parity trailer when
            # repairs were needed to complete.
            t0 = base if k0 == 0 else max(base, parity_arr)
        elif kp == 0:
            t0 = parity_arr       # all parity in, gaps remain: NACK now
        else:
            # Grace while parity is outstanding: the receiver defers
            # silently, so no control packet ever re-arms the sender
            # keepalive — it fires first (start + timeout, before the
            # grace timer armed at the last-data arrival) and its
            # duplicate last packet is what triggers the volley,
            # spending one last-packet retry on the way.
            st.last_packet_retries += 1
            st.retransmissions += 1
            st.data_sent += 1
            dup_lost = ctx.uniform(PH_LAST, 2) < p
            last_size = ctx.sizes[-1]
            _, t_dup = ctx.fwd.occupy(ctx.sim.now_ns + cfg.timeout_ns,
                                      [last_size])
            ctx.count(ctx.fwd, PacketKind.DATA, 1, last_size,
                      1 if dup_lost else 0, last_size if dup_lost else 0)
            # If the duplicate is lost too, the receiver's grace timer
            # (armed at the last-data arrival) fires shortly after.
            t0 = t_dup if not dup_lost else base + cfg.timeout_ns
        completed, t_done = flow_recover(
            ctx, m=m, last_seen=True, t_last=t0,
            timeout_ns=cfg.timeout_ns, max_retries=cfg.max_retries,
            retain_p=p)
    if not completed:
        return FlowOutcome(end_ns=t_done, completed=False)
    return flow_ack_outcome(ctx, t_done)


from repro.core import flow as _flow  # noqa: E402  (registration at bottom)

_flow.register_flow_model("mudp+fec", _fec_flow_model)
