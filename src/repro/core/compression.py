"""Legacy payload codecs: the single-stage wire formats.

The paper hex-encodes each weight before packetizing (lossless, 2x inflation).
We keep that as the faithful codec and add the production codecs a
thousand-node deployment needs: raw bytes (lossless, 1x), blockwise int8
quantization (4x smaller, lossy), and top-k sparsification.

These classes define the **headerless wire layouts** that
``TransportConfig(codec=...)`` has always produced; the composable wire
plane (``repro.core.wire``) re-expresses each as a single-stage pipeline
(byte-identical on this path) and composes them with ``delta``/``ef``
stages and self-describing headers — see ``docs/WIRE.md``.

All codecs operate on a flat float32 vector — the packetizer owns
pytree<->vector conversion, and the Pallas ``quantize`` kernel accelerates the
int8 path on TPU (``repro.kernels.quantize.ops``); here we keep a pure-numpy
implementation so the transport layer never requires a device.
"""

from __future__ import annotations

import binascii
import dataclasses
import struct

import numpy as np

_U32 = struct.Struct("!I")
_U64 = struct.Struct("!Q")

#: Upper bound on a *declared* (wire-supplied) vector length a decoder will
#: allocate for.  The sparse formats size their output from a header field,
#: not from the bytes actually present, so without a cap one crafted
#: payload can demand a u32-limit (~17 GiB) zero vector.  2**28 params
#: (1 GiB of float32) is far above any model this simulator ships; raise it
#: module-wide if you legitimately need more.
MAX_DECODE_PARAMS = 1 << 28


class Codec:
    """bytes <-> flat float32 vector."""

    name: str = "abstract"
    lossless: bool = True

    def encode(self, vec: np.ndarray) -> bytes:  # pragma: no cover
        raise NotImplementedError

    def decode(self, data: bytes) -> np.ndarray:  # pragma: no cover
        raise NotImplementedError


class RawCodec(Codec):
    """Little-endian float32 bytes. 4 bytes/param."""

    name = "raw"
    lossless = True

    def encode(self, vec: np.ndarray) -> bytes:
        return np.ascontiguousarray(vec, dtype="<f4").tobytes()

    def decode(self, data: bytes) -> np.ndarray:
        return np.frombuffer(data, dtype="<f4").copy()


class HexCodec(Codec):
    """The paper's codec: each weight converted to a hexadecimal
    representation (Algorithm I, `ConvertToHex`). 8 bytes/param."""

    name = "hex"
    lossless = True

    def encode(self, vec: np.ndarray) -> bytes:
        return binascii.hexlify(np.ascontiguousarray(vec, dtype="<f4").tobytes())

    def decode(self, data: bytes) -> np.ndarray:
        return np.frombuffer(binascii.unhexlify(data), dtype="<f4").copy()


# --------------------------------------------------------------------------
# Blockwise int8 quantization (absmax per block) — beyond-paper compression.
# --------------------------------------------------------------------------
def quantize_int8(vec: np.ndarray, block: int = 1024
                  ) -> tuple[np.ndarray, np.ndarray]:
    """Return (int8 values, float32 per-block scales). Mirrors
    ``repro.kernels.quantize.ref`` — the kernel's oracle calls this."""
    vec = np.asarray(vec, dtype=np.float32)
    n = vec.size
    nb = -(-n // block)
    padded = np.zeros(nb * block, dtype=np.float32)
    padded[:n] = vec
    blocks = padded.reshape(nb, block)
    scales = np.maximum(np.abs(blocks).max(axis=1), 1e-12) / 127.0
    q = np.clip(np.rint(blocks / scales[:, None]), -127, 127).astype(np.int8)
    return q.reshape(-1), scales.astype(np.float32)


def dequantize_int8(q: np.ndarray, scales: np.ndarray, n: int,
                    block: int = 1024) -> np.ndarray:
    q = np.asarray(q, dtype=np.int8).astype(np.float32)
    nb = scales.size
    out = (q.reshape(nb, block) * scales[:, None]).reshape(-1)
    return out[:n]


def quantize_int8_batch(mat: np.ndarray, block: int = 1024
                        ) -> tuple[np.ndarray, np.ndarray]:
    """Row-wise :func:`quantize_int8` over an ``(N, P)`` matrix in one shot:
    returns ``(q (N, nb*block) int8, scales (N, nb) f32)``.

    Bit-identical to quantizing each row separately — every op (absmax
    reduce, divide, rint, clip) is per-block elementwise, so batching
    cannot change a single rounding decision.  The wire batch plane
    (``repro.core.wire``) relies on that for its byte-identity contract.
    """
    mat = np.asarray(mat, dtype=np.float32)
    n_items, n = mat.shape
    nb = -(-n // block)
    if n == nb * block:
        padded = np.ascontiguousarray(mat)     # aligned: skip the pad copy
    else:
        padded = np.zeros((n_items, nb * block), dtype=np.float32)
        padded[:, :n] = mat
    blocks = padded.reshape(n_items * nb, block) if nb else \
        padded.reshape(0, block)
    if blocks.shape[0]:
        # max(row.max, -row.min) == |row|.max without materializing |row|.
        scales = np.maximum(blocks.max(axis=1), -blocks.min(axis=1))
        np.maximum(scales, 1e-12, out=scales)
        scales /= 127.0
        q = blocks / scales[:, None]
        np.rint(q, out=q)
        np.clip(q, -127, 127, out=q)
        q = q.astype(np.int8)
    else:
        scales = np.zeros(0, np.float32)
        q = blocks.astype(np.int8)
    return (q.reshape(n_items, nb * block),
            scales.astype(np.float32, copy=False).reshape(n_items, nb))


def dequantize_int8_batch(q: np.ndarray, scales: np.ndarray, n: int,
                          block: int = 1024) -> np.ndarray:
    """Row-wise :func:`dequantize_int8`: ``(N, nb*block) -> (N, n)``,
    bit-identical to per-row dequantization (one elementwise multiply)."""
    out = np.asarray(q, dtype=np.int8).astype(np.float32)
    n_items, nb = scales.shape
    view = out.reshape(n_items, nb, block)
    view *= np.asarray(scales, np.float32)[:, :, None]
    return out.reshape(n_items, nb * block)[:, :n]


@dataclasses.dataclass
class Int8Codec(Codec):
    """Wire layout: n(u64) block(u32) nb(u32) | scales f32[nb] | int8[nb*block]."""

    block: int = 1024
    name = "int8"
    lossless = False

    def encode(self, vec: np.ndarray) -> bytes:
        vec = np.asarray(vec, dtype=np.float32)
        q, scales = quantize_int8(vec, self.block)
        head = _U64.pack(vec.size) + _U32.pack(self.block) + _U32.pack(scales.size)
        return head + scales.astype("<f4").tobytes() + q.tobytes()

    def decode(self, data: bytes) -> np.ndarray:
        n = _U64.unpack_from(data, 0)[0]
        block = _U32.unpack_from(data, 8)[0]
        nb = _U32.unpack_from(data, 12)[0]
        off = 16
        scales = np.frombuffer(data, dtype="<f4", count=nb, offset=off)
        off += 4 * nb
        q = np.frombuffer(data, dtype=np.int8, count=nb * block, offset=off)
        return dequantize_int8(q, scales.astype(np.float32), n, block)


# --------------------------------------------------------------------------
# Top-k sparsification (delta transmission) — beyond-paper compression.
# --------------------------------------------------------------------------
def topk_sparsify(vec: np.ndarray, k: int) -> tuple[np.ndarray, np.ndarray]:
    vec = np.asarray(vec, dtype=np.float32)
    k = min(k, vec.size)
    if k <= 0:
        # argpartition's -k would select the WHOLE array for k=0.
        return (np.zeros(0, dtype=np.uint32), np.zeros(0, dtype=np.float32))
    idx = np.argpartition(np.abs(vec), -k)[-k:].astype(np.uint32)
    idx.sort()
    return idx, vec[idx]


@dataclasses.dataclass
class TopKCodec(Codec):
    """Keep the k largest-magnitude entries. Wire: n(u64) k(u32) | idx u32[k]
    | vals f32[k]. Pair with the ``ef`` wire stage (residual error
    feedback, ``repro.core.wire``) for convergence."""

    k_fraction: float = 0.01
    name = "topk"
    lossless = False

    def encode(self, vec: np.ndarray) -> bytes:
        vec = np.asarray(vec, dtype=np.float32)
        k = min(vec.size, max(1, int(vec.size * self.k_fraction)))
        idx, vals = topk_sparsify(vec, k)
        # Header k is the ACTUAL entry count: for an empty (or size < k)
        # vector, packing the requested k would make decode read past the
        # buffer.
        return (_U64.pack(vec.size) + _U32.pack(idx.size)
                + idx.astype("<u4").tobytes() + vals.astype("<f4").tobytes())

    def decode(self, data: bytes) -> np.ndarray:
        n = _U64.unpack_from(data, 0)[0]
        if n > MAX_DECODE_PARAMS:
            # The output is sized from this wire-supplied field, so it must
            # be bounded before np.zeros(n) (u32 indices also cannot
            # address beyond 2**32 by construction).
            raise ValueError(f"topk n={n} exceeds MAX_DECODE_PARAMS "
                             f"({MAX_DECODE_PARAMS})")
        k = _U32.unpack_from(data, 8)[0]
        idx = np.frombuffer(data, dtype="<u4", count=k, offset=12)
        vals = np.frombuffer(data, dtype="<f4", count=k, offset=12 + 4 * k)
        out = np.zeros(n, dtype=np.float32)
        out[idx] = vals
        return out


CODECS: dict[str, type] = {
    "raw": RawCodec, "hex": HexCodec, "int8": Int8Codec, "topk": TopKCodec,
}


def make_codec(name: str, **kw) -> Codec:
    return CODECS[name](**kw)
