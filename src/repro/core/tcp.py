"""TCP-like baseline (Reno-lite) for the protocol comparison.

Models the properties the paper attributes to TCP (§IV.A): a 3-way handshake
before any data moves, per-packet cumulative acknowledgements, and
window-limited transmission (slow start + congestion avoidance + fast
retransmit + RTO). It is intentionally a simplified Reno — enough to show the
handshake/ACK overhead and loss-recovery latency that motivate MUDP, without
modelling SACK or timestamps.
"""

from __future__ import annotations

from typing import Callable, Optional

from repro.core.mudp import TxnStats, prep_attempt
from repro.core.packets import Packet, PacketKind
from repro.core.simulator import Node, Simulator, Timer


class TcpSender:
    def __init__(self, sim: Simulator, node: Node, dest: Node,
                 packets: list[Packet], *,
                 rto_ns: int = 8_000_000_000,
                 init_cwnd: float = 1.0,
                 ssthresh: float = 64.0,
                 max_rto_backoff: int = 6,
                 on_complete: Optional[Callable[["TcpSender"], None]] = None,
                 on_fail: Optional[Callable[["TcpSender"], None]] = None):
        self.sim, self.node, self.dest = sim, node, dest
        self.packets = {p.seq: p for p in packets}
        self.total = packets[0].total
        self.txn = packets[0].txn
        self.rto_ns = rto_ns
        self.cwnd = init_cwnd
        self.ssthresh = ssthresh
        self.max_rto_backoff = max_rto_backoff
        self.on_complete, self.on_fail = on_complete, on_fail
        self.stats = TxnStats(txn=self.txn, total_packets=self.total)
        self.base = 1            # lowest unacked seq
        self.next_seq = 1        # next never-sent seq
        self.dup_acks = 0
        self.backoffs = 0
        self._attempts: dict[int, int] = {s: 0 for s in self.packets}
        self._timer: Optional[Timer] = None
        self._done = False
        self._established = False
        # Keyed on (txn, responder) — see MudpSender: O(1) control-packet
        # dispatch however many concurrent senders share this node.
        node.register_keyed((self.txn, dest.addr), self._on_packet)

    # -- handshake ---------------------------------------------------------
    def start(self) -> None:
        self.stats.start_ns = self.sim.now_ns
        self.node.send(Packet(PacketKind.SYN, 0, 0, self.node.addr, self.txn),
                       self.dest)
        self._arm()

    # -- window pump ---------------------------------------------------------
    def _pump(self) -> None:
        burst = []
        while (self.next_seq <= self.total
               and self.next_seq < self.base + int(self.cwnd)):
            burst.append(self._prep(self.next_seq))
            self.next_seq += 1
        if burst:
            # The whole window goes out back-to-back: one flight under the
            # batched engine (a full cwnd once past slow start).
            self.node.send_burst(burst, self.dest)

    def _prep(self, seq: int):
        return prep_attempt(self, seq)

    def _send(self, seq: int) -> None:
        self.node.send(self._prep(seq), self.dest)

    # -- events ----------------------------------------------------------------
    def _on_packet(self, pkt: Packet) -> bool:
        # (txn, responder) match — see MudpSender._on_packet: concurrent
        # broadcast senders share a txn on one node.
        if self._done or pkt.txn != self.txn or pkt.addr != self.dest.addr:
            return False
        if pkt.kind == PacketKind.SYN_ACK and not self._established:
            self._established = True
            self.node.send(Packet(PacketKind.ACK, 1, 0, self.node.addr,
                                  self.txn), self.dest)
            self._pump()
            self._arm()
            return True
        if pkt.kind == PacketKind.ACK and self._established:
            ack = pkt.seq  # cumulative: next expected seq
            if ack > self.base:
                acked = ack - self.base
                self.base = ack
                self.dup_acks = 0
                self.backoffs = 0
                # slow start vs congestion avoidance
                for _ in range(acked):
                    if self.cwnd < self.ssthresh:
                        self.cwnd += 1.0
                    else:
                        self.cwnd += 1.0 / self.cwnd
                if self.base > self.total:
                    self.node.send(Packet(PacketKind.FIN, 0, 0,
                                          self.node.addr, self.txn), self.dest)
                    self._finish(failed=False)
                    return True
                self._pump()
                self._arm()
            elif ack == self.base:
                self.dup_acks += 1
                if self.dup_acks == 3:  # fast retransmit + Reno halving
                    self.ssthresh = max(self.cwnd / 2.0, 2.0)
                    self.cwnd = self.ssthresh
                    self.dup_acks = 0
                    self._send(self.base)
                    self._arm()
            return True
        return False

    def _on_timeout(self) -> None:
        if self._done:
            return
        if self.backoffs >= self.max_rto_backoff:
            self._finish(failed=True)
            return
        self.backoffs += 1
        self.ssthresh = max(self.cwnd / 2.0, 2.0)
        self.cwnd = 1.0
        if not self._established:
            self.node.send(Packet(PacketKind.SYN, 0, 0, self.node.addr,
                                  self.txn), self.dest)
        else:
            self._send(self.base)
        self._arm(backoff=True)

    def _arm(self, backoff: bool = False) -> None:
        if self._timer is not None:
            self._timer.cancel()
        rto = self.rto_ns * (2 ** self.backoffs if backoff else 1)
        self._timer = self.sim.schedule(rto, self._on_timeout)

    def _finish(self, *, failed: bool) -> None:
        self._done = True
        self.stats.end_ns = self.sim.now_ns
        self.stats.completed = not failed
        self.stats.failed = failed
        if self._timer is not None:
            self._timer.cancel()
        self.node.unregister_keyed((self.txn, self.dest.addr),
                                   self._on_packet)
        cb = self.on_fail if failed else self.on_complete
        if cb is not None:
            cb(self)


class TcpReceiver:
    """In-order delivery with cumulative ACKs; buffers out-of-order segments."""

    def __init__(self, sim: Simulator, node: Node, *,
                 on_deliver: Optional[
                     Callable[[str, int, dict[int, Packet]], None]] = None):
        self.sim, self.node = sim, node
        self.on_deliver = on_deliver
        self._next: dict[tuple[str, int], int] = {}
        self._buf: dict[tuple[str, int], dict[int, Packet]] = {}
        self._done: set[tuple[str, int]] = set()
        node.register(self._on_packet)

    def _on_packet(self, pkt: Packet) -> bool:
        key = (pkt.addr, pkt.txn)
        if pkt.kind == PacketKind.SYN:
            self.node.send(Packet(PacketKind.SYN_ACK, 0, 0, self.node.addr,
                                  pkt.txn), self.sim.node(pkt.addr))
            self._next.setdefault(key, 1)
            self._buf.setdefault(key, {})
            return True
        if pkt.kind == PacketKind.DATA and key in self._next:
            if key in self._done:
                self._ack(pkt.addr, pkt.txn, pkt.total + 1)
                return True
            if pkt.verify():
                self._buf[key][pkt.seq] = pkt
            nxt = self._next[key]
            while nxt in self._buf[key]:
                nxt += 1
            self._next[key] = nxt
            self._ack(pkt.addr, pkt.txn, nxt)
            if nxt > pkt.total:
                self._done.add(key)
                packets = self._buf.pop(key)
                if self.on_deliver is not None:
                    self.on_deliver(pkt.addr, pkt.txn, packets)
            return True
        if pkt.kind == PacketKind.FIN and key in self._next:
            return True
        # ACKs belong to a TcpSender (possibly on this same node) — never
        # consume them here.
        return False

    def _ack(self, addr: str, txn: int, next_expected: int) -> None:
        self.node.send(Packet(PacketKind.ACK, next_expected, 0,
                              self.node.addr, txn), self.sim.node(addr))


# --------------------------------------------------------------------------
# Flow-engine model (Simulator(engine="flow")) — see repro.core.flow
# --------------------------------------------------------------------------
def _tcp_flow_model(ctx):
    """Analytic Reno-lite transaction: handshake RTT, then one Binomial per
    congestion window.  Clean windows grow cwnd exactly like the packet
    sender (slow start below ssthresh, +1/cwnd above); lossy windows repair
    gap-by-gap — the first gap via dup-ack fast retransmit when at least
    three arrivals can dup-ack, the rest via RTO waits with exponential
    backoff and the cumulative-failure cap of the packet state machine.
    Per-arrival cumulative ACK bytes are accounted so wire totals match.
    """
    from repro.core.flow import CONTROL_BYTES as CB
    from repro.core.flow import FlowOutcome, PH_LOSS, PH_RETX
    st = ctx.stats
    n = ctx.total
    p = ctx.p
    timeout = ctx.cfg.timeout_ns
    max_backoff = 6               # TcpSender.max_rto_backoff default
    # Handshake (control packets: lossless under the default loss models).
    ctx.count(ctx.fwd, PacketKind.SYN, 1, CB)
    _, t = ctx.fwd.occupy(ctx.sim.now_ns, [CB])
    ctx.count(ctx.rev, PacketKind.SYN_ACK, 1, CB)
    _, t = ctx.rev.occupy(t, [CB])
    ctx.count(ctx.fwd, PacketKind.ACK, 1, CB)
    ctx.fwd.occupy(t, [CB])
    base = 1
    cwnd, ssthresh = 1.0, 64.0    # TcpSender defaults
    window = 0
    t_deliver = t
    while base <= n:
        window += 1
        w = min(int(cwnd), n - base + 1)
        sizes = ctx.sizes[base - 1:base - 1 + w]
        st.data_sent += w
        _, f_last = ctx.fwd.occupy(t, sizes)
        lost = ctx.binom(w, p, PH_LOSS, window)
        ctx.count(ctx.fwd, PacketKind.DATA, w, sum(sizes),
                  lost, min(lost * ctx.chunk, sum(sizes)))
        acks = w - lost
        r_last = t
        if acks:
            # The receiver ACKs every DATA arrival (new or duplicate ack).
            ctx.count(ctx.rev, PacketKind.ACK, acks, acks * CB)
            _, r_last = ctx.rev.occupy(f_last, [CB] * acks)
        if lost == 0:
            for _ in range(w):
                cwnd = cwnd + 1.0 if cwnd < ssthresh else cwnd + 1.0 / cwnd
            base += w
            t = r_last
            t_deliver = f_last
            continue
        # Gap-by-gap recovery.  Reno without SACK recovers roughly one loss
        # per RTT (fast retransmit) or per RTO; consecutive losses of the
        # same retransmission escalate the backoff (cumulative cap -> fail).
        t = r_last
        for g in range(lost):
            if g == 0 and acks >= 3:
                wait = 0                      # three dup-acks: no timer
                ssthresh = max(cwnd / 2.0, 2.0)
                cwnd = ssthresh
            else:
                wait = timeout
                ssthresh = max(cwnd / 2.0, 2.0)
                cwnd = 1.0
            attempts = 0
            while True:
                attempts += 1
                if attempts > max_backoff:
                    return FlowOutcome(end_ns=t + wait, completed=False)
                t_fire = t + wait
                st.retransmissions += 1
                st.data_sent += 1
                _, t_arr = ctx.fwd.occupy(t_fire, [ctx.chunk])
                relost = ctx.uniform(
                    PH_RETX, window * 1024 + g * 16 + attempts) < p
                ctx.count(ctx.fwd, PacketKind.DATA, 1, ctx.chunk,
                          1 if relost else 0, ctx.chunk if relost else 0)
                if not relost:
                    ctx.count(ctx.rev, PacketKind.ACK, 1, CB)
                    _, t = ctx.rev.occupy(t_arr, [CB])
                    t_deliver = t_arr
                    break
                wait = timeout * (2 ** attempts)
        base += w
    # Final cumulative ACK arrived: FIN goes out and the sender finishes.
    ctx.count(ctx.fwd, PacketKind.FIN, 1, CB)
    ctx.fwd.occupy(t, [CB])
    return FlowOutcome(end_ns=t, completed=True, deliver_ns=t_deliver,
                       packets={p_.seq: p_ for p_ in ctx.packets},
                       total=n, complete=True)


from repro.core import flow as _flow  # noqa: E402  (registration at bottom)

_flow.register_flow_model("tcp", _tcp_flow_model)
