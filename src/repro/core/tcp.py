"""TCP-like baseline (Reno-lite) for the protocol comparison.

Models the properties the paper attributes to TCP (§IV.A): a 3-way handshake
before any data moves, per-packet cumulative acknowledgements, and
window-limited transmission (slow start + congestion avoidance + fast
retransmit + RTO). It is intentionally a simplified Reno — enough to show the
handshake/ACK overhead and loss-recovery latency that motivate MUDP, without
modelling SACK or timestamps.
"""

from __future__ import annotations

from typing import Callable, Optional

from repro.core.mudp import TxnStats, prep_attempt
from repro.core.packets import Packet, PacketKind
from repro.core.simulator import Node, Simulator, Timer


class TcpSender:
    def __init__(self, sim: Simulator, node: Node, dest: Node,
                 packets: list[Packet], *,
                 rto_ns: int = 8_000_000_000,
                 init_cwnd: float = 1.0,
                 ssthresh: float = 64.0,
                 max_rto_backoff: int = 6,
                 on_complete: Optional[Callable[["TcpSender"], None]] = None,
                 on_fail: Optional[Callable[["TcpSender"], None]] = None):
        self.sim, self.node, self.dest = sim, node, dest
        self.packets = {p.seq: p for p in packets}
        self.total = packets[0].total
        self.txn = packets[0].txn
        self.rto_ns = rto_ns
        self.cwnd = init_cwnd
        self.ssthresh = ssthresh
        self.max_rto_backoff = max_rto_backoff
        self.on_complete, self.on_fail = on_complete, on_fail
        self.stats = TxnStats(txn=self.txn, total_packets=self.total)
        self.base = 1            # lowest unacked seq
        self.next_seq = 1        # next never-sent seq
        self.dup_acks = 0
        self.backoffs = 0
        self._attempts: dict[int, int] = {s: 0 for s in self.packets}
        self._timer: Optional[Timer] = None
        self._done = False
        self._established = False
        # Keyed on (txn, responder) — see MudpSender: O(1) control-packet
        # dispatch however many concurrent senders share this node.
        node.register_keyed((self.txn, dest.addr), self._on_packet)

    # -- handshake ---------------------------------------------------------
    def start(self) -> None:
        self.stats.start_ns = self.sim.now_ns
        self.node.send(Packet(PacketKind.SYN, 0, 0, self.node.addr, self.txn),
                       self.dest)
        self._arm()

    # -- window pump ---------------------------------------------------------
    def _pump(self) -> None:
        burst = []
        while (self.next_seq <= self.total
               and self.next_seq < self.base + int(self.cwnd)):
            burst.append(self._prep(self.next_seq))
            self.next_seq += 1
        if burst:
            # The whole window goes out back-to-back: one flight under the
            # batched engine (a full cwnd once past slow start).
            self.node.send_burst(burst, self.dest)

    def _prep(self, seq: int):
        return prep_attempt(self, seq)

    def _send(self, seq: int) -> None:
        self.node.send(self._prep(seq), self.dest)

    # -- events ----------------------------------------------------------------
    def _on_packet(self, pkt: Packet) -> bool:
        # (txn, responder) match — see MudpSender._on_packet: concurrent
        # broadcast senders share a txn on one node.
        if self._done or pkt.txn != self.txn or pkt.addr != self.dest.addr:
            return False
        if pkt.kind == PacketKind.SYN_ACK and not self._established:
            self._established = True
            self.node.send(Packet(PacketKind.ACK, 1, 0, self.node.addr,
                                  self.txn), self.dest)
            self._pump()
            self._arm()
            return True
        if pkt.kind == PacketKind.ACK and self._established:
            ack = pkt.seq  # cumulative: next expected seq
            if ack > self.base:
                acked = ack - self.base
                self.base = ack
                self.dup_acks = 0
                self.backoffs = 0
                # slow start vs congestion avoidance
                for _ in range(acked):
                    if self.cwnd < self.ssthresh:
                        self.cwnd += 1.0
                    else:
                        self.cwnd += 1.0 / self.cwnd
                if self.base > self.total:
                    self.node.send(Packet(PacketKind.FIN, 0, 0,
                                          self.node.addr, self.txn), self.dest)
                    self._finish(failed=False)
                    return True
                self._pump()
                self._arm()
            elif ack == self.base:
                self.dup_acks += 1
                if self.dup_acks == 3:  # fast retransmit + Reno halving
                    self.ssthresh = max(self.cwnd / 2.0, 2.0)
                    self.cwnd = self.ssthresh
                    self.dup_acks = 0
                    self._send(self.base)
                    self._arm()
            return True
        return False

    def _on_timeout(self) -> None:
        if self._done:
            return
        if self.backoffs >= self.max_rto_backoff:
            self._finish(failed=True)
            return
        self.backoffs += 1
        self.ssthresh = max(self.cwnd / 2.0, 2.0)
        self.cwnd = 1.0
        if not self._established:
            self.node.send(Packet(PacketKind.SYN, 0, 0, self.node.addr,
                                  self.txn), self.dest)
        else:
            self._send(self.base)
        self._arm(backoff=True)

    def _arm(self, backoff: bool = False) -> None:
        if self._timer is not None:
            self._timer.cancel()
        rto = self.rto_ns * (2 ** self.backoffs if backoff else 1)
        self._timer = self.sim.schedule(rto, self._on_timeout)

    def _finish(self, *, failed: bool) -> None:
        self._done = True
        self.stats.end_ns = self.sim.now_ns
        self.stats.completed = not failed
        self.stats.failed = failed
        if self._timer is not None:
            self._timer.cancel()
        self.node.unregister_keyed((self.txn, self.dest.addr),
                                   self._on_packet)
        cb = self.on_fail if failed else self.on_complete
        if cb is not None:
            cb(self)


class TcpReceiver:
    """In-order delivery with cumulative ACKs; buffers out-of-order segments."""

    def __init__(self, sim: Simulator, node: Node, *,
                 on_deliver: Optional[
                     Callable[[str, int, dict[int, Packet]], None]] = None):
        self.sim, self.node = sim, node
        self.on_deliver = on_deliver
        self._next: dict[tuple[str, int], int] = {}
        self._buf: dict[tuple[str, int], dict[int, Packet]] = {}
        self._done: set[tuple[str, int]] = set()
        node.register(self._on_packet)

    def _on_packet(self, pkt: Packet) -> bool:
        key = (pkt.addr, pkt.txn)
        if pkt.kind == PacketKind.SYN:
            self.node.send(Packet(PacketKind.SYN_ACK, 0, 0, self.node.addr,
                                  pkt.txn), self.sim.node(pkt.addr))
            self._next.setdefault(key, 1)
            self._buf.setdefault(key, {})
            return True
        if pkt.kind == PacketKind.DATA and key in self._next:
            if key in self._done:
                self._ack(pkt.addr, pkt.txn, pkt.total + 1)
                return True
            if pkt.verify():
                self._buf[key][pkt.seq] = pkt
            nxt = self._next[key]
            while nxt in self._buf[key]:
                nxt += 1
            self._next[key] = nxt
            self._ack(pkt.addr, pkt.txn, nxt)
            if nxt > pkt.total:
                self._done.add(key)
                packets = self._buf.pop(key)
                if self.on_deliver is not None:
                    self.on_deliver(pkt.addr, pkt.txn, packets)
            return True
        if pkt.kind == PacketKind.FIN and key in self._next:
            return True
        # ACKs belong to a TcpSender (possibly on this same node) — never
        # consume them here.
        return False

    def _ack(self, addr: str, txn: int, next_expected: int) -> None:
        self.node.send(Packet(PacketKind.ACK, next_expected, 0,
                              self.node.addr, txn), self.sim.node(addr))
