"""The Modified UDP (MUDP) protocol — the paper's contribution (§IV.B).

Sender
  1. Sends the packets as required in quick succession.
  2. Keeps all sent packets for possible resending on packet loss.
  3. Starts a timer for determining when to resend:
     - ACK ``(0, 0, A)`` -> all packets received, transaction completes.
     - NACK ``(X, Np, A)`` with ``0 < X <= Np`` -> resend packet X.
     - Timer expiry with no acknowledgement -> resend the LAST packet to make
       the receiver report its missing sequences, with Y (=3) max retries.

Receiver
  1. Receives and stores all packets.
  2. Once the last packet (``X == Np``) is received:
     - all present -> ACK ``(0, 0, A)``, reconstruct the original payload,
       proceed with federated learning, clear storage;
     - gaps -> send a NACK per missing sequence number and start a timer for
       resending the NACKs.

The implementation is a pair of event-driven state machines over the
discrete-event simulator. They are deliberately transport-only: bytes in,
bytes out — the FL layer (``repro.core.rounds``) composes them with the
packetizer and aggregation.
"""

from __future__ import annotations

import dataclasses
import zlib
from typing import Callable, Optional

from repro.core.packets import (Packet, PacketKind, make_ack_ok, make_nack)
from repro.core.simulator import Node, Simulator, Timer


@dataclasses.dataclass
class TxnStats:
    """Per-transaction accounting surfaced to benchmarks/EXPERIMENTS.md."""

    txn: int
    total_packets: int = 0
    start_ns: int = 0
    end_ns: int = 0
    data_sent: int = 0
    retransmissions: int = 0
    parity_sent: int = 0          # FEC redundancy (mudp+fec), not data
    last_packet_retries: int = 0  # the paper's Y counter
    nacks_sent: int = 0
    nacks_received: int = 0
    completed: bool = False
    failed: bool = False

    @property
    def duration_ns(self) -> int:
        return self.end_ns - self.start_ns


def prep_attempt(sender, seq: int) -> Packet:
    """Shared (re)transmission bookkeeping for burst/window senders
    (MUDP and TCP): bump the attempt counter, account data/retx stats,
    and return the packet stamped with its attempt number."""
    attempt = sender._attempts[seq]
    sender._attempts[seq] = attempt + 1
    sender.stats.data_sent += 1
    if attempt > 0:
        sender.stats.retransmissions += 1
    pkt = sender.packets[seq]
    if pkt.attempt != attempt:
        pkt = dataclasses.replace(pkt, attempt=attempt)
    return pkt


def ingest_data_run(pkts: list, i: int, j: int, received: dict,
                    addr: str, txn: int) -> int:
    """The bulk-contract inner loop shared by the MUDP and UDP receivers:
    verify-and-store consecutive *interior* DATA packets of transaction
    ``(addr, txn)`` from ``pkts[i:j]``; stops at any kind/addr/txn
    mismatch or the transaction's last packet.  Returns packets consumed.
    """
    adler32 = zlib.adler32
    k = i
    while k < j:
        p = pkts[k]
        if (p.kind != PacketKind.DATA or p.addr != addr or p.txn != txn
                or p.seq == p.total):
            break
        if adler32(p.payload) & 0xFFFFFFFF == p.checksum:   # == p.verify()
            received[p.seq] = p
        k += 1
    return k - i


class MudpSender:
    """One transaction: ship ``packets`` to ``dest`` reliably."""

    def __init__(self, sim: Simulator, node: Node, dest: Node,
                 packets: list[Packet], *,
                 timeout_ns: int = 6_000_000_000,
                 max_retries: int = 3,
                 on_complete: Optional[Callable[["MudpSender"], None]] = None,
                 on_fail: Optional[Callable[["MudpSender"], None]] = None):
        if not packets:
            raise ValueError("empty transaction")
        self.sim, self.node, self.dest = sim, node, dest
        self.packets = {p.seq: p for p in packets}
        self.total = packets[0].total
        self.txn = packets[0].txn
        self.timeout_ns = timeout_ns
        self.max_retries = max_retries
        self.on_complete = on_complete
        self.on_fail = on_fail
        self.stats = TxnStats(txn=self.txn, total_packets=self.total)
        self._attempts: dict[int, int] = {s: 0 for s in self.packets}
        self._timer: Optional[Timer] = None
        self._done = False
        # Keyed registration: this sender only ever consumes ACK/NACK from
        # (txn, responder), so the node dispatches by dict lookup — a
        # broadcast of N concurrent senders stays O(1) per control packet.
        node.register_keyed((self.txn, dest.addr), self._on_packet)

    # -- paper step 1: send in quick succession --------------------------
    def start(self) -> None:
        self.stats.start_ns = self.sim.now_ns
        # One burst over one link: the batched engine plans the whole
        # transaction's FIFO serialization, jitter and loss in one shot.
        # Initial transmissions are all attempt 0, so the per-seq
        # bookkeeping of _prep collapses to bulk counter updates.
        if any(a != 0 for a in self._attempts.values()) or any(
                p.attempt != 0 for p in self.packets.values()):
            burst = [self._prep(seq) for seq in range(1, self.total + 1)]
        else:
            burst = [self.packets[seq] for seq in range(1, self.total + 1)]
            self._attempts = {s: 1 for s in self._attempts}
            self.stats.data_sent += self.total
        self.node.send_burst(burst, self.dest)
        self._arm_timer()

    def _prep(self, seq: int) -> Packet:
        """Account one (re)transmission of ``seq`` and return the packet."""
        return prep_attempt(self, seq)

    def _send(self, seq: int) -> None:
        self.node.send(self._prep(seq), self.dest)

    # -- paper step 3: the timer ------------------------------------------
    def _arm_timer(self) -> None:
        self._cancel_timer()
        self._timer = self.sim.schedule(self.timeout_ns, self._on_timeout)

    def _cancel_timer(self) -> None:
        if self._timer is not None:
            self._timer.cancel()
            self._timer = None

    def _on_timeout(self) -> None:
        if self._done:
            return
        if self.stats.last_packet_retries >= self.max_retries:
            self._finish(failed=True)
            return
        # "the sender resends the last packets to inform the receiver of the
        #  missing sequences with Y amount of maximum retries"
        self.stats.last_packet_retries += 1
        if self.sim.trace:
            self.sim.log(f"t={self.sim.now_ns}ns {self.node.addr}: timer "
                         f"expired, resending last packet ({self.total}, "
                         f"{self.total}, {self.node.addr}) retry "
                         f"{self.stats.last_packet_retries}/"
                         f"{self.max_retries}")
        self._send(self.total)
        self._arm_timer()

    # -- acknowledgement handling ------------------------------------------
    def _on_packet(self, pkt: Packet) -> bool:
        # Match on (txn, responder): a server broadcast runs one sender per
        # client under the SAME txn on one node, and another client's
        # ACK/NACK must not complete or steer this transaction.
        if self._done or pkt.txn != self.txn or pkt.addr != self.dest.addr:
            return False
        if pkt.kind == PacketKind.ACK_OK:
            # "(0, 0, A) ... all packets have been received and the
            #  transaction completes."
            self._finish(failed=False)
            return True
        if pkt.kind == PacketKind.NACK:
            self.stats.nacks_received += 1
            if 0 < pkt.seq <= self.total:
                if self.sim.trace:
                    self.sim.log(f"t={self.sim.now_ns}ns {self.node.addr}: "
                                 f"NACK for missing packet {pkt.seq}, "
                                 f"resending")
                self._send(pkt.seq)
                self._arm_timer()
            return True
        return False

    def _finish(self, *, failed: bool) -> None:
        self._done = True
        self.stats.end_ns = self.sim.now_ns
        self.stats.completed = not failed
        self.stats.failed = failed
        self._cancel_timer()
        self.node.unregister_keyed((self.txn, self.dest.addr),
                                   self._on_packet)
        cb = self.on_fail if failed else self.on_complete
        if cb is not None:
            cb(self)


@dataclasses.dataclass
class _RxState:
    """Receiver-side storage for one in-flight transaction."""

    total: int
    sender_addr: str
    received: dict[int, Packet] = dataclasses.field(default_factory=dict)
    saw_last: bool = False
    nack_rounds: int = 0
    nack_timer: Optional[Timer] = None
    first_ns: int = 0


class MudpReceiver:
    """Persistent receiver serving many senders/transactions (the FL server).

    ``on_deliver(sender_addr, txn, packets)`` fires exactly once per completed
    transaction with the full ``{seq: Packet}`` map.
    """

    def __init__(self, sim: Simulator, node: Node, *,
                 nack_timeout_ns: int = 6_000_000_000,
                 max_nack_retries: int = 3,
                 on_deliver: Optional[
                     Callable[[str, int, dict[int, Packet]], None]] = None):
        self.sim, self.node = sim, node
        self.nack_timeout_ns = nack_timeout_ns
        self.max_nack_retries = max_nack_retries
        self.on_deliver = on_deliver
        self._rx: dict[tuple[str, int], _RxState] = {}
        self._completed: set[tuple[str, int]] = set()
        self.stats_nacks_sent = 0
        node.register(self._on_packet, bulk=self._ingest_run)

    def _ingest_run(self, pkts: list, i: int, j: int, arrivals: list) -> int:
        """Batched-engine fast path: ingest consecutive DATA packets of one
        flight in a single call (see ``Node.register`` for the contract).

        Consumes a prefix of ``pkts[i:j]`` that behaves exactly like that
        many :meth:`_on_packet` calls — interior (non-last) packets of one
        un-completed transaction whose last packet has not been seen, where
        the per-packet effect is precisely verify-and-store.  A completed
        transaction (per-packet re-ACKs) or armed gap machinery declines
        the flight permanently (-1); anything else unexpected declines the
        due packet (0).
        """
        p0 = pkts[i]
        if p0.kind != PacketKind.DATA:
            return 0
        key = (p0.addr, p0.txn)
        if key in self._completed:
            return -1
        st = self._rx.get(key)
        if st is None:
            st = _RxState(total=p0.total, sender_addr=p0.addr,
                          first_ns=self.sim.now_ns)
            self._rx[key] = st
        if st.saw_last:
            return -1
        return ingest_data_run(pkts, i, j, st.received, p0.addr, p0.txn)

    def _on_packet(self, pkt: Packet) -> bool:
        if pkt.kind != PacketKind.DATA:
            return False
        key = (pkt.addr, pkt.txn)
        if key in self._completed:
            # Sender missed our ACK and retried the last packet: re-ACK so it
            # can terminate (at-least-once delivery of the completion signal).
            self._send_ack(pkt.addr, pkt.txn)
            return True
        st = self._rx.get(key)
        if st is None:
            st = _RxState(total=pkt.total, sender_addr=pkt.addr,
                          first_ns=self.sim.now_ns)
            self._rx[key] = st
        if not pkt.verify():
            if self.sim.trace:
                self.sim.log(f"t={self.sim.now_ns}ns {self.node.addr}: "
                             f"checksum fail on {pkt}, treating as lost")
            return True
        st.received[pkt.seq] = pkt
        if self.sim.trace:
            self.sim.log(f"t={self.sim.now_ns}ns {self.node.addr}: got {pkt} "
                         f"[{len(st.received)}/{st.total}]")
        if pkt.is_last:
            st.saw_last = True
        if st.saw_last and not self._try_deliver(key, st) and pkt.is_last:
            # Gap reporting happens only on last-packet arrival (including a
            # timer-driven resend of it) or on the NACK timer — an interior
            # retransmission that still leaves gaps must NOT re-NACK packets
            # already in flight.
            self._report_gaps(key, st)
        return True

    # -- paper receiver step 2 ---------------------------------------------
    def _try_deliver(self, key: tuple[str, int], st: _RxState) -> bool:
        # O(1) fast path: fewer verified packets than Np means gaps for
        # sure; the O(Np) scan only runs at (potential) completion.
        if len(st.received) < st.total:
            return False
        missing = [s for s in range(1, st.total + 1) if s not in st.received]
        if missing:
            return False
        if st.nack_timer is not None:
            st.nack_timer.cancel()
        self._completed.add(key)
        packets = st.received
        del self._rx[key]
        self._send_ack(st.sender_addr, key[1])
        if self.on_deliver is not None:
            self.on_deliver(st.sender_addr, key[1], packets)
        return True

    def _report_gaps(self, key: tuple[str, int], st: _RxState) -> None:
        missing = [s for s in range(1, st.total + 1) if s not in st.received]
        # "If some packets are missing, send acknowledgements with sequence
        #  numbers of only those missing packets."  The whole NACK volley
        # goes out back-to-back, so it is one burst on the wire.
        if self.sim.trace:
            for seq in missing:
                self.sim.log(f"t={self.sim.now_ns}ns {self.node.addr}: "
                             f"packet {seq} is missing! requesting resend")
        self.stats_nacks_sent += len(missing)
        self.node.send_burst(
            [make_nack(seq, st.total, self.node.addr, key[1])
             for seq in missing],
            self.sim.node(st.sender_addr))
        # "Start the timer for determining when to resend the acknowledgement"
        if st.nack_timer is not None:
            st.nack_timer.cancel()
        if st.nack_rounds < self.max_nack_retries:
            st.nack_rounds += 1
            st.nack_timer = self.sim.schedule(
                self.nack_timeout_ns, lambda: self._on_nack_timeout(key))

    def _on_nack_timeout(self, key: tuple[str, int]) -> None:
        st = self._rx.get(key)
        if st is not None and st.saw_last and not self._try_deliver(key, st):
            self._report_gaps(key, st)

    def _send_ack(self, dest_addr: str, txn: int) -> None:
        # "(0, 0, A)" where A is the responder's address (Figs 5-7 show the
        # server responding with (0, 0, 10.1.2.5)).
        self.node.send(make_ack_ok(self.node.addr, txn),
                       self.sim.node(dest_addr))


# --------------------------------------------------------------------------
# Flow-engine model (Simulator(engine="flow")) — see repro.core.flow
# --------------------------------------------------------------------------
def flow_recover(ctx, *, m: int, last_seen: bool, t_last: int,
                 timeout_ns: int, max_retries: int,
                 nack_rounds: int = 0,
                 retain_p: float | None = None) -> tuple[bool, int]:
    """The MUDP recovery machinery as an expected-value recursion, shared by
    the ``mudp`` and ``mudp+fec`` flow models.

    ``m`` interior gaps remain at the receiver; ``last_seen`` says whether
    the final packet (the paper's gap-reporting trigger) has arrived, and
    ``t_last`` is when the receiver last made progress.  Mirrors the packet
    state machines: while the last packet is unseen the receiver is silent
    and the sender keepalive timer resends it (``last_packet_retries`` is a
    cumulative budget — the timer fire after it hits ``max_retries`` fails
    the transaction); once seen, each NACK volley resends the missing set,
    with losses redrawn by seeded stochastic rounding.  Timer-armed volleys
    are budgeted by ``max_nack_retries`` (== ``max_retries``); past that,
    volleys are driven by keepalive duplicates of the last packet.

    ``retain_p`` is the probability a lost volley retransmission still
    needs another volley.  Plain MUDP leaves it ``None`` (every loss
    survives); the FEC model passes its residual-loss probability, because
    the real receiver re-runs repair on every retransmission arrival — a
    group reduced to one missing packet is rebuilt from parity on the
    spot, so only losses whose parity cover is also gone re-volley.

    Returns ``(completed, t)`` — the receiver completion time on success,
    the failing sender-timer expiry otherwise.
    """
    from repro.core.flow import CONTROL_BYTES as CB
    from repro.core.flow import PH_LAST, PH_RETX
    st = ctx.stats
    last_size = ctx.sizes[-1]
    fires = 0
    while not last_seen:
        # Receiver never saw the last packet: it stays silent, and the
        # sender timer (armed at start, re-armed per resend) fires at
        # start + k*timeout.
        fires += 1
        t_fire = ctx.sim.now_ns + fires * timeout_ns
        if st.last_packet_retries >= max_retries:
            return False, t_fire
        st.last_packet_retries += 1
        st.retransmissions += 1
        st.data_sent += 1
        lost = ctx.uniform(PH_LAST, fires) < ctx.p
        _, t_arr = ctx.fwd.occupy(t_fire, [last_size])
        ctx.count(ctx.fwd, PacketKind.DATA, 1, last_size,
                  1 if lost else 0, last_size if lost else 0)
        if not lost:
            last_seen = True
            t_last = t_arr
    volley = 0
    while m > 0:
        # One volley: NACK burst back, retransmission burst forward.
        volley += 1
        st.nacks_received += m
        ctx.count(ctx.rev, PacketKind.NACK, m, m * CB)
        _, t_nack = ctx.rev.occupy(t_last, [CB] * m)
        st.retransmissions += m
        st.data_sent += m
        # Loss count of the retransmission burst: the exact Binomial (an
        # integer, replayable refinement of stochastically rounding m*p —
        # same mean, and the correct P(another volley needed)).
        lost = min(m, ctx.binom(m, ctx.p, PH_RETX, volley))
        _, t_retx = ctx.fwd.occupy(t_nack, [ctx.chunk] * m)
        ctx.count(ctx.fwd, PacketKind.DATA, m, m * ctx.chunk,
                  lost, lost * ctx.chunk)
        if retain_p is not None and lost:
            # Receiver-side repair at the retransmission arrivals: only
            # losses whose parity cover is also unavailable survive.
            lost = ctx.binom(lost, retain_p, PH_RETX, 500 + volley)
        if lost == 0:
            return True, t_retx
        m = lost
        if nack_rounds < max_retries:
            # Receiver nack timer, armed when the volley went out.
            nack_rounds += 1
            t_last = t_last + timeout_ns
        else:
            # NACK-timer budget spent: the sender keepalive (re-armed by
            # the volley's NACK arrivals) resends the last packet; its
            # duplicate arrival triggers the next volley.
            waits = 0
            while True:
                waits += 1
                t_fire = t_nack + waits * timeout_ns
                if st.last_packet_retries >= max_retries:
                    return False, t_fire
                st.last_packet_retries += 1
                st.retransmissions += 1
                st.data_sent += 1
                dup_lost = ctx.uniform(
                    PH_LAST, 1000 + volley * 8 + waits) < ctx.p
                _, t_arr = ctx.fwd.occupy(t_fire, [last_size])
                ctx.count(ctx.fwd, PacketKind.DATA, 1, last_size,
                          1 if dup_lost else 0, last_size if dup_lost else 0)
                if not dup_lost:
                    t_last = t_arr
                    break
    return True, t_last


def flow_ack_outcome(ctx, t_done: int):
    """Completion tail shared by the reliable MUDP-family models: ACK_OK
    travels back and the sender finishes on its arrival."""
    from repro.core.flow import CONTROL_BYTES as CB
    from repro.core.flow import FlowOutcome
    ctx.count(ctx.rev, PacketKind.ACK_OK, 1, CB)
    _, t_ack = ctx.rev.occupy(t_done, [CB])
    return FlowOutcome(end_ns=t_ack, completed=True, deliver_ns=t_done,
                       packets={p.seq: p for p in ctx.packets},
                       total=ctx.total, complete=True)


def spurious_volley(ctx, m: int, t: int, act_p: float = 1.0) -> None:
    """Account a reorder-triggered NACK volley: ``m`` NACKs back and the
    acted-on subset as duplicate retransmissions forward, starting at
    ``t``.  The originals are still in flight and complete the transaction
    themselves, so the volley is pure wire overhead — no timing
    consequence beyond the link occupancy it adds (shared by the ``mudp``
    and ``mudp+fec`` models).

    ``act_p`` is the probability the sender acts on one of these NACKs:
    the completing ACK_OK races the NACKs over the same jittered reverse
    path, and a NACK that arrives after it finds the transaction already
    retired — wire bytes spent, no resend."""
    if m <= 0:
        return
    from repro.core.flow import CONTROL_BYTES as CB
    from repro.core.flow import PH_RETX
    st = ctx.stats
    st.nacks_received += m
    ctx.count(ctx.rev, PacketKind.NACK, m, m * CB)
    _, t_nack = ctx.rev.occupy(t, [CB] * m)
    if act_p < 1.0:
        m = ctx.binom(m, max(0.0, act_p), PH_RETX, 901)
        if m <= 0:
            return
    st.retransmissions += m
    st.data_sent += m
    lost = min(m, ctx.binom(m, ctx.p, PH_RETX, 900))
    ctx.fwd.occupy(t_nack, [ctx.chunk] * m)
    ctx.count(ctx.fwd, PacketKind.DATA, m, m * ctx.chunk,
              lost, lost * ctx.chunk)


def _mudp_flow_model(ctx):
    """Analytic MUDP transaction: one Binomial for the initial burst, the
    last-packet conditional, then the volley recursion."""
    from repro.core.flow import (FlowOutcome, PH_LAST, PH_LOSS,
                                 spurious_reorder_nacks)
    cfg = ctx.cfg
    n = ctx.total
    ctx.stats.data_sent += n
    _, last_arr = ctx.fwd.occupy(ctx.sim.now_ns, ctx.sizes)
    k0 = ctx.binom(n, ctx.p, PH_LOSS, 0)
    last_lost = k0 > 0 and ctx.uniform(PH_LAST, 0) < k0 / n
    dropped_bytes = ((k0 - 1) * ctx.chunk + ctx.sizes[-1] if last_lost
                     else k0 * ctx.chunk)
    ctx.count(ctx.fwd, PacketKind.DATA, n, ctx.data_bytes, k0, dropped_bytes)
    if not last_lost:
        # Jitter reordering: in-flight interiors NACKed at last arrival.
        # The reordered original completes delivery shortly after, so its
        # ACK_OK chases the NACK down the reverse path with a head start
        # of roughly the mean residual reorder excess (~ jitter/3).
        from repro.core.flow import reorder_prob
        act_p = 1.0 - reorder_prob(ctx.rev.link.jitter_ns,
                                   ctx.fwd.link.jitter_ns // 3)
        spurious_volley(ctx, spurious_reorder_nacks(ctx), last_arr,
                        act_p=act_p)
    completed, t_done = flow_recover(
        ctx, m=k0 - (1 if last_lost else 0), last_seen=not last_lost,
        t_last=last_arr, timeout_ns=cfg.timeout_ns,
        max_retries=cfg.max_retries)
    if not completed:
        return FlowOutcome(end_ns=t_done, completed=False)
    return flow_ack_outcome(ctx, t_done)


from repro.core import flow as _flow  # noqa: E402  (registration at bottom)

_flow.register_flow_model("mudp", _mudp_flow_model)
