"""The Modified UDP (MUDP) protocol — the paper's contribution (§IV.B).

Sender
  1. Sends the packets as required in quick succession.
  2. Keeps all sent packets for possible resending on packet loss.
  3. Starts a timer for determining when to resend:
     - ACK ``(0, 0, A)`` -> all packets received, transaction completes.
     - NACK ``(X, Np, A)`` with ``0 < X <= Np`` -> resend packet X.
     - Timer expiry with no acknowledgement -> resend the LAST packet to make
       the receiver report its missing sequences, with Y (=3) max retries.

Receiver
  1. Receives and stores all packets.
  2. Once the last packet (``X == Np``) is received:
     - all present -> ACK ``(0, 0, A)``, reconstruct the original payload,
       proceed with federated learning, clear storage;
     - gaps -> send a NACK per missing sequence number and start a timer for
       resending the NACKs.

The implementation is a pair of event-driven state machines over the
discrete-event simulator. They are deliberately transport-only: bytes in,
bytes out — the FL layer (``repro.core.rounds``) composes them with the
packetizer and aggregation.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Optional

from repro.core.packets import (Packet, PacketKind, make_ack_ok, make_nack)
from repro.core.simulator import Node, Simulator, Timer


@dataclasses.dataclass
class TxnStats:
    """Per-transaction accounting surfaced to benchmarks/EXPERIMENTS.md."""

    txn: int
    total_packets: int = 0
    start_ns: int = 0
    end_ns: int = 0
    data_sent: int = 0
    retransmissions: int = 0
    parity_sent: int = 0          # FEC redundancy (mudp+fec), not data
    last_packet_retries: int = 0  # the paper's Y counter
    nacks_sent: int = 0
    nacks_received: int = 0
    completed: bool = False
    failed: bool = False

    @property
    def duration_ns(self) -> int:
        return self.end_ns - self.start_ns


class MudpSender:
    """One transaction: ship ``packets`` to ``dest`` reliably."""

    def __init__(self, sim: Simulator, node: Node, dest: Node,
                 packets: list[Packet], *,
                 timeout_ns: int = 6_000_000_000,
                 max_retries: int = 3,
                 on_complete: Optional[Callable[["MudpSender"], None]] = None,
                 on_fail: Optional[Callable[["MudpSender"], None]] = None):
        if not packets:
            raise ValueError("empty transaction")
        self.sim, self.node, self.dest = sim, node, dest
        self.packets = {p.seq: p for p in packets}
        self.total = packets[0].total
        self.txn = packets[0].txn
        self.timeout_ns = timeout_ns
        self.max_retries = max_retries
        self.on_complete = on_complete
        self.on_fail = on_fail
        self.stats = TxnStats(txn=self.txn, total_packets=self.total)
        self._attempts: dict[int, int] = {s: 0 for s in self.packets}
        self._timer: Optional[Timer] = None
        self._done = False
        node.register(self._on_packet)

    # -- paper step 1: send in quick succession --------------------------
    def start(self) -> None:
        self.stats.start_ns = self.sim.now_ns
        for seq in range(1, self.total + 1):
            self._send(seq)
        self._arm_timer()

    def _send(self, seq: int) -> None:
        pkt = dataclasses.replace(self.packets[seq],
                                  attempt=self._attempts[seq])
        self._attempts[seq] += 1
        self.stats.data_sent += 1
        if pkt.attempt > 0:
            self.stats.retransmissions += 1
        self.node.send(pkt, self.dest)

    # -- paper step 3: the timer ------------------------------------------
    def _arm_timer(self) -> None:
        self._cancel_timer()
        self._timer = self.sim.schedule(self.timeout_ns, self._on_timeout)

    def _cancel_timer(self) -> None:
        if self._timer is not None:
            self._timer.cancel()
            self._timer = None

    def _on_timeout(self) -> None:
        if self._done:
            return
        if self.stats.last_packet_retries >= self.max_retries:
            self._finish(failed=True)
            return
        # "the sender resends the last packets to inform the receiver of the
        #  missing sequences with Y amount of maximum retries"
        self.stats.last_packet_retries += 1
        self.sim.log(f"t={self.sim.now_ns}ns {self.node.addr}: timer expired, "
                     f"resending last packet ({self.total}, {self.total}, "
                     f"{self.node.addr}) retry "
                     f"{self.stats.last_packet_retries}/{self.max_retries}")
        self._send(self.total)
        self._arm_timer()

    # -- acknowledgement handling ------------------------------------------
    def _on_packet(self, pkt: Packet) -> bool:
        # Match on (txn, responder): a server broadcast runs one sender per
        # client under the SAME txn on one node, and another client's
        # ACK/NACK must not complete or steer this transaction.
        if self._done or pkt.txn != self.txn or pkt.addr != self.dest.addr:
            return False
        if pkt.kind == PacketKind.ACK_OK:
            # "(0, 0, A) ... all packets have been received and the
            #  transaction completes."
            self._finish(failed=False)
            return True
        if pkt.kind == PacketKind.NACK:
            self.stats.nacks_received += 1
            if 0 < pkt.seq <= self.total:
                self.sim.log(f"t={self.sim.now_ns}ns {self.node.addr}: NACK "
                             f"for missing packet {pkt.seq}, resending")
                self._send(pkt.seq)
                self._arm_timer()
            return True
        return False

    def _finish(self, *, failed: bool) -> None:
        self._done = True
        self.stats.end_ns = self.sim.now_ns
        self.stats.completed = not failed
        self.stats.failed = failed
        self._cancel_timer()
        self.node.unregister(self._on_packet)
        cb = self.on_fail if failed else self.on_complete
        if cb is not None:
            cb(self)


@dataclasses.dataclass
class _RxState:
    """Receiver-side storage for one in-flight transaction."""

    total: int
    sender_addr: str
    received: dict[int, Packet] = dataclasses.field(default_factory=dict)
    saw_last: bool = False
    nack_rounds: int = 0
    nack_timer: Optional[Timer] = None
    first_ns: int = 0


class MudpReceiver:
    """Persistent receiver serving many senders/transactions (the FL server).

    ``on_deliver(sender_addr, txn, packets)`` fires exactly once per completed
    transaction with the full ``{seq: Packet}`` map.
    """

    def __init__(self, sim: Simulator, node: Node, *,
                 nack_timeout_ns: int = 6_000_000_000,
                 max_nack_retries: int = 3,
                 on_deliver: Optional[
                     Callable[[str, int, dict[int, Packet]], None]] = None):
        self.sim, self.node = sim, node
        self.nack_timeout_ns = nack_timeout_ns
        self.max_nack_retries = max_nack_retries
        self.on_deliver = on_deliver
        self._rx: dict[tuple[str, int], _RxState] = {}
        self._completed: set[tuple[str, int]] = set()
        self.stats_nacks_sent = 0
        node.register(self._on_packet)

    def _on_packet(self, pkt: Packet) -> bool:
        if pkt.kind != PacketKind.DATA:
            return False
        key = (pkt.addr, pkt.txn)
        if key in self._completed:
            # Sender missed our ACK and retried the last packet: re-ACK so it
            # can terminate (at-least-once delivery of the completion signal).
            self._send_ack(pkt.addr, pkt.txn)
            return True
        st = self._rx.get(key)
        if st is None:
            st = _RxState(total=pkt.total, sender_addr=pkt.addr,
                          first_ns=self.sim.now_ns)
            self._rx[key] = st
        if not pkt.verify():
            self.sim.log(f"t={self.sim.now_ns}ns {self.node.addr}: checksum "
                         f"fail on {pkt}, treating as lost")
            return True
        st.received[pkt.seq] = pkt
        self.sim.log(f"t={self.sim.now_ns}ns {self.node.addr}: got {pkt} "
                     f"[{len(st.received)}/{st.total}]")
        if pkt.is_last:
            st.saw_last = True
        if st.saw_last and not self._try_deliver(key, st) and pkt.is_last:
            # Gap reporting happens only on last-packet arrival (including a
            # timer-driven resend of it) or on the NACK timer — an interior
            # retransmission that still leaves gaps must NOT re-NACK packets
            # already in flight.
            self._report_gaps(key, st)
        return True

    # -- paper receiver step 2 ---------------------------------------------
    def _try_deliver(self, key: tuple[str, int], st: _RxState) -> bool:
        missing = [s for s in range(1, st.total + 1) if s not in st.received]
        if missing:
            return False
        if st.nack_timer is not None:
            st.nack_timer.cancel()
        self._completed.add(key)
        packets = st.received
        del self._rx[key]
        self._send_ack(st.sender_addr, key[1])
        if self.on_deliver is not None:
            self.on_deliver(st.sender_addr, key[1], packets)
        return True

    def _report_gaps(self, key: tuple[str, int], st: _RxState) -> None:
        missing = [s for s in range(1, st.total + 1) if s not in st.received]
        # "If some packets are missing, send acknowledgements with sequence
        #  numbers of only those missing packets."
        for seq in missing:
            self.sim.log(f"t={self.sim.now_ns}ns {self.node.addr}: packet "
                         f"{seq} is missing! requesting resend")
            self.stats_nacks_sent += 1
            self.node.send(make_nack(seq, st.total, self.node.addr, key[1]),
                           self.sim.node(st.sender_addr))
        # "Start the timer for determining when to resend the acknowledgement"
        if st.nack_timer is not None:
            st.nack_timer.cancel()
        if st.nack_rounds < self.max_nack_retries:
            st.nack_rounds += 1
            st.nack_timer = self.sim.schedule(
                self.nack_timeout_ns, lambda: self._on_nack_timeout(key))

    def _on_nack_timeout(self, key: tuple[str, int]) -> None:
        st = self._rx.get(key)
        if st is not None and st.saw_last and not self._try_deliver(key, st):
            self._report_gaps(key, st)

    def _send_ack(self, dest_addr: str, txn: int) -> None:
        # "(0, 0, A)" where A is the responder's address (Figs 5-7 show the
        # server responding with (0, 0, 10.1.2.5)).
        self.node.send(make_ack_ok(self.node.addr, txn),
                       self.sim.node(dest_addr))
