"""Channel models for the discrete-event network simulator.

A link is (data_rate, propagation_delay, loss model). The paper's NS3 setup is
5 Mbps with a 2000 ms delay; that is `PAPER_LINK`. Production presets model
DCN/WAN-class cross-pod links.

Loss models are deterministic given a seed (or an explicit drop predicate), so
every test and benchmark replays bit-for-bit — the NS3-equivalent of a fixed
RngSeedManager seed.  All stochastic draws (loss, burst state, jitter) are
counter-based keyed uniforms (splitmix64 over the packet identity) with a
single array-shaped implementation, so the per-packet and batched simulator
engines produce identical values by construction.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Callable, Iterable, Optional, Sequence

import numpy as np

from repro.core.packets import Packet, PacketKind

NS_PER_SEC = 1_000_000_000

# Payload-bearing kinds are subject to stochastic loss; small control packets
# (ACK/NACK/SYN/...) only drop when drop_control=True. FEC parity rides the
# same links as data and must be just as losable, or comparisons against
# non-FEC transports would be biased.
_PAYLOAD_KINDS = frozenset({PacketKind.DATA, PacketKind.PARITY})


# --------------------------------------------------------------------------
# Keyed, counter-based uniform draws (the replay-stable RNG)
# --------------------------------------------------------------------------
# Every stochastic decision in the channel layer is a *pure function* of a
# per-packet key (stream tag, model seed, txn, kind, seq, attempt): no
# generator state ever advances, so replays are bit-identical regardless of
# event interleaving, and a whole burst of draws can be computed as one
# vectorized numpy expression — which is what the batched flight engine
# (``Simulator(engine="batched")``) relies on.  There is exactly ONE
# implementation of the draw (array-shaped); the per-packet path calls it
# with length-1 arrays, so the two engines cannot diverge by construction.
#
# The mixer is the splitmix64 finalizer — a full-avalanche 64-bit hash whose
# xor/shift/multiply steps are identical under python ints (masked to 64
# bits) and ``np.uint64`` wrap-around arithmetic.
_MASK64 = (1 << 64) - 1
_MIX_BASE = 0x9E3779B97F4A7C15          # golden-ratio offset
_M1, _M2 = 0xBF58476D1CE4E5B9, 0x94D049BB133111EB

# Distinct stream tags keep the loss / burst-state / jitter draws
# decorrelated even when their model seeds are equal (same role as the old
# 0x117E2 jitter tag, now one per stream).
LOSS_STREAM = 0x10D5
BURST_STREAM = 0x6E11
JITTER_STREAM = 0x117E2
# Flow-engine draws (repro.core.flow): burst-level binomial loss counts,
# stochastic rounding of expected-value recursions, missing-seq selection.
# Its own tag so a flow run's draws are decorrelated from the per-packet
# streams that share the same (seed, txn, ...) key material.
FLOW_STREAM = 0xF7011

_NP_M1, _NP_M2 = np.uint64(_M1), np.uint64(_M2)
_NP_S30, _NP_S27, _NP_S31 = np.uint64(30), np.uint64(27), np.uint64(31)
_NP_S11 = np.uint64(11)
_INV_2_53 = float(2.0 ** -53)


def _mix_int(x: int) -> int:
    """splitmix64 finalizer on a python int (used only for the scalar key
    prefix; the per-packet tail runs through :func:`_mix_arr`)."""
    x = ((x ^ (x >> 30)) * _M1) & _MASK64
    x = ((x ^ (x >> 27)) * _M2) & _MASK64
    return x ^ (x >> 31)


def _mix_arr(x: np.ndarray) -> np.ndarray:
    """The same finalizer on a ``np.uint64`` array (wrap-around multiply)."""
    x = (x ^ (x >> _NP_S30)) * _NP_M1
    x = (x ^ (x >> _NP_S27)) * _NP_M2
    return x ^ (x >> _NP_S31)


def keyed_uniforms(stream: int, seed: int, txns: np.ndarray,
                   kinds: np.ndarray, seqs: np.ndarray,
                   attempts: np.ndarray) -> np.ndarray:
    """One uniform [0, 1) draw per packet, keyed by
    ``(stream, seed, txn, kind, seq, attempt)``.

    ``txns``/``kinds``/``seqs``/``attempts`` are parallel ``np.uint64``
    arrays; the result is ``float64`` with full 53-bit resolution.  The
    draw for a given key is the same whether it is computed alone or as
    part of a burst — the property the engine-equivalence tests pin down.
    """
    h0 = _mix_int(_MIX_BASE ^ (stream & _MASK64))
    h0 = _mix_int(h0 ^ (seed & _MASK64))
    h = _mix_arr(np.uint64(h0) ^ txns)
    h = _mix_arr(h ^ kinds)
    h = _mix_arr(h ^ seqs)
    h = _mix_arr(h ^ attempts)
    return (h >> _NP_S11) * _INV_2_53


def keyed_uniform(stream: int, seed: int, pkt: Packet) -> float:
    """Scalar form: the identical draw for one packet, via the python-int
    splitmix chain (the uint64 wrap-around arithmetic is the same math as
    :func:`_mix_arr`; ``tests/test_engine_equivalence.py`` pins the scalar
    and array paths to each other bit-for-bit)."""
    h = _mix_int(_MIX_BASE ^ (stream & _MASK64))
    h = _mix_int(h ^ (seed & _MASK64))
    h = _mix_int(h ^ pkt.txn)
    h = _mix_int(h ^ int(pkt.kind))
    h = _mix_int(h ^ pkt.seq)
    h = _mix_int(h ^ pkt.attempt)
    return (h >> 11) * _INV_2_53


def flow_uniform(stream: int, seed: int, a: int, b: int = 0, c: int = 0,
                 d: int = 0) -> float:
    """One keyed uniform [0, 1) draw from raw integer key material — the
    same splitmix64 chain as :func:`keyed_uniform` without requiring a
    :class:`Packet`.  The flow engine keys its burst-level draws on
    ``(txn, phase, counter, attempt)`` tuples that have no packet identity.
    """
    h = _mix_int(_MIX_BASE ^ (stream & _MASK64))
    h = _mix_int(h ^ (seed & _MASK64))
    h = _mix_int(h ^ (a & _MASK64))
    h = _mix_int(h ^ (b & _MASK64))
    h = _mix_int(h ^ (c & _MASK64))
    h = _mix_int(h ^ (d & _MASK64))
    return (h >> 11) * _INV_2_53


def keyed_binomial(n: int, p: float, u: float) -> int:
    """Binomial(n, p) sample by CDF inversion from one uniform ``u``.

    A deterministic, platform-stable walk up the pmf recurrence
    ``pmf(k+1) = pmf(k) * (n-k) p / ((k+1)(1-p))`` — no generator state,
    no numpy Generator (whose binomial algorithm is not guaranteed stable
    across versions).  O(n) worst case but terminates near ``n*p`` for the
    loss rates links model; exact for the degenerate edges.
    """
    if n <= 0 or p <= 0.0:
        return 0
    if p >= 1.0:
        return n
    q = 1.0 - p
    pmf = q ** n
    if pmf == 0.0:
        # Underflow (huge n, large p): fall back to a normal-approximation
        # quantile, clamped — the regime where per-k inversion is hopeless.
        mean, sd = n * p, math.sqrt(n * p * q)
        # Acklam-style inverse CDF is overkill; a 4-term rational
        # approximation of the probit is plenty at these tolerances.
        x = max(1e-12, min(1.0 - 1e-12, u))
        t = math.sqrt(-2.0 * math.log(min(x, 1.0 - x)))
        z = t - (2.30753 + 0.27061 * t) / (1.0 + 0.99229 * t
                                           + 0.04481 * t * t)
        if x < 0.5:
            z = -z
        return max(0, min(n, int(round(mean + sd * z))))
    cdf, k = pmf, 0
    ratio = p / q
    while u >= cdf and k < n:
        pmf *= (n - k) * ratio / (k + 1)
        k += 1
        cdf += pmf
    return k


def stochastic_round(x: float, u: float) -> int:
    """``floor(x) + (u < frac(x))`` — integerize an expected value so the
    mean is preserved exactly while every replay of the same key gives the
    same integer (the flow engine's retx counts stay replayable)."""
    base = int(x)
    return base + (1 if u < (x - base) else 0)


def packet_key_arrays(pkts: Sequence[Packet]
                      ) -> tuple[np.ndarray, np.ndarray, np.ndarray,
                                 np.ndarray]:
    """(txns, kinds, seqs, attempts) as ``np.uint64`` arrays, in send order."""
    n = len(pkts)
    txns = np.fromiter((p.txn for p in pkts), np.uint64, n)
    kinds = np.fromiter((int(p.kind) for p in pkts), np.uint64, n)
    seqs = np.fromiter((p.seq for p in pkts), np.uint64, n)
    attempts = np.fromiter((p.attempt for p in pkts), np.uint64, n)
    return txns, kinds, seqs, attempts


def _payload_kind_mask(kinds: np.ndarray) -> np.ndarray:
    mask = kinds == np.uint64(int(PacketKind.DATA))
    for k in _PAYLOAD_KINDS:
        if k != PacketKind.DATA:
            mask |= kinds == np.uint64(int(k))
    return mask


# --------------------------------------------------------------------------
# Loss models
# --------------------------------------------------------------------------
class LossModel:
    """Decides whether a given transmission of a packet is dropped."""

    def drops(self, pkt: Packet) -> bool:  # pragma: no cover - interface
        raise NotImplementedError

    def stationary_loss_p(self) -> float:
        """The model's marginal per-payload-packet drop probability — what
        the flow engine (``Simulator(engine="flow")``) uses for its
        burst-level Binomial loss draws.  Models whose drops are not an
        exchangeable per-packet event (e.g. :class:`DropList`) have no
        meaningful stationary rate and refuse, which in turn makes the
        flow engine refuse the link."""
        raise NotImplementedError(
            f"{type(self).__name__} defines no stationary loss "
            f"probability; the flow engine cannot model this link")

    def drop_mask(self, pkts: Sequence[Packet], txns: np.ndarray,
                  kinds: np.ndarray, seqs: np.ndarray,
                  attempts: np.ndarray) -> np.ndarray:
        """Vectorized form for one burst: bool array, True = dropped.

        The key arrays are the burst's :func:`packet_key_arrays`.  The
        default falls back to per-packet :meth:`drops`, so any custom loss
        model stays bit-identical under the batched engine without writing
        a vectorized path.
        """
        return np.fromiter((self.drops(p) for p in pkts), bool, len(pkts))


class NoLoss(LossModel):
    def drops(self, pkt: Packet) -> bool:
        return False

    def drop_mask(self, pkts, txns, kinds, seqs, attempts) -> np.ndarray:
        return np.zeros(len(pkts), bool)

    def stationary_loss_p(self) -> float:
        return 0.0


@dataclasses.dataclass
class DropList(LossModel):
    """Drop exact (seq, attempt) pairs — reproduces the paper's test cases,
    where the client 'deliberately skips' specific sequence numbers on the
    first transmission only.

    ``drops_on`` entries are ``(seq, attempt)``; attempt 0 is the initial
    transmission. DATA packets only — control packets always pass (as in the
    paper's scenarios).
    """

    drops_on: frozenset

    def __init__(self, pairs: Iterable[tuple[int, int]]):
        self.drops_on = frozenset(pairs)

    def drops(self, pkt: Packet) -> bool:
        if pkt.kind != PacketKind.DATA:
            return False
        return (pkt.seq, pkt.attempt) in self.drops_on


@dataclasses.dataclass
class BernoulliLoss(LossModel):
    """IID loss with probability ``p``, deterministic per (txn, seq, attempt,
    kind) so replays are stable regardless of event interleaving."""

    p: float
    seed: int = 0
    drop_control: bool = False  # whether ACK/NACK packets can also be lost

    def drops(self, pkt: Packet) -> bool:
        if self.p <= 0.0:
            return False
        if not self.drop_control and pkt.kind not in _PAYLOAD_KINDS:
            return False
        return keyed_uniform(LOSS_STREAM, self.seed, pkt) < self.p

    def drop_mask(self, pkts, txns, kinds, seqs, attempts) -> np.ndarray:
        if self.p <= 0.0:
            return np.zeros(len(pkts), bool)
        mask = keyed_uniforms(LOSS_STREAM, self.seed, txns, kinds, seqs,
                              attempts) < self.p
        if not self.drop_control:
            mask &= _payload_kind_mask(kinds)
        return mask

    def stationary_loss_p(self) -> float:
        return max(0.0, min(1.0, self.p))


@dataclasses.dataclass
class GilbertElliott(LossModel):
    """Two-state bursty loss (good/bad) — the standard WAN burst-loss model.

    State advances per transmission attempt, keyed deterministically by a
    per-packet hash so that the model is replayable; this is a mean-field
    variant (per-packet independent two-state mixture) adequate for sweeps.
    """

    p_good_loss: float = 0.001
    p_bad_loss: float = 0.3
    p_bad: float = 0.05          # stationary probability of the bad state
    seed: int = 0
    drop_control: bool = False

    def drops(self, pkt: Packet) -> bool:
        if not self.drop_control and pkt.kind not in _PAYLOAD_KINDS:
            return False
        bad = keyed_uniform(BURST_STREAM, self.seed, pkt) < self.p_bad
        p = self.p_bad_loss if bad else self.p_good_loss
        return keyed_uniform(LOSS_STREAM, self.seed, pkt) < p

    def drop_mask(self, pkts, txns, kinds, seqs, attempts) -> np.ndarray:
        bad = keyed_uniforms(BURST_STREAM, self.seed, txns, kinds, seqs,
                             attempts) < self.p_bad
        p = np.where(bad, self.p_bad_loss, self.p_good_loss)
        mask = keyed_uniforms(LOSS_STREAM, self.seed, txns, kinds, seqs,
                              attempts) < p
        if not self.drop_control:
            mask &= _payload_kind_mask(kinds)
        return mask

    def stationary_loss_p(self) -> float:
        # This implementation is a mean-field per-packet two-state mixture
        # (state keyed independently per packet), so the two-state closed
        # form IS the exact marginal, not an approximation.
        return self.p_bad * self.p_bad_loss + (1.0 - self.p_bad) \
            * self.p_good_loss


# --------------------------------------------------------------------------
# Links
# --------------------------------------------------------------------------
@dataclasses.dataclass
class Link:
    """Point-to-point link: serialization at ``data_rate_bps`` plus
    ``delay_ns`` propagation (optionally jittered), with an attached loss
    model.

    Serialization occupies the link (FIFO): back-to-back sends queue behind
    each other, matching NS3 PointToPointNetDevice semantics.

    ``jitter_ns`` adds a per-packet propagation jitter drawn uniformly from
    ``[0, jitter_ns)``, keyed deterministically by (jitter_seed, txn, kind,
    seq, attempt) — the same replay-stable idiom as :class:`BernoulliLoss`,
    so a fleet of hundreds of jittered links still replays bit-for-bit.
    Jitter can reorder packets in flight, which is exactly the wide-area
    behaviour the MUDP gap machinery has to absorb.  The batched engine
    draws a whole burst's jitter at once via :meth:`propagation_array`.
    """

    data_rate_bps: float = 5_000_000.0       # paper: 5 Mbps
    delay_ns: int = 2_000_000_000            # paper: 2000 ms
    loss: LossModel = dataclasses.field(default_factory=NoLoss)
    jitter_ns: int = 0                       # uniform extra delay in [0, jitter_ns)
    jitter_seed: int = 0
    # Busy-until bookkeeping (owned by the simulator).
    _busy_until_ns: int = 0

    def serialization_ns(self, size_bytes: int) -> int:
        return int(round(size_bytes * 8 * NS_PER_SEC / self.data_rate_bps))

    def propagation_ns(self, pkt: Optional[Packet] = None) -> int:
        """Propagation delay for one transmission of ``pkt``."""
        if self.jitter_ns <= 0 or pkt is None:
            return self.delay_ns
        # JITTER_STREAM keeps this stream decorrelated from the loss models'
        # draws, which key the same (seed, txn, kind, seq, attempt) shape —
        # with one tag, equal seeds would make drop and jitter draws the
        # same number, biasing delivered-packet jitter upward.
        return self.delay_ns + int(
            keyed_uniform(JITTER_STREAM, self.jitter_seed, pkt)
            * self.jitter_ns)

    def propagation_array(self, txns: np.ndarray, kinds: np.ndarray,
                          seqs: np.ndarray, attempts: np.ndarray
                          ) -> np.ndarray:
        """Per-packet propagation delays for one burst (int64 ns), drawing
        every jitter value in one vectorized shot — the same values
        :meth:`propagation_ns` produces packet by packet."""
        n = len(seqs)
        if self.jitter_ns <= 0:
            return np.full(n, self.delay_ns, np.int64)
        u = keyed_uniforms(JITTER_STREAM, self.jitter_seed, txns, kinds,
                           seqs, attempts)
        return self.delay_ns + (u * self.jitter_ns).astype(np.int64)

    def expected_propagation_ns(self) -> int:
        """Mean propagation delay — base delay plus the expectation of the
        uniform [0, jitter_ns) jitter.  The flow engine charges every
        packet this mean instead of drawing per-packet jitter."""
        return self.delay_ns + self.jitter_ns // 2

    def reset(self) -> None:
        self._busy_until_ns = 0


PAPER_LINK = dict(data_rate_bps=5_000_000.0, delay_ns=2_000_000_000)
# Cross-pod DCN-class link: 25 Gbps effective per stream, 1 ms RTT/2.
DCN_LINK = dict(data_rate_bps=25_000_000_000.0, delay_ns=500_000)
# Cross-region WAN: 2 Gbps, 30 ms one-way.
WAN_LINK = dict(data_rate_bps=2_000_000_000.0, delay_ns=30_000_000)
