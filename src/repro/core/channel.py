"""Channel models for the discrete-event network simulator.

A link is (data_rate, propagation_delay, loss model). The paper's NS3 setup is
5 Mbps with a 2000 ms delay; that is `PAPER_LINK`. Production presets model
DCN/WAN-class cross-pod links.

Loss models are deterministic given a seed (or an explicit drop predicate), so
every test and benchmark replays bit-for-bit — the NS3-equivalent of a fixed
RngSeedManager seed.
"""

from __future__ import annotations

import dataclasses
import random
from typing import Callable, Iterable, Optional

from repro.core.packets import Packet, PacketKind

NS_PER_SEC = 1_000_000_000

# Payload-bearing kinds are subject to stochastic loss; small control packets
# (ACK/NACK/SYN/...) only drop when drop_control=True. FEC parity rides the
# same links as data and must be just as losable, or comparisons against
# non-FEC transports would be biased.
_PAYLOAD_KINDS = frozenset({PacketKind.DATA, PacketKind.PARITY})


# --------------------------------------------------------------------------
# Loss models
# --------------------------------------------------------------------------
class LossModel:
    """Decides whether a given transmission of a packet is dropped."""

    def drops(self, pkt: Packet) -> bool:  # pragma: no cover - interface
        raise NotImplementedError


class NoLoss(LossModel):
    def drops(self, pkt: Packet) -> bool:
        return False


@dataclasses.dataclass
class DropList(LossModel):
    """Drop exact (seq, attempt) pairs — reproduces the paper's test cases,
    where the client 'deliberately skips' specific sequence numbers on the
    first transmission only.

    ``drops_on`` entries are ``(seq, attempt)``; attempt 0 is the initial
    transmission. DATA packets only — control packets always pass (as in the
    paper's scenarios).
    """

    drops_on: frozenset

    def __init__(self, pairs: Iterable[tuple[int, int]]):
        self.drops_on = frozenset(pairs)

    def drops(self, pkt: Packet) -> bool:
        if pkt.kind != PacketKind.DATA:
            return False
        return (pkt.seq, pkt.attempt) in self.drops_on


@dataclasses.dataclass
class BernoulliLoss(LossModel):
    """IID loss with probability ``p``, deterministic per (txn, seq, attempt,
    kind) so replays are stable regardless of event interleaving."""

    p: float
    seed: int = 0
    drop_control: bool = False  # whether ACK/NACK packets can also be lost

    def drops(self, pkt: Packet) -> bool:
        if self.p <= 0.0:
            return False
        if not self.drop_control and pkt.kind not in _PAYLOAD_KINDS:
            return False
        key = (self.seed, pkt.txn, int(pkt.kind), pkt.seq, pkt.attempt)
        return random.Random(hash(key)).random() < self.p


@dataclasses.dataclass
class GilbertElliott(LossModel):
    """Two-state bursty loss (good/bad) — the standard WAN burst-loss model.

    State advances per transmission attempt, keyed deterministically by a
    per-packet hash so that the model is replayable; this is a mean-field
    variant (per-packet independent two-state mixture) adequate for sweeps.
    """

    p_good_loss: float = 0.001
    p_bad_loss: float = 0.3
    p_bad: float = 0.05          # stationary probability of the bad state
    seed: int = 0
    drop_control: bool = False

    def drops(self, pkt: Packet) -> bool:
        if not self.drop_control and pkt.kind not in _PAYLOAD_KINDS:
            return False
        key = (self.seed, pkt.txn, int(pkt.kind), pkt.seq, pkt.attempt)
        rng = random.Random(hash(key))
        bad = rng.random() < self.p_bad
        return rng.random() < (self.p_bad_loss if bad else self.p_good_loss)


# --------------------------------------------------------------------------
# Links
# --------------------------------------------------------------------------
@dataclasses.dataclass
class Link:
    """Point-to-point link: serialization at ``data_rate_bps`` plus
    ``delay_ns`` propagation (optionally jittered), with an attached loss
    model.

    Serialization occupies the link (FIFO): back-to-back sends queue behind
    each other, matching NS3 PointToPointNetDevice semantics.

    ``jitter_ns`` adds a per-packet propagation jitter drawn uniformly from
    ``[0, jitter_ns)``, keyed deterministically by (jitter_seed, txn, kind,
    seq, attempt) — the same replay-stable idiom as :class:`BernoulliLoss`,
    so a fleet of hundreds of jittered links still replays bit-for-bit.
    Jitter can reorder packets in flight, which is exactly the wide-area
    behaviour the MUDP gap machinery has to absorb.
    """

    data_rate_bps: float = 5_000_000.0       # paper: 5 Mbps
    delay_ns: int = 2_000_000_000            # paper: 2000 ms
    loss: LossModel = dataclasses.field(default_factory=NoLoss)
    jitter_ns: int = 0                       # uniform extra delay in [0, jitter_ns)
    jitter_seed: int = 0
    # Busy-until bookkeeping (owned by the simulator).
    _busy_until_ns: int = 0

    def serialization_ns(self, size_bytes: int) -> int:
        return int(round(size_bytes * 8 * NS_PER_SEC / self.data_rate_bps))

    def propagation_ns(self, pkt: Optional[Packet] = None) -> int:
        """Propagation delay for one transmission of ``pkt``."""
        if self.jitter_ns <= 0 or pkt is None:
            return self.delay_ns
        # The 0x117E2 tag keeps this stream decorrelated from the loss
        # models' draws, which hash the same (seed, txn, kind, seq, attempt)
        # shape — without it, equal seeds would make drop and jitter draws
        # the same number, biasing delivered-packet jitter upward.
        key = (0x117E2, self.jitter_seed, pkt.txn, int(pkt.kind), pkt.seq,
               pkt.attempt)
        return self.delay_ns + int(
            random.Random(hash(key)).random() * self.jitter_ns)

    def reset(self) -> None:
        self._busy_until_ns = 0


PAPER_LINK = dict(data_rate_bps=5_000_000.0, delay_ns=2_000_000_000)
# Cross-pod DCN-class link: 25 Gbps effective per stream, 1 ms RTT/2.
DCN_LINK = dict(data_rate_bps=25_000_000_000.0, delay_ns=500_000)
# Cross-region WAN: 2 Gbps, 30 ms one-way.
WAN_LINK = dict(data_rate_bps=2_000_000_000.0, delay_ns=30_000_000)
