"""Vectorized client compute: batched local training behind a registry.

Every fleet round used to optimize its objective client-by-client in a
Python loop (``FLClient.train_fn`` called once per session timer).  This
module batches the *client dimension* instead:

* :class:`ClientModel` — a registered model family
  (``register_model`` / ``make_model`` / ``available_models``) that can
  train one client the legacy way (``train_fn(i)`` — a per-client callable,
  bit-identical to the historical path) **and** as a pure, vmappable JAX
  function over a flat parameter vector (``jax_train``).  Built-ins:
  ``"consensus"`` (the analytic quadratic objective the fleet benchmarks
  always used) and ``"mlp"`` (the paper's MNIST MLP —
  ``repro.models.mlp`` over ``repro.data.mnist`` non-IID dirichlet shards).
* :class:`TrainBackend` — how a batch of pending training steps executes
  (``register_train_backend`` / ``make_train_backend``):
  ``"python"`` loops the per-client callables (today's path), ``"vmap"``
  runs one ``jax.jit(jax.vmap(...))`` call over the stacked batch,
  ``"shard"`` additionally ``shard_map``s the batch over the local device
  mesh (``repro.distributed.fl_mesh.client_mesh``) and falls back to vmap
  on a single device.
* :class:`BatchTrainer` — the orchestrator glue.  ``ServerCore`` (and the
  hierarchical :class:`~repro.core.topology.CellScheduler` cells through
  their nested cores, and :class:`~repro.core.topology.GossipSystem`)
  *submit* a session's training input the moment its model is delivered
  and *collect* the result when the session's training timer fires.
  Because local training is deterministic and per-client independent, the
  trainer may compute any pending set in one batched call without
  changing a single event: the first timer to fire flushes everything
  submitted so far — in a typical round that is the whole roster, so K
  clients train as one vmapped batch while the simulator still observes
  per-client completion times.

The default path is untouched: with no trainer attached,
``ServerCore.schedule_training`` runs the exact historical per-client
code, pinned by the 24 orchestrator-equivalence digests.
"""

from __future__ import annotations

import abc
from typing import Any, Callable, Optional

import numpy as np

from repro.core.packetizer import flatten_to_vector, unflatten_from_vector


# --------------------------------------------------------------------------
# The model contract + registry
# --------------------------------------------------------------------------
class ClientModel(abc.ABC):
    """A model family the fleet can train: per-client or batched.

    Implementations expose the *same* local training step two ways, and
    ``tests/test_client_compute.py`` pins that they agree (bit-identical
    for the python loop vs itself; ULP-bounded python-vs-vmap):

    * :meth:`train_fn` — ``(params_tree, round_idx, client) -> (tree,
      metrics)``, the historical per-client callable handed to
      :class:`~repro.core.server.FLClient`.
    * :meth:`jax_train` — ``(flat_vec, client_idx, round_idx) ->
      (flat_vec', aux)``, pure and vmappable over all three arguments
      (``aux`` is a dict of scalar training metrics).
    """

    name: str = "abstract"

    def __init__(self, n_clients: int, *, seed: int = 0):
        self.n_clients = int(n_clients)
        self.seed = int(seed)

    @abc.abstractmethod
    def init_params(self) -> Any:
        """The global model template (numpy pytree, float32 leaves)."""

    @abc.abstractmethod
    def loss(self, params: Any) -> float:
        """Global objective value (lower is better)."""

    def eval_metrics(self, params: Any) -> dict:
        """Benchmark-facing evaluation record (subclasses extend)."""
        return {"loss": self.loss(params)}

    @abc.abstractmethod
    def train_fn(self, i: int, profile: Any = None) -> Callable:
        """The i-th client's legacy per-client training callable."""

    @abc.abstractmethod
    def jax_train(self, vec, client_idx, round_idx):
        """One client's local training as a pure JAX function."""


_MODELS: dict[str, Callable[..., ClientModel]] = {}


def register_model(name: str, factory: Callable[..., ClientModel], *,
                   overwrite: bool = False) -> None:
    """Register a model factory (the transport/topology registry idiom:
    silent shadowing of a built-in would invalidate benchmarks)."""
    if not overwrite and name in _MODELS:
        raise ValueError(f"model {name!r} is already registered "
                         f"(pass overwrite=True to replace it)")
    _MODELS[name] = factory


def make_model(name: str, n_clients: int, *, seed: int = 0,
               **kwargs) -> ClientModel:
    try:
        factory = _MODELS[name]
    except KeyError:
        raise ValueError(f"unknown model {name!r}; registered models: "
                         f"{available_models()}") from None
    return factory(n_clients, seed=seed, **kwargs)


def available_models() -> list[str]:
    return sorted(_MODELS)


# --------------------------------------------------------------------------
# Built-in model: the analytic consensus objective
# --------------------------------------------------------------------------
class ConsensusModel(ClientModel):
    """:class:`~repro.core.fleet.ConsensusObjective` as a registered model.

    The python path delegates to the objective's own ``train_fn`` — the
    byte-for-byte historical fleet workload — while :meth:`jax_train`
    expresses the same ``w + lr * (c_k - w)`` step over the stacked target
    matrix for the vmap/shard backends.
    """

    name = "consensus"

    def __init__(self, n_clients: int, *, seed: int = 0,
                 n_params: int = 1024, lr: float = 0.5,
                 heterogeneity: float = 0.1):
        from repro.core.fleet import ConsensusObjective
        super().__init__(n_clients, seed=seed)
        self.objective = ConsensusObjective(
            n_clients, n_params, seed=seed, lr=lr, heterogeneity=heterogeneity)

    def init_params(self) -> Any:
        return self.objective.init_params()

    def loss(self, params: Any) -> float:
        return self.objective.loss(params)

    def train_fn(self, i: int, profile: Any = None) -> Callable:
        return self.objective.train_fn(i, profile)

    def jax_train(self, vec, client_idx, round_idx):
        import jax.numpy as jnp
        targets = jnp.asarray(self.objective.targets)
        target = targets[client_idx]
        w = vec.astype(jnp.float32)
        new = w + jnp.float32(self.objective.lr) * (target - w)
        return new, {"local_gap": jnp.mean((w - target) ** 2)}


register_model("consensus", ConsensusModel)


def _mlp_factory(n_clients: int, *, seed: int = 0, **kwargs) -> ClientModel:
    # Lazy: repro.models.mlp imports jax at module load; keep that off the
    # critical import path of the pure-simulator layers.
    from repro.models.mlp import MnistMLPModel
    return MnistMLPModel(n_clients, seed=seed, **kwargs)


register_model("mlp", _mlp_factory)


# --------------------------------------------------------------------------
# Train backends
# --------------------------------------------------------------------------
class TrainBackend(abc.ABC):
    """Executes a batch of independent local-training steps.

    ``train(model, stack, client_idx, round_idx)`` takes the K pending
    steps as a stacked float32 matrix ``(K, n_params)`` plus int32 vectors
    of client indices and round numbers, and returns ``(new_stack,
    metrics)`` where ``metrics`` is one dict per row.
    """

    name: str = "abstract"

    @abc.abstractmethod
    def train(self, model: ClientModel, stack: np.ndarray,
              client_idx: np.ndarray, round_idx: np.ndarray
              ) -> tuple[np.ndarray, list[dict]]:
        ...


class PythonLoopBackend(TrainBackend):
    """Today's path: one ``train_fn`` call per client, in batch order.

    Bit-identical to the historical per-session training (it calls the
    very same callables), which is why it is the default everywhere the
    replay digests are pinned.
    """

    name = "python"

    def __init__(self) -> None:
        self._fns: dict[tuple[int, int], Callable] = {}
        self._template: dict[int, Any] = {}

    def _fn(self, model: ClientModel, i: int) -> Callable:
        key = (id(model), i)
        fn = self._fns.get(key)
        if fn is None:
            fn = self._fns[key] = model.train_fn(i)
        return fn

    def train(self, model, stack, client_idx, round_idx):
        template = self._template.get(id(model))
        if template is None:
            template = self._template[id(model)] = model.init_params()
        out = np.empty_like(stack)
        metrics: list[dict] = []
        for j in range(stack.shape[0]):
            tree = unflatten_from_vector(stack[j], template)
            new_tree, m = self._fn(model, int(client_idx[j]))(
                tree, int(round_idx[j]), None)
            out[j] = flatten_to_vector(new_tree)
            metrics.append(m)
        return out, metrics


def _aux_to_rows(aux: dict, k: int) -> list[dict]:
    """Split a dict of (K,)-arrays into K per-row metric dicts."""
    rows: list[dict] = []
    for j in range(k):
        rows.append({key: float(np.asarray(val)[j])
                     for key, val in aux.items()})
    return rows


def _next_pow2(k: int) -> int:
    return 1 << max(0, (k - 1).bit_length())


class VmapBackend(TrainBackend):
    """One ``jax.jit(jax.vmap(model.jax_train))`` call per flush.

    Batches are padded to the next power of two (duplicating the last
    row; padded outputs are discarded) so a fleet with varying roster
    sizes compiles O(log K) programs instead of one per distinct K.
    """

    name = "vmap"

    def __init__(self) -> None:
        self._jitted: dict[int, Callable] = {}

    def _batched(self, model: ClientModel) -> Callable:
        fn = self._jitted.get(id(model))
        if fn is None:
            import jax
            fn = self._jitted[id(model)] = jax.jit(jax.vmap(model.jax_train))
        return fn

    def train(self, model, stack, client_idx, round_idx):
        import jax.numpy as jnp
        k = stack.shape[0]
        kp = _next_pow2(k)
        if kp != k:
            pad = kp - k
            stack = np.concatenate([stack, np.repeat(stack[-1:], pad, 0)])
            client_idx = np.concatenate(
                [client_idx, np.repeat(client_idx[-1:], pad)])
            round_idx = np.concatenate(
                [round_idx, np.repeat(round_idx[-1:], pad)])
        new, aux = self._batched(model)(
            jnp.asarray(stack, jnp.float32),
            jnp.asarray(client_idx, jnp.int32),
            jnp.asarray(round_idx, jnp.int32))
        out = np.asarray(new, np.float32)[:k]
        return out, _aux_to_rows(aux, k)


class ShardBackend(VmapBackend):
    """vmap sharded over the local device mesh (``clients`` axis).

    With one device (the CI case) this is exactly :class:`VmapBackend`;
    with D devices the padded batch is split D ways via ``shard_map`` so
    each device trains K/D clients.
    """

    name = "shard"

    def __init__(self) -> None:
        super().__init__()
        self._sharded: dict[int, Callable] = {}

    def _batched(self, model: ClientModel) -> Callable:
        import jax
        if jax.device_count() <= 1:
            return super()._batched(model)
        fn = self._sharded.get(id(model))
        if fn is None:
            from jax.experimental.shard_map import shard_map
            from jax.sharding import PartitionSpec as P
            from repro.distributed.fl_mesh import client_mesh
            mesh = client_mesh()
            vmapped = jax.vmap(model.jax_train)
            spec = P("clients")
            fn = self._sharded[id(model)] = jax.jit(shard_map(
                vmapped, mesh=mesh,
                in_specs=(spec, spec, spec),
                out_specs=(spec, spec),
                check_rep=False))
        return fn

    def train(self, model, stack, client_idx, round_idx):
        import jax
        d = jax.device_count()
        if d <= 1:
            return super().train(model, stack, client_idx, round_idx)
        # Pad to a device multiple (shard_map needs an even split), then
        # reuse the pow2 padding inside the parent for jit stability.
        k = stack.shape[0]
        kp = max(d, _next_pow2(k))
        kp = -(-kp // d) * d
        if kp != k:
            pad = kp - k
            stack = np.concatenate([stack, np.repeat(stack[-1:], pad, 0)])
            client_idx = np.concatenate(
                [client_idx, np.repeat(client_idx[-1:], pad)])
            round_idx = np.concatenate(
                [round_idx, np.repeat(round_idx[-1:], pad)])
        import jax.numpy as jnp
        new, aux = self._batched(model)(
            jnp.asarray(stack, jnp.float32),
            jnp.asarray(client_idx, jnp.int32),
            jnp.asarray(round_idx, jnp.int32))
        return np.asarray(new, np.float32)[:k], _aux_to_rows(aux, k)


_TRAIN_BACKENDS: dict[str, Callable[[], TrainBackend]] = {}


def register_train_backend(name: str, factory: Callable[[], TrainBackend],
                           *, overwrite: bool = False) -> None:
    if not overwrite and name in _TRAIN_BACKENDS:
        raise ValueError(f"train backend {name!r} is already registered "
                         f"(pass overwrite=True to replace it)")
    _TRAIN_BACKENDS[name] = factory


def make_train_backend(name: str) -> TrainBackend:
    try:
        factory = _TRAIN_BACKENDS[name]
    except KeyError:
        raise ValueError(
            f"unknown train backend {name!r}; registered backends: "
            f"{available_train_backends()}") from None
    return factory()


def available_train_backends() -> list[str]:
    return sorted(_TRAIN_BACKENDS)


register_train_backend("python", PythonLoopBackend)
register_train_backend("vmap", VmapBackend)
register_train_backend("shard", ShardBackend)


# --------------------------------------------------------------------------
# The orchestrator glue: submit at delivery, collect at the timer
# --------------------------------------------------------------------------
class BatchTrainer:
    """Opportunistic batching without touching the event calendar.

    A session's training *input* is fully known the moment its downlink
    delivers (``ServerCore.schedule_training`` runs then); only the
    *result* is deferred by ``train_time_ns``.  So the core submits the
    input immediately and collects at the timer — and because every local
    step is deterministic and independent, ``collect`` may flush all
    currently-pending submissions as one backend call without perturbing
    any event time or order.  In a sync round the whole roster's downlinks
    usually land before the fastest client finishes training, so the first
    ``collect`` trains the entire round in one vmapped batch; stragglers
    whose models arrive later simply join the next flush.
    """

    def __init__(self, model: ClientModel, backend: TrainBackend,
                 client_index: dict[str, int]):
        self.model = model
        self.backend = backend
        self.client_index = dict(client_index)
        self._template = model.init_params()
        self._pending: list[tuple[Any, np.ndarray, int, int]] = []
        self._results: dict[Any, tuple[Any, Any, dict]] = {}
        #: Flush sizes, newest last — benchmarks read this to report how
        #: much batching the event schedule actually allowed.
        self.batch_sizes: list[int] = []

    def submit(self, key: Any, addr: str, params_tree: Any,
               round_idx: int) -> None:
        """Register one session's training input (model just delivered)."""
        if key in self._results:
            raise RuntimeError(f"duplicate submit for session key {key!r}")
        try:
            idx = self.client_index[addr]
        except KeyError:
            raise KeyError(f"no model client index for {addr!r}") from None
        self._pending.append((key, params_tree, idx, int(round_idx)))

    def flush(self) -> None:
        """Train every pending submission as one backend call."""
        if not self._pending:
            return
        pending, self._pending = self._pending, []
        stack = np.stack([flatten_to_vector(tree) for _, tree, _, _ in
                          pending]).astype(np.float32, copy=False)
        client_idx = np.asarray([i for _, _, i, _ in pending], np.int32)
        round_idx = np.asarray([r for _, _, _, r in pending], np.int32)
        new_stack, metrics = self.backend.train(
            self.model, stack, client_idx, round_idx)
        self.batch_sizes.append(len(pending))
        for j, (key, tree, _, _) in enumerate(pending):
            new_tree = unflatten_from_vector(
                np.asarray(new_stack[j], np.float32), self._template)
            self._results[key] = (tree, new_tree, metrics[j])

    def collect(self, key: Any) -> tuple[Any, Any, dict]:
        """(received_tree, trained_tree, metrics) for a submitted key."""
        if key not in self._results:
            self.flush()
        try:
            return self._results.pop(key)
        except KeyError:
            raise KeyError(f"session key {key!r} was never submitted") from \
                None


def attach_trainer(system: Any, trainer: BatchTrainer) -> int:
    """Wire ``trainer`` into every training site of a built system.

    Returns the number of cores/systems wired: a star's single
    ``ServerCore``, every hierarchical edge cell's nested core (the root
    never trains — its "training" is the cell round), or the gossip
    system itself.
    """
    from repro.core.rounds import FederatedSystem
    from repro.core.topology import GossipSystem, HierSystem
    if isinstance(system, FederatedSystem):
        system.core.batch_trainer = trainer
        return 1
    if isinstance(system, HierSystem):
        for edge in system.edges:
            edge.core.batch_trainer = trainer
        return len(system.edges)
    if isinstance(system, GossipSystem):
        system.batch_trainer = trainer
        return 1
    raise TypeError(f"don't know how to attach a trainer to "
                    f"{type(system).__name__}")
