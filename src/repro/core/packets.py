"""Packet model for the Modified UDP (MUDP) transport.

The paper's sequence header is the triple ``(X, Np, A)``:

* ``X``  -- sequence number of this packet, ``1 <= X <= Np`` for data packets.
* ``Np`` -- total number of packets in the transaction.
* ``A``  -- address of the sender of this packet.

Control packets reuse the triple:

* success acknowledgement is ``(0, 0, A_receiver)`` (paper §IV.B),
* a NACK for missing sequence ``X`` is ``(X, Np, A_receiver)`` flagged as NACK.

Checksums guard payload integrity (the paper assumes NS3 delivers intact
packets; a real UDP deployment needs this, so it is first-class here and
backed by the Pallas ``checksum`` kernel in the production path).
"""

from __future__ import annotations

import dataclasses
import enum
import struct
import zlib
from typing import Optional


class PacketKind(enum.IntEnum):
    """Wire discriminator for every packet the framework can emit."""

    DATA = 0        # carries a payload chunk, header (X, Np, A)
    ACK_OK = 1      # transaction complete, header (0, 0, A)
    NACK = 2        # receiver reports missing sequence X, header (X, Np, A)
    # TCP-baseline control packets.
    SYN = 3
    SYN_ACK = 4
    ACK = 5         # cumulative ack (TCP baseline), X = next expected seq
    FIN = 6
    # FL orchestration control.
    ROUND_BEGIN = 7
    HEARTBEAT = 8
    # Forward-error-correction (mudp+fec): XOR parity over a block of DATA
    # packets, header (parity_index, n_parity, A).
    PARITY = 9


# Wire header: kind(B) seq(I) total(I) txn(I) payload_len(I) checksum(I) = 21B,
# plus a 16-byte fixed-width address field -> 37 bytes, comparable to a real
# UDP/IP header budget.
_HEADER_FMT = "!BIIII"
_ADDR_BYTES = 16
HEADER_BYTES = struct.calcsize(_HEADER_FMT) + 4 + _ADDR_BYTES


def checksum32(payload: bytes) -> int:
    """Adler-32 checksum (same family as the Pallas kernel's blockwise sum)."""
    return zlib.adler32(payload) & 0xFFFFFFFF


@dataclasses.dataclass(frozen=True, slots=True)
class Packet:
    """One simulated datagram.

    ``seq``/``total``/``addr`` are the paper's ``(X, Np, A)``. ``txn`` tags the
    transaction (one model transfer) so concurrent transfers from many FL
    clients never collide at the server. ``attempt`` counts (re)transmissions
    of this sequence number — it exists only for loss-model determinism and
    does not travel on the wire (NS3 equivalent: the send event identity).
    """

    kind: PacketKind
    seq: int
    total: int
    addr: str
    txn: int = 0
    payload: bytes = b""
    checksum: int = 0
    attempt: int = 0

    # -- paper-visible representation ------------------------------------
    def header(self) -> tuple[int, int, str]:
        """The paper's ``(X, Np, A)`` triple."""
        return (self.seq, self.total, self.addr)

    def __str__(self) -> str:  # e.g. "(2, 4, 10.1.2.4)" as printed in Figs 5-7
        return f"({self.seq}, {self.total}, {self.addr})"

    # -- sizes -------------------------------------------------------------
    @property
    def size_bytes(self) -> int:
        return HEADER_BYTES + len(self.payload)

    @property
    def is_last(self) -> bool:
        return self.kind == PacketKind.DATA and self.seq == self.total

    def verify(self) -> bool:
        return checksum32(self.payload) == self.checksum

    # -- wire codec (used by the checkpoint journal and tests) -------------
    def to_bytes(self) -> bytes:
        addr = self.addr.encode("utf-8")[:_ADDR_BYTES].ljust(_ADDR_BYTES, b"\x00")
        head = struct.pack(
            _HEADER_FMT, int(self.kind), self.seq, self.total, self.txn,
            len(self.payload),
        )
        return head + struct.pack("!I", self.checksum) + addr + self.payload

    @staticmethod
    def from_bytes(raw: bytes) -> "Packet":
        base = struct.calcsize(_HEADER_FMT)
        kind, seq, total, txn, plen = struct.unpack(_HEADER_FMT, raw[:base])
        (csum,) = struct.unpack("!I", raw[base:base + 4])
        addr = raw[base + 4:base + 4 + _ADDR_BYTES].rstrip(b"\x00").decode("utf-8")
        payload = raw[base + 4 + _ADDR_BYTES:base + 4 + _ADDR_BYTES + plen]
        return Packet(PacketKind(kind), seq, total, addr, txn, payload, csum)


def make_data_packet(seq: int, total: int, addr: str, payload: bytes,
                     txn: int = 0) -> Packet:
    return Packet(PacketKind.DATA, seq, total, addr, txn, payload,
                  checksum32(payload))


def make_ack_ok(addr: str, txn: int = 0) -> Packet:
    """Paper §IV.B: 'send an acknowledgement with sequence number (0, 0, A)'."""
    return Packet(PacketKind.ACK_OK, 0, 0, addr, txn)


def make_nack(missing_seq: int, total: int, addr: str, txn: int = 0,
              payload: bytes = b"") -> Packet:
    """NACK for one missing sequence number (paper sends one per gap)."""
    return Packet(PacketKind.NACK, missing_seq, total, addr, txn, payload,
                  checksum32(payload))
