"""Per-client transport telemetry: the observability half of the loop.

The paper's future work asks for "optimization of the Modified UDP ... to
improve efficiency while ensuring reliability"; optimizing *per client*
first requires seeing each client.  This module is the seeing: a
:class:`Telemetry` plane owned by :class:`repro.core.server.ServerCore`
that folds every transaction completion (and every explicit decode
degradation) into per-client EWMA estimators of

* ``loss_rate`` — retransmissions per data packet sent (the observable
  proxy for path loss; FEC repairs that avoided a retransmission
  correctly do not count),
* ``rtt_ns`` — whole-transaction latency (start to completion in
  simulated time),
* ``retransmissions`` — the per-transaction retransmission count,
* ``goodput_bps`` — payload bits delivered per second of transaction
  time,

plus monotonic counters (``txns``, ``failures``, ``decode_errors``).
Snapshots are immutable :class:`ClientHealth` records — what
:mod:`repro.core.control` policies consume and what ``RoundResult.
client_health`` exports.

Determinism contract: the plane is **simulated-time-driven and pure** — it
consumes no RNG, schedules no events, and touches no simulator stats, so
it observes identical transactions (and produces bit-identical snapshots)
under the ``per_packet`` and ``batched`` engines, and distributionally
equivalent ones under ``flow``.  That purity is also why it is always on:
recording cannot move any pinned digest.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

#: Default EWMA smoothing factor: each observation contributes a quarter,
#: so ~9 transactions cover 90% of the estimate — fast enough to track a
#: bursty edge link inside a short benchmark, smooth enough that one lucky
#: transaction does not flap a control policy.
DEFAULT_ALPHA = 0.25


@dataclasses.dataclass(frozen=True, slots=True)
class ClientHealth:
    """One client's health snapshot (immutable; safe to export/compare)."""

    addr: str
    #: Observed transactions (completed + failed).
    txns: int = 0
    #: Transactions that exhausted transport retries.
    failures: int = 0
    #: Payloads from this client explicitly degraded to zero-fill.
    decode_errors: int = 0
    #: EWMA of retransmissions / data packets per transaction.
    loss_rate: float = 0.0
    #: EWMA of whole-transaction latency (simulated ns).
    rtt_ns: float = 0.0
    #: EWMA of per-transaction retransmission count.
    retransmissions: float = 0.0
    #: EWMA of payload bits per second of transaction time.
    goodput_bps: float = 0.0
    #: Simulated time of the most recent observation.
    last_update_ns: int = 0


class _Cell:
    """Mutable per-client accumulator behind the frozen snapshots."""

    __slots__ = ("txns", "failures", "decode_errors", "loss_rate", "rtt_ns",
                 "retransmissions", "goodput_bps", "last_update_ns")

    def __init__(self) -> None:
        self.txns = 0
        self.failures = 0
        self.decode_errors = 0
        self.loss_rate = 0.0
        self.rtt_ns = 0.0
        self.retransmissions = 0.0
        self.goodput_bps = 0.0
        self.last_update_ns = 0


class Telemetry:
    """Per-client EWMA estimators fed by the server core.

    All methods are O(1) per observation and allocation-light; the plane
    sits on the transaction-completion path of every engine.
    """

    def __init__(self, alpha: float = DEFAULT_ALPHA):
        if not 0.0 < alpha <= 1.0:
            raise ValueError(f"telemetry alpha must be in (0, 1], "
                             f"got {alpha}")
        self.alpha = float(alpha)
        self._cells: dict[str, _Cell] = {}

    def _cell(self, addr: str) -> _Cell:
        cell = self._cells.get(addr)
        if cell is None:
            cell = self._cells[addr] = _Cell()
        return cell

    def _ewma(self, old: float, obs: float, first: bool) -> float:
        # The first observation initializes the estimate (no cold-start
        # bias toward zero); afterwards the standard recursion.
        if first:
            return float(obs)
        return (1.0 - self.alpha) * old + self.alpha * float(obs)

    # -- feed ---------------------------------------------------------------
    def observe_txn(self, addr: str, *, now_ns: int, duration_ns: int,
                    data_sent: int, retransmissions: int,
                    payload_bytes: int, completed: bool = True) -> None:
        """Fold one finished (or failed) transaction for ``addr``."""
        cell = self._cell(addr)
        first = cell.txns == 0
        loss = retransmissions / max(1, data_sent)
        goodput = (payload_bytes * 8e9 / duration_ns
                   if completed and duration_ns > 0 else 0.0)
        cell.loss_rate = self._ewma(cell.loss_rate, loss, first)
        cell.rtt_ns = self._ewma(cell.rtt_ns, max(0, duration_ns), first)
        cell.retransmissions = self._ewma(cell.retransmissions,
                                          retransmissions, first)
        cell.goodput_bps = self._ewma(cell.goodput_bps, goodput, first)
        cell.txns += 1
        if not completed:
            cell.failures += 1
        cell.last_update_ns = int(now_ns)

    def observe_decode_error(self, addr: str, *, now_ns: int) -> None:
        """One payload from ``addr`` was explicitly degraded to zero-fill."""
        cell = self._cell(addr)
        cell.decode_errors += 1
        cell.last_update_ns = int(now_ns)

    # -- snapshots ----------------------------------------------------------
    def snapshot(self, addr: str) -> Optional[ClientHealth]:
        """The client's current :class:`ClientHealth`, or None if this
        plane has never observed it."""
        cell = self._cells.get(addr)
        if cell is None:
            return None
        return ClientHealth(
            addr=addr, txns=cell.txns, failures=cell.failures,
            decode_errors=cell.decode_errors, loss_rate=cell.loss_rate,
            rtt_ns=cell.rtt_ns, retransmissions=cell.retransmissions,
            goodput_bps=cell.goodput_bps,
            last_update_ns=cell.last_update_ns)

    def snapshot_all(self) -> dict[str, ClientHealth]:
        """Every observed client's snapshot, sorted by address (the sort
        keeps exports deterministic regardless of observation order)."""
        return {addr: self.snapshot(addr)
                for addr in sorted(self._cells)}

    def forget(self, addr: str) -> None:
        """Elastic removal: a later client at a recycled address must not
        inherit the dead client's history."""
        self._cells.pop(addr, None)
