"""Plain UDP baseline: fire-and-forget, no recovery.

The comparison the paper defers to future work ("a comparison between the
traditional UDP protocol and the Modified UDP protocol will be simulated").
The receiver delivers whatever subset arrived once it sees the last packet or
its deadline expires; missing chunks are the FL layer's problem (it zero-fills
them, which is what silently corrupts the global model and motivates MUDP).
"""

from __future__ import annotations

from typing import Callable, Optional

from repro.core.mudp import TxnStats, ingest_data_run
from repro.core.packets import Packet, PacketKind
from repro.core.simulator import Node, Simulator, Timer


class UdpSender:
    """Sends every packet once. Completes immediately after the burst."""

    def __init__(self, sim: Simulator, node: Node, dest: Node,
                 packets: list[Packet], *,
                 on_complete: Optional[Callable[["UdpSender"], None]] = None):
        self.sim, self.node, self.dest = sim, node, dest
        self.packets = packets
        self.stats = TxnStats(txn=packets[0].txn,
                              total_packets=packets[0].total)
        self.on_complete = on_complete

    def start(self) -> None:
        self.stats.start_ns = self.sim.now_ns
        self.stats.data_sent += len(self.packets)
        # Fire-and-forget is the ideal flight: one vectorized burst under
        # the batched engine, a plain loop of sends otherwise.
        self.node.send_burst(self.packets, self.dest)
        self.stats.end_ns = self.sim.now_ns
        self.stats.completed = True
        if self.on_complete is not None:
            self.on_complete(self)


class UdpReceiver:
    """Delivers the (possibly incomplete) packet map per transaction.

    Delivery triggers on the last packet's arrival, or on a deadline measured
    from the first packet of the transaction (covers a lost tail).
    ``on_deliver(sender_addr, txn, packets, total)``.
    """

    def __init__(self, sim: Simulator, node: Node, *,
                 deadline_ns: int = 30_000_000_000,
                 on_deliver: Optional[
                     Callable[[str, int, dict[int, Packet], int], None]] = None):
        self.sim, self.node = sim, node
        self.deadline_ns = deadline_ns
        self.on_deliver = on_deliver
        self._rx: dict[tuple[str, int], dict[int, Packet]] = {}
        self._total: dict[tuple[str, int], int] = {}
        self._timers: dict[tuple[str, int], Timer] = {}
        self._done: set[tuple[str, int]] = set()
        node.register(self._on_packet, bulk=self._ingest_run)

    def _ingest_run(self, pkts: list, i: int, j: int, arrivals: list) -> int:
        """Batched-engine fast path: one call for a run of consecutive
        non-last DATA packets — exactly the per-packet verify-and-store
        (or silent post-delivery consumption) that :meth:`_on_packet`
        performs, minus the call-per-packet overhead.

        A transaction's *first* packet is never bulk-consumed: it arms the
        deadline timer, and the bulk contract forbids scheduling (tie
        numbers must only be consumed in true event order)."""
        p0 = pkts[i]
        if p0.kind != PacketKind.DATA:
            return 0
        key = (p0.addr, p0.txn)
        addr, txn = key
        k = i
        if key in self._done:
            # Late duplicates after delivery: consumed with no effect.
            while k < j:
                p = pkts[k]
                if p.kind != PacketKind.DATA or p.addr != addr or p.txn != txn:
                    break
                k += 1
            return k - i
        rx = self._rx.get(key)
        if rx is None:
            return 0
        return ingest_data_run(pkts, k, j, rx, addr, txn)

    def _on_packet(self, pkt: Packet) -> bool:
        if pkt.kind != PacketKind.DATA:
            return False
        key = (pkt.addr, pkt.txn)
        if key in self._done:
            return True
        if key not in self._rx:
            self._rx[key] = {}
            self._total[key] = pkt.total
            self._timers[key] = self.sim.schedule(
                self.deadline_ns, lambda: self._deliver(key))
        if pkt.verify():
            self._rx[key][pkt.seq] = pkt
        if pkt.is_last:
            self._deliver(key)
        return True

    def _deliver(self, key: tuple[str, int]) -> None:
        if key in self._done or key not in self._rx:
            return
        self._done.add(key)
        self._timers[key].cancel()
        packets, total = self._rx.pop(key), self._total.pop(key)
        if self.on_deliver is not None:
            self.on_deliver(key[0], key[1], packets, total)


def reassemble_partial(packets: dict[int, Packet], total: int) -> bytes:
    """Best-effort reconstruction with zero-filled gaps (UDP baseline).

    Chunk size is inferred from any non-final packet (all equal by
    construction); a missing tail is sized the same way.
    """
    if not packets:
        return b""
    sizes = [len(p.payload) for s, p in packets.items() if s != total]
    chunk = max(sizes) if sizes else len(packets[next(iter(packets))].payload)
    out = []
    for seq in range(1, total + 1):
        if seq in packets:
            out.append(packets[seq].payload)
        elif seq < total:
            out.append(b"\x00" * chunk)
        else:  # unknown-length missing tail: assume a full chunk
            out.append(b"\x00" * chunk)
    return b"".join(out)


# --------------------------------------------------------------------------
# Flow-engine model (Simulator(engine="flow")) — see repro.core.flow
# --------------------------------------------------------------------------
def _udp_flow_model(ctx):
    """Analytic fire-and-forget transaction: one Binomial picks the loss
    count, a keyed subset picks *which* sequences vanished (the zero-filled
    gaps feed the FL layer), and the receiver delivers at the last arrival
    — or at the deadline armed by the first surviving packet when the final
    packet never shows."""
    from repro.core.flow import FlowOutcome, PH_LOSS, PH_REORD, reorder_prob
    n = ctx.total
    ctx.stats.data_sent += n
    first_arr, last_arr = ctx.fwd.occupy(ctx.sim.now_ns, ctx.sizes)
    k = ctx.binom(n, ctx.p, PH_LOSS, 0)
    missing = ctx.pick_missing(k)
    dropped_bytes = sum(ctx.sizes[s - 1] for s in missing)
    ctx.count(ctx.fwd, PacketKind.DATA, n, ctx.data_bytes, k, dropped_bytes)
    now = ctx.sim.now_ns   # sender is done the moment the burst is queued
    if k >= n:
        return FlowOutcome(end_ns=now, completed=True)   # silence: no rx
    pkts = {p.seq: p for p in ctx.packets if p.seq not in missing}
    if n in missing:
        # Deadline timer armed by the first surviving arrival.  By the
        # time it fires every surviving packet is long in, so the delivery
        # holds all of them.
        s0 = min(pkts)
        ser = ctx.fwd.link.serialization_ns(ctx.chunk)
        t_del = first_arr + (s0 - 1) * ser + ctx.cfg.udp_deadline_ns
    else:
        t_del = last_arr
        # Delivery fires the instant the last packet lands, and jitter can
        # push an earlier packet *past* it: that packet misses the
        # delivery (its payload zero-fills) and its later arrival is a
        # consumed late duplicate.  Pairwise overtake probability per
        # surviving seq, exactly the spurious-NACK geometry of the mudp
        # flow model.
        jit = ctx.fwd.link.jitter_ns
        if jit > 0 and n >= 2:
            ser = ctx.fwd.link.serialization_ns(ctx.chunk)
            for i in range(1, n):
                if i in missing:
                    continue
                r = reorder_prob(jit, (n - i) * ser)
                if r > 0.0 and ctx.uniform(PH_REORD, i) < r:
                    del pkts[i]
    return FlowOutcome(end_ns=now, completed=True, deliver_ns=t_del,
                       packets=pkts, total=n, complete=len(pkts) == n)


from repro.core import flow as _flow  # noqa: E402  (registration at bottom)

_flow.register_flow_model("udp", _udp_flow_model)
