"""Plain UDP baseline: fire-and-forget, no recovery.

The comparison the paper defers to future work ("a comparison between the
traditional UDP protocol and the Modified UDP protocol will be simulated").
The receiver delivers whatever subset arrived once it sees the last packet or
its deadline expires; missing chunks are the FL layer's problem (it zero-fills
them, which is what silently corrupts the global model and motivates MUDP).
"""

from __future__ import annotations

from typing import Callable, Optional

from repro.core.mudp import TxnStats, ingest_data_run
from repro.core.packets import Packet, PacketKind
from repro.core.simulator import Node, Simulator, Timer


class UdpSender:
    """Sends every packet once. Completes immediately after the burst."""

    def __init__(self, sim: Simulator, node: Node, dest: Node,
                 packets: list[Packet], *,
                 on_complete: Optional[Callable[["UdpSender"], None]] = None):
        self.sim, self.node, self.dest = sim, node, dest
        self.packets = packets
        self.stats = TxnStats(txn=packets[0].txn,
                              total_packets=packets[0].total)
        self.on_complete = on_complete

    def start(self) -> None:
        self.stats.start_ns = self.sim.now_ns
        self.stats.data_sent += len(self.packets)
        # Fire-and-forget is the ideal flight: one vectorized burst under
        # the batched engine, a plain loop of sends otherwise.
        self.node.send_burst(self.packets, self.dest)
        self.stats.end_ns = self.sim.now_ns
        self.stats.completed = True
        if self.on_complete is not None:
            self.on_complete(self)


class UdpReceiver:
    """Delivers the (possibly incomplete) packet map per transaction.

    Delivery triggers on the last packet's arrival, or on a deadline measured
    from the first packet of the transaction (covers a lost tail).
    ``on_deliver(sender_addr, txn, packets, total)``.
    """

    def __init__(self, sim: Simulator, node: Node, *,
                 deadline_ns: int = 30_000_000_000,
                 on_deliver: Optional[
                     Callable[[str, int, dict[int, Packet], int], None]] = None):
        self.sim, self.node = sim, node
        self.deadline_ns = deadline_ns
        self.on_deliver = on_deliver
        self._rx: dict[tuple[str, int], dict[int, Packet]] = {}
        self._total: dict[tuple[str, int], int] = {}
        self._timers: dict[tuple[str, int], Timer] = {}
        self._done: set[tuple[str, int]] = set()
        node.register(self._on_packet, bulk=self._ingest_run)

    def _ingest_run(self, pkts: list, i: int, j: int, arrivals: list) -> int:
        """Batched-engine fast path: one call for a run of consecutive
        non-last DATA packets — exactly the per-packet verify-and-store
        (or silent post-delivery consumption) that :meth:`_on_packet`
        performs, minus the call-per-packet overhead.

        A transaction's *first* packet is never bulk-consumed: it arms the
        deadline timer, and the bulk contract forbids scheduling (tie
        numbers must only be consumed in true event order)."""
        p0 = pkts[i]
        if p0.kind != PacketKind.DATA:
            return 0
        key = (p0.addr, p0.txn)
        addr, txn = key
        k = i
        if key in self._done:
            # Late duplicates after delivery: consumed with no effect.
            while k < j:
                p = pkts[k]
                if p.kind != PacketKind.DATA or p.addr != addr or p.txn != txn:
                    break
                k += 1
            return k - i
        rx = self._rx.get(key)
        if rx is None:
            return 0
        return ingest_data_run(pkts, k, j, rx, addr, txn)

    def _on_packet(self, pkt: Packet) -> bool:
        if pkt.kind != PacketKind.DATA:
            return False
        key = (pkt.addr, pkt.txn)
        if key in self._done:
            return True
        if key not in self._rx:
            self._rx[key] = {}
            self._total[key] = pkt.total
            self._timers[key] = self.sim.schedule(
                self.deadline_ns, lambda: self._deliver(key))
        if pkt.verify():
            self._rx[key][pkt.seq] = pkt
        if pkt.is_last:
            self._deliver(key)
        return True

    def _deliver(self, key: tuple[str, int]) -> None:
        if key in self._done or key not in self._rx:
            return
        self._done.add(key)
        self._timers[key].cancel()
        packets, total = self._rx.pop(key), self._total.pop(key)
        if self.on_deliver is not None:
            self.on_deliver(key[0], key[1], packets, total)


def reassemble_partial(packets: dict[int, Packet], total: int) -> bytes:
    """Best-effort reconstruction with zero-filled gaps (UDP baseline).

    Chunk size is inferred from any non-final packet (all equal by
    construction); a missing tail is sized the same way.
    """
    if not packets:
        return b""
    sizes = [len(p.payload) for s, p in packets.items() if s != total]
    chunk = max(sizes) if sizes else len(packets[next(iter(packets))].payload)
    out = []
    for seq in range(1, total + 1):
        if seq in packets:
            out.append(packets[seq].payload)
        elif seq < total:
            out.append(b"\x00" * chunk)
        else:  # unknown-length missing tail: assume a full chunk
            out.append(b"\x00" * chunk)
    return b"".join(out)
