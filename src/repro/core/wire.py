"""Composable wire-plane: self-describing codec pipelines.

The single-stage ``Codec`` string on :class:`TransportConfig` couples three
decisions that scale differently — *what* to ship (weights vs deltas), *how*
to shrink it (sparsify, quantize), and *how the receiver knows what it got*
(out-of-band config vs the wire itself).  This module separates them:

* :class:`Stage` — one reversible transform over a flat float32 vector with
  a per-endpoint/per-direction mutable state slot.  Stages compose:
  ``delta`` (ship trained - received), ``ef`` (error-feedback residual,
  wrapping everything downstream of it), ``topk(f)`` (sparsify to values +
  index sidecar), ``int8(b)`` (blockwise absmax quantization), ``raw`` /
  ``hex`` (terminal serializers).
* :class:`Pipeline` — an ordered stage list parsed from a ``|``-separated
  spec string (``"delta|ef|topk(0.01)|int8(1024)"``) with **derived**
  capability flags (:class:`PipelineCaps`: lossless, stateful, estimated
  wire ratio, delta-domain) so callers branch on what a pipeline guarantees,
  never on its spelling.
* :class:`WireHeader` — a versioned header prepended to every
  self-describing payload: magic, wire version, the canonical pipeline spec,
  and each stage's dynamic per-message params.  The receiver rebuilds the
  pipeline **from the wire** via the stage registry and decodes with zero
  out-of-band knowledge; malformed or truncated payloads raise
  :class:`WireDecodeError` with a reason instead of being swallowed by a
  bare ``except``.
* the registry — ``register_stage`` / ``parse_pipeline`` /
  ``available_stages``, mirroring the transport registry, so third-party
  stages participate in specs and in wire negotiation for free.

**Legacy mode.**  ``Pipeline`` also runs *headerless* (``self_describing=
False``): the terminal stage emits exactly the historical ``Codec`` wire
bytes (``repro.core.compression``) and transform stages touch only local
state.  This is how ``TransportConfig(codec="int8")`` keeps producing
byte-identical traffic — the 24 pinned orchestrator-equivalence digests are
the proof that the redesign is a pure refactor on that path.

State model: a :class:`Pipeline` object is immutable/shareable; everything
mutable (delta references, EF residuals) lives in a :class:`PipelineState`
created per (endpoint, direction) via :meth:`Pipeline.new_state`.  Decode
is stateless for every built-in stage, which is what makes decoding from
the header alone possible.

**Batch plane.**  Every stage also exposes ``encode_batch`` /
``decode_batch`` over stacked ``(N, P)`` matrices (base-class fallbacks
loop; the built-ins override with vectorized numpy and optional Pallas
kernel paths — see :func:`set_batch_backend`).  :meth:`Pipeline.
encode_batch`, :meth:`Pipeline.decode_batch` and
:func:`decode_payload_batch` walk all N clients through each stage in one
call: per-client state (delta refs, EF residuals) is gathered into an
``(N, P)`` slab on entry and scattered back into the per-client
:class:`PipelineState` slots on exit, so batched and looped execution see
the exact same state evolution.  The contract is strict: batch encode is
byte-identical to the per-item loop and batch decode bit-identical, which
is what lets the orchestrator batch by default without moving any of the
24 pinned equivalence digests.
"""

from __future__ import annotations

import abc
import binascii
import struct
from typing import Callable, Optional, Sequence

import numpy as np

from repro.core.compression import (MAX_DECODE_PARAMS, HexCodec, Int8Codec,
                                    RawCodec, TopKCodec, dequantize_int8,
                                    dequantize_int8_batch, quantize_int8,
                                    quantize_int8_batch, topk_sparsify)

_U16 = struct.Struct("!H")
_U32 = struct.Struct("!I")
_U64 = struct.Struct("!Q")

#: Wire magic + current header version.  Bump the version for any layout
#: change; receivers reject versions they do not understand explicitly.
WIRE_MAGIC = b"WP"
WIRE_VERSION = 1

#: Body dtypes a terminal stage may emit, indexed by the code stored in the
#: header.  Codes are append-only (removing or reordering would silently
#: reinterpret old payloads).
_BODY_DTYPES: tuple[str, ...] = ("<f4", "i1", "u1", "<u4")


class WireError(ValueError):
    """Mis-use of the wire API (bad spec, bad composition, bad config)."""


class WireDecodeError(WireError):
    """A payload that cannot be decoded: wrong magic, unknown version or
    stage, truncated header/params/body, or a count mismatch inside a
    stage.  The FL layer degrades these *explicitly* (zero-fill + counter),
    anything else propagates."""


def _body_dtype_code(dtype: np.dtype) -> int:
    s = np.dtype(dtype).str.lstrip("=|")
    for i, d in enumerate(_BODY_DTYPES):
        if np.dtype(d) == np.dtype(dtype):
            return i
    raise WireError(f"unsupported body dtype {s!r}")


# --------------------------------------------------------------------------
# Per-direction state
# --------------------------------------------------------------------------
class PipelineState:
    """Mutable state for one (endpoint, direction): one dict slot per stage.

    Created by :meth:`Pipeline.new_state`; the orchestrator keeps one per
    client per direction, which is where delta references and EF residuals
    live (they used to live on ``FLClient`` / inside ``ServerCore``).
    """

    def __init__(self, n_stages: int):
        self.slots: list[dict] = [{} for _ in range(n_stages)]

    def copy(self) -> "PipelineState":
        """Slot-shallow copy: stages replace slot values wholesale (never
        mutate arrays in place), so copying the dicts is enough to run a
        what-if encode without touching the live state."""
        out = PipelineState(len(self.slots))
        out.slots = [dict(s) for s in self.slots]
        return out

    def __repr__(self) -> str:
        keys = [sorted(s) for s in self.slots]
        return f"PipelineState({keys})"


# --------------------------------------------------------------------------
# Stage ABC
# --------------------------------------------------------------------------
class Stage(abc.ABC):
    """One composable wire transform over numpy arrays.

    ``encode(arr, slot) -> (arr_out, params)``: transform the array and
    return the dynamic per-message params the *decoder* needs (goes into
    the :class:`WireHeader`; empty for stages that are self-inverse).
    ``decode(arr, params, slot)`` inverts it.  Both sides receive a mutable
    per-(endpoint, direction) ``slot`` dict; decode must work with an empty
    slot for the built-ins (wire negotiation decodes with fresh state).

    Class attributes drive the derived pipeline capabilities: ``lossless``
    (decode∘encode is the identity), ``stateful`` (encode reads/writes the
    slot), ``est_ratio`` (estimated encoded-bytes / input-bytes, used by
    planners and benchmarks — an estimate, not a promise).

    **Batch twins.**  ``encode_batch`` / ``decode_batch`` process N stacked
    items at once; the base-class versions loop over ``encode`` /
    ``decode`` and set ``batch_capable = False``, which makes the pipeline
    take the per-item path.  A vectorized override must (a) set
    ``batch_capable = True``, (b) be byte-identical on encode and
    bit-identical on decode to the loop (the parity sweep in
    ``tests/test_wire_batch.py`` pins every built-in), and (c) raise
    :class:`WireDecodeError` when the group's per-item params are not
    uniform enough to vectorize — the caller then degrades to per-item
    decode.  A subclass that overrides ``encode``/``decode`` without
    overriding the batch twins must reset ``batch_capable = False`` or the
    inherited vectorized twin will silently bypass its override.
    """

    name: str = "abstract"
    lossless: bool = True
    stateful: bool = False
    est_ratio: float = 1.0
    # The encoded array is a difference against a reference the decoder
    # does not reconstruct (decode stays in the delta domain).  Drives
    # PipelineCaps.delta_domain — declare it on third-party delta-like
    # stages so the server aggregates them correctly; such stages receive
    # the reference via slot["ref"] from Pipeline.set_reference.
    delta_domain: bool = False
    # Encode output is not coordinate-aligned with its input (reordered,
    # re-lengthed, or re-typed).  An `ef` stage must not follow one: its
    # residual would be added across mismatched coordinates.
    remaps_coordinates: bool = False

    @abc.abstractmethod
    def encode(self, arr: np.ndarray, slot: dict
               ) -> tuple[np.ndarray, bytes]: ...

    @abc.abstractmethod
    def decode(self, arr: np.ndarray, params: bytes,
               slot: dict) -> np.ndarray: ...

    def spec(self) -> str:
        """Canonical spec token; ``parse_stage(s.spec())`` reconstructs."""
        return self.name

    # -- legacy (headerless) terminal serialization -------------------------
    # Implemented only by the classic codec stages (raw/hex/int8/topk):
    # byte-identical to the historical repro.core.compression wire formats.
    legacy_codec = None   # a compression.Codec instance, or None

    def legacy_encode(self, vec: np.ndarray) -> bytes:
        if self.legacy_codec is None:
            raise WireError(f"stage {self.name!r} cannot terminate a "
                            f"legacy (headerless) pipeline")
        return self.legacy_codec.encode(vec)

    def legacy_decode(self, data: bytes) -> np.ndarray:
        if self.legacy_codec is None:
            raise WireError(f"stage {self.name!r} cannot terminate a "
                            f"legacy (headerless) pipeline")
        return self.legacy_codec.decode(data)

    # -- batch plane ---------------------------------------------------------
    #: True when encode_batch/decode_batch are genuinely vectorized.  The
    #: base-class fallbacks below just loop — correct for any stage — so a
    #: Pipeline only takes the one-call batched walk when EVERY stage
    #: opts in.
    batch_capable: bool = False

    def encode_batch(self, batch: np.ndarray, slots: Sequence[dict]
                     ) -> tuple[np.ndarray, list[bytes]]:
        """Encode N stacked items: ``(N, P) -> ((N, P'), [params] * N)``.

        ``slots[i]`` is item i's per-(endpoint, direction) state dict —
        the same object :meth:`encode` would receive.  Fallback: loops
        over :meth:`encode` one row at a time and stacks the outputs
        (raising :class:`WireError` if a stage produces ragged rows).
        """
        rows, params = [], []
        for i in range(batch.shape[0]):
            arr, p = self.encode(batch[i], slots[i])
            rows.append(arr)
            params.append(p)
        return _stack_rows(rows, self.spec()), params

    def decode_batch(self, arr: np.ndarray, params: Sequence[bytes],
                     slots: Sequence[dict]) -> np.ndarray:
        """Inverse of :meth:`encode_batch` over a rectangular group.

        Callers only attempt batch decode on groups that are already
        uniform in spec, body dtype and body length; an override must
        still verify the per-item *params* agree (e.g. one topk count for
        the whole group) and raise :class:`WireDecodeError` otherwise —
        the caller then isolates the odd item via per-item decode.
        """
        rows = [self.decode(arr[i], params[i], slots[i])
                for i in range(arr.shape[0])]
        return _stack_rows(rows, self.spec())


def _require_f4(arr: np.ndarray, stage: str) -> np.ndarray:
    arr = np.asarray(arr)
    if arr.dtype != np.dtype("<f4"):
        raise WireError(f"stage {stage!r} requires a float32 input, got "
                        f"{arr.dtype} (check stage order in the spec)")
    return arr


def _require_f4_batch(batch: np.ndarray, stage: str) -> np.ndarray:
    batch = np.asarray(batch)
    if batch.ndim != 2:
        raise WireError(f"stage {stage!r} batch input must be 2-D (N, P), "
                        f"got shape {batch.shape}")
    if batch.dtype != np.dtype("<f4"):
        raise WireError(f"stage {stage!r} requires a float32 input, got "
                        f"{batch.dtype} (check stage order in the spec)")
    return batch


def _stack_rows(rows: Sequence[np.ndarray], what: str) -> np.ndarray:
    """Stack per-item outputs into a rectangle, or explain why we cannot."""
    if not rows:
        return np.zeros((0, 0), dtype=np.float32)
    arrs = [np.asarray(r) for r in rows]
    if len({a.shape for a in arrs}) != 1:
        raise WireError(f"{what!r} produced ragged batch rows (shapes "
                        f"{sorted({a.shape for a in arrs})}); batch calls "
                        f"need a rectangle")
    return np.stack(arrs)


# --------------------------------------------------------------------------
# Batch backend: vectorized numpy (default) or Pallas stage kernels
# --------------------------------------------------------------------------
WIRE_BATCH_BACKENDS = ("numpy", "pallas", "auto")

_BATCH_BACKEND = "numpy"

#: Rows per vectorized walk are capped so the chunk's working set stays
#: cache-resident: a monolithic (256, 250k) walk streams every
#: intermediate through DRAM and loses to the per-item loop, while
#: a few-MB chunk keeps the batch plane ahead at every size.  Per-item
#: independence makes chunking invisible to the byte/bit-identity
#: contract.
_BATCH_CHUNK_ELEMS = 1 << 20


def _batch_chunk_rows(row_elems: int) -> int:
    return max(1, _BATCH_CHUNK_ELEMS // max(1, row_elems))


def batch_backend() -> str:
    """The active vectorized-stage backend (``"numpy"`` or ``"pallas"``)."""
    return _BATCH_BACKEND


def set_batch_backend(name: str) -> str:
    """Select the batch-stage backend; returns the previous one.

    ``"numpy"`` (the default) is pure vectorized numpy — byte-identical to
    the per-item loop by construction, requires no device, and is what the
    orchestrator ships with.  ``"pallas"`` routes the hot inner ops
    through the Pallas kernels under ``repro.kernels`` — int8
    (de)quantize at the kernel's 1024 block via ``kernels/quantize``,
    top-k gather/scatter via ``kernels/topk`` — with parity pinned in
    ``tests/test_kernel_parity.py``.  ``"auto"`` resolves to pallas when
    the kernels import (JAX present), else numpy.  Stages whose params
    fall outside a kernel's tile contract (e.g. ``int8(512)``) silently
    keep the numpy path, so flipping the backend never changes bytes.
    """
    global _BATCH_BACKEND
    if name not in WIRE_BATCH_BACKENDS:
        raise WireError(f"unknown batch backend {name!r}; choose from "
                        f"{WIRE_BATCH_BACKENDS}")
    if name == "auto":
        name = "pallas" if (_topk_kernel_ops() is not None
                            and _quantize_kernel_ops() is not None) \
            else "numpy"
    prev = _BATCH_BACKEND
    _BATCH_BACKEND = name
    return prev


_TOPK_OPS = None
_QUANT_OPS = None


def _topk_kernel_ops():
    """``repro.kernels.topk.ops``, or None when JAX is unavailable."""
    global _TOPK_OPS
    if _TOPK_OPS is None:
        try:
            from repro.kernels.topk import ops
            _TOPK_OPS = ops
        except Exception:
            _TOPK_OPS = False
    return _TOPK_OPS or None


def _quantize_kernel_ops():
    """``repro.kernels.quantize.ops``, or None when JAX is unavailable."""
    global _QUANT_OPS
    if _QUANT_OPS is None:
        try:
            from repro.kernels.quantize import ops
            _QUANT_OPS = ops
        except Exception:
            _QUANT_OPS = False
    return _QUANT_OPS or None


# --------------------------------------------------------------------------
# Transform stages: delta, ef
# --------------------------------------------------------------------------
class DeltaStage(Stage):
    """Ship ``vec - reference`` instead of ``vec``.

    The encoder's slot holds the reference (the model the endpoint last
    received), primed by the orchestrator via
    :meth:`Pipeline.set_reference`; an unprimed reference counts as zero,
    so the first update is a delta against the zero model.  Decode is the
    identity: the receiver *aggregates in the delta domain*
    (``PipelineCaps.delta_domain`` tells it to), it never reconstructs the
    sender's full model.
    """

    name = "delta"
    lossless = True
    stateful = True
    est_ratio = 1.0
    delta_domain = True

    def encode(self, arr, slot):
        arr = _require_f4(arr, self.name)
        ref = slot.get("ref")
        if ref is None:
            return arr, b""
        if ref.size != arr.size:
            raise WireError(f"delta reference has {ref.size} params, "
                            f"update has {arr.size}")
        return arr - ref, b""

    def decode(self, arr, params, slot):
        return arr

    batch_capable = True

    def encode_batch(self, batch, slots):
        batch = _require_f4_batch(batch, self.name)
        refs = [slot.get("ref") for slot in slots]
        for ref in refs:
            if ref is not None and ref.size != batch.shape[1]:
                raise WireError(f"delta reference has {ref.size} params, "
                                f"update has {batch.shape[1]}")
        if all(ref is None for ref in refs):
            out = batch
        elif any(ref is None for ref in refs):
            # Mixed priming (some endpoints have never seen a model):
            # subtract row-wise, unprimed rows pass through untouched.
            out = batch.copy()
            for i, ref in enumerate(refs):
                if ref is not None:
                    out[i] = batch[i] - ref
        else:
            out = batch - np.stack(refs)
        return out, [b""] * batch.shape[0]

    def decode_batch(self, arr, params, slots):
        return arr


class ErrorFeedbackStage(Stage):
    """Residual compensation (Seide et al. 2014) for everything downstream.

    Encode-side only: the pipeline transmits ``tail(vec + residual)`` and
    stores ``residual = (vec + residual) - tail_decoded`` in the slot, so
    whatever the lossy tail dropped this message is re-injected into the
    next one.  Decode is the identity.  The tail round-trip is orchestrated
    by :class:`Pipeline` (this stage wraps everything after it).
    """

    name = "ef"
    lossless = True          # adds information back, never discards it
    stateful = True
    est_ratio = 1.0

    def compensate(self, arr: np.ndarray, slot: dict) -> np.ndarray:
        arr = _require_f4(arr, self.name)
        residual = slot.get("residual")
        if residual is None:
            return arr
        return arr + residual

    def update(self, compensated: np.ndarray, decoded: np.ndarray,
               slot: dict) -> None:
        slot["residual"] = compensated - decoded

    def encode(self, arr, slot):     # pragma: no cover - pipeline intercepts
        raise WireError("ef is applied by Pipeline (it wraps the tail); "
                        "it cannot be encoded standalone")

    def decode(self, arr, params, slot):
        return arr

    # Batch twins of compensate/update: one add / one subtract across the
    # (N, P) slab, with residual rows gathered from / scattered back to the
    # per-client slots.  Subclasses overriding compensate/update must
    # override these too (or reset batch_capable) — see the Stage docs.
    batch_capable = True

    def compensate_batch(self, batch: np.ndarray,
                         slots: Sequence[dict]) -> np.ndarray:
        batch = _require_f4_batch(batch, self.name)
        residuals = [slot.get("residual") for slot in slots]
        if all(r is None for r in residuals):
            return batch
        if all(r is not None and r.size == batch.shape[1]
               for r in residuals):
            return batch + np.stack(residuals)
        out = batch.copy()
        for i, r in enumerate(residuals):
            if r is not None:
                out[i] = batch[i] + r
        return out

    def update_batch(self, compensated: np.ndarray, decoded: np.ndarray,
                     slots: Sequence[dict]) -> None:
        diff = compensated - decoded
        for i, slot in enumerate(slots):
            # copy() so a slot never pins the whole (N, P) slab alive.
            slot["residual"] = diff[i].copy()

    def decode_batch(self, arr, params, slots):
        return arr


# --------------------------------------------------------------------------
# Compression stages: topk, int8
# --------------------------------------------------------------------------
class TopKStage(Stage):
    """Keep the ``k = max(1, k_fraction * n)`` largest-|x| entries.

    Encode emits the kept *values* as the flowing vector (so a downstream
    quantizer compresses them further) and ``n`` + the sorted indices as
    params.  Wire cost ≈ ``8 bytes/kept`` alone, less when composed.
    """

    name = "topk"
    lossless = False
    stateful = False
    remaps_coordinates = True     # output = values at per-message indices

    def __init__(self, k_fraction: float = 0.01):
        if not 0.0 < k_fraction <= 1.0:
            raise WireError(f"topk fraction must be in (0, 1], "
                            f"got {k_fraction}")
        self.k_fraction = float(k_fraction)
        self.est_ratio = 2.0 * self.k_fraction   # (u4 idx + f4 val) per kept
        self.legacy_codec = TopKCodec(k_fraction=self.k_fraction)

    def spec(self) -> str:
        return f"topk({self.k_fraction:g})"

    def encode(self, arr, slot):
        arr = _require_f4(arr, self.name)
        k = min(arr.size, max(1, int(arr.size * self.k_fraction)))
        idx, vals = topk_sparsify(arr, k)
        params = _U64.pack(arr.size) + idx.astype("<u4").tobytes()
        return np.ascontiguousarray(vals, dtype="<f4"), params

    def decode(self, arr, params, slot):
        if len(params) < 8:
            raise WireDecodeError("topk params truncated")
        n = _U64.unpack_from(params, 0)[0]
        if n > MAX_DECODE_PARAMS:
            # A wire-controlled u64 must never size an allocation
            # unchecked (and u32 indices cannot address beyond 2**32
            # anyway); the cap lives in repro.core.compression.
            raise WireDecodeError(f"topk n={n} exceeds MAX_DECODE_PARAMS "
                                  f"({MAX_DECODE_PARAMS})")
        idx = np.frombuffer(params, dtype="<u4", offset=8)
        vals = np.asarray(arr, dtype=np.float32)
        if idx.size != vals.size:
            raise WireDecodeError(f"topk index/value count mismatch: "
                                  f"{idx.size} vs {vals.size}")
        if idx.size and (n == 0 or int(idx.max()) >= n):
            raise WireDecodeError("topk index out of range")
        out = np.zeros(n, dtype=np.float32)
        out[idx] = vals
        return out

    batch_capable = True

    def encode_batch(self, batch, slots):
        batch = _require_f4_batch(batch, self.name)
        n_items, n = batch.shape
        k = min(n, max(1, int(n * self.k_fraction)))
        if k <= 0:       # only when n == 0: an empty sparsification
            idx = np.zeros((n_items, 0), dtype="<u4")
            vals = np.zeros((n_items, 0), dtype="<f4")
        else:
            # Selection stays numpy even on the pallas backend:
            # np.argpartition's introselect runs per row under axis=1, so
            # each row picks the same index SET as the 1-D call inside
            # topk_sparsify (pinned by the batch==loop parity sweep), and
            # sorting makes the byte layout identical.
            idx = np.sort(np.argpartition(np.abs(batch), -k, axis=1)[:, -k:],
                          axis=1).astype("<u4")
            ops = _topk_kernel_ops() if _BATCH_BACKEND == "pallas" else None
            if ops is not None:
                vals = np.asarray(ops.topk_gather(batch, idx), dtype="<f4")
            else:
                vals = np.take_along_axis(batch, idx.astype(np.int64),
                                          axis=1)
        head = _U64.pack(n)
        # One bulk tobytes + C-level slicing beats n_items row tobytes.
        blob, step = np.ascontiguousarray(idx).tobytes(), 4 * k
        params = ([head + blob[o:o + step]
                   for o in range(0, n_items * step, step)]
                  if step else [head] * n_items)
        return np.ascontiguousarray(vals, dtype="<f4"), params

    def decode_batch(self, arr, params, slots):
        if not params:
            return np.zeros((0, 0), dtype=np.float32)
        if len(params[0]) < 8:
            raise WireDecodeError("topk params truncated")
        head = params[0][:8]
        if any(len(p) != len(params[0]) or p[:8] != head for p in params):
            # Mixed n or k across the group: degrade to per-item decode
            # rather than guess a rectangle.
            raise WireDecodeError("topk batch group is not uniform")
        n = _U64.unpack_from(head, 0)[0]
        if n > MAX_DECODE_PARAMS:
            raise WireDecodeError(f"topk n={n} exceeds MAX_DECODE_PARAMS "
                                  f"({MAX_DECODE_PARAMS})")
        vals = np.asarray(arr, dtype=np.float32)
        n_items = vals.shape[0]
        k, rem = divmod(len(params[0]) - 8, 4)
        if rem or k != vals.shape[1]:
            raise WireDecodeError(f"topk index/value count mismatch: "
                                  f"{k} vs {vals.shape[1]}")
        if k == 0:
            return np.zeros((n_items, n), dtype=np.float32)
        # Uniform group (checked above): join once, view as a (N, k) u4
        # matrix past the 8-byte heads — no per-item frombuffer.
        buf = np.frombuffer(b"".join(params), dtype=np.uint8)
        idx = np.ascontiguousarray(
            buf.reshape(n_items, 8 + 4 * k)[:, 8:]).view("<u4")
        if n == 0 or int(idx.max()) >= n:
            raise WireDecodeError("topk index out of range")
        ops = _topk_kernel_ops() if _BATCH_BACKEND == "pallas" else None
        if ops is not None:
            return np.asarray(ops.topk_scatter(idx, vals, n),
                              dtype=np.float32)
        out = np.zeros((n_items, n), dtype=np.float32)
        # Flat fancy assignment: duplicate indices resolve last-wins in
        # row-major order, exactly like the per-item out[idx] = vals.
        rows = np.repeat(np.arange(n_items), k)
        out[rows, idx.reshape(-1).astype(np.int64)] = vals.reshape(-1)
        return out


class Int8Stage(Stage):
    """Blockwise absmax int8 quantization (the ``quantize`` kernel's wire
    twin).  Encode emits the int8 values as the flowing array and
    ``n, block`` + per-block float32 scales as params."""

    name = "int8"
    lossless = False
    remaps_coordinates = True     # block padding changes the length

    def __init__(self, block: int = 1024):
        if block < 1:
            raise WireError(f"int8 block must be >= 1, got {block}")
        self.block = int(block)
        self.est_ratio = 0.25 + 4.0 / (4.0 * self.block)  # q + scale share
        self.legacy_codec = Int8Codec(block=self.block)

    def spec(self) -> str:
        return f"int8({self.block})"

    def encode(self, arr, slot):
        arr = _require_f4(arr, self.name)
        q, scales = quantize_int8(arr, self.block)
        params = (_U64.pack(arr.size) + _U32.pack(self.block)
                  + scales.astype("<f4").tobytes())
        return q, params

    def decode(self, arr, params, slot):
        if len(params) < 12:
            raise WireDecodeError("int8 params truncated")
        n = _U64.unpack_from(params, 0)[0]
        block = _U32.unpack_from(params, 8)[0]
        if block < 1:
            raise WireDecodeError("int8 block must be >= 1")
        scales = np.frombuffer(params, dtype="<f4", offset=12)
        q = np.asarray(arr)
        if q.dtype != np.int8:
            raise WireDecodeError(f"int8 body has dtype {q.dtype}, "
                                  f"expected int8")
        nb = -(-n // block) if n else 0
        if scales.size != nb or q.size != nb * block:
            raise WireDecodeError(
                f"int8 count mismatch: n={n} block={block} expects "
                f"{nb} scales / {nb * block} values, got "
                f"{scales.size} / {q.size}")
        return dequantize_int8(q, scales.astype(np.float32), n, block)

    batch_capable = True

    def encode_batch(self, batch, slots):
        batch = _require_f4_batch(batch, self.name)
        n_items, n = batch.shape
        ops = _quantize_kernel_ops() if _BATCH_BACKEND == "pallas" else None
        if ops is not None and self.block == ops.QBLOCK and n:
            q, scales = ops.quantize_matrix(batch)
            q = np.asarray(q, dtype=np.int8)
            scales = np.asarray(scales, dtype=np.float32)
        else:
            q, scales = quantize_int8_batch(batch, self.block)
        head = _U64.pack(n) + _U32.pack(self.block)
        blob = np.ascontiguousarray(scales, dtype="<f4").tobytes()
        step = 4 * scales.shape[1]
        params = ([head + blob[o:o + step]
                   for o in range(0, n_items * step, step)]
                  if step else [head] * n_items)
        return q, params

    def decode_batch(self, arr, params, slots):
        if not params:
            return np.zeros((0, 0), dtype=np.float32)
        if len(params[0]) < 12:
            raise WireDecodeError("int8 params truncated")
        head = params[0][:12]
        if any(len(p) != len(params[0]) or p[:12] != head for p in params):
            raise WireDecodeError("int8 batch group is not uniform")
        n = _U64.unpack_from(head, 0)[0]
        block = _U32.unpack_from(head, 8)[0]
        if block < 1:
            raise WireDecodeError("int8 block must be >= 1")
        q = np.asarray(arr)
        if q.dtype != np.int8:
            raise WireDecodeError(f"int8 body has dtype {q.dtype}, "
                                  f"expected int8")
        nb = -(-n // block) if n else 0
        n_scales = (len(params[0]) - 12) // 4
        if n_scales != nb or q.shape[1] != nb * block:
            raise WireDecodeError(
                f"int8 count mismatch: n={n} block={block} expects "
                f"{nb} scales / {nb * block} values, got "
                f"{n_scales} / {q.shape[1]}")
        if nb:
            buf = np.frombuffer(b"".join(params), dtype=np.uint8)
            scales = np.ascontiguousarray(
                buf.reshape(len(params), 12 + 4 * nb)[:, 12:]).view("<f4")
            scales = scales.astype(np.float32, copy=False)
        else:
            scales = np.zeros((len(params), 0), dtype=np.float32)
        ops = _quantize_kernel_ops() if _BATCH_BACKEND == "pallas" else None
        if ops is not None and block == ops.QBLOCK and nb:
            return np.asarray(ops.dequantize_matrix(q, scales, n),
                              dtype=np.float32)
        return dequantize_int8_batch(q, scales, n, block)


# --------------------------------------------------------------------------
# Terminal serializers: raw, hex
# --------------------------------------------------------------------------
class RawStage(Stage):
    """Identity over float32 — the 4-bytes/param wire floor."""

    name = "raw"
    lossless = True
    est_ratio = 1.0
    legacy_codec = RawCodec()

    def encode(self, arr, slot):
        return np.ascontiguousarray(arr, dtype="<f4"), b""

    def decode(self, arr, params, slot):
        return np.asarray(arr, dtype=np.float32)

    batch_capable = True

    def encode_batch(self, batch, slots):
        batch = np.ascontiguousarray(batch, dtype="<f4")
        return batch, [b""] * batch.shape[0]

    def decode_batch(self, arr, params, slots):
        return np.asarray(arr, dtype=np.float32)


class HexStage(Stage):
    """The paper's codec (Algorithm I ``ConvertToHex``): hexlify the input
    bytes, 2x inflation.  Generic over input dtype (the code travels in
    params) so it composes after any stage."""

    name = "hex"
    lossless = True
    est_ratio = 2.0
    remaps_coordinates = True     # bytes-of-hex, not aligned floats
    legacy_codec = HexCodec()

    def encode(self, arr, slot):
        arr = np.ascontiguousarray(arr)
        code = _body_dtype_code(arr.dtype)
        out = np.frombuffer(binascii.hexlify(arr.tobytes()), dtype=np.uint8)
        return out, bytes([code])

    def decode(self, arr, params, slot):
        if len(params) != 1 or params[0] >= len(_BODY_DTYPES):
            raise WireDecodeError("hex params must be one dtype code")
        try:
            raw = binascii.unhexlify(np.ascontiguousarray(arr).tobytes())
        except binascii.Error as e:
            raise WireDecodeError(f"hex body is not hexadecimal: {e}") from e
        return np.frombuffer(raw, dtype=_BODY_DTYPES[params[0]]).copy()

    batch_capable = True

    def encode_batch(self, batch, slots):
        # Rows are contiguous, so hexlifying the whole (N, P) buffer is the
        # concatenation of the per-row hexlifys — one C call instead of N.
        batch = np.ascontiguousarray(batch)
        n_items = batch.shape[0]
        code = _body_dtype_code(batch.dtype)
        hexed = np.frombuffer(binascii.hexlify(batch.tobytes()),
                              dtype=np.uint8)
        out = (hexed.reshape(n_items, -1) if hexed.size
               else np.zeros((n_items, 0), dtype=np.uint8))
        return out, [bytes([code])] * n_items

    def decode_batch(self, arr, params, slots):
        if not params:
            return np.zeros((0, 0), dtype=np.float32)
        p0 = params[0]
        if len(p0) != 1 or p0[0] >= len(_BODY_DTYPES):
            raise WireDecodeError("hex params must be one dtype code")
        if any(p != p0 for p in params):
            raise WireDecodeError("hex batch group is not uniform")
        arr = np.ascontiguousarray(arr)
        n_items = arr.shape[0]
        if arr.shape[1] % 2:
            # Whole-buffer unhexlify would smear the odd row boundaries
            # together; per-item decode raises here too.
            raise WireDecodeError("hex body is not hexadecimal: "
                                  "odd-length row")
        try:
            raw = binascii.unhexlify(arr.tobytes())
        except binascii.Error as e:
            raise WireDecodeError(f"hex body is not hexadecimal: {e}") from e
        flat = np.frombuffer(raw, dtype=_BODY_DTYPES[p0[0]])
        return (flat.reshape(n_items, -1) if flat.size
                else np.zeros((n_items, 0), dtype=flat.dtype)).copy()


# --------------------------------------------------------------------------
# Integrity stage: crc
# --------------------------------------------------------------------------
#: ChunkSum-32 weight period (repro.kernels.checksum.ref.WEIGHT_PERIOD,
#: duplicated here so the wire plane never imports jax transitively).
_CRC_WEIGHT_PERIOD = 8191


def chunksum32(data: bytes) -> int:
    """ChunkSum-32 over a byte string — the numpy twin of the
    ``repro.kernels.checksum`` kernel (parity pinned in the kernel tests).

    Every term is independent (weights are positional, not a running
    prefix like Adler-32), so the per-row batch form below is a plain
    vectorized reduction with identical results.
    """
    x = np.frombuffer(data, dtype=np.uint8)
    if x.size == 0:
        return 0
    w = (np.arange(x.size, dtype=np.uint64) % _CRC_WEIGHT_PERIOD) + 1
    xs = x.astype(np.uint64)
    a = int(xs.sum(dtype=np.uint64)) & 0xFFFFFFFF
    b = int((w * xs).sum(dtype=np.uint64)) & 0xFFFFFFFF
    return (a & 0xFFFF) | ((b & 0xFFFF) << 16)


def _chunksum32_rows(mat: np.ndarray) -> np.ndarray:
    """Per-row :func:`chunksum32` over a contiguous 2-D array (any dtype:
    rows are checksummed as their raw bytes)."""
    mat = np.ascontiguousarray(mat)
    rows = (mat.view(np.uint8).reshape(mat.shape[0], -1)
            if mat.size else np.zeros((mat.shape[0], 0), dtype=np.uint8))
    if rows.shape[1] == 0:
        return np.zeros(rows.shape[0], dtype=np.uint64)
    w = (np.arange(rows.shape[1], dtype=np.uint64)
         % _CRC_WEIGHT_PERIOD) + 1
    xs = rows.astype(np.uint64)
    a = xs.sum(axis=1, dtype=np.uint64) & 0xFFFFFFFF
    b = (xs * w).sum(axis=1, dtype=np.uint64) & 0xFFFFFFFF
    return (a & 0xFFFF) | ((b & 0xFFFF) << 16)


class CrcStage(Stage):
    """End-to-end wire-body integrity: ChunkSum-32 in the header params.

    Encode is the identity on the flowing array; the checksum of its
    exact bytes rides as this stage's header params.  Decode re-checksums
    the received body **before any other stage touches it** (it sits last
    in the spec, so it runs first on the reversed decode walk) and raises
    :class:`WireDecodeError` on mismatch — the FL layer's existing
    zero-fill degradation then absorbs the corrupt payload.

    Self-describing pipelines only (the checksum needs header params to
    travel in); ``Pipeline`` validation pins it to the terminal position
    because any later lossy stage would decode to different bytes than
    were checksummed and fail every payload.
    """

    name = "crc"
    lossless = True
    stateful = False
    est_ratio = 1.0
    remaps_coordinates = False
    legacy_codec = None

    def encode(self, arr, slot):
        arr = np.ascontiguousarray(arr)
        return arr, _U32.pack(chunksum32(arr.tobytes()))

    def decode(self, arr, params, slot):
        if len(params) != 4:
            raise WireDecodeError("crc params must be one u32 checksum")
        want = _U32.unpack(params)[0]
        got = chunksum32(np.ascontiguousarray(arr).tobytes())
        if got != want:
            raise WireDecodeError(f"crc mismatch: header 0x{want:08x}, "
                                  f"body 0x{got:08x}")
        return arr

    batch_capable = True

    def encode_batch(self, batch, slots):
        batch = np.ascontiguousarray(batch)
        if batch.ndim != 2:
            raise WireError(f"stage 'crc' batch input must be 2-D (N, P), "
                            f"got shape {batch.shape}")
        return batch, [_U32.pack(int(s)) for s in _chunksum32_rows(batch)]

    def decode_batch(self, arr, params, slots):
        if not params:
            return arr
        if any(len(p) != 4 for p in params):
            raise WireDecodeError("crc params must be one u32 checksum")
        arr = np.ascontiguousarray(arr)
        got = _chunksum32_rows(arr)
        want = np.frombuffer(b"".join(params), dtype=">u4")
        if got.size != want.size or not np.array_equal(
                got, want.astype(np.uint64)):
            raise WireDecodeError("crc mismatch in batch group")
        return arr


# --------------------------------------------------------------------------
# Registry + spec parser (the transport-registry idiom)
# --------------------------------------------------------------------------
_STAGES: dict[str, Callable[..., Stage]] = {}


def register_stage(name: str, factory: Callable[..., Stage], *,
                   overwrite: bool = False) -> None:
    """Register a stage factory under ``name``.  The factory is called with
    the (already number-parsed) args from the spec token, e.g.
    ``topk(0.01)`` calls ``factory(0.01)``.  Re-registering raises unless
    ``overwrite=True`` — silently shadowing ``int8`` would corrupt every
    payload already in flight under the old meaning."""
    if not overwrite and name in _STAGES:
        raise WireError(f"stage {name!r} is already registered "
                        f"(pass overwrite=True to replace it)")
    if overwrite:
        _NEGOTIATED.clear()   # memoized pipelines may hold the old stage
    _STAGES[name] = factory


def available_stages() -> list[str]:
    return sorted(_STAGES)


def _parse_number(tok: str) -> float | int:
    try:
        return int(tok)
    except ValueError:
        try:
            return float(tok)
        except ValueError:
            raise WireError(f"bad stage argument {tok!r}") from None


def parse_stage(token: str) -> Stage:
    """``"topk(0.01)"`` -> a TopKStage.  Raises WireError for unknown names
    or malformed args (WireDecodeError when reached from a wire header)."""
    token = token.strip()
    name, args = token, ()
    if "(" in token:
        if not token.endswith(")"):
            raise WireError(f"malformed stage token {token!r}")
        name, _, arg_s = token[:-1].partition("(")
        name = name.strip()
        if arg_s.strip():
            args = tuple(_parse_number(a.strip()) for a in arg_s.split(","))
    try:
        factory = _STAGES[name]
    except KeyError:
        raise WireError(f"unknown stage {name!r}; registered stages: "
                        f"{available_stages()}") from None
    try:
        return factory(*args)
    except WireError:
        raise
    except Exception as e:
        # Specs can arrive from the wire ('int8(inf)', 'raw(1)', ...): any
        # constructor rejection must stay inside the WireError contract so
        # the server degrades the payload instead of crashing.
        raise WireError(f"stage {name!r} rejected args {args!r}: "
                        f"{type(e).__name__}: {e}") from e


def parse_pipeline(spec: str) -> "Pipeline":
    """``"delta|ef|topk(0.01)|int8(1024)"`` -> a Pipeline (self-describing
    by default)."""
    tokens = [t for t in (tok.strip() for tok in spec.split("|")) if t]
    if not tokens:
        raise WireError(f"empty pipeline spec {spec!r}")
    return Pipeline([parse_stage(t) for t in tokens])


def parse_hop_specs(spec: str,
                    known_hops: Optional[Sequence[str]] = None
                    ) -> dict[str, str]:
    """Parse a *per-hop* pipeline spec string into ``{hop: pipeline spec}``.

    A multi-tier topology (``repro.core.topology``) composes a different
    wire pipeline on every hop — e.g. a lossy sparsifying uplink from
    clients to their edge aggregator but a lossless delta on the
    aggregated edge->root link::

        "client->edge: topk(0.01)|int8(1024); edge->root: delta"

    Entries are ``;``-separated ``hop: pipeline`` pairs (the first ``:``
    splits, so stage arguments are unaffected).  Every pipeline is parsed
    eagerly — a typo'd stage fails here, at configuration time, not deep
    inside a round.  When ``known_hops`` is given, hop names outside it
    are rejected (each topology publishes its hop names).
    """
    out: dict[str, str] = {}
    for entry in spec.split(";"):
        entry = entry.strip()
        if not entry:
            continue
        hop, sep, pipe = entry.partition(":")
        hop, pipe = hop.strip(), pipe.strip()
        if not sep or not hop or not pipe:
            raise WireError(f"malformed hop spec entry {entry!r}; expected "
                            f"'hop: stage|stage(...)'")
        if hop in out:
            raise WireError(f"duplicate hop {hop!r} in hop spec")
        if known_hops is not None and hop not in known_hops:
            raise WireError(f"unknown hop {hop!r}; this topology's hops: "
                            f"{sorted(known_hops)}")
        parse_pipeline(pipe)     # validate eagerly; raises WireError
        out[hop] = pipe
    if not out:
        raise WireError(f"empty hop spec {spec!r}")
    return out


# --------------------------------------------------------------------------
# The header
# --------------------------------------------------------------------------
class WireHeader:
    """``magic | version(u8) | spec_len(u16) spec | dtype(u8) |
    n_stages(u8) | per stage: params_len(u32) params`` — everything a
    receiver needs to rebuild the pipeline and decode the body."""

    __slots__ = ("version", "spec", "dtype_code", "stage_params")

    def __init__(self, spec: str, stage_params: list[bytes],
                 dtype_code: int, version: int = WIRE_VERSION):
        self.version = version
        self.spec = spec
        self.dtype_code = dtype_code
        self.stage_params = stage_params

    def pack(self) -> bytes:
        spec_b = self.spec.encode("utf-8")
        if len(spec_b) > 0xFFFF:
            raise WireError("pipeline spec too long")
        if len(self.stage_params) > 0xFF:
            raise WireError("too many stages")
        out = [WIRE_MAGIC, bytes([self.version]),
               _U16.pack(len(spec_b)), spec_b,
               bytes([self.dtype_code, len(self.stage_params)])]
        for p in self.stage_params:
            out.append(_U32.pack(len(p)))
            out.append(p)
        return b"".join(out)

    @classmethod
    def unpack(cls, data: bytes) -> tuple["WireHeader", int]:
        """Parse a header off the front of ``data``; returns (header, body
        offset).  Every malformation raises WireDecodeError with a reason."""
        if len(data) < 6:
            raise WireDecodeError(f"payload too short for a wire header "
                                  f"({len(data)} bytes)")
        if data[:2] != WIRE_MAGIC:
            raise WireDecodeError(f"bad wire magic {data[:2]!r}")
        version = data[2]
        if not 1 <= version <= WIRE_VERSION:
            raise WireDecodeError(f"unsupported wire version {version}")
        spec_len = _U16.unpack_from(data, 3)[0]
        off = 5
        if len(data) < off + spec_len + 2:
            raise WireDecodeError("truncated wire header (spec)")
        try:
            spec = data[off:off + spec_len].decode("utf-8")
        except UnicodeDecodeError as e:
            raise WireDecodeError(f"undecodable pipeline spec: {e}") from e
        off += spec_len
        dtype_code = data[off]
        if dtype_code >= len(_BODY_DTYPES):
            raise WireDecodeError(f"unknown body dtype code {dtype_code}")
        n_stages = data[off + 1]
        off += 2
        params: list[bytes] = []
        for _ in range(n_stages):
            if len(data) < off + 4:
                raise WireDecodeError("truncated wire header (params length)")
            plen = _U32.unpack_from(data, off)[0]
            off += 4
            if len(data) < off + plen:
                raise WireDecodeError("truncated wire header (params body)")
            params.append(data[off:off + plen])
            off += plen
        return cls(spec, params, dtype_code, version), off


# --------------------------------------------------------------------------
# Derived capabilities
# --------------------------------------------------------------------------
class PipelineCaps:
    """What a composed pipeline guarantees, derived from its stages."""

    __slots__ = ("lossless", "stateful", "est_ratio", "delta_domain")

    def __init__(self, stages: list[Stage]):
        self.lossless = all(s.lossless for s in stages)
        self.stateful = any(s.stateful for s in stages)
        ratio = 1.0
        for s in stages:
            ratio *= s.est_ratio
        self.est_ratio = ratio
        self.delta_domain = any(s.delta_domain for s in stages)

    def __repr__(self) -> str:
        return (f"PipelineCaps(lossless={self.lossless}, "
                f"stateful={self.stateful}, est_ratio={self.est_ratio:.4g}, "
                f"delta_domain={self.delta_domain})")


# --------------------------------------------------------------------------
# The pipeline
# --------------------------------------------------------------------------
class Pipeline:
    """An ordered, immutable stage composition.

    ``self_describing=True`` (the default, and what ``parse_pipeline``
    returns): ``encode`` prepends a :class:`WireHeader` and any receiver
    decodes via :func:`decode_payload` from the wire alone.
    ``self_describing=False`` (legacy): headerless — the terminal stage
    emits the historical codec bytes and ``decode`` needs this pipeline
    out-of-band, exactly the pre-refactor contract.
    """

    def __init__(self, stages: list[Stage], *, self_describing: bool = True):
        if not stages:
            raise WireError("a pipeline needs at least one stage")
        if isinstance(stages[-1], ErrorFeedbackStage):
            raise WireError("ef cannot be the terminal stage "
                            "(it wraps the stages after it)")
        ef_seen = remapped = False
        for s in stages:
            if isinstance(s, ErrorFeedbackStage):
                if remapped:
                    # Residual coordinates would belong to the PREVIOUS
                    # message's remapping (e.g. last round's top-k set) —
                    # compensation across mismatched coordinates silently
                    # corrupts every update.
                    raise WireError(
                        "ef must precede any coordinate-remapping stage "
                        "(topk/int8/hex); order the spec 'ef|topk|...'")
                ef_seen = True
            remapped = remapped or s.remaps_coordinates
            if ef_seen and s.delta_domain:
                # delta's decode intentionally stays in the delta domain
                # (not an encode-inverse), so a wrapping ef would compute
                # residual = comp - (comp - ref) = ref and re-inject the
                # whole reference model every message.
                raise WireError("ef cannot wrap delta; order the spec "
                                "'delta|ef|...' so the residual tracks "
                                "only what the lossy tail dropped")
        for s in stages[:-1]:
            if isinstance(s, CrcStage):
                # A later stage's decode need not reproduce the exact
                # bytes crc checksummed (int8 dequantizes, topk scatters),
                # so a non-terminal crc would fail every payload.
                raise WireError("crc must be the terminal stage (it "
                                "checksums the exact wire body)")
        self.stages = list(stages)
        self.self_describing = self_describing
        self.caps = PipelineCaps(self.stages)
        self.spec = "|".join(s.spec() for s in self.stages)

    def __repr__(self) -> str:
        mode = "wire" if self.self_describing else "legacy"
        return f"Pipeline({self.spec!r}, {mode})"

    @property
    def batchable(self) -> bool:
        """True when batch calls take the one-pass vectorized walk: a
        self-describing pipeline whose every stage ships vectorized batch
        twins.  Otherwise ``encode_batch``/``decode_batch`` still work but
        loop per item (legacy pipelines always loop — their wire format is
        the historical per-item one)."""
        return self.self_describing and all(s.batch_capable
                                            for s in self.stages)

    # -- state ---------------------------------------------------------------
    def new_state(self) -> PipelineState:
        return PipelineState(len(self.stages))

    def set_reference(self, state: PipelineState, vec: np.ndarray) -> None:
        """Prime every delta stage's reference (the model this endpoint
        last received); the orchestrator calls this at downlink time."""
        ref = np.ascontiguousarray(vec, dtype=np.float32)
        for i, s in enumerate(self.stages):
            if s.delta_domain:
                state.slots[i]["ref"] = ref

    def _state(self, state: Optional[PipelineState]) -> PipelineState:
        if state is None:
            return self.new_state()
        if len(state.slots) != len(self.stages):
            raise WireError(f"state has {len(state.slots)} slots, pipeline "
                            f"{self.spec!r} has {len(self.stages)} stages")
        return state

    # -- encode ---------------------------------------------------------------
    def encode(self, vec: np.ndarray,
               state: Optional[PipelineState] = None) -> bytes:
        """flat float32 vector -> wire bytes (headered unless legacy)."""
        state = self._state(state)
        vec = np.ascontiguousarray(vec, dtype=np.float32)
        if not self.self_describing:
            return self._encode_legacy(vec, state)
        arr = vec
        params: list[bytes] = []
        ef_marks: list[tuple[int, np.ndarray]] = []   # (index, compensated)
        for i, stage in enumerate(self.stages):
            if isinstance(stage, ErrorFeedbackStage):
                arr = stage.compensate(arr, state.slots[i])
                ef_marks.append((i, arr))
                params.append(b"")
                continue
            arr, p = stage.encode(arr, state.slots[i])
            params.append(p)
        # EF residual updates: decode each wrapped tail (deepest first) and
        # store comp - decoded.  Array-domain decode is numerically
        # identical to decoding the wire bytes (tobytes/frombuffer round-
        # trips exactly), so no second serialization happens.
        for i, comp in reversed(ef_marks):
            decoded = self._decode_tail(arr, params, i + 1, None)
            self.stages[i].update(comp, decoded, state.slots[i])
        header = WireHeader(self.spec, params, _body_dtype_code(arr.dtype))
        return header.pack() + np.ascontiguousarray(arr).tobytes()

    def encode_batch(self, vecs: Sequence[np.ndarray],
                     states: Optional[Sequence[Optional[PipelineState]]]
                     = None) -> list[bytes]:
        """Encode N same-length vectors in one vectorized stage walk.

        Returns the same bytes, in order, as ``[self.encode(v, s) for
        v, s in zip(vecs, states)]`` — byte-identical by contract — and
        mutates the per-item states exactly as the loop would (delta refs
        read, EF residuals written).  Falls back to that loop for legacy
        pipelines, non-:attr:`batchable` stage sets, or ragged input
        lengths, so it is always safe to call.
        """
        n_items = len(vecs)
        if states is None:
            states = [None] * n_items
        elif len(states) != n_items:
            raise WireError(f"encode_batch got {n_items} vectors but "
                            f"{len(states)} states")
        if n_items == 0:
            return []
        arrs = [np.ascontiguousarray(v, dtype=np.float32).reshape(-1)
                for v in vecs]
        if not self.batchable or len({a.size for a in arrs}) != 1:
            return [self.encode(a, s) for a, s in zip(arrs, states)]
        if (not self.caps.stateful and not self.caps.delta_domain
                and all(s is None for s in states)):
            # Stateless pipeline, no caller state: stages never write their
            # slots (the caps contract), so one shared scratch state serves
            # every item — skips N PipelineState allocations per call.
            states = [self.new_state()] * n_items
        else:
            states = [self._state(s) for s in states]
        chunk = _batch_chunk_rows(arrs[0].size)
        if n_items > chunk:
            out: list[bytes] = []
            for o in range(0, n_items, chunk):
                out.extend(self._encode_batch_walk(arrs[o:o + chunk],
                                                   states[o:o + chunk]))
            return out
        return self._encode_batch_walk(arrs, states)

    def _encode_batch_walk(self, arrs: Sequence[np.ndarray],
                           states: Sequence[PipelineState]) -> list[bytes]:
        """One vectorized stage walk over a (cache-sized) chunk of
        same-length vectors; see :meth:`encode_batch`."""
        n_items = len(arrs)
        batch = np.stack(arrs)
        slot_cols = [[st.slots[j] for st in states]
                     for j in range(len(self.stages))]
        arr = batch
        params_cols: list[Sequence[bytes]] = []
        ef_marks: list[tuple[int, np.ndarray]] = []
        for j, stage in enumerate(self.stages):
            if isinstance(stage, ErrorFeedbackStage):
                arr = stage.compensate_batch(arr, slot_cols[j])
                ef_marks.append((j, arr))
                params_cols.append([b""] * n_items)
                continue
            arr, p = stage.encode_batch(arr, slot_cols[j])
            if len(p) != n_items:
                raise WireError(f"stage {stage.spec()!r} returned {len(p)} "
                                f"params for {n_items} items")
            params_cols.append(p)
        for j, comp in reversed(ef_marks):
            decoded = self._decode_tail_batch(arr, params_cols, j + 1)
            self.stages[j].update_batch(comp, decoded, slot_cols[j])
        dtype_code = _body_dtype_code(arr.dtype)
        arr = np.ascontiguousarray(arr)
        n_st = len(self.stages)
        # Inline WireHeader.pack: the magic..n_stages prefix is shared by
        # the whole batch, so build it once and join per-item params +
        # body slices off bulk buffers (same bytes, no per-item objects).
        spec_b = self.spec.encode("utf-8")
        if len(spec_b) > 0xFFFF:
            raise WireError("pipeline spec too long")
        if n_st > 0xFF:
            raise WireError("too many stages")
        prefix = (WIRE_MAGIC + bytes([WIRE_VERSION]) + _U16.pack(len(spec_b))
                  + spec_b + bytes([dtype_code, n_st]))
        lens_cols = [[_U32.pack(len(p)) for p in col] for col in params_cols]
        body = arr.tobytes()
        row_b = arr.shape[1] * arr.itemsize if arr.ndim == 2 else 0
        out = []
        for i in range(n_items):
            parts = [prefix]
            for j in range(n_st):
                parts.append(lens_cols[j][i])
                parts.append(params_cols[j][i])
            parts.append(body[i * row_b:(i + 1) * row_b])
            out.append(b"".join(parts))
        return out

    def _encode_legacy(self, vec: np.ndarray, state: PipelineState) -> bytes:
        arr = vec
        ef_marks: list[tuple[int, np.ndarray]] = []
        for i, stage in enumerate(self.stages[:-1]):
            if isinstance(stage, ErrorFeedbackStage):
                arr = stage.compensate(arr, state.slots[i])
                ef_marks.append((i, arr))
                continue
            arr, p = stage.encode(arr, state.slots[i])
            if p:
                raise WireError(
                    f"stage {stage.spec()!r} emits wire params and cannot "
                    f"ride a legacy (headerless) pipeline mid-stream")
        terminal = self.stages[-1]
        data = terminal.legacy_encode(arr)
        if ef_marks:
            # The historical EF contract: residual against the terminal
            # codec's own decode of the just-encoded bytes.
            decoded = terminal.legacy_decode(data)
            for i, comp in reversed(ef_marks):
                # Transform stages between ef and the terminal are identity
                # on decode (delta) — the built-in legacy pipelines are
                # [delta?][ef?][codec], so decoded already matches comp's
                # domain.
                self.stages[i].update(comp, decoded, state.slots[i])
        return data

    # -- decode ---------------------------------------------------------------
    def _decode_tail(self, arr: np.ndarray, params: list[bytes],
                     start: int, state: Optional[PipelineState]
                     ) -> np.ndarray:
        for i in range(len(self.stages) - 1, start - 1, -1):
            slot = state.slots[i] if state is not None else {}
            try:
                arr = self.stages[i].decode(arr, params[i], slot)
            except WireDecodeError:
                raise
            except Exception as e:
                raise WireDecodeError(
                    f"stage {self.stages[i].spec()!r} failed to decode: "
                    f"{type(e).__name__}: {e}") from e
        return arr

    def decode(self, data: bytes,
               state: Optional[PipelineState] = None) -> np.ndarray:
        """wire bytes -> flat float32 vector.

        Self-describing pipelines parse their own header (and verify the
        header names *this* spec — use :func:`decode_payload` to honor
        whatever pipeline the sender chose).  Legacy pipelines decode the
        raw codec bytes.  All failures surface as WireDecodeError.
        """
        state = self._state(state)
        if not self.self_describing:
            try:
                arr = self.stages[-1].legacy_decode(data)
            except WireError:
                raise
            except Exception as e:
                raise WireDecodeError(
                    f"legacy payload undecodable under "
                    f"{self.stages[-1].spec()!r}: {type(e).__name__}: {e}"
                ) from e
            # Transform stages (delta/ef) are identity on decode; run them
            # anyway so third-party transform stages keep working here.
            for i in range(len(self.stages) - 2, -1, -1):
                arr = self.stages[i].decode(arr, b"", state.slots[i])
            return np.asarray(arr, dtype=np.float32)
        header, off = WireHeader.unpack(data)
        if header.spec != self.spec:
            raise WireDecodeError(
                f"header names pipeline {header.spec!r}, this pipeline is "
                f"{self.spec!r} (use decode_payload for negotiation)")
        return self._decode_body(header, data, off, state)

    def _decode_body(self, header: WireHeader, data: bytes, off: int,
                     state: Optional[PipelineState]) -> np.ndarray:
        if len(header.stage_params) != len(self.stages):
            raise WireDecodeError(
                f"header carries {len(header.stage_params)} stage params, "
                f"pipeline {self.spec!r} has {len(self.stages)} stages")
        dtype = np.dtype(_BODY_DTYPES[header.dtype_code])
        body = data[off:]
        if len(body) % dtype.itemsize:
            raise WireDecodeError(
                f"body length {len(body)} is not a multiple of "
                f"{dtype.itemsize}-byte {dtype} items")
        arr = np.frombuffer(body, dtype=dtype)
        vec = np.asarray(self._decode_tail(arr, header.stage_params, 0,
                                           state), dtype=np.float32)
        if not vec.flags.writeable:
            # Pass-through terminals (raw, bare delta) would hand back a
            # read-only view of the wire buffer; the codec contract has
            # always returned a writable array.
            vec = vec.copy()
        return vec

    # -- batch decode ---------------------------------------------------------
    def _decode_tail_batch(self, arr: np.ndarray,
                           params_cols: Sequence[Sequence[bytes]],
                           start: int) -> np.ndarray:
        """Batched :meth:`_decode_tail` over a rectangular group.  Decode
        is stateless for the built-ins, so every stage sees fresh empty
        slots (same as wire negotiation)."""
        n_items = arr.shape[0]
        for j in range(len(self.stages) - 1, start - 1, -1):
            # One shared dict: decode slots are read-only scratch for
            # correctly-declared stages (anything that writes decode state
            # must set PipelineCaps.stateful, which routes per-item).
            slots = [{}] * n_items
            try:
                arr = self.stages[j].decode_batch(arr, params_cols[j], slots)
            except WireDecodeError:
                raise
            except Exception as e:
                raise WireDecodeError(
                    f"stage {self.stages[j].spec()!r} failed to decode "
                    f"batch: {type(e).__name__}: {e}") from e
        return arr

    def _decode_body_batch(self, headers: Sequence[WireHeader],
                           datas: Sequence[bytes],
                           offs: Sequence[int]) -> np.ndarray:
        """Decode a *uniform* group (same spec, body dtype and body length
        — the grouping :func:`decode_payload_batch` performs) in one
        vectorized walk; returns the stacked ``(N, P)`` float32 matrix,
        bit-identical to per-item :meth:`_decode_body`.  Any malformation
        raises :class:`WireDecodeError`; the caller degrades to per-item
        decode to isolate the offending payload."""
        n_st = len(self.stages)
        for h in headers:
            if len(h.stage_params) != n_st:
                raise WireDecodeError(
                    f"header carries {len(h.stage_params)} stage params, "
                    f"pipeline {self.spec!r} has {n_st} stages")
        dtype = np.dtype(_BODY_DTYPES[headers[0].dtype_code])
        body_len = len(datas[0]) - offs[0]
        if body_len % dtype.itemsize:
            raise WireDecodeError(
                f"body length {body_len} is not a multiple of "
                f"{dtype.itemsize}-byte {dtype} items")
        count = body_len // dtype.itemsize
        chunk = _batch_chunk_rows(count)
        if len(datas) > chunk:
            parts = [self._decode_body_batch(headers[o:o + chunk],
                                             datas[o:o + chunk],
                                             offs[o:o + chunk])
                     for o in range(0, len(datas), chunk)]
            if any(m.shape[1] != parts[0].shape[1] for m in parts[1:]):
                # Uniform within each chunk but not across them (e.g.
                # mixed topk n values): same refusal as the stage-level
                # uniformity checks — degrade, don't guess a rectangle.
                raise WireDecodeError("batch group is not uniform")
            return np.concatenate(parts, axis=0)
        off0 = offs[0]
        if count <= 4096 and all(o == off0 for o in offs):
            # Small rows, same header length everywhere: one join + one
            # sliced copy beats N frombuffer calls' fixed overhead.
            buf = np.frombuffer(b"".join(datas), dtype=np.uint8)
            arr = np.ascontiguousarray(
                buf.reshape(len(datas), off0 + body_len)[:, off0:]
            ).view(dtype)
        else:
            # Large rows: copy overhead dominates call overhead, so fill
            # a preallocated matrix from zero-copy frombuffer views —
            # exactly one pass over the data.
            arr = np.empty((len(datas), count), dtype=dtype)
            for i, (d, off) in enumerate(zip(datas, offs)):
                arr[i] = np.frombuffer(d, dtype=dtype, count=count,
                                       offset=off)
        params_cols = [[h.stage_params[j] for h in headers]
                       for j in range(n_st)]
        mat = np.asarray(self._decode_tail_batch(arr, params_cols, 0),
                         dtype=np.float32)
        if not mat.flags.writeable:
            mat = mat.copy()
        return mat

    def decode_batch(self, datas: Sequence[bytes],
                     states: Optional[Sequence[Optional[PipelineState]]]
                     = None) -> np.ndarray:
        """Decode N payloads of *this* pipeline into a stacked ``(N, P)``
        float32 matrix, bit-identical to per-item :meth:`decode`.

        Strict: any malformed item raises :class:`WireDecodeError` (the
        server's per-item-degrading, negotiating entry point is
        :func:`decode_payload_batch`).  Falls back to a per-item loop for
        legacy pipelines, non-batchable stages, or a non-uniform group.
        """
        datas = list(datas)
        if states is None:
            states = [None] * len(datas)
        if not datas:
            return np.zeros((0, 0), dtype=np.float32)
        if self.batchable:
            headers, offs = [], []
            for data in datas:
                header, off = WireHeader.unpack(data)
                if header.spec != self.spec:
                    raise WireDecodeError(
                        f"header names pipeline {header.spec!r}, this "
                        f"pipeline is {self.spec!r} (use "
                        f"decode_payload_batch for negotiation)")
                headers.append(header)
                offs.append(off)
            key0 = (headers[0].dtype_code, len(datas[0]) - offs[0])
            if all((h.dtype_code, len(d) - o) == key0
                   for h, d, o in zip(headers, datas, offs)):
                return self._decode_body_batch(headers, datas, offs)
        return _stack_rows([self.decode(d, s)
                            for d, s in zip(datas, states)], self.spec)


# --------------------------------------------------------------------------
# State migration across renegotiated pipeline swaps
# --------------------------------------------------------------------------
def migrate_state(old: Pipeline, old_state: Optional[PipelineState],
                  new: Pipeline) -> Optional[PipelineState]:
    """Carry encoder state across a live pipeline renegotiation
    (:mod:`repro.core.control`), under the rules in ``docs/CONTROL.md``:

    * the first delta stage's reference (``slot["ref"]``) and the first
      ef stage's residual (``slot["residual"]``) carry over — both live
      in model coordinates (pipeline validation forces ef before any
      remapping stage), so they stay meaningful whatever the tail
      becomes;
    * everything else resets (a stage's private state is only defined
      under its own spec);
    * returns None when the new pipeline is stateless.

    The explicit-reset alternative (``ControlDecision.reset_state``) is
    simply not calling this and taking ``new.new_state()``.
    """
    if not new.caps.stateful:
        return None
    state = new.new_state()
    if old_state is None or len(old_state.slots) != len(old.stages):
        return state

    def _first(stages, pred):
        for i, s in enumerate(stages):
            if pred(s):
                return i
        return None

    for key, pred in (("ref", lambda s: s.delta_domain),
                      ("residual",
                       lambda s: isinstance(s, ErrorFeedbackStage))):
        i_old = _first(old.stages, pred)
        i_new = _first(new.stages, pred)
        if i_old is not None and i_new is not None:
            val = old_state.slots[i_old].get(key)
            if val is not None:
                state.slots[i_new][key] = val
    return state


# --------------------------------------------------------------------------
# Wire negotiation: decode from the header alone
# --------------------------------------------------------------------------
# Negotiation sits on the per-delivery hot path: memoize spec -> Pipeline
# (pipelines are immutable and state lives outside them, so sharing one
# instance across receivers is safe).  Invalidated implicitly by spec text;
# register_stage(..., overwrite=True) mid-run is the one case a stale entry
# could survive, so the cache is cleared there.  Size-capped because the
# keys are wire-supplied: a sender cycling through distinct parseable specs
# must not grow server memory without bound.
_NEGOTIATED: dict[str, Pipeline] = {}
_NEGOTIATED_CAP = 256


def _negotiated_pipeline(spec: str) -> Pipeline:
    """Memoized spec -> Pipeline for wire negotiation (see cache notes
    above); raises WireDecodeError for unparseable specs."""
    pipeline = _NEGOTIATED.get(spec)
    if pipeline is None:
        try:
            pipeline = parse_pipeline(spec)
        except WireError as e:
            raise WireDecodeError(
                f"header pipeline spec rejected: {e}") from e
        if len(_NEGOTIATED) >= _NEGOTIATED_CAP:
            _NEGOTIATED.clear()   # rare full reset beats unbounded growth
        _NEGOTIATED[spec] = pipeline
    return pipeline


def decode_payload(data: bytes,
                   state: Optional[PipelineState] = None
                   ) -> tuple[np.ndarray, Pipeline]:
    """Decode a self-describing payload with **zero out-of-band knowledge**:
    parse the header, rebuild the sender's pipeline from the stage
    registry, decode the body.  Returns ``(vector, pipeline)`` so the
    caller can branch on the negotiated ``pipeline.caps`` (e.g. aggregate
    in the delta domain).  Raises WireDecodeError for anything malformed,
    including spec tokens naming unregistered stages."""
    header, off = WireHeader.unpack(data)
    pipeline = _negotiated_pipeline(header.spec)
    if state is not None and len(state.slots) != len(pipeline.stages):
        state = None   # negotiated spec changed shape; decode is stateless
    vec = pipeline._decode_body(header, data, off, state)
    return vec, pipeline


def decode_payload_batch(datas: Sequence[bytes]) -> list[
        tuple[Optional[np.ndarray], Optional[Pipeline],
              Optional[WireDecodeError]]]:
    """Batched :func:`decode_payload` with **per-item degradation**.

    Returns one ``(vector, pipeline, error)`` triple per payload, in
    input order; exactly one of ``vector`` / ``error`` is None.  Payloads
    are grouped by (spec, body dtype, body length); each uniform group of
    a fully :attr:`Pipeline.batchable` pipeline decodes in one vectorized
    stage walk, bit-identical to the per-item path.  Anything else — a
    singleton, a non-batchable spec, or a group whose vectorized walk
    reports a malformation — degrades to per-item decode, so one corrupt
    payload zeroes out *that* client only and never poisons the batch.
    """
    results: list = [None] * len(datas)
    groups: dict[tuple, list] = {}
    # Fast header scan: payloads from one sender fleet share the entire
    # magic..spec..dtype..n_stages prefix, so after fully validating the
    # first header a byte-compare against that prefix lets the rest skip
    # straight to the per-stage params walk.  Any mismatch (or truncation
    # mid-walk) falls through to the full unpack for a proper error.
    fp_bytes = b""
    fp_len = fp_n_st = fp_dtype = fp_version = 0
    fp_spec, fp_pipeline = "", None
    for i, data in enumerate(datas):
        if fp_pipeline is not None and data[:fp_len] == fp_bytes:
            off, params, ok = fp_len, [], True
            for _ in range(fp_n_st):
                if len(data) < off + 4:
                    ok = False
                    break
                plen = _U32.unpack_from(data, off)[0]
                off += 4
                if len(data) < off + plen:
                    ok = False
                    break
                params.append(data[off:off + plen])
                off += plen
            if ok:
                header = WireHeader(fp_spec, params, fp_dtype, fp_version)
                key = (fp_spec, fp_dtype, len(data) - off)
                groups.setdefault(key, []).append(
                    (i, header, off, fp_pipeline))
                continue
        try:
            header, off = WireHeader.unpack(data)
            pipeline = _negotiated_pipeline(header.spec)
        except WireDecodeError as e:
            results[i] = (None, None, e)
            continue
        if fp_pipeline is None:
            # utf-8 re-encode reproduces the on-wire spec bytes exactly,
            # so the prefix length is recoverable from the parsed header.
            fp_len = 7 + len(header.spec.encode("utf-8"))
            fp_bytes = bytes(data[:fp_len])
            fp_n_st = len(header.stage_params)
            fp_dtype, fp_version = header.dtype_code, header.version
            fp_spec, fp_pipeline = header.spec, pipeline
        key = (header.spec, header.dtype_code, len(data) - off)
        groups.setdefault(key, []).append((i, header, off, pipeline))
    for members in groups.values():
        pipeline = members[0][3]
        if len(members) > 1 and pipeline.batchable:
            try:
                mat = pipeline._decode_body_batch(
                    [m[1] for m in members],
                    [datas[m[0]] for m in members],
                    [m[2] for m in members])
            except WireDecodeError:
                mat = None    # some item is malformed: isolate it below
            if mat is not None:
                for (i, _, _, _), row in zip(members, mat):
                    results[i] = (row, pipeline, None)
                continue
        for i, header, off, pipeline in members:
            try:
                vec = pipeline._decode_body(header, datas[i], off, None)
                results[i] = (vec, pipeline, None)
            except WireDecodeError as e:
                results[i] = (None, None, e)
    return results


# --------------------------------------------------------------------------
# Legacy bridge: TransportConfig(codec=...) -> headerless pipelines
# --------------------------------------------------------------------------
def legacy_pipeline(codec: str, codec_kwargs: Optional[dict] = None, *,
                    send_deltas: bool = False,
                    error_feedback: bool = False) -> Pipeline:
    """The pre-refactor wire behavior as a pipeline: ``[delta?][ef?][codec]``
    headerless.  EF is included only for lossy codecs — byte- and
    state-identical to the old hand-wired ``ServerCore.send_update`` path
    (pinned by the orchestrator-equivalence digests)."""
    kwargs = dict(codec_kwargs or {})
    if "(" in codec:
        if kwargs:
            raise WireError(
                f"codec {codec!r} embeds its args; passing codec_kwargs="
                f"{kwargs} too is ambiguous — use one or the other")
        terminal = parse_stage(codec)
    else:
        terminal = _terminal_from_name(codec, kwargs)
    stages: list[Stage] = []
    if send_deltas:
        stages.append(DeltaStage())
    if error_feedback and not terminal.lossless:
        stages.append(ErrorFeedbackStage())
    stages.append(terminal)
    return Pipeline(stages, self_describing=False)


class CodecStage(Stage):
    """Adapter: any legacy :class:`repro.core.compression.Codec` instance
    as a terminal stage.  Headered mode ships the codec's own bytes as a
    uint8 body; wire negotiation of a CodecStage requires its name to be
    registered (the four built-ins map to canonical stages instead)."""

    def __init__(self, codec):
        self.codec = codec
        self.name = codec.name
        self.lossless = codec.lossless
        self.legacy_codec = codec

    def encode(self, arr, slot):
        arr = _require_f4(arr, self.name)
        return np.frombuffer(self.codec.encode(arr), dtype=np.uint8), b""

    def decode(self, arr, params, slot):
        data = np.ascontiguousarray(arr, dtype=np.uint8).tobytes()
        try:
            return np.asarray(self.codec.decode(data), dtype=np.float32)
        except Exception as e:
            raise WireDecodeError(f"codec {self.name!r} failed to decode: "
                                  f"{type(e).__name__}: {e}") from e


def stage_for_codec(codec) -> Stage:
    """Map a legacy Codec instance onto its canonical stage (the four
    built-ins) or a :class:`CodecStage` adapter (anything else)."""
    if isinstance(codec, RawCodec):
        return RawStage()
    if isinstance(codec, HexCodec):
        return HexStage()
    if isinstance(codec, Int8Codec):
        return Int8Stage(block=codec.block)
    if isinstance(codec, TopKCodec):
        return TopKStage(codec.k_fraction)
    return CodecStage(codec)


def _terminal_from_name(codec: str, kwargs: dict) -> Stage:
    # Codec kwargs use the compression.py names; map them onto stage args.
    if codec == "int8":
        return Int8Stage(**kwargs)
    if codec == "topk":
        if "k_fraction" in kwargs:
            return TopKStage(kwargs["k_fraction"])
        return TopKStage(**kwargs)
    if kwargs:
        raise WireError(f"codec {codec!r} takes no kwargs, got {kwargs}")
    return parse_stage(codec)


register_stage("delta", DeltaStage)
register_stage("ef", ErrorFeedbackStage)
register_stage("topk", TopKStage)
register_stage("int8", Int8Stage)
register_stage("raw", RawStage)
register_stage("hex", HexStage)
register_stage("crc", CrcStage)
