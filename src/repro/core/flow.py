"""Flow-level simulator engine: analytic burst models instead of packets.

``Simulator(engine="flow")`` is the third engine tier.  The per-packet and
batched engines are bit-for-bit identical to each other; the flow engine
deliberately is not — it models each transport transaction *analytically*
(one Binomial loss draw per burst, FIFO-cumsum serialization closed forms,
expected jitter, expected-value NACK/retransmission recursions with seeded
stochastic rounding) and schedules only a handful of calendar events per
transaction.  Its correctness claim is **statistical**: the distributional-
equivalence harness (``tests/statcheck.py`` + ``tests/test_flow_engine.py``)
gates flow-vs-batched agreement on round time, bytes on wire, retransmission
counts and rounds-to-target-loss, the same way
``tests/test_engine_equivalence.py`` pins batched-vs-per-packet bit equality.

Every stochastic decision is a counter-based ``flow_uniform`` draw
(``repro.core.channel``, stream tag ``FLOW_STREAM``) keyed by the link's
loss seed, the endpoint addresses, the transaction and a per-phase counter
— so a flow run is *deterministic and replayable per seed*, exactly like
the other engines, while drawing far fewer numbers.

Architecture mirrors the transport registry: this module owns the
framework — :class:`FlowCtx` (link occupancy, loss draws, stat ledgers),
:class:`FlowSender` / :class:`FlowTransport` (the ``Transport``-shaped
adapters), and :func:`register_flow_model` — while each transport module
(``mudp.py`` / ``udp.py`` / ``tcp.py`` / ``fec.py``) registers its own
analytic model at import time.  A transport with no registered flow model
is refused with the registered names, like an unknown transport kind.

Known approximations (documented, and what the harness tolerances absorb):

* per-packet jitter is replaced by its mean (``jitter_ns / 2``), so flow
  runs have slightly lower round-time variance on heavily jittered links;
* control packets (ACK/NACK/SYN/...) are modeled lossless, matching the
  default ``drop_control=False`` of the shipped loss models;
* recovery traffic (retransmissions, NACK volleys) is *accounted* at plan
  time and *delivered* at the analytically derived completion time, so
  mid-transaction snapshots of ``sim.stats`` may differ from the packet
  engines; totals at round boundaries agree.
"""

from __future__ import annotations

import dataclasses
import zlib
from typing import Callable, Optional

import numpy as np

from repro.core.channel import (FLOW_STREAM, flow_uniform, keyed_binomial,
                                stochastic_round)
from repro.core.packets import HEADER_BYTES, PacketKind
from repro.core.simulator import (_DELIVERED_KEY, _DROPPED_KEY, _SENT_KEY,
                                  Node, Simulator)

# Phase tags keep the per-transaction draw streams decorrelated: the same
# (seed, txn, counter) key under a different phase is an independent draw.
PH_LOSS = 1       # Binomial loss count of a burst (counter = burst index)
PH_PICK = 2       # missing-sequence selection (counter = seq)
PH_LAST = 3       # last-packet-lost conditionals (counter = attempt)
PH_RETX = 4       # stochastic rounding of retransmission losses
PH_WINDOW = 5     # TCP per-window draws
PH_REORD = 6      # jitter-reordering conditionals (spurious NACK volleys)

_MASK64 = (1 << 64) - 1


# --------------------------------------------------------------------------
# Model registry (the transport-registry idiom)
# --------------------------------------------------------------------------
# transport name -> model(ctx) -> FlowOutcome.  Populated by the transport
# modules at import time (mudp.py, udp.py, tcp.py, fec.py) — the framework
# never imports them, so there is no import cycle.
FLOW_MODELS: dict[str, Callable] = {}


def register_flow_model(name: str, model: Callable, *,
                        overwrite: bool = False) -> None:
    """Register the analytic flow model for transport ``name``."""
    if not overwrite and name in FLOW_MODELS:
        raise ValueError(f"flow model {name!r} is already registered "
                         f"(pass overwrite=True to replace it)")
    FLOW_MODELS[name] = model


def available_flow_models() -> list[str]:
    return sorted(FLOW_MODELS)


@dataclasses.dataclass
class FlowOutcome:
    """What a transport's flow model hands back: when the sender finishes
    (and whether it failed), and the receiver-side delivery, if any."""

    end_ns: int
    completed: bool
    deliver_ns: Optional[int] = None
    packets: Optional[dict] = None       # seq -> Packet for the Delivery
    total: int = 0
    complete: bool = True                # Delivery.complete


# --------------------------------------------------------------------------
# Link occupancy + stat ledger
# --------------------------------------------------------------------------
class _Path:
    """One direction of a link pair, with the flow-engine closed forms:
    FIFO occupancy (``max(t, busy_until) + cumsum(serialization)``) and
    mean propagation."""

    __slots__ = ("sim", "src_addr", "dst_addr", "link", "eprop", "loss_p")

    def __init__(self, sim: Simulator, src_addr: str, dst_addr: str):
        link = sim._links.get((src_addr, dst_addr))
        if link is None:
            raise KeyError(f"no link {src_addr} -> {dst_addr}")
        self.sim = sim
        self.src_addr = src_addr
        self.dst_addr = dst_addr
        self.link = link
        self.eprop = link.expected_propagation_ns()
        self.loss_p = link.loss.stationary_loss_p()

    def occupy(self, t: int, sizes: list[int]) -> tuple[int, int]:
        """Serialize ``sizes`` back-to-back starting no earlier than ``t``;
        returns (first arrival, last arrival) under mean propagation."""
        link = self.link
        start = max(int(t), link._busy_until_ns)
        first = 0
        total = 0
        for i, s in enumerate(sizes):
            ser = link.serialization_ns(s)
            total += ser
            if i == 0:
                first = ser
        link._busy_until_ns = start + total
        return start + first + self.eprop, start + total + self.eprop


class FlowCtx:
    """Everything a transport's flow model needs: the paths, the keyed
    draws, and the stat ledger the completion event settles."""

    def __init__(self, sim: Simulator, src: Node, dst: Node,
                 packets: list, cfg, stats):
        self.sim = sim
        self.src = src
        self.dst = dst
        self.packets = packets
        self.cfg = cfg
        self.stats = stats               # repro.core.mudp.TxnStats
        self.txn = packets[0].txn
        self.total = packets[0].total
        self.fwd = _Path(sim, src.addr, dst.addr)
        self.rev = _Path(sim, dst.addr, src.addr)
        self.p = self.fwd.loss_p         # payload loss on the forward path
        self.sizes = [p.size_bytes for p in packets]
        self.chunk = self.sizes[0]
        self.data_bytes = sum(self.sizes)
        # Replay-stable draw key: loss seed x endpoint addresses.  Sync
        # scheduling reuses one txn across a whole round, so the addresses
        # must decorrelate concurrent transactions (crc32: stable across
        # interpreters, unlike str hash).
        self.seed = ((getattr(self.fwd.link.loss, "seed", 0)
                      * 0x9E3779B1)
                     ^ zlib.crc32(src.addr.encode())
                     ^ (zlib.crc32(dst.addr.encode()) << 20)) & _MASK64
        # kind -> [sent, dropped]; settled into delivered counters by the
        # completion event.
        self._ledger: dict[PacketKind, list[int]] = {}
        self._bytes_sent = 0
        self._bytes_dropped = 0

    # -- keyed draws -------------------------------------------------------
    def uniform(self, phase: int, counter: int = 0, extra: int = 0) -> float:
        return flow_uniform(FLOW_STREAM, self.seed, self.txn, phase,
                            counter, extra)

    def binom(self, n: int, p: float, phase: int, counter: int = 0) -> int:
        return keyed_binomial(n, p, self.uniform(phase, counter))

    def sround(self, x: float, phase: int, counter: int = 0) -> int:
        return stochastic_round(x, self.uniform(phase, counter))

    def pick_missing(self, k: int) -> set[int]:
        """A uniformly random ``k``-subset of sequence numbers 1..total,
        keyed per-seq so the same transaction replays the same subset."""
        if k <= 0:
            return set()
        n = self.total
        if k >= n:
            return set(range(1, n + 1))
        u = np.fromiter((self.uniform(PH_PICK, s) for s in range(1, n + 1)),
                        np.float64, n)
        order = np.argpartition(u, k - 1)[:k]
        return {int(i) + 1 for i in order}

    # -- accounting --------------------------------------------------------
    def count(self, path: _Path, kind: PacketKind, n: int, nbytes: int,
              ndropped: int = 0, dropped_bytes: int = 0) -> None:
        """Account ``n`` sends (``ndropped`` of them lost) of ``kind`` over
        ``path`` — send-time counters exactly like ``Simulator.transmit``;
        delivered counters are settled by the completion event."""
        if n <= 0:
            return
        sim = self.sim
        stats = sim.stats
        stats["packets_sent"] += n
        stats["bytes_sent"] += nbytes
        k = _SENT_KEY[kind]
        stats[k] = stats.get(k, 0) + n
        if sim._hop_of:
            hop = sim._hop_of.get((path.src_addr, path.dst_addr))
            if hop is not None:
                sim.hop_bytes[hop] += nbytes
                sim.hop_packets[hop] += n
        if ndropped:
            stats["packets_dropped"] += ndropped
            k = _DROPPED_KEY[kind]
            stats[k] = stats.get(k, 0) + ndropped
        led = self._ledger.setdefault(kind, [0, 0])
        led[0] += n
        led[1] += ndropped
        self._bytes_sent += nbytes
        self._bytes_dropped += dropped_bytes

    def settle_delivered(self) -> None:
        """Fold the ledger's survivors into the delivered counters (called
        by the completion event)."""
        stats = self.sim.stats
        for kind, (sent, dropped) in self._ledger.items():
            c = sent - dropped
            if c <= 0:
                continue
            stats["packets_delivered"] += c
            k = _DELIVERED_KEY[kind]
            stats[k] = stats.get(k, 0) + c
        stats["bytes_delivered"] += self._bytes_sent - self._bytes_dropped


def reorder_prob(jitter_ns: int, gap_ns: int) -> float:
    """P(packet sent ``gap_ns`` earlier still arrives *after* a reference
    packet), both carrying iid ``U[0, jitter_ns)`` propagation jitter:
    ``P(j_early > gap + j_ref) = (J - g)^2 / (2 J^2)`` for ``g < J``."""
    if jitter_ns <= 0 or gap_ns >= jitter_ns:
        return 0.0
    x = (jitter_ns - gap_ns) / jitter_ns
    return 0.5 * x * x


def spurious_reorder_nacks(ctx, *, trailer_gap_ns: int | None = None,
                           phase_base: int = 0) -> int:
    """How many *surviving* interior packets the receiver NACKs anyway,
    because jitter reordered them behind the last packet.

    The packet receivers report gaps the moment the last packet arrives;
    with per-packet jitter comparable to the inter-packet serialization
    gap, in-flight interiors look like losses and draw an immediate NACK
    volley even though their originals land moments later.  The volley is
    pure overhead — duplicate retransmissions and NACK bytes, no timing
    consequence — but it dominates fleet-scale retransmission counts, so
    the flow engine reproduces it: one Bernoulli per interior with the
    exact pairwise reordering probability.

    ``trailer_gap_ns`` (the FEC case) conditions each draw on the parity
    trailer having beaten the last data packet — all three orderings share
    the last packet's jitter draw, so the joint probability
    ``(1 - gi - gp)^3 / 6`` (iid uniform jitter) is divided by the
    trailer-first probability the caller already gated on."""
    link = ctx.fwd.link
    jit = getattr(link, "jitter_ns", 0)
    n = ctx.total
    if jit <= 0 or n < 2:
        return 0
    ser = link.serialization_ns(ctx.chunk)
    if trailer_gap_ns is not None:
        q = reorder_prob(jit, trailer_gap_ns)
        if q <= 0.0:
            return 0
        gp = trailer_gap_ns / jit
    m = 0
    for i in range(1, n):
        if trailer_gap_ns is None:
            r = reorder_prob(jit, (n - i) * ser)
        else:
            x = 1.0 - (n - i) * ser / jit - gp
            r = min(1.0, x * x * x / 6.0 / q) if x > 0.0 else 0.0
        r *= 1.0 - ctx.p
        if r > 0.0 and ctx.uniform(PH_REORD, phase_base + i) < r:
            m += 1
    return m


# --------------------------------------------------------------------------
# The Transport-shaped adapters
# --------------------------------------------------------------------------
class FlowSender:
    """One transaction under the flow engine: runs the transport's analytic
    model at ``start()`` and schedules the (few) resulting events.  Exposes
    the same ``start()`` / ``stats`` / callback surface as the packet-level
    senders, so schedulers and topologies cannot tell the difference.

    ``cfg`` is captured per *transaction*, not per transport: the server
    passes each client's current effective TransportConfig
    (``ServerCore.transport_cfg_for``), so when the adaptive control plane
    renegotiates FEC geometry mid-run the analytic models see the new
    parameters on the very next transaction — same cadence as the packet
    engines, whose sender factories take the identical argument."""

    def __init__(self, model: Callable, sim: Simulator, src: Node,
                 dst: Node, packets: list, cfg, *,
                 on_complete: Optional[Callable] = None,
                 on_fail: Optional[Callable] = None):
        if not packets:
            raise ValueError("empty transaction")
        from repro.core.mudp import TxnStats
        self._model = model
        self.sim, self.src, self.dst = sim, src, dst
        self.packets = packets
        self.cfg = cfg
        self.txn = packets[0].txn
        self.total = packets[0].total
        self.on_complete = on_complete
        self.on_fail = on_fail
        self.stats = TxnStats(txn=self.txn, total_packets=self.total)

    def start(self) -> None:
        from repro.core.transport import Delivery
        sim = self.sim
        now = sim.now_ns
        self.stats.start_ns = now
        ctx = FlowCtx(sim, self.src, self.dst, self.packets, self.cfg,
                      self.stats)
        out = self._model(ctx)

        if out.packets is not None:
            delivery = Delivery(self.src.addr, self.txn, out.packets,
                                out.total, out.complete)
            deliver_at = max(now, int(out.deliver_ns))

            def _deliver() -> None:
                cb = getattr(sim, "_flow_deliver", {}).get(self.dst.addr)
                if cb is not None:
                    cb(delivery)
            sim.schedule(deliver_at - now, _deliver)

        end_at = max(now, int(out.end_ns))

        def _finish() -> None:
            st = self.stats
            st.end_ns = sim.now_ns
            st.completed = out.completed
            st.failed = not out.completed
            ctx.settle_delivered()
            cb = self.on_complete if out.completed else self.on_fail
            if cb is not None:
                cb(self)
        sim.schedule(end_at - now, _finish)


class _FlowReceiver:
    """Persistent receiver under the flow engine: a registry entry.  The
    senders drive delivery analytically, so the only receiver-side state is
    the ``on_deliver`` callback keyed by node address."""

    def __init__(self, sim: Simulator, addr: str):
        self.sim = sim
        self.addr = addr


class FlowTransport:
    """``Transport``-shaped wrapper that swaps a protocol's packet-level
    state machines for its registered analytic flow model.  Same ``name``,
    same ``caps`` — callers branch on capabilities and never notice."""

    def __init__(self, base):
        if base.name not in FLOW_MODELS:
            raise ValueError(
                f"transport {base.name!r} has no registered flow model; "
                f"flow-capable transports: {available_flow_models()}")
        self.base = base
        self.name = base.name
        self.caps = base.caps
        self._model = FLOW_MODELS[base.name]

    def create_sender(self, sim, src, dst, packets, cfg, *,
                      on_complete=None, on_fail=None):
        return FlowSender(self._model, sim, src, dst, packets, cfg,
                          on_complete=on_complete, on_fail=on_fail)

    def create_receiver(self, sim, node, cfg, on_deliver):
        registry = getattr(sim, "_flow_deliver", None)
        if registry is None:
            registry = sim._flow_deliver = {}
        registry[node.addr] = on_deliver
        return _FlowReceiver(sim, node.addr)


def maybe_flow(sim: Simulator, transport):
    """Wrap ``transport`` in its flow adapter when ``sim`` runs the flow
    engine; hand it back untouched otherwise.  The one hook every
    transport-dispatching layer (ServerCore, GossipSystem) calls."""
    if sim.engine == "flow":
        return FlowTransport(transport)
    return transport


# --------------------------------------------------------------------------
# Shared model building blocks (used by the transport modules)
# --------------------------------------------------------------------------
CONTROL_BYTES = HEADER_BYTES     # ACK/NACK/SYN/... are header-only packets
