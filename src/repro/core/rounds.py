"""Federated-learning round orchestration (paper Fig. 4, generalized).

One round, per the paper: the server broadcasts the global model; each client
trains locally; the client ships its weights to the server in packets over the
Modified UDP; the server aggregates (Eq. 1) and the transport-level ACK
``(0, 0, A_server)`` closes the client's transaction.

Beyond the paper (required at thousand-node scale):
 * round deadline -> straggler cutoff: aggregate whoever arrived (the paper's
   timer, promoted from packet level to round level);
 * async late-update buffer: a straggler's update that lands after the
   deadline is folded into the NEXT round with a staleness discount;
 * elastic client pool with health tracking (transport failures demote a
   client; it is re-admitted after a cool-down);
 * delta transmission + lossy codecs with error feedback;
 * pluggable transport (any name in ``available_transports()``, dispatched
   through the ``repro.core.transport`` registry) and aggregation
   (pairwise | fedavg | trimmed_mean).
"""

from __future__ import annotations

import dataclasses
import random
from typing import Any, Callable, Optional

import numpy as np

from repro.core import aggregation as agg
from repro.core.compression import ErrorFeedback, make_codec
from repro.core.packetizer import (Packetizer, flatten_to_vector, packetize,
                                   unflatten_from_vector)
from repro.core.simulator import Simulator
from repro.core.transport import (Delivery, Transport, TransportConfig,
                                  make_transport, validate_transport_kind)


# --------------------------------------------------------------------------
# Configuration (TransportConfig lives with the transport registry and is
# re-exported here for backward compatibility)
# --------------------------------------------------------------------------
@dataclasses.dataclass
class FLConfig:
    transport: TransportConfig = dataclasses.field(
        default_factory=TransportConfig)
    aggregation: str = "fedavg"          # pairwise (paper Eq.1) | fedavg | trimmed_mean
    send_deltas: bool = False            # ship (trained - received) instead of weights
    error_feedback: bool = False         # residual compensation for lossy codecs
    broadcast_model: bool = True         # server->client downlink each round
    round_deadline_ns: Optional[int] = None
    server_lr: float = 1.0               # for delta aggregation
    staleness_discount: float = 0.5      # late update weight *= discount^age
    unhealthy_after_failures: int = 2
    readmit_after_rounds: int = 2
    # Partial participation (fleet-scale): each round samples
    # round(participation_fraction * |active|) clients, at least
    # min_participants, via a seeded Fisher-Yates draw keyed by
    # (participation_seed, round_idx) — deterministic across Python versions
    # because it only consumes Random.random().
    participation_fraction: float = 1.0
    min_participants: int = 1
    participation_seed: int = 0

    def __post_init__(self) -> None:
        # Fail at construction time (with the registered names) rather than
        # deep inside receiver setup; also covers dataclasses.replace(...).
        validate_transport_kind(self.transport.kind)


@dataclasses.dataclass
class RoundResult:
    round_idx: int
    duration_ns: int
    arrived: list[str]
    failed: list[str]
    skipped_unhealthy: list[str]
    late_folded: int
    bytes_sent: int
    packets_sent: int
    packets_dropped: int
    retransmissions: int
    metrics: dict = dataclasses.field(default_factory=dict)
    roster: list[str] = dataclasses.field(default_factory=list)
    # Per-kind traffic split (from the simulator's per-PacketKind counters)
    # so benchmarks separate payload from protocol chatter.
    data_packets: int = 0
    nack_packets: int = 0
    parity_packets: int = 0


# --------------------------------------------------------------------------
# Client
# --------------------------------------------------------------------------
class FLClient:
    """One federated client.

    ``train_fn(params, round_idx, client) -> (new_params, metrics)`` runs real
    (JAX) local training; ``train_time_ns`` models how long that takes inside
    the simulation (heterogeneous values create stragglers).
    """

    def __init__(self, addr: str, train_fn: Callable, *,
                 train_time_ns: int = 1_000_000_000,
                 weight: float = 1.0):
        self.addr = addr
        self.train_fn = train_fn
        self.train_time_ns = train_time_ns
        self.weight = weight
        self.params: Any = None          # local copy of the global model
        self.error_feedback = ErrorFeedback()
        self.metrics_history: list[dict] = []


class ClientPool:
    """Elastic membership with health tracking."""

    def __init__(self, clients: list[FLClient], *,
                 unhealthy_after: int = 2, readmit_after: int = 2):
        self.clients: dict[str, FLClient] = {c.addr: c for c in clients}
        self.failures: dict[str, int] = {c.addr: 0 for c in clients}
        self.benched_until: dict[str, int] = {}
        self.unhealthy_after = unhealthy_after
        self.readmit_after = readmit_after

    def add(self, client: FLClient) -> None:
        self.clients[client.addr] = client
        self.failures[client.addr] = 0

    def remove(self, addr: str) -> None:
        self.clients.pop(addr, None)
        self.failures.pop(addr, None)
        self.benched_until.pop(addr, None)

    def active(self, round_idx: int) -> list[FLClient]:
        out = []
        for addr, c in self.clients.items():
            if self.benched_until.get(addr, -1) > round_idx:
                continue
            out.append(c)
        return out

    def benched(self, round_idx: int) -> list[str]:
        return [a for a, r in self.benched_until.items() if r > round_idx]

    def record_failure(self, addr: str, round_idx: int) -> None:
        self.failures[addr] = self.failures.get(addr, 0) + 1
        if self.failures[addr] >= self.unhealthy_after:
            self.benched_until[addr] = round_idx + 1 + self.readmit_after
            self.failures[addr] = 0

    def record_success(self, addr: str) -> None:
        self.failures[addr] = 0


# --------------------------------------------------------------------------
# The federated system
# --------------------------------------------------------------------------
class FederatedSystem:
    """Server + clients + transport over one Simulator."""

    def __init__(self, sim: Simulator, server_addr: str,
                 clients: list[FLClient], global_params: Any,
                 cfg: Optional[FLConfig] = None):
        self.sim = sim
        self.cfg = cfg or FLConfig()
        self.server_addr = server_addr
        self.server_node = sim.node(server_addr)
        self.pool = ClientPool(
            clients, unhealthy_after=self.cfg.unhealthy_after_failures,
            readmit_after=self.cfg.readmit_after_rounds)
        self.global_params = global_params
        codec = make_codec(self.cfg.transport.codec,
                           **self.cfg.transport.codec_kwargs)
        self.packetizer = Packetizer(codec=codec, mtu=self.cfg.transport.mtu)
        self.history: list[RoundResult] = []
        self.on_round_end: Optional[Callable[[RoundResult, Any], None]] = None

        # Transport dispatch goes through the registry: FederatedSystem has
        # no per-protocol branches, so new transports plug in unchanged.
        self.transport: Transport = make_transport(self.cfg.transport.kind)

        # Persistent receivers.
        self._server_rx = self.transport.create_receiver(
            sim, self.server_node, self.cfg.transport,
            self._on_server_delivery)
        self._client_rx: dict[str, object] = {}
        for c in clients:
            self._install_client_rx(c)

        # Per-round state.
        self._round_idx = -1
        self._roster: dict[str, FLClient] = {}
        self._resolved: set[str] = set()
        self._updates: dict[str, np.ndarray] = {}   # addr -> flat vector
        self._late_buffer: list[tuple[int, str, np.ndarray]] = []
        self._round_open = False
        self._round_start_ns = 0
        self._deadline_timer = None
        self._failed: list[str] = []
        self._round_retx = 0
        self._late_folded = 0

    # -- receiver plumbing ---------------------------------------------------
    def _install_client_rx(self, client: FLClient) -> None:
        self._client_rx[client.addr] = self.transport.create_receiver(
            self.sim, self.sim.node(client.addr), self.cfg.transport,
            self._make_client_deliver(client))

    def add_client(self, client: FLClient) -> None:
        """Elastic join (between rounds)."""
        self.pool.add(client)
        self._install_client_rx(client)

    def remove_client(self, addr: str) -> None:
        self.pool.remove(addr)

    # -- txn numbering ------------------------------------------------------
    @staticmethod
    def _txn_down(round_idx: int) -> int:
        return round_idx * 2

    @staticmethod
    def _txn_up(round_idx: int) -> int:
        return round_idx * 2 + 1

    @staticmethod
    def _round_of_txn(txn: int) -> int:
        return txn // 2

    # -- round driver ---------------------------------------------------------
    def run_round(self, round_idx: Optional[int] = None) -> RoundResult:
        self._round_idx = (self._round_idx + 1 if round_idx is None
                           else round_idx)
        r = self._round_idx
        roster = self._sample_participants(self.pool.active(r), r)
        self._roster = {c.addr: c for c in roster}
        self._resolved = set()
        self._updates = {}
        self._failed = []
        self._round_open = True
        self._round_retx = 0
        self._late_folded = 0
        self._round_start_ns = self.sim.now_ns
        stats0 = dict(self.sim.stats)

        if self.cfg.round_deadline_ns is not None:
            self._deadline_timer = self.sim.schedule(
                self.cfg.round_deadline_ns, self._on_deadline)

        for client in roster:
            if self.cfg.broadcast_model:
                self._broadcast_to(client)
            else:
                client.params = self.global_params
                self._schedule_training(client)

        self.sim.run()

        if self._round_open:       # e.g. every client failed before deadline
            self._finalize()

        stats1 = self.sim.stats
        result = RoundResult(
            round_idx=r,
            duration_ns=self.sim.now_ns - self._round_start_ns,
            arrived=sorted(self._updates.keys()),
            failed=list(self._failed),
            skipped_unhealthy=self.pool.benched(r),
            late_folded=self._late_folded,
            bytes_sent=stats1["bytes_sent"] - stats0["bytes_sent"],
            packets_sent=stats1["packets_sent"] - stats0["packets_sent"],
            packets_dropped=(stats1["packets_dropped"]
                             - stats0["packets_dropped"]),
            retransmissions=self._round_retx,
            roster=sorted(self._roster),
            data_packets=(stats1.get("sent_data", 0)
                          - stats0.get("sent_data", 0)),
            nack_packets=(stats1.get("sent_nack", 0)
                          - stats0.get("sent_nack", 0)),
            parity_packets=(stats1.get("sent_parity", 0)
                            - stats0.get("sent_parity", 0)),
        )
        self.history.append(result)
        if self.on_round_end is not None:
            self.on_round_end(result, self.global_params)
        return result

    def run_rounds(self, n: int) -> list[RoundResult]:
        return [self.run_round() for _ in range(n)]

    # -- per-round client sampling (partial participation) -------------------
    def _sample_participants(self, active: list[FLClient],
                             round_idx: int) -> list[FLClient]:
        f = self.cfg.participation_fraction
        if f >= 1.0 or len(active) <= 1:
            return list(active)
        k = max(self.cfg.min_participants, int(round(f * len(active))))
        k = min(k, len(active))
        # Partial Fisher-Yates over indices, driven only by Random.random()
        # (the one generator method with a cross-version stability guarantee),
        # keyed by integers so PYTHONHASHSEED cannot perturb the draw.
        rng = random.Random(hash((self.cfg.participation_seed, round_idx)))
        idx = list(range(len(active)))
        for j in range(k):
            pick = j + int(rng.random() * (len(idx) - j))
            idx[j], idx[pick] = idx[pick], idx[j]
        return [active[i] for i in sorted(idx[:k])]

    # -- downlink: server -> client -------------------------------------------
    def _broadcast_to(self, client: FLClient) -> None:
        packets = self.packetizer.to_packets(
            self.global_params, self.server_addr, self._txn_down(self._round_idx))
        self._make_sender(self.server_node, self.sim.node(client.addr),
                          packets,
                          on_fail=lambda s, a=client.addr:
                          self._uplink_failed(a)).start()

    def _make_client_deliver(self, client: FLClient):
        def _cb(d: Delivery) -> None:
            if self._round_of_txn(d.txn) != self._round_idx:
                return
            if d.complete:
                client.params = self.packetizer.from_packets(
                    d.packets, self.global_params)
            else:
                # Best-effort downlink: the client trains on the zero-filled
                # model (Delivery.complete makes the gap explicit instead of
                # silently treating a partial broadcast as the full model).
                vec = self._decode_vec(d.reassemble())
                client.params = unflatten_from_vector(vec, self.global_params)
            self._schedule_training(client)
        return _cb

    # -- local training ------------------------------------------------------
    def _schedule_training(self, client: FLClient) -> None:
        def _train_done() -> None:
            received = client.params
            new_params, metrics = client.train_fn(
                received, self._round_idx, client)
            client.metrics_history.append(metrics)
            payload_tree = (agg.tree_sub(new_params, received)
                            if self.cfg.send_deltas else new_params)
            client.params = new_params
            self._send_update(client, payload_tree)
        self.sim.schedule(client.train_time_ns, _train_done)

    # -- uplink: client -> server -------------------------------------------
    def _send_update(self, client: FLClient, payload_tree: Any) -> None:
        vec = flatten_to_vector(payload_tree)
        if self.cfg.error_feedback and not self.packetizer.codec.lossless:
            comp = client.error_feedback.compensate(vec)
            data = self.packetizer.codec.encode(comp)
            decoded = self.packetizer.codec.decode(data)
            client.error_feedback.update(comp, decoded)
        else:
            data = self.packetizer.codec.encode(vec)
        packets = packetize(data, client.addr,
                            self._txn_up(self._round_idx),
                            self.packetizer.mtu)
        node = self.sim.node(client.addr)
        self._make_sender(
            node, self.server_node, packets,
            on_fail=lambda s, a=client.addr: self._uplink_failed(a)).start()

    def _make_sender(self, src, dst, packets, on_fail=None):
        def _fail(sender) -> None:
            self._note_retx(sender)
            if on_fail is not None:
                on_fail(sender)
        return self.transport.create_sender(
            self.sim, src, dst, packets, self.cfg.transport,
            on_complete=self._note_retx, on_fail=_fail)

    def _note_retx(self, sender) -> None:
        self._round_retx += getattr(sender.stats, "retransmissions", 0)

    # -- server-side delivery --------------------------------------------------
    def _on_server_delivery(self, d: Delivery) -> None:
        if not d.complete and not self.transport.caps.partial_delivery:
            return  # a reliable transport never hands over a partial payload
        self._ingest_update(d.sender_addr, d.txn, d.reassemble())

    def _decode_vec(self, data: bytes) -> np.ndarray:
        """Decode a (possibly zero-filled) byte stream to a model-sized
        vector; undecodable or mis-sized payloads degrade to zeros, the
        capability-driven path for partial deliveries."""
        n_expected = flatten_to_vector(self.global_params).size
        try:
            vec = self.packetizer.codec.decode(data)
        except Exception:
            vec = np.zeros(n_expected, dtype=np.float32)
        if vec.size < n_expected:
            vec = np.concatenate(
                [vec, np.zeros(n_expected - vec.size, dtype=np.float32)])
        return vec[:n_expected]

    def _ingest_update(self, sender_addr: str, txn: int, data: bytes) -> None:
        vec = self._decode_vec(data)
        upd_round = self._round_of_txn(txn)
        if upd_round != self._round_idx or not self._round_open:
            # Straggler from a previous round: fold next round, discounted.
            self._late_buffer.append((upd_round, sender_addr, vec))
            return
        self._updates[sender_addr] = vec
        self.pool.record_success(sender_addr)
        self._mark_resolved(sender_addr)

    def _uplink_failed(self, addr: str) -> None:
        if addr in self._roster and addr not in self._resolved:
            self._failed.append(addr)
            self.pool.record_failure(addr, self._round_idx)
            self._mark_resolved(addr)

    def _mark_resolved(self, addr: str) -> None:
        self._resolved.add(addr)
        if self._round_open and self._resolved >= set(self._roster):
            self._finalize()

    def _on_deadline(self) -> None:
        if self._round_open:
            self.sim.log(f"t={self.sim.now_ns}ns SERVER round "
                         f"{self._round_idx} deadline -> straggler cutoff "
                         f"({len(self._updates)}/{len(self._roster)} arrived)")
            self._finalize()

    # -- aggregation -----------------------------------------------------------
    def _finalize(self) -> None:
        self._round_open = False
        if self._deadline_timer is not None:
            self._deadline_timer.cancel()
            self._deadline_timer = None

        self._late_folded = 0
        contribs: list[tuple[np.ndarray, float]] = []
        for addr, vec in self._updates.items():
            contribs.append((vec, self._roster[addr].weight))
        for upd_round, addr, vec in self._late_buffer:
            age = max(1, self._round_idx - upd_round)
            w = (self.cfg.staleness_discount ** age)
            client = self.pool.clients.get(addr)
            contribs.append((vec, w * (client.weight if client else 1.0)))
            self._late_folded += 1
        self._late_buffer = []
        if not contribs:
            return

        template = self.global_params
        if self.cfg.send_deltas:
            vecs = [v for v, _ in contribs]
            ws = np.asarray([w for _, w in contribs], dtype=np.float32)
            mean_delta = sum(w * v for v, w in zip(vecs, ws)) / ws.sum()
            delta_tree = unflatten_from_vector(
                mean_delta.astype(np.float32), template)
            self.global_params = agg.apply_delta(
                template, delta_tree, self.cfg.server_lr)
            return

        trees = [unflatten_from_vector(v, template) for v, _ in contribs]
        weights = [w for _, w in contribs]
        if self.cfg.aggregation == "pairwise":
            # Paper Eq. 1: fold per arrival order.
            g = self.global_params
            for t in trees:
                g = agg.pairwise_average(g, t)
            self.global_params = g
        elif self.cfg.aggregation == "fedavg":
            self.global_params = agg.fedavg(trees, weights)
        elif self.cfg.aggregation == "trimmed_mean":
            self.global_params = agg.trimmed_mean(trees)
        else:
            raise ValueError(f"unknown aggregation {self.cfg.aggregation}")
