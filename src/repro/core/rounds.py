"""Federated-learning round orchestration (paper Fig. 4, generalized).

One round, per the paper: the server broadcasts the global model; each client
trains locally; the client ships its weights to the server in packets over the
Modified UDP; the server aggregates (Eq. 1) and the transport-level ACK
``(0, 0, A_server)`` closes the client's transaction.

Beyond the paper (required at thousand-node scale):
 * round deadline -> straggler cutoff: aggregate whoever arrived (the paper's
   timer, promoted from packet level to round level);
 * async late-update buffer: a straggler's update that lands after the
   deadline is folded into the NEXT round with a staleness discount;
 * elastic client pool with health tracking (transport failures demote a
   client; it is re-admitted after a cool-down);
 * delta transmission + lossy codecs with error feedback;
 * pluggable transport (any name in ``available_transports()``, dispatched
   through the ``repro.core.transport`` registry) and aggregation
   (pairwise | fedavg | trimmed_mean, numpy or Pallas-kernel backend);
 * pluggable **scheduling**: ``FLConfig.mode`` selects the round policy —
   ``"sync"`` (the paper's barrier, bit-compatible with the historical
   loop) or ``"async"`` (FedBuff-style overlapping rounds, see
   ``docs/ASYNC.md``).

This module is the stable facade.  The event-driven mechanics live in
``repro.core.server`` (per-client :class:`ClientSession` pipelines over one
:class:`ServerCore`); the policies live in ``repro.core.scheduling``.
``FLConfig`` / ``RoundResult`` / ``FLClient`` / ``ClientPool`` are defined
in ``repro.core.server`` and re-exported here, alongside
``TransportConfig``, for backward compatibility.
"""

from __future__ import annotations

from typing import Any, Callable, Optional

from repro.core.scheduling import make_scheduler, sample_participants  # noqa: F401
from repro.core.server import (ClientPool, ClientSession, FLClient,  # noqa: F401
                               FLConfig, RoundResult, ServerCore)
from repro.core.simulator import Simulator
from repro.core.transport import TransportConfig  # noqa: F401  (re-export)

__all__ = [
    "ClientPool", "ClientSession", "FederatedSystem", "FLClient", "FLConfig",
    "RoundResult", "ServerCore", "TransportConfig",
]


class FederatedSystem:
    """Server + clients + transport over one Simulator.

    A thin facade binding a :class:`ServerCore` (mechanics) to the
    scheduler named by ``cfg.mode`` (policy).  ``run_round`` /
    ``run_rounds`` keep their historical signatures: under ``sync`` each
    call is one barrier round; under ``async`` each result is one buffered
    aggregation and ``run_rounds(n)`` performs up to ``n`` of them over
    continuously overlapping client sessions.
    """

    def __init__(self, sim: Simulator, server_addr: str,
                 clients: list[FLClient], global_params: Any,
                 cfg: Optional[FLConfig] = None):
        self.cfg = cfg or FLConfig()
        self.sim = sim
        self.server_addr = server_addr
        self.core = ServerCore(sim, server_addr, clients, global_params,
                               self.cfg)
        self.scheduler = make_scheduler(self.cfg.mode, self.core)

    # -- the stable surface ---------------------------------------------------
    def run_round(self, round_idx: Optional[int] = None) -> RoundResult:
        return self.scheduler.run_round(round_idx)

    def run_rounds(self, n: int) -> list[RoundResult]:
        return self.scheduler.run_rounds(n)

    def add_client(self, client: FLClient) -> None:
        """Elastic join (between rounds under sync; any time under async)."""
        self.core.pool.add(client)
        self.core.install_client_rx(client)
        self.scheduler.on_client_added(client)

    def remove_client(self, addr: str) -> None:
        self.core.pool.remove(addr)

    # -- state owned by the core, surfaced here for compatibility ------------
    @property
    def global_params(self) -> Any:
        return self.core.global_params

    @global_params.setter
    def global_params(self, value: Any) -> None:
        self.core.global_params = value

    @property
    def pool(self) -> ClientPool:
        return self.core.pool

    @property
    def history(self) -> list[RoundResult]:
        return self.core.history

    @property
    def on_round_end(self) -> Optional[Callable[[RoundResult, Any], None]]:
        return self.core.on_round_end

    @on_round_end.setter
    def on_round_end(self,
                     cb: Optional[Callable[[RoundResult, Any], None]]) -> None:
        self.core.on_round_end = cb

    @property
    def transport(self):
        return self.core.transport

    @property
    def packetizer(self):
        return self.core.packetizer

    @property
    def server_node(self):
        return self.core.server_node
