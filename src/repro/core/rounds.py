"""The stable FL facade: :class:`FederatedSystem` = one core + one policy.

This module no longer implements rounds — it *binds*.  Everything that used
to live in the historical round loop has a dedicated home:

* **mechanics** — ``repro.core.server``: :class:`ServerCore` (transport
  dispatch, downlink/train/uplink legs, wire-pipeline encode/decode with
  explicit degradation, the late-update staleness buffer, health tracking,
  aggregation math) and the per-client :class:`ClientSession` state machine;
* **policy** — ``repro.core.scheduling``: ``FLConfig.mode`` picks
  ``"sync"`` (the paper's Fig. 4 barrier, bit-compatible with the
  historical loop — pinned by ``tests/test_orchestrator_equivalence.py``)
  or ``"async"`` (FedBuff-style overlapping rounds, ``docs/ASYNC.md``);
* **wire** — ``repro.core.wire``: per-direction codec pipelines
  (``TransportConfig.uplink`` / ``downlink`` specs such as
  ``"delta|ef|topk(0.01)|int8(1024)"``), self-describing on the wire; the
  legacy ``TransportConfig.codec`` string still works byte-identically
  (``docs/WIRE.md``);
* **transports** — ``repro.core.transport``: any name in
  ``available_transports()``, dispatched through the registry.

:class:`FederatedSystem` keeps the historical surface — ``run_round`` /
``run_rounds`` / ``add_client`` / ``global_params`` / ``history`` — so
callers written against the pre-refactor orchestrator keep working
unchanged.  ``FLConfig`` / ``RoundResult`` / ``FLClient`` / ``ClientPool``
are defined in ``repro.core.server`` and re-exported here, alongside
``TransportConfig``, for backward compatibility.
"""

from __future__ import annotations

from typing import Any, Callable, Optional

from repro.core.scheduling import make_scheduler, sample_participants  # noqa: F401
from repro.core.server import (ClientPool, ClientSession, FLClient,  # noqa: F401
                               FLConfig, RoundResult, ServerCore)
from repro.core.simulator import Simulator
from repro.core.transport import TransportConfig  # noqa: F401  (re-export)

__all__ = [
    "ClientPool", "ClientSession", "FederatedSystem", "FLClient", "FLConfig",
    "RoundResult", "ServerCore", "TransportConfig",
]


class FederatedSystem:
    """Server + clients + transport over one Simulator.

    A thin facade binding a :class:`ServerCore` (mechanics) to the
    scheduler named by ``cfg.mode`` (policy).  ``run_round`` /
    ``run_rounds`` keep their historical signatures: under ``sync`` each
    call is one barrier round; under ``async`` each result is one buffered
    aggregation and ``run_rounds(n)`` performs up to ``n`` of them over
    continuously overlapping client sessions.
    """

    def __init__(self, sim: Simulator, server_addr: str,
                 clients: list[FLClient], global_params: Any,
                 cfg: Optional[FLConfig] = None):
        self.cfg = cfg or FLConfig()
        self.sim = sim
        self.server_addr = server_addr
        self.core = ServerCore(sim, server_addr, clients, global_params,
                               self.cfg)
        self.scheduler = make_scheduler(self.cfg.mode, self.core)

    # -- the stable surface ---------------------------------------------------
    def run_round(self, round_idx: Optional[int] = None) -> RoundResult:
        return self.scheduler.run_round(round_idx)

    def run_rounds(self, n: int) -> list[RoundResult]:
        return self.scheduler.run_rounds(n)

    def add_client(self, client: FLClient) -> None:
        """Elastic join (between rounds under sync; any time under async)."""
        self.core.pool.add(client)
        self.core.install_client_rx(client)
        self.scheduler.on_client_added(client)

    def remove_client(self, addr: str) -> None:
        self.core.remove_client(addr)

    # -- state owned by the core, surfaced here for compatibility ------------
    @property
    def global_params(self) -> Any:
        return self.core.global_params

    @global_params.setter
    def global_params(self, value: Any) -> None:
        self.core.global_params = value

    @property
    def pool(self) -> ClientPool:
        return self.core.pool

    @property
    def history(self) -> list[RoundResult]:
        return self.core.history

    @property
    def on_round_end(self) -> Optional[Callable[[RoundResult, Any], None]]:
        return self.core.on_round_end

    @on_round_end.setter
    def on_round_end(self,
                     cb: Optional[Callable[[RoundResult, Any], None]]) -> None:
        self.core.on_round_end = cb

    @property
    def transport(self):
        return self.core.transport

    @property
    def packetizer(self):
        return self.core.packetizer

    @property
    def server_node(self):
        return self.core.server_node
